"""Generation demo: prefill + sampled decode across model families.

    PYTHONPATH=src python examples/generate_text.py --arch zamba2-2.7b
"""
import argparse
import time

import jax

from repro.configs import get_config, list_archs
from repro.models import init_model
from repro.models.generate import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list_archs())
    ap.add_argument("--tokens", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--top-k", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.embeds_in:
        raise SystemExit(f"{cfg.name} consumes codec embeddings; "
                         "see examples/train_lm_backbone.py for its path")
    params = init_model(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    t0 = time.perf_counter()
    out = generate(params, cfg, prompt, max_new_tokens=args.tokens,
                   key=jax.random.PRNGKey(2), temperature=args.temperature,
                   top_k=args.top_k)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} (reduced, family={cfg.family})")
    for b in range(out.shape[0]):
        print(f"  prompt {list(map(int, prompt[b]))} -> {list(map(int, out[b]))}")
    print(f"{out.size} tokens in {dt:.1f}s ({out.size / dt:.1f} tok/s on CPU, "
          "untrained weights — ids only)")


if __name__ == "__main__":
    main()
