"""Online-serving simulation on the `repro.serve` subsystem.

    PYTHONPATH=src python examples/serve_lsplm.py

The production story of §4: a trained Theta is PRUNED into a deployable
artifact (L1/L2,1 leave ~2-5% of feature rows alive — only those ship),
and every page view is scored as one BUNDLE (1 user id list + N ad
candidates) with the user half of Theta^T x computed once per bundle
(the serving side of Eq. 13). This example drives all of it through the
one inference layer everything in the repo now uses (`repro.serve`):

  1. compress -> save -> load a pruned artifact; pruned scoring is
     bit-identical to full-Theta scoring on the sparse paths;
  2. session-shared vs naive per-ad bundle scoring (same scores, the
     shared path skips the (N-1)/N redundant user gathers);
  3. the ScoringEngine on ragged request traffic: bucketed envelopes,
     per-bucket cached executables, steady state with ZERO recompiles.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.sparse import generate_sparse
from repro.serve import (
    ScoreBundle,
    ScoringEngine,
    as_model,
    compress,
    load_artifact,
    save_artifact,
    score_bundles,
    score_bundles_naive,
    score_sparse,
    synthetic_requests,
)

D = 500_000  # feature columns (production width)
M = 12       # regions


def bench(fn, *args, iters=50):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def make_model(nnz: float = 0.05) -> jax.Array:
    """A production-like sparsified Theta (Table 2: few % of rows alive)."""
    rng = np.random.default_rng(0)
    theta = rng.normal(size=(D, 2 * M)).astype(np.float32) * 0.05
    theta[rng.random(D) >= nnz] = 0.0  # exact-zero rows, like OWLQN+ leaves
    return jnp.asarray(theta)


def main():
    theta = make_model()
    # normalise (and pad) the full model ONCE at load time — the pad row
    # is part of the served model, not per-request work
    full = as_model(theta)

    # ---- 1. pruned artifact: compress -> save -> load -> parity
    art = compress(theta)
    save_artifact("/tmp/lsplm_artifact.npz", art)
    art = load_artifact("/tmp/lsplm_artifact.npz")
    full_mb = theta.size * 4 / 2**20
    packed_mb = art.theta.size * 4 / 2**20
    remap_mb = art.remap.size * 4 / 2**20
    print(f"model: d={D:,} rows -> {art.num_alive:,} alive "
          f"({art.compression:.1%}); {full_mb:.1f} MiB -> "
          f"{packed_mb + remap_mb:.1f} MiB (rows {packed_mb:.1f} + "
          f"remap {remap_mb:.1f})")

    rng = np.random.default_rng(1)
    ids = jnp.asarray(rng.integers(0, D, (4096, 24)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(4096, 24)).astype(np.float32) / 5.0)
    p_full = score_sparse(full, ids, vals)
    p_pruned = score_sparse(art, ids, vals)
    np.testing.assert_array_equal(np.asarray(p_full), np.asarray(p_pruned))
    t_full = bench(jax.jit(lambda i, v: score_sparse(full, i, v)), ids, vals)
    t_pruned = bench(jax.jit(lambda i, v: score_sparse(art, i, v)), ids, vals)
    print(f"flat sparse scoring, 4096 requests: full {t_full * 1e6:7.1f} us, "
          f"pruned {t_pruned * 1e6:7.1f} us (scores BIT-IDENTICAL)")

    # ---- 2. session-shared vs naive per-ad bundle scoring
    batch = generate_sparse(num_features=D,
                            num_user_features_range=(300_000, D),
                            sessions=64, ads_per_session=30,
                            seed=2, with_plans=False)
    bundle = ScoreBundle(batch.user_ids, batch.user_vals,
                         batch.ad_ids, batch.ad_vals, batch.session_id)
    p_shared = score_bundles(art, bundle)
    p_naive = score_bundles_naive(art, bundle)
    np.testing.assert_allclose(np.asarray(p_shared), np.asarray(p_naive),
                               rtol=1e-5, atol=1e-6)
    t_shared = bench(jax.jit(lambda b: score_bundles(art, b)), bundle)
    t_naive = bench(jax.jit(lambda b: score_bundles_naive(art, b)), bundle)
    n_ads = bundle.ad_ids.shape[0]
    print(f"bundles: 64 page views x 30 ads = {n_ads} candidates")
    print(f"session-shared scoring: {t_shared * 1e6:8.1f} us/batch "
          f"({n_ads / t_shared:,.0f} ads/s)")
    print(f"naive per-ad scoring  : {t_naive * 1e6:8.1f} us/batch "
          f"({n_ads / t_naive:,.0f} ads/s)")
    print(f"speedup: {t_naive / t_shared:.2f}x  (scores identical)")

    # ---- 3. the engine on ragged online traffic
    engine = ScoringEngine(art)
    requests = synthetic_requests(256, num_features=D, seed=3)
    engine.warm({engine.envelope(r) for r in requests})  # deploy-time warmup
    warm_compiles = engine.stats.compiles
    engine.score_many(requests)  # steady state
    s = engine.stats
    assert s.compiles == warm_compiles, "steady state must not recompile"
    print(f"engine: {s.requests} ragged requests over "
          f"{len(s.bucket_hits)} buckets, {s.compiles} compiles "
          f"(ALL during warmup), {s.latency_us:.0f} us/request, "
          f"{s.candidates_per_sec:,.0f} ads/s")


if __name__ == "__main__":
    main()
