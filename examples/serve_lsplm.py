"""Online-serving simulation: batched CTR scoring with session-grouped
requests (the serving-side common-feature trick).

    PYTHONPATH=src python examples/serve_lsplm.py

Each page view produces one request bundle: 1 user-feature vector + N ad
candidates. The server computes the user part of Theta^T x ONCE per bundle
(Eq. 13) and scores all candidates, exactly like the paper's production
serving path. Reports per-bundle latency and throughput vs the naive path.

Part 2 scores PADDED-COO sparse requests (the real production wire format:
K active ids out of d columns) through the fused sparse kernel
(`repro.kernels.lsplm_sparse_fused`) and compares it against the
gather+einsum reference and against densifying the batch.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import CTRDataConfig, generate, to_dense_batch
from repro.data.sparse import pad_theta
from repro.kernels.lsplm_sparse_fused.ops import lsplm_sparse_forward
from repro.kernels.lsplm_sparse_fused.ref import lsplm_sparse_forward_ref

CFG = CTRDataConfig(num_user_features=512, num_ad_features=32,
                    noise_features=0, ads_per_session=30, density=0.1, seed=0)
M = 12


@jax.jit
def score_bundles(theta, x_common, x_nc, session_id):
    """Compressed scoring: user dot-products once per session (Eq. 13)."""
    d_c = x_common.shape[-1]
    z = (x_common @ theta[:d_c])[session_id] + x_nc @ theta[d_c:]
    m = theta.shape[-1] // 2
    gate = jax.nn.softmax(z[..., :m], axis=-1)
    fit = jax.nn.sigmoid(z[..., m:])
    return jnp.sum(gate * fit, axis=-1)


@jax.jit
def score_dense(theta, x):
    m = theta.shape[-1] // 2
    z = x @ theta
    gate = jax.nn.softmax(z[..., :m], axis=-1)
    fit = jax.nn.sigmoid(z[..., m:])
    return jnp.sum(gate * fit, axis=-1)


def main():
    rng = np.random.default_rng(0)
    d = CFG.num_features
    theta = jnp.asarray(rng.normal(size=(d, 2 * M)) * 0.05, jnp.float32)
    # sparsify like a production model (Table 2: ~2% nnz)
    theta = theta * (rng.random(theta.shape) < 0.05)

    batch, _ = generate(CFG, num_sessions=64, seed=3)  # 64 page views in flight
    dense = to_dense_batch(batch)
    xc = jnp.asarray(batch.x_common)
    xnc = jnp.asarray(batch.x_noncommon)
    sid = jnp.asarray(batch.session_id)
    xd = jnp.asarray(dense.x)

    p1 = score_bundles(theta, xc, xnc, sid)
    p2 = score_dense(theta, xd)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=2e-3, atol=2e-5)

    def bench(fn, *args, iters=50):
        jax.block_until_ready(fn(*args))
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / iters

    t_cf = bench(score_bundles, theta, xc, xnc, sid)
    t_dense = bench(score_dense, theta, xd)
    n_ads = xd.shape[0]
    print(f"bundles: 64 page views x {CFG.ads_per_session} ads = {n_ads} candidates")
    print(f"common-feature scoring: {t_cf * 1e6:8.1f} us/batch "
          f"({n_ads / t_cf:,.0f} ads/s)")
    print(f"naive dense scoring   : {t_dense * 1e6:8.1f} us/batch "
          f"({n_ads / t_dense:,.0f} ads/s)")
    print(f"speedup: {t_dense / t_cf:.2f}x  (scores identical)")

    serve_sparse(bench)


def serve_sparse(bench, n_req: int = 16384, K: int = 24,
                 d: int = 500_000, m: int = 12):
    """Part 2: production-width sparse scoring through the fused kernel."""
    rng = np.random.default_rng(1)
    theta = jnp.asarray(rng.normal(size=(d, 2 * m)) * 0.05, jnp.float32)
    theta = theta * (rng.random(theta.shape) < 0.05)  # Table-2-like nnz
    ids = jnp.asarray(rng.integers(0, d, (n_req, K)), jnp.int32)
    vals = jnp.asarray(
        rng.normal(size=(n_req, K)).astype(np.float32) / np.sqrt(K))

    # pad Theta ONCE at model-load time — the zero pad row is part of the
    # served model, not of the per-request work.
    tp = pad_theta(theta)
    score_fused = jax.jit(lambda i, v, t: lsplm_sparse_forward(i, v, t))
    score_ref = jax.jit(lsplm_sparse_forward_ref)
    p1 = score_fused(ids, vals, tp)
    p2 = score_ref(ids, vals, tp)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                               rtol=2e-4, atol=2e-6)

    t_fused = bench(score_fused, ids, vals, tp)
    t_ref = bench(score_ref, ids, vals, tp)
    print(f"\nsparse requests: {n_req} x {K} active ids of d={d:,} "
          f"(dense batch would be {n_req * d * 4 / 2**30:.1f} GiB — never built)")
    print(f"fused sparse scoring  : {t_fused * 1e6:8.1f} us/batch "
          f"({n_req / t_fused:,.0f} ads/s)")
    print(f"gather+einsum scoring : {t_ref * 1e6:8.1f} us/batch "
          f"({n_req / t_ref:,.0f} ads/s)")
    print(f"speedup: {t_ref / t_fused:.2f}x  (scores identical)")


if __name__ == "__main__":
    main()
