"""Streaming day-by-day LS-PLM training: the paper's production cadence.

    PYTHONPATH=src python examples/train_sparse_streaming.py

The full-batch OWLQN+ of the paper is how ONE retrain runs; Alibaba's
system retrains as new days of impressions arrive. This example runs
that loop on a synthetic drifted day stream (``repro.stream``):

  * a :class:`DayStream` yields per-day padded-COO batches whose
    Zipf-hot id head ROTATES a little every day (real CTR traffic:
    new ads/users heat up, old ones cool off);
  * per day, the trainer re-plans the sliding window of the last W days
    on the host — transpose plans + (re)compilation — OVERLAPPED with
    the previous window's device iterations (``WindowPlanner``), then
    runs a bounded budget of warm-started OWLQN+ steps;
  * Theta carries across windows bit-exactly (exact zeros stay exact
    zeros), the L-BFGS history resets at boundaries by default
    (``history="carry"`` keeps it — useful at small drift);
  * every window ends in a resumable checkpoint
    (Theta + OWLQN+ history + day cursor, ``repro.io.checkpoint``).

The punchline printed at the end: held-out NEXT-day NLL of the streamed
model vs a train-once model given the same total iteration budget on
day 0 — under drift, the stream wins — plus the planner's measured
overlap ratio. ``benchmarks/bench_stream.py`` measures the
overlapped-vs-synchronous steps/sec speedup on production shapes.
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.objective import nll_sparse
from repro.data import auc
from repro.data.sparse import sparse_predict
from repro.stream import DayStream, StreamTrainer

D, M = 400, 4
DAYS, WINDOW, INNER = 6, 2, 5
LAM = BETA = 0.25


def main():
    # DAYS of training traffic + one held-out next day
    # sized so ids repeat enough for a CPU demo to LEARN the drifting
    # head (production-width shapes are bench_stream's job)
    stream = DayStream(DAYS + 1, sessions_per_day=192, num_features=D,
                       active_user=8, active_ad=5, drift=0.06,
                       head_width=0.06, head_frac=0.85, seed=11)
    theta0 = jnp.asarray(
        0.01 * np.random.default_rng(0).normal(size=(D, 2 * M)), jnp.float32)
    held = stream.day(DAYS)
    B = held.y.shape[0]

    def next_day(theta):
        p = np.asarray(sparse_predict(theta, held))
        return (float(nll_sparse(theta, held)) / B,
                auc(np.asarray(held.y), p))

    trainer = StreamTrainer(stream, lam=LAM, beta=BETA, window=WINDOW,
                            inner_iters=INNER)
    print(f"stream: {DAYS} days x {stream.sessions_per_day} sessions, "
          f"d={D:,}, window={WINDOW} days, {INNER} OWLQN+ iters/window, "
          f"overlapped re-planner")
    t0 = time.perf_counter()
    state, trace = trainer.run(
        trainer.init(theta0), days=DAYS,
        callback=lambda t, ws, st: print(
            f"  day {t}  window={ws.days_in_window}d f={ws.fs[-1]:9.2f} "
            f"nnz={ws.nnz:6d} plan={ws.build_seconds * 1e3:5.0f}ms "
            f"step={ws.step_seconds * 1e3:5.0f}ms"))
    dt = time.perf_counter() - t0
    ps = trainer.planner_stats
    print(f"streamed {DAYS} windows in {dt:.1f}s — host re-planning "
          f"{ps.build_seconds:.1f}s, only {ps.wait_seconds:.1f}s exposed "
          f"(overlap ratio {ps.overlap_ratio:.2f})")

    # train-once baseline: the SAME total iteration budget, all on day 0
    base = StreamTrainer(stream, lam=LAM, beta=BETA, window=1,
                         inner_iters=INNER * DAYS)
    base_state, _ = base.run(base.init(theta0), days=1)

    nll_s, auc_s = next_day(trainer.theta(state))
    nll_b, auc_b = next_day(base.theta(base_state))
    print(f"\nheld-out day {DAYS} (next day after the stream):")
    print(f"  train-once on day 0 : NLL {nll_b:.4f}  AUC {auc_b:.4f}")
    print(f"  streamed (window={WINDOW}): NLL {nll_s:.4f}  AUC {auc_s:.4f}")
    print(f"  drift makes the stale model pay "
          f"{(nll_b - nll_s) / nll_s * 100:+.1f}% NLL")


if __name__ == "__main__":
    main()
