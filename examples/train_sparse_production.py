"""Production-regime LS-PLM: 1M sparse feature columns, 8M parameters.

    PYTHONPATH=src python examples/train_sparse_production.py

Dense (B, d) features are impossible at this width (a 2048-sample batch
would be 8 TB); the padded-COO sparse path (`repro.data.sparse`) stores
only active ids — exactly the paper's one-hot regime — and OWLQN+ trains
Theta (1e6 x 8) with L1+L2,1 sparsity.

Execution: the whole job rides the FUSED sparse kernel package
(`repro.kernels.lsplm_sparse_fused`) — a pipelined block-DMA Pallas
gather-matmul on TPU (scalar-prefetched ids, double-buffered K-row
blocks, Theta in HBM), K-chunked `lax.scan` accumulation on CPU/GPU, and
a custom-VJP backward scheduled by per-batch TRANSPOSE PLANS
(`generate_sparse` attaches them): the id->entries sort happens once on
the host, every optimizer step then runs sort-free, scatter-free segment
sums into active Theta rows only. No (B, d) batch is ever built, and the
(N, K, 2m) gather blob exists only below ``ROWS_REUSE_LIMIT`` — where it
is deliberately kept as a VJP residual so the backward skips re-gathering
— never at production batch sizes like this one.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.sparse import (
    generate_sparse,
    sparse_loss_and_grad,
    sparse_predict,
)
from repro.eval import report
from repro.optim import OWLQNPlus

D = 1_000_000
M = 4


def main():
    train = generate_sparse(num_features=D, sessions=2048, seed=1)
    test = generate_sparse(num_features=D, sessions=128, seed=2)
    theta0 = jnp.asarray(
        0.01 * np.random.default_rng(0).normal(size=(D, 2 * M)), jnp.float32)
    n_samples = np.asarray(train.ad_ids).shape[0]
    backend = jax.default_backend()
    print(f"sparse execution path: fused kernel "
          f"({'pipelined Pallas' if backend == 'tpu' else 'scan-jnp fallback'}, "
          f"backend={backend}), transpose-plan custom VJP "
          f"({train.ad_plan.num_unique:,} unique ad ids, "
          f"{train.user_plan.num_unique:,} unique user ids)")
    print(f"features d = {D:,}; params = {theta0.size:,} "
          f"(this batch dense: {n_samples * D * 4 / 2**30:.1f} GiB; one of "
          f"the paper's 1.4e9-sample days dense: "
          f"{1.4e9 * D * 4 / 2**50:.1f} PiB — sparse batch here: "
          f"{np.asarray(train.ad_ids).nbytes / 2**20:.1f} MB)")

    opt = OWLQNPlus(lambda t: sparse_loss_and_grad(t, train), lam=0.05, beta=0.05)
    t0 = time.perf_counter()
    theta, trace = opt.run(theta0, max_iters=40)
    dt = time.perf_counter() - t0

    p = np.asarray(sparse_predict(theta, test))
    r = report(np.asarray(test.y), p)
    nnz_rows = int((np.abs(np.asarray(theta)).sum(1) > 0).sum())
    print(f"trained {len(trace)} iters in {dt:.1f}s  "
          f"f {float(trace[0].f):.1f} -> {float(trace[-1].f_new):.1f}")
    print(f"test: AUC={r['auc']:.4f} NE={r['normalized_entropy']:.4f} "
          f"calibration={r['calibration']:.3f}")
    print(f"sparsity: {nnz_rows:,}/{D:,} feature rows non-zero "
          "(only ids seen in training can survive)")
    print("note: test AUC is bounded by cold-id coverage (ids never seen "
          "in training score 0.5 by construction) — the paper's billions "
          "of samples make coverage a non-issue; this example demonstrates "
          "the SPARSE SUBSTRATE at production width, whose exactness vs "
          "the dense path is proven in tests/test_sparse.py")


if __name__ == "__main__":
    main()
