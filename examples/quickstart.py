"""Quickstart: train LS-PLM on nonlinear CTR data, compare with LR.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's core story in one page: LR underfits the nonlinear
click distribution; LS-PLM (Eq. 2) fits it; L1+L2,1 (Eq. 4) keeps the
model sparse; Algorithm 1 optimises the non-convex non-smooth objective.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import CTRBatch, predict_proba, regularizers
from repro.core.lsplm import params_from_theta
from repro.core.objective import smooth_loss_and_grad
from repro.data import CTRDataConfig, auc, generate, to_dense_batch
from repro.optim import OWLQNPlus


def fit(tb, d, m, lam, beta, iters):
    theta0 = jnp.asarray(
        0.01 * np.random.default_rng(0).normal(size=(d, 2 * m)), jnp.float32)
    opt = OWLQNPlus(lambda t: smooth_loss_and_grad(t, tb), lam=lam, beta=beta)
    theta, trace = opt.run(theta0, max_iters=iters)
    return theta, trace


def main():
    cfg = CTRDataConfig(num_user_features=24, num_ad_features=24,
                        noise_features=8, true_regions=4, seed=0)
    train = to_dense_batch(generate(cfg, 4000, seed=1)[0])
    test = to_dense_batch(generate(cfg, 800, seed=2)[0])
    tb = CTRBatch(x=jnp.asarray(train.x), y=jnp.asarray(train.y))

    print("== LR baseline (m=1, L1) ==")
    theta_lr, tr = fit(tb, cfg.num_features, m=1, lam=0.0, beta=1.0, iters=30)
    p_lr = predict_proba(params_from_theta(theta_lr), jnp.asarray(test.x))
    print(f"  iters={len(tr)}  test AUC = {auc(test.y, np.asarray(p_lr)):.4f}")

    print("== LS-PLM (m=12, L1 + L2,1 — the paper's production setting) ==")
    theta, tr = fit(tb, cfg.num_features, m=12, lam=1.0, beta=1.0, iters=70)
    p = predict_proba(params_from_theta(theta), jnp.asarray(test.x))
    nnz = int(regularizers.nonzero_count(theta))
    nfeat = int(regularizers.nonzero_feature_count(theta))
    print(f"  iters={len(tr)}  test AUC = {auc(test.y, np.asarray(p)):.4f}")
    print(f"  sparsity: {nnz}/{theta.size} non-zero params, "
          f"{nfeat}/{cfg.num_features} features kept")
    print("  (noise features pruned by the L2,1 group penalty: "
          f"last {cfg.noise_features} rows nnz = "
          f"{int((np.asarray(theta)[-cfg.noise_features:] != 0).sum())})")


if __name__ == "__main__":
    main()
