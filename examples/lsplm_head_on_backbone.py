"""Beyond-paper integration: LS-PLM as a CTR head on a transformer
backbone, trained with the paper's OWLQN+ for structured sparsity.

    PYTHONPATH=src python examples/lsplm_head_on_backbone.py

A reduced llama-family backbone embeds 'ad text' token sequences; the
LS-PLM head (repro.core.head) predicts clicks from the pooled embedding.
OWLQN+ applies L1+L2,1 over the head's (embed_dim x 2m) parameters —
feature selection now prunes BACKBONE CHANNELS (each embedding channel is
a group), the transformer-era analogue of the paper's feature selection.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.head import init_head
from repro.core.lsplm import LSPLMParams, predict_logits_stable
from repro.data import auc
from repro.models import forward, init_model
from repro.optim import OWLQNPlus


def main():
    cfg = get_config("llama3.2-1b").reduced()
    backbone = init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # synthetic 'ad text' + clicks whose truth depends nonlinearly on a
    # subset of embedding channels
    B, S = 512, 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    @jax.jit
    def features(tokens):
        logits, _ = forward(backbone, cfg, tokens=tokens, remat=False)
        # take last-position logits' top slice as a fixed random projection
        return jnp.tanh(logits[:, -1, : cfg.d_model] * 0.1)

    h = features(tokens)  # (B, d_model)
    d = h.shape[-1]
    w_true = rng.normal(size=(16,))
    sel = rng.choice(d, size=16, replace=False)
    logit_true = np.tanh(np.asarray(h)[:, sel] @ w_true) * 3.0
    y = jnp.asarray((rng.random(B) < 1 / (1 + np.exp(-logit_true))).astype(np.float32))

    m = 6
    head0 = init_head(jax.random.PRNGKey(1), d, num_regions=m)
    theta0 = jnp.concatenate([head0.u, head0.w], axis=1)

    def loss_and_grad(theta):
        def nll(theta):
            params = LSPLMParams(u=theta[:, :m], w=theta[:, m:])
            lp1, lp0 = predict_logits_stable(params, h)
            return -jnp.sum(y * lp1 + (1 - y) * lp0)
        return jax.value_and_grad(nll)(theta)

    opt = OWLQNPlus(loss_and_grad, lam=0.3, beta=0.05)
    theta, trace = opt.run(theta0, max_iters=60)

    params = LSPLMParams(u=theta[:, :m], w=theta[:, m:])
    lp1, _ = predict_logits_stable(params, h)
    a = auc(np.asarray(y), np.exp(np.asarray(lp1)))
    rows_kept = int((np.abs(np.asarray(theta)).sum(1) > 0).sum())
    print(f"train AUC = {a:.4f}")
    print(f"backbone channels kept by L2,1: {rows_kept}/{d} "
          f"(truth uses 16 channels)")
    print(f"iterations: {len(trace)}, final nnz = {int(trace[-1].nnz)}")


if __name__ == "__main__":
    main()
