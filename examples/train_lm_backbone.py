"""Train a reduced transformer backbone on the synthetic LM stream —
exercises the training substrate (AdamW, token pipeline, remat scan).

    PYTHONPATH=src python examples/train_lm_backbone.py --arch llama3.2-1b --steps 30
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.data.tokens import TokenStream
from repro.models import init_model, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"arch={cfg.name} (reduced): {n_params / 1e6:.2f}M params")

    opt, train_step = make_train_step(cfg, lr=3e-3)
    opt_state = opt.init(params)
    step = jax.jit(train_step)
    stream = TokenStream(cfg.vocab_size, seed=0)

    losses = []
    for i in range(args.steps):
        b = stream.batch(args.batch, args.seq + 1)
        if cfg.embeds_in:  # audio-style: embeddings stub instead of tokens
            rngk = jax.random.PRNGKey(i)
            batch = {"embeds": 0.1 * jax.random.normal(
                         rngk, (args.batch, args.seq, cfg.d_model)),
                     "labels": jnp.asarray(b["labels"][:, :args.seq] % cfg.vocab_size)}
        else:
            batch = {"tokens": jnp.asarray(b["tokens"]),
                     "labels": jnp.asarray(b["labels"])}
        t0 = time.perf_counter()
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["ce"]))
        if i % 5 == 0:
            print(f"step {i:3d}  ce={losses[-1]:.4f} "
                  f"({(time.perf_counter() - t0) * 1e3:.0f} ms)")
    print(f"ce: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({'improved' if losses[-1] < losses[0] else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
