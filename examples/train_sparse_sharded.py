"""Distributed sparse LS-PLM: the paper's worker/server split, end to end.

    PYTHONPATH=src python examples/train_sparse_sharded.py

Simulates the paper's §4 cluster on 8 forced host devices as a
(data=2, model=4) mesh and trains the padded-COO sparse path on it:

  * workers ('data')  — each data shard holds 1/2 of the sessions;
  * servers ('model') — each model shard owns a contiguous id RANGE of
    Theta rows (``repro.shard.make_partition``); ids are bucketed per
    shard on the host (``route_batch``), so every gather and every
    plan-driven scatter in the backward is shard-local, and the only
    tensor crossing shards is one psum of the (B, 2m) region-logit
    partials per step.

The per-batch transpose plans are NOT rebuilt per shard: the full
batch's id sort is sliced at the id-range boundaries
(``repro.shard.plan_slicing`` — sorted-by-id layouts split into
contiguous slices), restacked, and handed to ``shard_map`` as sharded
operands. OWLQN+ runs through the same ``repro.dist`` machinery as the
dense path: Theta rows are the L2,1 groups, so the orthant algebra never
crosses a shard boundary.

On real TPU meshes replace ``make_debug_mesh`` with
``launch.mesh.make_production_mesh``; everything else is identical.
"""
import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.sparse import generate_sparse, sparse_predict
from repro.dist import make_distributed_step, shard_sparse_batch, shard_state
from repro.eval import report
from repro.launch.mesh import make_debug_mesh
from repro.optim import OWLQNPlus
from repro.shard import make_partition, make_sharded_sparse_loss, route_batch

D = 200_000
M = 4
MESH_DATA, MESH_MODEL = 2, 4


def main():
    user_range = (int(0.6 * D), D)
    train = generate_sparse(num_features=D, num_user_features_range=user_range,
                            sessions=512, seed=1)
    test = generate_sparse(num_features=D, num_user_features_range=user_range,
                           sessions=64, seed=2)
    theta0 = jnp.asarray(
        0.01 * np.random.default_rng(0).normal(size=(D, 2 * M)), jnp.float32)

    mesh = make_debug_mesh(data=MESH_DATA, model=MESH_MODEL)
    part = make_partition(D, MESH_MODEL)
    sbatch = shard_sparse_batch(
        mesh, route_batch(train, part, data_shards=MESH_DATA))
    print(f"mesh: data={MESH_DATA} x model={MESH_MODEL} on "
          f"{jax.device_count()} devices; Theta ({D:,} x {2 * M}) id-range "
          f"sharded at {part.rows_per_shard:,} rows/shard")
    print(f"routed: user ids (S,G,K)={tuple(sbatch.user_ids.shape)}, "
          f"ad ids={tuple(sbatch.ad_ids.shape)}; plan cells "
          f"(data,model)={tuple(sbatch.ad_plan.row_ids.shape[:2])}, "
          f"{sbatch.ad_plan.num_kept:,} padded entries/cell")

    opt = OWLQNPlus(make_sharded_sparse_loss(sbatch, mesh),
                    lam=0.05, beta=0.05)
    state = shard_state(opt.init(part.pad_rows(theta0)), mesh)
    step = make_distributed_step(opt, mesh)

    t0 = time.perf_counter()
    iters = 30
    for k in range(iters):
        state, stats = step(state)
        if k % 5 == 0 or k == iters - 1:
            print(f"iter {k:3d}  f={float(stats.f_new):12.2f} "
                  f"alpha={float(stats.alpha):.3g} nnz={int(stats.nnz):8d}")
    dt = time.perf_counter() - t0

    shard_shapes = {s.data.shape for s in state.theta.addressable_shards}
    assert shard_shapes == {(D // MESH_MODEL, 2 * M)}, shard_shapes
    theta = part.unpad_rows(jnp.asarray(jax.device_get(state.theta)))
    p = np.asarray(sparse_predict(theta, test))
    r = report(np.asarray(test.y), p)
    print(f"trained {iters} sharded iters in {dt:.1f}s — theta stayed "
          f"row-sharded over 'model' the whole run: {shard_shapes}")
    print(f"test: AUC={r['auc']:.4f} NE={r['normalized_entropy']:.4f} "
          f"calibration={r['calibration']:.3f}")
    print("note: on forced host devices the mesh demonstrates the "
          "DISTRIBUTION PLAN, not speed — all 8 'devices' share this CPU; "
          "parity with the single-device path is proven in "
          "tests/test_shard_step.py")


if __name__ == "__main__":
    main()
