"""WindowPlanner / plan_window invariants: background builds return
exactly what a synchronous build returns, plans attached by plan_window
are bit-identical to build_batch_plans, and the overlap accounting adds
up."""
import time

import jax
import numpy as np
import pytest

from repro.data.sparse import build_batch_plans
from repro.stream import DayStream, WindowPlanner, plan_window
from repro.stream.planner import PreparedWindow


def _stream():
    return DayStream(4, sessions_per_day=16, num_features=1500,
                     active_user=6, active_ad=4, seed=3)


def _plans_equal(a, b):
    la, auxa = jax.tree.flatten(a)
    lb, auxb = jax.tree.flatten(b)
    assert auxa == auxb
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_plan_window_matches_build_batch_plans():
    s = _stream()
    raw = s.window(1, 2)
    got = plan_window(raw)
    want = build_batch_plans(raw)
    _plans_equal(got.user_plan, want.user_plan)
    _plans_equal(got.ad_plan, want.ad_plan)
    np.testing.assert_array_equal(np.asarray(got.ad_ids),
                                  np.asarray(want.ad_ids))


def test_plan_window_routed_matches_manual_route():
    from repro.shard import make_partition, route_batch

    s = _stream()
    raw = s.window(2, 2)
    part = make_partition(s.num_features, 3)
    got = plan_window(raw, partition=part, data_shards=2)
    want = route_batch(build_batch_plans(raw), part, data_shards=2)
    assert got.bounds == want.bounds
    np.testing.assert_array_equal(np.asarray(got.user_ids),
                                  np.asarray(want.user_ids))
    _plans_equal(got.ad_plan, want.ad_plan)


def test_plan_window_mesh_requires_partition():
    with pytest.raises(ValueError, match="partition"):
        plan_window(_stream().day(0), mesh=object())


def _build(day: int) -> PreparedWindow:
    time.sleep(0.05)  # measurable build
    return PreparedWindow(day=day, batch=("batch", day), step=None)


@pytest.mark.parametrize("overlap", [False, True])
def test_planner_returns_same_windows(overlap):
    planner = WindowPlanner(_build, overlap=overlap)
    with planner:
        got = []
        for t in range(3):
            win = planner.get(t)
            planner.prefetch(t + 1)
            got.append(win)
            time.sleep(0.08)  # "device work" the build can hide behind
    assert [w.day for w in got] == [0, 1, 2]
    assert [w.batch for w in got] == [("batch", t) for t in range(3)]
    assert all(w.build_seconds > 0 for w in got)
    st = planner.stats
    assert st.windows == 3
    assert st.build_seconds >= 3 * 0.05
    if overlap:
        # windows 1..2 were prefetched and fully hidden behind the sleep
        assert st.prefetched_build_seconds > 0
        assert st.overlap_ratio > 0.5, st
    else:
        assert st.prefetched_build_seconds == 0.0
        assert st.overlap_ratio == 0.0


def test_planner_sync_get_without_prefetch():
    planner = WindowPlanner(_build, overlap=True)
    with planner:
        win = planner.get(5)  # never prefetched -> builds inline
    assert win.day == 5
    st = planner.stats
    assert st.prefetched_build_seconds == 0.0
    assert st.wait_seconds >= win.build_seconds


def test_planner_close_cancels_pending():
    planner = WindowPlanner(_build, overlap=True)
    planner.prefetch(0)
    planner.close()  # must not hang or raise
    assert planner.stats.windows == 0


def test_overlap_ratio_zero_prefetched_build():
    """Regression: overlap_ratio must be 0.0 (not a ZeroDivisionError)
    when nothing was ever prefetched — fresh planner, sync planner, and
    a stats object reconstructed from zeroed counters alike."""
    from repro.stream.planner import PlannerStats

    assert WindowPlanner(_build, overlap=True).stats.overlap_ratio == 0.0
    assert PlannerStats(windows=3, build_seconds=1.0, wait_seconds=1.0,
                        prefetched_build_seconds=0.0,
                        prefetched_wait_seconds=0.0).overlap_ratio == 0.0
