"""The ledger analytics CLI (``repro.obs.report``): section folding,
md/html rendering, bit-identical reconstruction of driver console
lines, and the CLI's error paths. Everything here feeds on ledger
records only — no model, data, or clock ever enters the report."""
import json

import pytest

from repro import obs
from repro.obs import report
from repro.obs.ledger import render_train_iter


def _ledger(path=None):
    led = obs.RunLedger(path)
    led.emit("run_meta", driver="repro.launch.train", mode="stream",
             backend="cpu", device_count=1, argv=["--stream"])
    for k, (f, nnz) in enumerate([(100.0, 50), (90.0, 40), (85.5, 38)]):
        led.emit("train_iter", step=k, f=f + 1, f_new=f, alpha=0.5,
                 grad_norm=0.1, nnz=nnz, ls_iters=1, test_auc=0.7 + k / 100)
    led.emit("stream_window", day=0, days_in_window=1, plan_s=0.01,
             compile_s=0.1, build_s=0.02, wait_s=0.0, prefetched=False,
             step_s=0.2, carry="reset", alpha=0.5, nnz=38, fs=[2.0, 1.5])
    led.emit("stream_eval", day=0, next_day_nll=0.512345,
             next_day_auc=0.698765)
    led.emit("stream_summary", windows=2, build_seconds=0.1,
             wait_seconds=0.02, prefetched_build_seconds=0.05,
             prefetched_wait_seconds=0.01, overlap_ratio=0.8)
    for reason, wall in (("full", 0.002), ("deadline", 0.001),
                         ("full", 0.003)):
        led.emit("serve_dispatch", envelope=[4, 8, 8, 2], g=4, requests=4,
                 candidates=8, occupancy=1.0, wall_s=wall,
                 flush_reason=reason, queue_delay_us=100.0)
    led.emit("alert", rule="lat", state="firing",
             signal="serve.p99_wall_us", value=3000.0, threshold=2500.0,
             op="<=")
    return led


def test_build_report_sections():
    rep = report.build_report(_ledger().events())
    assert rep["records"] == 11
    assert rep["kinds"]["train_iter"] == 3
    assert rep["meta"]["driver"] == "repro.launch.train"
    conv = rep["convergence"]
    assert conv["iters"] == 3
    assert (conv["f_first"], conv["f_last"]) == (100.0, 85.5)
    assert conv["nnz_last"] == 38
    assert rep["decay"] == [{"day": 0, "next_day_nll": 0.512345,
                             "next_day_auc": 0.698765}]
    assert rep["windows"]["count"] == 1
    assert rep["windows"]["overlap_ratio"] == 0.8
    serving = rep["serving"]
    assert serving["dispatches"] == 3
    assert serving["requests"] == 12
    assert serving["flush_mix"]["full"]["dispatches"] == 2
    assert serving["wall_p50_us"] == pytest.approx(2000.0)
    assert rep["alerts"][0]["rule"] == "lat"


def test_report_reconstructs_console_lines_bit_identically():
    led = _ledger()
    rep = report.build_report(led.events())
    # the exact strings the driver printed during the run, rebuilt from
    # ledger records alone
    want = [render_train_iter(r) for r in led.events("train_iter")]
    assert [r["line"] for r in rep["convergence"]["rows"]] == want
    md = report.render_md(rep)
    for line in want:
        assert line in md
    # the decay table carries the driver's own {:.4f} formatting
    assert "0.5123" in md and "0.6988" in md


def test_render_md_and_html_agree_on_numbers():
    rep = report.build_report(_ledger().events())
    md, html_doc = report.render_md(rep), report.render_html(rep)
    for token in ("85.50", "0.5123", "firing", "deadline", "full"):
        assert token in md, token
        assert token in html_doc, token
    assert html_doc.startswith("<!doctype html>")
    assert "<script" not in html_doc  # self-contained, no external deps


def test_report_without_serving_or_alert_records():
    led = obs.RunLedger(None)
    led.emit("log", text="just a log line")
    rep = report.build_report(led.events())
    assert "serving" not in rep and "convergence" not in rep
    md = report.render_md(rep)
    assert "## Alerts" in md and "_none_" in md
    report.render_html(rep)  # renders without KeyError


def test_cli_writes_report_and_validates(tmp_path, capsys):
    ledger_path = str(tmp_path / "run.jsonl")
    with _ledger(ledger_path):
        pass
    out = tmp_path / "report.md"
    assert report.main([ledger_path, "--out", str(out)]) == 0
    assert out.read_text().startswith("# Run report")
    capsys.readouterr()

    html_out = tmp_path / "report.html"
    assert report.main([ledger_path, "--format", "html",
                        "--out", str(html_out)]) == 0
    assert html_out.read_text().startswith("<!doctype html>")

    assert report.main([ledger_path]) == 0  # stdout mode
    assert "# Run report" in capsys.readouterr().out


def test_cli_rejects_missing_invalid_and_empty_ledgers(tmp_path, capsys):
    assert report.main([str(tmp_path / "nope.jsonl")]) == 1
    assert "FAIL" in capsys.readouterr().err

    bad = tmp_path / "bad.jsonl"
    bad.write_text(json.dumps({"kind": "mystery"}) + "\n")
    assert report.main([str(bad)]) == 1
    assert "FAIL" in capsys.readouterr().err

    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert report.main([str(empty)]) == 1
    assert "empty" in capsys.readouterr().err
