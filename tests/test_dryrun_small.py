"""The dry-run machinery must work end-to-end at CI scale: reduced archs,
tiny shape variants, 2x2 device mesh, in a subprocess with 8 host devices.
(The production 512-device sweep runs via repro.launch.dryrun --all.)"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
import repro.configs as C
from repro.launch import dryrun
from repro.launch.mesh import make_debug_mesh
from repro.utils.hlo import collective_bytes

# shrink the workload shapes for CI
C.INPUT_SHAPES.clear()
C.INPUT_SHAPES.update({
    "train_4k": dict(kind="train", seq_len=64, global_batch=4),
    "prefill_32k": dict(kind="prefill", seq_len=64, global_batch=4),
    "decode_32k": dict(kind="decode", seq_len=64, global_batch=4),
    "long_500k": dict(kind="decode", seq_len=256, global_batch=1),
})
mesh = make_debug_mesh(data=2, model=2)

archs = ["llama3.2-1b", "granite-moe-1b-a400m", "falcon-mamba-7b",
         "zamba2-2.7b", "musicgen-medium", "internvl2-2b"]
shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
for arch in archs:
    cfg = C.get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, num_prefix_embeds=min(cfg.num_prefix_embeds, 8),
                              attn_chunk=32, sliding_window=32)
    for shape in shapes:
        lowered, compiled, meta = dryrun.lower_combo(cfg, shape, mesh)
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):  # jax<=0.4: one dict per device
            ca = ca[0]
        assert ca.get("flops", 0) > 0, (arch, shape)
        txt = compiled.as_text()
        coll = collective_bytes(txt)
        assert compiled.memory_analysis().argument_size_in_bytes > 0
        print(f"ok {arch} {shape} coll_bytes={coll['total_bytes']}")
print("DRYRUN-SMALL-OK")
"""


@pytest.mark.slow
def test_dryrun_machinery_small():
    env = os.environ.copy()
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=1500)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout[-3000:]}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "DRYRUN-SMALL-OK" in r.stdout
