"""Mamba1/Mamba2 layer tests: chunked/scan forward vs step-by-step decode
oracle, state handoff (prefill -> decode), and SSD chunk invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import ssm as S

CFG1 = ArchConfig(name="toy-m1", family="ssm", source="t", num_layers=2,
                  d_model=32, num_heads=0, num_kv_heads=0, d_ff=0,
                  vocab_size=64, ssm_version=1, ssm_state=8, ssm_expand=2,
                  ssm_conv=4)
CFG2 = ArchConfig(name="toy-m2", family="hybrid", source="t", num_layers=2,
                  d_model=32, num_heads=4, num_kv_heads=4, d_ff=64,
                  vocab_size=64, ssm_version=2, ssm_state=8, ssm_expand=2,
                  ssm_conv=4, ssm_headdim=16, shared_attn_every=2)


def test_mamba1_forward_matches_stepwise_decode():
    p = S.init_mamba1(jax.random.PRNGKey(0), CFG1, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 12, CFG1.d_model))
    y_full = S.mamba1_forward(x, p, CFG1)
    y_step = S.mamba_ref_sequential(x, p, CFG1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               rtol=1e-4, atol=1e-5)


def test_mamba2_forward_matches_stepwise_decode():
    p = S.init_mamba2(jax.random.PRNGKey(0), CFG2, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 12, CFG2.d_model))
    y_full = S.mamba2_forward(x, p, CFG2, chunk=4)
    y_step = S.mamba_ref_sequential(x, p, CFG2)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("chunk", [2, 3, 6, 12])
def test_ssd_chunk_size_invariance(chunk):
    """The chunked SSD algorithm must be exact for any chunk size dividing S."""
    if 12 % chunk:
        pytest.skip("chunk must divide S")
    p = S.init_mamba2(jax.random.PRNGKey(0), CFG2, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 12, CFG2.d_model))
    y_ref = S.mamba2_forward(x, p, CFG2, chunk=12)
    y = S.mamba2_forward(x, p, CFG2, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("version", [1, 2])
def test_state_handoff_prefill_to_decode(version):
    """forward(x[:S]) state + decode(x[S]) == forward(x[:S+1]) last output."""
    cfg = CFG1 if version == 1 else CFG2
    init = S.init_mamba1 if version == 1 else S.init_mamba2
    fwd = S.mamba1_forward if version == 1 else S.mamba2_forward
    dec = S.mamba1_decode if version == 1 else S.mamba2_decode
    p = init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model))
    y_full = fwd(x, p, cfg) if version == 1 else fwd(x, p, cfg, chunk=3)
    if version == 1:
        _, st = fwd(x[:, :8], p, cfg, return_state=True)
    else:
        _, st = fwd(x[:, :8], p, cfg, chunk=4, return_state=True)
    y_dec, _ = dec(x[:, 8], st, p, cfg)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, 8]),
                               rtol=2e-4, atol=2e-5)


def test_short_sequence_conv_state_padding():
    """Sequences shorter than conv kernel still produce a valid state."""
    p = S.init_mamba1(jax.random.PRNGKey(0), CFG1, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 2, CFG1.d_model))
    y, st = S.mamba1_forward(x, p, CFG1, return_state=True)
    assert st["conv"].shape == (2, CFG1.ssm_conv - 1, CFG1.d_inner)
    assert np.all(np.isfinite(np.asarray(y)))
