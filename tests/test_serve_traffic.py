"""Micro-batching queue + open-loop load generator: flush triggers
(full / deadline / drain), admission control, the virtual-clock server
model (sealed batches, serial service, monotonic completions), score
parity with direct engine calls, Poisson arrival statistics, and the
replay report's steady-state zero-recompile guarantee."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.serve import (
    MicroBatchQueue,
    QueueConfig,
    ScoringEngine,
    compress,
    poisson_arrivals,
    replay_open_loop,
    synthetic_requests,
)

D, M = 500, 2


@pytest.fixture(scope="module")
def engine():
    rng = np.random.default_rng(0)
    th = rng.normal(size=(D, 2 * M)).astype(np.float32) * 0.3
    th[rng.random(D) >= 0.2] = 0.0
    return ScoringEngine(compress(jnp.asarray(th)))


def _uniform_requests(num, seed=1, ku=6, ka=4, n=3):
    """Same-envelope traffic (one group in the queue)."""
    return synthetic_requests(num, num_features=D, k_user=(ku, ku),
                              k_ad=(ka, ka), n_ads=(n, n), seed=seed)


# --------------------------------------------------------- flush triggers
def test_full_flush_at_max_batch(engine):
    q = MicroBatchQueue(engine, QueueConfig(max_batch=3, max_delay_us=1e6))
    reqs = _uniform_requests(3)
    assert q.submit(reqs[0], 0.0) == 0
    assert q.submit(reqs[1], 0.0) == 1
    assert q.pending == 2 and not q.completions
    assert q.submit(reqs[2], 0.0) == 2  # hits max_batch -> flushes now
    assert q.pending == 0
    assert len(q.completions) == 3
    assert all(c.reason == "full" for c in q.completions)
    assert q.stats.flushes == {"full": 1, "deadline": 0, "drain": 0}


def test_deadline_flush(engine):
    q = MicroBatchQueue(engine, QueueConfig(max_batch=8, max_delay_us=1000.0))
    req = _uniform_requests(1)[0]
    q.submit(req, 0.0)
    assert q.next_deadline() == pytest.approx(1e-3)
    assert q.flush_due(0.5e-3) == []  # not due yet
    done = q.flush_due(2e-3)
    assert [c.reason for c in done] == ["deadline"]
    # the batch seals and starts AT its deadline, not at poll time
    assert done[0].started == pytest.approx(1e-3)
    assert done[0].completed > done[0].started  # real service time
    assert q.next_deadline() is None


def test_flush_due_handles_multiple_groups_in_deadline_order(engine):
    q = MicroBatchQueue(engine, QueueConfig(max_batch=8, max_delay_us=1000.0))
    small = _uniform_requests(1, ku=4)[0]
    big = _uniform_requests(1, ku=20, seed=2)[0]
    q.submit(small, 0.0)
    q.submit(big, 0.4e-3)  # different envelope -> its own group
    done = q.flush_due(5e-3)
    assert len(done) == 2
    assert done[0].arrival < done[1].arrival  # oldest deadline first
    # serial server: the second flush cannot start before the first ends
    assert done[1].started >= done[0].completed


def test_admission_control_sheds_load(engine):
    q = MicroBatchQueue(engine, QueueConfig(max_batch=8, max_delay_us=1e6,
                                            max_pending=2))
    reqs = _uniform_requests(4)
    assert q.submit(reqs[0], 0.0) is not None
    assert q.submit(reqs[1], 0.0) is not None
    assert q.submit(reqs[2], 0.0) is None  # backlog full -> shed
    assert q.stats.rejected == 1 and q.stats.accepted == 2
    q.drain(1.0)
    assert q.submit(reqs[3], 2.0) is not None  # space again after flush


def test_drain_flushes_everything(engine):
    q = MicroBatchQueue(engine, QueueConfig(max_batch=8, max_delay_us=1e6))
    q.submit(_uniform_requests(1, ku=4)[0], 0.0)
    q.submit(_uniform_requests(1, ku=20, seed=2)[0], 0.1)
    done = q.drain(0.2)
    assert len(done) == 2 and q.pending == 0
    assert all(c.reason == "drain" for c in done)


def test_queue_rejects_bad_config(engine):
    with pytest.raises(ValueError):
        MicroBatchQueue(engine, QueueConfig(max_batch=0))


# ----------------------------------------------------------- score parity
def test_queue_scores_match_direct_engine(engine):
    """Tickets map completions back to submissions and each completion
    carries exactly the scores a direct engine call produces."""
    reqs = synthetic_requests(17, num_features=D, seed=3)
    q = MicroBatchQueue(engine, QueueConfig(max_batch=4, max_delay_us=500.0))
    tickets = {}
    for i, r in enumerate(reqs):
        t = float(i) * 1e-4
        q.flush_due(t)
        tickets[q.submit(r, t)] = i
    q.drain(len(reqs) * 1e-4)
    assert len(q.completions) == len(reqs)
    fresh = ScoringEngine(engine._model)
    for c in q.completions:
        r = reqs[tickets[c.ticket]]
        np.testing.assert_array_equal(c.scores, fresh.score(r))
        assert c.completed >= c.started >= c.arrival
        assert c.latency_us > 0


# ------------------------------------------------------------ arrivals
def test_poisson_arrivals_statistics():
    a = poisson_arrivals(4000, qps=1000.0, seed=0)
    assert a.shape == (4000,)
    assert (np.diff(a) > 0).all()  # strictly increasing
    gaps = np.diff(np.concatenate([[0.0], a]))
    assert np.isclose(gaps.mean(), 1e-3, rtol=0.1)  # mean gap ~ 1/qps
    np.testing.assert_array_equal(a, poisson_arrivals(4000, 1000.0, seed=0))
    assert not np.array_equal(a, poisson_arrivals(4000, 1000.0, seed=1))
    with pytest.raises(ValueError):
        poisson_arrivals(10, qps=0.0)


# ---------------------------------------------------------- open loop
def test_replay_open_loop_report_and_steady_state(engine):
    reqs = synthetic_requests(48, num_features=D, seed=5)
    eng = ScoringEngine(engine._model)
    eng.warm({eng.envelope(r) for r in reqs}, batch_sizes=eng.g_buckets)
    warm = eng.stats.compiles
    rep = replay_open_loop(eng, reqs, qps=3000.0,
                           config=QueueConfig(max_batch=8,
                                              max_delay_us=2000.0), seed=6)
    assert eng.stats.compiles == warm, "load replay recompiled"
    assert rep["requests"] == 48
    assert rep["served"] + rep["rejected"] == 48
    assert rep["served"] > 0
    assert 0 < rep["latency_p50_us"] <= rep["latency_p99_us"]
    assert rep["candidates_per_sec"] > 0 and rep["achieved_qps"] > 0
    assert 0 < rep["occupancy"] <= 1.0
    # one dispatch per flush unless a flush outgrew the top G bucket
    assert rep["dispatches"] >= sum(rep["flushes"].values())
    assert rep["offered_qps"] == 3000.0


def test_replay_open_loop_sheds_under_overload(engine):
    """A tiny backlog cap + a burst far above the flush rate must shed
    load: arrivals land inside the deadline window faster than any
    flush trigger fires, the backlog caps at max_pending, and the rest
    are rejected (every served request still gets real scores)."""
    reqs = synthetic_requests(60, num_features=D, seed=7)
    eng = ScoringEngine(engine._model)
    eng.warm({eng.envelope(r) for r in reqs}, batch_sizes=eng.g_buckets)
    rep = replay_open_loop(eng, reqs, qps=2_000_000.0,
                           config=QueueConfig(max_batch=64,
                                              max_delay_us=50_000.0,
                                              max_pending=4), seed=8)
    assert rep["rejected"] > 0
    assert rep["served"] == 60 - rep["rejected"]
