"""Micro-batching queue + open-loop load generator: flush triggers
(full / deadline / drain / coalesced), admission control, the
virtual-clock server model (sealed batches, serial service, monotonic
completions), score parity with direct engine calls (coalesced rounds
bitwise vs per-envelope), the wall-clock pump, queue-derived g_buckets,
Poisson arrival statistics, and the replay report's steady-state
zero-recompile guarantee."""
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.serve import (
    MicroBatchQueue,
    QueueConfig,
    RealClockPump,
    ScoringEngine,
    compress,
    derive_g_buckets,
    poisson_arrivals,
    replay_open_loop,
    synthetic_requests,
)

D, M = 500, 2


@pytest.fixture(scope="module")
def engine():
    rng = np.random.default_rng(0)
    th = rng.normal(size=(D, 2 * M)).astype(np.float32) * 0.3
    th[rng.random(D) >= 0.2] = 0.0
    return ScoringEngine(compress(jnp.asarray(th)))


def _uniform_requests(num, seed=1, ku=6, ka=4, n=3):
    """Same-envelope traffic (one group in the queue)."""
    return synthetic_requests(num, num_features=D, k_user=(ku, ku),
                              k_ad=(ka, ka), n_ads=(n, n), seed=seed)


# --------------------------------------------------------- flush triggers
def test_full_flush_at_max_batch(engine):
    q = MicroBatchQueue(engine, QueueConfig(max_batch=3, max_delay_us=1e6))
    reqs = _uniform_requests(3)
    assert q.submit(reqs[0], 0.0) == 0
    assert q.submit(reqs[1], 0.0) == 1
    assert q.pending == 2 and not q.completions
    assert q.submit(reqs[2], 0.0) == 2  # hits max_batch -> flushes now
    assert q.pending == 0
    assert len(q.completions) == 3
    assert all(c.reason == "full" for c in q.completions)
    assert q.stats.flushes == {"full": 1, "deadline": 0, "drain": 0,
                               "coalesced": 0}
    assert q.stats.flush_sizes == {3: 1}


def test_deadline_flush(engine):
    q = MicroBatchQueue(engine, QueueConfig(max_batch=8, max_delay_us=1000.0))
    req = _uniform_requests(1)[0]
    q.submit(req, 0.0)
    assert q.next_deadline() == pytest.approx(1e-3)
    assert q.flush_due(0.5e-3) == []  # not due yet
    done = q.flush_due(2e-3)
    assert [c.reason for c in done] == ["deadline"]
    # the batch seals and starts AT its deadline, not at poll time
    assert done[0].started == pytest.approx(1e-3)
    assert done[0].completed > done[0].started  # real service time
    assert q.next_deadline() is None


def test_flush_due_handles_multiple_groups_in_deadline_order(engine):
    q = MicroBatchQueue(engine, QueueConfig(max_batch=8, max_delay_us=1000.0))
    small = _uniform_requests(1, ku=4)[0]
    big = _uniform_requests(1, ku=20, seed=2)[0]
    q.submit(small, 0.0)
    q.submit(big, 0.4e-3)  # different envelope -> its own group
    done = q.flush_due(5e-3)
    assert len(done) == 2
    assert done[0].arrival < done[1].arrival  # oldest deadline first
    # serial server: the second flush cannot start before the first ends
    assert done[1].started >= done[0].completed


def test_admission_control_sheds_load(engine):
    q = MicroBatchQueue(engine, QueueConfig(max_batch=8, max_delay_us=1e6,
                                            max_pending=2))
    reqs = _uniform_requests(4)
    assert q.submit(reqs[0], 0.0) is not None
    assert q.submit(reqs[1], 0.0) is not None
    assert q.submit(reqs[2], 0.0) is None  # backlog full -> shed
    assert q.stats.rejected == 1 and q.stats.accepted == 2
    q.drain(1.0)
    assert q.submit(reqs[3], 2.0) is not None  # space again after flush


def test_drain_flushes_everything(engine):
    q = MicroBatchQueue(engine, QueueConfig(max_batch=8, max_delay_us=1e6))
    q.submit(_uniform_requests(1, ku=4)[0], 0.0)
    q.submit(_uniform_requests(1, ku=20, seed=2)[0], 0.1)
    done = q.drain(0.2)
    assert len(done) == 2 and q.pending == 0
    assert all(c.reason == "drain" for c in done)


def test_queue_rejects_bad_config(engine):
    with pytest.raises(ValueError):
        MicroBatchQueue(engine, QueueConfig(max_batch=0))


# ----------------------------------------------------------- score parity
def test_queue_scores_match_direct_engine(engine):
    """Tickets map completions back to submissions and each completion
    carries exactly the scores a direct engine call produces."""
    reqs = synthetic_requests(17, num_features=D, seed=3)
    q = MicroBatchQueue(engine, QueueConfig(max_batch=4, max_delay_us=500.0))
    tickets = {}
    for i, r in enumerate(reqs):
        t = float(i) * 1e-4
        q.flush_due(t)
        tickets[q.submit(r, t)] = i
    q.drain(len(reqs) * 1e-4)
    assert len(q.completions) == len(reqs)
    fresh = ScoringEngine(engine._model)
    for c in q.completions:
        r = reqs[tickets[c.ticket]]
        np.testing.assert_array_equal(c.scores, fresh.score(r))
        assert c.completed >= c.started >= c.arrival
        assert c.latency_us > 0


# ------------------------------------------------------- coalesced flush
def _mixed_envelope_run(eng, reqs, arrivals, *, coalesce, max_batch=8):
    q = MicroBatchQueue(eng, QueueConfig(max_batch=max_batch,
                                         max_delay_us=2000.0,
                                         coalesce=coalesce))
    for t, r in zip(arrivals, reqs):
        q.flush_due(t)
        q.submit(r, t)
    q.flush_due(arrivals[-1] + 1.0)
    q.drain(arrivals[-1] + 1.0)
    return q


def test_coalesced_dispatch_bitwise_matches_per_envelope(engine):
    """Same arrivals, coalesce on vs off: every ticket's scores are
    BITWISE identical (widening to the max due envelope only adds pad
    slots) and coalescing strictly reduces device rounds."""
    reqs = synthetic_requests(24, num_features=D, seed=11)
    arrivals = poisson_arrivals(len(reqs), qps=500.0, seed=12)
    q_off = _mixed_envelope_run(ScoringEngine(engine._model), reqs,
                                arrivals, coalesce=False)
    q_on = _mixed_envelope_run(ScoringEngine(engine._model), reqs,
                               arrivals, coalesce=True)
    off = {c.ticket: c.scores for c in q_off.completions}
    on = {c.ticket: c.scores for c in q_on.completions}
    assert off.keys() == on.keys() and len(off) == len(reqs)
    for t in off:
        np.testing.assert_array_equal(off[t], on[t])
    assert q_on.stats.flushes["coalesced"] > 0
    assert sum(q_on.stats.flushes.values()) < sum(q_off.stats.flushes.values())
    # every coalesced round merged >= 2 groups
    assert q_on.stats.coalesced_groups >= 2 * q_on.stats.flushes["coalesced"]
    assert all(c.reason in ("full", "deadline", "drain", "coalesced")
               for c in q_on.completions)


def test_coalesced_flush_respects_max_batch(engine):
    """Groups merge only while the combined round fits max_batch; the
    overflow group flushes on its own deadline instead."""
    q = MicroBatchQueue(engine, QueueConfig(max_batch=3, max_delay_us=1000.0,
                                            coalesce=True))
    for r in _uniform_requests(2, ku=4, seed=21):
        q.submit(r, 0.0)
    for r in _uniform_requests(2, ku=20, seed=22):
        q.submit(r, 0.0)
    done = q.flush_due(1.0)
    assert len(done) == 4 and q.pending == 0
    sizes = [len({c.started for c in done if c.reason == r})
             for r in ("coalesced", "deadline")]
    # one coalesced round couldn't fit both 2-request groups (2+2 > 3):
    # the first group went out alone as a deadline flush, leaving one
    # group -> also a plain deadline flush (coalescing needs >= 2 due)
    assert q.stats.flushes["coalesced"] == 0 and sizes[1] == 2
    # with room for both, one round serves all four
    q2 = MicroBatchQueue(engine, QueueConfig(max_batch=4, max_delay_us=1000.0,
                                             coalesce=True))
    for r in _uniform_requests(2, ku=4, seed=21):
        q2.submit(r, 0.0)
    for r in _uniform_requests(2, ku=20, seed=22):
        q2.submit(r, 0.0)
    done2 = q2.flush_due(1.0)
    assert len(done2) == 4
    assert q2.stats.flushes["coalesced"] == 1
    assert q2.stats.coalesced_groups == 2
    assert len({c.started for c in done2}) == 1  # one device round


def test_coalesce_off_by_default(engine):
    assert QueueConfig().coalesce is False


# ----------------------------------------------------------- wall clock
def test_real_clock_pump_serves_and_drains_deterministically(engine):
    """The pump's timer thread fires deadline flushes on wall time and
    stop() joins-then-drains: afterwards every accepted request has a
    completion with direct-engine scores, whatever the thread timing."""
    reqs = synthetic_requests(10, num_features=D, seed=31)
    eng = ScoringEngine(engine._model)
    eng.warm({eng.envelope(r) for r in reqs}, batch_sizes=eng.g_buckets)
    q = MicroBatchQueue(eng, QueueConfig(max_batch=4, max_delay_us=3000.0))
    with RealClockPump(q) as pump:
        tickets = [pump.submit(r) for r in reqs]
    assert all(t is not None for t in tickets)
    comps = {c.ticket: c for c in q.completions}
    assert sorted(comps) == sorted(tickets)
    fresh = ScoringEngine(engine._model)
    for t, r in zip(tickets, reqs):
        np.testing.assert_array_equal(comps[t].scores, fresh.score(r))
    assert pump._thread is None  # joined
    assert pump.stop() == []  # idempotent, nothing left to drain


def test_real_clock_pump_deadline_fires_without_further_submits(engine):
    """A lone queued request must flush from the timer thread alone."""
    req = _uniform_requests(1, seed=41)[0]
    eng = ScoringEngine(engine._model)
    eng.warm({eng.envelope(req)}, batch_sizes=eng.g_buckets)
    q = MicroBatchQueue(eng, QueueConfig(max_batch=8, max_delay_us=2000.0))
    pump = RealClockPump(q).start()
    try:
        pump.submit(req)
        deadline = 2e-3
        for _ in range(200):  # ~2s budget for the 2ms deadline
            if pump.completions():
                break
            time.sleep(0.01)
        comps = pump.completions()
        assert len(comps) == 1 and comps[0].reason == "deadline"
        assert comps[0].completed - comps[0].arrival >= deadline
    finally:
        pump.stop()
    with pytest.raises(RuntimeError):
        RealClockPump(q).start().start()


# ------------------------------------------------- g_buckets autoscaling
def test_derive_g_buckets_from_flush_mix():
    # pow2 rounding, {1} always present, top edge covers the max size
    assert derive_g_buckets({1: 3, 3: 5, 7: 50}) == (1, 4, 8)
    assert derive_g_buckets({2: 10}) == (1, 2)
    # cap keeps the most frequent edges + the top
    got = derive_g_buckets({1: 9, 2: 8, 3: 7, 5: 6, 9: 5, 17: 1},
                           max_buckets=4)
    assert got[0] == 1 and got[-1] == 32 and len(got) == 4
    assert 2 in got  # most frequent non-forced edge survives
    # no observations -> builtin default
    from repro.serve.engine import DEFAULT_G_BUCKETS
    assert derive_g_buckets({}) == DEFAULT_G_BUCKETS
    with pytest.raises(TypeError):
        derive_g_buckets([(1, 2)])


def test_derive_g_buckets_accepts_queue_stats_and_warns(engine, capsys):
    q = MicroBatchQueue(engine, QueueConfig(max_batch=3, max_delay_us=1e6))
    for r in _uniform_requests(6, seed=51):
        q.submit(r, 0.0)
    q.drain(0.0)
    assert q.stats.flush_sizes == {3: 2}
    assert derive_g_buckets(q.stats) == (1, 4)
    assert "saturate" in capsys.readouterr().out  # all flushes at the top
    # an unsaturated mix stays quiet
    derive_g_buckets({1: 99, 8: 1})
    assert "saturate" not in capsys.readouterr().out


# ------------------------------------------------------------ arrivals
def test_poisson_arrivals_statistics():
    a = poisson_arrivals(4000, qps=1000.0, seed=0)
    assert a.shape == (4000,)
    assert (np.diff(a) > 0).all()  # strictly increasing
    gaps = np.diff(np.concatenate([[0.0], a]))
    assert np.isclose(gaps.mean(), 1e-3, rtol=0.1)  # mean gap ~ 1/qps
    np.testing.assert_array_equal(a, poisson_arrivals(4000, 1000.0, seed=0))
    assert not np.array_equal(a, poisson_arrivals(4000, 1000.0, seed=1))
    with pytest.raises(ValueError):
        poisson_arrivals(10, qps=0.0)


# ---------------------------------------------------------- open loop
def test_replay_open_loop_report_and_steady_state(engine):
    reqs = synthetic_requests(48, num_features=D, seed=5)
    eng = ScoringEngine(engine._model)
    eng.warm({eng.envelope(r) for r in reqs}, batch_sizes=eng.g_buckets)
    warm = eng.stats.compiles
    rep = replay_open_loop(eng, reqs, qps=3000.0,
                           config=QueueConfig(max_batch=8,
                                              max_delay_us=2000.0), seed=6)
    assert eng.stats.compiles == warm, "load replay recompiled"
    assert rep["requests"] == 48
    assert rep["served"] + rep["rejected"] == 48
    assert rep["served"] > 0
    assert 0 < rep["latency_p50_us"] <= rep["latency_p99_us"]
    assert rep["candidates_per_sec"] > 0 and rep["achieved_qps"] > 0
    assert 0 < rep["occupancy"] <= 1.0
    # one dispatch per flush unless a flush outgrew the top G bucket
    assert rep["dispatches"] >= sum(rep["flushes"].values())
    assert rep["offered_qps"] == 3000.0


def test_replay_open_loop_sheds_under_overload(engine):
    """A tiny backlog cap + a burst far above the flush rate must shed
    load: arrivals land inside the deadline window faster than any
    flush trigger fires, the backlog caps at max_pending, and the rest
    are rejected (every served request still gets real scores)."""
    reqs = synthetic_requests(60, num_features=D, seed=7)
    eng = ScoringEngine(engine._model)
    eng.warm({eng.envelope(r) for r in reqs}, batch_sizes=eng.g_buckets)
    rep = replay_open_loop(eng, reqs, qps=2_000_000.0,
                           config=QueueConfig(max_batch=64,
                                              max_delay_us=50_000.0,
                                              max_pending=4), seed=8)
    assert rep["rejected"] > 0
    assert rep["served"] == 60 - rep["rejected"]
