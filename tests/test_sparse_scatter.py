"""Transpose plan + plan-driven scatter backward: plan structure
invariants, class-gather jnp path and Pallas run-length kernel (interpret)
vs the direct scatter oracle, pad-entry dropping, and degenerate shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.lsplm_sparse_scatter.lsplm_sparse_scatter import (
    lsplm_sparse_scatter_compact,
)
from repro.kernels.lsplm_sparse_scatter.ops import (
    build_transpose_plan,
    dvals_planned,
    pad_plan_entries,
    scatter_add_planned,
)
from repro.kernels.lsplm_sparse_scatter.ref import scatter_bwd_ref


def _batch(N, K, d, m, pad_frac=0.0, zipf=False, seed=0):
    rng = np.random.default_rng(seed)
    if zipf:
        ids = (d * (rng.random((N, K)) ** 6)).astype(np.int64)
    else:
        ids = rng.integers(0, d, (N, K))
    vals = rng.normal(size=(N, K)).astype(np.float32)
    n_pad = int(round(pad_frac * K))
    if n_pad:
        ids[:, K - n_pad:] = d
        vals[:, K - n_pad:] = 0.0
    theta = np.concatenate(
        [(rng.normal(size=(d, 2 * m)) * 0.3).astype(np.float32),
         np.zeros((1, 2 * m), np.float32)], axis=0)
    dz = rng.normal(size=(N, 2 * m)).astype(np.float32)
    return ids, vals, theta, dz


# ---------------------------------------------------------- plan structure
def test_plan_is_a_permutation_sorted_by_id():
    ids, _, _, _ = _batch(32, 6, 100, 3, seed=1)
    plan = build_transpose_plan(ids, 101)
    order = np.asarray(plan.order)
    assert sorted(order.tolist()) == list(range(ids.size))  # a permutation
    srt = np.asarray(plan.row_ids)
    assert (np.diff(srt) >= 0).all()                        # sorted by id
    np.testing.assert_array_equal(srt, ids.reshape(-1)[order])
    np.testing.assert_array_equal(np.asarray(plan.sample_sorted), order // 6)
    np.testing.assert_array_equal(np.asarray(plan.slot_sorted), order % 6)
    # rank is the inverse permutation
    rank = np.asarray(plan.rank)
    np.testing.assert_array_equal(rank[order], np.arange(ids.size))


def test_plan_classes_partition_entries_with_bounded_padding():
    ids, _, _, _ = _batch(64, 8, 50, 2, zipf=True, seed=2)  # heavy duplicates
    plan = build_transpose_plan(ids, 51)
    covered = []
    padded_slots = 0
    for src, mask, width in zip(plan.class_src, plan.class_mask,
                                plan.class_width):
        mask = np.asarray(mask).astype(bool)
        covered.append(np.asarray(src)[mask])
        padded_slots += mask.size
        assert mask.size % width == 0
    covered = np.concatenate(covered)
    # every entry appears exactly once across all classes
    assert sorted(covered.tolist()) == list(range(ids.size))
    # power-of-two class padding never doubles the work
    assert padded_slots <= 2 * ids.size + len(plan.class_width)


def test_plan_drops_pad_entries():
    ids, _, _, _ = _batch(16, 8, 40, 2, pad_frac=0.5, seed=3)
    plan = build_transpose_plan(ids, 41, pad_id=40)
    assert plan.num_kept == (np.asarray(ids) != 40).sum()
    assert (np.asarray(plan.row_ids) != 40).all()
    # dropped entries' rank points at the appended zero slot
    rank = np.asarray(plan.rank).reshape(16, 8)
    assert (rank[ids == 40] == plan.num_kept).all()


def test_plan_validate_rejects_mismatched_shapes():
    ids, _, _, _ = _batch(8, 4, 30, 2, seed=4)
    plan = build_transpose_plan(ids, 31)
    with pytest.raises(ValueError):
        plan.validate((8, 5), 31)
    with pytest.raises(ValueError):
        plan.validate((8, 4), 32)
    with pytest.raises(ValueError):
        build_transpose_plan(ids, 20)  # ids out of range


# ------------------------------------------------- scatter vs the oracle
@pytest.mark.parametrize("mode", ["jnp", "interpret"])
@pytest.mark.parametrize("N,K,d,m,pad_frac,zipf", [
    (40, 6, 200, 4, 0.25, False),
    (64, 8, 256, 4, 0.0, True),    # hot-id duplicates across samples
    (33, 1, 100, 2, 0.0, False),   # K=1
    (8, 4, 64, 3, 0.5, False),     # heavy padding
    (16, 5, 50, 2, 1.0, False),    # ALL pad (empty plan)
])
def test_planned_scatter_matches_oracle(mode, N, K, d, m, pad_frac, zipf):
    ids, vals, theta, dz = _batch(N, K, d, m, pad_frac, zipf, seed=N + K)
    idsj = jnp.asarray(ids, jnp.int32)
    valsj, thetaj, dzj = map(jnp.asarray, (vals, theta, dz))
    dv_ref, dt_ref = scatter_bwd_ref(idsj, valsj, thetaj, dzj)
    for pad_id in (None, d):
        plan = build_transpose_plan(ids, d + 1, pad_id=pad_id)
        dt = scatter_add_planned(plan, valsj, dzj, mode=mode, block_e=32)
        dv = dvals_planned(plan, thetaj, dzj, (N, K))
        np.testing.assert_allclose(np.asarray(dt), np.asarray(dt_ref),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(dv), np.asarray(dv_ref),
                                   rtol=1e-4, atol=1e-5)


def test_planned_scatter_pad_row_cotangent_is_exactly_zero():
    """Pad-id entries carry value 0, so the pad row's gradient must be
    EXACTLY zero — with and without plan-side pad dropping."""
    ids, vals, theta, dz = _batch(24, 8, 60, 3, pad_frac=0.375, seed=7)
    valsj, dzj = jnp.asarray(vals), jnp.asarray(dz)
    for pad_id in (None, 60):
        plan = build_transpose_plan(ids, 61, pad_id=pad_id)
        dt = np.asarray(scatter_add_planned(plan, valsj, dzj, mode="jnp"))
        assert (dt[60] == 0.0).all()


def test_planned_scatter_under_jit_with_plan_argument():
    ids, vals, theta, dz = _batch(20, 5, 80, 2, seed=8)
    plan = build_transpose_plan(ids, 81)

    @jax.jit
    def f(plan, vals, dz):
        return scatter_add_planned(plan, vals, dz, mode="jnp")

    dt = f(plan, jnp.asarray(vals), jnp.asarray(dz))
    _, dt_ref = scatter_bwd_ref(jnp.asarray(ids, jnp.int32),
                                jnp.asarray(vals), jnp.asarray(theta),
                                jnp.asarray(dz))
    np.testing.assert_allclose(np.asarray(dt), np.asarray(dt_ref),
                               rtol=1e-4, atol=1e-5)


# ------------------------------------------ pipelined-flush kernel edges
def test_compact_kernel_trailing_row_is_exactly_zero():
    """The sentinel-tail flush must write an EXACT zero trailing row —
    untouched pad entries gather from it, so any residue from the
    double-buffered accumulator would leak into real gradients."""
    ids, vals, _, dz = _batch(24, 8, 60, 3, pad_frac=0.375, seed=11)
    plan = build_transpose_plan(ids, 61, pad_id=60)
    row_ids, sample, vals_sorted = pad_plan_entries(
        plan, jnp.asarray(vals), block_e=32)
    compact = lsplm_sparse_scatter_compact(
        row_ids, sample, vals_sorted, jnp.asarray(dz),
        num_unique=plan.num_unique, num_kept=plan.num_kept,
        block_e=32, interpret=True)
    assert compact.shape == (plan.num_unique + 1, 6)
    assert (np.asarray(compact)[-1] == 0.0).all()   # exact, not allclose


def test_compact_kernel_all_unique_ids_flush_every_entry():
    """Every entry is its own run: a flush (and a slot swap) fires on
    every single entry — the double-buffer drain logic gets no slack."""
    N, K, d, m = 16, 4, 200, 2
    rng = np.random.default_rng(12)
    ids = rng.permutation(d)[:N * K].reshape(N, K)   # all distinct
    vals = rng.normal(size=(N, K)).astype(np.float32)
    dz = rng.normal(size=(N, 2 * m)).astype(np.float32)
    plan = build_transpose_plan(ids, d + 1)
    assert plan.num_unique == N * K
    dt = scatter_add_planned(plan, jnp.asarray(vals), jnp.asarray(dz),
                             mode="interpret", block_e=8)  # many grid blocks
    _, dt_ref = scatter_bwd_ref(jnp.asarray(ids, jnp.int32),
                                jnp.asarray(vals),
                                jnp.zeros((d + 1, 2 * m), jnp.float32),
                                jnp.asarray(dz))
    np.testing.assert_allclose(np.asarray(dt), np.asarray(dt_ref),
                               rtol=1e-4, atol=1e-5)


def test_compact_kernel_run_spanning_grid_blocks():
    """One hot id dominating the batch: its run spans several grid
    blocks, so the accumulator must persist across sequential steps and
    the in-flight flush state must survive block boundaries."""
    N, K, d, m = 32, 8, 50, 3
    rng = np.random.default_rng(13)
    ids = np.where(rng.random((N, K)) < 0.7, 7, rng.integers(0, d, (N, K)))
    vals = rng.normal(size=(N, K)).astype(np.float32)
    dz = rng.normal(size=(N, 2 * m)).astype(np.float32)
    plan = build_transpose_plan(ids, d + 1)
    for block_e in (16, 64):
        dt = scatter_add_planned(plan, jnp.asarray(vals), jnp.asarray(dz),
                                 mode="interpret", block_e=block_e)
        _, dt_ref = scatter_bwd_ref(jnp.asarray(ids, jnp.int32),
                                    jnp.asarray(vals),
                                    jnp.zeros((d + 1, 2 * m), jnp.float32),
                                    jnp.asarray(dz))
        np.testing.assert_allclose(np.asarray(dt), np.asarray(dt_ref),
                                   rtol=1e-4, atol=1e-5)


def test_pad_plan_entries_appends_sentinels():
    ids, vals, _, _ = _batch(8, 4, 30, 2, seed=9)
    plan = build_transpose_plan(ids, 31)
    row_ids, sample, vals_sorted = pad_plan_entries(
        plan, jnp.asarray(vals), block_e=16)
    assert row_ids.shape[0] % 16 == 0
    assert row_ids.shape[0] > plan.num_kept          # >= 1 sentinel
    tail = np.asarray(row_ids)[plan.num_kept:]
    assert (tail == 31).all()                        # sentinel id == num_rows
    assert (np.asarray(vals_sorted)[plan.num_kept:] == 0).all()
    np.testing.assert_array_equal(
        np.asarray(vals_sorted)[:plan.num_kept],
        vals.reshape(-1)[np.asarray(plan.order)])
