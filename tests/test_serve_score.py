"""The unified inference layer (repro.serve.score): one predict() for
dense, flat-COO and session-shared requests, polymorphic over full
Theta / LSPLMParams / pruned artifacts, in parity with the kernel
oracles and the core predictors it replaced."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lsplm import (
    params_from_theta,
    predict_logits_stable_sparse,
    predict_proba,
    predict_proba_sparse,
)
from repro.data.sparse import generate_sparse, sparse_predict, to_dense
from repro.kernels.lsplm_sparse_fused.ref import (
    lsplm_sparse_forward_ref,
    lsplm_sparse_logps_ref,
)
from repro.kernels.lsplm_sparse_fused.ops import pad_theta
from repro.serve import (
    ScoreBundle,
    ServingModel,
    as_model,
    compress,
    predict,
    score_bundles,
    score_bundles_naive,
    score_dense,
    score_sparse,
    score_sparse_logps,
)

D, M = 600, 3


@pytest.fixture(scope="module")
def theta():
    rng = np.random.default_rng(0)
    th = rng.normal(size=(D, 2 * M)).astype(np.float32) * 0.3
    th[rng.random(D) >= 0.3] = 0.0
    return jnp.asarray(th)


@pytest.fixture(scope="module")
def batch():
    return generate_sparse(num_features=D,
                           num_user_features_range=(D // 2, D),
                           sessions=16, seed=2, with_plans=False)


def _bundle(batch):
    return ScoreBundle(batch.user_ids, batch.user_vals,
                       batch.ad_ids, batch.ad_vals, batch.session_id)


# ------------------------------------------------------------- as_model
def test_as_model_forms(theta):
    full = as_model(theta)
    assert isinstance(full, ServingModel)
    assert full.remap is None and full.num_features == D
    np.testing.assert_array_equal(np.asarray(full.theta),
                                  np.asarray(pad_theta(theta)))
    # idempotent; params and artifacts coerce too
    assert as_model(full) is full
    from_params = as_model(params_from_theta(theta))
    np.testing.assert_array_equal(np.asarray(from_params.theta),
                                  np.asarray(full.theta))
    art = as_model(compress(theta))
    assert art.remap is not None


def test_as_model_rejects_bad_shapes():
    with pytest.raises(ValueError):
        as_model(jnp.zeros((10, 3)))


# ------------------------------------------------------------- parities
def test_score_sparse_matches_oracle(theta):
    rng = np.random.default_rng(3)
    ids = jnp.asarray(rng.integers(0, D, (40, 8)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(40, 8)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(score_sparse(theta, ids, vals)),
        np.asarray(lsplm_sparse_forward_ref(ids, vals, pad_theta(theta))),
        rtol=1e-6, atol=1e-7)


def test_score_sparse_logps_matches_oracle(theta):
    rng = np.random.default_rng(4)
    ids = jnp.asarray(rng.integers(0, D, (24, 6)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(24, 6)).astype(np.float32))
    lp1, lp0 = score_sparse_logps(theta, ids, vals)
    r1, r0 = lsplm_sparse_logps_ref(ids, vals, pad_theta(theta))
    np.testing.assert_allclose(np.asarray(lp1), np.asarray(r1),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(lp0), np.asarray(r0),
                               rtol=1e-6, atol=1e-6)


def test_bundles_shared_equals_naive_equals_dense(theta, batch):
    b = _bundle(batch)
    p_shared = np.asarray(score_bundles(theta, b))
    p_naive = np.asarray(score_bundles_naive(theta, b))
    np.testing.assert_allclose(p_shared, p_naive, rtol=1e-5, atol=1e-6)
    x = jnp.asarray(to_dense(batch))
    p_dense = np.asarray(predict_proba(params_from_theta(theta), x))
    np.testing.assert_allclose(p_shared, p_dense, rtol=1e-4, atol=1e-5)


def test_predict_dispatcher(theta, batch):
    b = _bundle(batch)
    np.testing.assert_array_equal(np.asarray(predict(theta, batch)),
                                  np.asarray(score_bundles(theta, b)))
    np.testing.assert_array_equal(np.asarray(predict(theta, b)),
                                  np.asarray(score_bundles(theta, b)))
    rng = np.random.default_rng(5)
    ids = jnp.asarray(rng.integers(0, D, (10, 5)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(10, 5)).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(predict(theta, (ids, vals))),
                                  np.asarray(score_sparse(theta, ids, vals)))
    x = jnp.asarray(rng.normal(size=(6, D)).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(predict(theta, x)),
                                  np.asarray(score_dense(theta, x)))


def test_legacy_entry_points_route_through_serve(theta, batch):
    """The rewired predictors (core + data) agree with the serve layer
    exactly — they ARE the serve layer now."""
    b = _bundle(batch)
    np.testing.assert_array_equal(np.asarray(sparse_predict(theta, batch)),
                                  np.asarray(score_bundles(theta, b)))
    rng = np.random.default_rng(6)
    ids = jnp.asarray(rng.integers(0, D, (12, 4)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(12, 4)).astype(np.float32))
    params = params_from_theta(theta)
    np.testing.assert_array_equal(
        np.asarray(predict_proba_sparse(params, ids, vals)),
        np.asarray(score_sparse(theta, ids, vals)))
    lp1, lp0 = predict_logits_stable_sparse(params, ids, vals)
    s1, s0 = score_sparse_logps(theta, ids, vals)
    np.testing.assert_array_equal(np.asarray(lp1), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(lp0), np.asarray(s0))


def test_artifact_plan_combination_rejected(theta, batch):
    planned = generate_sparse(num_features=D,
                              num_user_features_range=(D // 2, D),
                              sessions=4, seed=7)  # with_plans=True
    art = compress(theta)
    with pytest.raises(ValueError, match="full Theta layout"):
        score_sparse(art, planned.ad_ids, planned.ad_vals,
                     plan=planned.ad_plan)
    # the same plan on the FULL model is fine
    score_sparse(theta, planned.ad_ids, planned.ad_vals,
                 plan=planned.ad_plan)


def test_predict_threads_plans_and_grads(theta):
    """A plan-carrying SparseCTRBatch keeps its transpose plans through
    predict()/sparse_predict: the forward is unchanged and the
    differentiated call runs the plan-driven backward (same grads as the
    no-plan scan fallback). On a pruned artifact the plans are dropped
    (inference-only) instead of raising."""
    import jax

    planned = generate_sparse(num_features=D,
                              num_user_features_range=(D // 2, D),
                              sessions=8, seed=9)  # with_plans=True
    assert planned.user_plan is not None
    bare = planned._replace(user_plan=None, ad_plan=None)
    np.testing.assert_array_equal(np.asarray(predict(theta, planned)),
                                  np.asarray(predict(theta, bare)))
    g_plan = jax.grad(lambda t: predict(t, planned).sum())(theta)
    g_scan = jax.grad(lambda t: predict(t, bare).sum())(theta)
    np.testing.assert_allclose(np.asarray(g_plan), np.asarray(g_scan),
                               rtol=1e-5, atol=1e-6)
    art = compress(theta)
    np.testing.assert_array_equal(np.asarray(predict(art, planned)),
                                  np.asarray(predict(art, bare)))


def test_interpret_mode_pruned_parity(theta):
    """CI gate: pruned-vs-full parity holds on the Pallas kernel path
    (interpret mode) too, not just the jnp fallback."""
    art = compress(theta)
    rng = np.random.default_rng(8)
    ids = jnp.asarray(rng.integers(0, D, (16, 5)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(16, 5)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(score_sparse(theta, ids, vals, mode="interpret")),
        np.asarray(score_sparse(art, ids, vals, mode="interpret")))
