"""SLO monitoring (``repro.obs.monitor``): rule parsing, rolling
windows, hysteresis semantics, the ledger-observer feed, alert records
and registry views, reentrancy safety, and the configure() wiring."""
import numpy as np
import pytest

from repro import obs
from repro.obs.ledger import validate_event
from repro.obs.metrics import MetricsRegistry
from repro.obs.monitor import HealthMonitor, RollingWindow, parse_rule


# ------------------------------------------------------- rolling window
def test_rolling_window_views_and_bound():
    w = RollingWindow(maxlen=4)
    assert w.percentile(99) is None and w.mean() is None and w.last() is None
    for v in (1.0, 2.0, 3.0, 4.0, 5.0):
        w.push(v)
    assert len(w) == 4  # the 1.0 fell out
    assert w.last() == 5.0
    assert w.mean() == pytest.approx(3.5)
    assert w.percentile(0) == 2.0
    assert w.percentile(100) == 5.0


# --------------------------------------------------------- rule parsing
def test_parse_rule_forms():
    r = parse_rule("serve.p99_wall_us <= 250000")
    assert r == ("serve.p99_wall_us", "serve.p99_wall_us", "<=",
                 250000.0, 3, 3)
    named = parse_rule("lat: serve.p99_wall_us <= 2.5e5 for 5/2")
    assert named.name == "lat"
    assert named.threshold == 2.5e5
    assert (named.breach_n, named.clear_n) == (5, 2)
    above = parse_rule("calib.ratio >= 0.75")
    assert above.ok(0.8) and not above.ok(0.5)
    below = parse_rule("drift.id_psi <= 0.25")
    assert below.ok(0.1) and not below.ok(0.3)
    for bad in ("nonsense", "sig < 5", "sig <= ", "sig <= 1 for 0/3"):
        with pytest.raises(ValueError):
            parse_rule(bad)


def _dispatch(led, wall_s=0.001, occupancy=1.0, qdelay=0.0):
    led.emit("serve_dispatch", envelope=[1, 8, 8, 4], g=1, requests=1,
             candidates=4, occupancy=occupancy, wall_s=wall_s,
             flush_reason="direct", queue_delay_us=qdelay)


# ----------------------------------------------------------- hysteresis
def test_hysteresis_fire_and_clear_on_consecutive_windows():
    led = obs.RunLedger(None)
    reg = MetricsRegistry()
    mon = HealthMonitor([parse_rule("serve.p99_wall_us <= 1000 for 3/2")],
                        window=4, eval_every=1, registry=reg).attach(led)
    # 2 breaching evals: not yet (hysteresis holds)
    _dispatch(led, wall_s=0.01)
    _dispatch(led, wall_s=0.01)
    assert mon.alerts() == []
    _dispatch(led, wall_s=0.01)  # 3rd consecutive: FIRES
    assert [a["state"] for a in mon.alerts()] == ["firing"]
    assert mon.active_alerts() == ["serve.p99_wall_us"]
    # steady breach: no re-emission (state changes only)
    _dispatch(led, wall_s=0.01)
    assert len(mon.alerts()) == 1
    # window=4 forgets the slow dispatches after enough fast ones
    _dispatch(led, wall_s=1e-5)
    assert [a["state"] for a in mon.alerts()] == ["firing"]  # 1 OK: holds
    for _ in range(4):
        _dispatch(led, wall_s=1e-5)
    assert [a["state"] for a in mon.alerts()] == ["firing", "cleared"]
    assert mon.active_alerts() == []
    # every emitted record validates against the ledger schema
    for a in led.events("alert"):
        assert validate_event(a) is None


def test_one_noisy_window_never_fires_and_breach_counter_resets():
    led = obs.RunLedger(None)
    mon = HealthMonitor([parse_rule("serve.occupancy >= 0.5 for 3/3")],
                        window=1, eval_every=1,
                        registry=MetricsRegistry()).attach(led)
    for occ in (0.1, 0.1, 0.9, 0.1, 0.1, 0.9):  # never 3 in a row
        _dispatch(led, occupancy=occ)
    assert mon.alerts() == []


def test_cold_signals_are_skipped_not_breached():
    reg = MetricsRegistry()
    mon = HealthMonitor([parse_rule("drift.score_psi <= 0.25"),
                         parse_rule("eval.next_day_nll <= 0.5")],
                        registry=reg)
    assert mon.evaluate() == []  # nothing warm: no rule evaluates
    sigs = mon.signals()
    assert sigs["drift.score_psi"] is None
    assert sigs["serve.p99_wall_us"] is None


def test_stream_eval_records_feed_eval_signals():
    led = obs.RunLedger(None)
    mon = HealthMonitor([parse_rule("eval.next_day_nll <= 0.5 for 2/2")],
                        eval_every=1, registry=MetricsRegistry()).attach(led)
    led.emit("stream_eval", day=0, next_day_nll=0.9, next_day_auc=0.5)
    led.emit("stream_eval", day=1, next_day_nll=0.9, next_day_auc=0.5)
    assert [a["state"] for a in mon.alerts()] == ["firing"]
    assert mon.signals()["eval.next_day_nll"] == 0.9
    assert mon.signals()["eval.next_day_auc"] == 0.5


def test_registry_alert_series_and_queue_signals():
    led = obs.RunLedger(None)
    reg = MetricsRegistry()
    prev_reg = obs.set_registry(reg)
    try:
        mon = HealthMonitor([parse_rule("queue.pending <= 2 for 1/1")],
                            eval_every=1, registry=reg).attach(led)
        reg.gauge("serve_queue_pending", queue="9").set(5.0)
        _dispatch(led)
        assert mon.active_alerts() == ["queue.pending"]
        snap = reg.as_dict()
        assert snap["obs_alerts{rule=queue.pending,state=firing}"][
            "value"] == 1.0
        assert snap["obs_alert_active{rule=queue.pending}"]["value"] == 1.0
        reg.gauge("serve_queue_pending", queue="9").set(0.0)
        _dispatch(led)
        assert mon.active_alerts() == []
        assert reg.as_dict()["obs_alert_active{rule=queue.pending}"][
            "value"] == 0.0
    finally:
        obs.set_registry(prev_reg)


def test_queue_updates_pending_gauge():
    import jax.numpy as jnp

    from repro.serve import MicroBatchQueue, QueueConfig, ScoringEngine
    from repro.serve import synthetic_requests

    rng = np.random.default_rng(0)
    theta = jnp.asarray(rng.normal(size=(300, 6)).astype(np.float32) * 0.3)
    reqs = synthetic_requests(3, num_features=300, seed=1,
                              k_user=(4, 4), k_ad=(2, 2), n_ads=(3, 3))
    queue = MicroBatchQueue(ScoringEngine(theta),
                            QueueConfig(max_batch=8, max_delay_us=1e6))
    gauge = queue.stats._pending
    queue.submit(reqs[0], now=0.0)
    queue.submit(reqs[1], now=1e-5)
    assert gauge.value == float(queue.pending) > 0
    queue.drain(now=1.0)
    assert gauge.value == 0.0 == float(queue.pending)


def test_monitor_reentrancy_alert_records_are_not_reingested():
    # the monitor alerts INTO the ledger it observes; its own alert
    # records must not recurse back through ingest
    led = obs.RunLedger(None)
    mon = HealthMonitor([parse_rule("serve.occupancy >= 0.9 for 1/1")],
                        eval_every=1, registry=MetricsRegistry()).attach(led)
    _dispatch(led, occupancy=0.1)  # fires inside the observer callback
    assert [a["state"] for a in mon.alerts()] == ["firing"]
    assert len(led.events("alert")) == 1  # exactly one, no echo

    mon.detach()
    _dispatch(led, occupancy=0.1)
    assert len(led.events("serve_dispatch")) == 2
    assert len(mon.alerts()) == 1  # detached: no longer listening


def test_null_monitor_is_inert_and_is_the_default():
    assert obs.get_monitor() is obs.NULL_MONITOR
    assert obs.NULL_MONITOR.enabled is False
    obs.NULL_MONITOR.observe_scores(np.array([0.5]))
    obs.NULL_MONITOR.observe_ids(np.array([1]))
    obs.NULL_MONITOR.observe_predictions(np.array([0.5]), np.array([1.0]))
    obs.NULL_MONITOR.ingest({"kind": "serve_dispatch"})
    assert obs.NULL_MONITOR.evaluate() == []
    assert obs.NULL_MONITOR.alerts() == []
    assert obs.NULL_MONITOR.summary()["alerts"] == 0


def test_configure_monitor_installs_and_restores_default(tmp_path):
    report = tmp_path / "report.md"
    session = obs.configure(monitor=True, report_out=str(report),
                            meta={"driver": "test", "mode": "unit"})
    try:
        mon = obs.get_monitor()
        assert mon.enabled and isinstance(mon, HealthMonitor)
        assert obs.get_ledger().enabled  # monitor implied a ledger
        _dispatch(obs.get_ledger())
    finally:
        session.close()
    assert obs.get_monitor() is obs.NULL_MONITOR
    text = report.read_text()
    assert text.startswith("# Run report")
    assert "serve_dispatch" in text  # the dispatch made it to the report


def test_default_rules_cover_documented_signals():
    from repro.obs.monitor import default_rules

    rules = default_rules()
    signals = {r.signal for r in rules}
    assert {"serve.p99_wall_us", "serve.p99_queue_delay_us",
            "serve.occupancy", "calib.ratio", "drift.score_psi",
            "drift.id_psi"} <= signals
    mon = HealthMonitor(registry=MetricsRegistry())  # default set loads
    known = set(mon.signals())
    assert signals <= known
