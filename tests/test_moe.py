"""MoE layer tests: sort-based dispatch vs dense oracle, capacity drops,
load-balance aux, and the shard_map expert-parallel path (subprocess)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import moe as M

CFG = ArchConfig(
    name="toy-moe", family="moe", source="test",
    num_layers=2, d_model=32, num_heads=4, num_kv_heads=4,
    d_ff=64, vocab_size=64, num_experts=4, top_k=2,
)


def _params(key=jax.random.PRNGKey(0)):
    return M.init_moe(key, CFG, jnp.float32)


def test_dispatch_matches_dense_oracle_when_capacity_ample():
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 8, CFG.d_model))
    p = _params()
    # capacity_factor big enough that nothing is dropped
    out, aux = M.moe_ffn(x, p, CFG, mesh=None, capacity_factor=8.0)
    ref, aux_ref = M.moe_ffn_dense_reference(x, p, CFG)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)


def test_capacity_drops_bounded():
    """With tiny capacity, output degrades gracefully (some tokens zero
    contribution) but stays finite; nothing crashes."""
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, CFG.d_model))
    p = _params()
    out, _ = M.moe_ffn(x, p, CFG, mesh=None, capacity_factor=0.25)
    assert np.all(np.isfinite(np.asarray(out)))
    ref, _ = M.moe_ffn_dense_reference(x, p, CFG)
    # dropped-token rows are zero; kept rows match the oracle
    flat_o = np.asarray(out).reshape(-1, CFG.d_model)
    flat_r = np.asarray(ref).reshape(-1, CFG.d_model)
    kept = np.abs(flat_o).sum(-1) > 0
    assert kept.sum() > 0


def test_aux_loss_uniform_router_is_one():
    """Perfectly balanced router -> aux == 1 (Switch normalisation)."""
    T, E = 4096, CFG.num_experts
    probs = jnp.full((T, E), 1.0 / E)
    idx = jnp.tile(jnp.arange(E), T // E)[:, None] * jnp.ones((1, 2), jnp.int32)
    aux = M._aux_loss(probs, idx, E)
    np.testing.assert_allclose(float(aux), 1.0, rtol=1e-3)


def test_moe_grads_flow_to_router_and_experts():
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(3), (2, 8, CFG.d_model))
    p = _params()

    def loss(p):
        out, aux = M.moe_ffn(x, p, CFG, capacity_factor=4.0)
        return jnp.sum(out**2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("router", "w1", "w2", "w3"):
        assert float(jnp.abs(g[name]).max()) > 0, f"no grad to {name}"


SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ArchConfig
from repro.models import moe as M
from repro.launch.mesh import make_debug_mesh

cfg = ArchConfig(name="toy", family="moe", source="t", num_layers=2,
                 d_model=32, num_heads=4, num_kv_heads=4, d_ff=64,
                 vocab_size=64, num_experts=4, top_k=2)
p = M.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
mesh = make_debug_mesh(data=2, model=4)
from jax.sharding import NamedSharding, PartitionSpec as P
xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
ps = {
    "router": jax.device_put(p["router"], NamedSharding(mesh, P())),
    "w1": jax.device_put(p["w1"], NamedSharding(mesh, P("model", None, "data"))),
    "w3": jax.device_put(p["w3"], NamedSharding(mesh, P("model", None, "data"))),
    "w2": jax.device_put(p["w2"], NamedSharding(mesh, P("model", "data", None))),
}
out_sh, aux_sh = jax.jit(lambda x, p: M.moe_ffn(x, p, cfg, mesh=mesh,
                                                capacity_factor=8.0))(xs, ps)
out_ref, aux_ref = M.moe_ffn_dense_reference(x, p, cfg)
np.testing.assert_allclose(np.asarray(out_sh), np.asarray(out_ref), rtol=3e-4, atol=3e-5)
# aux is a mean of PER-DATA-SHARD Switch losses (standard practice) -> only
# approximately equal to the global-batch loss
np.testing.assert_allclose(float(aux_sh), float(aux_ref), rtol=0.05)
print("MOE-SHARD-OK")
"""


@pytest.mark.slow
def test_shard_map_expert_parallel_matches_oracle():
    env = os.environ.copy()
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SHARD_SCRIPT], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)), timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "MOE-SHARD-OK" in r.stdout


TG_SCRIPT = SHARD_SCRIPT.replace(
    'M.moe_ffn(x, p, cfg, mesh=mesh,\n                                                capacity_factor=8.0)',
    'M.moe_ffn(x, p, cfg, mesh=mesh, capacity_factor=8.0, serving_mode="token_gather")'
).replace("MOE-SHARD-OK", "MOE-TG-OK")


@pytest.mark.slow
def test_token_gather_serving_mode_matches_oracle():
    """The decode-optimised communication plan must be numerically
    identical to the dense oracle (same routing, same math)."""
    env = os.environ.copy()
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", TG_SCRIPT], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)), timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "MOE-TG-OK" in r.stdout
