"""End-to-end acceptance for the observability layer: one real
``repro.launch.train --stream`` subprocess with ``--ledger-out`` /
``--trace-out`` / ``--metrics-out`` must yield

  * a schema-valid ledger from which the per-iteration NLL/nnz curves
    and the planner's overlap ratio reconstruct exactly,
  * a loadable Chrome-trace JSON whose plan/compile/step/iter spans
    nest correctly,
  * a metrics snapshot carrying the planner series,

while the human console output keeps its pre-obs shape."""
import json
import os
import subprocess
import sys

import pytest

from repro import obs

DAYS, WINDOW, INNER = 3, 2, 2


def _contains(outer: dict, inner: dict) -> bool:
    return (outer["tid"] == inner["tid"]
            and outer["ts"] <= inner["ts"] + 1e-9
            and inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-9)


@pytest.fixture(scope="module")
def stream_run(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("obs_launch")
    paths = {"ledger": tmp / "run.jsonl", "trace": tmp / "trace.json",
             "metrics": tmp / "metrics.jsonl", "report": tmp / "report.md",
             "drift_ref": tmp / "dref.npz"}
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--stream",
         "--days", str(DAYS), "--window", str(WINDOW),
         "--inner-iters", str(INNER), "--sessions", "24",
         "--sparse-features", "1200", "--iters", "2",
         "--ledger-out", str(paths["ledger"]),
         "--trace-out", str(paths["trace"]),
         "--metrics-out", str(paths["metrics"]),
         "--report-out", str(paths["report"]),
         "--drift-ref", str(paths["drift_ref"]),
         "--monitor"],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr
    return paths, proc


@pytest.mark.slow
def test_ledger_validates_and_reconstructs_curves(stream_run):
    paths, _ = stream_run
    assert obs.validate_file(str(paths["ledger"])) == []
    recs = obs.read_jsonl(str(paths["ledger"]))

    assert recs[0]["kind"] == "run_meta"
    assert recs[0]["driver"] == "repro.launch.train"
    assert recs[0]["mode"] == "stream"

    # per-iteration objective/nnz curves: DAYS x INNER records, with
    # globally increasing step numbers
    iters = [r for r in recs if r["kind"] == "train_iter"]
    assert len(iters) == DAYS * INNER
    assert [r["step"] for r in iters] == list(range(DAYS * INNER))
    nll_curve = [r["f_new"] for r in iters]
    nnz_curve = [r["nnz"] for r in iters]
    assert all(isinstance(v, float) for v in nll_curve)
    assert all(isinstance(v, int) and v >= 0 for v in nnz_curve)

    # the window records carry the same per-iteration objective values
    wins = [r for r in recs if r["kind"] == "stream_window"]
    assert [w["day"] for w in wins] == list(range(DAYS))
    assert [f for w in wins for f in w["fs"]] == nll_curve

    # the planner's overlap ratio reconstructs from the window records
    # with the exact accounting the summary reports
    pre_build = sum(w["build_s"] for w in wins if w["prefetched"])
    pre_wait = sum(min(w["wait_s"], w["build_s"])
                   for w in wins if w["prefetched"])
    want = 1.0 - pre_wait / pre_build if pre_build > 0 else 0.0
    (summary,) = [r for r in recs if r["kind"] == "stream_summary"]
    assert summary["windows"] == DAYS
    assert summary["overlap_ratio"] == pytest.approx(want, abs=1e-9)

    # held-out next-day eval exists for every day but the last
    evals = [r for r in recs if r["kind"] == "stream_eval"]
    assert [r["day"] for r in evals] == list(range(DAYS - 1))
    assert all(r["next_day_nll"] > 0 for r in evals)


@pytest.mark.slow
def test_trace_loads_and_spans_nest(stream_run):
    paths, _ = stream_run
    doc = json.load(open(paths["trace"]))
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    by_name = {}
    for e in spans:
        by_name.setdefault(e["name"], []).append(e)

    assert len(by_name["stream/step"]) == DAYS
    assert len(by_name["train/iter"]) == DAYS * INNER
    assert len(by_name["stream/plan_window"]) == DAYS
    # every train/iter nests inside exactly one stream/step
    for it in by_name["train/iter"]:
        assert sum(_contains(st, it) for st in by_name["stream/step"]) == 1
    # every plan and compile nests inside a plan_window build
    for name in ("stream/plan", "stream/compile"):
        for sp in by_name[name]:
            assert any(_contains(pw, sp)
                       for pw in by_name["stream/plan_window"]), name
    # prefetched builds run on the replanner thread, steps on the main
    # thread — the trace must carry both thread_name metadata records
    threads = {e["args"]["name"] for e in doc["traceEvents"]
               if e["ph"] == "M" and e["name"] == "thread_name"}
    assert any(n.startswith("replanner") for n in threads), threads


@pytest.mark.slow
def test_metrics_snapshot_and_console_text(stream_run):
    paths, proc = stream_run
    series = {json.loads(ln)["series"]: json.loads(ln)
              for ln in open(paths["metrics"]) if ln.strip()}
    (windows,) = [s for k, s in series.items()
                  if k.startswith("stream_planner_windows")]
    assert windows["value"] == float(DAYS)
    assert any(k.startswith("stream_planner_build_wall_seconds")
               for k in series)

    # the human lines survived the print() -> obs.log migration
    lines = proc.stdout.splitlines()
    assert lines[0].startswith(f"stream: {DAYS} days x 24 sessions")
    day_lines = [ln for ln in lines if ln.startswith("day ")]
    assert len(day_lines) == DAYS
    assert "plan=" in day_lines[0] and "step=" in day_lines[0]
    assert "next-day nll=" in day_lines[0]
    (trained,) = [ln for ln in lines if ln.startswith("trained ")]
    assert trained.startswith(f"trained {DAYS} windows in ")
    assert "overlap ratio" in trained


@pytest.mark.slow
def test_report_reconstructs_stdout_numbers_bit_identically(stream_run):
    from repro.obs import report

    paths, proc = stream_run
    recs = obs.read_jsonl(str(paths["ledger"]))
    rep = report.build_report(recs)
    text = paths["report"].read_text()

    # the next-day decay table carries the EXACT {:.4f} strings the
    # driver printed to the console during the run
    day_lines = [ln for ln in proc.stdout.splitlines()
                 if "next-day nll=" in ln]
    assert len(day_lines) == DAYS - 1
    for row, line in zip(rep["decay"], day_lines):
        nll_str = f"{row['next_day_nll']:.4f}"
        auc_str = f"{row['next_day_auc']:.4f}"
        assert f"next-day nll={nll_str} auc={auc_str}" in line
        assert f"| {nll_str} |" in text  # and the table agrees

    # per-iteration convergence: the report rebuilds the ledger's
    # NLL/nnz curve completely and in order
    iters = [r for r in recs if r["kind"] == "train_iter"]
    assert [r["f_new"] for r in rep["convergence"]["rows"]] == \
        [r["f_new"] for r in iters]
    assert [r["nnz"] for r in rep["convergence"]["rows"]] == \
        [r["nnz"] for r in iters]
    for row in rep["convergence"]["rows"]:
        assert row["line"] in text  # reconstructed console block

    # --monitor ran: any alert records it emitted are schema-valid
    # (quality rules may or may not fire on a 2-iter smoke model)
    assert obs.validate_file(str(paths["ledger"])) == []


@pytest.mark.slow
def test_drift_ref_written_and_arms_a_monitor(stream_run):
    paths, proc = stream_run
    assert "drift reference (last held-out day" in proc.stdout
    ref = obs.load_drift_reference(str(paths["drift_ref"]))
    assert ref.num_features == 1200
    assert ref.score_counts.sum() > 0
    mon = obs.HealthMonitor(registry=obs.MetricsRegistry())
    mon.arm_drift(ref, min_count=1)
    mon.observe_scores([0.5] * 8)
    assert mon.signals()["drift.score_psi"] is not None
