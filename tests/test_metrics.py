"""Unit tests for the CTR metrics (repro.eval.metrics): AUC and the
calibration ratio get exact hand-computed cases — they gate the serving
parity checks and the bench_serve / bench_stream decay rows."""
import numpy as np
import pytest

from repro.eval import auc, calibration_ratio, log_loss, normalized_entropy


# ---------------------------------------------------------------- AUC
def test_auc_perfect_ranking():
    y = np.array([0, 0, 1, 1])
    assert auc(y, np.array([0.1, 0.2, 0.8, 0.9])) == 1.0


def test_auc_inverted_ranking():
    y = np.array([0, 0, 1, 1])
    assert auc(y, np.array([0.9, 0.8, 0.2, 0.1])) == 0.0


def test_auc_all_tied_is_half():
    y = np.array([0, 1, 0, 1, 1])
    assert auc(y, np.full(5, 0.5)) == 0.5


def test_auc_degenerate_labels():
    s = np.array([0.2, 0.4, 0.6])
    assert auc(np.zeros(3), s) == 0.5
    assert auc(np.ones(3), s) == 0.5


def test_auc_hand_case_with_tie():
    # scores: pos {0.8, 0.5}, neg {0.5, 0.2}; pairs: (0.8 beats both)=2,
    # (0.5 vs 0.5)=0.5, (0.5 beats 0.2)=1 -> 3.5/4
    y = np.array([1, 1, 0, 0])
    s = np.array([0.8, 0.5, 0.5, 0.2])
    assert auc(y, s) == pytest.approx(3.5 / 4)


def test_auc_matches_pairwise_reference():
    rng = np.random.default_rng(0)
    y = (rng.random(200) < 0.3).astype(np.float64)
    s = np.round(rng.random(200), 2)  # coarse grid -> plenty of ties
    pos, neg = s[y == 1], s[y == 0]
    wins = (pos[:, None] > neg[None, :]).sum()
    ties = (pos[:, None] == neg[None, :]).sum()
    ref = (wins + 0.5 * ties) / (len(pos) * len(neg))
    assert auc(y, s) == pytest.approx(ref, abs=1e-12)


# -------------------------------------------------------- calibration
def test_calibration_exact_ratio():
    y = np.array([1, 0, 0, 1])  # empirical CTR 0.5
    p = np.array([0.5, 0.5, 0.5, 0.5])  # mean predicted 0.5
    assert calibration_ratio(y, p) == pytest.approx(1.0)
    assert calibration_ratio(y, 2 * p / 3) == pytest.approx(2 / 3)


def test_calibration_is_mean_pred_over_mean_empirical():
    rng = np.random.default_rng(1)
    y = (rng.random(500) < 0.2).astype(np.float64)
    p = rng.random(500)
    assert calibration_ratio(y, p) == pytest.approx(p.mean() / y.mean())


def test_calibration_no_clicks_is_inf():
    assert calibration_ratio(np.zeros(4), np.full(4, 0.3)) == float("inf")


# ------------------------------------------------- log-loss / NE sanity
def test_log_loss_known_value():
    y = np.array([1.0, 0.0])
    p = np.array([0.8, 0.4])
    want = -(np.log(0.8) + np.log(0.6)) / 2
    assert log_loss(y, p) == pytest.approx(want)


def test_normalized_entropy_base_rate_predictor_is_one():
    rng = np.random.default_rng(2)
    y = (rng.random(4000) < 0.25).astype(np.float64)
    p = np.full(4000, y.mean())
    assert normalized_entropy(y, p) == pytest.approx(1.0, abs=1e-9)


def test_data_auc_reexport_is_same_function():
    from repro.data import auc as data_auc

    assert data_auc is auc
