"""Generation driver + CTR eval metrics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.eval import log_loss, normalized_entropy, report
from repro.models import init_model
from repro.models.generate import generate, sample_logits


@pytest.mark.parametrize("arch", ["llama3.2-1b", "zamba2-2.7b",
                                  "falcon-mamba-7b", "granite-moe-1b-a400m"])
def test_generate_runs_all_families(arch):
    cfg = get_config(arch).reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab_size)
    out = generate(params, cfg, prompt, max_new_tokens=5,
                   key=jax.random.PRNGKey(2), temperature=0.8, top_k=16)
    assert out.shape == (2, 5)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < cfg.vocab_size).all()


def test_greedy_sampling_is_argmax():
    logits = jnp.asarray([[0.1, 3.0, -1.0], [2.0, 0.0, 0.5]])
    out = sample_logits(logits, jax.random.PRNGKey(0), temperature=0.0)
    np.testing.assert_array_equal(np.asarray(out), [1, 0])


def test_top_k_restricts_support():
    logits = jnp.asarray([[0.0, 5.0, 4.9, -10.0]])
    for seed in range(20):
        t = sample_logits(logits, jax.random.PRNGKey(seed), temperature=1.0,
                          top_k=2)
        assert int(t[0]) in (1, 2)


def test_metrics_sane():
    rng = np.random.default_rng(0)
    p = rng.random(2000)
    y = (rng.random(2000) < p).astype(np.float32)  # perfectly calibrated
    r = report(y, p)
    assert 0.9 < r["calibration"] < 1.1
    assert r["auc"] > 0.7
    assert r["normalized_entropy"] < 1.0  # better than base-rate predictor
    # constant base-rate predictor has NE ~ 1
    base = np.full_like(p, y.mean())
    assert abs(normalized_entropy(y, base) - 1.0) < 1e-6
    # log_loss of perfect predictions ~ 0
    assert log_loss(y, y) < 1e-5
