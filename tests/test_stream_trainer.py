"""Streaming trainer gates.

  * PARITY: with window = full dataset and carry disabled, the streaming
    trainer's trajectory is bit-for-bit the full-batch OWLQN+ path
    (same f trace, same Theta) — the planner, the AOT-compiled step and
    the warm-start plumbing change WHEN things happen, never WHAT.
  * SPARSITY: exact zeros cross window boundaries exactly (rows whose
    ids are absent from a window keep their bits).
  * DRIFT: on a drifted multi-day stream, held-out next-day NLL beats a
    train-once baseline with the same total iteration budget.
  * CHECKPOINT: save -> load resumes the stream exactly (Theta + OWLQN+
    history + day cursor), continuing bit-for-bit.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.objective import nll_sparse, smooth_loss_and_grad
from repro.data.sparse import build_batch_plans
from repro.optim import OWLQNPlus
from repro.stream import DayStream, StreamTrainer


def _theta0(d, m=2, seed=0):
    return jnp.asarray(
        0.01 * np.random.default_rng(seed).normal(size=(d, 2 * m)),
        jnp.float32)


def _small_stream(days=3, **over):
    kw = dict(sessions_per_day=16, num_features=1200, active_user=6,
              active_ad=4, seed=2)
    kw.update(over)
    return DayStream(days, **kw)


@pytest.mark.parametrize("overlap", [False, True])
def test_streaming_matches_full_batch_bit_for_bit(overlap):
    """window = full dataset, carry disabled -> the full-batch trajectory."""
    D = 3
    s = _small_stream(D)
    theta0 = _theta0(s.num_features)
    iters = 4

    full = build_batch_plans(s.window(D - 1, D))
    opt = OWLQNPlus(lambda t: smooth_loss_and_grad(t, full), lam=0.1, beta=0.1)
    st = opt.init(theta0)
    step = jax.jit(opt.step)
    fs_ref = []
    for _ in range(iters):
        st, stats = step(st)
        fs_ref.append(float(stats.f_new))

    tr = StreamTrainer(s, lam=0.1, beta=0.1, window=D, inner_iters=iters,
                       history="reset", overlap=overlap)
    state = tr.init(theta0)._replace(day=D - 1)
    state, trace = tr.run(state, days=1)
    assert list(trace[0].fs) == fs_ref
    np.testing.assert_array_equal(np.asarray(jax.device_get(st.theta)),
                                  np.asarray(tr.theta(state)))
    assert state.day == D
    assert trace[0].days_in_window == D


def test_exact_zero_sparsity_across_window_boundaries():
    """A row L1/L2,1 pushed to EXACT zero must stay exact zero until a
    window's data references it again: zero rows with zero gradient have
    a zero Eq. 9 direction, and the warm start copies bits. (Untouched
    NONZERO rows legitimately keep shrinking — the regularizer applies
    everywhere — so the invariant is about zeros, not about all
    untouched rows.)"""
    D = 3
    s = _small_stream(D, num_features=4000)
    d = s.num_features
    theta0 = _theta0(d)
    tr = StreamTrainer(s, lam=0.3, beta=0.3, window=1, inner_iters=3)
    state = tr.init(theta0)
    checked = 0
    for t in range(D):
        prev = tr.theta(state) if t else None
        state, _ = tr.run(state, days=1)
        th = np.asarray(tr.theta(state))
        wb = s.window(t, 1)
        touched = np.zeros(d, bool)
        for ids in (np.asarray(wb.user_ids), np.asarray(wb.ad_ids)):
            touched[ids.reshape(-1)] = True
        if prev is not None:
            zero_rows = ~np.asarray(prev).any(axis=1)
            keep = zero_rows & ~touched
            assert not th[keep].any(), int((th[keep] != 0).sum())
            checked += int(keep.sum())
    assert checked > 0, "no exact-zero untouched rows crossed a boundary"


def test_history_carry_runs_and_uses_safeguard():
    s = _small_stream(3)
    tr = StreamTrainer(s, lam=0.1, beta=0.1, window=2, inner_iters=2,
                       history="carry")
    state, trace = tr.run(tr.init(_theta0(s.num_features)))
    assert state.day == 3 and len(trace) == 3
    # the carried state keeps counting steps across windows
    assert int(state.opt.step) == 6
    assert all(np.isfinite(f) for w in trace for f in w.fs)


def test_streaming_beats_train_once_on_next_day_nll():
    """The drifted-stream gate (acceptance criterion): same total
    iteration budget, streamed warm starts vs everything on day 0."""
    d, m, DAYS = 400, 4, 6
    s = DayStream(DAYS + 1, sessions_per_day=192, num_features=d,
                  active_user=8, active_ad=5, drift=0.06, head_width=0.06,
                  head_frac=0.85, seed=11)
    theta0 = _theta0(d, m=m)
    held = s.day(DAYS)
    B = held.y.shape[0]

    def nll(trainer, state):
        return float(nll_sparse(trainer.theta(state), held)) / B

    base = StreamTrainer(s, lam=0.25, beta=0.25, window=1,
                         inner_iters=5 * DAYS)
    sb, _ = base.run(base.init(theta0), days=1)
    stream = StreamTrainer(s, lam=0.25, beta=0.25, window=2, inner_iters=5)
    ss, _ = stream.run(stream.init(theta0), days=DAYS)
    nll_base, nll_stream = nll(base, sb), nll(stream, ss)
    assert nll_stream < nll_base - 0.02, (nll_stream, nll_base)


def test_checkpoint_roundtrip_resumes_exactly():
    s = _small_stream(4)
    theta0 = _theta0(s.num_features)
    tr = StreamTrainer(s, lam=0.1, beta=0.1, window=2, inner_iters=2)
    mid, _ = tr.run(tr.init(theta0), days=2)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "stream.npz")
        tr.save(path, mid)
        back = tr.load(path, theta0)
    # the cursor comes back a python int, the state bit-identical
    assert back.day == 2 and type(back.day) is int
    for a, b in zip(jax.tree.leaves(mid.opt), jax.tree.leaves(back.opt)):
        np.testing.assert_array_equal(np.asarray(jax.device_get(a)),
                                      np.asarray(jax.device_get(b)))
    # continuing from the restored state == continuing uninterrupted
    fin_a, tr_a = tr.run(mid, days=2)
    fin_b, tr_b = tr.run(back, days=2)
    assert [w.fs for w in tr_a] == [w.fs for w in tr_b]
    np.testing.assert_array_equal(np.asarray(tr.theta(fin_a)),
                                  np.asarray(tr.theta(fin_b)))
    assert fin_a.day == fin_b.day == 4


def test_checkpoint_rejects_mismatched_shapes():
    """Resuming under a different configuration must fail loudly, not
    silently train on a stale-shaped Theta."""
    s = _small_stream(2)
    tr = StreamTrainer(s, lam=0.1, beta=0.1, inner_iters=1)
    state, _ = tr.run(tr.init(_theta0(s.num_features)), days=1)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "stream.npz")
        tr.save(path, state)
        with pytest.raises(ValueError, match="different configuration"):
            tr.load(path, _theta0(s.num_features // 2))


def test_planner_stats_populated_and_days_bounds():
    s = _small_stream(2)
    tr = StreamTrainer(s, lam=0.1, beta=0.1, inner_iters=1)
    state, trace = tr.run(tr.init(_theta0(s.num_features)))
    assert tr.planner_stats.windows == 2
    assert tr.planner_stats.build_seconds > 0
    assert all(w.build_seconds > 0 and w.step_seconds > 0 for w in trace)
    # running past the end errors; running an exhausted stream is a no-op
    with pytest.raises(ValueError, match="days"):
        tr.run(state, days=1)
    same, empty = tr.run(state)
    assert empty == [] and same is state


def test_constructor_validation():
    s = _small_stream(2)
    with pytest.raises(ValueError, match="history"):
        StreamTrainer(s, lam=0.1, beta=0.1, history="sometimes")
    with pytest.raises(ValueError, match=">= 1"):
        StreamTrainer(s, lam=0.1, beta=0.1, window=0)
    with pytest.raises(ValueError, match="mesh"):
        StreamTrainer(s, lam=0.1, beta=0.1, partition=object())
