"""§Perf sharding variants must be NUMERICALLY IDENTICAL to the baseline
plan — they change communication/layout, not math. (subprocess, 8 devices)"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.launch.mesh import make_debug_mesh
from repro.models import forward, init_model, param_specs

mesh = make_debug_mesh(data=2, model=4)
base = get_config("qwen1.5-32b").reduced()
base = dataclasses.replace(base, attn_chunk=16)
params = init_model(base, jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, base.vocab_size)

def run(cfg):
    pspec = param_specs(cfg, model_size=4)
    ps = jax.tree.map(lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                      params, pspec)
    ts = jax.device_put(tokens, NamedSharding(mesh, P("data", None)))
    logits, _ = jax.jit(lambda p, t: forward(p, cfg, tokens=t, mesh=mesh,
                                             remat=False))(ps, ts)
    return np.asarray(logits, np.float32)

ref = run(base)
for variant in (
    dataclasses.replace(base, seq_parallel=True),
    dataclasses.replace(base, attn_shard="head_dim"),
    dataclasses.replace(base, seq_parallel=True, attn_shard="head_dim"),
):
    out = run(variant)
    # resharding changes bf16 reduction order -> tiny per-element noise;
    # demand tight agreement for ~all elements and bounded worst case
    close = np.isclose(out, ref, rtol=3e-2, atol=3e-2).mean()
    assert close > 0.998, close
    np.testing.assert_allclose(out, ref, rtol=0.5, atol=0.08)
    assert abs(out.mean() - ref.mean()) < 1e-3
print("VARIANTS-OK")
"""


@pytest.mark.slow
def test_sharding_variants_numerically_identical():
    env = os.environ.copy()
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "VARIANTS-OK" in r.stdout
