"""The CI bench-regression gate (benchmarks/check_regression.py):
flattening, rule precedence, per-kind tolerance math, the
missing-metric / recorded-error failure modes, and the markdown table
that lands in $GITHUB_STEP_SUMMARY."""
import json
import sys

from benchmarks import check_regression as cr


# ------------------------------------------------------------- plumbing
def test_flatten_nested_and_lists():
    flat = cr.flatten({"a": {"b": 1, "c": [10, {"d": "x"}]}, "e": 2.5})
    assert flat == {"a.b": 1, "a.c.0": 10, "a.c.1.d": "x", "e": 2.5}


def test_rule_precedence():
    # load-section latency gates loosely; its flush mix is info
    assert cr.rule_for("configs.t.load.500.latency_p99_us")[0] == "lower_better"
    assert cr.rule_for("configs.t.load.500.flushes.deadline")[0] == "info"
    # load-section config echoes are info, top-level ones exact
    assert cr.rule_for("configs.t.load.500.max_delay_us")[0] == "info"
    assert cr.rule_for("configs.t.d")[0] == "exact"
    # deterministic counters gate exactly even though they look "speedy"
    assert cr.rule_for("configs.t.engine_batched.compiles")[0] == "exact"
    assert cr.rule_for("configs.t.engine_batched.dispatches")[0] == "exact"
    # engine wall-clock derived rates are loose, occupancy/qps info
    assert cr.rule_for("configs.t.engine_batched.candidates_per_sec")[0] \
        == "higher_better"
    assert cr.rule_for("configs.t.engine_batched.occupancy")[0] == "info"
    assert cr.rule_for("error")[0] == "forbidden"
    assert cr.rule_for("configs.t.quality.auc_pruned")[0] == "higher_better"
    assert cr.rule_for("something.unknown_metric")[0] == "info"


# -------------------------------------------------------------- compare
def test_compare_identical_passes():
    base = {"a": {"compiles": 3, "flat_full_us": 10.0, "parity": "bitwise"}}
    rows, ok = cr.compare(base, json.loads(json.dumps(base)))
    assert ok
    assert all(r["status"] == "ok" for r in rows)


def test_compare_within_tolerance_passes():
    base = {"flat_full_us": 10.0, "shared_speedup": 2.0,
            "quality": {"auc_full": 0.80}}
    run = {"flat_full_us": 30.0,  # 3x slower < 5x limit
           "shared_speedup": 1.2,  # > 2.0 * 0.5
           "quality": {"auc_full": 0.79}}  # within 2%
    rows, ok = cr.compare(base, run)
    assert ok, [r for r in rows if r["status"] != "ok"]


def test_compare_past_tolerance_fails():
    base = {"flat_full_us": 10.0, "shared_speedup": 2.0,
            "quality": {"auc_full": 0.80}}
    bad = {"flat_full_us": 60.0, "shared_speedup": 0.9,
           "quality": {"auc_full": 0.70}}
    rows, ok = cr.compare(base, bad)
    assert not ok
    failed = {r["metric"] for r in rows if r["status"].startswith("FAIL")}
    assert failed == {"flat_full_us", "shared_speedup", "quality.auc_full"}


def test_compare_exact_metric_any_drift_fails():
    rows, ok = cr.compare({"a": {"compiles": 3}}, {"a": {"compiles": 4}})
    assert not ok


def test_compare_missing_metric_fails_new_metric_ok():
    base = {"a": {"compiles": 3, "flat_full_us": 10.0}}
    run = {"a": {"compiles": 3, "brand_new_us": 1.0}}
    rows, ok = cr.compare(base, run)
    assert not ok
    by_metric = {r["metric"]: r["status"] for r in rows}
    assert by_metric["a.flat_full_us"].startswith("FAIL: metric missing")
    assert by_metric["a.brand_new_us"] == "new (no baseline)"


def test_compare_recorded_error_fails():
    base = {"a": {"compiles": 3}}
    run = {"a": {"compiles": 3}, "error": "Traceback ..."}
    rows, ok = cr.compare(base, run)
    assert not ok
    assert any(r["metric"] == "error"
               and r["status"].startswith("FAIL") for r in rows)


def test_info_metrics_never_fail():
    base = {"engine": {"occupancy": 0.9, "qps": 5000.0},
            "load": {"500": {"flushes": {"deadline": 7}}}}
    run = {"engine": {"occupancy": 0.1, "qps": 3.0},
           "load": {"500": {"flushes": {"deadline": 999}}}}
    _, ok = cr.compare(base, run)
    assert ok


# ------------------------------------------------------------- markdown
def test_render_markdown_table():
    rows, ok = cr.compare({"a": {"compiles": 3, "occupancy": 0.5}},
                          {"a": {"compiles": 4, "occupancy": 0.5}})
    md = cr.render_markdown("BENCH_x.json", rows, ok)
    assert "**FAIL**" in md
    assert "| `a.compiles` | 3 | 4 | exact |" in md
    assert "info-only metrics not shown" in md
    # info rows stay out of the table
    assert "occupancy" not in md


# ----------------------------------------------------------------- main
def _write(path, obj):
    path.write_text(json.dumps(obj))
    return str(path)


def test_main_pass_and_summary_append(tmp_path, monkeypatch):
    base = _write(tmp_path / "base.json", {"a": {"compiles": 3}})
    run = _write(tmp_path / "run.json", {"a": {"compiles": 3}})
    summary = tmp_path / "summary.md"
    summary.write_text("# earlier step\n")
    monkeypatch.setattr(sys, "argv", ["check_regression", run,
                                      "--baseline", base,
                                      "--summary", str(summary)])
    assert cr.main() == 0
    text = summary.read_text()
    assert text.startswith("# earlier step")  # appended, not clobbered
    assert "**PASS**" in text


def test_main_fail_exit_code(tmp_path, monkeypatch):
    base = _write(tmp_path / "base.json", {"a": {"compiles": 3}})
    run = _write(tmp_path / "run.json", {"a": {"compiles": 5}})
    monkeypatch.setattr(sys, "argv",
                        ["check_regression", run, "--baseline", base])
    assert cr.main() == 1


def test_main_missing_files_explain(tmp_path, monkeypatch, capsys):
    run = _write(tmp_path / "run.json", {})
    monkeypatch.setattr(sys, "argv",
                        ["check_regression", run,
                         "--baseline", str(tmp_path / "nope.json")])
    assert cr.main() == 1
    assert "generate one" in capsys.readouterr().err
    base = _write(tmp_path / "base.json", {})
    monkeypatch.setattr(sys, "argv",
                        ["check_regression", str(tmp_path / "gone.json"),
                         "--baseline", base])
    assert cr.main() == 1
    assert "--json" in capsys.readouterr().err
