"""Attention tests: chunked-causal vs naive oracle, GQA semantics,
decode vs full, sliding-window ring buffer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def naive_causal(q, k, v, scale=None):
    """Materialised S x S oracle. q/k/v (B,S,H,hd)."""
    B, S, H, hd = q.shape
    scale = scale or hd ** -0.5
    s = jnp.einsum("bqhd,bshd->bhqs", q, k) * scale
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p, v)


@pytest.mark.parametrize("S,chunk", [(16, 4), (16, 16), (32, 8)])
@pytest.mark.parametrize("kvh,rep", [(4, 1), (2, 2), (1, 4)])
def test_chunked_matches_naive(S, chunk, kvh, rep):
    B, hd = 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, kvh * rep, hd))
    k = L.repeat_kv(jax.random.normal(ks[1], (B, S, kvh, hd)), rep)
    v = L.repeat_kv(jax.random.normal(ks[2], (B, S, kvh, hd)), rep)
    out = L.chunked_causal_attention(q, k, v, chunk=chunk)
    ref = naive_causal(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_repeat_kv_semantics():
    kv = jax.random.normal(jax.random.PRNGKey(9), (2, 4, 2, 8))
    r = L.repeat_kv(kv, 3)
    assert r.shape == (2, 4, 6, 8)
    for g in range(2):
        for j in range(3):
            np.testing.assert_array_equal(np.asarray(r[:, :, g * 3 + j]),
                                          np.asarray(kv[:, :, g]))


def test_decode_attention_matches_last_row_of_full():
    B, S, H, hd = 2, 12, 6, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    full = naive_causal(q, k, v)
    dec = L.decode_attention(q[:, -1:], k, v, valid_len=jnp.asarray(S))
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_ignores_invalid_slots():
    """Garbage beyond valid_len must not affect the result."""
    B, S, H, hd = 1, 10, 2, 4
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    out1 = L.decode_attention(q, k, v, valid_len=jnp.asarray(6))
    k2 = k.at[:, 6:].set(99.0)
    v2 = v.at[:, 6:].set(-99.0)
    out2 = L.decode_attention(q, k2, v2, valid_len=jnp.asarray(6))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


@pytest.mark.slow
def test_sliding_window_decode_equals_full_when_window_covers():
    """Ring-buffer sliding-window decode == full-cache decode while
    pos < window (the window hasn't wrapped yet)."""
    from repro.configs import get_config
    from repro.models import decode_step, init_caches, init_model

    cfg = get_config("llama3.2-1b").reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    B, W = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab_size)
    c_full = init_caches(cfg, B, 64)
    c_win = init_caches(cfg, B, W)
    for t in range(8):
        lf, c_full = decode_step(params, cfg, c_full, token=tokens[:, t],
                                 pos=jnp.asarray(t), window=False)
        lw, c_win = decode_step(params, cfg, c_win, token=tokens[:, t],
                                pos=jnp.asarray(t), window=True)
        np.testing.assert_allclose(np.asarray(lf, np.float32),
                                   np.asarray(lw, np.float32), rtol=2e-2, atol=2e-2)


def test_rope_relative_property():
    """RoPE inner products depend only on relative position."""
    hd = 16
    q = jax.random.normal(jax.random.PRNGKey(3), (hd,))
    k = jax.random.normal(jax.random.PRNGKey(4), (hd,))

    def dot_at(pq, pk):
        cos_q, sin_q = L.rope_cos_sin(jnp.asarray(pq, jnp.float32), hd, 1e4)
        cos_k, sin_k = L.rope_cos_sin(jnp.asarray(pk, jnp.float32), hd, 1e4)
        qr = L.apply_rope(q[None], cos_q[None], sin_q[None])[0]
        kr = L.apply_rope(k[None], cos_k[None], sin_k[None])[0]
        return float(qr @ kr)

    assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-4
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-4  # sanity: differs otherwise
