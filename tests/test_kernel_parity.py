"""Compiled-kernel vs interpret-mode parity (real accelerator only).

CI runs every Pallas kernel in interpret mode; this harness re-runs the
same inputs through the COMPILED path (``mode="kernel"``) and demands
the two agree. It is the bring-up gate for a real TPU: set
``REPRO_KERNEL_PARITY=1`` on a box with the accelerator attached —
without it the whole module skips, keeping CI interpret-only (a CPU
"compiled" Mosaic run would just fail to lower).

    REPRO_KERNEL_PARITY=1 PYTHONPATH=src python -m pytest \
        tests/test_kernel_parity.py -q
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.lsplm_sparse_fused.ops import (
    pad_theta,
    sparse_gather_matmul,
)
from repro.kernels.lsplm_sparse_scatter.ops import (
    build_transpose_plan,
    scatter_add_planned,
)

pytestmark = pytest.mark.skipif(
    os.environ.get("REPRO_KERNEL_PARITY", "") != "1",
    reason="compiled-kernel parity needs a real accelerator; "
           "set REPRO_KERNEL_PARITY=1 to enable")

SHAPES = [  # (N, K, d, m) — small bring-up shapes + one bench envelope
    (64, 8, 512, 2),
    (512, 8, 4_096, 4),
    (4096, 16, 16_384, 12),
]


def _make(N, K, d, m, seed):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, d, (N, K))
    ids[:, -1] = d  # keep at least one pad column in play
    vals = rng.normal(size=(N, K)).astype(np.float32)
    vals[:, -1] = 0.0
    theta = rng.normal(size=(d, 2 * m)).astype(np.float32) * 0.1
    dz = rng.normal(size=(N, 2 * m)).astype(np.float32)
    return ids, vals, theta, dz


@pytest.mark.parametrize("N,K,d,m", SHAPES)
def test_fused_forward_kernel_matches_interpret(N, K, d, m):
    ids, vals, theta, _ = _make(N, K, d, m, seed=N)
    idsj = jnp.asarray(ids, jnp.int32)
    valsj, tp = jnp.asarray(vals), pad_theta(jnp.asarray(theta))
    z_int = sparse_gather_matmul(idsj, valsj, tp, mode="interpret")
    z_ker = sparse_gather_matmul(idsj, valsj, tp, mode="kernel")
    np.testing.assert_allclose(np.asarray(z_ker), np.asarray(z_int),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("N,K,d,m", SHAPES)
def test_scatter_kernel_matches_interpret(N, K, d, m):
    ids, vals, _, dz = _make(N, K, d, m, seed=N + 1)
    plan = build_transpose_plan(ids, d + 1, pad_id=d)
    valsj, dzj = jnp.asarray(vals), jnp.asarray(dz)
    dt_int = scatter_add_planned(plan, valsj, dzj, mode="interpret")
    dt_ker = scatter_add_planned(plan, valsj, dzj, mode="kernel")
    np.testing.assert_allclose(np.asarray(dt_ker), np.asarray(dt_int),
                               rtol=2e-4, atol=2e-5)
