"""Fused sparse LS-PLM kernel: interpret-mode parity vs the jnp oracle,
custom-VJP gradients vs jax.grad of the reference, and end-to-end sparse
training parity vs the dense path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CTRBatch
from repro.core.objective import nll, nll_sparse, smooth_loss_and_grad
from repro.data.sparse import generate_sparse, to_dense
from repro.kernels.lsplm_sparse_fused.lsplm_sparse_fused import (
    lsplm_sparse_fused_forward,
)
from repro.kernels.lsplm_sparse_fused.ops import (
    lsplm_sparse_forward,
    lsplm_sparse_logps,
    pad_theta,
    sparse_gather_matmul,
)
from repro.kernels.lsplm_sparse_fused.ref import (
    lsplm_sparse_forward_ref,
    lsplm_sparse_logps_ref,
    sparse_matmul_ref,
)


def _coo(N, K, d, m, pad_frac=0.25, seed=0, scale=0.3):
    """Padded-COO batch + padded Theta. pad_frac of each row's K slots
    carry the pad id (== d) with zero value, like real ragged id lists."""
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, d, (N, K))
    vals = rng.normal(size=(N, K)).astype(np.float32)
    n_pad = int(round(pad_frac * K))
    if n_pad:
        ids[:, K - n_pad:] = d
        vals[:, K - n_pad:] = 0.0
    theta = (rng.normal(size=(d, 2 * m)) * scale).astype(np.float32)
    return (jnp.asarray(ids, jnp.int32), jnp.asarray(vals),
            pad_theta(jnp.asarray(theta)), jnp.asarray(theta))


# ------------------------------------------------------- forward parity
@pytest.mark.parametrize("N,K,d,m,pad_frac,block_n", [
    (64, 8, 256, 4, 0.25, 32),
    (50, 7, 300, 4, 0.3, 16),     # ragged N, odd K
    (128, 16, 4096, 12, 0.0, 128),  # no padding, paper's m
    (33, 12, 1024, 1, 0.5, 32),   # m=1 (LR special case), heavy padding
    (8, 4, 64, 6, 0.25, 8),
])
def test_sparse_fused_kernel_vs_oracle(N, K, d, m, pad_frac, block_n):
    ids, vals, tp, _ = _coo(N, K, d, m, pad_frac)
    p, z = lsplm_sparse_fused_forward(ids, vals, tp, block_n=block_n,
                                      interpret=True)
    np.testing.assert_allclose(np.asarray(z),
                               np.asarray(sparse_matmul_ref(ids, vals, tp)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(p), np.asarray(lsplm_sparse_forward_ref(ids, vals, tp)),
        rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mode", ["jnp", "interpret"])
def test_sparse_dispatch_modes_match_oracle(mode):
    ids, vals, tp, _ = _coo(48, 9, 500, 4, 0.3, seed=1)
    z = sparse_gather_matmul(ids, vals, tp, mode=mode, block_n=16)
    np.testing.assert_allclose(np.asarray(z),
                               np.asarray(sparse_matmul_ref(ids, vals, tp)),
                               rtol=1e-5, atol=1e-5)
    p = lsplm_sparse_forward(ids, vals, tp, mode=mode, block_n=16)
    np.testing.assert_allclose(
        np.asarray(p), np.asarray(lsplm_sparse_forward_ref(ids, vals, tp)),
        rtol=1e-5, atol=1e-6)
    lp1, lp0 = lsplm_sparse_logps(ids, vals, tp, mode=mode, block_n=16)
    r1, r0 = lsplm_sparse_logps_ref(ids, vals, tp)
    np.testing.assert_allclose(np.asarray(lp1), np.asarray(r1), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(lp0), np.asarray(r0), rtol=1e-5,
                               atol=1e-5)


# ------------------------------------------------------- custom VJP
@pytest.mark.parametrize("mode", ["jnp", "interpret"])
def test_custom_vjp_matches_jax_grad_of_reference(mode):
    """The scatter-add backward == jax.grad of the take+einsum oracle,
    through the stable-NLL head (the training path)."""
    ids, vals, tp_unused, theta = _coo(40, 6, 200, 4, 0.25, seed=2)
    y = jnp.asarray((np.random.default_rng(3).random(40) < 0.5)
                    .astype(np.float32))

    def nll_fused(theta, vals):
        lp1, lp0 = lsplm_sparse_logps(ids, vals, pad_theta(theta), mode=mode,
                                      block_n=16)
        return -jnp.sum(y * lp1 + (1 - y) * lp0)

    def nll_oracle(theta, vals):
        lp1, lp0 = lsplm_sparse_logps_ref(ids, vals, pad_theta(theta))
        return -jnp.sum(y * lp1 + (1 - y) * lp0)

    (v_f, g_f) = jax.value_and_grad(nll_fused, argnums=(0, 1))(theta, vals)
    (v_r, g_r) = jax.value_and_grad(nll_oracle, argnums=(0, 1))(theta, vals)
    np.testing.assert_allclose(float(v_f), float(v_r), rtol=1e-6)
    for a, b in zip(g_f, g_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("mode", ["jnp", "interpret"])
def test_fused_p_vjp_matches_jax_grad_of_reference(mode):
    """The fully-fused probability op's VJP (dp -> dz in-register ->
    scatter-add) == jax.grad of the oracle probabilities."""
    ids, vals, _, theta = _coo(32, 8, 128, 3, 0.25, seed=4)
    w = jnp.asarray(np.random.default_rng(5).normal(size=32), jnp.float32)

    def s_fused(theta, vals):
        return jnp.sum(w * lsplm_sparse_forward(
            ids, vals, pad_theta(theta), mode=mode, block_n=16))

    def s_oracle(theta, vals):
        return jnp.sum(w * lsplm_sparse_forward_ref(ids, vals, pad_theta(theta)))

    g_f = jax.grad(s_fused, argnums=(0, 1))(theta, vals)
    g_r = jax.grad(s_oracle, argnums=(0, 1))(theta, vals)
    for a, b in zip(g_f, g_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_grad_touches_only_active_rows():
    """The scatter-add backward writes only gathered Theta rows — the
    property that makes sparse training tractable at d ~ 1e6."""
    ids, vals, _, theta = _coo(16, 4, 512, 2, 0.0, seed=6)

    def s(theta):
        return jnp.sum(sparse_gather_matmul(ids, vals, pad_theta(theta),
                                            mode="jnp") ** 2)

    g = np.asarray(jax.grad(s)(theta))
    active = np.unique(np.asarray(ids))
    inactive = np.setdiff1d(np.arange(theta.shape[0]), active)
    assert np.abs(g[inactive]).max() == 0.0
    assert np.abs(g[active[active < theta.shape[0]]]).max() > 0.0


# ------------------------------------------------- block-size edge cases
@pytest.mark.parametrize("mode", ["jnp", "interpret"])
@pytest.mark.parametrize("N,K,d,m,block_n,block_k", [
    (17, 5, 128, 3, 64, 8),    # block_n >= N (clamped to one tile)
    (50, 7, 200, 4, 16, 4),    # N not a block multiple, ragged K chunk
    (33, 1, 96, 2, 8, 8),      # K = 1 (block_k clamped)
    (12, 9, 64, 2, 5, 2),      # odd block_n, K not a block_k multiple
])
def test_block_edge_cases_forward_and_grad(mode, N, K, d, m, block_n, block_k):
    ids, vals, tp, theta = _coo(N, K, d, m, 0.2, seed=N + K)
    z = sparse_gather_matmul(ids, vals, tp, mode=mode, block_n=block_n,
                             block_k=block_k)
    np.testing.assert_allclose(np.asarray(z),
                               np.asarray(sparse_matmul_ref(ids, vals, tp)),
                               rtol=1e-4, atol=1e-5)

    def s_fused(theta):
        return jnp.sum(sparse_gather_matmul(
            ids, vals, pad_theta(theta), mode=mode, block_n=block_n,
            block_k=block_k) ** 2)

    def s_oracle(theta):
        return jnp.sum(sparse_matmul_ref(ids, vals, pad_theta(theta)) ** 2)

    np.testing.assert_allclose(np.asarray(jax.grad(s_fused)(theta)),
                               np.asarray(jax.grad(s_oracle)(theta)),
                               rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("mode", ["jnp", "interpret"])
def test_duplicate_ids_within_sample(mode):
    """Tile dedup (hot features fetched once) must not change z or the
    gradients — duplicates collapse onto one slot with summed values."""
    rng = np.random.default_rng(13)
    N, K, d, m = 24, 8, 64, 3
    ids = rng.integers(0, d, (N, K))
    ids[:, 1] = ids[:, 0]                      # forced duplicate
    ids[:, 3] = ids[:, 2]
    vals = rng.normal(size=(N, K)).astype(np.float32)
    theta = (rng.normal(size=(d, 2 * m)) * 0.3).astype(np.float32)
    ids, vals, theta = (jnp.asarray(ids, jnp.int32), jnp.asarray(vals),
                        jnp.asarray(theta))
    tp = pad_theta(theta)
    z = sparse_gather_matmul(ids, vals, tp, mode=mode, block_n=8, block_k=4)
    np.testing.assert_allclose(np.asarray(z),
                               np.asarray(sparse_matmul_ref(ids, vals, tp)),
                               rtol=1e-4, atol=1e-5)

    def s(theta, vals):
        return jnp.sum(sparse_gather_matmul(
            ids, vals, pad_theta(theta), mode=mode, block_n=8, block_k=4) ** 2)

    def s_ref(theta, vals):
        return jnp.sum(sparse_matmul_ref(ids, vals, pad_theta(theta)) ** 2)

    g = jax.grad(s, argnums=(0, 1))(theta, vals)
    g_ref = jax.grad(s_ref, argnums=(0, 1))(theta, vals)
    for a, b in zip(g, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-5)


def test_pad_slot_with_nonzero_val_still_contracts_as_zero_row():
    """Contract robustness: a pad-id slot carrying a (convention-breaking)
    nonzero value must still contract against the ZERO pad row on the
    kernel path — the skip-DMA pipeline zeroes the buffer row in place,
    matching the oracle's actual gather of theta[D-1] == 0."""
    rng = np.random.default_rng(17)
    N, K, d, m = 16, 6, 80, 2
    ids = rng.integers(0, d, (N, K))
    ids[:, -2:] = d                              # pad ids ...
    vals = rng.normal(size=(N, K)).astype(np.float32)  # ... nonzero vals
    theta = (rng.normal(size=(d, 2 * m)) * 0.3).astype(np.float32)
    ids, vals = jnp.asarray(ids, jnp.int32), jnp.asarray(vals)
    tp = pad_theta(jnp.asarray(theta))
    for dedup in (True, False):
        z = sparse_gather_matmul(ids, vals, tp, mode="interpret", block_n=8,
                                 block_k=2, dedup=dedup)
        np.testing.assert_allclose(
            np.asarray(z), np.asarray(sparse_matmul_ref(ids, vals, tp)),
            rtol=1e-4, atol=1e-5)


# ------------------------------------------------- pad-slot gradients
@pytest.mark.parametrize("mode", ["jnp", "interpret"])
@pytest.mark.parametrize("use_plan", [False, True])
def test_pad_row_cotangent_exactly_zero(mode, use_plan):
    """Pad-id slots (value 0 by convention) must give the pad Theta row
    an EXACTLY zero cotangent, plan or no plan."""
    from repro.data.sparse import build_transpose_plan

    ids, vals, _, theta = _coo(32, 8, 120, 3, pad_frac=0.5, seed=21)
    d = theta.shape[0]
    plan = (build_transpose_plan(np.asarray(ids), d + 1, pad_id=d)
            if use_plan else None)

    def s(tp):
        return jnp.sum(sparse_gather_matmul(
            ids, vals, tp, mode=mode, block_n=16, block_k=4, plan=plan) ** 2)

    g = np.asarray(jax.grad(s)(pad_theta(theta)))   # grad w.r.t. PADDED Theta
    assert (g[d] == 0.0).all()
    # pad slots' dvals are exactly zero too (theta pad row is zero)
    dv = np.asarray(jax.grad(
        lambda v: jnp.sum(sparse_gather_matmul(
            ids, vals, pad_theta(theta), mode=mode, block_n=16, block_k=4,
            plan=plan) ** 2))(vals))
    assert (dv[np.asarray(ids) == d] == 0.0).all()


def test_pad_row_stays_zero_through_owlqn_step():
    """An OWLQN+ step on the sparse loss never moves untouched feature
    rows off exact zero: their smooth gradient is exactly 0, so the
    L1 orthant logic keeps them pinned (the property that makes 1e6-
    column training sparse in practice). The conceptual pad row (id d)
    is rebuilt as zero by pad_theta every evaluation by construction."""
    from repro.optim import OWLQNPlus

    b = generate_sparse(num_features=300, num_user_features_range=(200, 300),
                        sessions=8, seed=23)
    d, m = b.num_features, 2
    theta0 = jnp.zeros((d, 2 * m), jnp.float32)
    active = (set(np.asarray(b.user_ids).ravel().tolist())
              | set(np.asarray(b.ad_ids).ravel().tolist())) - {d}
    untouched = np.setdiff1d(np.arange(d), np.asarray(sorted(active)))
    assert untouched.size > 0

    opt = OWLQNPlus(lambda t: smooth_loss_and_grad(t, b), lam=0.2, beta=0.2)
    st = opt.init(theta0)
    for _ in range(2):
        st, _ = jax.jit(opt.step)(st)
    theta = np.asarray(st.theta)
    assert (theta[untouched] == 0.0).all()
    assert np.abs(theta).max() > 0.0            # the step did move something


# ------------------------------------------------- plan/no-plan parity
@pytest.mark.parametrize("mode", ["jnp", "interpret"])
def test_plan_and_noplan_backwards_agree(mode):
    from repro.data.sparse import build_transpose_plan

    ids, vals, _, theta = _coo(48, 9, 500, 4, 0.3, seed=31)
    d = theta.shape[0]
    plan = build_transpose_plan(np.asarray(ids), d + 1, pad_id=d)

    def loss(theta, vals, plan):
        lp1, lp0 = lsplm_sparse_logps(ids, vals, pad_theta(theta), mode=mode,
                                      block_n=16, plan=plan)
        return jnp.sum(lp1 - 0.5 * lp0)

    g_plan = jax.grad(loss, argnums=(0, 1))(theta, vals, plan)
    g_none = jax.grad(loss, argnums=(0, 1))(theta, vals, None)
    for a, b in zip(g_plan, g_none):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-5)


def test_generated_batches_carry_plans_and_train_identically():
    """generate_sparse attaches transpose plans; training with them must
    match a plan-free batch exactly (same objective trace, same Theta)."""
    from repro.optim import OWLQNPlus

    b_plan = generate_sparse(num_features=250, sessions=8,
                             num_user_features_range=(150, 250), seed=41)
    assert b_plan.user_plan is not None and b_plan.ad_plan is not None
    b_none = b_plan._replace(user_plan=None, ad_plan=None)

    def run(batch):
        theta0 = jnp.asarray(
            0.05 * np.random.default_rng(42).normal(size=(250, 4)), jnp.float32)
        opt = OWLQNPlus(lambda t: smooth_loss_and_grad(t, batch),
                        lam=0.3, beta=0.3)
        st = opt.init(theta0)
        fs = []
        for _ in range(2):
            st, stats = jax.jit(opt.step)(st)
            fs.append(float(stats.f_new))
        return np.asarray(st.theta), fs

    t_p, f_p = run(b_plan)
    t_n, f_n = run(b_none)
    np.testing.assert_allclose(f_p, f_n, rtol=2e-4)
    np.testing.assert_allclose(t_p, t_n, rtol=2e-3, atol=2e-5)


# ------------------------------------------------- end-to-end training
def test_sparse_train_step_parity_vs_dense():
    """One smooth_loss_and_grad on a SparseCTRBatch (fused path) must
    match the dense CTRBatch path on the densified batch — value AND
    gradient, i.e. a full OWLQN+ step sees identical inputs."""
    b = generate_sparse(num_features=400, num_user_features_range=(250, 400),
                        sessions=12, seed=7)
    d, m = b.num_features, 3
    theta = jnp.asarray(
        np.random.default_rng(8).normal(size=(d, 2 * m)) * 0.2, jnp.float32)

    v_s, g_s = smooth_loss_and_grad(theta, b)  # sparse dispatch -> fused
    dense = CTRBatch(x=jnp.asarray(to_dense(b)), y=b.y)
    v_d, g_d = jax.value_and_grad(nll)(theta, dense)

    np.testing.assert_allclose(float(v_s), float(v_d), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g_s), np.asarray(g_d),
                               rtol=2e-4, atol=1e-5)


def test_nll_sparse_equals_data_layer_sparse_nll():
    from repro.data.sparse import sparse_nll

    b = generate_sparse(num_features=300, num_user_features_range=(200, 300),
                        sessions=8, seed=9)
    theta = jnp.asarray(
        np.random.default_rng(10).normal(size=(300, 8)) * 0.1, jnp.float32)
    np.testing.assert_allclose(float(nll_sparse(theta, b)),
                               float(sparse_nll(theta, b)), rtol=1e-7)


def test_sparse_train_steps_match_dense_steps():
    """Two full OWLQN+ iterations, sparse-fused vs dense: same objective
    trace and same Theta (the orthant logic is sign-exact)."""
    from repro.optim import OWLQNPlus

    b = generate_sparse(num_features=200, num_user_features_range=(120, 200),
                        sessions=8, seed=11)
    d, m = b.num_features, 2
    theta0 = jnp.asarray(
        0.05 * np.random.default_rng(12).normal(size=(d, 2 * m)), jnp.float32)
    dense = CTRBatch(x=jnp.asarray(to_dense(b)), y=b.y)

    def run(batch):
        opt = OWLQNPlus(lambda t: smooth_loss_and_grad(t, batch),
                        lam=0.3, beta=0.3)
        st = opt.init(theta0)
        fs = []
        for _ in range(2):
            st, stats = jax.jit(opt.step)(st)
            fs.append(float(stats.f_new))
        return np.asarray(st.theta), fs

    t_s, f_s = run(b)
    t_d, f_d = run(dense)
    np.testing.assert_allclose(f_s, f_d, rtol=2e-4)
    np.testing.assert_allclose(t_s, t_d, rtol=2e-3, atol=2e-5)
    np.testing.assert_array_equal(t_s == 0.0, t_d == 0.0)
