"""Fused sparse LS-PLM kernel: interpret-mode parity vs the jnp oracle,
custom-VJP gradients vs jax.grad of the reference, and end-to-end sparse
training parity vs the dense path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CTRBatch
from repro.core.objective import nll, nll_sparse, smooth_loss_and_grad
from repro.data.sparse import generate_sparse, to_dense
from repro.kernels.lsplm_sparse_fused.lsplm_sparse_fused import (
    lsplm_sparse_fused_forward,
)
from repro.kernels.lsplm_sparse_fused.ops import (
    lsplm_sparse_forward,
    lsplm_sparse_logps,
    pad_theta,
    sparse_gather_matmul,
)
from repro.kernels.lsplm_sparse_fused.ref import (
    lsplm_sparse_forward_ref,
    lsplm_sparse_logps_ref,
    sparse_matmul_ref,
)


def _coo(N, K, d, m, pad_frac=0.25, seed=0, scale=0.3):
    """Padded-COO batch + padded Theta. pad_frac of each row's K slots
    carry the pad id (== d) with zero value, like real ragged id lists."""
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, d, (N, K))
    vals = rng.normal(size=(N, K)).astype(np.float32)
    n_pad = int(round(pad_frac * K))
    if n_pad:
        ids[:, K - n_pad:] = d
        vals[:, K - n_pad:] = 0.0
    theta = (rng.normal(size=(d, 2 * m)) * scale).astype(np.float32)
    return (jnp.asarray(ids, jnp.int32), jnp.asarray(vals),
            pad_theta(jnp.asarray(theta)), jnp.asarray(theta))


# ------------------------------------------------------- forward parity
@pytest.mark.parametrize("N,K,d,m,pad_frac,block_n", [
    (64, 8, 256, 4, 0.25, 32),
    (50, 7, 300, 4, 0.3, 16),     # ragged N, odd K
    (128, 16, 4096, 12, 0.0, 128),  # no padding, paper's m
    (33, 12, 1024, 1, 0.5, 32),   # m=1 (LR special case), heavy padding
    (8, 4, 64, 6, 0.25, 8),
])
def test_sparse_fused_kernel_vs_oracle(N, K, d, m, pad_frac, block_n):
    ids, vals, tp, _ = _coo(N, K, d, m, pad_frac)
    p, z = lsplm_sparse_fused_forward(ids, vals, tp, block_n=block_n,
                                      interpret=True)
    np.testing.assert_allclose(np.asarray(z),
                               np.asarray(sparse_matmul_ref(ids, vals, tp)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(p), np.asarray(lsplm_sparse_forward_ref(ids, vals, tp)),
        rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mode", ["jnp", "interpret"])
def test_sparse_dispatch_modes_match_oracle(mode):
    ids, vals, tp, _ = _coo(48, 9, 500, 4, 0.3, seed=1)
    z = sparse_gather_matmul(ids, vals, tp, mode=mode, block_n=16)
    np.testing.assert_allclose(np.asarray(z),
                               np.asarray(sparse_matmul_ref(ids, vals, tp)),
                               rtol=1e-5, atol=1e-5)
    p = lsplm_sparse_forward(ids, vals, tp, mode=mode, block_n=16)
    np.testing.assert_allclose(
        np.asarray(p), np.asarray(lsplm_sparse_forward_ref(ids, vals, tp)),
        rtol=1e-5, atol=1e-6)
    lp1, lp0 = lsplm_sparse_logps(ids, vals, tp, mode=mode, block_n=16)
    r1, r0 = lsplm_sparse_logps_ref(ids, vals, tp)
    np.testing.assert_allclose(np.asarray(lp1), np.asarray(r1), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(lp0), np.asarray(r0), rtol=1e-5,
                               atol=1e-5)


# ------------------------------------------------------- custom VJP
@pytest.mark.parametrize("mode", ["jnp", "interpret"])
def test_custom_vjp_matches_jax_grad_of_reference(mode):
    """The scatter-add backward == jax.grad of the take+einsum oracle,
    through the stable-NLL head (the training path)."""
    ids, vals, tp_unused, theta = _coo(40, 6, 200, 4, 0.25, seed=2)
    y = jnp.asarray((np.random.default_rng(3).random(40) < 0.5)
                    .astype(np.float32))

    def nll_fused(theta, vals):
        lp1, lp0 = lsplm_sparse_logps(ids, vals, pad_theta(theta), mode=mode,
                                      block_n=16)
        return -jnp.sum(y * lp1 + (1 - y) * lp0)

    def nll_oracle(theta, vals):
        lp1, lp0 = lsplm_sparse_logps_ref(ids, vals, pad_theta(theta))
        return -jnp.sum(y * lp1 + (1 - y) * lp0)

    (v_f, g_f) = jax.value_and_grad(nll_fused, argnums=(0, 1))(theta, vals)
    (v_r, g_r) = jax.value_and_grad(nll_oracle, argnums=(0, 1))(theta, vals)
    np.testing.assert_allclose(float(v_f), float(v_r), rtol=1e-6)
    for a, b in zip(g_f, g_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("mode", ["jnp", "interpret"])
def test_fused_p_vjp_matches_jax_grad_of_reference(mode):
    """The fully-fused probability op's VJP (dp -> dz in-register ->
    scatter-add) == jax.grad of the oracle probabilities."""
    ids, vals, _, theta = _coo(32, 8, 128, 3, 0.25, seed=4)
    w = jnp.asarray(np.random.default_rng(5).normal(size=32), jnp.float32)

    def s_fused(theta, vals):
        return jnp.sum(w * lsplm_sparse_forward(
            ids, vals, pad_theta(theta), mode=mode, block_n=16))

    def s_oracle(theta, vals):
        return jnp.sum(w * lsplm_sparse_forward_ref(ids, vals, pad_theta(theta)))

    g_f = jax.grad(s_fused, argnums=(0, 1))(theta, vals)
    g_r = jax.grad(s_oracle, argnums=(0, 1))(theta, vals)
    for a, b in zip(g_f, g_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_grad_touches_only_active_rows():
    """The scatter-add backward writes only gathered Theta rows — the
    property that makes sparse training tractable at d ~ 1e6."""
    ids, vals, _, theta = _coo(16, 4, 512, 2, 0.0, seed=6)

    def s(theta):
        return jnp.sum(sparse_gather_matmul(ids, vals, pad_theta(theta),
                                            mode="jnp") ** 2)

    g = np.asarray(jax.grad(s)(theta))
    active = np.unique(np.asarray(ids))
    inactive = np.setdiff1d(np.arange(theta.shape[0]), active)
    assert np.abs(g[inactive]).max() == 0.0
    assert np.abs(g[active[active < theta.shape[0]]]).max() > 0.0


# ------------------------------------------------- end-to-end training
def test_sparse_train_step_parity_vs_dense():
    """One smooth_loss_and_grad on a SparseCTRBatch (fused path) must
    match the dense CTRBatch path on the densified batch — value AND
    gradient, i.e. a full OWLQN+ step sees identical inputs."""
    b = generate_sparse(num_features=400, num_user_features_range=(250, 400),
                        sessions=12, seed=7)
    d, m = b.num_features, 3
    theta = jnp.asarray(
        np.random.default_rng(8).normal(size=(d, 2 * m)) * 0.2, jnp.float32)

    v_s, g_s = smooth_loss_and_grad(theta, b)  # sparse dispatch -> fused
    dense = CTRBatch(x=jnp.asarray(to_dense(b)), y=b.y)
    v_d, g_d = jax.value_and_grad(nll)(theta, dense)

    np.testing.assert_allclose(float(v_s), float(v_d), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g_s), np.asarray(g_d),
                               rtol=2e-4, atol=1e-5)


def test_nll_sparse_equals_data_layer_sparse_nll():
    from repro.data.sparse import sparse_nll

    b = generate_sparse(num_features=300, num_user_features_range=(200, 300),
                        sessions=8, seed=9)
    theta = jnp.asarray(
        np.random.default_rng(10).normal(size=(300, 8)) * 0.1, jnp.float32)
    np.testing.assert_allclose(float(nll_sparse(theta, b)),
                               float(sparse_nll(theta, b)), rtol=1e-7)


def test_sparse_train_steps_match_dense_steps():
    """Two full OWLQN+ iterations, sparse-fused vs dense: same objective
    trace and same Theta (the orthant logic is sign-exact)."""
    from repro.optim import OWLQNPlus

    b = generate_sparse(num_features=200, num_user_features_range=(120, 200),
                        sessions=8, seed=11)
    d, m = b.num_features, 2
    theta0 = jnp.asarray(
        0.05 * np.random.default_rng(12).normal(size=(d, 2 * m)), jnp.float32)
    dense = CTRBatch(x=jnp.asarray(to_dense(b)), y=b.y)

    def run(batch):
        opt = OWLQNPlus(lambda t: smooth_loss_and_grad(t, batch),
                        lam=0.3, beta=0.3)
        st = opt.init(theta0)
        fs = []
        for _ in range(2):
            st, stats = jax.jit(opt.step)(st)
            fs.append(float(stats.f_new))
        return np.asarray(st.theta), fs

    t_s, f_s = run(b)
    t_d, f_d = run(dense)
    np.testing.assert_allclose(f_s, f_d, rtol=2e-4)
    np.testing.assert_allclose(t_s, t_d, rtol=2e-3, atol=2e-5)
    np.testing.assert_array_equal(t_s == 0.0, t_d == 0.0)
