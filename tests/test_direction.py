"""Tests for Eq. 8-10 and Proposition 2 (the Eq. 9 direction)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.direction import (
    choose_orthant,
    descent_direction,
    directional_derivative,
    project_orthant,
)


def _numeric_dirderiv(f, theta, d, eps=1e-7):
    # float64 one-sided difference (directional derivative is one-sided
    # by definition, Eq. 7 — central differencing would be wrong at kinks)
    return (f(theta + eps * d) - f(theta)) / eps


def _full_objective(theta, grad_lin, lam, beta):
    """A synthetic objective whose smooth part has constant gradient
    grad_lin: f = <grad_lin, Theta> + lam*L21 + beta*L1. Evaluated in
    float64 numpy so the finite difference has headroom."""
    grad_lin = np.asarray(grad_lin, dtype=np.float64)

    def f(t):
        t = np.asarray(t, dtype=np.float64)
        l21 = np.sum(np.sqrt(np.sum(t * t, axis=-1)))
        l1 = np.sum(np.abs(t))
        return np.vdot(grad_lin, t) + lam * l21 + beta * l1
    return f


def _rand_theta_with_zeros(key, d=12, m2=8):
    k1, k2 = jax.random.split(key)
    theta = jax.random.normal(k1, (d, m2))
    # plant exact elementwise zeros and whole zero rows (all 3 Eq.9 cases)
    mask = jax.random.bernoulli(k2, 0.5, theta.shape)
    theta = theta * mask
    theta = theta.at[0].set(0.0).at[5].set(0.0)
    return theta


@pytest.mark.parametrize("lam,beta", [(0.0, 0.0), (0.5, 0.0), (0.0, 0.7), (0.8, 0.6)])
def test_closed_form_dirderiv_matches_numeric(lam, beta):
    key = jax.random.PRNGKey(0)
    theta = _rand_theta_with_zeros(key)
    grad = jax.random.normal(jax.random.PRNGKey(1), theta.shape)
    d = jax.random.normal(jax.random.PRNGKey(2), theta.shape)
    f = _full_objective(theta, grad, lam, beta)
    closed = float(directional_derivative(theta, grad, d, lam, beta))
    numeric = float(_numeric_dirderiv(f, np.asarray(theta, np.float64), np.asarray(d, np.float64)))
    np.testing.assert_allclose(closed, numeric, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("lam,beta", [(0.3, 0.2), (1.0, 1.0), (0.0, 1.0), (1.0, 0.0)])
def test_direction_is_descent(lam, beta):
    """f'(Theta; d) < 0 unless d == 0 (Prop. 2: d minimises the
    directional derivative, and 0 is feasible)."""
    for seed in range(5):
        theta = _rand_theta_with_zeros(jax.random.PRNGKey(seed))
        grad = jax.random.normal(jax.random.PRNGKey(100 + seed), theta.shape)
        d = descent_direction(theta, grad, lam, beta)
        dd = float(directional_derivative(theta, grad, d, lam, beta))
        dnorm = float(jnp.linalg.norm(d))
        if dnorm > 1e-8:
            assert dd < 0.0, f"not a descent direction: f'={dd}, |d|={dnorm}"


def test_reduces_to_owlqn_pseudogradient_when_lam_zero():
    """With lam=0, Eq. 9 must equal OWLQN's negative pseudo-gradient
    (Andrew & Gao 2007), the paper's own claim after Prop. 2."""
    beta = 0.4
    theta = _rand_theta_with_zeros(jax.random.PRNGKey(3))
    grad = jax.random.normal(jax.random.PRNGKey(4), theta.shape)
    d = descent_direction(theta, grad, lam=0.0, beta=beta)

    # reference OWLQN pseudo-gradient (elementwise; sign convention: we
    # return the NEGATIVE pseudo-gradient as the descent direction)
    g = np.asarray(grad)
    t = np.asarray(theta)
    pg = np.zeros_like(g)
    nz = t != 0
    pg[nz] = g[nz] + beta * np.sign(t[nz])
    z = ~nz
    right = g + beta  # right partial derivative at 0
    left = g - beta
    pg[z & (left > 0)] = left[z & (left > 0)]
    pg[z & (right < 0)] = right[z & (right < 0)]
    np.testing.assert_allclose(np.asarray(d), -pg, rtol=1e-5, atol=1e-6)


def test_direction_zero_at_optimum_of_pure_reg():
    """If grad=0 and Theta=0, the direction must be 0 (0 is optimal)."""
    theta = jnp.zeros((6, 4))
    grad = jnp.zeros((6, 4))
    d = descent_direction(theta, grad, lam=0.5, beta=0.5)
    assert float(jnp.abs(d).max()) == 0.0


def test_direction_soft_thresholds_small_gradients():
    """At Theta=0, |grad| <= beta entries must yield d=0 (subgradient
    optimality), and rows with ||softthresh(g,beta)|| <= lam must be 0."""
    grad = jnp.array([[0.3, -0.2], [2.0, 0.0]])
    theta = jnp.zeros_like(grad)
    d = descent_direction(theta, grad, lam=0.0, beta=0.5)
    assert float(jnp.abs(d[0]).max()) == 0.0
    assert float(d[1, 0]) == -(2.0 - 0.5) * 1.0  # sign(-g)*(|g|-beta): g=2 -> -1.5
    # group shrink: row norm 1.5 <= lam=2 -> whole row zero
    d2 = descent_direction(theta, grad, lam=2.0, beta=0.5)
    assert float(jnp.abs(d2).max()) == 0.0


def test_project_orthant():
    theta = jnp.array([1.0, -2.0, 3.0, -4.0, 0.0])
    omega = jnp.array([1.0, 1.0, -1.0, -1.0, 1.0])
    out = project_orthant(theta, omega)
    np.testing.assert_array_equal(np.asarray(out), [1.0, 0.0, 0.0, -4.0, 0.0])


def test_project_idempotent_and_orthant_consistency():
    key = jax.random.PRNGKey(7)
    theta = jax.random.normal(key, (20,))
    d = jax.random.normal(jax.random.PRNGKey(8), (20,))
    xi = choose_orthant(theta, d)
    p1 = project_orthant(theta, xi)
    p2 = project_orthant(p1, xi)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    # theta entries never flip sign under projection onto own orthant
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(theta))
