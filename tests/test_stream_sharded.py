"""Sharded streaming smoke: the streaming trainer on a (data x model)
mesh must reproduce (a) the sharded full-batch OWLQN+ trajectory
bit-for-bit when the window is the full dataset, and (b) the
SINGLE-DEVICE streaming trajectory to fp32 tolerance across several
drifting windows — with checkpoints resuming exactly.

Runs in a subprocess so XLA_FLAGS can force 8 host devices without
polluting the main test process (same pattern as test_shard_step.py);
REPRO_DEVICES overrides the device count (the CI stream job sets 8).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
DEV = int(os.environ.get("REPRO_DEVICES", "8"))
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={DEV}"
MESH_DATA, MESH_MODEL = 2, 4
import numpy as np
import jax, jax.numpy as jnp
assert jax.device_count() >= MESH_DATA * MESH_MODEL, jax.device_count()

from repro.data.sparse import build_batch_plans
from repro.dist import make_distributed_step, shard_sparse_batch, shard_state
from repro.launch.mesh import make_debug_mesh
from repro.optim import OWLQNPlus
from repro.shard import make_partition, make_sharded_sparse_loss
from repro.stream import DayStream, StreamTrainer

D, d, m = 3, 600, 2
stream = DayStream(D, sessions_per_day=16, num_features=d, active_user=6,
                   active_ad=4, seed=4)
theta0 = jnp.asarray(
    0.01 * np.random.default_rng(0).normal(size=(d, 2 * m)), jnp.float32)
mesh = make_debug_mesh(data=MESH_DATA, model=MESH_MODEL)
part = make_partition(d, MESH_MODEL)

# ---- (a) full-window parity vs the sharded full-batch path, bit-for-bit
full = stream.window(D - 1, D)
sb = shard_sparse_batch(
    mesh, build_batch_plans(full, shards=part, data_shards=MESH_DATA))
opt = OWLQNPlus(make_sharded_sparse_loss(sb, mesh), lam=0.1, beta=0.1)
st = shard_state(opt.init(part.pad_rows(theta0)), mesh)
step = make_distributed_step(opt, mesh)
fs_ref = []
for _ in range(3):
    st, stats = step(st)
    fs_ref.append(float(stats.f_new))

tr = StreamTrainer(stream, lam=0.1, beta=0.1, window=D, inner_iters=3,
                   mesh=mesh)
state = tr.init(theta0)._replace(day=D - 1)
state, trace = tr.run(state, days=1)
assert list(trace[0].fs) == fs_ref, (trace[0].fs, fs_ref)
np.testing.assert_array_equal(
    np.asarray(part.unpad_rows(jnp.asarray(jax.device_get(st.theta)))),
    np.asarray(tr.theta(state)))
# theta really stayed row-sharded over 'model'
shapes = {s.data.shape for s in state.opt.theta.addressable_shards}
assert shapes == {(part.rows_per_shard, 2 * m)}, shapes

# ---- (b) multi-window drift run: sharded == single-device (fp32 tol),
#      both carry policies; checkpoint resumes exactly
for history in ("reset", "carry"):
    tr1 = StreamTrainer(stream, lam=0.1, beta=0.1, window=2, inner_iters=2,
                        history=history)
    s1, t1 = tr1.run(tr1.init(theta0))
    trm = StreamTrainer(stream, lam=0.1, beta=0.1, window=2, inner_iters=2,
                        history=history, mesh=mesh)
    sm, tm = trm.run(trm.init(theta0))
    np.testing.assert_allclose([f for w in t1 for f in w.fs],
                               [f for w in tm for f in w.fs], rtol=2e-4)
    th1, thm = np.asarray(tr1.theta(s1)), np.asarray(trm.theta(sm))
    np.testing.assert_allclose(th1, thm, rtol=2e-3, atol=2e-5)
    np.testing.assert_array_equal(th1 == 0.0, thm == 0.0)

import tempfile
trm = StreamTrainer(stream, lam=0.1, beta=0.1, window=2, inner_iters=2,
                    mesh=mesh)
mid, _ = trm.run(trm.init(theta0), days=2)
with tempfile.TemporaryDirectory() as td:
    path = td + "/stream.npz"
    trm.save(path, mid)
    back = trm.load(path, theta0)
assert back.day == 2 and type(back.day) is int
fin_a, ta = trm.run(mid, days=1)
fin_b, tb = trm.run(back, days=1)
assert [w.fs for w in ta] == [w.fs for w in tb]
np.testing.assert_array_equal(np.asarray(trm.theta(fin_a)),
                              np.asarray(trm.theta(fin_b)))
print("STREAM-SHARD-OK")
"""


@pytest.mark.slow
def test_sharded_streaming_matches_single_device():
    env = os.environ.copy()
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)), timeout=600,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "STREAM-SHARD-OK" in r.stdout
