"""Unit tests for the collective-byte HLO parser (roofline input)."""
from repro.utils.hlo import _shape_bytes, collective_bytes


def test_shape_bytes():
    assert _shape_bytes("f32[16,4096]") == 16 * 4096 * 4
    assert _shape_bytes("bf16[2,3,4]{2,1,0}") == 24 * 2
    assert _shape_bytes("(f32[8], s32[4])") == 32 + 16
    assert _shape_bytes("pred[]") == 1  # scalar
    assert _shape_bytes("token[]") == 0  # non-numeric types ignored


def test_collective_bytes_counts_ops():
    hlo = """
  %ag = f32[256,512]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = bf16[1024]{0} all-reduce(%y), to_apply=%add
  %rs = (f32[64], f32[64]) reduce-scatter(%a, %b), dimensions={0}
  %a2a = f32[32,32]{1,0} all-to-all(%z), dimensions={0}
  %cp = f32[8]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %ags = f32[128]{0} all-gather-start(%v)
  %agd = f32[128]{0} all-gather-done(%ags)
  %not_a_collective = f32[999]{0} add(%p, %q)
"""
    stats = collective_bytes(hlo)
    assert stats["all-gather"]["count"] == 2  # ag + ag-start (done skipped)
    assert stats["all-gather"]["bytes"] == 256 * 512 * 4 + 128 * 4
    assert stats["all-reduce"]["bytes"] == 1024 * 2
    assert stats["reduce-scatter"]["bytes"] == 2 * 64 * 4
    assert stats["all-to-all"]["bytes"] == 32 * 32 * 4
    assert stats["collective-permute"]["bytes"] == 8 * 4
    assert stats["total_bytes"] == sum(
        v["bytes"] for k, v in stats.items() if k != "total_bytes")


def test_no_collectives():
    assert collective_bytes("%x = f32[4] add(%a, %b)")["total_bytes"] == 0
