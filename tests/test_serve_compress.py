"""Pruned serving artifacts: compress -> save -> load -> score round
trips. The acceptance bar: pruned-artifact scoring is BIT-IDENTICAL to
full-Theta scoring on the sparse paths (flat COO, session-shared,
interpret-mode kernel), and <= 1e-6 on the dense path (shorter
reassociated contraction — the documented carve-out). Covers an
all-rows-alive model and a heavily-pruned OWLQN+-trained model whose
sparsity pattern comes from real L1/L2,1 training on Zipf id traffic.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lsplm import params_from_theta, predict_proba
from repro.core.objective import smooth_loss_and_grad
from repro.data.sparse import generate_sparse, to_dense
from repro.serve import (
    QuantizedArtifact,
    ScoreBundle,
    as_model,
    compress,
    dequantize,
    load_artifact,
    quantize,
    save_artifact,
    score_bundles,
    score_dense,
    score_sparse,
)


def _sparsified_theta(d, m, nnz=0.1, seed=0):
    rng = np.random.default_rng(seed)
    th = rng.normal(size=(d, 2 * m)).astype(np.float32) * 0.2
    th[rng.random(d) >= nnz] = 0.0
    return jnp.asarray(th)


def _requests(d, n=64, k=9, seed=1, pad_frac=0.25):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, d, (n, k))
    vals = rng.normal(size=(n, k)).astype(np.float32)
    n_pad = int(round(pad_frac * k))
    if n_pad:
        ids[:, k - n_pad:] = d
        vals[:, k - n_pad:] = 0.0
    return jnp.asarray(ids, jnp.int32), jnp.asarray(vals)


# ------------------------------------------------------------ compress
def test_compress_structure():
    theta = _sparsified_theta(500, 3)
    art = compress(theta)
    alive = np.flatnonzero(np.abs(np.asarray(theta)).max(axis=1) > 0)
    assert art.num_features == 500
    assert art.num_regions == 3
    assert art.num_alive == alive.size
    np.testing.assert_array_equal(np.asarray(art.alive_ids), alive)
    # packed rows are the alive rows verbatim + one zero pad row
    np.testing.assert_array_equal(np.asarray(art.theta[:-1]),
                                  np.asarray(theta)[alive])
    assert not np.asarray(art.theta[-1]).any()
    # remap: alive ids -> their packed position, dropped + pad id -> pad row
    remap = np.asarray(art.remap)
    np.testing.assert_array_equal(remap[alive], np.arange(alive.size))
    dropped = np.setdiff1d(np.arange(501), alive)
    assert (remap[dropped] == art.pad_id).all()


def test_compress_rejects_padded_or_odd_theta():
    with pytest.raises(ValueError):
        compress(jnp.zeros((10, 5)))  # odd last dim
    with pytest.raises(ValueError):
        compress(jnp.zeros((10,)))


def test_compress_all_rows_dead():
    art = compress(jnp.zeros((50, 4)))
    assert art.num_alive == 0
    ids, vals = _requests(50, n=8, k=4)
    p = np.asarray(score_sparse(art, ids, vals))
    np.testing.assert_allclose(p, 0.5)  # z == 0 -> sigmoid mix is exactly 1/2


def test_compress_threshold_drops_small_rows():
    theta = np.zeros((10, 4), np.float32)
    theta[2] = 1e-4
    theta[7] = 1.0
    art = compress(jnp.asarray(theta), threshold=1e-3)
    np.testing.assert_array_equal(np.asarray(art.alive_ids), [7])


# ------------------------------------------------- round trip + parity
def _assert_all_paths_bitwise(theta, art, *, d, seed=3):
    """Flat sparse, interpret-mode kernel and session-shared scoring all
    bit-identical between the full Theta and the artifact."""
    full = as_model(theta)
    ids, vals = _requests(d, n=48, k=7, seed=seed)
    np.testing.assert_array_equal(
        np.asarray(score_sparse(full, ids, vals)),
        np.asarray(score_sparse(art, ids, vals)))
    np.testing.assert_array_equal(
        np.asarray(score_sparse(full, ids, vals, mode="interpret")),
        np.asarray(score_sparse(art, ids, vals, mode="interpret")))
    batch = generate_sparse(num_features=d,
                            num_user_features_range=(max(1, d // 2), d),
                            sessions=12, seed=seed + 1, with_plans=False)
    bundle = ScoreBundle(batch.user_ids, batch.user_vals,
                         batch.ad_ids, batch.ad_vals, batch.session_id)
    np.testing.assert_array_equal(
        np.asarray(score_bundles(full, bundle)),
        np.asarray(score_bundles(art, bundle)))
    # dense: <= 1e-6, NOT bitwise (contraction over R alive columns)
    x = jnp.asarray(to_dense(batch))
    np.testing.assert_allclose(
        np.asarray(score_dense(full, x)), np.asarray(score_dense(art, x)),
        rtol=1e-6, atol=1e-6)


def test_roundtrip_pruned_model(tmp_path):
    d = 800
    theta = _sparsified_theta(d, 4, nnz=0.07)
    art = compress(theta)
    assert 0 < art.num_alive < d // 4  # actually pruned
    path = str(tmp_path / "art.npz")
    save_artifact(path, art)
    loaded = load_artifact(path)
    for a, b in zip(art[:-1], loaded[:-1]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert loaded.num_features == art.num_features
    _assert_all_paths_bitwise(theta, loaded, d=d)


def test_roundtrip_all_rows_alive(tmp_path):
    d = 300
    rng = np.random.default_rng(5)
    theta = jnp.asarray(rng.normal(size=(d, 4)).astype(np.float32) + 3.0)
    art = compress(theta)  # nothing to drop
    assert art.num_alive == d
    path = str(tmp_path / "art_full.npz")
    save_artifact(path, art)
    _assert_all_paths_bitwise(theta, load_artifact(path), d=d)


@pytest.mark.slow
def test_roundtrip_owlqn_trained_zipf_model(tmp_path):
    """The real thing: OWLQN+ with strong L1/L2,1 on Zipf id traffic
    leaves most rows exactly zero; the pruned artifact must reproduce
    the trained model's scores bit-for-bit."""
    from repro.optim import OWLQNPlus

    d, m = 2000, 3
    train = generate_sparse(num_features=d,
                            num_user_features_range=(d // 2, d),
                            sessions=96, seed=7)
    theta0 = jnp.asarray(
        0.01 * np.random.default_rng(0).normal(size=(d, 2 * m)), jnp.float32)
    opt = OWLQNPlus(lambda t: smooth_loss_and_grad(t, train),
                    lam=0.5, beta=0.5)
    theta, _ = opt.run(theta0, max_iters=12)
    art = compress(theta)
    assert art.num_alive < d // 2, "training should have pruned heavily"
    assert art.num_alive > 0
    path = str(tmp_path / "trained.npz")
    save_artifact(path, art)
    _assert_all_paths_bitwise(theta, load_artifact(path), d=d, seed=9)


def test_dropped_id_requests_hit_pad_row():
    """A request touching ONLY dropped ids scores exactly like the full
    model (whose rows there are exact zeros)."""
    d = 400
    theta = _sparsified_theta(d, 2, nnz=0.05, seed=11)
    art = compress(theta)
    dropped = np.setdiff1d(np.arange(d), np.asarray(art.alive_ids))
    rng = np.random.default_rng(12)
    ids = jnp.asarray(rng.choice(dropped, (16, 6)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(16, 6)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(score_sparse(theta, ids, vals)),
        np.asarray(score_sparse(art, ids, vals)))


def test_dense_matches_core_predictor():
    """score_dense(full Theta) is the same math as the core predictor."""
    d = 150
    theta = _sparsified_theta(d, 4, nnz=0.5, seed=13)
    rng = np.random.default_rng(14)
    x = jnp.asarray(rng.normal(size=(32, d)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(score_dense(theta, x)),
        np.asarray(predict_proba(params_from_theta(theta), x)),
        rtol=1e-6, atol=1e-7)


def test_save_artifact_returns_real_path(tmp_path):
    """np.savez appends .npz to suffix-less paths; save_artifact returns
    the path actually written so callers can print/reload it."""
    art = compress(_sparsified_theta(60, 2))
    bare = str(tmp_path / "art")  # no suffix
    real = save_artifact(bare, art)
    assert real == bare + ".npz"
    loaded = load_artifact(real)
    np.testing.assert_array_equal(np.asarray(loaded.theta),
                                  np.asarray(art.theta))
    assert save_artifact(real, art) == real  # suffixed path is unchanged


def test_load_artifact_rejects_foreign_checkpoint(tmp_path):
    from repro.io import checkpoint

    path = str(tmp_path / "not_art.npz")
    checkpoint.save(path, {"theta": np.zeros((4, 4), np.float32)})
    with pytest.raises(ValueError, match="missing fields"):
        load_artifact(path)


# ------------------------------------------------------- int8 quantise
def test_quantize_structure_and_error_bound():
    """codes are int8, scales per row, and every reconstructed entry is
    within half an int8 step (max|row|/254) of the fp32 row."""
    theta = _sparsified_theta(600, 3, nnz=0.2, seed=21)
    art = compress(theta)
    q = quantize(art)
    assert np.asarray(q.codes).dtype == np.int8
    assert q.codes.shape == art.theta.shape
    assert q.scales.shape == (art.theta.shape[0],)
    np.testing.assert_array_equal(np.asarray(q.remap), np.asarray(art.remap))
    th = np.asarray(art.theta)
    rec = np.asarray(dequantize(q).theta)
    bound = np.abs(th).max(axis=1, keepdims=True) / 254.0
    assert (np.abs(rec - th) <= bound + 1e-12).all()
    # the pad row is all-zero and must stay EXACTLY zero
    assert not np.asarray(q.codes)[-1].any()
    assert np.asarray(q.scales)[-1] == 0.0
    assert not rec[-1].any()


def test_quantized_roundtrip_and_bounded_scores(tmp_path):
    """save -> load keeps codes/scales bit-exact (and the int8 dtype, so
    the npz really is ~4x smaller rows); serving the loaded artifact
    moves every probability by <= 1e-2 vs fp32 on flat, bundle and
    dense paths."""
    d = 900
    theta = _sparsified_theta(d, 4, nnz=0.08, seed=22)
    art = compress(theta)
    q = quantize(art)
    path = save_artifact(str(tmp_path / "art_int8"), q)
    loaded = load_artifact(path)
    assert isinstance(loaded, QuantizedArtifact)
    assert np.asarray(loaded.codes).dtype == np.int8
    np.testing.assert_array_equal(np.asarray(loaded.codes),
                                  np.asarray(q.codes))
    np.testing.assert_array_equal(np.asarray(loaded.scales),
                                  np.asarray(q.scales))
    assert loaded.num_features == d

    ids, vals = _requests(d, n=48, k=7, seed=23)
    p_fp = np.asarray(score_sparse(art, ids, vals))
    p_q = np.asarray(score_sparse(loaded, ids, vals))
    assert np.abs(p_q - p_fp).max() <= 1e-2
    batch = generate_sparse(num_features=d,
                            num_user_features_range=(d // 2, d),
                            sessions=12, seed=24, with_plans=False)
    bundle = ScoreBundle(batch.user_ids, batch.user_vals,
                         batch.ad_ids, batch.ad_vals, batch.session_id)
    assert np.abs(np.asarray(score_bundles(loaded, bundle))
                  - np.asarray(score_bundles(art, bundle))).max() <= 1e-2
    x = jnp.asarray(to_dense(batch))
    assert np.abs(np.asarray(score_dense(loaded, x))
                  - np.asarray(score_dense(art, x))).max() <= 1e-2


def test_quantize_dropped_ids_still_hit_pad_row():
    """Dropped-id requests score exactly 0.5-symmetric like fp32: the
    remap is untouched and the pad row survives quantisation as exact
    zeros, so dropped ids contribute nothing."""
    d = 400
    theta = _sparsified_theta(d, 2, nnz=0.05, seed=25)
    art = compress(theta)
    q = quantize(art)
    dropped = np.setdiff1d(np.arange(d), np.asarray(art.alive_ids))
    rng = np.random.default_rng(26)
    ids = jnp.asarray(rng.choice(dropped, (16, 6)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(16, 6)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(score_sparse(art, ids, vals)),
        np.asarray(score_sparse(q, ids, vals)))


def test_int8_native_matches_dequantized_scoring():
    """The int8-NATIVE path (codes/scales straight into the scale-fused
    gather) reproduces dequantize-then-score to <= 1e-6 on every sparse
    path and mode — the same fp32 row values enter the contraction, the
    only daylight is reassociation inside the kernel."""
    d = 700
    theta = _sparsified_theta(d, 4, nnz=0.15, seed=31)
    q = quantize(compress(theta))
    deq = dequantize(q)  # fp32 rows, scored on the fp32 kernels
    native = as_model(q)
    assert native.is_int8 and native.theta is None
    ids, vals = _requests(d, n=48, k=7, seed=32)
    for mode in ("auto", "interpret"):
        np.testing.assert_allclose(
            np.asarray(score_sparse(deq, ids, vals, mode=mode)),
            np.asarray(score_sparse(native, ids, vals, mode=mode)),
            rtol=1e-6, atol=1e-6)
    batch = generate_sparse(num_features=d,
                            num_user_features_range=(d // 2, d),
                            sessions=10, seed=33, with_plans=False)
    bundle = ScoreBundle(batch.user_ids, batch.user_vals,
                         batch.ad_ids, batch.ad_vals, batch.session_id)
    np.testing.assert_allclose(
        np.asarray(score_bundles(deq, bundle)),
        np.asarray(score_bundles(native, bundle)),
        rtol=1e-6, atol=1e-6)
    # dense carve-out: on-the-fly dequantise is the same rows too
    x = jnp.asarray(to_dense(batch))
    np.testing.assert_allclose(
        np.asarray(score_dense(deq, x)), np.asarray(score_dense(native, x)),
        rtol=1e-6, atol=1e-6)


def test_quantize_m1_single_region_pair():
    """Smallest model shape: m=1 (one softmax/sigmoid column pair per
    row). Quantise/dequantise keeps the error bound and int8-native
    scoring still matches."""
    d = 120
    theta = _sparsified_theta(d, 1, nnz=0.4, seed=34)
    art = compress(theta)
    q = quantize(art)
    assert q.codes.shape == (art.theta.shape[0], 2)
    th = np.asarray(art.theta)
    rec = np.asarray(dequantize(q).theta)
    bound = np.abs(th).max(axis=1, keepdims=True) / 254.0
    assert (np.abs(rec - th) <= bound + 1e-12).all()
    ids, vals = _requests(d, n=16, k=5, seed=35)
    np.testing.assert_allclose(
        np.asarray(score_sparse(dequantize(q), ids, vals)),
        np.asarray(score_sparse(q, ids, vals)), rtol=1e-6, atol=1e-6)


def test_quantize_subnormal_and_huge_rows():
    """Extreme row magnitudes: a subnormal-max row must not divide by a
    zero-flushed scale (codes stay finite, the row reconstructs to ~0),
    and a huge-magnitude row must keep codes in [-127, 127] with the
    max-|entry| column hitting +-127 exactly."""
    m = 2
    theta = np.zeros((6, 2 * m), np.float32)
    theta[0] = 1e-38  # subnormal-ish max: scale underflows toward 0
    theta[1, 0] = 3e38  # near-fp32-max magnitude
    theta[1, 1] = -3e38
    theta[2] = 1.0
    q = quantize(compress(jnp.asarray(theta), threshold=0.0))
    codes = np.asarray(q.codes)
    scales = np.asarray(q.scales)
    assert np.isfinite(scales).all()
    assert (np.abs(codes) <= 127).all()
    # the extreme row's max-magnitude entries quantise to exactly +-127
    alive = np.asarray(q.alive_ids)
    huge = int(np.flatnonzero(alive == 1)[0])
    assert codes[huge].max() == 127 and codes[huge].min() == -127
    rec = np.asarray(dequantize(q).theta)
    assert np.isfinite(rec).all()
    # reconstruction error bound holds even at the extremes
    th = np.asarray(compress(jnp.asarray(theta), threshold=0.0).theta)
    bound = np.abs(th).max(axis=1, keepdims=True) / 254.0 + 1e-12
    assert (np.abs(rec - th) <= bound).all()


def test_quantized_artifact_embedded_drift_ref_roundtrip(tmp_path):
    """One deploy file carries the int8 artifact AND the training-time
    drift reference: load_artifact auto-detects the quantised form
    untouched, load_drift_reference reads the same file."""
    from repro import obs

    d = 300
    theta = _sparsified_theta(d, 2, nnz=0.2, seed=36)
    q = quantize(compress(theta))
    rng = np.random.default_rng(37)
    scores = rng.random(256)
    labels = (rng.random(256) < scores).astype(np.float32)
    ids = rng.integers(0, d, 2048)
    ref = obs.capture_reference(scores, labels, ids, num_features=d)
    path = save_artifact(str(tmp_path / "deploy_int8"), q, drift_ref=ref)
    loaded = load_artifact(path)
    assert isinstance(loaded, QuantizedArtifact)
    np.testing.assert_array_equal(np.asarray(loaded.codes),
                                  np.asarray(q.codes))
    np.testing.assert_array_equal(np.asarray(loaded.scales),
                                  np.asarray(q.scales))
    back = obs.load_drift_reference(path)
    np.testing.assert_array_equal(back.score_edges, ref.score_edges)
    np.testing.assert_array_equal(back.score_counts, ref.score_counts)
    assert back.num_features == d
    # and the embedded reference didn't leak into the served scores
    ids_r, vals_r = _requests(d, n=12, k=5, seed=38)
    np.testing.assert_array_equal(np.asarray(score_sparse(q, ids_r, vals_r)),
                                  np.asarray(score_sparse(loaded, ids_r,
                                                          vals_r)))


def test_quantize_size_accounting():
    """deployed_bytes counts int8 codes + fp32 scales/remap/alive_ids;
    the ROWS payload shrinks ~4x at production region counts."""
    theta = _sparsified_theta(500, 12, nnz=0.3, seed=27)  # m=12 as deployed
    art = compress(theta)
    q = quantize(art)
    rows_fp32 = art.theta.size * 4
    rows_int8 = q.codes.size + q.scales.size * 4
    assert rows_fp32 / rows_int8 > 3.4  # 24 cols: 96B -> 28B per row
    assert q.deployed_bytes == (q.codes.size + q.scales.size * 4
                                + q.remap.size * 4 + q.alive_ids.size * 4)
