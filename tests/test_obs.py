"""The unified observability layer (``repro.obs``): metrics registry
thread-safety and export, span nesting + Chrome-trace round-trip,
ledger schema round-trip and validation errors, instrumented-vs-clean
trajectory parity, and the engine/queue dispatch records."""
import json
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs.ledger import render_train_iter, validate_event
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer


# ----------------------------------------------------------- registry
def test_registry_get_or_create_identity_and_kind_mismatch():
    reg = MetricsRegistry()
    c = reg.counter("builds", planner="0")
    assert reg.counter("builds", planner="0") is c
    assert reg.counter("builds", planner="1") is not c  # distinct series
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("builds", planner="0")


def test_counter_thread_safety_exact_total():
    reg = MetricsRegistry()
    c = reg.counter("hits")
    h = reg.histogram("walls")
    n_threads, per_thread = 4, 5000

    def work():
        for _ in range(per_thread):
            c.inc(1.0)
            h.observe(1e-3)

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == float(n_threads * per_thread)
    assert h.count == n_threads * per_thread
    assert h.sum == pytest.approx(n_threads * per_thread * 1e-3)


def test_histogram_quantiles_interpolate_and_clamp():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    for v in np.linspace(1e-4, 1e-2, 100):
        h.observe(float(v))
    assert 5e-4 <= h.quantile(0.5) <= 5e-3
    assert h.quantile(0.0) == pytest.approx(1e-4)
    assert h.quantile(1.0) == pytest.approx(1e-2)
    single = reg.histogram("one")
    single.observe(0.42)
    # clamped to the observed range, never extrapolated into the bucket
    assert single.quantile(0.99) == pytest.approx(0.42)
    assert reg.histogram("empty").quantile(0.5) == 0.0
    # empty histogram: every q (including the edges) reads 0.0
    assert reg.histogram("empty").quantile(0.0) == 0.0
    assert reg.histogram("empty").quantile(1.0) == 0.0
    # single observation: every q collapses to that value
    assert single.quantile(0.0) == pytest.approx(0.42)
    assert single.quantile(0.5) == pytest.approx(0.42)
    assert single.quantile(1.0) == pytest.approx(0.42)


def test_histogram_rejects_out_of_range_q_and_nan():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    h.observe(1e-3)
    with pytest.raises(ValueError, match=r"pass 0.99, not 99"):
        h.quantile(99)
    with pytest.raises(ValueError, match="must be in"):
        h.quantile(-0.1)
    # NaN would silently poison min/max and every later quantile
    with pytest.raises(ValueError, match="NaN observation"):
        h.observe(float("nan"))
    assert h.count == 1  # the rejected observation left no trace
    assert h.quantile(1.0) == pytest.approx(1e-3)


def test_registry_write_jsonl_and_json(tmp_path):
    reg = MetricsRegistry()
    reg.counter("a", k="x").inc(2.0)
    reg.gauge("b").set(7.0)
    reg.histogram("c").observe(1e-3)

    p = reg.write(str(tmp_path / "m.jsonl"))
    lines = [json.loads(ln) for ln in open(p) if ln.strip()]
    assert {ln["series"] for ln in lines} == {"a{k=x}", "b", "c"}
    by = {ln["series"]: ln for ln in lines}
    assert by["a{k=x}"] == {"series": "a{k=x}", "type": "counter",
                            "value": 2.0}
    assert by["c"]["count"] == 1

    p2 = reg.write(str(tmp_path / "m.json"))
    doc = json.load(open(p2))
    assert doc["b"] == {"type": "gauge", "value": 7.0}


# ------------------------------------------------------- atomic writes
def test_atomic_write_interruption_preserves_previous_file(tmp_path):
    from repro.obs.fileio import atomic_write

    target = tmp_path / "snap.json"
    with atomic_write(str(target)) as f:
        f.write("good")
    assert target.read_text() == "good"

    # a crash mid-write must leave the previous bytes, not a prefix
    with pytest.raises(RuntimeError, match="simulated crash"):
        with atomic_write(str(target)) as f:
            f.write("partial garbage that must never be seen")
            raise RuntimeError("simulated crash")
    assert target.read_text() == "good"
    # and no temp litter survives the failure
    assert [p.name for p in tmp_path.iterdir()] == ["snap.json"]

    with pytest.raises(ValueError, match="write mode"):
        with atomic_write(str(target), mode="r"):
            pass


def test_registry_and_tracer_writes_are_atomic(tmp_path, monkeypatch):
    import repro.obs.fileio as fileio

    reg = MetricsRegistry()
    reg.counter("a").inc()
    mpath = str(tmp_path / "m.json")
    reg.write(mpath)
    tr = Tracer(enabled=True)
    with tr.span("w"):
        pass
    tpath = str(tmp_path / "t.json")
    tr.write(tpath)
    before_m, before_t = open(mpath).read(), open(tpath).read()

    def boom(src, dst):
        raise RuntimeError("simulated replace crash")

    monkeypatch.setattr(fileio.os, "replace", boom)
    reg.counter("a").inc()
    with pytest.raises(RuntimeError):
        reg.write(mpath)
    with tr.span("w2"):
        pass
    with pytest.raises(RuntimeError):
        tr.write(tpath)
    # both snapshots still read as complete documents from BEFORE
    assert open(mpath).read() == before_m
    assert open(tpath).read() == before_t
    json.load(open(mpath)), json.load(open(tpath))


# -------------------------------------------------------------- tracing
def test_span_nesting_and_chrome_trace_round_trip(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("outer", day=3):
        with tr.step_span("train/iter", 7):
            pass
    path = tr.write(str(tmp_path / "trace.json"))
    doc = json.load(open(path))
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = {e["name"]: e for e in doc["traceEvents"] if e["ph"] == "X"}
    assert set(evs) == {"outer", "train/iter"}
    outer, inner = evs["outer"], evs["train/iter"]
    # proper containment in the exported timeline (spans record on exit)
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-9
    assert outer["args"] == {"day": 3}
    assert inner["args"] == {"step": 7}
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert len(metas) == 1 and metas[0]["name"] == "thread_name"


def test_tracer_separates_threads():
    tr = Tracer(enabled=True)
    with tr.span("main-side"):
        pass

    def worker():
        with tr.span("worker-side"):
            pass

    t = threading.Thread(target=worker, name="bg")
    t.start()
    t.join()
    evs = tr.events()
    tids = {e["tid"] for e in evs if e["ph"] == "X"}
    assert len(tids) == 2
    names = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert "bg" in names


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("never", x=1):
        with tr.step_span("inner", 0):
            pass
    assert tr.events() == []
    assert tr.span("a") is tr.step_span("b", 1)  # one shared null span


# --------------------------------------------------------------- ledger
def test_ledger_round_trip_and_offline_validation(tmp_path):
    path = str(tmp_path / "run.jsonl")
    with obs.RunLedger(path) as led:
        led.emit("run_meta", driver="test", mode="unit")
        led.emit("train_iter", step=0, f=2.0, f_new=1.5, alpha=0.5,
                 grad_norm=0.1, nnz=12, ls_iters=1)
        led.emit("stream_window", day=0, days_in_window=1, plan_s=0.01,
                 compile_s=0.1, build_s=0.02, wait_s=0.0, prefetched=False,
                 step_s=0.2, carry="reset", alpha=0.5, nnz=12, fs=[2.0, 1.5])
    recs = obs.read_jsonl(path)
    assert [r["kind"] for r in recs] == ["run_meta", "train_iter",
                                         "stream_window"]
    assert obs.validate_file(path) == []
    assert all("t" in r for r in recs)
    # in-memory mirror kept the same records (minus json round-trip)
    led2 = obs.RunLedger(None)
    led2.emit("log", text="hi")
    assert led2.events("log")[0]["text"] == "hi"


def test_ledger_schema_rejects_bad_records():
    assert "unknown kind" in validate_event({"kind": "nope"})
    assert "missing required" in validate_event(
        {"kind": "train_iter", "step": 0})
    good = {"kind": "train_iter", "step": 0, "f": 1.0, "f_new": 0.9,
            "alpha": 0.5, "grad_norm": 0.1, "nnz": 3}
    assert validate_event(good) is None
    assert validate_event({**good, "extra_field": "ok"}) is None  # extras ok
    # bool is not an int and an int is not a bool (bool subclasses int)
    assert "expected int" in validate_event({**good, "nnz": True})
    win = {"kind": "stream_window", "day": 0, "days_in_window": 1,
           "plan_s": 0.0, "compile_s": 0.0, "build_s": 0.0, "wait_s": 0.0,
           "prefetched": 1, "step_s": 0.0, "carry": "reset", "alpha": 0.1,
           "nnz": 1, "fs": []}
    assert "expected bool" in validate_event(win)
    led = obs.RunLedger(None)
    with pytest.raises(ValueError, match="invalid ledger record"):
        led.emit("train_iter", step="zero")


def test_alert_records_validate_like_any_other_kind():
    good = {"kind": "alert", "rule": "p99", "state": "firing",
            "signal": "serve.p99_wall_us", "value": 3e5, "threshold": 2.5e5}
    assert validate_event(good) is None
    assert validate_event({**good, "op": "<=", "breach_n": 3,
                           "clear_n": 3}) is None
    assert "missing required" in validate_event(
        {"kind": "alert", "rule": "p99"})
    assert "expected str" in validate_event({**good, "state": 1})
    led = obs.RunLedger(None)
    with pytest.raises(ValueError, match="invalid ledger record"):
        led.emit("alert", rule="r", state="firing", signal="s",
                 value="high", threshold=1.0)


def test_ledger_observers_see_records_and_can_emit_back():
    led = obs.RunLedger(None)
    seen: list[dict] = []

    def observer(event):
        seen.append(event["kind"])
        # re-entrant emit from inside an observer must not deadlock
        # (observers run outside the ledger lock)
        if event["kind"] == "log":
            led.emit("alert", rule="r", state="firing", signal="s",
                     value=1.0, threshold=0.5)

    led.add_observer(observer)
    led.add_observer(observer)  # deduped: one subscription
    led.emit("log", text="x")
    assert seen == ["log", "alert"]
    led.remove_observer(observer)
    led.emit("log", text="y")
    assert seen == ["log", "alert"]
    # the null ledger accepts (and ignores) observers
    obs.NULL_LEDGER.add_observer(observer)
    obs.NULL_LEDGER.remove_observer(observer)


def test_null_ledger_is_inert():
    assert obs.NULL_LEDGER.enabled is False
    assert obs.NULL_LEDGER.emit("anything_goes", junk=object()) is None
    assert obs.NULL_LEDGER.events() == []


def test_log_prints_exact_text_and_records():
    led = obs.RunLedger(None)
    out = []
    obs.log("hello world", ledger=led, printer=out.append)
    obs.log("iter line", kind="train_iter", ledger=led, printer=out.append,
            step=0, f=1.0, f_new=0.9, alpha=0.5, grad_norm=0.1, nnz=3)
    assert out == ["hello world", "iter line"]
    assert [e["kind"] for e in led.events()] == ["log", "train_iter"]
    assert led.events("train_iter")[0]["text"] == "iter line"
    # disabled ledger: still prints, records nothing
    out2 = []
    obs.log("quiet", ledger=obs.NULL_LEDGER, printer=out2.append)
    assert out2 == ["quiet"]


def test_render_train_iter_matches_driver_format():
    rec = {"step": 7, "f_new": 123.456, "alpha": 0.25, "nnz": 42}
    assert render_train_iter(rec) == \
        f"iter {7:3d}  f={123.456:12.2f} alpha={0.25:.3g} nnz={42:8d}"
    full = {**rec, "test_auc": 0.87654, "wall_s": 0.0123}
    assert render_train_iter(full, nnz_width=7) == (
        f"iter {7:3d}  f={123.456:12.2f} alpha={0.25:.3g} nnz={42:7d}"
        f" test_auc={0.87654:.4f}  ({12.3:.0f} ms/iter)")


def test_ledger_cli_check(tmp_path, capsys):
    from repro.obs.ledger import main

    good = tmp_path / "good.jsonl"
    with obs.RunLedger(str(good)) as led:
        led.emit("log", text="ok")
    assert main(["--check", str(good)]) == 0
    assert "ledger OK" in capsys.readouterr().out

    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"kind": "mystery"}\n')
    assert main(["--check", str(bad)]) == 1
    assert "FAIL" in capsys.readouterr().err
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert main([str(empty)]) == 1


# ------------------------------------------------- configure / session
def test_configure_writes_all_outputs_and_restores_defaults(tmp_path):
    prev_tracer, prev_ledger = obs.get_tracer(), obs.get_ledger()
    m, tr, led = (str(tmp_path / "m.jsonl"), str(tmp_path / "t.json"),
                  str(tmp_path / "l.jsonl"))
    session = obs.configure(metrics_out=m, trace_out=tr, ledger_out=led,
                            meta={"driver": "test", "mode": "unit"})
    try:
        assert obs.get_tracer().enabled and obs.get_ledger().enabled
        with obs.get_tracer().span("work"):
            pass
        obs.get_registry().counter("obs_test_configure").inc()
        obs.log("one line", printer=lambda s: None)
    finally:
        session.close()
    session.close()  # idempotent
    assert obs.get_tracer() is prev_tracer
    assert obs.get_ledger() is prev_ledger
    assert obs.validate_file(led) == []
    recs = obs.read_jsonl(led)
    assert recs[0]["kind"] == "run_meta" and recs[0]["driver"] == "test"
    assert [e["name"] for e in json.load(open(tr))["traceEvents"]
            if e["ph"] == "X"] == ["work"]
    assert any(json.loads(ln)["series"] == "obs_test_configure"
               for ln in open(m))


# -------------------------------------- trajectory parity (obs on/off)
def test_owlqn_trajectory_bitwise_identical_with_obs_on():
    from repro.optim import OWLQNPlus

    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(40, 20)) / np.sqrt(20), jnp.float32)
    b = A @ jnp.asarray(rng.normal(size=(20, 6)).astype(np.float32))

    def lg(theta):
        r = A @ theta - b
        return 0.5 * jnp.vdot(r, r), A.T @ r

    theta0 = jnp.zeros((20, 6), jnp.float32)
    opt = OWLQNPlus(lg, lam=0.2, beta=0.2)
    t_off, trace_off = opt.run(theta0, max_iters=12)
    led = obs.RunLedger(None)
    tracer = Tracer(enabled=True)
    t_on, trace_on = opt.run(theta0, max_iters=12, ledger=led, tracer=tracer)
    np.testing.assert_array_equal(np.asarray(t_off), np.asarray(t_on))
    fs_off = [float(s.f_new) for s in trace_off]
    fs_on = [float(s.f_new) for s in trace_on]
    assert fs_off == fs_on
    # and the ledger/trace captured exactly that trajectory
    recs = led.events("train_iter")
    assert [r["f_new"] for r in recs] == fs_on
    assert [r["nnz"] for r in recs] == [int(s.nnz) for s in trace_on]
    steps = [e["args"]["step"] for e in tracer.events()
             if e.get("name") == "train/iter"]
    assert steps == list(range(len(recs)))


# ------------------------------------------- serve dispatch records
def test_engine_and_queue_emit_serve_dispatch_records():
    from repro.serve import (MicroBatchQueue, QueueConfig, ScoringEngine,
                             synthetic_requests)

    rng = np.random.default_rng(0)
    theta = jnp.asarray(rng.normal(size=(300, 6)).astype(np.float32) * 0.3)
    reqs = synthetic_requests(6, num_features=300, seed=1,
                              k_user=(4, 4), k_ad=(2, 2), n_ads=(3, 3))
    led = obs.RunLedger(None)
    prev = obs.set_ledger(led)
    try:
        eng = ScoringEngine(theta)
        eng.score(reqs[0])
        direct = led.events("serve_dispatch")
        assert len(direct) == 1
        assert direct[0]["flush_reason"] == "direct"
        assert direct[0]["requests"] == 1
        assert direct[0]["queue_delay_us"] == 0.0
        assert direct[0]["envelope"][0] == direct[0]["g"]

        queue = MicroBatchQueue(eng, QueueConfig(max_batch=4,
                                                 max_delay_us=1000.0))
        for i, r in enumerate(reqs[:4]):
            queue.submit(r, now=i * 1e-5)  # 4th submit -> full flush
        queue.submit(reqs[4], now=1.0)
        queue.drain(now=2.0)
        recs = led.events("serve_dispatch")[1:]
        assert [r["flush_reason"] for r in recs] == ["full", "drain"]
        assert recs[0]["requests"] == 4
        assert recs[0]["queue_delay_us"] >= 0.0
        for r in recs:
            assert validate_event(r) is None
    finally:
        obs.set_ledger(prev)
