"""Distributed (PS-mapped) LS-PLM training must match single-device math.

Runs in a subprocess so XLA_FLAGS can request 8 host devices without
polluting the main test process (which must keep 1 device).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
assert jax.device_count() == 8, jax.device_count()

from repro.core.objective import smooth_loss_and_grad
from repro.data import CTRDataConfig, generate, pad_to_multiple
from repro.dist import make_distributed_step, shard_batch, shard_state
from repro.launch.mesh import make_debug_mesh
from repro.optim import OWLQNPlus

cfg = CTRDataConfig(num_user_features=24, num_ad_features=24, noise_features=8)
batch, _ = generate(cfg, num_sessions=64, seed=3)
batch = pad_to_multiple(batch, 8)
d, m = cfg.num_features, 4
theta0 = jnp.asarray(0.02 * np.random.default_rng(0).normal(size=(d, 2 * m)), jnp.float32)

def run_single(steps):
    b = jax.tree.map(jnp.asarray, batch)
    opt = OWLQNPlus(lambda t: smooth_loss_and_grad(t, b, common_feature=True), lam=0.5, beta=0.5)
    st = opt.init(theta0)
    step = jax.jit(opt.step)
    out = []
    for _ in range(steps):
        st, stats = step(st)
        out.append(float(stats.f_new))
    return np.asarray(jax.device_get(st.theta)), out

def run_dist(steps):
    mesh = make_debug_mesh(data=2, model=4)
    b = shard_batch(mesh, jax.tree.map(jnp.asarray, batch), common_feature=True)
    opt = OWLQNPlus(lambda t: smooth_loss_and_grad(t, b, common_feature=True), lam=0.5, beta=0.5)
    st = shard_state(opt.init(theta0), mesh)
    step = make_distributed_step(opt, mesh)
    out = []
    for _ in range(steps):
        st, stats = step(st)
        out.append(float(stats.f_new))
    # verify theta really is sharded over 'model'
    shard_shapes = {s.data.shape for s in st.theta.addressable_shards}
    assert shard_shapes == {(d // 4, 2 * m)}, shard_shapes
    return np.asarray(jax.device_get(st.theta)), out

t1, f1 = run_single(6)
t2, f2 = run_dist(6)
np.testing.assert_allclose(f1, f2, rtol=2e-4)
np.testing.assert_allclose(t1, t2, rtol=2e-3, atol=2e-5)
# sparsity pattern must agree exactly (orthant logic is sign-exact)
np.testing.assert_array_equal(t1 == 0.0, t2 == 0.0)
print("DIST-OK")
"""


@pytest.mark.slow
def test_distributed_step_matches_single_device():
    env = os.environ.copy()
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        timeout=600,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "DIST-OK" in r.stdout
