"""The bucketed scoring engine: envelope rounding, padded-score parity
with direct unpadded scoring, the steady-state ZERO-recompile guarantee
under a randomized request replay, and the stats ledger."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.serve import (
    BundleRequest,
    ScoreBundle,
    ScoringEngine,
    compress,
    score_bundles,
    synthetic_requests,
)
from repro.serve.engine import _round_up

D, M = 700, 3


@pytest.fixture(scope="module")
def theta():
    rng = np.random.default_rng(0)
    th = rng.normal(size=(D, 2 * M)).astype(np.float32) * 0.3
    th[rng.random(D) >= 0.2] = 0.0
    return jnp.asarray(th)


def _direct_scores(theta, req: BundleRequest) -> np.ndarray:
    """Unpadded single-bundle scoring through the plain score layer."""
    n = req.ad_ids.shape[0]
    bundle = ScoreBundle(
        user_ids=jnp.asarray(req.user_ids[None], jnp.int32),
        user_vals=jnp.asarray(req.user_vals[None]),
        ad_ids=jnp.asarray(req.ad_ids, jnp.int32),
        ad_vals=jnp.asarray(req.ad_vals),
        session_id=jnp.zeros((n,), jnp.int32))
    return np.asarray(score_bundles(theta, bundle))


# ------------------------------------------------------------ envelopes
def test_round_up_bucket_edges():
    assert _round_up(1, (8, 16)) == 8
    assert _round_up(8, (8, 16)) == 8
    assert _round_up(9, (8, 16)) == 16
    assert _round_up(17, (8, 16)) == 32  # past the top: multiples of it
    assert _round_up(33, (8, 16)) == 48
    with pytest.raises(ValueError):
        _round_up(0, (8, 16))


def test_envelope_uses_configured_buckets(theta):
    eng = ScoringEngine(theta, k_buckets=(4, 8), n_buckets=(2, 4))
    req = synthetic_requests(1, num_features=D, k_user=(5, 5), k_ad=(3, 3),
                             n_ads=(3, 3))[0]
    assert eng.envelope(req) == (8, 4, 4)


# ------------------------------------------------------ score parity
def test_engine_scores_match_direct(theta):
    """Padding to the envelope must not change the scores beyond fp
    reassociation of the padded-K contraction (<= 1e-6)."""
    eng = ScoringEngine(theta)
    for req in synthetic_requests(12, num_features=D, seed=1):
        np.testing.assert_allclose(eng.score(req), _direct_scores(theta, req),
                                   rtol=1e-6, atol=1e-6)


def test_engine_pruned_equals_full(theta):
    """The engine on a pruned artifact returns BIT-identical scores to
    the engine on the full Theta (same envelopes, same kernel path)."""
    full = ScoringEngine(theta)
    pruned = ScoringEngine(compress(theta))
    for req in synthetic_requests(8, num_features=D, seed=2):
        np.testing.assert_array_equal(full.score(req), pruned.score(req))


# --------------------------------------------------- steady-state cache
def test_zero_recompiles_on_randomized_replay(theta):
    rng = np.random.default_rng(3)
    eng = ScoringEngine(theta)
    requests = synthetic_requests(40, num_features=D, seed=4)
    eng.warm({eng.envelope(r) for r in requests})
    warm_compiles = eng.stats.compiles
    assert warm_compiles == len({eng.envelope(r) for r in requests})
    first = {}
    for _ in range(3):  # three shuffled replays of the same traffic
        order = rng.permutation(len(requests))
        for i in order:
            p = eng.score(requests[i])
            if i in first:
                np.testing.assert_array_equal(p, first[i])  # deterministic
            else:
                first[i] = p
    assert eng.stats.compiles == warm_compiles, "steady state recompiled"
    assert eng.stats.requests == 3 * len(requests)


def test_new_envelope_compiles_exactly_once(theta):
    eng = ScoringEngine(theta, k_buckets=(8,), n_buckets=(4,))
    reqs = synthetic_requests(4, num_features=D, k_user=(6, 6), k_ad=(4, 4),
                              n_ads=(3, 3), seed=5)
    eng.score(reqs[0])
    assert eng.stats.compiles == 1
    eng.score_many(reqs[1:])
    assert eng.stats.compiles == 1  # same envelope, cached executable
    big = synthetic_requests(1, num_features=D, k_user=(10, 10), k_ad=(4, 4),
                             n_ads=(3, 3), seed=6)[0]
    eng.score(big)  # Ku 10 -> bucket 16 (8x2): a genuinely new envelope
    assert eng.stats.compiles == 2


def test_stats_ledger(theta):
    eng = ScoringEngine(theta)
    requests = synthetic_requests(10, num_features=D, seed=7)
    eng.score_many(requests)
    s = eng.stats
    assert s.requests == 10
    assert s.candidates == sum(r.ad_ids.shape[0] for r in requests)
    assert sum(s.bucket_hits.values()) == 10
    assert s.dispatches == 10 and s.slots == 10  # all G=1 dispatches
    assert s.occupancy == 1.0
    assert s.score_seconds > 0 and s.compile_seconds > 0
    assert s.latency_us > 0 and s.candidates_per_sec > 0
    d = s.as_dict()
    assert d["requests"] == 10 and len(d["bucket_hits"]) == len(s.bucket_hits)
    assert d["occupancy"] == 1.0 and d["dispatches"] == 10


# ------------------------------------------------------- batched (G>1)
def test_score_batch_matches_score_bitwise(theta):
    """Stacking same-envelope requests into one G>1 dispatch returns the
    SAME numbers as scoring each alone: a request's padded block is
    identical either way, G slots are independent bundles."""
    reqs = synthetic_requests(20, num_features=D, seed=8)
    eng_one = ScoringEngine(theta)
    eng_many = ScoringEngine(theta)
    want = [eng_one.score(r) for r in reqs]
    got = eng_many.score_batch(reqs)
    assert len(got) == len(want)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a, b)
    # batching really batched: fewer dispatches than requests, G rounded
    # onto buckets (slots >= requests), every request accounted for
    b = eng_many.stats
    assert b.requests == 20 and b.dispatches < 20
    assert b.slots >= b.requests
    assert 0 < b.occupancy <= 1.0


def test_score_batch_mixed_envelopes_preserve_order(theta):
    """Requests from different envelopes come back in input order even
    though they are served by different grouped dispatches."""
    small = synthetic_requests(3, num_features=D, k_user=(4, 4), k_ad=(3, 3),
                               n_ads=(2, 2), seed=9)
    big = synthetic_requests(3, num_features=D, k_user=(20, 20), k_ad=(9, 9),
                             n_ads=(12, 12), seed=10)
    mixed = [small[0], big[0], small[1], big[1], small[2], big[2]]
    eng = ScoringEngine(theta)
    got = eng.score_batch(mixed)
    for r, p in zip(mixed, got):
        assert p.shape == (r.ad_ids.shape[0],)
        np.testing.assert_array_equal(p, ScoringEngine(theta).score(r))


def test_score_batch_splits_past_max_batch(theta):
    """A same-envelope wavefront bigger than the top G bucket splits
    into max_batch-sized chunks (scores unchanged)."""
    eng = ScoringEngine(theta, g_buckets=(1, 2, 4))
    assert eng.max_batch == 4
    reqs = synthetic_requests(11, num_features=D, k_user=(6, 6), k_ad=(4, 4),
                              n_ads=(3, 3), seed=11)
    got = eng.score_batch(reqs)
    assert eng.stats.dispatches == 3  # 4 + 4 + 3(->G=4)
    assert eng.stats.slots == 12
    for r, p in zip(reqs, got):
        np.testing.assert_array_equal(p, ScoringEngine(theta).score(r))


def test_batched_zero_recompiles_after_g_bucket_warm(theta):
    """warm(envelopes, batch_sizes=g_buckets) covers every dispatch the
    batched path can make: replays of any grouping never recompile."""
    rng = np.random.default_rng(12)
    eng = ScoringEngine(theta)
    reqs = synthetic_requests(30, num_features=D, seed=13)
    eng.warm({eng.envelope(r) for r in reqs}, batch_sizes=eng.g_buckets)
    warm = eng.stats.compiles
    for _ in range(3):
        order = rng.permutation(len(reqs))
        eng.score_batch([reqs[i] for i in order])
    eng.score_many(reqs)  # the G=1 path rides the same warmed cache
    assert eng.stats.compiles == warm, "steady state recompiled"


def test_batched_envelope_compiles_key_on_g(theta):
    """Each (G, Ku, Ka, N) key compiles exactly once: same envelope at a
    new batch size is one more compile, replays are free."""
    eng = ScoringEngine(theta, k_buckets=(8,), n_buckets=(4,),
                        g_buckets=(1, 2, 4))
    reqs = synthetic_requests(4, num_features=D, k_user=(6, 6), k_ad=(4, 4),
                              n_ads=(3, 3), seed=14)
    eng.score(reqs[0])  # (1, 8, 8, 4)
    assert eng.stats.compiles == 1
    eng.score_batch(reqs[:2])  # (2, 8, 8, 4)
    assert eng.stats.compiles == 2
    eng.score_batch(reqs)  # (4, 8, 8, 4)
    assert eng.stats.compiles == 3
    eng.score_batch(reqs[:2])  # cached
    eng.score(reqs[3])  # cached
    assert eng.stats.compiles == 3


# ------------------------------------------------- forced envelopes
def test_score_batch_at_bitwise_matches_natural_envelopes(theta):
    """Forcing a mixed wavefront onto one wide envelope (the coalesced
    dispatch primitive) returns the SAME numbers as per-envelope
    dispatch: widening only adds pad slots, which alias the zero pad
    row."""
    small = synthetic_requests(3, num_features=D, k_user=(4, 4), k_ad=(3, 3),
                               n_ads=(2, 2), seed=15)
    big = synthetic_requests(2, num_features=D, k_user=(20, 20), k_ad=(9, 9),
                             n_ads=(12, 12), seed=16)
    mixed = [small[0], big[0], small[1], big[1], small[2]]
    eng = ScoringEngine(theta)
    widest = tuple(max(eng.envelope(r)[i] for r in mixed) for i in range(3))
    got = eng.score_batch_at(mixed, widest)
    assert eng.stats.dispatches == 1  # the whole wavefront in one round
    for r, p in zip(mixed, got):
        assert p.shape == (r.ad_ids.shape[0],)
        np.testing.assert_array_equal(p, ScoringEngine(theta).score(r))


def test_score_batch_at_rejects_overflowing_requests(theta):
    reqs = synthetic_requests(2, num_features=D, k_user=(12, 12), k_ad=(6, 6),
                              n_ads=(8, 8), seed=17)
    eng = ScoringEngine(theta)
    with pytest.raises(ValueError):
        eng.score_batch_at(reqs, (8, 8, 8))  # Ku 12 > forced Ku 8


# ------------------------------------------------------- int8-native
def test_int8_engine_parity_and_dtype_keyed_cache(theta):
    """An engine built straight on a QuantizedArtifact serves int8-
    native: scores match the dequantized fp32 engine to <= 1e-6 and stay
    within |dp| <= 1e-2 of the unquantised model, while the executable
    cache keys on dtype (no sharing, no clobbering)."""
    from repro.serve import dequantize, quantize

    q = quantize(compress(theta))
    reqs = synthetic_requests(12, num_features=D, seed=18)
    eng_i8 = ScoringEngine(q)
    eng_deq = ScoringEngine(dequantize(q))
    eng_fp = ScoringEngine(theta)
    assert eng_i8._dtype == "int8" and eng_deq._dtype == "fp32"
    for r in reqs:
        p_i8 = eng_i8.score(r)
        np.testing.assert_allclose(p_i8, eng_deq.score(r),
                                   rtol=1e-6, atol=1e-6)
        assert np.abs(p_i8 - eng_fp.score(r)).max() <= 1e-2
    # batched path too
    for a, b in zip(eng_i8.score_batch(reqs), eng_deq.score_batch(reqs)):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
    # dtype rides the cache key and the stats labels
    assert all(k[-1] == "int8" for k in eng_i8._compiled)
    assert all(k[-1] == "fp32" for k in eng_deq._compiled)
    assert all(k[-1] == "int8" for k in eng_i8.stats.bucket_hits)


def test_int8_engine_zero_recompiles_on_randomized_replay(theta):
    """The steady-state guarantee holds unchanged for int8-native
    engines: warm the (envelope x g_bucket) grid once, then shuffled
    replays never recompile."""
    from repro.serve import quantize

    rng = np.random.default_rng(19)
    eng = ScoringEngine(quantize(compress(theta)))
    reqs = synthetic_requests(30, num_features=D, seed=20)
    eng.warm({eng.envelope(r) for r in reqs}, batch_sizes=eng.g_buckets)
    warm = eng.stats.compiles
    first = {}
    for _ in range(3):
        order = rng.permutation(len(reqs))
        eng.score_batch([reqs[i] for i in order])
        for i in order:
            p = eng.score(reqs[i])
            if i in first:
                np.testing.assert_array_equal(p, first[i])
            else:
                first[i] = p
    assert eng.stats.compiles == warm, "int8 steady state recompiled"
