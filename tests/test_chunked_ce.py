"""Chunked cross-entropy (ce_chunk) must equal the full-logits path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.models.transformer import loss_fn


@pytest.mark.parametrize("arch", ["llama3.2-1b", "granite-moe-1b-a400m",
                                  "internvl2-2b"])
@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_ce_matches_full(arch, chunk):
    cfg = get_config(arch).reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)}
    if cfg.num_prefix_embeds:
        batch["prefix_embeds"] = 0.1 * jax.random.normal(
            ks[2], (B, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
    cfg_c = dataclasses.replace(cfg, ce_chunk=chunk)

    l_full, _ = loss_fn(params, cfg, batch)
    l_chunk, _ = loss_fn(params, cfg_c, batch)
    np.testing.assert_allclose(float(l_full), float(l_chunk), rtol=1e-4)

    g_full = jax.grad(lambda p: loss_fn(p, cfg, batch)[0])(params)
    g_chunk = jax.grad(lambda p: loss_fn(p, cfg_c, batch)[0])(params)
    # bf16 recompute-order noise only
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=5e-2, atol=2e-3),
        g_full, g_chunk,
    )


def test_chunked_ce_with_loss_weights():
    cfg = get_config("llama3.2-1b").reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    B, S = 2, 8
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                          cfg.vocab_size),
             "loss_weights": jnp.asarray(
                 np.random.default_rng(0).random((B, S)) > 0.3,
                 jnp.float32)}
    cfg_c = dataclasses.replace(cfg, ce_chunk=4)
    l_full, _ = loss_fn(params, cfg, batch)
    l_chunk, _ = loss_fn(params, cfg_c, batch)
    np.testing.assert_allclose(float(l_full), float(l_chunk), rtol=1e-4)
