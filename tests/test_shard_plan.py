"""Plan-slicing invariants (repro.shard.plan_slicing).

The TransposePlan's sorted-by-id layout must split at id-range
boundaries into per-shard plans that are BIT-IDENTICAL to plans built
from scratch on the routed shard-local ids — same stable entry order,
same popularity classes, same inverse maps — and the per-shard segment
sums must reassemble the full plan's scatter exactly. Seeded-grid
parametrization (the repo's hypothesis-free property style).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.lsplm_sparse_scatter.ops import (
    build_transpose_plan,
    dvals_planned,
    scatter_add_planned,
    scatter_add_ref,
)
from repro.shard.partition import Partition, make_partition, route_ids
from repro.shard.plan_slicing import (
    cell_plan,
    restrict_plan,
    shard_plan_grid,
    slice_plan,
    stack_plans,
)

GRID = [
    # (seed, N, K, d, S, zipf_power or None, pad_frac)
    (0, 24, 6, 200, 4, None, 0.0),
    (1, 32, 9, 500, 3, 6.0, 0.25),
    (2, 16, 4, 120, 5, 3.0, 0.5),
    (3, 8, 3, 64, 2, None, 0.9),   # nearly all pad
    (4, 40, 12, 1000, 7, 8.0, 0.1),  # hot head, many shards
    (5, 6, 2, 50, 6, None, 1.0),   # all pad: every shard empty
]


def _make(seed, N, K, d, power, pad_frac):
    rng = np.random.default_rng(seed)
    if power is None:
        ids = rng.integers(0, d, (N, K))
    else:
        ids = (d * (rng.random((N, K)) ** power)).astype(np.int64)
    ids[rng.random((N, K)) < pad_frac] = d
    vals = rng.normal(size=(N, K)).astype(np.float32)
    vals[ids == d] = 0.0
    return ids, vals, rng


def _random_partition(rng, d, S):
    cuts = np.sort(rng.choice(np.arange(1, d), S - 1, replace=False))
    return Partition(np.concatenate([[0], cuts, [d]]))


def _assert_plans_equal(a, b):
    la, auxa = jax.tree.flatten(a)
    lb, auxb = jax.tree.flatten(b)
    assert auxa == auxb
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("seed,N,K,d,S,power,pad_frac", GRID)
def test_slice_plan_equals_build_on_routed_ids(seed, N, K, d, S, power,
                                               pad_frac):
    ids, vals, rng = _make(seed, N, K, d, power, pad_frac)
    plan = build_transpose_plan(ids, d + 1, pad_id=d)
    part = _random_partition(rng, d, S)
    ids_r, _, Ks = route_ids(part, ids, vals, pad_id=d)
    sliced = slice_plan(plan, part, num_cols=K)
    assert len(sliced) == S
    for s in range(S):
        ref = build_transpose_plan(ids_r[s], part.rows_per_shard + 1,
                                   pad_id=part.rows_per_shard)
        assert sliced[s].num_entries == N * Ks == ref.num_entries
        _assert_plans_equal(sliced[s], ref)


@pytest.mark.parametrize("seed,N,K,d,S,power,pad_frac", GRID[:4])
def test_restrict_plan_equals_build_on_sample_range(seed, N, K, d, S, power,
                                                    pad_frac):
    ids, _, rng = _make(seed, N, K, d, power, pad_frac)
    plan = build_transpose_plan(ids, d + 1, pad_id=d)
    n0, n1 = N // 4, N - N // 4
    _assert_plans_equal(
        restrict_plan(plan, n0, n1, num_cols=K),
        build_transpose_plan(ids[n0:n1], d + 1, pad_id=d))


@pytest.mark.parametrize("seed,N,K,d,S,power,pad_frac", GRID)
def test_sliced_segment_sums_reassemble_full_scatter(seed, N, K, d, S, power,
                                                     pad_frac):
    ids, vals, rng = _make(seed, N, K, d, power, pad_frac)
    m2 = 6
    dz = jnp.asarray(rng.normal(size=(N, m2)).astype(np.float32))
    plan = build_transpose_plan(ids, d + 1, pad_id=d)
    part = _random_partition(rng, d, S)
    ids_r, vals_r, Ks = route_ids(part, ids, vals, pad_id=d)
    sliced = slice_plan(plan, part, num_cols=K, shard_k=Ks)

    full = np.asarray(scatter_add_planned(plan, jnp.asarray(vals), dz,
                                          mode="jnp"))
    oracle = np.asarray(scatter_add_ref(jnp.asarray(ids), jnp.asarray(vals),
                                        dz, d + 1))
    assembled = np.zeros((d + 1, m2), np.float32)
    R = part.rows_per_shard
    for s, (lo, hi) in enumerate(part.ranges()):
        loc = np.asarray(scatter_add_planned(
            sliced[s], jnp.asarray(vals_r[s]), dz, mode="jnp"))
        assert loc.shape == (R + 1, m2)
        # rows past the shard's true range and its pad row stay zero
        assert np.all(loc[hi - lo:] == 0.0)
        assembled[lo:hi] += loc[: hi - lo]
    scale = max(1.0, np.abs(full).max())
    np.testing.assert_allclose(assembled / scale, full / scale, atol=2e-6)
    np.testing.assert_allclose(full / scale, oracle / scale, atol=2e-6)


@pytest.mark.parametrize("seed,N,K,d,S,power,pad_frac", GRID[:5])
def test_stacked_plan_cells_match_unpadded(seed, N, K, d, S, power, pad_frac):
    """stack_plans pads cells to uniform shapes; padding must be inert:
    each extracted cell's scatter AND dvals equal the unpadded cell
    plan's, for every (data block, shard)."""
    Dd = 2
    if N % Dd:
        N += N % Dd
    ids, vals, rng = _make(seed, N, K, d, power, pad_frac)
    m2 = 4
    plan = build_transpose_plan(ids, d + 1, pad_id=d)
    part = _random_partition(rng, d, S)
    ids_r, vals_r, Ks = route_ids(part, ids, vals, pad_id=d)
    grid = shard_plan_grid(plan, part, num_cols=K, data_shards=Dd,
                           shard_k=Ks)
    stacked = stack_plans(grid)
    R = part.rows_per_shard
    N_l = N // Dd
    assert stacked.num_rows == R + 1
    assert stacked.num_entries == N_l * Ks

    for b in range(Dd):
        dz = jnp.asarray(rng.normal(size=(N_l, m2)).astype(np.float32))
        for s in range(S):
            cell = jax.tree.map(lambda a: a[b, s], stacked)
            ref = grid[b][s]
            vloc = jnp.asarray(vals_r[s, b * N_l: (b + 1) * N_l])
            iloc = ids_r[s, b * N_l: (b + 1) * N_l]
            want = np.asarray(scatter_add_planned(ref, vloc, dz, mode="jnp"))
            got = np.asarray(scatter_add_planned(cell, vloc, dz, mode="jnp"))
            np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)
            # the run-length KERNEL must tolerate the padded entries too
            # (pad run flushes exact zeros onto the absent-id compact row)
            if b == 0 and s < 2:  # interpret mode is slow: spot-check
                got_k = np.asarray(scatter_add_planned(
                    cell, vloc, dz, mode="interpret", block_e=64))
                np.testing.assert_allclose(got_k, want, rtol=1e-6,
                                           atol=1e-6)
            tp = jnp.asarray(
                rng.normal(size=(R + 1, m2)).astype(np.float32)
            ).at[R].set(0.0)  # local pad row is zero by construction
            dv_ref = np.asarray(dvals_planned(ref, tp, dz, iloc.shape))
            dv_got = np.asarray(dvals_planned(cell, tp, dz, iloc.shape))
            np.testing.assert_allclose(dv_got, dv_ref, rtol=1e-6, atol=1e-7)


def test_cell_plan_roundtrip_and_none():
    assert cell_plan(None) is None
    ids = np.array([[0, 3, 1], [2, 3, 0]])
    plan = build_transpose_plan(ids, 5, pad_id=4)
    stacked = stack_plans([[plan]])
    _assert_plans_equal(cell_plan(stacked), plan)


def test_restrict_plan_window_edges():
    """Window edges the streaming trainer produces: empty window,
    single-sample window, and boundaries that split a SESSION's samples
    (restriction is by sample index — nothing requires it to respect the
    session grouping). Each restricted plan must be bit-identical to a
    fresh build on the restricted ids."""
    rng = np.random.default_rng(9)
    d, K, A = 300, 5, 4          # A samples (ads) per session
    N = 6 * A                    # 6 sessions
    ids = rng.integers(0, d, (N, K))
    ids[rng.random((N, K)) < 0.3] = d  # pads
    plan = build_transpose_plan(ids, d + 1, pad_id=d)
    windows = [
        (0, 0),            # empty window at the start
        (N // 2, N // 2),  # empty window inside
        (N, N),            # empty window at the end
        (7, 8),            # single sample (mid-session)
        (0, N),            # identity window
        (2, 10),           # splits session 0 AND session 2
        (A, 3 * A),        # session-aligned (the common case)
        (N - 3, N),        # tail splitting the last session
    ]
    for (n0, n1) in windows:
        got = restrict_plan(plan, n0, n1, num_cols=K)
        want = build_transpose_plan(ids[n0:n1], d + 1, pad_id=d)
        assert got.num_entries == (n1 - n0) * K
        _assert_plans_equal(got, want)
    # an empty restriction still drives the scatter (to all zeros)
    empty = restrict_plan(plan, 3, 3, num_cols=K)
    out = scatter_add_planned(empty, jnp.zeros((0, K)),
                              jnp.zeros((0, 2)), mode="jnp")
    assert out.shape == (d + 1, 2)
    assert not np.asarray(out).any()


def test_restrict_plan_bad_ranges():
    ids = np.array([[0, 1], [2, 3], [1, 2]])
    plan = build_transpose_plan(ids, 5, pad_id=4)
    for (n0, n1) in [(-1, 2), (2, 1), (0, 4), (4, 4)]:
        with pytest.raises(ValueError, match="bad sample range"):
            restrict_plan(plan, n0, n1, num_cols=2)
    with pytest.raises(ValueError, match="does not divide"):
        restrict_plan(plan, 0, 1, num_cols=4)


def test_slice_plan_errors():
    ids = np.array([[0, 1], [2, 3]])
    plan = build_transpose_plan(ids, 5, pad_id=4)
    with pytest.raises(ValueError, match="does not divide"):
        slice_plan(plan, make_partition(4, 2), num_cols=3)
    with pytest.raises(ValueError, match="too small"):
        slice_plan(plan, make_partition(4, 1), num_cols=2, shard_k=1)
    with pytest.raises(ValueError, match="disagree"):
        stack_plans([[plan, build_transpose_plan(ids, 6, pad_id=5)]])
