"""int8 KV cache (quantised serving) must closely track the bf16 cache."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_step, init_caches, init_model


@pytest.mark.slow
def test_int8_kv_decode_tracks_bf16():
    cfg = get_config("qwen1.5-32b").reduced()
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = init_model(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    c16 = init_caches(cfg, B, 32)
    c8 = init_caches(cfg8, B, 32)
    assert c8["k"].dtype == jnp.int8 and "k_scale" in c8

    agree = 0
    for t in range(S):
        l16, c16 = decode_step(params, cfg, c16, token=tokens[:, t],
                               pos=jnp.asarray(t))
        l8, c8 = decode_step(params, cfg8, c8, token=tokens[:, t],
                             pos=jnp.asarray(t))
        a16 = np.asarray(l16, np.float32)
        a8 = np.asarray(l8, np.float32)
        assert np.all(np.isfinite(a8))
        # logits close; argmax agreement across steps
        np.testing.assert_allclose(a8, a16, rtol=0.2, atol=0.2)
        agree += int((a8.argmax(-1) == a16.argmax(-1)).all())
    assert agree >= S - 1, f"top-1 agreement {agree}/{S}"


def test_int8_cache_memory_is_half():
    cfg = dataclasses.replace(get_config("llama3.2-1b").reduced(),
                              kv_cache_dtype="int8")
    c = init_caches(cfg, 2, 64)
    bf16 = init_caches(get_config("llama3.2-1b").reduced(), 2, 64)
    bytes8 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c))
    bytes16 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(bf16))
    assert bytes8 < 0.6 * bytes16  # int8 + small scale overhead
