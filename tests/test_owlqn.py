"""Tests for Algorithm 1 (OWLQN+): convergence, sparsity, invariants."""
import jax.numpy as jnp
import numpy as np

from repro.core import CTRBatch, predict_proba
from repro.core.objective import smooth_loss_and_grad
from repro.data import CTRDataConfig, auc, generate, to_dense_batch
from repro.optim import OWLQNPlus


def _quadratic_problem(d=20, m2=6, seed=0):
    """Smooth part: 0.5||A theta - b||^2 (convex); known solvable baseline."""
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.normal(size=(40, d)) / np.sqrt(d), jnp.float32)
    theta_true = rng.normal(size=(d, m2)).astype(np.float32)
    theta_true[rng.random((d, m2)) < 0.6] = 0.0  # sparse truth
    b = A @ jnp.asarray(theta_true)

    def loss_and_grad(theta):
        r = A @ theta - b
        return 0.5 * jnp.vdot(r, r), A.T @ r

    return loss_and_grad, jnp.asarray(theta_true)


def test_converges_smooth_case():
    """lam=beta=0: plain LBFGS on a convex quadratic -> near-exact solve."""
    lg, theta_true = _quadratic_problem()
    opt = OWLQNPlus(lg, lam=0.0, beta=0.0, memory=10)
    theta, trace = opt.run(jnp.zeros_like(theta_true), max_iters=200, tol=1e-10)
    final = float(lg(theta)[0])
    assert final < 1e-6, f"final loss {final}"


def test_monotone_decrease():
    lg, theta_true = _quadratic_problem()
    opt = OWLQNPlus(lg, lam=0.3, beta=0.3)
    _, trace = opt.run(jnp.zeros_like(theta_true), max_iters=50)
    fs = [float(s.f) for s in trace] + [float(trace[-1].f_new)]
    # f is evaluated pre-step; accepted steps never increase the objective
    for a, b in zip(fs[:-1], fs[1:]):
        assert b <= a + 1e-4 * max(1.0, abs(a)), (a, b)


def test_l1_induces_elementwise_sparsity():
    lg, theta_true = _quadratic_problem()
    opt_dense = OWLQNPlus(lg, lam=0.0, beta=0.0)
    opt_sparse = OWLQNPlus(lg, lam=0.0, beta=2.0)
    t_dense, _ = opt_dense.run(jnp.ones_like(theta_true) * 0.1, max_iters=100)
    t_sparse, _ = opt_sparse.run(jnp.ones_like(theta_true) * 0.1, max_iters=100)
    nnz_dense = int(jnp.sum(t_dense != 0))
    nnz_sparse = int(jnp.sum(t_sparse != 0))
    assert nnz_sparse < nnz_dense
    assert nnz_sparse < theta_true.size * 0.8


def test_l21_induces_row_sparsity():
    """Table 2's claim: L2,1 kills whole feature rows."""
    lg, theta_true = _quadratic_problem()
    opt = OWLQNPlus(lg, lam=4.0, beta=0.0)
    theta, _ = opt.run(jnp.ones_like(theta_true) * 0.1, max_iters=150)
    row_norms = np.asarray(jnp.sqrt(jnp.sum(theta**2, axis=1)))
    zero_rows = int((row_norms == 0.0).sum())
    assert zero_rows > 0, "L2,1 should remove whole features"
    # surviving rows are fully dense or fully zero more often than chance:
    # elementwise zeros inside surviving rows only come from projection
    t = np.asarray(theta)
    for i in range(t.shape[0]):
        if row_norms[i] == 0.0:
            np.testing.assert_array_equal(t[i], 0.0)


def test_lasso_matches_scipy_proximal_reference():
    """L1-only convex case cross-checked against scipy's L-BFGS-B split
    formulation (theta = a - b, a,b >= 0) — an exact LASSO reference."""
    from scipy.optimize import minimize

    rng = np.random.default_rng(1)
    A = rng.normal(size=(30, 10)).astype(np.float64)
    b = rng.normal(size=(30,)).astype(np.float64)
    beta = 1.5

    Aj, bj = jnp.asarray(A, jnp.float32), jnp.asarray(b, jnp.float32)

    def lg(theta):
        r = Aj @ theta[:, 0] - bj
        return 0.5 * jnp.vdot(r, r), (Aj.T @ r)[:, None]

    opt = OWLQNPlus(lg, lam=0.0, beta=beta)
    theta, _ = opt.run(jnp.zeros((10, 1), jnp.float32), max_iters=300, tol=1e-12)
    ours = float(0.5 * np.sum((A @ np.asarray(theta)[:, 0] - b) ** 2)
                 + beta * np.abs(np.asarray(theta)).sum())

    def split_obj(z):
        a, c = z[:10], z[10:]
        t = a - c
        r = A @ t - b
        return 0.5 * r @ r + beta * (a.sum() + c.sum())

    def split_grad(z):
        a, c = z[:10], z[10:]
        g = A.T @ (A @ (a - c) - b)
        return np.concatenate([g + beta, -g + beta])

    res = minimize(split_obj, np.zeros(20), jac=split_grad, method="L-BFGS-B",
                   bounds=[(0, None)] * 20, options={"maxiter": 2000, "ftol": 1e-14})
    assert ours <= res.fun * (1 + 1e-3) + 1e-6, (ours, res.fun)


def test_lsplm_end_to_end_beats_lr():
    """The paper's headline claim (Fig. 5): LS-PLM > LR on nonlinear data."""
    cfg = CTRDataConfig(num_user_features=24, num_ad_features=24,
                        noise_features=8, true_regions=4, seed=0)
    train_cf, _ = generate(cfg, num_sessions=4000, seed=1)
    test_cf, _ = generate(cfg, num_sessions=800, seed=2)
    train = to_dense_batch(train_cf)
    test = to_dense_batch(test_cf)
    tb = CTRBatch(x=jnp.asarray(train.x), y=jnp.asarray(train.y))
    d = cfg.num_features

    def fit(m, lam, beta, iters):
        theta0 = jnp.asarray(
            0.01 * np.random.default_rng(0).normal(size=(d, 2 * m)), jnp.float32
        )
        lg = lambda theta: smooth_loss_and_grad(theta, tb)
        opt = OWLQNPlus(lg, lam=lam, beta=beta)
        theta, _ = opt.run(theta0, max_iters=iters)
        from repro.core.lsplm import params_from_theta
        return np.asarray(predict_proba(params_from_theta(theta), jnp.asarray(test.x)))

    auc_lr = auc(test.y, fit(m=1, lam=0.0, beta=1.0, iters=30))
    auc_plm = auc(test.y, fit(m=8, lam=1.0, beta=1.0, iters=70))
    # Fig. 5: LS-PLM improves AUC over LR markedly (paper: +1.4% absolute
    # on production data; our synthetic truth is piecewise-linear so the
    # gap is larger)
    assert auc_plm > auc_lr + 0.05, (auc_lr, auc_plm)
    assert auc_plm > 0.8
