"""Distributed sparse training (repro.shard.step) must match the
single-device fused path.

Runs in a subprocess so XLA_FLAGS can request 8 host devices without
polluting the main test process (which must keep 1 device) — the same
pattern as tests/test_distributed.py for the dense path. REPRO_DEVICES
overrides the forced device count (the CI shard job sets it to 8).

Checks, per (data, model) mesh shape:
  * sharded loss AND row-sharded grad == single-device fused loss/grad
    (fp32 tolerance; the association order of the z psum differs),
  * several sharded OWLQN+ steps reproduce the single-device f trace,
    theta, and EXACT sparsity pattern (orthant logic is sign-exact),
  * untouched Theta rows stay exactly zero under the sharded step,
  * theta really is row-sharded over 'model',
  * a frequency-balanced (unequal-range, padded-layout) partition gives
    the same loss/grad.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
DEV = int(os.environ.get("REPRO_DEVICES", "8"))
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={DEV}"
MESH_DATA, MESH_MODEL = %d, %d
import numpy as np
import jax, jax.numpy as jnp
assert jax.device_count() >= MESH_DATA * MESH_MODEL, jax.device_count()

from repro.data.sparse import generate_sparse, sparse_loss_and_grad
from repro.dist import make_distributed_step, shard_sparse_batch, shard_state
from repro.launch.mesh import make_debug_mesh
from repro.optim import OWLQNPlus
from repro.shard import (
    balanced_partition, make_partition, make_sharded_sparse_loss,
    route_batch, sharded_sparse_loss_and_grad,
)

d, m = 600, 4
batch = generate_sparse(num_features=d, num_user_features_range=(360, d),
                        sessions=32, ads_per_session=4, active_user=8,
                        active_ad=5, seed=3)
# init only the rows some id touches: untouched rows start at exact zero
# and the L1/L2,1 orthant algebra must KEEP them there, sharded or not
seen = np.zeros(d, bool)
for ids in (np.asarray(batch.user_ids), np.asarray(batch.ad_ids)):
    seen[ids.reshape(-1)[ids.reshape(-1) < d]] = True
theta0 = jnp.asarray(
    0.02 * np.random.default_rng(0).normal(size=(d, 2 * m)) * seen[:, None],
    jnp.float32)
mesh = make_debug_mesh(data=MESH_DATA, model=MESH_MODEL)

# ---- loss/grad parity, equal and frequency-balanced partitions
l_ref, g_ref = jax.jit(sparse_loss_and_grad)(theta0, batch)
g_scale = max(1.0, float(jnp.abs(g_ref).max()))
for part in (
        make_partition(d, MESH_MODEL),
        balanced_partition(d, MESH_MODEL, np.asarray(batch.user_ids),
                           np.asarray(batch.ad_ids), pad_id=d)):
    sb = shard_sparse_batch(mesh, route_batch(batch, part,
                                              data_shards=MESH_DATA))
    l_sh, g_sh = jax.jit(
        lambda t: sharded_sparse_loss_and_grad(t, sb, mesh)
    )(part.pad_rows(theta0))
    np.testing.assert_allclose(float(l_sh), float(l_ref), rtol=2e-5)
    np.testing.assert_allclose(
        np.asarray(part.unpad_rows(jax.device_get(g_sh))) / g_scale,
        np.asarray(g_ref) / g_scale, atol=3e-5)

# ---- OWLQN+ trajectory parity (equal partition = the trainer's config)
def run_single(steps):
    opt = OWLQNPlus(lambda t: sparse_loss_and_grad(t, batch),
                    lam=0.5, beta=0.5)
    st = opt.init(theta0)
    step = jax.jit(opt.step)
    fs = []
    for _ in range(steps):
        st, stats = step(st)
        fs.append(float(stats.f_new))
    return np.asarray(jax.device_get(st.theta)), fs

def run_sharded(steps):
    part = make_partition(d, MESH_MODEL)
    sb = shard_sparse_batch(mesh, route_batch(batch, part,
                                              data_shards=MESH_DATA))
    opt = OWLQNPlus(make_sharded_sparse_loss(sb, mesh), lam=0.5, beta=0.5)
    st = shard_state(opt.init(part.pad_rows(theta0)), mesh)
    step = make_distributed_step(opt, mesh)
    fs = []
    for _ in range(steps):
        st, stats = step(st)
        fs.append(float(stats.f_new))
    shard_shapes = {s.data.shape for s in st.theta.addressable_shards}
    assert shard_shapes == {(d // MESH_MODEL, 2 * m)}, shard_shapes
    return np.asarray(part.unpad_rows(jax.device_get(st.theta))), fs

t1, f1 = run_single(6)
t2, f2 = run_sharded(6)
np.testing.assert_allclose(f1, f2, rtol=2e-4)
np.testing.assert_allclose(t1, t2, rtol=2e-3, atol=2e-5)
# sparsity pattern must agree exactly (orthant logic is sign-exact)
np.testing.assert_array_equal(t1 == 0.0, t2 == 0.0)
# rows never touched by an id stayed at EXACT zero through the sharded
# steps (their grad is identically zero, so Eq. 9 leaves them alone)
assert np.all(t2[~seen] == 0.0), int((t2[~seen] != 0).sum())
print("SHARD-STEP-OK")
"""


@pytest.mark.slow
@pytest.mark.parametrize("mesh_data,mesh_model", [(2, 4), (4, 2)])
def test_sharded_sparse_matches_single_device(mesh_data, mesh_model):
    env = os.environ.copy()
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT % (mesh_data, mesh_model)],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)), timeout=600,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "SHARD-STEP-OK" in r.stdout
