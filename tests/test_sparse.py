"""Sparse feature substrate: exactness vs dense, training at 1M columns."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CTRBatch
from repro.core.objective import nll
from repro.data.sparse import (
    generate_sparse,
    sparse_loss_and_grad,
    sparse_nll,
    sparse_predict,
    to_dense,
)
from repro.optim import OWLQNPlus


def _small_batch(d=500, sessions=16):
    return generate_sparse(num_features=d,
                           num_user_features_range=(300, d),
                           sessions=sessions, seed=0)


def test_sparse_nll_equals_dense_nll():
    b = _small_batch()
    d, m = b.num_features, 4
    theta = jnp.asarray(
        np.random.default_rng(0).normal(size=(d, 2 * m)) * 0.2, jnp.float32)
    x = to_dense(b)
    dense_val = nll(theta, CTRBatch(x=jnp.asarray(x), y=b.y))
    sparse_val = sparse_nll(theta, b)
    np.testing.assert_allclose(float(sparse_val), float(dense_val), rtol=1e-5)


def test_sparse_grad_touches_only_active_rows():
    b = _small_batch()
    d, m = b.num_features, 4
    theta = jnp.zeros((d, 2 * m), jnp.float32) + 0.01
    _, g = sparse_loss_and_grad(theta, b)
    active = set(np.asarray(b.user_ids).ravel().tolist()) | \
        set(np.asarray(b.ad_ids).ravel().tolist())
    active.discard(d)
    g_np = np.asarray(g)
    inactive = np.setdiff1d(np.arange(d), np.asarray(sorted(active)))
    assert np.abs(g_np[inactive]).max() == 0.0
    assert np.abs(g_np[np.asarray(sorted(active))]).max() > 0.0


@pytest.mark.slow
def test_lsplm_trains_on_million_column_sparse_features():
    """The production regime the dense path cannot touch: 1M columns.
    Theta is (1e6, 8) = 8M params; a dense x would be 2M x 1M = 8 TB."""
    b = generate_sparse(num_features=1_000_000, sessions=256, seed=1)
    b_test = generate_sparse(num_features=1_000_000, sessions=64, seed=2)
    d, m = b.num_features, 4
    theta0 = jnp.asarray(
        0.01 * np.random.default_rng(0).normal(size=(d, 2 * m)), jnp.float32)
    opt = OWLQNPlus(lambda t: sparse_loss_and_grad(t, b), lam=0.1, beta=0.1)
    theta, trace = opt.run(theta0, max_iters=15)
    assert float(trace[-1].f_new) < float(trace[0].f)
    p = np.asarray(sparse_predict(theta, b_test))
    assert np.all(np.isfinite(p)) and (0 <= p).all() and (p <= 1).all()
    # sparsity: only rows seen in training can be non-zero
    nnz_rows = int((np.abs(np.asarray(theta)).sum(1) > 0).sum())
    active = len(set(np.asarray(b.user_ids).ravel().tolist())
                 | set(np.asarray(b.ad_ids).ravel().tolist()) - {d})
    assert nnz_rows <= active
