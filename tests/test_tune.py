"""Autotune table: envelope bucketing edges, JSON round-trip, fallback
chain (explicit kwarg > overrides > table entry > builtin defaults),
override validation, and call-site resolution on the fused ops."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.lsplm_sparse_fused.ops import _resolve_fused
from repro.tune import (
    BUILTIN_DEFAULTS,
    E_BUCKETS,
    K_BUCKETS,
    N_BUCKETS,
    AutotuneTable,
    backend_key,
    clear_overrides,
    fused_envelope,
    get_overrides,
    resolve,
    round_up,
    scatter_envelope,
    set_active_table,
    set_overrides,
)


@pytest.fixture(autouse=True)
def _isolated_table():
    """Every test runs against an explicit table and no overrides; the
    lazy committed-file load is re-armed on exit."""
    set_active_table(AutotuneTable())
    clear_overrides()
    yield
    set_active_table(None)
    clear_overrides()


def _table(kernel, envelope, config, backend=None):
    t = AutotuneTable()
    t.put(backend or backend_key(), kernel, envelope, config)
    set_active_table(t)
    return t


# ------------------------------------------------------------- bucketing
def test_round_up_picks_smallest_edge_at_or_above():
    assert round_up(1, N_BUCKETS) == 256          # below the first edge
    assert round_up(256, N_BUCKETS) == 256        # exactly on an edge
    assert round_up(257, N_BUCKETS) == 512        # just past an edge
    assert round_up(65536, N_BUCKETS) == 65536    # exactly the top edge


def test_round_up_past_top_edge_rounds_to_multiples_of_it():
    top = N_BUCKETS[-1]
    assert round_up(top + 1, N_BUCKETS) == 2 * top
    assert round_up(2 * top, N_BUCKETS) == 2 * top
    assert round_up(2 * top + 1, N_BUCKETS) == 3 * top


def test_round_up_rejects_non_positive():
    with pytest.raises(ValueError):
        round_up(0, N_BUCKETS)
    with pytest.raises(ValueError):
        round_up(-4, K_BUCKETS)


def test_envelopes_bucket_every_dimension():
    assert fused_envelope(4096, 16, 24) == "n4096_k16_m24"
    assert fused_envelope(4000, 13, 17) == "n4096_k16_m24"   # rounds up
    # d-free by construction: no theta row count in the key
    assert scatter_envelope(60_000, 8) == "e65536_m8"
    assert scatter_envelope(0, 8) == f"e{E_BUCKETS[0]}_m8"   # empty plan
    assert scatter_envelope(E_BUCKETS[-1] + 1, 8) == f"e{2 * E_BUCKETS[-1]}_m8"


def test_backend_key_interpret_is_its_own_backend():
    assert backend_key("interpret") == "interpret"
    assert backend_key() != "interpret"


# ---------------------------------------------------------- JSON round-trip
def test_table_json_round_trip_preserves_entries_and_meta():
    t = AutotuneTable()
    t.put("cpu", "chunk_fwd", "n4096_k16_m24", {"chunk": 16})
    t.put("cpu", "fused_fwd", "n512_k8_m8", {"block_n": 64, "block_k": 4})
    t.meta["cpu"] = {"generator": "test", "reps": 3}
    back = AutotuneTable()
    assert back.merge_json(t.to_json("cpu")) == "cpu"
    assert back.entries("cpu") == t.entries("cpu")
    assert back.meta["cpu"] == t.meta["cpu"]
    # and the get() view agrees
    assert back.get("cpu", "fused_fwd", "n512_k8_m8") == {
        "block_n": 64, "block_k": 4}


def test_table_rejects_wrong_version_and_bad_configs():
    with pytest.raises(ValueError):
        AutotuneTable().merge_json('{"version": 99, "backend": "cpu"}')
    t = AutotuneTable()
    with pytest.raises(ValueError):
        t.put("cpu", "warp_drive", "n512_k8_m8", {"chunk": 8})
    with pytest.raises(ValueError):   # wrong key set for the kernel
        t.put("cpu", "fused_fwd", "n512_k8_m8", {"block_n": 64})
    with pytest.raises(ValueError):   # non-positive
        t.put("cpu", "chunk_fwd", "n512_k8_m8", {"chunk": 0})
    with pytest.raises(ValueError):   # bool is not an int here
        t.put("cpu", "chunk_fwd", "n512_k8_m8", {"chunk": True})


def test_table_save_load_dir(tmp_path):
    t = AutotuneTable()
    t.put("cpu", "chunk_bwd", "n4096_k16_m24", {"chunk": 4})
    t.put("interpret", "scatter", "e4096_m8", {"block_e": 256})
    t.save(tmp_path / "cpu.json", "cpu")
    t.save(tmp_path / "interpret.json", "interpret")
    back = AutotuneTable.load_dir(tmp_path)
    assert back.backends() == ("cpu", "interpret")
    assert back.get("cpu", "chunk_bwd", "n4096_k16_m24") == {"chunk": 4}
    assert back.get("interpret", "scatter", "e4096_m8") == {"block_e": 256}


# --------------------------------------------------------- resolution chain
def test_resolve_falls_back_to_builtin_defaults():
    # empty table (fixture) and an envelope nobody swept
    for kernel in BUILTIN_DEFAULTS:
        assert resolve(kernel, "n256_k4_m4") == BUILTIN_DEFAULTS[kernel]


def test_resolve_ignores_entries_from_other_backends():
    # a tpu-only table must not leak onto this (cpu) backend
    _table("chunk_fwd", "n4096_k16_m24", {"chunk": 64}, backend="tpu")
    assert resolve("chunk_fwd", "n4096_k16_m24") == BUILTIN_DEFAULTS["chunk_fwd"]


def test_resolve_prefers_table_entry_over_default():
    _table("chunk_fwd", "n4096_k16_m24", {"chunk": 16})
    assert resolve("chunk_fwd", "n4096_k16_m24") == {"chunk": 16}
    # unswept envelope on the same backend still defaults
    assert resolve("chunk_fwd", "n256_k4_m4") == BUILTIN_DEFAULTS["chunk_fwd"]


def test_overrides_beat_the_table():
    _table("chunk_fwd", "n4096_k16_m24", {"chunk": 16})
    set_overrides(chunk=4)
    assert resolve("chunk_fwd", "n4096_k16_m24") == {"chunk": 4}
    assert resolve("chunk_bwd", "n4096_k16_m24") == {"chunk": 4}  # both scans
    set_overrides(chunk=None)  # None clears
    assert get_overrides() == {}
    assert resolve("chunk_fwd", "n4096_k16_m24") == {"chunk": 16}


def test_set_overrides_validates_loudly():
    with pytest.raises(ValueError):
        set_overrides(block_q=7)          # unknown knob
    with pytest.raises(ValueError):
        set_overrides(chunk=0)            # not positive
    with pytest.raises(ValueError):
        set_overrides(block_n=True)       # bool sneaking in as int
    assert get_overrides() == {}          # nothing half-applied


def test_resolve_rejects_unknown_kernel():
    with pytest.raises(ValueError):
        resolve("warp_drive", "n256_k4_m4")


# ------------------------------------------------- call-site resolution
def test_explicit_kwarg_beats_table_at_the_call_site():
    ids = jnp.zeros((4096, 16), jnp.int32)
    theta = jnp.zeros((100, 24), jnp.float32)
    t = AutotuneTable()
    t.put(backend_key(), "fused_fwd", "n4096_k16_m24",
          {"block_n": 64, "block_k": 4})
    t.put(backend_key(), "chunk_fwd", "n4096_k16_m24", {"chunk": 16})
    t.put(backend_key(), "chunk_bwd", "n4096_k16_m24", {"chunk": 4})
    set_active_table(t)
    # None knobs pull the table entries (chunk as a (fwd, bwd) pair)
    bn, bk, chunk = _resolve_fused(ids, theta, "auto", None, None, None)
    assert (bn, bk) == (64, 4)
    assert chunk == (16, 4)
    # explicit kwargs win over all of it, including per-knob mixes
    bn, bk, chunk = _resolve_fused(ids, theta, "auto", 512, 2, 32)
    assert (bn, bk, chunk) == (512, 2, (32, 32))
    bn, bk, _ = _resolve_fused(ids, theta, "auto", 512, None, None)
    assert (bn, bk) == (512, 4)           # table still fills the other knob
    # explicit also beats overrides
    set_overrides(chunk=8)
    _, _, chunk = _resolve_fused(ids, theta, "auto", None, None, 32)
    assert chunk == (32, 32)
    _, _, chunk = _resolve_fused(ids, theta, "auto", None, None, None)
    assert chunk == (8, 8)


def test_resolved_configs_do_not_change_results():
    """The table only picks block sizes — same math either way."""
    from repro.kernels.lsplm_sparse_fused.ops import (
        pad_theta,
        sparse_gather_matmul,
    )
    from repro.kernels.lsplm_sparse_fused.ref import sparse_matmul_ref

    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, 64, (32, 6)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(32, 6)).astype(np.float32))
    tp = pad_theta(jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32)))
    env = fused_envelope(32, 6, 8)
    _table("chunk_fwd", env, {"chunk": 2})
    z_tab = sparse_gather_matmul(ids, vals, tp)          # table chunk=2
    z_exp = sparse_gather_matmul(ids, vals, tp, chunk=6)  # explicit
    z_ref = sparse_matmul_ref(ids, vals, tp)
    np.testing.assert_allclose(np.asarray(z_tab), np.asarray(z_ref),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(z_exp), np.asarray(z_ref),
                               rtol=1e-5, atol=1e-6)
