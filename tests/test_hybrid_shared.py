"""Hybrid (zamba-style) specifics: the shared transformer block is ONE
set of weights applied every k layers; sliding-window decode wraps
correctly past the window boundary."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_step, forward, init_caches, init_model


@pytest.mark.slow
def test_shared_block_gradient_accumulates_across_groups():
    """If the shared block were per-group copies, its grad tree would have
    a leading J axis; being shared, grads accumulate into ONE param set
    and perturbing it changes all groups' outputs."""
    cfg = get_config("zamba2-2.7b").reduced()
    assert cfg.num_layers // cfg.shared_attn_every == 1  # reduced: 1 group
    cfg = dataclasses.replace(cfg, num_layers=4)  # 2 groups
    params = init_model(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)

    def loss(p):
        logits, _ = forward(p, cfg, tokens=toks, remat=False)
        return jnp.sum(logits.astype(jnp.float32) ** 2)

    g = jax.grad(loss)(params)
    wq_g = g["shared"]["attn"]["wq"]
    assert wq_g.shape == params["shared"]["attn"]["wq"].shape  # no J axis
    assert float(jnp.abs(wq_g).max()) > 0

    # ablate: zeroing the shared block changes outputs of BOTH groups
    p2 = jax.tree.map(jnp.copy, params)
    p2["shared"]["attn"]["wq"] = jnp.zeros_like(p2["shared"]["attn"]["wq"])
    l1, _ = forward(params, cfg, tokens=toks, remat=False)
    l2, _ = forward(p2, cfg, tokens=toks, remat=False)
    assert float(jnp.abs(l1 - l2).max()) > 1e-3


@pytest.mark.slow
def test_sliding_window_wraps_and_is_shift_invariant_single_layer():
    """Ring buffer wraps correctly far past the window. With ONE layer the
    logits depend only on the last W tokens (exact shift invariance); with
    stacked layers the receptive field grows beyond W through cached keys
    (by design), so the multi-layer check is finiteness + wrap behaviour.
    """
    cfg = dataclasses.replace(get_config("llama3.2-1b").reduced(),
                              num_layers=1)
    params = init_model(cfg, jax.random.PRNGKey(0))
    B, W = 1, 8
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, 24), jnp.int32)

    def run(seq, offset=0):
        caches = init_caches(cfg, B, W)
        out = None
        for t, tok in enumerate(np.asarray(seq)):
            out, caches = decode_step(
                params, cfg, caches, token=jnp.asarray([tok]),
                pos=jnp.asarray(t + offset), window=True)
        return np.asarray(out, np.float32)

    full = run(toks)  # wraps the ring buffer twice
    assert np.all(np.isfinite(full))
    # feeding ONLY the last W tokens with matching absolute positions must
    # reproduce the logits exactly (1 layer => window == receptive field)
    tail = run(toks[-W:], offset=len(toks) - W)
    np.testing.assert_allclose(tail, full, rtol=2e-2, atol=2e-2)


@pytest.mark.slow
def test_sliding_window_multilayer_finite_past_wrap():
    cfg = get_config("llama3.2-1b").reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    caches = init_caches(cfg, 1, 8)
    rng = np.random.default_rng(1)
    out = None
    for t in range(20):
        out, caches = decode_step(
            params, cfg, caches,
            token=jnp.asarray([rng.integers(0, cfg.vocab_size)]),
            pos=jnp.asarray(t), window=True)
    assert np.all(np.isfinite(np.asarray(out, np.float32)))
