"""Per-architecture smoke tests: REDUCED variant (2 layers, d_model<=256,
<=4 experts) of each assigned config runs one forward + one train step +
one decode step on CPU; asserts shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import (
    decode_step,
    forward,
    init_caches,
    init_model,
    make_train_step,
    prefill,
)

ARCHS = list_archs()


def _toy_batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.embeds_in:
        batch["embeds"] = 0.1 * jax.random.normal(ks[0], (B, S, cfg.d_model),
                                                  jnp.float32)
        batch["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
        return batch, S
    if cfg.num_prefix_embeds:
        P = cfg.num_prefix_embeds
        batch["prefix_embeds"] = 0.1 * jax.random.normal(
            ks[0], (B, P, cfg.d_model), jnp.float32)
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
        batch["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size)
        return batch, S + P
    batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    return batch, S


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers == 2 and cfg.d_model <= 256
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch, S_tot = _toy_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = forward(
        params, cfg,
        tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        prefix_embeds=batch.get("prefix_embeds"), remat=False,
    )
    assert logits.shape == (2, S_tot, cfg.vocab_size), logits.shape
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch, _ = _toy_batch(cfg, jax.random.PRNGKey(1))
    opt, train_step = make_train_step(cfg, lr=1e-3)
    opt_state = opt.init(params)
    step = jax.jit(train_step)
    losses = []
    for _ in range(3):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses), losses
    assert losses[-1] < losses[0], losses  # same batch -> must descend


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_runs(arch):
    cfg = get_config(arch).reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    B, S_cache = 2, 32
    caches = init_caches(cfg, B, S_cache)
    if cfg.embeds_in:
        kw = {"embed": 0.1 * jax.random.normal(jax.random.PRNGKey(2),
                                               (B, cfg.d_model), jnp.float32)}
    else:
        kw = {"token": jnp.array([1, 2], jnp.int32)}
    logits, caches = jax.jit(
        lambda c, pos, **k: decode_step(params, cfg, c, pos=pos, **k)
    )(caches, jnp.asarray(0, jnp.int32), **kw)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_consistent_with_forward(arch):
    """prefill(S tokens) + decode(token S) logits == forward(S+1 tokens)
    last-position logits (the fundamental serving invariant)."""
    cfg = get_config(arch).reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    B, S = 2, 8
    key = jax.random.PRNGKey(3)
    if cfg.embeds_in:
        emb = 0.1 * jax.random.normal(key, (B, S + 1, cfg.d_model), jnp.float32)
        full_kw = dict(embeds=emb)
        pre_kw = dict(embeds=emb[:, :S])
        dec_kw = dict(embed=emb[:, S])
        S_tot = S + 1
    elif cfg.num_prefix_embeds:
        P = cfg.num_prefix_embeds
        pe = 0.1 * jax.random.normal(key, (B, P, cfg.d_model), jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(4), (B, S + 1), 0, cfg.vocab_size)
        full_kw = dict(prefix_embeds=pe, tokens=toks)
        pre_kw = dict(prefix_embeds=pe, tokens=toks[:, :S])
        dec_kw = dict(token=toks[:, S])
        S_tot = P + S + 1
    else:
        toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
        full_kw = dict(tokens=toks)
        pre_kw = dict(tokens=toks[:, :S])
        dec_kw = dict(token=toks[:, S])
        S_tot = S + 1

    logits_full, _ = forward(params, cfg, remat=False, **full_kw)
    _, caches0 = prefill(params, cfg, **pre_kw)
    # grow cache to S_tot slots
    caches = init_caches(cfg, B, S_tot)
    caches = jax.tree.map(
        lambda big, small: jax.lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), 0, axis=2),
        caches, caches0,
    )
    logits_dec, _ = decode_step(params, cfg, caches,
                                pos=jnp.asarray(S_tot - 1, jnp.int32), **dec_kw)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full[:, -1], np.float32),
        rtol=0.05, atol=0.05,
    )
