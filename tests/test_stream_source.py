"""DayStream invariants: determinism, shapes, window concatenation, and
the drift structure the streaming NLL gate relies on (adjacent days
share id traffic, distant days do not)."""
import numpy as np
import pytest

from repro.stream import DayStream, concat_batches

STREAM_KW = dict(sessions_per_day=24, num_features=3000, active_user=8,
                 active_ad=5, seed=7)


def _stream(days=5, **over):
    kw = {**STREAM_KW, **over}
    return DayStream(days, **kw)


def test_day_shapes_and_determinism():
    s = _stream()
    b = s.day(2)
    G, A = s.sessions_per_day, s.ads_per_session
    assert b.user_ids.shape == (G, s.active_user)
    assert b.ad_ids.shape == (G * A, s.active_ad)
    assert b.session_id.shape == b.y.shape == (G * A,)
    assert b.num_features == s.num_features
    assert b.user_plan is None and b.ad_plan is None
    assert set(np.unique(np.asarray(b.y))) <= {0.0, 1.0}
    # ids in their segments
    uid, aid = np.asarray(b.user_ids), np.asarray(b.ad_ids)
    assert uid.min() >= s.user_lo and uid.max() < s.num_features
    assert aid.min() >= 0 and aid.max() < s.user_lo
    # same (seed, day) -> bit-identical batch; different day differs
    s2 = _stream()
    np.testing.assert_array_equal(np.asarray(s2.day(2).user_ids), uid)
    np.testing.assert_array_equal(np.asarray(s2.day(2).y), np.asarray(b.y))
    assert not np.array_equal(np.asarray(s.day(3).user_ids), uid)


def test_window_concatenates_days_in_order():
    s = _stream()
    w = s.window(3, 2)  # days 2 and 3
    G, A = s.sessions_per_day, s.ads_per_session
    assert w.user_ids.shape[0] == 2 * G
    assert w.ad_ids.shape[0] == 2 * G * A
    np.testing.assert_array_equal(
        np.asarray(w.user_ids),
        np.concatenate([np.asarray(s.day(2).user_ids),
                        np.asarray(s.day(3).user_ids)]))
    np.testing.assert_array_equal(
        np.asarray(w.y),
        np.concatenate([np.asarray(s.day(2).y), np.asarray(s.day(3).y)]))
    # sessions stay contiguous ascending (route_batch's requirement)
    sid = np.asarray(w.session_id)
    np.testing.assert_array_equal(np.unique(sid), np.arange(2 * G))
    assert np.all(np.diff(sid) >= 0)
    # early days clamp: window 4 at day 1 = days 0..1
    w01 = s.window(1, 4)
    assert w01.user_ids.shape[0] == 2 * G
    # window 1 is the day itself
    np.testing.assert_array_equal(np.asarray(s.window(2, 1).ad_ids),
                                  np.asarray(s.day(2).ad_ids))


def test_drift_decays_coverage_of_stale_models():
    """Fraction of day t's id traffic already seen on day t-1 must stay
    roughly flat, while coverage by day 0 decays — this is the property
    that makes streaming beat train-once."""
    s = _stream(days=10, drift=0.06, head_width=0.06, head_frac=0.85)
    ids = [np.concatenate([np.asarray(s.day(t).user_ids).reshape(-1),
                           np.asarray(s.day(t).ad_ids).reshape(-1)])
           for t in range(10)]

    def cover(train, test):
        seen = set(train.tolist())
        return np.mean([x in seen for x in test.tolist()])

    adj = np.mean([cover(ids[t - 1], ids[t]) for t in range(1, 10)])
    stale = cover(ids[0], ids[9])
    assert adj > 2 * stale, (adj, stale)


def test_concat_batches_errors_and_identity():
    s = _stream()
    with pytest.raises(ValueError, match="at least one"):
        concat_batches([])
    other = _stream(num_features=4000)
    with pytest.raises(ValueError, match="disagree"):
        concat_batches([s.day(0), other.day(0)])
    one = concat_batches([s.day(1)])
    np.testing.assert_array_equal(np.asarray(one.user_ids),
                                  np.asarray(s.day(1).user_ids))


def test_day_cache_bounded_and_eviction_deterministic():
    s = _stream(days=8, cache_days=3)
    first = np.asarray(s.day(0).user_ids)
    for t in range(8):
        s.day(t)
    assert len(s._cache) <= 3
    assert 0 not in s._cache  # oldest evicted...
    np.testing.assert_array_equal(np.asarray(s.day(0).user_ids), first)


def test_stream_protocol_and_bounds():
    s = _stream(days=3)
    assert len(s) == 3
    assert len(list(iter(s))) == 3
    with pytest.raises(IndexError):
        s.day(3)
    with pytest.raises(IndexError):
        s.day(-1)
    with pytest.raises(ValueError):
        s.window(1, 0)
    with pytest.raises(ValueError):
        DayStream(0)
