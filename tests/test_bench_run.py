"""benchmarks/run.py CLI edges: an unknown --only name must error with
the list of valid modules (not silently run nothing — CI would archive
an empty artifact and stay green), and --json must write the per-module
trajectory file even when a benchmark gate raises (partial data + the
error traceback), because the CI regression gate diffs that file."""
import json
import sys
from types import SimpleNamespace

import pytest

import benchmarks.run as bench_run


def _fake_mods(*names):
    return [SimpleNamespace(__name__=f"benchmarks.{n}") for n in names]


# ---------------------------------------------------------------- _select
def test_select_exact_prefixed_and_substring():
    mods = _fake_mods("bench_stream", "bench_serve", "bench_sparse_fused")
    assert bench_run._select(mods, "bench_serve") == [mods[1]]
    assert bench_run._select(mods, "serve") == [mods[1]]  # bench_ implied
    assert bench_run._select(mods, "sparse") == [mods[2]]  # substring
    assert bench_run._select(mods, "stream,serve") == [mods[0], mods[1]]
    assert bench_run._select(mods, "serve,serve") == [mods[1]]  # deduped


def test_select_unknown_name_lists_valid_modules():
    mods = _fake_mods("bench_stream", "bench_serve")
    with pytest.raises(SystemExit) as exc:
        bench_run._select(mods, "sevre")  # the typo CI must catch
    msg = str(exc.value)
    assert "sevre" in msg
    assert "bench_serve" in msg and "bench_stream" in msg


def test_select_unknown_name_among_valid_ones_still_errors():
    mods = _fake_mods("bench_stream", "bench_serve")
    with pytest.raises(SystemExit, match="valid names"):
        bench_run._select(mods, "stream,nope")


# ------------------------------------------------------------------ --json
def test_json_written_even_when_gate_raises(tmp_path, monkeypatch):
    """A failing quality gate still leaves BENCH_serve.json on disk with
    whatever the bench collected before dying, plus the traceback."""
    import benchmarks.bench_serve as bench_serve

    def failing_run(smoke=False, collect=None):
        collect["backend"] = "cpu"
        collect["configs"] = {"tiny": {"shared_speedup": 0.9}}
        raise AssertionError("speedup below target")

    monkeypatch.setattr(bench_serve, "run", failing_run)
    monkeypatch.chdir(tmp_path)
    monkeypatch.setattr(sys, "argv",
                        ["run.py", "--only", "serve", "--json"])
    with pytest.raises(SystemExit) as exc:
        bench_run.main()
    assert exc.value.code == 1  # the failure still fails the step
    data = json.loads((tmp_path / "BENCH_serve.json").read_text())
    assert data["configs"]["tiny"]["shared_speedup"] == 0.9
    assert "speedup below target" in data["error"]


def test_json_written_when_gate_raises_before_collecting(tmp_path,
                                                         monkeypatch):
    """Even a bench that dies before binding anything leaves a JSON with
    the error, so the archived artifact explains itself."""
    import benchmarks.bench_stream as bench_stream

    def dead_on_arrival(smoke=False, collect=None):
        raise RuntimeError("import-time shape bug")

    monkeypatch.setattr(bench_stream, "run", dead_on_arrival)
    monkeypatch.chdir(tmp_path)
    monkeypatch.setattr(sys, "argv",
                        ["run.py", "--only", "stream", "--json"])
    with pytest.raises(SystemExit):
        bench_run.main()
    data = json.loads((tmp_path / "BENCH_stream.json").read_text())
    assert sorted(data) == ["error", "meta"]  # provenance even on error
    assert "import-time shape bug" in data["error"]


def test_json_written_on_success(tmp_path, monkeypatch):
    import benchmarks.bench_stream as bench_stream

    def ok_run(smoke=False, collect=None):
        collect["steps_per_sec"] = 42.0

    monkeypatch.setattr(bench_stream, "run", ok_run)
    monkeypatch.chdir(tmp_path)
    monkeypatch.setattr(sys, "argv",
                        ["run.py", "--only", "stream", "--json", "--smoke"])
    bench_run.main()  # no SystemExit
    data = json.loads((tmp_path / "BENCH_stream.json").read_text())
    assert data["steps_per_sec"] == 42.0
    # every artifact self-describes: git rev, backend, device/cpu
    # counts, module wall — info-only for the regression gate
    meta = data["meta"]
    assert set(meta) == {"git_rev", "backend", "device_count",
                         "cpu_count", "wall_seconds"}
    assert meta["wall_seconds"] >= 0.0
