"""Id-range partitioner + host-side routing (repro.shard.partition).

Routing must be a lossless re-arrangement: every (id, val) entry lands on
exactly one shard with a re-based id, order within a sample preserved,
pads dropped — so the sum of shard-local gather-matmuls equals the global
one, which is the invariant the sharded step's single psum relies on.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.data.sparse import generate_sparse
from repro.shard.partition import (
    Partition,
    balanced_partition,
    make_partition,
    route_batch,
    route_ids,
    shard_slot_width,
)


def _zipf_ids(rng, n, k, d, power=8.0):
    return (d * (rng.random((n, k)) ** power)).astype(np.int64)


# ------------------------------------------------------------- partitions
def test_make_partition_equal_and_remainder():
    p = make_partition(100, 4)
    assert p.ranges() == [(0, 25), (25, 50), (50, 75), (75, 100)]
    assert p.is_uniform and p.rows_per_shard == 25
    q = make_partition(10, 3)
    assert q.sizes.tolist() == [4, 3, 3]
    assert not q.is_uniform and q.rows_per_shard == 4
    assert q.num_rows == 10 and q.num_shards == 3


def test_partition_validation():
    with pytest.raises(ValueError):
        Partition([1, 5, 10])  # must start at 0
    with pytest.raises(ValueError):
        Partition([0, 7, 5])  # decreasing
    with pytest.raises(ValueError):
        make_partition(3, 4)  # more shards than rows


def test_shard_of_edges():
    p = Partition([0, 3, 3, 10])  # middle shard empty
    ids = np.array([0, 2, 3, 9, 10, 11])
    np.testing.assert_array_equal(p.shard_of(ids), [0, 0, 2, 2, 3, 3])
    assert p.sizes.tolist() == [3, 0, 7]


def test_balanced_partition_flattens_zipf_head():
    rng = np.random.default_rng(0)
    d, S = 10_000, 8
    ids = _zipf_ids(rng, 512, 24, d, power=4.0)
    part = balanced_partition(d, S, ids)
    counts = np.bincount(part.shard_of(ids.reshape(-1)), minlength=S)
    mean = counts.mean()
    # quantile cuts keep every shard within ~2x of the mean...
    assert counts.max() <= 2.0 * mean, counts
    # ...whereas equal ranges drown shard 0 under the hot head
    eq = np.bincount(make_partition(d, S).shard_of(ids.reshape(-1)),
                     minlength=S)
    assert eq.max() > 4.0 * mean, eq
    assert part.num_rows == d and part.num_shards == S


def test_balanced_partition_no_signal_falls_back_equal():
    part = balanced_partition(100, 4, np.full((4, 3), 100), pad_id=100)
    assert part == make_partition(100, 4)


def test_pad_unpad_roundtrip():
    rng = np.random.default_rng(1)
    theta = jnp.asarray(rng.normal(size=(10, 4)).astype(np.float32))
    part = Partition([0, 1, 5, 10])  # sizes 1, 4, 5 -> rows_per_shard 5
    padded = part.pad_rows(theta)
    assert padded.shape == (15, 4)
    np.testing.assert_array_equal(np.asarray(part.unpad_rows(padded)),
                                  np.asarray(theta))
    # shard s's rows live at [s*R, s*R+size): pad rows are zero
    pn = np.asarray(padded)
    assert np.all(pn[1:5] == 0) and np.all(pn[9:10] == 0)
    np.testing.assert_array_equal(pn[0:1], np.asarray(theta)[0:1])
    np.testing.assert_array_equal(pn[5:9], np.asarray(theta)[1:5])
    np.testing.assert_array_equal(pn[10:15], np.asarray(theta)[5:10])
    # uniform partitions pad as the identity
    u = make_partition(10, 2)
    assert u.pad_rows(theta) is theta


# --------------------------------------------------------------- routing
@pytest.mark.parametrize("seed,zipf", [(0, False), (1, True), (2, True)])
def test_route_ids_lossless(seed, zipf):
    rng = np.random.default_rng(seed)
    N, K, d, S = 32, 9, 500, 4
    ids = _zipf_ids(rng, N, K, d) if zipf else rng.integers(0, d, (N, K))
    ids[rng.random((N, K)) < 0.25] = d  # pad entries
    vals = rng.normal(size=(N, K)).astype(np.float32)
    vals[ids == d] = 0.0
    part = make_partition(d, S)
    ids_r, vals_r, Ks = route_ids(part, ids, vals, pad_id=d)
    assert ids_r.shape == (S, N, Ks) == vals_r.shape
    assert Ks == shard_slot_width(part, ids, pad_id=d)

    R = part.rows_per_shard
    for n in range(N):
        want = sorted((int(i), float(v)) for i, v in zip(ids[n], vals[n])
                      if i != d)
        got = []
        for s in range(S):
            keep = ids_r[s, n] != R
            # local ids are in the shard's range, re-based
            assert np.all(ids_r[s, n][keep] < part.sizes[s])
            got += [(int(i) + int(part.bounds[s]), float(v))
                    for i, v in zip(ids_r[s, n][keep], vals_r[s, n][keep])]
            # pad slots carry zero values
            assert np.all(vals_r[s, n][~keep] == 0.0)
        assert sorted(got) == want


def test_route_ids_preserves_sample_order_and_k_multiple():
    part = make_partition(100, 2)
    ids = np.array([[70, 3, 60, 5, 50]])
    vals = np.arange(5, dtype=np.float32)[None] + 1
    ids_r, vals_r, Ks = route_ids(part, ids, vals, pad_id=100, k_multiple=4)
    assert Ks == 4  # 3 entries on shard 1, rounded up to the multiple
    np.testing.assert_array_equal(ids_r[0, 0], [3, 5, 50, 50])
    np.testing.assert_array_equal(vals_r[0, 0], [2, 4, 0, 0])
    np.testing.assert_array_equal(ids_r[1, 0], [20, 10, 0, 50])
    np.testing.assert_array_equal(vals_r[1, 0], [1, 3, 5, 0])


def test_route_ids_rejects_out_of_range_and_small_k():
    part = make_partition(10, 2)
    with pytest.raises(ValueError, match="outside partition"):
        route_ids(part, np.array([[11]]), np.ones((1, 1), np.float32),
                  pad_id=99)
    with pytest.raises(ValueError, match="too small"):
        route_ids(part, np.array([[1, 2, 3]]), np.ones((1, 3), np.float32),
                  pad_id=10, shard_k=2)


def test_route_batch_z_parity_and_session_rebase():
    """Sum of shard-local gather-matmuls == the global one (the psum
    invariant), sessions re-based per data block."""
    d, Dd = 300, 2
    batch = generate_sparse(num_features=d, num_user_features_range=(180, d),
                            sessions=16, ads_per_session=3, active_user=6,
                            active_ad=4, seed=5)
    part = balanced_partition(
        d, 3, np.asarray(batch.user_ids), np.asarray(batch.ad_ids), pad_id=d)
    sb = route_batch(batch, part, data_shards=Dd)
    assert sb.num_shards == 3 and sb.data_shards == Dd
    assert sb.partition == part

    rng = np.random.default_rng(0)
    theta = rng.normal(size=(d, 4)).astype(np.float32)
    R = part.rows_per_shard

    def z_of(ids, vals):  # global padded-COO matmul, numpy
        tp = np.concatenate([theta, np.zeros((1, 4), np.float32)])
        return np.einsum("nk,nkm->nm", vals, tp[ids])

    for glob_ids, glob_vals, loc_ids, loc_vals in (
            (batch.ad_ids, batch.ad_vals, sb.ad_ids, sb.ad_vals),
            (batch.user_ids, batch.user_vals, sb.user_ids, sb.user_vals)):
        want = z_of(np.asarray(glob_ids), np.asarray(glob_vals))
        got = np.zeros_like(want)
        for s, (lo, hi) in enumerate(part.ranges()):
            tp_l = np.concatenate([theta[lo:hi],
                                   np.zeros((R - (hi - lo) + 1, 4),
                                            np.float32)])
            got += np.einsum("nk,nkm->nm", np.asarray(loc_vals)[s],
                             tp_l[np.asarray(loc_ids)[s]])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    G_l = 16 // Dd
    sid = np.asarray(batch.session_id)
    np.testing.assert_array_equal(np.asarray(sb.session_id), sid % G_l)
    # plans rode along, stacked over (data blocks, shards)
    assert sb.ad_plan.row_ids.shape[:2] == (Dd, 3)
    assert sb.user_plan.row_ids.shape[:2] == (Dd, 3)


def test_route_batch_divisibility_errors():
    batch = generate_sparse(num_features=100,
                            num_user_features_range=(60, 100), sessions=6,
                            ads_per_session=2, active_user=3, active_ad=2,
                            seed=0, with_plans=False)
    with pytest.raises(ValueError, match="divide"):
        route_batch(batch, make_partition(100, 2), data_shards=4)
    with pytest.raises(ValueError, match="partition covers"):
        route_batch(batch, make_partition(99, 3))
