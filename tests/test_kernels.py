"""Pallas kernel tests: interpret-mode execution vs pure-jnp oracles,
sweeping shapes/dtypes (+ hypothesis property sweeps).

hypothesis is an OPTIONAL test dependency (declared in requirements-dev
/ pyproject [dev]): without it the property sweeps skip and every other
kernel test still runs, so a bare checkout collects cleanly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.lsplm_fused.lsplm_fused import lsplm_fused_forward
from repro.kernels.lsplm_fused.ref import lsplm_forward_ref
from repro.kernels.mamba_scan.mamba_scan import mamba1_scan
from repro.kernels.mamba_scan.ref import mamba1_scan_ref
from repro.kernels.owlqn_direction.owlqn_direction import owlqn_direction
from repro.kernels.owlqn_direction.ref import owlqn_direction_ref


# ------------------------------------------------------------- lsplm_fused
@pytest.mark.parametrize("B,d,m,bb,bd", [
    (64, 128, 12, 32, 64),
    (128, 256, 4, 128, 256),  # single tile in d
    (32, 512, 1, 32, 128),  # m=1 (LR special case)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lsplm_fused_vs_ref(B, d, m, bb, bd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = (0.3 * jax.random.normal(ks[0], (B, d))).astype(dtype)
    u = (0.1 * jax.random.normal(ks[1], (d, m))).astype(dtype)
    w = (0.1 * jax.random.normal(ks[2], (d, m))).astype(dtype)
    out = lsplm_fused_forward(x, u, w, block_b=bb, block_d=bd, interpret=True)
    ref = lsplm_forward_ref(x, u, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("B,d", [(50, 100), (1, 7), (33, 130), (257, 513)])
def test_lsplm_fused_ragged_shapes(B, d):
    """Ragged B/d (real loaders' tail batches) must pad, not crash."""
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    x = 0.3 * jax.random.normal(ks[0], (B, d))
    u = 0.1 * jax.random.normal(ks[1], (d, 5))
    w = 0.1 * jax.random.normal(ks[2], (d, 5))
    out = lsplm_fused_forward(x, u, w, block_b=32, block_d=64, interpret=True)
    ref = lsplm_forward_ref(x, u, w)
    assert out.shape == (B,)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_lsplm_fused_rejects_bad_blocks():
    x = jnp.ones((8, 8))
    u = w = jnp.ones((8, 2))
    with pytest.raises(ValueError):
        lsplm_fused_forward(x, u, w, block_b=0, interpret=True)


def test_lsplm_fused_probability_range():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    x = 2.0 * jax.random.normal(ks[0], (64, 64))
    u = jax.random.normal(ks[1], (64, 8))
    w = jax.random.normal(ks[2], (64, 8))
    out = np.asarray(lsplm_fused_forward(x, u, w, block_b=32, block_d=32,
                                         interpret=True))
    assert np.all(out >= 0.0) and np.all(out <= 1.0)


# --------------------------------------------------------- owlqn_direction
@pytest.mark.parametrize("d,m2,br", [(64, 8, 16), (128, 24, 128), (32, 2, 32)])
@pytest.mark.parametrize("lam,beta", [(0.0, 0.0), (1.0, 1.0), (0.5, 0.0), (0.0, 0.7)])
def test_owlqn_direction_vs_ref(d, m2, br, lam, beta):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    theta = jax.random.normal(ks[0], (d, m2))
    theta = theta * jax.random.bernoulli(ks[1], 0.6, theta.shape)  # exact 0s
    theta = theta.at[0].set(0.0)  # a whole zero row (case c)
    grad = jax.random.normal(ks[2], (d, m2))
    out = owlqn_direction(theta, grad, lam, beta, block_rows=br, interpret=True)
    ref = owlqn_direction_ref(theta, grad, lam, beta)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(
        d_tiles=st.integers(1, 4),
        m=st.integers(1, 6),
        lam=st.floats(0.0, 2.0),
        beta=st.floats(0.0, 2.0),
        seed=st.integers(0, 2**31 - 1),
        sparsity=st.floats(0.0, 1.0),
    )
    def test_owlqn_direction_property_sweep(d_tiles, m, lam, beta, seed, sparsity):
        """Kernel == oracle on randomly sparse Theta for arbitrary (lam, beta)."""
        d = 16 * d_tiles
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        theta = jax.random.normal(ks[0], (d, 2 * m))
        theta = theta * jax.random.bernoulli(ks[1], 1.0 - sparsity, theta.shape)
        grad = jax.random.normal(ks[2], (d, 2 * m))
        out = owlqn_direction(theta, grad, lam, beta, block_rows=16, interpret=True)
        ref = owlqn_direction_ref(theta, grad, lam, beta)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)
else:
    @pytest.mark.skip(reason="hypothesis not installed (optional dev dep)")
    def test_owlqn_direction_property_sweep():
        pass


# --------------------------------------------------------- flash_attention
@pytest.mark.parametrize("S,bq,bk", [(32, 8, 8), (64, 16, 32), (64, 64, 64),
                                     (48, 16, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(S, bq, bk, dtype):
    B, H, hd = 2, 3, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, H, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, H, hd)).astype(dtype)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    ref = attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_flash_attention_non_causal():
    B, S, H, hd = 1, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, hd)) for kk in ks)
    out = flash_attention(q, k, v, causal=False, block_q=8, block_k=8,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_model_layer():
    """Kernel agrees with the model's chunked-attention layer (the jnp
    production path it replaces on TPU)."""
    from repro.models.layers import chunked_causal_attention
    B, S, H, hd = 2, 64, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, hd)) for kk in ks)
    out = flash_attention(q, k, v, block_q=16, block_k=16, interpret=True)
    ref = chunked_causal_attention(q, k, v, chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


# --------------------------------------------------------------- mamba_scan
@pytest.mark.parametrize("S,di,N,bd", [(16, 32, 8, 16), (32, 64, 16, 64),
                                       (8, 16, 4, 8)])
def test_mamba_scan_vs_ref(S, di, N, bd):
    B = 2
    ks = jax.random.split(jax.random.PRNGKey(6), 6)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, di)))
    x = jax.random.normal(ks[1], (B, S, di))
    B_in = jax.random.normal(ks[2], (B, S, N))
    C_in = jax.random.normal(ks[3], (B, S, N))
    A = -jnp.exp(jax.random.normal(ks[4], (di, N)) * 0.5)
    D = jax.random.normal(ks[5], (di,))
    y, hT = mamba1_scan(dt, x, B_in, C_in, A, D, block_d=bd, interpret=True)
    y_ref, hT_ref = mamba1_scan_ref(dt, x, B_in, C_in, A, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_ref), rtol=2e-5, atol=2e-5)


def test_mamba_scan_chained_state_equals_full():
    """Scanning [0:S/2) then [S/2:S) with carried h equals one full scan —
    the property the caller uses to split long sequences."""
    B, S, di, N = 1, 32, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, di)))
    x = jax.random.normal(ks[1], (B, S, di))
    B_in = jax.random.normal(ks[2], (B, S, N))
    C_in = jax.random.normal(ks[3], (B, S, N))
    A = -jnp.exp(jax.random.normal(ks[4], (di, N)) * 0.5)
    D = jax.random.normal(ks[5], (di,))
    y_full, h_full = mamba1_scan(dt, x, B_in, C_in, A, D, block_d=16,
                                 interpret=True)
    h = None
    ys = []
    for sl in (slice(0, 16), slice(16, 32)):
        y_p, h = mamba1_scan(dt[:, sl], x[:, sl], B_in[:, sl], C_in[:, sl],
                             A, D, h, block_d=16, interpret=True)
        ys.append(y_p)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, axis=1)),
                               np.asarray(y_full), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full),
                               rtol=2e-5, atol=2e-5)


def test_mamba_scan_matches_model_layer():
    """Kernel reproduces the model's mamba1 SSM inner math."""
    from repro.configs.base import ArchConfig
    from repro.models import ssm as SS
    cfg = ArchConfig(name="t", family="ssm", source="t", num_layers=1,
                     d_model=16, num_heads=0, num_kv_heads=0, d_ff=0,
                     vocab_size=16, ssm_version=1, ssm_state=4, ssm_expand=2,
                     ssm_conv=4)
    p = SS.init_mamba1(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y_model = SS.mamba1_forward(x, p, cfg)

    # re-derive the kernel inputs exactly as the layer does
    di, N, R = cfg.d_inner, cfg.ssm_state, cfg.resolved_dt_rank
    xz = x @ p["in_proj"]
    x_in, z = jnp.split(xz, [di], axis=-1)
    x_conv = jax.nn.silu(SS.causal_conv1d(x_in, p["conv_w"], p["conv_b"]))
    xdb = x_conv @ p["x_proj"]
    dt_in, B_ssm, C_ssm = jnp.split(xdb, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y_k, _ = mamba1_scan(dt, x_conv, B_ssm, C_ssm, A, p["D"], block_d=16,
                         interpret=True)
    y_k = (y_k * jax.nn.silu(z)) @ p["out_proj"]
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_model),
                               rtol=3e-5, atol=3e-5)
