"""Drift detection (``repro.obs.drift``): reference capture, PSI/KL,
the rolling trackers, persistence (standalone + artifact-embedded), and
the end-to-end detector check — the id-traffic PSI must fire on a
replay of :class:`~repro.stream.source.DayStream`'s planted drift and
stay silent on the stationary control at the same thresholds."""
import numpy as np
import pytest

from repro import obs
from repro.obs.drift import _RollingCounts, capture_reference, kl, psi


def _eval_pass(seed=0, n=4000, d=1000, hot=0.8):
    """Synthetic eval pass with a hot-headed id distribution (geometric
    over the first ids, like DayStream's exponential head)."""
    rng = np.random.default_rng(seed)
    p = rng.uniform(0.02, 0.9, n)
    y = (rng.uniform(size=n) < p).astype(np.float64)
    ids = np.minimum(rng.geometric(1 - hot, size=(n, 8)) - 1, d - 1)
    return p, y, ids


# ------------------------------------------------------------ reference
def test_capture_reference_shapes_and_conservation():
    p, y, ids = _eval_pass()
    ref = capture_reference(p, y, ids, num_features=1000, bins=10, top_m=32)
    assert ref.num_bins == 10
    assert ref.score_edges.shape == (11,)
    assert ref.score_counts.sum() == p.size
    assert ref.bucket_p.sum() == pytest.approx(p.sum())
    assert ref.bucket_y.sum() == pytest.approx(y.sum())
    assert ref.top_ids.shape == (32,)
    assert np.all(np.diff(ref.top_ids) > 0)  # sorted, unique
    assert ref.top_counts.shape == (33,)  # +1 tail bucket
    assert ref.top_counts.sum() == ids.size  # every real id counted once
    assert 0.5 < ref.ratio < 2.0
    assert ref.bucket_ratios().shape == (10,)


def test_capture_reference_drops_pad_ids_and_validates():
    p, y, ids = _eval_pass(n=500)
    padded = np.concatenate([ids.ravel(), np.full(100, -1),
                             np.full(100, 5000)])
    ref = capture_reference(p, y, padded, num_features=1000)
    assert ref.top_counts.sum() == ids.size  # pads never counted
    with pytest.raises(ValueError, match="non-empty"):
        capture_reference([], [], ids, num_features=1000)
    with pytest.raises(ValueError, match="disagree"):
        capture_reference(p, y[:-1], ids, num_features=1000)
    with pytest.raises(ValueError, match="no real"):
        capture_reference(p, y, np.full(10, -1), num_features=1000)


def test_capture_reference_fewer_ids_than_top_m():
    p, y, _ = _eval_pass(n=100)
    ids = np.array([3, 3, 7, 7, 7, 11])
    ref = capture_reference(p, y, ids, num_features=1000, top_m=128)
    assert ref.top_ids.tolist() == [3, 7, 11]
    assert ref.top_counts.tolist() == [2, 3, 1, 0]  # counts + empty tail


# ---------------------------------------------------------- divergences
def test_psi_and_kl_basics():
    a = np.array([100, 200, 300, 400])
    assert psi(a, a * 7) == pytest.approx(0.0)  # scale-invariant
    assert kl(a, 3 * a) == pytest.approx(0.0)
    shifted = np.array([400, 300, 200, 100])
    assert psi(a, shifted) > 0.25  # a real shift reads as drifted
    assert kl(a, shifted) > 0.0
    assert psi(np.array([1000, 0]), np.array([0, 1000])) > 1.0  # finite
    with pytest.raises(ValueError, match="empty"):
        psi(np.zeros(3), a[:3])


def test_rolling_counts_chunked_eviction():
    roll = _RollingCounts(4, capacity=100)
    roll.add(np.zeros(60, np.int64))
    roll.add(np.full(60, 1, np.int64))
    # 120 > 100: the oldest chunk evicts whole, leaving the newest 60
    assert roll.total == 60
    assert roll.counts.tolist() == [0, 60, 0, 0]
    roll.add(np.full(200, 2, np.int64))  # one oversized chunk stays
    assert roll.total == 200
    assert roll.counts.tolist() == [0, 0, 200, 0]
    roll.add(np.array([], dtype=np.int64))  # no-op
    assert roll.total == 200


# ------------------------------------------------------------- trackers
def test_score_tracker_warmup_then_detects_shift():
    p, y, ids = _eval_pass()
    ref = capture_reference(p, y, ids, num_features=1000)
    trk = obs.ScoreDriftTracker(ref, window=4096, min_count=256)
    assert trk.psi() is None and trk.kl() is None  # cold: no verdict
    rng = np.random.default_rng(1)
    trk.update(rng.uniform(0.02, 0.9, 1000))  # same distribution
    assert trk.ready
    assert trk.psi() < 0.1
    # rolling window forgets: flood with a shifted distribution
    trk.update(rng.uniform(0.8, 0.99, 5000))
    assert trk.psi() > 0.25
    assert trk.kl() > 0.0


def test_id_tracker_fires_on_head_rotation_only():
    p, y, ids = _eval_pass(hot=0.9)
    ref = capture_reference(p, y, ids, num_features=1000)
    rng = np.random.default_rng(2)
    same = obs.IdTrafficTracker(ref, min_count=512)
    same.update(np.minimum(rng.geometric(0.1, size=8000) - 1, 999))
    assert same.psi() < 0.1
    rotated = obs.IdTrafficTracker(ref, min_count=512)
    # the hot head moved: same shape, different ids (DayStream's drift)
    rotated.update(np.minimum(500 + rng.geometric(0.1, size=8000) - 1, 999))
    assert rotated.psi() > 0.25
    # pad ids are dropped, never counted
    pads = obs.IdTrafficTracker(ref, min_count=1)
    pads.update(np.full(100, -1))
    assert not pads.ready


def test_calibration_tracker_rolling_ratio_and_bucket_dev():
    p, y, ids = _eval_pass()
    ref = capture_reference(p, y, ids, num_features=1000)
    trk = obs.CalibrationTracker(ref, window=4096, min_count=64)
    assert trk.ratio() is None
    trk.update(p[:2000], y[:2000])  # calibrated by construction
    assert trk.ratio() == pytest.approx(1.0, abs=0.1)
    # per-bucket ratios are click-count noisy; just bounded, not tight
    assert trk.max_bucket_deviation() < 1.0
    # an over-predicting model pushes the ratio up
    over = obs.CalibrationTracker(ref, min_count=64)
    over.update(np.clip(p[:2000] * 2.0, 0, 1), y[:2000])
    assert over.ratio() > 1.5
    with pytest.raises(ValueError, match="disagree"):
        trk.update(p[:5], y[:4])


# ----------------------------------------------------------- persistence
def test_reference_roundtrip_standalone_and_artifact_embedded(tmp_path):
    import jax.numpy as jnp

    from repro.serve import compress, load_artifact, save_artifact

    p, y, ids = _eval_pass(n=600, d=300)
    ref = capture_reference(p, y, ids, num_features=300)
    path = obs.save_drift_reference(str(tmp_path / "ref"), ref)
    back = obs.load_drift_reference(path)
    for field in ref._fields:
        np.testing.assert_array_equal(np.asarray(getattr(ref, field)),
                                      np.asarray(getattr(back, field)))

    # embedded in a serving artifact: same loader, artifact unchanged
    rng = np.random.default_rng(0)
    theta = jnp.asarray(rng.normal(size=(300, 4)).astype(np.float32))
    theta = theta.at[100:].set(0.0)
    art = compress(theta)
    plain = save_artifact(str(tmp_path / "plain"), art)
    embedded = save_artifact(str(tmp_path / "emb"), art, drift_ref=ref)
    back2 = obs.load_drift_reference(embedded)
    np.testing.assert_array_equal(back2.top_counts, ref.top_counts)
    a0, a1 = load_artifact(plain), load_artifact(embedded)
    np.testing.assert_array_equal(np.asarray(a0.theta), np.asarray(a1.theta))
    np.testing.assert_array_equal(np.asarray(a0.remap), np.asarray(a1.remap))
    with pytest.raises(ValueError, match="no drift reference"):
        obs.load_drift_reference(plain)


# -------------------------------------------- end-to-end on DayStream
def _day_requests(batch, sessions, ads_per=2):
    """Turn one DayStream day into engine bundle requests (user block +
    a couple of its ad rows per request)."""
    from repro.serve.engine import BundleRequest

    reqs = []
    ui = np.asarray(batch.user_ids)
    uv = np.asarray(batch.user_vals)
    ai = np.asarray(batch.ad_ids)
    av = np.asarray(batch.ad_vals)
    per = ai.shape[0] // ui.shape[0]
    for s in range(ui.shape[0]):
        rows = slice(s * per, s * per + ads_per)
        reqs.append(BundleRequest(user_ids=ui[s], user_vals=uv[s],
                                  ad_ids=ai[rows], ad_vals=av[rows]))
    return reqs


@pytest.mark.parametrize("drift,expect_alert", [(0.5, True), (0.0, False)])
def test_id_psi_detector_on_daystream_replay(drift, expect_alert):
    """The planted-drift acceptance check: day 0 is IDENTICAL across
    drift values (the rotation offset is drift*day*span = 0), so one
    day-0 reference serves both replays; the drifted stream's later days
    must fire the id-PSI rule and the stationary stream must not."""
    import jax.numpy as jnp

    from repro.serve import ScoringEngine
    from repro.stream import DayStream

    d, sessions = 2000, 64
    stream = DayStream(6, sessions_per_day=sessions, num_features=d,
                       drift=drift, seed=3)
    day0 = stream.day(0)
    rng = np.random.default_rng(4)
    theta = jnp.asarray(0.05 * rng.normal(size=(d, 4)).astype(np.float32))

    # reference from day-0 traffic (scores/labels only matter for the
    # calibration tracker, which this rule set never consults)
    ids0 = np.concatenate([np.asarray(day0.user_ids).ravel(),
                           np.asarray(day0.ad_ids).ravel()])
    scores0 = np.random.default_rng(5).uniform(0.05, 0.95, 4000)
    labels0 = (np.random.default_rng(6).uniform(size=4000) < scores0)
    ref = capture_reference(scores0, labels0.astype(float), ids0,
                            num_features=d)

    # evaluate every 32 dispatches so the rule only ever judges a warm
    # window (>= 1024 rolling ids) — early tiny samples are pure noise
    led = obs.RunLedger(None)
    mon = obs.HealthMonitor(
        [obs.parse_rule("drift.id_psi <= 0.25 for 2/2")],
        eval_every=32).attach(led)
    mon.arm_drift(ref, id_window=1 << 16, min_count=1024)
    prev = obs.set_monitor(mon)
    prev_led = obs.set_ledger(led)
    try:
        engine = ScoringEngine(theta)
        for day in (4, 5):  # 4-5 days of rotation at drift=0.5
            for req in _day_requests(stream.day(day), sessions,
                                     ads_per=4):
                engine.score(req)
        mon.evaluate()
    finally:
        obs.set_monitor(prev)
        obs.set_ledger(prev_led)

    fired = [a for a in mon.alerts() if a["state"] == "firing"]
    if expect_alert:
        assert fired, f"drifted replay stayed silent: {mon.signals()}"
        assert fired[0]["rule"] == "drift.id_psi"
        assert led.events("alert"), "alert never reached the ledger"
    else:
        assert not fired, f"stationary replay alerted: {fired}"
        assert mon.signals()["drift.id_psi"] is not None  # warm, just OK
