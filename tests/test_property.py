"""Property-based tests (hypothesis) for system invariants.

hypothesis is an OPTIONAL test dependency: the whole module skips
cleanly when it is absent (CI installs it; a bare checkout need not)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property sweeps need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.direction import (
    choose_orthant,
    descent_direction,
    directional_derivative,
    project_orthant,
)
from repro.core.objective import smooth_loss_and_grad
from repro.data import CTRDataConfig, auc, generate, pad_to_multiple
from repro.optim import OWLQNPlus


def _rand_problem(seed, d=10, m2=6, n=24):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.normal(size=(n, d)) / np.sqrt(d), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n, m2)), jnp.float32)

    def lg(theta):
        r = A @ theta - b
        return 0.5 * jnp.vdot(r, r), A.T @ r

    return lg


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), lam=st.floats(0.0, 2.0),
       beta=st.floats(0.0, 2.0))
def test_direction_is_minimiser_among_random_directions(seed, lam, beta):
    """Prop. 2: d minimises f'(Theta; .) among equal-norm directions."""
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.normal(size=(8, 6)) *
                        (rng.random((8, 6)) > 0.4), jnp.float32)
    grad = jnp.asarray(rng.normal(size=(8, 6)), jnp.float32)
    d = descent_direction(theta, grad, lam, beta)
    dn = float(jnp.linalg.norm(d))
    if dn < 1e-8:
        return
    fd = float(directional_derivative(theta, grad, d, lam, beta))
    for _ in range(8):
        r = jnp.asarray(rng.normal(size=d.shape), jnp.float32)
        r = r * (dn / float(jnp.linalg.norm(r)))
        fr = float(directional_derivative(theta, grad, r, lam, beta))
        assert fd <= fr + 1e-3 * max(1.0, abs(fd)), (fd, fr)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_projection_idempotent_and_sign_safe(seed):
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.normal(size=(30,)) * (rng.random(30) > 0.3))
    d = jnp.asarray(rng.normal(size=(30,)))
    xi = choose_orthant(theta, d)
    p1 = project_orthant(theta + 0.5 * d, xi)
    p2 = project_orthant(p1, xi)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    # projected point never has sign opposite to xi
    s = np.sign(np.asarray(p1))
    x = np.asarray(xi)
    assert np.all((s == 0) | (x == 0) | (s == x))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), lam=st.floats(0.0, 1.0),
       beta=st.floats(0.0, 1.0))
def test_owlqn_step_never_flips_signs(seed, lam, beta):
    """Eq. 10/12 invariant: within one iteration parameters never cross
    zero — they move within the chosen orthant or become exactly 0."""
    lg = _rand_problem(seed)
    opt = OWLQNPlus(lg, lam=lam, beta=beta)
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.normal(size=(10, 6)) *
                        (rng.random((10, 6)) > 0.5), jnp.float32)
    state = opt.init(theta)
    step = jax.jit(opt.step)
    for _ in range(5):
        old = np.asarray(state.theta)
        state, _ = step(state)
        new = np.asarray(state.theta)
        crossed = (old != 0) & (new != 0) & (np.sign(old) != np.sign(new))
        assert not crossed.any()


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), lam=st.floats(0.0, 2.0),
       beta=st.floats(0.0, 2.0))
def test_owlqn_objective_never_increases(seed, lam, beta):
    lg = _rand_problem(seed)
    opt = OWLQNPlus(lg, lam=lam, beta=beta)
    theta = jnp.zeros((10, 6), jnp.float32) + 0.1
    state = opt.init(theta)
    step = jax.jit(opt.step)
    prev = None
    for _ in range(6):
        state, stats = step(state)
        f_before, f_after = float(stats.f), float(stats.f_new)
        assert f_after <= f_before + 1e-5 * max(1.0, abs(f_before))
        if prev is not None:
            assert f_before <= prev + 1e-4 * max(1.0, abs(prev))
        prev = f_after


@settings(max_examples=10, deadline=None)
@given(mult=st.integers(1, 7), sessions=st.integers(2, 20))
def test_pad_to_multiple_preserves_loss(mult, sessions):
    cfg = CTRDataConfig(num_user_features=6, num_ad_features=6,
                        noise_features=2)
    batch, _ = generate(cfg, sessions, seed=1)
    theta = jnp.asarray(
        np.random.default_rng(0).normal(size=(cfg.num_features, 8)) * 0.2,
        jnp.float32)
    l0, _ = smooth_loss_and_grad(theta, jax.tree.map(jnp.asarray, batch),
                                 common_feature=True)
    padded = pad_to_multiple(batch, mult)
    assert np.asarray(padded.y).shape[0] % mult == 0
    l1, _ = smooth_loss_and_grad(theta, jax.tree.map(jnp.asarray, padded),
                                 common_feature=True)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(5, 60))
def test_auc_agrees_with_quadratic_reference(seed, n):
    rng = np.random.default_rng(seed)
    y = (rng.random(n) > 0.5).astype(np.float32)
    s = rng.normal(size=n)
    if y.sum() in (0, n):
        return
    ours = auc(y, s)
    pos, neg = s[y == 1], s[y == 0]
    cmp = (pos[:, None] > neg[None, :]).sum() + 0.5 * (pos[:, None] == neg[None, :]).sum()
    ref = cmp / (len(pos) * len(neg))
    np.testing.assert_allclose(ours, ref, atol=1e-9)


def test_checkpoint_roundtrip(tmp_path):
    from repro.io import checkpoint
    from repro.optim import OWLQNPlus

    lg = _rand_problem(0)
    opt = OWLQNPlus(lg, lam=0.5, beta=0.5)
    state = opt.init(jnp.ones((10, 6)) * 0.1)
    state, _ = jax.jit(opt.step)(state)
    path = str(tmp_path / "state.npz")
    checkpoint.save(path, state._asdict())
    restored = checkpoint.load(path, state._asdict())
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        state._asdict(), restored,
    )
