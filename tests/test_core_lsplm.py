"""Unit tests for the LS-PLM core model (Eq. 1-3)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    LSPLMConfig,
    foe_mixture_proba,
    init_params,
    nll,
    nll_common_feature,
    objective,
    predict_logits_stable,
    predict_proba,
    CTRBatch,
)
from repro.data import CTRDataConfig, generate, to_dense_batch

KEY = jax.random.PRNGKey(0)


def _params(d=16, m=6, key=KEY):
    return init_params(LSPLMConfig(num_features=d, num_regions=m), key, scale=0.5)


def test_predict_is_probability():
    p = _params()
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    prob = predict_proba(p, x)
    assert prob.shape == (64,)
    assert np.all(np.asarray(prob) >= 0.0) and np.all(np.asarray(prob) <= 1.0)


def test_foe_equivalence():
    """Eq. 2 == Eq. 3 (FOE / mixed-LR view)."""
    p = _params()
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 16))
    np.testing.assert_allclose(
        np.asarray(predict_proba(p, x)), np.asarray(foe_mixture_proba(p, x)), rtol=1e-6
    )


def test_stable_logps_consistent_with_proba():
    p = _params()
    x = jax.random.normal(jax.random.PRNGKey(3), (32, 16))
    log_p1, log_p0 = predict_logits_stable(p, x)
    np.testing.assert_allclose(
        np.exp(np.asarray(log_p1)), np.asarray(predict_proba(p, x)), rtol=1e-5
    )
    # p1 + p0 == 1 (mixture of valid Bernoullis)
    np.testing.assert_allclose(
        np.exp(np.asarray(log_p1)) + np.exp(np.asarray(log_p0)), 1.0, rtol=1e-5
    )


def test_stable_logps_extreme_weights_no_nan():
    p = _params()
    p = p._replace(w=p.w * 1e4, u=p.u * 1e3)  # saturate everything
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 16))
    log_p1, log_p0 = predict_logits_stable(p, x)
    assert np.all(np.isfinite(np.asarray(log_p1)))
    assert np.all(np.isfinite(np.asarray(log_p0)))


def test_m_equals_one_reduces_to_lr():
    """With m=1 the gate is constant 1 -> plain logistic regression."""
    cfg = LSPLMConfig(num_features=16, num_regions=1)
    p = init_params(cfg, KEY, scale=0.5)
    x = jax.random.normal(jax.random.PRNGKey(5), (32, 16))
    expected = jax.nn.sigmoid(x @ p.w[:, 0])
    np.testing.assert_allclose(
        np.asarray(predict_proba(p, x)), np.asarray(expected), rtol=1e-6
    )


def test_common_feature_nll_equals_dense_nll():
    """Eq. 13: the trick is exact, not an approximation."""
    cfg = CTRDataConfig(num_user_features=8, num_ad_features=8, noise_features=4)
    batch, x_dense = generate(cfg, num_sessions=16)
    dense = to_dense_batch(batch)
    np.testing.assert_allclose(np.asarray(dense.x), x_dense, rtol=0, atol=0)

    theta = jax.random.normal(KEY, (cfg.num_features, 2 * 5)) * 0.3
    v_compressed = nll_common_feature(theta, batch)
    v_dense = nll(theta, CTRBatch(x=jnp.asarray(dense.x), y=jnp.asarray(dense.y)))
    np.testing.assert_allclose(float(v_compressed), float(v_dense), rtol=1e-5)


def test_objective_adds_regularizers():
    cfg = CTRDataConfig(num_user_features=8, num_ad_features=8, noise_features=4)
    batch, _ = generate(cfg, num_sessions=8)
    dense = to_dense_batch(batch)
    b = CTRBatch(x=jnp.asarray(dense.x), y=jnp.asarray(dense.y))
    theta = jax.random.normal(KEY, (cfg.num_features, 10)) * 0.3
    f0 = objective(theta, b, lam=0.0, beta=0.0)
    f1 = objective(theta, b, lam=1.0, beta=1.0)
    l21 = jnp.sum(jnp.sqrt(jnp.sum(theta**2, axis=1)))
    l1 = jnp.sum(jnp.abs(theta))
    np.testing.assert_allclose(float(f1 - f0), float(l21 + l1), rtol=1e-5)
