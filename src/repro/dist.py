"""Distribution plan (DESIGN.md §3): the paper's worker/server split as a
GSPMD (data, model) mesh.

  * batch axis   -> 'data'  (the paper's workers): every per-sample row
    block — x, ids, labels, session ids — is split over the data axis.
  * Theta rows   -> 'model' (the paper's parameter servers): feature rows
    are the L2,1 groups, so a row never straddles shards and OWLQN+'s
    orthant/direction algebra stays shard-local; only the scalar dot
    products of the two-loop recursion and line search all-reduce.
  * feature (contraction) axes of x are sharded over 'model' to line up
    with Theta's row sharding — each matmul psums exactly once.

Multi-pod meshes add a leading 'pod' axis to the data split
(``launch.mesh.data_axes``).

Sparse padded-COO batches shard the same way through the
``repro.shard`` subsystem: Theta rows over 'model' with id-range
routing (each server shard owns a contiguous id range; ids are bucketed
per shard by ``shard.route_batch``, gathers and the plan-driven scatter
backward run shard-local, z partials psum once). ``sparse_batch_specs``
/ ``shard_sparse_batch`` below are the sparse analogues of the dense
spec helpers; the step itself lives in ``repro.shard.step`` and
composes with ``make_distributed_step`` unchanged — the padded
row-sharded Theta is an ordinary ``P('model', None)`` array.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.objective import CommonFeatureBatch, CTRBatch
from repro.launch.mesh import data_axes
from repro.optim.lbfgs import LBFGSHistory
from repro.optim.owlqn_plus import OWLQNState

_is_spec = lambda x: isinstance(x, P)


def _row_axes(mesh):
    axes = data_axes(mesh)
    return axes[0] if len(axes) == 1 else axes


def batch_specs(mesh, *, common_feature: bool = False):
    """PartitionSpec tree for a CTRBatch / CommonFeatureBatch."""
    row = _row_axes(mesh)
    if common_feature:
        return CommonFeatureBatch(
            x_common=P(row, "model"),
            x_noncommon=P(row, "model"),
            session_id=P(row),
            y=P(row),
            weight=P(row),
        )
    return CTRBatch(x=P(row, "model"), y=P(row), weight=P(row))


def state_specs(mesh):
    """PartitionSpec tree for OWLQNState with a (d, 2m) Theta: Theta-like
    leaves row-sharded over 'model', LBFGS stacks likewise (history axis
    replicated), scalars replicated."""
    del mesh  # specs are mesh-independent; kept for call-site symmetry
    t = P("model", None)
    hist = LBFGSHistory(
        s=P(None, "model", None),
        y=P(None, "model", None),
        rho=P(),
        valid=P(),
        gamma=P(),
    )
    return OWLQNState(theta=t, history=hist, prev_theta=t, prev_d=t,
                      step=P(), f=P())


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=_is_spec)


def shard_batch(mesh, batch, *, common_feature: bool = False):
    """device_put a batch onto the mesh per ``batch_specs`` (None leaves,
    e.g. an absent weight, pass through)."""
    specs = batch_specs(mesh, common_feature=common_feature)
    put = lambda x, s: None if x is None else jax.device_put(
        x, NamedSharding(mesh, s))
    return type(batch)(*(put(x, s) for x, s in zip(batch, specs)))


def sparse_batch_specs(mesh, sbatch):
    """PartitionSpec tree for a routed ``shard.ShardedSparseBatch``:
    routed id/val tensors (model, batch, K) split over ('model', data),
    per-sample rows over the data axes, stacked plan leaves over their
    leading (data, model) axes, static metadata untouched (None)."""
    row = _row_axes(mesh)
    coo = P("model", row, None)
    plan = lambda p: None if p is None else jax.tree.map(
        lambda _: P(row, "model"), p)
    return type(sbatch)(
        user_ids=coo, user_vals=coo, ad_ids=coo, ad_vals=coo,
        session_id=P(row), y=P(row),
        num_features=None, rows_per_shard=None, data_shards=None,
        bounds=None,
        user_plan=plan(sbatch.user_plan), ad_plan=plan(sbatch.ad_plan))


def shard_sparse_batch(mesh, sbatch):
    """device_put a routed sparse batch onto the mesh per
    ``sparse_batch_specs`` (static int/tuple metadata passes through)."""
    specs = sparse_batch_specs(mesh, sbatch)
    put = lambda x, s: x if s is None else jax.tree.map(
        lambda leaf, sp: jax.device_put(leaf, NamedSharding(mesh, sp)),
        x, s, is_leaf=_is_spec)
    return type(sbatch)(*(put(x, s) for x, s in zip(sbatch, specs)))


def shard_state(state: OWLQNState, mesh) -> OWLQNState:
    """device_put an optimizer state onto the mesh per ``state_specs``."""
    return jax.tree.map(lambda s, x: jax.device_put(x, NamedSharding(mesh, s)),
                        state_specs(mesh), state, is_leaf=_is_spec)


def make_distributed_step(opt, mesh):
    """jit ``opt.step`` with state kept sharded across iterations (stats
    shardings left to the compiler)."""
    ns = _named(mesh, state_specs(mesh))
    return jax.jit(opt.step, in_shardings=(ns,), out_shardings=(ns, None))
