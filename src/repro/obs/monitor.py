"""Online model-health monitoring: rolling SLO signals, declarative
rules with hysteresis, typed ``alert`` ledger records.

The :class:`HealthMonitor` is the ACTIVE layer over PR 8's passive
primitives. It consumes the run ledger's ``serve_dispatch`` /
``stream_eval`` records (attached as a ledger observer, so every
instrumented subsystem feeds it for free) plus the drift trackers'
score/id/label streams (fed directly by the scoring engine and the
stream trainer's eval callback), folds them into rolling windows, and
evaluates declarative SLO rules::

    serve.p99_wall_us <= 250000 for 3/3
    calib.ratio >= 0.75
    drift.id_psi <= 0.25 for 2/2

A rule states a REQUIREMENT; it breaches when the requirement is
violated. HYSTERESIS keeps alerts from flapping: a rule must breach on
``breach_n`` CONSECUTIVE evaluations to fire and hold on ``clear_n``
consecutive OK evaluations to clear — one noisy window never pages, and
one lucky window never silences a real regression. State changes emit
typed ``alert`` ledger records (validated like every other kind) and
feed the ``obs_alerts``/``obs_alert_active`` registry series, so both
the post-hoc report (``repro.obs.report``) and a live ``--metrics-out``
snapshot carry the alert history.

Signals a rule can reference (``signals()``; a signal that is not warm
yet reads ``None`` and its rules are SKIPPED, never breached):

  * ``serve.p50_wall_us`` / ``serve.p99_wall_us`` — dispatch wall
  * ``serve.p99_queue_delay_us``                  — micro-batch delay
  * ``serve.occupancy``                           — real/padded slots
  * ``queue.pending`` / ``queue.rejected``        — registry view
  * ``eval.next_day_nll`` / ``eval.next_day_auc`` — stream eval
  * ``calib.ratio`` / ``calib.bucket_dev``        — calibration tracker
  * ``drift.score_psi`` / ``drift.score_kl`` /
    ``drift.id_psi``                              — drift trackers

Disabled fast path: the process default is :data:`NULL_MONITOR`
(``enabled = False``); the engine's per-dispatch feed is guarded behind
one attribute load, and evaluation batches behind ``eval_every`` so the
monitored dispatch loop stays inside ``bench_obs``'s <=2% overhead
gate.
"""
from __future__ import annotations

import re
import threading
from collections import deque
from typing import NamedTuple, Sequence

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs.drift import (
    CalibrationTracker,
    DriftReference,
    IdTrafficTracker,
    ScoreDriftTracker,
)
from repro.obs.ledger import NULL_LEDGER


_MAX_PENDING = 512  # drift-buffer backstop when nothing ever evaluates


def _subsample(arr: np.ndarray, cap: int) -> np.ndarray:
    """Deterministic stride subsample down to at most ``cap`` elements
    (0 = no cap). No RNG: a replayed request stream feeds the trackers
    identically every run."""
    arr = arr.ravel()
    if not cap or arr.size <= cap:
        return arr
    return arr[:: -(-arr.size // cap)]


class RollingWindow:
    """Bounded deque of floats with percentile/mean views (None while
    empty — "no data" must never read as "0 and breaching")."""

    def __init__(self, maxlen: int = 256):
        self._vals: deque[float] = deque(maxlen=maxlen)

    def push(self, value: float) -> None:
        self._vals.append(float(value))

    def __len__(self) -> int:
        return len(self._vals)

    def percentile(self, q: float) -> float | None:
        if not self._vals:
            return None
        return float(np.percentile(np.fromiter(self._vals, np.float64), q))

    def mean(self) -> float | None:
        if not self._vals:
            return None
        return float(np.fromiter(self._vals, np.float64).mean())

    def last(self) -> float | None:
        return self._vals[-1] if self._vals else None


class SLORule(NamedTuple):
    """One declarative health requirement (see module docstring)."""

    name: str
    signal: str
    op: str  # "<=" (stay below) or ">=" (stay above)
    threshold: float
    breach_n: int = 3  # consecutive breaching evals to FIRE
    clear_n: int = 3  # consecutive OK evals to CLEAR

    def ok(self, value: float) -> bool:
        if self.op == "<=":
            return value <= self.threshold
        return value >= self.threshold


_RULE_RE = re.compile(
    r"^\s*(?:(?P<name>[\w.-]+)\s*:)?\s*(?P<signal>[\w.]+)\s*"
    r"(?P<op><=|>=)\s*(?P<thr>[-+eE\d.]+)"
    r"(?:\s+for\s+(?P<breach>\d+)/(?P<clear>\d+))?\s*$")


def parse_rule(text: str) -> SLORule:
    """``"[name:] signal <=|>= threshold [for B/C]"`` -> :class:`SLORule`
    (name defaults to the signal; B/C default to 3/3)."""
    m = _RULE_RE.match(text)
    if m is None:
        raise ValueError(
            f"bad SLO rule {text!r}; expected "
            f"'[name:] signal <=|>= threshold [for B/C]'")
    breach = int(m["breach"]) if m["breach"] else 3
    clear = int(m["clear"]) if m["clear"] else 3
    if breach < 1 or clear < 1:
        raise ValueError(f"rule {text!r}: B/C must be >= 1")
    return SLORule(name=m["name"] or m["signal"], signal=m["signal"],
                   op=m["op"], threshold=float(m["thr"]),
                   breach_n=breach, clear_n=clear)


def default_rules() -> list[SLORule]:
    """The drivers' ``--monitor`` rule set: serving SLOs loose enough
    for shared CI runners, calibration band and the conventional 0.25
    PSI drift thresholds."""
    return [parse_rule(r) for r in (
        "serve.p99_wall_us <= 250000 for 3/3",
        "serve.p99_queue_delay_us <= 100000 for 3/3",
        "serve.occupancy >= 0.05 for 3/3",
        "calib.ratio <= 1.3 for 3/3",
        "calib.ratio >= 0.75 for 3/3",
        "drift.score_psi <= 0.25 for 2/2",
        "drift.id_psi <= 0.25 for 2/2",
    )]


class _RuleState:
    __slots__ = ("breaches", "oks", "active")

    def __init__(self):
        self.breaches = 0
        self.oks = 0
        self.active = False


class HealthMonitor:
    """Rolling-window SLO evaluation with hysteresis (module docstring).

    Thread-safe and reentrancy-safe: ingestion takes an RLock, and the
    alert records ``evaluate`` emits are ignored on re-entry, so a
    monitor attached to the very ledger it alerts into cannot recurse.
    """

    enabled = True

    def __init__(self, rules: Sequence[SLORule] | None = None, *,
                 window: int = 256, eval_every: int = 32,
                 registry=None, ledger=None):
        self.rules = list(rules) if rules is not None else default_rules()
        self._eval_every = max(1, int(eval_every))
        self._reg = registry if registry is not None \
            else obs_metrics.get_registry()
        self._ledger = ledger if ledger is not None else NULL_LEDGER
        self._lock = threading.RLock()
        # one deque of (wall_us, queue_delay_us, occupancy) triples —
        # ingest is on the dispatch hot path, so it pays ONE append;
        # the percentile/mean views unpack lazily at evaluation time
        self._disp: deque[tuple] = deque(maxlen=window)
        self._eval: dict[str, float] = {}
        self._score_tracker: ScoreDriftTracker | None = None
        self._id_tracker: IdTrafficTracker | None = None
        self._calib_tracker: CalibrationTracker | None = None
        self._sample_cap = 256
        self._pending_scores: list[np.ndarray] = []
        self._pending_ids: list[np.ndarray] = []
        self._piece_start = 0
        self._states = {r.name: _RuleState() for r in self.rules}
        self._alerts: list[dict] = []
        self._since_eval = 0
        self._attached_to = None
        self._active_gauges: dict[str, obs_metrics.Gauge] = {}

    # ------------------------------------------------------------- wiring
    def attach(self, ledger) -> "HealthMonitor":
        """Subscribe to a ledger's record stream AND alert into it."""
        ledger.add_observer(self.ingest)
        self._attached_to = ledger
        self._ledger = ledger
        return self

    def detach(self) -> None:
        if self._attached_to is not None:
            self._attached_to.remove_observer(self.ingest)
            self._attached_to = None

    def arm_drift(self, ref: DriftReference, *, score_window: int = 4096,
                  id_window: int = 65536, calib_window: int = 4096,
                  min_count: int = 256, sample_cap: int = 256) -> None:
        """Arm the drift/calibration detectors against a train-time
        reference (``repro.obs.drift.capture_reference``).

        ``sample_cap`` bounds the per-call work of the serving-side
        feeds (:meth:`observe_scores` / :meth:`observe_ids`): each call
        is stride-subsampled down to at most that many elements before
        it reaches a tracker. Drift detection is statistical — a big
        dispatch carries thousands of candidate ids, and folding every
        one of them in costs more than the dispatch itself. 0 disables
        the cap (tests that count exact tracker volume)."""
        with self._lock:
            self._sample_cap = int(sample_cap)
            self._pending_scores.clear()  # stale feeds vs the old ref
            self._pending_ids.clear()
            self._score_tracker = ScoreDriftTracker(
                ref, window=score_window, min_count=min_count)
            self._id_tracker = IdTrafficTracker(
                ref, window=id_window, min_count=min_count)
            self._calib_tracker = CalibrationTracker(
                ref, window=calib_window,
                min_count=max(1, min_count // 4))

    # -------------------------------------------------------------- feeds
    def ingest(self, event: dict) -> None:
        """Ledger-observer entry point: fold one record into the
        windows. Alert records are ignored (they are our own output)."""
        kind = event.get("kind")
        if kind == "serve_dispatch":
            with self._lock:
                self._disp.append((event["wall_s"] * 1e6,
                                   event["queue_delay_us"],
                                   event["occupancy"]))
                self._tick()
        elif kind == "stream_eval":
            with self._lock:
                for field in ("next_day_nll", "next_day_auc"):
                    if field in event:
                        self._eval[field] = float(event[field])
                self.evaluate()

    def _sample_pieces(self, arrs) -> list[np.ndarray]:
        """Sample a per-dispatch sequence of arrays down to roughly
        ``sample_cap`` elements BY PIECE: starting from a rotating
        offset, just enough pieces to fill the cap are taken and
        strided down — a hot dispatch touches one or two of its tensors
        instead of all of them, and the rotation works through every
        slot across dispatches."""
        cap = self._sample_cap
        if not cap:
            return [np.asarray(a).ravel() for a in arrs]
        k = len(arrs)
        start = self._piece_start
        self._piece_start = (start + 1) % k
        picked, budget = [], 0
        for j in range(k):
            a = np.asarray(arrs[(start + j) % k])
            picked.append(a)
            budget += a.size
            if budget >= cap:
                break
        stride = -(-budget // cap) if budget > cap else 1
        return [a.ravel()[::stride] for a in picked]

    def observe_dispatch(self, scores, requests) -> None:
        """Combined drift feed for the scoring engine's hot path: ONE
        lock take and one sampled tensor per dispatch. Calls alternate
        between the score and the id stream, and each call samples a
        single rotating request — the trackers' rolling windows span
        hundreds of dispatches, so every request slot still gets
        worked through while the per-dispatch cost stays a small
        fraction of the dispatch wall.

        ``scores`` is the engine's per-request score list, ``requests``
        the matching request sequence (``.user_ids`` / ``.ad_ids``)."""
        if self._score_tracker is None and self._id_tracker is None:
            return
        k = len(requests)
        if k == 0:
            return
        rot = self._piece_start
        self._piece_start = rot + 1
        cap = self._sample_cap
        if rot % 2 == 0:
            if self._score_tracker is None:
                return
            chunk = _subsample(np.asarray(scores[(rot >> 1) % k]), cap)
            with self._lock:
                if self._score_tracker is not None:
                    self._pending_scores.append(chunk)
                    if len(self._pending_scores) >= _MAX_PENDING:
                        self._drain_drift()
        else:
            if self._id_tracker is None:
                return
            r = requests[(rot >> 1) % k]
            pieces = [np.asarray(r.user_ids).ravel(),
                      _subsample(np.asarray(r.ad_ids), cap)]
            with self._lock:
                if self._id_tracker is not None:
                    self._pending_ids.extend(pieces)
                    if len(self._pending_ids) >= _MAX_PENDING:
                        self._drain_drift()

    def observe_scores(self, scores) -> None:
        """Serving-score feed (the engine calls this per dispatch) —
        one array or a sequence of per-request arrays, subsampled to
        the armed ``sample_cap`` and buffered; the trackers fold the
        buffer in at the next evaluation."""
        if self._score_tracker is None:
            return
        if isinstance(scores, (list, tuple)):
            if not scores:
                return
            pieces = self._sample_pieces(scores)
        else:
            pieces = [_subsample(np.asarray(scores), self._sample_cap)]
        with self._lock:
            if self._score_tracker is not None:
                self._pending_scores.extend(pieces)
                if len(self._pending_scores) >= _MAX_PENDING:
                    self._drain_drift()

    def observe_ids(self, ids) -> None:
        """Id-traffic feed (pad ids are filtered by the tracker) —
        same shapes and sampling as :meth:`observe_scores`."""
        if self._id_tracker is None:
            return
        if isinstance(ids, (list, tuple)):
            if not ids:
                return
            pieces = self._sample_pieces(ids)
        else:
            pieces = [_subsample(np.asarray(ids), self._sample_cap)]
        with self._lock:
            if self._id_tracker is not None:
                self._pending_ids.extend(pieces)
                if len(self._pending_ids) >= _MAX_PENDING:
                    self._drain_drift()

    def _drain_drift(self) -> None:
        """Fold buffered score/id chunks into the trackers (caller holds
        the lock). Buffering amortises numpy's fixed per-op cost over
        ``eval_every`` dispatches — one tracker update per evaluation
        instead of one per dispatch keeps the monitored dispatch loop
        inside ``bench_obs``'s 2% overhead gate."""
        if self._pending_scores:
            self._score_tracker.update(np.concatenate(self._pending_scores))
            self._pending_scores.clear()
        if self._pending_ids:
            self._id_tracker.update(np.concatenate(self._pending_ids))
            self._pending_ids.clear()

    def observe_predictions(self, p, y) -> None:
        """Labeled-prediction feed (stream eval / delayed feedback)."""
        with self._lock:
            if self._calib_tracker is not None:
                self._calib_tracker.update(p, y)

    def _tick(self) -> None:
        self._since_eval += 1
        if self._since_eval >= self._eval_every:
            self.evaluate()

    # ------------------------------------------------------------ signals
    _SIGNAL_NAMES = (
        "serve.p50_wall_us", "serve.p99_wall_us",
        "serve.p99_queue_delay_us", "serve.occupancy",
        "queue.pending", "queue.rejected",
        "eval.next_day_nll", "eval.next_day_auc",
        "calib.ratio", "calib.bucket_dev",
        "drift.score_psi", "drift.score_kl", "drift.id_psi",
    )

    def signals(self) -> dict[str, float | None]:
        """The current rule-addressable signal values (None = not warm)."""
        with self._lock:
            self._drain_drift()
            return {n: self._signal(n) for n in self._SIGNAL_NAMES}

    def _signal(self, name: str) -> float | None:
        """One signal on demand (caller holds the lock and has drained
        the drift buffers) — ``evaluate`` touches only the signals its
        rules actually reference, never the full dict."""
        if name == "serve.p50_wall_us":
            col = self._disp_col(0)
            return None if col is None else float(np.percentile(col, 50))
        if name == "serve.p99_wall_us":
            col = self._disp_col(0)
            return None if col is None else float(np.percentile(col, 99))
        if name == "serve.p99_queue_delay_us":
            col = self._disp_col(1)
            return None if col is None else float(np.percentile(col, 99))
        if name == "serve.occupancy":
            col = self._disp_col(2)
            return None if col is None else float(col.mean())
        if name == "queue.pending":
            return self._registry_value("serve_queue_pending")
        if name == "queue.rejected":
            return self._registry_value("serve_queue_rejected")
        if name == "eval.next_day_nll":
            return self._eval.get("next_day_nll")
        if name == "eval.next_day_auc":
            return self._eval.get("next_day_auc")
        if name == "calib.ratio":
            return None if self._calib_tracker is None \
                else self._calib_tracker.ratio()
        if name == "calib.bucket_dev":
            return None if self._calib_tracker is None \
                else self._calib_tracker.max_bucket_deviation()
        if name == "drift.score_psi":
            return None if self._score_tracker is None \
                else self._score_tracker.psi()
        if name == "drift.score_kl":
            return None if self._score_tracker is None \
                else self._score_tracker.kl()
        if name == "drift.id_psi":
            return None if self._id_tracker is None \
                else self._id_tracker.psi()
        return None

    def _disp_col(self, i: int) -> np.ndarray | None:
        if not self._disp:
            return None
        return np.fromiter((t[i] for t in self._disp), np.float64)

    def _registry_value(self, name: str) -> float | None:
        vals = [s.value for s in self._reg.series() if s.name == name]
        return max(vals) if vals else None

    # ----------------------------------------------------------- evaluate
    def evaluate(self) -> list[dict]:
        """Evaluate every rule against the current signals, advancing
        hysteresis state; returns the alert records emitted (state
        CHANGES only — a steadily-firing rule emits once)."""
        with self._lock:
            self._since_eval = 0
            self._drain_drift()
            sigs: dict[str, float | None] = {}
            emitted = []
            for rule in self.rules:
                if rule.signal not in sigs:
                    sigs[rule.signal] = self._signal(rule.signal)
                value = sigs[rule.signal]
                if value is None or value != value:  # not warm / NaN: skip
                    continue
                st = self._states[rule.name]
                if rule.ok(value):
                    st.oks += 1
                    st.breaches = 0
                    if st.active and st.oks >= rule.clear_n:
                        st.active = False
                        emitted.append(self._emit(rule, "cleared", value))
                else:
                    st.breaches += 1
                    st.oks = 0
                    if not st.active and st.breaches >= rule.breach_n:
                        st.active = True
                        emitted.append(self._emit(rule, "firing", value))
            return emitted

    def _emit(self, rule: SLORule, state: str, value: float) -> dict:
        event = {"kind": "alert", "rule": rule.name, "state": state,
                 "signal": rule.signal, "value": float(value),
                 "threshold": rule.threshold, "op": rule.op,
                 "breach_n": rule.breach_n, "clear_n": rule.clear_n}
        self._alerts.append(dict(event))
        self._reg.counter("obs_alerts", rule=rule.name, state=state).inc()
        gauge = self._active_gauges.get(rule.name)
        if gauge is None:
            gauge = self._reg.gauge("obs_alert_active", rule=rule.name)
            self._active_gauges[rule.name] = gauge
        gauge.set(1.0 if state == "firing" else 0.0)
        if self._ledger.enabled:
            self._ledger.emit(**event)
        return event

    # -------------------------------------------------------------- views
    def alerts(self) -> list[dict]:
        """Every alert state change so far (oldest first)."""
        with self._lock:
            return [dict(a) for a in self._alerts]

    def active_alerts(self) -> list[str]:
        """Names of rules currently firing."""
        with self._lock:
            return [name for name, st in self._states.items() if st.active]

    def summary(self) -> dict:
        """One log-friendly health snapshot."""
        with self._lock:
            sigs = {k: v for k, v in self.signals().items() if v is not None}
            return {"signals": sigs, "active": self.active_alerts(),
                    "alerts": len(self._alerts)}


class NullMonitor:
    """The disabled default: every feed is one early return."""

    enabled = False

    def attach(self, ledger) -> "NullMonitor":
        return self

    def detach(self) -> None:
        return None

    def arm_drift(self, ref, **kwargs) -> None:
        return None

    def ingest(self, event: dict) -> None:
        return None

    def observe_dispatch(self, scores, requests) -> None:
        return None

    def observe_scores(self, scores) -> None:
        return None

    def observe_ids(self, ids) -> None:
        return None

    def observe_predictions(self, p, y) -> None:
        return None

    def evaluate(self) -> list[dict]:
        return []

    def signals(self) -> dict:
        return {}

    def alerts(self) -> list[dict]:
        return []

    def active_alerts(self) -> list[str]:
        return []

    def summary(self) -> dict:
        return {"signals": {}, "active": [], "alerts": 0}


NULL_MONITOR = NullMonitor()
_DEFAULT: HealthMonitor | NullMonitor = NULL_MONITOR


def get_monitor() -> HealthMonitor | NullMonitor:
    """The process default monitor — :data:`NULL_MONITOR` until a driver
    configures ``--monitor`` (see ``repro.obs.configure``)."""
    return _DEFAULT


def set_monitor(monitor: HealthMonitor | NullMonitor,
                ) -> HealthMonitor | NullMonitor:
    """Swap the process default monitor; returns the previous one."""
    global _DEFAULT
    prev, _DEFAULT = _DEFAULT, monitor
    return prev
