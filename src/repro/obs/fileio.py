"""Crash-safe snapshot writes: temp file + atomic ``os.replace``.

The obs layer writes two kinds of files. STREAMED files (the run
ledger) append one line per record to a line-buffered handle — a crash
leaves a readable prefix, which is exactly what a forensic artifact
should do. SNAPSHOT files (metrics registry exports, Chrome traces,
rendered reports, drift references) are written whole at one point in
time — for those, writing in place means a crash mid-``write`` leaves a
truncated JSON document that silently poisons whatever reads it later
(CI archives, the report CLI, a drift-armed monitor).

:func:`atomic_write` closes that hole: the content lands in a unique
temp file in the TARGET directory (same filesystem, so the final rename
cannot cross a device boundary) and only a completed write is
``os.replace``-d onto the destination — readers see either the old
bytes or the new bytes, never a prefix. On any failure the temp file is
removed and the destination is untouched.
"""
from __future__ import annotations

import os
import tempfile
from contextlib import contextmanager
from typing import IO, Iterator


def _umask() -> int:
    """The process umask (os.umask can only read by setting)."""
    cur = os.umask(0)
    os.umask(cur)
    return cur


@contextmanager
def atomic_write(path: str, mode: str = "w") -> Iterator[IO]:
    """``with atomic_write(p) as f: f.write(...)`` — all-or-nothing.

    Creates parent directories, yields a handle onto a temp file next
    to ``path``, and renames it over ``path`` only when the body
    completes without raising. ``mode`` must be a write mode ("w" or
    "wb").
    """
    if "w" not in mode:
        raise ValueError(f"atomic_write needs a write mode, got {mode!r}")
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=parent,
                               prefix=f".{os.path.basename(path)}.",
                               suffix=".tmp")
    try:
        # mkstemp creates 0600; the published file should honour the
        # umask like a plain open() would
        os.chmod(tmp, 0o666 & ~_umask())
        with os.fdopen(fd, mode) as f:
            yield f
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
