"""Process-wide metrics registry: counters, gauges and fixed-bucket
histograms with labeled series and JSON/JSONL export.

The registry is the ONE place the repo's runtime statistics live. The
pre-existing ad-hoc stats classes (``stream.planner.PlannerStats``,
``serve.engine.EngineStats``, ``serve.traffic.QueueStats``) are VIEWS
over registry series — they keep their exact APIs (every field is read
back out of a counter), but the same numbers are now also exportable as
one machine-readable snapshot (``repro.launch.* --metrics-out``).

Design constraints, in order:

  * THREAD-SAFE: the :class:`~repro.stream.planner.WindowPlanner`
    background thread and the trainer's main thread feed the same
    registry concurrently. Series creation locks the registry; every
    instrument carries its own lock for updates.
  * BIT-FOR-BIT: a counter accumulates with the same ``+=`` float
    arithmetic the old stats attributes used, in the same call order,
    so derived values (``PlannerStats.overlap_ratio``) are unchanged to
    the last bit.
  * CHEAP: an update is one lock + one add. Nothing allocates on the
    hot path; export walks the series only when asked.

Histograms use fixed bucket upper bounds (default: a log-spaced
1 us .. 500 s wall-clock ladder) and support p50/p99-style quantile
estimates by linear interpolation inside the covering bucket, clamped
to the observed min/max.
"""
from __future__ import annotations

import itertools
import json
import threading
from typing import Iterator

# log-spaced seconds ladder: 1us .. 500s (1, 2.5, 5 per decade) — wide
# enough for kernel dispatches and whole-window walls alike
DEFAULT_BUCKETS: tuple[float, ...] = tuple(
    b * 10.0 ** e for e in range(-6, 3) for b in (1.0, 2.5, 5.0))

_INSTANCE_IDS: dict[str, itertools.count] = {}
_INSTANCE_LOCK = threading.Lock()


def next_instance(kind: str) -> str:
    """Monotonic per-kind instance label (``"0"``, ``"1"``, ...) so each
    planner/engine/queue object owns its own labeled series."""
    with _INSTANCE_LOCK:
        counter = _INSTANCE_IDS.setdefault(kind, itertools.count())
        return str(next(counter))


def _series_key(name: str, labels: dict[str, str]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically-accumulating float (counts or summed seconds)."""

    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: dict[str, str]):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def as_dict(self) -> dict:
        return {"type": "counter", "value": self._value}


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: dict[str, str]):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    @property
    def value(self) -> float:
        return self._value

    def as_dict(self) -> dict:
        return {"type": "gauge", "value": self._value}


class Histogram:
    """Fixed-bucket histogram (upper bounds + implicit +inf overflow)
    with count/sum/min/max and interpolated quantiles."""

    __slots__ = ("name", "labels", "bounds", "_lock", "_counts",
                 "_count", "_sum", "_min", "_max")

    def __init__(self, name: str, labels: dict[str, str],
                 buckets: tuple[float, ...] = DEFAULT_BUCKETS):
        self.name = name
        self.labels = labels
        self.bounds = tuple(sorted(buckets))
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.bounds) + 1)  # last = +inf
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")

    def observe(self, value: float) -> None:
        v = float(value)
        if v != v:  # NaN would poison min/max (min(inf, nan) -> inf but
            # max(-inf, nan) -> nan on some paths) and make quantile()
            # return garbage; reject at the source where the bug is
            raise ValueError(f"histogram {self.name!r}: NaN observation")
        # linear scan is fine: bucket ladders are tens of entries and
        # observations land near the front for sub-second walls
        idx = len(self.bounds)
        for i, b in enumerate(self.bounds):
            if v <= b:
                idx = i
                break
        with self._lock:
            self._counts[idx] += 1
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def quantile(self, q: float) -> float:
        """Estimate the q-quantile (q in [0, 1]) by linear interpolation
        inside the covering bucket, clamped to the observed range.

        Edge cases (all tested in ``tests/test_obs.py``): an EMPTY
        histogram returns 0.0 (there is no observed range to clamp to);
        ``q=0`` returns the observed min and ``q=1`` the observed max
        (the clamp, not extrapolation into the bucket bounds); a
        SINGLE-observation series returns that value for every q.
        ``q`` outside [0, 1] raises — a quantile request like 99 where
        0.99 was meant must not silently clamp to the max."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(
                f"quantile q must be in [0, 1], got {q!r} "
                f"(pass 0.99, not 99)")
        with self._lock:
            count = self._count
            counts = list(self._counts)
            lo_obs, hi_obs = self._min, self._max
        if count == 0:
            return 0.0
        rank = q * count
        cum = 0.0
        lo = 0.0
        for i, c in enumerate(counts):
            hi = self.bounds[i] if i < len(self.bounds) else hi_obs
            if c and cum + c >= rank:
                frac = (rank - cum) / c
                est = lo + frac * (max(hi, lo) - lo)
                return min(max(est, lo_obs), hi_obs)
            cum += c
            lo = hi
        return hi_obs

    def as_dict(self) -> dict:
        with self._lock:
            buckets = {("+inf" if i == len(self.bounds)
                        else f"{self.bounds[i]:g}"): c
                       for i, c in enumerate(self._counts) if c}
            out = {"type": "histogram", "count": self._count,
                   "sum": self._sum, "buckets": buckets}
            if self._count:
                out["min"] = self._min
                out["max"] = self._max
        if self._count:
            out["p50"] = self.quantile(0.5)
            out["p99"] = self.quantile(0.99)
        return out


class MetricsRegistry:
    """Thread-safe get-or-create home for labeled metric series."""

    def __init__(self):
        self._lock = threading.Lock()
        self._series: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, cls, name: str, labels: dict[str, str], **kwargs):
        key = _series_key(name, labels)
        with self._lock:
            inst = self._series.get(key)
            if inst is None:
                inst = cls(name, labels, **kwargs)
                self._series[key] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"series {key!r} already registered as "
                    f"{type(inst).__name__}, requested {cls.__name__}")
            return inst

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str,
                  buckets: tuple[float, ...] = DEFAULT_BUCKETS,
                  **labels: str) -> Histogram:
        return self._get(Histogram, name, labels, buckets=buckets)

    def series(self) -> Iterator[Counter | Gauge | Histogram]:
        with self._lock:
            return iter(list(self._series.values()))

    def as_dict(self) -> dict:
        """``{series_key: {type, value | count/sum/buckets/...}}``."""
        with self._lock:
            items = list(self._series.items())
        return {key: inst.as_dict() for key, inst in items}

    def write(self, path: str) -> str:
        """Snapshot to ``path``: ``.jsonl`` writes one series per line,
        anything else one nested JSON document. The write is ATOMIC
        (temp file + ``os.replace``) — a crash mid-snapshot leaves the
        previous file intact, never a truncated JSON artifact."""
        from repro.obs.fileio import atomic_write

        snap = self.as_dict()
        with atomic_write(path) as f:
            if path.endswith(".jsonl"):
                for key, payload in sorted(snap.items()):
                    f.write(json.dumps({"series": key, **payload},
                                       sort_keys=True) + "\n")
            else:
                json.dump(snap, f, indent=2, sort_keys=True)
                f.write("\n")
        return path

    def clear(self) -> None:
        with self._lock:
            self._series.clear()


_DEFAULT = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide registry every subsystem feeds by default."""
    return _DEFAULT


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-wide registry (tests); returns the previous one."""
    global _DEFAULT
    prev, _DEFAULT = _DEFAULT, registry
    return prev
