"""Unified observability layer: metrics registry, span tracing, run ledger.

Three primitives, one configuration point:

  * :mod:`repro.obs.metrics` — process-wide thread-safe registry of
    counters/gauges/histograms; the subsystem stats classes
    (``PlannerStats``/``EngineStats``/``QueueStats``) are views over it.
  * :mod:`repro.obs.trace` — nesting, thread-safe context-manager spans
    exported as Chrome-trace/Perfetto JSON; optionally mirrored into
    ``jax.profiler`` annotations.
  * :mod:`repro.obs.ledger` — append-only JSONL run ledger of typed
    event records (per optimizer iteration, stream window, serve
    dispatch) that the launch drivers render human-readable lines from.

Everything is DISABLED by default (null tracer, null ledger, an idle
registry) so library code pays ~nothing when a driver doesn't ask for
output. Drivers call :func:`configure` with their ``--metrics-out``/
``--trace-out``/``--ledger-out`` flags and close the returned session
when done::

    obs = repro.obs.configure(metrics_out=args.metrics_out,
                              trace_out=args.trace_out,
                              ledger_out=args.ledger_out,
                              meta={"driver": "repro.launch.train"})
    try:
        ...
    finally:
        obs.close()   # snapshots metrics/trace, closes the ledger
"""
from __future__ import annotations

from .drift import (  # noqa: F401
    CalibrationTracker,
    DriftReference,
    IdTrafficTracker,
    ScoreDriftTracker,
    capture_reference,
    kl,
    load_drift_reference,
    psi,
    save_drift_reference,
)
from .fileio import atomic_write  # noqa: F401
from .ledger import (  # noqa: F401
    NULL_LEDGER,
    NullLedger,
    RunLedger,
    SCHEMA,
    get_ledger,
    log,
    read_jsonl,
    render_stream_day,
    render_train_iter,
    set_ledger,
    validate_event,
    validate_events,
    validate_file,
)
from .metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
    next_instance,
    set_registry,
)
from .monitor import (  # noqa: F401
    NULL_MONITOR,
    HealthMonitor,
    NullMonitor,
    RollingWindow,
    SLORule,
    default_rules,
    get_monitor,
    parse_rule,
    set_monitor,
)
from .trace import (  # noqa: F401
    NULL_SPAN,
    NULL_TRACER,
    Tracer,
    get_tracer,
    set_tracer,
)


class ObsSession:
    """A configured observability scope: owns the enabled tracer/ledger
    it installed as process defaults and knows where to write snapshots.

    ``close()`` writes the metrics/trace files (if requested), closes
    the ledger file, and restores the previous process defaults —
    idempotent, safe in a ``finally``.
    """

    def __init__(self, *, metrics_out=None, trace_out=None,
                 ledger_out=None, report_out=None, registry=None,
                 tracer=None, ledger=None, monitor=None,
                 prev_tracer=None, prev_ledger=None, prev_monitor=None):
        self.metrics_out = metrics_out
        self.trace_out = trace_out
        self.ledger_out = ledger_out
        self.report_out = report_out
        self.registry = registry if registry is not None else get_registry()
        self.tracer = tracer if tracer is not None else get_tracer()
        self.ledger = ledger if ledger is not None else get_ledger()
        self.monitor = monitor if monitor is not None else get_monitor()
        self._prev_tracer = prev_tracer
        self._prev_ledger = prev_ledger
        self._prev_monitor = prev_monitor
        self._closed = False

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self.monitor.enabled:
            # settle any partial hysteresis window before the snapshot
            self.monitor.evaluate()
            self.monitor.detach()
        if self.metrics_out:
            self.registry.write(self.metrics_out)
        if self.trace_out:
            self.tracer.write(self.trace_out)
        if self.report_out:
            from . import report as _report

            rep = _report.build_report(self.ledger.events())
            text = (_report.render_html(rep)
                    if self.report_out.endswith((".html", ".htm"))
                    else _report.render_md(rep))
            with atomic_write(self.report_out) as f:
                f.write(text + "\n")
        self.ledger.close()
        if self._prev_tracer is not None:
            set_tracer(self._prev_tracer)
        if self._prev_ledger is not None:
            set_ledger(self._prev_ledger)
        if self._prev_monitor is not None:
            set_monitor(self._prev_monitor)

    def __enter__(self) -> "ObsSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def configure(*, metrics_out: str | None = None, trace_out: str | None = None,
              ledger_out: str | None = None, report_out: str | None = None,
              monitor: bool = False,
              monitor_rules: list | None = None,
              trace_annotate: bool = False,
              meta: dict | None = None) -> ObsSession:
    """Install enabled process defaults for whichever outputs the driver
    asked for and return the owning :class:`ObsSession`.

    A tracer is enabled only when ``trace_out`` is given; a file-backed
    ledger only when ``ledger_out`` is. ``monitor=True`` installs a
    :class:`HealthMonitor` (default or ``monitor_rules``) attached to
    the run ledger; ``report_out`` renders the ledger into a run report
    on close (md, or html by extension). Both need ledger records, so
    either implies an in-memory ledger when ``--ledger-out`` was not
    given. When ``meta`` is given (and a ledger is active) it is
    emitted as the leading ``run_meta`` record. With no arguments this
    is a no-op session over the null defaults.
    """
    prev_tracer = prev_ledger = prev_monitor = None
    tracer = get_tracer()
    ledger = get_ledger()
    mon = get_monitor()
    if trace_out:
        tracer = Tracer(enabled=True, annotate=trace_annotate)
        prev_tracer = set_tracer(tracer)
    if ledger_out or monitor or report_out:
        ledger = RunLedger(ledger_out)  # path=None -> in-memory only
        prev_ledger = set_ledger(ledger)
        if meta:
            ledger.emit("run_meta", **meta)
    if monitor:
        mon = HealthMonitor(monitor_rules).attach(ledger)
        prev_monitor = set_monitor(mon)
    return ObsSession(metrics_out=metrics_out, trace_out=trace_out,
                      ledger_out=ledger_out, report_out=report_out,
                      registry=get_registry(),
                      tracer=tracer, ledger=ledger, monitor=mon,
                      prev_tracer=prev_tracer, prev_ledger=prev_ledger,
                      prev_monitor=prev_monitor)


def add_flags(parser) -> None:
    """The launch drivers' shared observability flags."""
    parser.add_argument("--metrics-out", default=None, metavar="PATH",
                        help="write a metrics-registry snapshot on exit "
                             "(.jsonl = one series per line, else JSON)")
    parser.add_argument("--trace-out", default=None, metavar="PATH",
                        help="record spans and write Chrome-trace JSON on "
                             "exit (open in chrome://tracing or Perfetto)")
    parser.add_argument("--ledger-out", default=None, metavar="PATH",
                        help="append typed run-ledger records (JSONL): "
                             "per-iteration, per-window, per-dispatch")
    parser.add_argument("--trace-annotate", action="store_true",
                        help="with --trace-out: mirror spans into "
                             "jax.profiler annotations so an active "
                             "profiler trace shows them on the device "
                             "timeline")
    parser.add_argument("--monitor", action="store_true",
                        help="run the health monitor (repro.obs.monitor): "
                             "rolling SLO rules over dispatch/eval records "
                             "with hysteresis, emitting typed 'alert' "
                             "ledger records")
    parser.add_argument("--monitor-rule", action="append", default=None,
                        metavar="RULE", dest="monitor_rules",
                        help="replace the default SLO rule set "
                             "(repeatable): '[name:] signal <=|>= "
                             "threshold [for B/C]', e.g. "
                             "'drift.id_psi <= 0.25 for 2/2'")
    parser.add_argument("--drift-ref", default=None, metavar="PATH",
                        help="drift-reference snapshot (.npz): training "
                             "drivers CAPTURE one here from held-out "
                             "eval; serving drivers LOAD it to arm the "
                             "monitor's drift/calibration detectors")
    parser.add_argument("--report-out", default=None, metavar="PATH",
                        help="render the run ledger into one analytics "
                             "report on exit (.html for HTML, else "
                             "markdown; same renderer as "
                             "python -m repro.obs.report)")


def configure_from_args(args, *, driver: str, mode: str | None = None,
                        ) -> ObsSession:
    """:func:`configure` from parsed :func:`add_flags` arguments, with a
    ``run_meta`` record carrying the jax backend/device context."""
    import sys

    import jax

    meta: dict = {"driver": driver, "backend": jax.default_backend(),
                  "device_count": jax.device_count(),
                  "argv": list(sys.argv[1:])}
    if mode is not None:
        meta["mode"] = mode
    rules = None
    if getattr(args, "monitor_rules", None):
        rules = [parse_rule(r) for r in args.monitor_rules]
    return configure(metrics_out=args.metrics_out, trace_out=args.trace_out,
                     ledger_out=args.ledger_out,
                     report_out=getattr(args, "report_out", None),
                     monitor=getattr(args, "monitor", False),
                     monitor_rules=rules,
                     trace_annotate=args.trace_annotate, meta=meta)
