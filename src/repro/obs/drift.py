"""Model-health drift detection: train-time reference snapshots and
online detectors over the serving stream.

LS-PLM's production story ("On the Factory Floor", PAPERS.md
2209.05310) treats calibration and distribution drift as first-class
gates: a model that scores fast but scores the WRONG traffic is worse
than a slow one. This module is the passive half of that gate — the
:class:`~repro.obs.monitor.HealthMonitor` turns its numbers into
alerts.

At TRAIN time, :func:`capture_reference` snapshots what "healthy"
looked like on held-out eval data:

  * the score histogram (fixed [0, 1] buckets) — the serving score
    distribution should keep this shape;
  * per-bucket predicted/empirical click mass — the bucketed
    calibration the online ratio is compared against (the per-bucket
    view is ``repro.eval.metrics.bucketed_calibration``);
  * the top-M id traffic histogram (+ one tail bucket) — the hot head
    of the id stream; :class:`~repro.stream.source.DayStream`'s planted
    drift rotates exactly this head, so the id-traffic PSI below is the
    detector that must fire on a drifted replay.

The reference saves standalone (:func:`save_drift_reference`) or rides
inside a serving-artifact file (``repro.serve.compress.save_artifact``
embeds it under a ``drift_ref/`` prefix the artifact loader ignores).

ONLINE, three rolling trackers consume the serving stream:

  * :class:`ScoreDriftTracker` — PSI and KL divergence of the rolling
    score histogram vs the reference (PSI > 0.25 is the conventional
    "population has shifted" threshold);
  * :class:`IdTrafficTracker` — PSI of the rolling top-id/tail traffic
    histogram vs the reference;
  * :class:`CalibrationTracker` — rolling overall calibration ratio
    (literally ``eval/metrics.calibration_ratio`` over the rolling
    sums) plus the worst per-bucket deviation from the reference's
    bucket ratios.

All three share the chunked-eviction rolling window (whole update
batches are evicted oldest-first once the window overflows), so an
update is a handful of vectorised numpy ops — cheap enough to live on
the engine dispatch path under the bench's <=2% overhead gate.
"""
from __future__ import annotations

from collections import deque
from typing import NamedTuple

import numpy as np

from repro.eval.metrics import calibration_ratio

DEFAULT_BINS = 20
DEFAULT_TOP_M = 128
PSI_EPS = 1e-4


class DriftReference(NamedTuple):
    """A train-time health snapshot (see module docstring)."""

    score_edges: np.ndarray  # (B+1,) ascending score-bucket boundaries
    score_counts: np.ndarray  # (B,) reference score histogram
    bucket_p: np.ndarray  # (B,) sum of predicted p per score bucket
    bucket_y: np.ndarray  # (B,) sum of labels per score bucket
    top_ids: np.ndarray  # (M,) hottest ids, sorted ascending
    top_counts: np.ndarray  # (M+1,) their traffic counts + tail bucket
    num_features: int  # d — ids >= d are padding and never counted

    @property
    def num_bins(self) -> int:
        return self.score_counts.shape[0]

    @property
    def ratio(self) -> float:
        """The reference's overall calibration ratio."""
        return calibration_ratio(np.asarray([self.bucket_y.sum()]),
                                 np.asarray([self.bucket_p.sum()]))

    def bucket_ratios(self) -> np.ndarray:
        """Per-bucket reference calibration ratios (inf where a bucket
        saw no clicks)."""
        return np.array([
            calibration_ratio(np.asarray([sy]), np.asarray([sp]))
            for sy, sp in zip(self.bucket_y, self.bucket_p)])


def _score_bins(scores: np.ndarray, edges: np.ndarray) -> np.ndarray:
    """Bucket index per score; out-of-range clamps into the end bins."""
    return np.clip(np.searchsorted(edges, scores, side="right") - 1,
                   0, edges.size - 2).astype(np.int64)


def capture_reference(scores, labels, ids, *, num_features: int,
                      bins: int = DEFAULT_BINS,
                      top_m: int = DEFAULT_TOP_M) -> DriftReference:
    """Snapshot a held-out eval pass into a :class:`DriftReference`.

    ``scores``/``labels`` are the eval predictions p(y=1|x) and their
    labels; ``ids`` is the raw id traffic that produced them (any
    shape — user and ad id tensors concatenated and raveled; entries
    >= ``num_features`` are padding and are dropped). ``top_m`` caps
    the tracked hot head; everything else lands in one tail bucket.
    """
    scores = np.asarray(scores, np.float64).ravel()
    labels = np.asarray(labels, np.float64).ravel()
    if scores.size == 0:
        raise ValueError("capture_reference needs a non-empty eval pass")
    if scores.shape != labels.shape:
        raise ValueError(
            f"scores/labels disagree: {scores.shape} vs {labels.shape}")
    edges = np.linspace(0.0, 1.0, bins + 1)
    idx = _score_bins(scores, edges)
    score_counts = np.bincount(idx, minlength=bins).astype(np.int64)
    bucket_p = np.bincount(idx, weights=scores, minlength=bins)
    bucket_y = np.bincount(idx, weights=labels, minlength=bins)

    flat = np.asarray(ids).ravel()
    flat = flat[(flat >= 0) & (flat < num_features)].astype(np.int64)
    if flat.size == 0:
        raise ValueError("capture_reference saw no real (non-pad) ids")
    uniq, counts = np.unique(flat, return_counts=True)
    keep = min(top_m, uniq.size)
    hot = np.argsort(counts)[::-1][:keep]
    top_ids = np.sort(uniq[hot])
    order = np.searchsorted(np.sort(uniq[hot]), uniq[hot])
    top_counts = np.zeros(keep + 1, np.int64)
    top_counts[order] = counts[hot]
    top_counts[keep] = flat.size - counts[hot].sum()  # tail traffic
    return DriftReference(
        score_edges=edges, score_counts=score_counts,
        bucket_p=bucket_p, bucket_y=bucket_y,
        top_ids=top_ids.astype(np.int64), top_counts=top_counts,
        num_features=int(num_features))


# ------------------------------------------------------------ divergences
def _proportions(counts: np.ndarray, eps: float) -> np.ndarray:
    c = np.asarray(counts, np.float64)
    total = c.sum()
    if total <= 0:
        raise ValueError("divergence over an empty histogram")
    return np.clip(c / total, eps, None)


def psi(ref_counts: np.ndarray, cur_counts: np.ndarray,
        eps: float = PSI_EPS) -> float:
    """Population stability index between two count histograms (bucket
    proportions clipped at ``eps`` so empty buckets stay finite).
    Conventional reading: < 0.1 stable, 0.1-0.25 moderate shift,
    > 0.25 the population has drifted."""
    a = _proportions(ref_counts, eps)
    b = _proportions(cur_counts, eps)
    return float(np.sum((b - a) * np.log(b / a)))


def kl(ref_counts: np.ndarray, cur_counts: np.ndarray,
       eps: float = PSI_EPS) -> float:
    """KL(current || reference) over the same clipped proportions."""
    a = _proportions(ref_counts, eps)
    b = _proportions(cur_counts, eps)
    return float(np.sum(b * np.log(b / a)))


# --------------------------------------------------------- rolling window
class _RollingCounts:
    """Rolling bucket counts with chunked eviction: each ``add`` pushes
    one (n, bincount) chunk; once the total observation count exceeds
    ``capacity``, whole chunks are evicted oldest-first. The window
    therefore holds the most recent ~capacity observations without any
    per-item bookkeeping — every operation is O(buckets)."""

    def __init__(self, num_buckets: int, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._chunks: deque[tuple[int, np.ndarray]] = deque()
        self._counts = np.zeros(num_buckets, np.int64)
        self._total = 0

    def add(self, idx: np.ndarray) -> None:
        if idx.size == 0:
            return
        c = np.bincount(idx, minlength=self._counts.size).astype(np.int64)
        self._chunks.append((int(idx.size), c))
        self._counts += c
        self._total += int(idx.size)
        while self._total > self.capacity and len(self._chunks) > 1:
            n, old = self._chunks.popleft()
            self._counts -= old
            self._total -= n

    @property
    def total(self) -> int:
        return self._total

    @property
    def counts(self) -> np.ndarray:
        return self._counts


class ScoreDriftTracker:
    """Rolling serving-score histogram vs the reference: PSI and KL."""

    def __init__(self, ref: DriftReference, *, window: int = 4096,
                 min_count: int = 256):
        self.ref = ref
        self.min_count = int(min_count)
        self._roll = _RollingCounts(ref.num_bins, window)

    def update(self, scores) -> None:
        s = np.asarray(scores, np.float64).ravel()
        self._roll.add(_score_bins(s, self.ref.score_edges))

    @property
    def ready(self) -> bool:
        return self._roll.total >= self.min_count

    def psi(self) -> float | None:
        if not self.ready:
            return None
        return psi(self.ref.score_counts, self._roll.counts)

    def kl(self) -> float | None:
        if not self.ready:
            return None
        return kl(self.ref.score_counts, self._roll.counts)


class IdTrafficTracker:
    """Rolling top-id/tail traffic histogram vs the reference: PSI.

    Ids map onto the reference's sorted hot head by binary search; any
    id outside it (including ids the reference never saw) books into
    the tail bucket, and pad ids (>= num_features) are dropped — so the
    detector fires when the hot head COOLS, which is exactly what
    ``DayStream``'s planted rotation does."""

    def __init__(self, ref: DriftReference, *, window: int = 65536,
                 min_count: int = 1024):
        self.ref = ref
        self.min_count = int(min_count)
        self._top = np.asarray(ref.top_ids, np.int64)
        self._roll = _RollingCounts(self._top.size + 1, window)

    def update(self, ids) -> None:
        flat = np.asarray(ids).ravel().astype(np.int64)
        flat = flat[(flat >= 0) & (flat < self.ref.num_features)]
        if flat.size == 0:
            return
        pos = np.searchsorted(self._top, flat)
        pos_c = np.minimum(pos, self._top.size - 1)
        hit = self._top[pos_c] == flat
        idx = np.where(hit, pos_c, self._top.size)  # miss -> tail bucket
        self._roll.add(idx)

    @property
    def ready(self) -> bool:
        return self._roll.total >= self.min_count

    def psi(self) -> float | None:
        if not self.ready:
            return None
        return psi(self.ref.top_counts, self._roll.counts)


class CalibrationTracker:
    """Rolling calibration vs the reference, in score buckets.

    ``update(p, y)`` pushes one labeled prediction chunk; ``ratio()``
    is the overall rolling calibration ratio (the same
    ``eval/metrics.calibration_ratio`` arithmetic over the rolling
    sums) and ``max_bucket_deviation()`` the worst per-bucket
    ``|cur/ref - 1|`` over buckets where both sides saw clicks."""

    def __init__(self, ref: DriftReference, *, window: int = 4096,
                 min_count: int = 64, min_bucket: int = 32):
        self.ref = ref
        self.min_count = int(min_count)
        self.min_bucket = int(min_bucket)
        nb = ref.num_bins
        self._chunks: deque[tuple[int, np.ndarray, np.ndarray,
                                  np.ndarray]] = deque()
        self._capacity = int(window)
        self._sum_p = np.zeros(nb)
        self._sum_y = np.zeros(nb)
        self._n = np.zeros(nb, np.int64)
        self._total = 0

    def update(self, p, y) -> None:
        p = np.asarray(p, np.float64).ravel()
        y = np.asarray(y, np.float64).ravel()
        if p.shape != y.shape:
            raise ValueError(f"p/y disagree: {p.shape} vs {y.shape}")
        if p.size == 0:
            return
        nb = self.ref.num_bins
        idx = _score_bins(p, self.ref.score_edges)
        cp = np.bincount(idx, weights=p, minlength=nb)
        cy = np.bincount(idx, weights=y, minlength=nb)
        cn = np.bincount(idx, minlength=nb).astype(np.int64)
        self._chunks.append((p.size, cp, cy, cn))
        self._sum_p += cp
        self._sum_y += cy
        self._n += cn
        self._total += p.size
        while self._total > self._capacity and len(self._chunks) > 1:
            n, op, oy, on = self._chunks.popleft()
            self._sum_p -= op
            self._sum_y -= oy
            self._n -= on
            self._total -= n

    @property
    def ready(self) -> bool:
        return self._total >= self.min_count

    def ratio(self) -> float | None:
        """Rolling overall calibration ratio (None until warm, inf when
        the window holds no clicks — exactly ``calibration_ratio``)."""
        if not self.ready:
            return None
        return calibration_ratio(np.asarray([self._sum_y.sum()]),
                                 np.asarray([self._sum_p.sum()]))

    def max_bucket_deviation(self) -> float | None:
        """Worst ``|rolling_ratio / reference_ratio - 1|`` over buckets
        with >= ``min_bucket`` rolling observations and clicks on both
        sides; None when no bucket qualifies yet."""
        if not self.ready:
            return None
        ok = (self._n >= self.min_bucket) & (self._sum_y > 0) \
            & (self.ref.bucket_y > 0)
        if not ok.any():
            return None
        cur = self._sum_p[ok] / self._sum_y[ok]
        ref = self.ref.bucket_p[ok] / self.ref.bucket_y[ok]
        return float(np.abs(cur / ref - 1.0).max())


# ------------------------------------------------------------ persistence
def save_drift_reference(path: str, ref: DriftReference) -> str:
    """Write a standalone reference file (flat npz under a
    ``drift_ref/`` prefix — the same layout ``serve.compress.
    save_artifact(..., drift_ref=...)`` embeds next to an artifact).
    Returns the real path written (``.npz`` appended when missing)."""
    from repro.io import checkpoint

    return checkpoint.save(path, {"drift_ref": ref})


def load_drift_reference(path: str) -> DriftReference:
    """Load a reference from either a standalone file or an artifact
    file that embedded one; raises ``ValueError`` when the file carries
    no ``drift_ref/`` entries."""
    from repro.io import checkpoint

    data = checkpoint.load_nested(path)
    node = data.get("drift_ref")
    if node is None:
        raise ValueError(
            f"{path!r} carries no drift reference (train with --drift-ref, "
            f"or save_artifact(..., drift_ref=...))")
    missing = [f for f in DriftReference._fields if f not in node]
    if missing:
        raise ValueError(f"{path!r}: drift reference missing {missing}")
    return DriftReference(
        num_features=int(np.asarray(node["num_features"]).item()),
        **{f: np.asarray(node[f]) for f in DriftReference._fields
           if f != "num_features"})
