"""Offline run analytics: one report from one ledger, no recomputation.

``python -m repro.obs.report run.jsonl --format md|html [--out PATH]``
folds a run ledger's typed records (``repro.obs.ledger``) into a single
human-readable report:

  * run metadata (driver, mode, backend, argv);
  * the per-iteration convergence/nnz curve from ``train_iter``
    records, formatted with the EXACT format strings the drivers print
    (``render_train_iter``) — the report's numbers are bit-identical to
    the console lines of the run that wrote the ledger;
  * the next-day decay table from ``stream_eval`` records (the Fig. 7
    analogue), again with the drivers' own ``{:.4f}`` formatting;
  * streaming window/planner accounting from ``stream_window`` /
    ``stream_summary``;
  * serving latency percentiles, occupancy and the flush-reason mix
    from ``serve_dispatch`` records;
  * every ``alert`` state change the health monitor emitted.

Everything derives from ledger records alone — the report never touches
data, models or clocks, so it reproduces byte-for-byte from an archived
ledger (the CI observability job renders and archives it next to the
raw JSONL). Output is atomic (``repro.obs.fileio.atomic_write``): a
crash mid-render never leaves a truncated artifact.
"""
from __future__ import annotations

import argparse
import html
import sys

import numpy as np

from repro.obs.fileio import atomic_write
from repro.obs.ledger import read_jsonl, render_train_iter, validate_events


def build_report(events: list[dict]) -> dict:
    """Fold ledger records into the report's section dict (pure data —
    the renderers below turn it into md/html)."""
    by_kind: dict[str, list[dict]] = {}
    for e in events:
        by_kind.setdefault(e.get("kind", "?"), []).append(e)

    report: dict = {"records": len(events),
                    "kinds": {k: len(v) for k, v in sorted(by_kind.items())}}

    metas = by_kind.get("run_meta", [])
    if metas:
        m = metas[0]
        report["meta"] = {k: m[k] for k in
                          ("driver", "mode", "backend", "device_count",
                           "argv") if k in m}

    iters = by_kind.get("train_iter", [])
    if iters:
        report["convergence"] = {
            "iters": len(iters),
            "rows": [{"step": r["step"], "f_new": r["f_new"],
                      "nnz": r["nnz"], "alpha": r["alpha"],
                      **({"test_auc": r["test_auc"]} if "test_auc" in r
                         else {}),
                      "line": render_train_iter(r)} for r in iters],
            "f_first": iters[0]["f_new"], "f_last": iters[-1]["f_new"],
            "nnz_last": iters[-1]["nnz"],
        }

    evals = [r for r in by_kind.get("stream_eval", [])
             if "next_day_nll" in r]
    if evals:
        report["decay"] = [{"day": r["day"],
                            "next_day_nll": r["next_day_nll"],
                            "next_day_auc": r.get("next_day_auc")}
                           for r in evals]

    wins = by_kind.get("stream_window", [])
    if wins:
        report["windows"] = {
            "count": len(wins),
            "plan_s": sum(w["build_s"] for w in wins),
            "step_s": sum(w["step_s"] for w in wins),
            "prefetched": sum(1 for w in wins if w["prefetched"]),
        }
        summaries = by_kind.get("stream_summary", [])
        if summaries:
            report["windows"]["overlap_ratio"] = \
                summaries[-1]["overlap_ratio"]

    disp = by_kind.get("serve_dispatch", [])
    if disp:
        walls_us = np.array([d["wall_s"] for d in disp]) * 1e6
        delays_us = np.array([d["queue_delay_us"] for d in disp])
        mix: dict[str, dict] = {}
        for d in disp:
            row = mix.setdefault(d["flush_reason"],
                                 {"dispatches": 0, "requests": 0,
                                  "candidates": 0})
            row["dispatches"] += 1
            row["requests"] += d["requests"]
            row["candidates"] += d["candidates"]
        report["serving"] = {
            "dispatches": len(disp),
            "requests": sum(d["requests"] for d in disp),
            "candidates": sum(d["candidates"] for d in disp),
            "occupancy_mean":
                float(np.mean([d["occupancy"] for d in disp])),
            "wall_p50_us": float(np.percentile(walls_us, 50)),
            "wall_p99_us": float(np.percentile(walls_us, 99)),
            "queue_delay_p99_us": float(np.percentile(delays_us, 99)),
            "flush_mix": mix,
        }

    alerts = by_kind.get("alert", [])
    if alerts:
        report["alerts"] = [{k: a[k] for k in
                             ("rule", "state", "signal", "value",
                              "threshold", "op") if k in a}
                            for a in alerts]
    return report


# ------------------------------------------------------------- rendering
def _md_table(headers: list[str], rows: list[list[str]]) -> list[str]:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "---|" * len(headers)]
    out += ["| " + " | ".join(r) + " |" for r in rows]
    return out


def _sections(report: dict) -> list[tuple[str, list[str], list[list[str]]]]:
    """(title, headers, rows) per tabular section, shared by both
    renderers so md and html always agree on the numbers."""
    secs = []
    if "convergence" in report:
        conv = report["convergence"]
        secs.append(("Convergence", ["iter", "f", "alpha", "nnz", "test_auc"],
                     [[str(r["step"]), f"{r['f_new']:.2f}",
                       f"{r['alpha']:.3g}", str(r["nnz"]),
                       (f"{r['test_auc']:.4f}" if "test_auc" in r else "")]
                      for r in conv["rows"]]))
    if "decay" in report:
        secs.append(("Next-day decay", ["day", "next-day nll",
                                        "next-day auc"],
                     [[str(r["day"]), f"{r['next_day_nll']:.4f}",
                       (f"{r['next_day_auc']:.4f}"
                        if r["next_day_auc"] is not None else "")]
                      for r in report["decay"]]))
    if "serving" in report:
        s = report["serving"]
        secs.append(("Serving", ["metric", "value"], [
            ["dispatches", str(s["dispatches"])],
            ["requests", str(s["requests"])],
            ["candidates", str(s["candidates"])],
            ["occupancy (mean)", f"{s['occupancy_mean']:.3f}"],
            ["dispatch wall p50", f"{s['wall_p50_us']:,.0f} us"],
            ["dispatch wall p99", f"{s['wall_p99_us']:,.0f} us"],
            ["queue delay p99", f"{s['queue_delay_p99_us']:,.0f} us"],
        ]))
        secs.append(("Flush mix", ["reason", "dispatches", "requests",
                                   "candidates"],
                     [[reason, str(row["dispatches"]), str(row["requests"]),
                       str(row["candidates"])]
                      for reason, row in sorted(s["flush_mix"].items())]))
    if "windows" in report:
        w = report["windows"]
        rows = [["windows", str(w["count"])],
                ["host plan wall", f"{w['plan_s']:.2f} s"],
                ["device step wall", f"{w['step_s']:.2f} s"],
                ["prefetched windows", str(w["prefetched"])]]
        if "overlap_ratio" in w:
            rows.append(["overlap ratio", f"{w['overlap_ratio']:.2f}"])
        secs.append(("Streaming windows", ["metric", "value"], rows))
    if "alerts" in report:
        secs.append(("Alerts", ["rule", "state", "signal", "value",
                                "threshold"],
                     [[a["rule"], a["state"], a["signal"],
                       f"{a['value']:.6g}",
                       f"{a['op']} {a['threshold']:.6g}"]
                      for a in report["alerts"]]))
    else:
        secs.append(("Alerts", ["rule", "state", "signal", "value",
                                "threshold"], []))
    return secs


def render_md(report: dict) -> str:
    out = ["# Run report", ""]
    if "meta" in report:
        m = report["meta"]
        out.append("- driver: `%s`" % m.get("driver", "?"))
        for k in ("mode", "backend", "device_count"):
            if k in m:
                out.append(f"- {k}: `{m[k]}`")
        if m.get("argv"):
            out.append("- argv: `%s`" % " ".join(m["argv"]))
    out.append(f"- records: {report['records']} "
               f"({', '.join(f'{k}={v}' for k, v in report['kinds'].items())})")
    out.append("")
    for title, headers, rows in _sections(report):
        out.append(f"## {title}")
        out.append("")
        if rows:
            out += _md_table(headers, rows)
        else:
            out.append("_none_")
        out.append("")
    if "convergence" in report:
        out.append("## Console lines (reconstructed)")
        out.append("")
        out.append("```")
        out += [r["line"] for r in report["convergence"]["rows"]]
        out.append("```")
        out.append("")
    return "\n".join(out)


def render_html(report: dict) -> str:
    esc = html.escape
    out = ["<!doctype html><html><head><meta charset='utf-8'>",
           "<title>Run report</title>",
           "<style>body{font-family:sans-serif;margin:2em}"
           "table{border-collapse:collapse}"
           "td,th{border:1px solid #999;padding:4px 8px;"
           "font-variant-numeric:tabular-nums}"
           "th{background:#eee}</style></head><body>",
           "<h1>Run report</h1>"]
    if "meta" in report:
        m = report["meta"]
        items = "".join(
            f"<li>{esc(str(k))}: <code>{esc(str(m[k]))}</code></li>"
            for k in ("driver", "mode", "backend", "device_count", "argv")
            if k in m)
        out.append(f"<ul>{items}</ul>")
    out.append(f"<p>{report['records']} records</p>")
    for title, headers, rows in _sections(report):
        out.append(f"<h2>{esc(title)}</h2>")
        if not rows:
            out.append("<p><em>none</em></p>")
            continue
        head = "".join(f"<th>{esc(h)}</th>" for h in headers)
        body = "".join(
            "<tr>" + "".join(f"<td>{esc(c)}</td>" for c in row) + "</tr>"
            for row in rows)
        out.append(f"<table><tr>{head}</tr>{body}</table>")
    if "convergence" in report:
        lines = "\n".join(esc(r["line"])
                          for r in report["convergence"]["rows"])
        out.append(f"<h2>Console lines (reconstructed)</h2>"
                   f"<pre>{lines}</pre>")
    out.append("</body></html>")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="render one analytics report from a run-ledger JSONL "
                    "file (no recomputation: every number comes from the "
                    "ledger records)")
    ap.add_argument("ledger", help="run ledger (.jsonl) to analyse")
    ap.add_argument("--format", choices=("md", "html"), default="md")
    ap.add_argument("--out", default=None,
                    help="write here (atomic); default: stdout")
    args = ap.parse_args(argv)

    try:
        events = read_jsonl(args.ledger)
    except (OSError, ValueError) as e:
        print(f"FAIL {args.ledger}: {e}", file=sys.stderr)
        return 1
    errors = validate_events(events)
    if errors:
        for err in errors[:10]:
            print(f"FAIL {args.ledger}: {err}", file=sys.stderr)
        return 1
    if not events:
        print(f"FAIL {args.ledger}: empty ledger", file=sys.stderr)
        return 1

    report = build_report(events)
    text = render_md(report) if args.format == "md" else render_html(report)
    if args.out:
        with atomic_write(args.out) as f:
            f.write(text + "\n")
        print(f"report -> {args.out}")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
