"""Context-manager span tracing with Chrome-trace/Perfetto export.

A :class:`Tracer` records host-side spans — nested, thread-safe (the
:class:`~repro.stream.planner.WindowPlanner` background thread and the
trainer's main thread interleave into one timeline, separated by their
``tid``) — and exports the Chrome trace event format that
``chrome://tracing`` and https://ui.perfetto.dev load directly: one
``"ph": "X"`` complete event per span with microsecond ``ts``/``dur``
relative to the tracer's epoch, plus one ``"M"`` metadata event naming
each thread.

When ``annotate=True`` every span additionally enters a
``jax.profiler.TraceAnnotation`` (and :meth:`Tracer.step_span` a
``jax.profiler.StepTraceAnnotation``), so when a jax profiler trace is
active the host spans line up with the device timeline in the same
Perfetto view. Annotation is off by default — it costs a couple of
microseconds per span even with no profiler attached.

Disabled fast path: ``Tracer(enabled=False)`` (and the module's default
tracer until a launch driver configures ``--trace-out``) hands out one
shared no-op context manager — a span in cold code costs a method call
and nothing else.
"""
from __future__ import annotations

import json
import os
import threading
import time


class _NullSpan:
    """Shared no-op context manager for disabled tracers."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None


NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "args", "_t0", "_ann")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.args = args
        self._t0 = 0
        self._ann = None

    def __enter__(self) -> "_Span":
        if self._tracer.annotate:
            import jax

            self._ann = jax.profiler.TraceAnnotation(self.name)
            self._ann.__enter__()
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter_ns()
        if self._ann is not None:
            self._ann.__exit__(*exc)
        self._tracer._record(self.name, self._t0, t1, self.args)


class _StepSpan(_Span):
    """A span that also enters ``jax.profiler.StepTraceAnnotation`` so
    device work launched inside it is attributed to ``step_num``."""

    def __enter__(self) -> "_StepSpan":
        if self._tracer.annotate:
            import jax

            self._ann = jax.profiler.StepTraceAnnotation(
                self.name, step_num=self.args.get("step", 0))
            self._ann.__enter__()
        self._t0 = time.perf_counter_ns()
        return self


class Tracer:
    """Span recorder; see the module docstring."""

    def __init__(self, *, enabled: bool = True, annotate: bool = False):
        self.enabled = enabled
        self.annotate = annotate
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._epoch_ns = time.perf_counter_ns()
        self._pid = os.getpid()
        self._named_tids: set[int] = set()

    # ------------------------------------------------------------- recording
    def span(self, name: str, **args):
        """``with tracer.span("stream/plan", day=3): ...`` — records one
        complete event on exit. No-op (shared null span) when disabled."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, args)

    def step_span(self, name: str, step: int, **args):
        """A span for one optimizer/train step; with ``annotate=True``
        it uses ``StepTraceAnnotation`` so the profiler's device timeline
        groups the step's kernels under ``step``."""
        if not self.enabled:
            return NULL_SPAN
        return _StepSpan(self, name, {"step": step, **args})

    def _record(self, name: str, t0_ns: int, t1_ns: int, args: dict) -> None:
        tid = threading.get_ident()
        ev = {
            "name": name,
            "ph": "X",
            "ts": (t0_ns - self._epoch_ns) / 1e3,  # us
            "dur": (t1_ns - t0_ns) / 1e3,
            "pid": self._pid,
            "tid": tid,
        }
        if args:
            ev["args"] = args
        with self._lock:
            if tid not in self._named_tids:
                self._named_tids.add(tid)
                self._events.append({
                    "name": "thread_name", "ph": "M", "pid": self._pid,
                    "tid": tid,
                    "args": {"name": threading.current_thread().name},
                })
            self._events.append(ev)

    # --------------------------------------------------------------- export
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def to_chrome_trace(self) -> dict:
        """The Chrome trace event JSON document (Perfetto-loadable)."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def write(self, path: str) -> str:
        """Atomic (temp + ``os.replace``): a crash mid-export leaves the
        previous trace intact, never a truncated JSON document."""
        from repro.obs.fileio import atomic_write

        with atomic_write(path) as f:
            json.dump(self.to_chrome_trace(), f)
            f.write("\n")
        return path

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._named_tids.clear()


NULL_TRACER = Tracer(enabled=False)
_DEFAULT = NULL_TRACER


def get_tracer() -> Tracer:
    """The process default tracer — disabled until a driver configures
    ``--trace-out`` (see ``repro.obs.configure``)."""
    return _DEFAULT


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the process default tracer; returns the previous one."""
    global _DEFAULT
    prev, _DEFAULT = _DEFAULT, tracer
    return prev
