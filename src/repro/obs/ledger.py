"""Append-only structured run ledger: typed JSONL event records.

Every run of the training/streaming/serving stack can write one JSONL
file (``--ledger-out``) whose lines are typed event records — the
machine-readable twin of the drivers' human log lines:

  * ``train_iter``     one OWLQN+ iteration: objective before/after,
                       accepted step, direction norm (the Eq. 4
                       optimality measure), non-zero parameter count —
                       the paper's Fig. 5/6 convergence-vs-sparsity
                       curves replayed straight from the file;
  * ``stream_window``  one streaming window: plan/compile/total build
                       walls, exposed wait, prefetched flag, device
                       step wall, carry policy — the planner's overlap
                       ratio reconstructs from these records exactly;
  * ``stream_summary`` the planner's end-of-run overlap accounting;
  * ``serve_dispatch`` one engine dispatch: envelope key, group size,
                       occupancy, queue delay, measured wall, flush
                       reason;
  * ``alert``          one health-monitor state change (firing or
                       cleared): the rule, the signal value that
                       crossed it and the hysteresis shape — see
                       ``repro.obs.monitor``;
  * ``run_meta`` / ``stream_eval`` / ``log``  driver context, held-out
                       per-day quality, and free-text lines that keep
                       their human-readable rendering.

OBSERVERS: ``add_observer(fn)`` subscribes a callable to every record
the ledger accepts (the health monitor's live feed). Observers run on
the emitting thread AFTER the ledger lock is released, so an observer
may itself emit (the monitor's alert records) without deadlocking.

Records validate against :data:`SCHEMA` on emit (cheap dict checks) and
again offline: ``python -m repro.obs.ledger --check run.jsonl`` is the
CI smoke gate over archived ledgers. Unknown EXTRA fields are allowed
(forward compatibility); unknown KINDS, missing required fields and
type mismatches are errors.

The human lines the drivers print are renderers over these records
(:func:`render_train_iter`, :func:`render_stream_day`) or, for one-off
lines, ``log(text, ...)`` which emits a record carrying the exact text
it prints — structure and stable output from one call.

Disabled fast path: the module default is :data:`NULL_LEDGER`
(``enabled=False``, ``emit`` returns immediately); instrumented code
guards record construction behind ``ledger.enabled`` so an
un-configured run pays a single attribute load per would-be event.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time
from typing import Any, Callable, Iterator

_NUM = (int, float)

# kind -> {"required": {field: type(s)}, "optional": {field: type(s)}}.
# "text" (str) is implicitly optional on every kind: any record may
# carry the human line it rendered to.
SCHEMA: dict[str, dict[str, dict[str, Any]]] = {
    "run_meta": {
        "required": {"driver": str},
        "optional": {"mode": str, "backend": str, "device_count": int,
                     "argv": list},
    },
    "log": {
        "required": {"text": str},
        "optional": {},
    },
    "train_iter": {
        "required": {"step": int, "f": _NUM, "f_new": _NUM, "alpha": _NUM,
                     "grad_norm": _NUM, "nnz": int},
        "optional": {"ls_iters": int, "wall_s": _NUM, "day": int,
                     "window_iter": int, "test_auc": _NUM},
    },
    "stream_window": {
        "required": {"day": int, "days_in_window": int, "plan_s": _NUM,
                     "compile_s": _NUM, "build_s": _NUM, "wait_s": _NUM,
                     "prefetched": bool, "step_s": _NUM, "carry": str,
                     "alpha": _NUM, "nnz": int, "fs": list},
        "optional": {},
    },
    "stream_summary": {
        "required": {"windows": int, "build_seconds": _NUM,
                     "wait_seconds": _NUM, "prefetched_build_seconds": _NUM,
                     "prefetched_wait_seconds": _NUM, "overlap_ratio": _NUM},
        "optional": {},
    },
    "stream_eval": {
        "required": {"day": int},
        "optional": {"next_day_nll": _NUM, "next_day_auc": _NUM},
    },
    "serve_dispatch": {
        "required": {"envelope": list, "g": int, "requests": int,
                     "candidates": int, "occupancy": _NUM, "wall_s": _NUM,
                     "flush_reason": str, "queue_delay_us": _NUM},
        "optional": {},
    },
    "alert": {
        "required": {"rule": str, "state": str, "signal": str,
                     "value": _NUM, "threshold": _NUM},
        "optional": {"op": str, "breach_n": int, "clear_n": int, "day": int},
    },
}


def validate_event(event: Any) -> str | None:
    """One record's schema error string, or None when it validates."""
    if not isinstance(event, dict):
        return f"record is not an object: {event!r}"
    kind = event.get("kind")
    if kind not in SCHEMA:
        return f"unknown kind {kind!r} (known: {sorted(SCHEMA)})"
    spec = SCHEMA[kind]
    for field, typ in spec["required"].items():
        if field not in event:
            return f"{kind}: missing required field {field!r}"
        if not _type_ok(event[field], typ):
            return (f"{kind}.{field}: expected {_type_name(typ)}, "
                    f"got {type(event[field]).__name__}")
    for field, typ in spec["optional"].items():
        if field in event and not _type_ok(event[field], typ):
            return (f"{kind}.{field}: expected {_type_name(typ)}, "
                    f"got {type(event[field]).__name__}")
    if "text" in event and not isinstance(event["text"], str):
        return f"{kind}.text: expected str, got {type(event['text']).__name__}"
    if "t" in event and not isinstance(event["t"], float):
        return f"{kind}.t: expected float timestamp"
    return None


def _type_ok(value: Any, typ: Any) -> bool:
    if typ is bool:
        return isinstance(value, bool)
    if isinstance(value, bool):  # bool is an int subclass; keep kinds apart
        return False
    return isinstance(value, typ)


def _type_name(typ: Any) -> str:
    if isinstance(typ, tuple):
        return "/".join(t.__name__ for t in typ)
    return typ.__name__


class RunLedger:
    """Append-only event sink: in-memory list + optional JSONL file.

    ``emit`` validates (raise on schema violation — a malformed record
    is a bug at the emit site, not something to discover in CI), stamps
    ``t`` (unix seconds) and ``kind``, appends, and — when ``path`` is
    given — writes one JSON line immediately (line-buffered, so a
    crashed run still leaves a readable prefix). Thread-safe: planner
    threads and the main thread may emit concurrently.
    """

    enabled = True

    def __init__(self, path: str | None = None, *, keep: bool = True,
                 validate: bool = True):
        self.path = path
        self._keep = keep
        self._validate = validate
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._observers: list = []
        self._fh = None
        if path:
            parent = os.path.dirname(path)
            if parent:
                os.makedirs(parent, exist_ok=True)
            self._fh = open(path, "w", buffering=1)

    def emit(self, kind: str, **fields) -> dict:
        event = {"kind": kind, "t": time.time(), **fields}
        if self._validate:
            err = validate_event(event)
            if err is not None:
                raise ValueError(f"invalid ledger record: {err}")
        with self._lock:
            if self._keep:
                self._events.append(event)
            if self._fh is not None:
                self._fh.write(json.dumps(event, sort_keys=True) + "\n")
        # outside the lock: an observer may emit back into this ledger
        # (the monitor's alert records) without deadlocking
        for fn in list(self._observers):
            fn(event)
        return event

    def add_observer(self, fn) -> None:
        """Subscribe ``fn(event)`` to every accepted record (called on
        the emitting thread, after the record is stored/written)."""
        if fn not in self._observers:
            self._observers.append(fn)

    def remove_observer(self, fn) -> None:
        if fn in self._observers:
            self._observers.remove(fn)

    def events(self, kind: str | None = None) -> list[dict]:
        with self._lock:
            evs = list(self._events)
        if kind is None:
            return evs
        return [e for e in evs if e.get("kind") == kind]

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self) -> "RunLedger":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class NullLedger:
    """The disabled default: ``emit`` is one early return."""

    enabled = False
    path = None

    def emit(self, kind: str, **fields) -> None:
        return None

    def events(self, kind: str | None = None) -> list[dict]:
        return []

    def add_observer(self, fn) -> None:
        return None

    def remove_observer(self, fn) -> None:
        return None

    def close(self) -> None:
        return None


NULL_LEDGER = NullLedger()
_DEFAULT: RunLedger | NullLedger = NULL_LEDGER


def get_ledger() -> RunLedger | NullLedger:
    """The process default ledger — :data:`NULL_LEDGER` until a driver
    configures ``--ledger-out`` (see ``repro.obs.configure``)."""
    return _DEFAULT


def set_ledger(ledger: RunLedger | NullLedger) -> RunLedger | NullLedger:
    """Swap the process default ledger; returns the previous one."""
    global _DEFAULT
    prev, _DEFAULT = _DEFAULT, ledger
    return prev


def log(text: str, *, kind: str = "log", ledger=None,
        printer: Callable[[str], None] = print, **fields) -> None:
    """Structured logging: emit ``kind`` (with the rendered ``text`` and
    any structured ``fields``) to the run ledger AND print the exact
    same human line — the drivers' replacement for free-form print()."""
    led = ledger if ledger is not None else _DEFAULT
    if led.enabled:
        led.emit(kind, text=text, **fields)
    printer(text)


# ------------------------------------------------------------- renderers
def render_train_iter(rec: dict, *, nnz_width: int = 8) -> str:
    """The training drivers' per-iteration line, rendered from a
    ``train_iter`` record (``test_auc``/``wall_s`` included if present)."""
    out = (f"iter {rec['step']:3d}  f={rec['f_new']:12.2f} "
           f"alpha={rec['alpha']:.3g} nnz={rec['nnz']:{nnz_width}d}")
    if "test_auc" in rec:
        out += f" test_auc={rec['test_auc']:.4f} "
    if "wall_s" in rec:
        out += f" ({rec['wall_s'] * 1e3:.0f} ms/iter)"
    return out


def render_stream_day(rec: dict) -> str:
    """``launch/train --stream``'s per-day line from a ``stream_window``
    record (the held-out next-day suffix is the driver's own
    ``stream_eval`` record)."""
    return (f"day {rec['day']:3d}  window={rec['days_in_window']}d "
            f"f={rec['fs'][-1]:12.2f} alpha={rec['alpha']:.3g} "
            f"nnz={rec['nnz']:8d} plan={rec['build_s'] * 1e3:6.0f}ms "
            f"step={rec['step_s'] * 1e3:6.0f}ms")


# ----------------------------------------------------- offline validation
def read_jsonl(path: str) -> list[dict]:
    """Parse a ledger file back into records (raises on malformed JSON)."""
    out = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: not JSON: {e}") from e
    return out


def validate_events(events: Iterator[dict]) -> list[str]:
    """Schema errors over a record stream (empty list == valid)."""
    errors = []
    for i, ev in enumerate(events):
        err = validate_event(ev)
        if err is not None:
            errors.append(f"record {i}: {err}")
    return errors


def validate_file(path: str) -> list[str]:
    try:
        events = read_jsonl(path)
    except (OSError, ValueError) as e:
        return [str(e)]
    errs = validate_events(events)
    if not events:
        errs.append(f"{path}: empty ledger (no records)")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="validate run-ledger JSONL files against the typed "
                    "event schema (the CI obs smoke gate)")
    ap.add_argument("paths", nargs="+", help="ledger .jsonl file(s)")
    ap.add_argument("--check", action="store_true",
                    help="accepted for symmetry; validation is the only "
                         "mode")
    args = ap.parse_args(argv)
    rc = 0
    for path in args.paths:
        errors = validate_file(path)
        if errors:
            rc = 1
            for err in errors[:20]:
                print(f"FAIL {path}: {err}", file=sys.stderr)
            more = len(errors) - 20
            if more > 0:
                print(f"FAIL {path}: ... and {more} more", file=sys.stderr)
        else:
            events = read_jsonl(path)
            kinds: dict[str, int] = {}
            for e in events:
                kinds[e["kind"]] = kinds.get(e["kind"], 0) + 1
            summary = ", ".join(f"{k}={v}" for k, v in sorted(kinds.items()))
            print(f"ledger OK: {path} ({len(events)} records: {summary})")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
