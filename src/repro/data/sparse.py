"""Sparse feature substrate — the paper's actual input format.

Production CTR features are one-hot/multi-hot IDs: each sample has a
small set of active feature ids (tens) out of millions of columns. Dense
(B, d) matrices waste d/active memory and FLOPs. We store padded COO per
sample:

    ids  (B, K) int32   active column ids (pad with id = d, weight 0)
    vals (B, K) float32 feature values

and compute z = x @ Theta as a gather + weighted segment-sum:
    z[b] = sum_k vals[b,k] * Theta[ids[b,k], :]

This is TPU-native (dense gather + reductions — no hash maps, DESIGN.md
§3), exactly how embedding lookups work in production CTR systems.

Execution path: everything here rides the FUSED sparse kernel package
(``repro.kernels.lsplm_sparse_fused``) — a pipelined block-DMA Pallas
gather-matmul on TPU (scalar-prefetched ids, double-buffered K-row
blocks), a K-chunked ``lax.scan`` accumulation elsewhere, and a
``jax.custom_vjp`` whose backward is the transposed scatter. The old
``take``+einsum formulation, which materialises the (N, K, 2m) gather
intermediate in HBM, lives on as the oracle in that package's ``ref.py``.

Transpose plans: the backward's id->entries transposition (a sort) is
data-dependent but BATCH-constant, so it is precomputed here, once per
batch, as a :class:`TransposePlan` (``build_transpose_plan`` /
``build_batch_plans``) and carried on the batch. With a plan attached
the per-step backward is pure gathers + segment sums — no sort, no
scatter — on every backend (``repro.kernels.lsplm_sparse_scatter``).
Batches without plans still work (scan-chunked scatter fallback).

The common-feature trick composes: user ids are stored once per session
(G, Ku) and gathered per sample, ad ids per sample (B, Ka).

Distribution composes too: ``build_batch_plans(shards=...)`` /
``generate_sparse(shards=...)`` route the batch for a (data x model)
mesh — ids bucketed per id-range Theta shard, plans sliced per shard
from the one sort already paid — returning a
``repro.shard.ShardedSparseBatch`` for the ``shard_map`` training step
(``repro.shard.step``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objective import nll_sparse
from repro.kernels.lsplm_sparse_fused.ops import (  # noqa: F401 (pad_theta re-exported)
    pad_theta,
    sparse_gather_matmul,
)
from repro.kernels.lsplm_sparse_scatter.ops import (  # noqa: F401 (re-export)
    TransposePlan,
    build_transpose_plan,
)


class SparseCTRBatch(NamedTuple):
    """Sparse analogue of CommonFeatureBatch (padded COO)."""

    user_ids: jax.Array  # (G, Ku) int32, pad = num_features
    user_vals: jax.Array  # (G, Ku)
    ad_ids: jax.Array  # (B, Ka)
    ad_vals: jax.Array  # (B, Ka)
    session_id: jax.Array  # (B,)
    y: jax.Array  # (B,)
    num_features: int = 0  # d (static)
    # precomputed backward transpose plans (None -> scan-chunked fallback)
    user_plan: TransposePlan | None = None
    ad_plan: TransposePlan | None = None


def _route(batch: "SparseCTRBatch", shards, data_shards: int):
    """Coerce ``shards`` (count or Partition) and route the batch for a
    (data x model) mesh — the one place the shards= paths share."""
    # local import: repro.shard builds on this module
    from repro.shard.partition import Partition, make_partition, route_batch

    part = shards if isinstance(shards, Partition) else make_partition(
        batch.num_features, int(shards))
    return route_batch(batch, part, data_shards=data_shards)


def build_batch_plans(batch: "SparseCTRBatch", *, shards=None,
                      data_shards: int = 1):
    """Attach per-batch transpose plans (one argsort per id tensor, on
    the host, once) so every optimizer step's backward is sort-free.
    Plans address the PADDED Theta (d + 1 rows, pad id == d).

    With ``shards`` (a shard count or a ``repro.shard.Partition``) the
    planned batch is additionally ROUTED for a (data x model) mesh and a
    ``repro.shard.ShardedSparseBatch`` is returned instead: ids bucketed
    per id-range shard, the freshly built plans sliced per (data block,
    id range) — the argsort is NOT redone per shard — and stacked for
    ``shard_map`` (see ``repro.shard``).
    """
    rows = batch.num_features + 1
    batch = batch._replace(
        user_plan=build_transpose_plan(
            np.asarray(batch.user_ids), rows, pad_id=batch.num_features),
        ad_plan=build_transpose_plan(
            np.asarray(batch.ad_ids), rows, pad_id=batch.num_features),
    )
    if shards is None:
        return batch
    return _route(batch, shards, data_shards)


def sparse_matmul(ids: jax.Array, vals: jax.Array, theta: jax.Array,
                  *, mode: str = "auto",
                  plan: TransposePlan | None = None) -> jax.Array:
    """(N, K) ids/vals  x  Theta (d+1, 2m) -> (N, 2m), FUSED.

    Theta must carry ONE trailing pad row (all zeros) so pad ids hit it
    (``pad_theta``). Dispatches to the pipelined Pallas kernel on TPU
    and the chunked jnp path elsewhere; differentiable via the
    transposed-scatter custom VJP either way (plan-driven when ``plan``
    is given).
    """
    return sparse_gather_matmul(ids, vals, theta, mode=mode, plan=plan)


def sparse_nll(theta: jax.Array, batch: SparseCTRBatch) -> jax.Array:
    """Eq. 5 on sparse features with the common-feature trick (Eq. 13):
    user dot-products computed ONCE per session, gathered per sample.
    Delegates to the fused-kernel path in ``repro.core.objective``."""
    return nll_sparse(theta, batch)


def sparse_loss_and_grad(theta: jax.Array, batch: SparseCTRBatch):
    return jax.value_and_grad(sparse_nll)(theta, batch)


def sparse_predict(theta: jax.Array, batch: SparseCTRBatch) -> jax.Array:
    """p(y=1|x) for a session-structured sparse batch — delegates to the
    unified inference layer's session-shared path (``repro.serve``), the
    same code that serves online traffic (model polymorphic: pass a
    pruned ``ServingArtifact`` instead of Theta and it still works)."""
    from repro.serve.score import predict

    return predict(theta, batch)


def sparse_predict_flat(theta: jax.Array, ids: jax.Array, vals: jax.Array,
                        *, mode: str = "auto") -> jax.Array:
    """p(y=1|x) for flat (sessionless) padded-COO rows — the serving hot
    path (``repro.serve.score.score_sparse``), fully fused down to the
    (N,) probabilities."""
    from repro.serve.score import score_sparse

    return score_sparse(theta, ids, vals, mode=mode)


# ----------------------------------------------------------------- generator
def planted_id_weight(ids: np.ndarray, salt: int) -> np.ndarray:
    """Deterministic latent weight per feature id (hash of the id), so a
    hot id keeps stable semantics across batches, splits and DAYS — the
    invariant that makes drifted multi-day streams learnable."""
    h = (np.asarray(ids).astype(np.uint64) * np.uint64(2654435761)
         + np.uint64(salt))
    return (((h % np.uint64(10007)).astype(np.float64) / 10007.0) * 4.0
            - 2.0).astype(np.float32)


def planted_ctr_labels(user_ids, user_vals, ad_ids, ad_vals, session_id,
                       rng: np.random.Generator) -> np.ndarray:
    """Sample click labels from the shared piecewise-linear ground truth
    (Eq. 2 family): every id carries a latent hashed weight
    (:func:`planted_id_weight`); the USER side selects one of 4 latent
    regions which modulates the ad-side weights. Used by both the
    full-batch generator (``generate_sparse``) and the day-sliced stream
    (``repro.stream.source.DayStream``) so their labels agree wherever
    their id draws do."""
    regions = 4
    session_id = np.asarray(session_id)
    region_score = np.stack([
        (user_vals * planted_id_weight(user_ids, 31 * (r + 1))).sum(-1)
        for r in range(regions)], axis=-1)  # (G, regions)
    region = np.argmax(region_score, axis=-1)[session_id]  # (B,)
    gains = np.asarray([2.5, -2.5, 1.0, -1.0], np.float32)[region]
    base = (ad_vals * planted_id_weight(ad_ids, 7)).sum(-1) \
        + 0.5 * (user_vals * planted_id_weight(user_ids, 13)).sum(-1)[session_id]
    logits = gains * base
    p = 1 / (1 + np.exp(-logits))
    return (rng.random(session_id.shape[0]) < p).astype(np.float32)


def generate_sparse(
    num_features: int = 1_000_000,
    num_user_features_range: tuple[int, int] = (600_000, 1_000_000),
    sessions: int = 512,
    ads_per_session: int = 4,
    active_user: int = 24,
    active_ad: int = 12,
    seed: int = 0,
    with_plans: bool = True,
    shards=None,
    data_shards: int = 1,
) -> SparseCTRBatch:
    """Million-column sparse CTR batch with session structure. Ground
    truth: piecewise-linear over a planted low-dim projection of the
    active ids (so LS-PLM has signal without densifying anything).

    ``shards`` (a model-shard count or ``repro.shard.Partition``) routes
    the batch for a (data x model) mesh and returns a
    ``repro.shard.ShardedSparseBatch`` — see ``build_batch_plans``.
    """
    rng = np.random.default_rng(seed)
    d = num_features
    G, A = sessions, ads_per_session
    B = G * A
    user_lo = num_user_features_range[0]

    def zipf_ids(lo, hi, shape):
        """Power-law id draws: hot ids recur across splits (real CTR
        feature traffic is Zipf — uniform draws over millions of columns
        would make train/test supports disjoint and learning impossible)."""
        u = rng.random(shape)
        r = (hi - lo) * (u ** 10.0)  # very hot head at lo (CTR id traffic)
        return (lo + r).astype(np.int64)

    user_ids = zipf_ids(user_lo, d, (G, active_user))
    ad_ids = zipf_ids(0, user_lo, (B, active_ad))
    user_vals = rng.normal(size=(G, active_user)).astype(np.float32) / np.sqrt(active_user)
    ad_vals = rng.normal(size=(B, active_ad)).astype(np.float32) / np.sqrt(active_ad)
    session_id = np.repeat(np.arange(G, dtype=np.int32), A)

    # planted truth shared with the streaming generator (see
    # planted_ctr_labels): hashed per-id weights + 4 user-selected regions
    y = planted_ctr_labels(user_ids, user_vals, ad_ids, ad_vals,
                           session_id, rng)

    batch = SparseCTRBatch(
        user_ids=jnp.asarray(user_ids, jnp.int32),
        user_vals=jnp.asarray(user_vals),
        ad_ids=jnp.asarray(ad_ids, jnp.int32),
        ad_vals=jnp.asarray(ad_vals),
        session_id=jnp.asarray(session_id),
        y=jnp.asarray(y),
        num_features=d,
    )
    if with_plans:
        return build_batch_plans(batch, shards=shards,
                                 data_shards=data_shards)
    if shards is not None:  # routed, scan-chunked fallback backward
        return _route(batch, shards, data_shards)
    return batch


def to_dense(batch: SparseCTRBatch) -> np.ndarray:
    """Densify (tests only — production never does this)."""
    d = batch.num_features
    G = np.asarray(batch.user_ids).shape[0]
    B = np.asarray(batch.ad_ids).shape[0]
    x = np.zeros((B, d), np.float32)
    uid = np.asarray(batch.user_ids)[np.asarray(batch.session_id)]
    uval = np.asarray(batch.user_vals)[np.asarray(batch.session_id)]
    np.add.at(x, (np.arange(B)[:, None], uid), uval)
    np.add.at(x, (np.arange(B)[:, None], np.asarray(batch.ad_ids)),
              np.asarray(batch.ad_vals))
    return x
