"""Common-feature trick (§3.2) batch utilities.

The trick has three production aspects (paper list, §3.2):
  1. group samples of one session on the same worker,
  2. store common features once,
  3. compute the common part of Theta^T x once per session.

``shard_sessions`` implements (1) for the data-parallel mesh axis: sessions
are assigned to workers as whole units so the per-worker gather stays local.
(2)/(3) live in the ``CommonFeatureBatch`` format + ``nll_common_feature``.
"""
from __future__ import annotations

import numpy as np

from repro.core.objective import CommonFeatureBatch


def memory_bytes(batch: CommonFeatureBatch, compressed: bool) -> int:
    """Storage cost of the two formats (Table 3 'Memory cost/node')."""
    xc = np.asarray(batch.x_common)
    xnc = np.asarray(batch.x_noncommon)
    sid = np.asarray(batch.session_id)
    if compressed:
        return xc.nbytes + xnc.nbytes + sid.nbytes
    # decompressed: user block replicated per sample
    return xc.dtype.itemsize * xnc.shape[0] * xc.shape[1] + xnc.nbytes


def flops_per_eval(batch: CommonFeatureBatch, m: int, compressed: bool) -> int:
    """Dot-product FLOPs of one loss/grad evaluation (Table 3 'Time/iter').

    Common part: 2 * G * d_c * 2m (once per session) vs 2 * B * d_c * 2m.
    """
    G, d_c = np.asarray(batch.x_common).shape
    B, d_nc = np.asarray(batch.x_noncommon).shape
    common_rows = G if compressed else B
    return 2 * (common_rows * d_c + B * d_nc) * 2 * m


def shard_sessions(batch: CommonFeatureBatch, num_shards: int) -> list[CommonFeatureBatch]:
    """Partition a compressed batch into per-worker batches, keeping
    sessions whole (aspect 1). Sessions are dealt round-robin by size
    balance; session_ids are re-indexed locally."""
    sid = np.asarray(batch.session_id)
    G = int(sid.max()) + 1 if sid.size else 0
    assignment = np.arange(G) % num_shards
    shards = []
    for s in range(num_shards):
        sessions = np.nonzero(assignment == s)[0]
        remap = -np.ones(G, dtype=np.int64)
        remap[sessions] = np.arange(len(sessions))
        mask = np.isin(sid, sessions)
        shards.append(
            CommonFeatureBatch(
                x_common=np.asarray(batch.x_common)[sessions],
                x_noncommon=np.asarray(batch.x_noncommon)[mask],
                session_id=remap[sid[mask]].astype(np.int32),
                y=np.asarray(batch.y)[mask],
            )
        )
    return shards


def pad_to_multiple(batch: CommonFeatureBatch, multiple: int) -> CommonFeatureBatch:
    """Pad samples (weight-0) so B divides the data axis — SPMD needs equal
    shards; padding carries zero weight so the loss is unchanged."""
    B = np.asarray(batch.y).shape[0]
    pad = (-B) % multiple
    w = np.ones(B, dtype=np.float32)
    if pad == 0 and batch.weight is None:
        return CommonFeatureBatch(*batch[:4], weight=w)
    xnc = np.asarray(batch.x_noncommon)
    return CommonFeatureBatch(
        x_common=np.asarray(batch.x_common),
        x_noncommon=np.concatenate([xnc, np.zeros((pad, xnc.shape[1]), xnc.dtype)]),
        session_id=np.concatenate([np.asarray(batch.session_id), np.zeros(pad, np.int32)]),
        y=np.concatenate([np.asarray(batch.y), np.zeros(pad, np.float32)]),
        weight=np.concatenate([w, np.zeros(pad, np.float32)]),
    )
