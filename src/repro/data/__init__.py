from repro.data.synthetic_ctr import (  # noqa: F401
    CTRDataConfig,
    auc,
    generate,
    to_dense_batch,
    train_val_test,
)
from repro.data.common_feature import (  # noqa: F401
    flops_per_eval,
    memory_bytes,
    pad_to_multiple,
    shard_sessions,
)
from repro.data.sparse import (  # noqa: F401
    SparseCTRBatch,
    TransposePlan,
    build_batch_plans,
    build_transpose_plan,
    generate_sparse,
    pad_theta,
    sparse_loss_and_grad,
    sparse_matmul,
    sparse_nll,
    sparse_predict,
    sparse_predict_flat,
)
from repro.data.tokens import TokenStream, host_sharded_stream  # noqa: F401
