"""Synthetic sparse CTR data with the paper's session / common-feature
structure (simulating the Alibaba production gate — DESIGN.md §8).

Generative story (mirrors §3.2 / Fig. 3):
  * A *session* = one user page-view showing ``ads_per_session`` ads.
  * User features (profile + behaviour) are COMMON across the session's
    samples; ad features are per-sample.
  * Ground-truth click probability is PIECEWISE-LINEAR: the user vector
    selects one of ``true_regions`` latent regions (argmax of a linear
    gating), and each region has its own linear logit over the full
    feature vector — i.e. exactly the function class LS-PLM (but not LR)
    can represent. A fraction of features is pure noise so that L1/L2,1
    feature selection has signal to find.

Features are one-hot/multi-hot sparse in production; we emit dense float
arrays whose columns are sparse Bernoulli activations scaled to unit
variance — same statistics, JAX-friendly.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.objective import CommonFeatureBatch, CTRBatch


@dataclasses.dataclass(frozen=True)
class CTRDataConfig:
    num_user_features: int = 48  # common block d_c
    num_ad_features: int = 48  # per-sample block d_nc
    density: float = 0.15  # fraction of active features per sample
    true_regions: int = 4  # ground-truth piecewise regions
    noise_features: int = 16  # appended pure-noise columns (in ad block)
    ads_per_session: int = 4
    label_noise: float = 0.02
    seed: int = 0

    @property
    def num_features(self) -> int:
        return self.num_user_features + self.num_ad_features + self.noise_features


def _sparse_block(rng: np.random.Generator, n: int, d: int, density: float) -> np.ndarray:
    mask = rng.random((n, d)) < density
    vals = rng.normal(size=(n, d)) / np.sqrt(max(density * d, 1.0))
    return (mask * vals).astype(np.float32)


class PiecewiseLinearTruth:
    """The planted ground-truth model."""

    def __init__(self, cfg: CTRDataConfig, rng: np.random.Generator):
        d = cfg.num_features
        du = cfg.num_user_features
        self.gate = rng.normal(size=(du, cfg.true_regions)).astype(np.float32)
        w = rng.normal(size=(d, cfg.true_regions)).astype(np.float32) * 2.0
        # noise features carry no signal
        if cfg.noise_features:
            w[-cfg.noise_features:, :] = 0.0
        self.w = w
        self.bias = rng.normal(size=(cfg.true_regions,)).astype(np.float32) * 0.5
        self.du = du

    def proba(self, x: np.ndarray) -> np.ndarray:
        region = np.argmax(x[:, : self.du] @ self.gate, axis=-1)
        logits = np.einsum("nd,dn->n", x, self.w[:, region]) + self.bias[region]
        return 1.0 / (1.0 + np.exp(-logits))


def generate(
    cfg: CTRDataConfig, num_sessions: int, seed: int | None = None
) -> tuple[CommonFeatureBatch, np.ndarray]:
    """Returns (compressed common-feature batch, dense x for reference).

    The compressed batch stores user features once per session (G rows);
    the dense x materialises them per sample (B = G * ads_per_session rows)
    — exactly the two storage formats of Table 3.
    """
    rng = np.random.default_rng(cfg.seed if seed is None else seed)
    # The planted truth depends ONLY on cfg.seed so that different splits
    # ("days", Table 1) share one ground-truth model.
    truth = PiecewiseLinearTruth(cfg, np.random.default_rng(cfg.seed + 7919))
    G, A = num_sessions, cfg.ads_per_session
    B = G * A
    x_user = _sparse_block(rng, G, cfg.num_user_features, cfg.density)
    x_ad = _sparse_block(rng, B, cfg.num_ad_features, cfg.density)
    x_noise = _sparse_block(rng, B, cfg.noise_features, cfg.density)
    x_nc = np.concatenate([x_ad, x_noise], axis=1)
    session_id = np.repeat(np.arange(G, dtype=np.int32), A)

    x_dense = np.concatenate([x_user[session_id], x_nc], axis=1)
    p = truth.proba(x_dense)
    p = (1 - cfg.label_noise) * p + cfg.label_noise * 0.5
    y = (rng.random(B) < p).astype(np.float32)

    batch = CommonFeatureBatch(
        x_common=x_user, x_noncommon=x_nc, session_id=session_id, y=y
    )
    return batch, x_dense


def to_dense_batch(batch: CommonFeatureBatch) -> CTRBatch:
    """Decompress (the 'Without CF' storage format of Table 3)."""
    x = np.concatenate(
        [np.asarray(batch.x_common)[np.asarray(batch.session_id)],
         np.asarray(batch.x_noncommon)], axis=1
    )
    return CTRBatch(x=x, y=np.asarray(batch.y))


def train_val_test(
    cfg: CTRDataConfig, sessions: tuple[int, int, int], seed: int = 0
):
    """Disjoint 'days' as in Table 1 (7:1:1 style splits are the caller's
    choice of session counts)."""
    out = []
    for i, n in enumerate(sessions):
        out.append(generate(cfg, n, seed=seed * 1000 + i))
    return out


# canonical implementation lives with the other metrics; re-exported here
# because every data consumer historically imported it from this module
from repro.eval.metrics import auc  # noqa: F401, E402
