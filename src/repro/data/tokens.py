"""Synthetic LM token pipeline for the transformer substrate.

Deterministic Zipf-distributed token streams with next-token structure
(bigram mixing) so train steps have a learnable signal; host-sharded
loading mirrors how each data-parallel worker would read its own files.
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab_size: int, seed: int = 0, zipf_a: float = 1.2):
        self.vocab_size = vocab_size
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = ranks ** -zipf_a
        self.p = p / p.sum()
        # a fixed random bigram successor table gives next-token signal
        self.successor = self.rng.integers(0, vocab_size, size=vocab_size)

    def batch(self, batch_size: int, seq_len: int) -> dict:
        base = self.rng.choice(self.vocab_size, size=(batch_size, seq_len),
                               p=self.p)
        # with prob 0.5 each token is the deterministic successor of the
        # previous one -> learnable bigram structure
        follow = self.rng.random((batch_size, seq_len)) < 0.5
        toks = base.copy()
        toks[:, 1:] = np.where(follow[:, 1:],
                               self.successor[toks[:, :-1]], base[:, 1:])
        tokens = toks[:, :-1] if seq_len > 1 else toks
        labels = toks[:, 1:] if seq_len > 1 else toks
        return {"tokens": tokens.astype(np.int32),
                "labels": labels.astype(np.int32)}


def host_sharded_stream(vocab_size: int, num_hosts: int, host_id: int,
                        seed: int = 0) -> TokenStream:
    """Each host reads a disjoint stream (data parallel input pipeline)."""
    return TokenStream(vocab_size, seed=seed * num_hosts + host_id)
