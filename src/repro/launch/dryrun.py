import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: prove every (architecture x input shape) lowers and
compiles on the production meshes, and extract roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single --out results.json
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi

For each combo we jit with explicit in/out shardings, .lower() on
ShapeDtypeStructs (no allocation), .compile(), then record
memory_analysis() / cost_analysis() / collective bytes parsed from the
compiled HLO.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (
    INPUT_SHAPES,
    decode_cache_len,
    get_config,
    input_specs,
    list_archs,
    uses_sliding_window,
)
from repro.launch.mesh import data_axes, make_production_mesh
from repro.models import (
    init_caches,
    init_model,
    make_serve_step,
    make_train_step,
    param_specs,
    prefill,
)
from repro.models.transformer import cache_specs
from repro.utils.hlo import collective_bytes
from repro.utils.roofline import Roofline, model_flops_per_chip


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _cast_tree(tree, dtype):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype),
        tree,
    )


def _batch_spec_tree(batch, dp):
    """Batch-dim sharding for every input leaf."""
    def spec(s):
        if s.shape and s.shape[0] > 1:
            return P(dp, *([None] * (len(s.shape) - 1)))
        return P(*([None] * len(s.shape)))

    return jax.tree.map(spec, batch)


def lower_combo(cfg, shape_name: str, mesh, serve_dtype=jnp.bfloat16,
                moe_serving_mode: str = "weight_gather"):
    """Build + lower + compile one (cfg x shape x mesh) combo.

    Returns (lowered, compiled, meta) — meta has tokens processed.
    """
    spec = INPUT_SHAPES[shape_name]
    kind = spec["kind"]
    B, S = spec["global_batch"], spec["seq_len"]
    dp = data_axes(mesh)
    pspec = param_specs(cfg)
    batch = input_specs(cfg, shape_name)

    if kind == "train":
        params_s = jax.eval_shape(lambda: init_model(cfg, jax.random.PRNGKey(0)))
        opt, train_step = make_train_step(cfg, mesh=mesh)
        opt_s = jax.eval_shape(opt.init, params_s)
        from repro.optim.adamw import AdamWState
        ospec = AdamWState(mu=pspec, nu=pspec, count=P())
        bspec = _batch_spec_tree(batch, dp)
        jitted = jax.jit(
            train_step,
            in_shardings=(_ns(mesh, pspec), _ns(mesh, ospec), _ns(mesh, bspec)),
            out_shardings=(_ns(mesh, pspec), _ns(mesh, ospec), None),
        )
        lowered = jitted.lower(params_s, opt_s, batch)
        tokens = B * S
    elif kind == "prefill":
        params_s = _cast_tree(
            jax.eval_shape(lambda: init_model(cfg, jax.random.PRNGKey(0))),
            serve_dtype,
        )
        bspec = _batch_spec_tree(batch, dp)

        def prefill_step(params, batch):
            return prefill(params, cfg, mesh=mesh, **batch)

        cspec = cache_specs(cfg, batch_sharded=True, dp=dp, model_size=mesh.shape["model"])
        jitted = jax.jit(
            prefill_step,
            in_shardings=(_ns(mesh, pspec), _ns(mesh, bspec)),
            out_shardings=(None, _ns(mesh, cspec)),
        )
        lowered = jitted.lower(params_s, batch)
        tokens = B * S
    else:  # decode
        params_s = _cast_tree(
            jax.eval_shape(lambda: init_model(cfg, jax.random.PRNGKey(0))),
            serve_dtype,
        )
        window = uses_sliding_window(cfg, shape_name)
        cache_len = decode_cache_len(cfg, shape_name)
        batch_sharded = B > 1
        caches_s = jax.eval_shape(
            lambda: init_caches(cfg, B, cache_len, dtype=serve_dtype))
        cspec = cache_specs(cfg, batch_sharded=batch_sharded, dp=dp, model_size=mesh.shape["model"])
        serve_step = make_serve_step(cfg, mesh=mesh, window=window,
                                     batch_sharded=batch_sharded,
                                     moe_serving_mode=moe_serving_mode)
        tok = batch.get("token", batch.get("embed"))
        tok_spec = P(dp) if (batch_sharded and tok.ndim >= 1) else P(
            *([None] * tok.ndim))
        jitted = jax.jit(
            serve_step,
            in_shardings=(_ns(mesh, pspec), _ns(mesh, cspec),
                          NamedSharding(mesh, tok_spec), None),
            out_shardings=(None, _ns(mesh, cspec)),
        )
        lowered = jitted.lower(params_s, caches_s, tok,
                               jax.ShapeDtypeStruct((), jnp.int32))
        tokens = B  # one new token per sequence
    compiled = lowered.compile()
    return lowered, compiled, {"tokens": tokens, "kind": kind,
                               "window": kind == "decode" and
                               uses_sliding_window(cfg, shape_name)}


def _costs(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax<=0.4 returns one dict per device
        ca = ca[0]
    coll = collective_bytes(compiled.as_text())
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            float(coll["total_bytes"]), coll)


def _ssm_scan_corrections(cfg, shape_name, chips):
    """Mamba1's per-timestep selective scan is a while loop whose body
    cost_analysis counts once; no matmul factorisation exists (DESIGN.md
    §4), so we model it analytically with the Pallas-kernel streaming
    model: state lives in VMEM, inputs/outputs stream from HBM once.

      flops  ~= 8 * B*S*di*N   per layer (exp, h update, C reduction)
      bytes  ~= 4 * B*S*di * 4 per layer (dt,x in + y out + misc, fp32)

    Mamba2's SSD path is matmul-form (honest under unrolling) except the
    tiny inter-chunk state pass, corrected the same way."""
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0, 0.0
    spec = INPUT_SHAPES[shape_name]
    if spec["kind"] == "decode":
        return 0.0, 0.0  # decode steps are loop-free (honest)
    B, S = spec["global_batch"], spec["seq_len"]
    di, N = cfg.d_inner, cfg.ssm_state
    L = cfg.num_layers
    mult = 3 if spec["kind"] == "train" else 1  # fwd+bwd ~ 3x fwd
    if cfg.family == "ssm":  # mamba1 per-step scan
        flops = 8.0 * B * S * di * N * L * mult
        bytes_ = 4.0 * B * S * di * 4 * L * mult
    else:  # mamba2: only inter-chunk state pass (nc steps)
        nh, p = di // cfg.ssm_headdim, cfg.ssm_headdim
        nc = S // cfg.ssd_chunk
        flops = 3.0 * B * nc * nh * p * N * L * mult
        bytes_ = 2.0 * B * nc * nh * p * N * 4 * L * mult
    return flops / chips, bytes_ / chips


def extrapolated_costs(cfg, shape_name, mesh, chips, **lower_kwargs):
    """XLA's cost_analysis counts while-loop (scan) bodies ONCE regardless
    of trip count. We recover true totals by compiling shallow variants
    with every layer/attention-chunk scan UNROLLED (cost_analysis then sees
    each iteration), and extrapolating linearly in depth:
        X(L) = X(l1) + (L - l1) * (X(l2) - X(l1)) / (l2 - l1),
    exact for uniform stacked layers. Mamba1's per-timestep scan cannot be
    unrolled (S up to 512k); it gets an analytic streaming correction."""
    import dataclasses

    spec = INPUT_SHAPES[shape_name]
    if spec["kind"] == "decode":
        # decode bodies are small (no chunk scans): unroll the FULL depth
        # and read exact costs — depth extrapolation is unreliable here
        # (GSPMD re-plans reshardings per depth).
        full = dataclasses.replace(cfg, unroll_layers=True)
        _, c_full, _ = lower_combo(full, shape_name, mesh, **lower_kwargs)
        f, b, cb, _ = _costs(c_full)
        df, db = _ssm_scan_corrections(cfg, shape_name, chips)
        return f + df, b + db, cb

    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        l1, l2 = k, 2 * k
    else:
        l1, l2 = 1, 2
    # keep probe compile time bounded: <= 16 attention chunks / 8 ssd chunks
    attn_chunk = max(cfg.attn_chunk, spec["seq_len"] // 16)
    ssd_chunk = max(cfg.ssd_chunk, min(spec["seq_len"] // 8, 512))
    probe = dict(unroll_layers=True, attn_chunk=attn_chunk, ssd_chunk=ssd_chunk)
    cfg1 = dataclasses.replace(cfg, num_layers=l1, **probe)
    cfg2 = dataclasses.replace(cfg, num_layers=l2, **probe)
    _, c1, _ = lower_combo(cfg1, shape_name, mesh, **lower_kwargs)
    f1, b1, cb1, _ = _costs(c1)
    _, c2, _ = lower_combo(cfg2, shape_name, mesh, **lower_kwargs)
    f2, b2, cb2, _ = _costs(c2)
    scale = (cfg.num_layers - l1) / (l2 - l1)
    df, db = _ssm_scan_corrections(cfg, shape_name, chips)
    return (f1 + scale * (f2 - f1) + df,
            b1 + scale * (b2 - b1) + db,
            max(cb1 + scale * (cb2 - cb1), 0.0))


def analyse(arch, shape_name, mesh_name, compiled, cfg, meta, mesh,
            probes: bool = True, lower_kwargs: dict | None = None) -> dict:
    lower_kwargs = lower_kwargs or {}
    chips = 512 if mesh_name == "multi" else 256
    ma = compiled.memory_analysis()
    f_raw, b_raw, cb_raw, coll = _costs(compiled)
    if probes:
        flops, hbm_bytes, coll_bytes = extrapolated_costs(
            cfg, shape_name, mesh, chips, **lower_kwargs)
    else:  # multi-pod pass proves lowering/sharding; roofline is single-pod
        flops, hbm_bytes, coll_bytes = f_raw, b_raw, cb_raw
    rl = Roofline(
        flops=flops,
        hbm_bytes=hbm_bytes,
        coll_bytes=coll_bytes,
        model_flops=model_flops_per_chip(cfg, meta["kind"], meta["tokens"], chips),
    )
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": meta["kind"],
        "sliding_window": bool(meta.get("window")),
        "chips": chips,
        "memory": {
            "argument_bytes_per_chip": ma.argument_size_in_bytes,
            "output_bytes_per_chip": ma.output_size_in_bytes,
            "temp_bytes_per_chip": ma.temp_size_in_bytes,
            "total_bytes_per_chip": (ma.argument_size_in_bytes
                                     + ma.temp_size_in_bytes),
        },
        "collectives": coll,
        "raw_body_once": {"flops": f_raw, "hbm_bytes": b_raw,
                          "collective_bytes": cb_raw},
        "roofline": rl.to_dict(),
    }


def run_one(arch, shape_name, mesh_name, verbose=True, probes=True):
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    cfg = get_config(arch)
    t0 = time.time()
    _lowered, compiled, meta = lower_combo(cfg, shape_name, mesh)
    rec = analyse(arch, shape_name, mesh_name, compiled, cfg, meta, mesh,
                  probes=probes)
    rec["compile_seconds"] = round(time.time() - t0, 1)
    if verbose:
        r = rec["roofline"]
        mem_gb = rec["memory"]["total_bytes_per_chip"] / 2**30
        print(f"[OK] {arch:22s} {shape_name:12s} {mesh_name:6s} "
              f"compile={rec['compile_seconds']:6.1f}s mem/chip={mem_gb:7.2f}GiB "
              f"t_comp={r['t_compute_s']:.3e} t_mem={r['t_memory_s']:.3e} "
              f"t_coll={r['t_collective_s']:.3e} bound={r['bottleneck']:10s} "
              f"useful={r['useful_flops_ratio']:.2f}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip depth-probe compiles (multi-pod pass)")
    args = ap.parse_args()

    combos = []
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    for a in archs:
        for s in shapes:
            combos.append((a, s))

    results = []
    if args.out and args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results if "roofline" in r}

    for arch, shape_name in combos:
        if (arch, shape_name, args.mesh) in done:
            continue
        try:
            rec = run_one(arch, shape_name, args.mesh,
                          probes=not args.no_probes)
        except Exception as e:  # noqa: BLE001 — record the failure, keep going
            rec = {"arch": arch, "shape": shape_name, "mesh": args.mesh,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"[FAIL] {arch} {shape_name} {args.mesh}: {rec['error']}",
                  flush=True)
        results.append(rec)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    n_ok = sum(1 for r in results if "roofline" in r)
    print(f"\n{n_ok}/{len(results)} combos compiled successfully")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
