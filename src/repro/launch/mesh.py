"""Production mesh definitions (TPU v5e target).

Defined as FUNCTIONS so importing this module never touches jax device
state (jax locks the device count on first backend init; the dry-run sets
XLA_FLAGS before any import).
"""
from __future__ import annotations

import jax

# Hardware constants used by the roofline model (TPU v5e)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

SINGLE_POD_SHAPE = (16, 16)  # 256 chips
MULTI_POD_SHAPE = (2, 16, 16)  # 2 pods x 256 chips


def _mk(shape, axes):
    if hasattr(jax.sharding, "AxisType"):  # jax >= 0.5
        auto = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=auto)
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int | None = None) -> jax.sharding.Mesh:
    """Small mesh for CPU multi-device tests (XLA_FLAGS host device count)."""
    if pod is None:
        return _mk((data, model), ("data", "model"))
    return _mk((pod, data, model), ("pod", "data", "model"))


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Axes that shard the batch: ('pod','data') on multi-pod else ('data',)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def has_pod_axis(mesh: jax.sharding.Mesh) -> bool:
    return "pod" in mesh.axis_names
