"""Launch-layer autotune knobs shared by the train and serve drivers.

``--block-n/--block-k/--chunk`` pin a kernel knob process-wide (they map
onto :func:`repro.tune.set_overrides`, which beats the committed table
but loses to explicit call-site kwargs); ``--tune`` runs a fresh sweep
at the job's own shapes and installs the result as the active in-memory
table for this process — nothing is written to disk.

Values are validated LOUDLY at launch: a non-positive knob, or one that
mismatches the job geometry (``--chunk``/``--block-k`` wider than the
batch's K, ``--block-n`` taller than the batch), is a ``SystemExit`` —
the kernels would silently clamp, and a silently-clamped flag reporting
timings for a config it never ran is worse than no flag at all.
"""
from __future__ import annotations

import argparse

from repro.tune import fused_envelope, set_active_table, set_overrides


def add_tuning_flags(ap: argparse.ArgumentParser) -> None:
    g = ap.add_argument_group(
        "autotune", "kernel block-size knobs (default: the committed "
        "autotune table — see repro.tune and README 'Autotuning')")
    g.add_argument("--block-n", type=int, default=None,
                   help="fused-forward batch tile (Pallas backends)")
    g.add_argument("--block-k", type=int, default=None,
                   help="fused-forward K tile (Pallas backends)")
    g.add_argument("--chunk", type=int, default=None,
                   help="K-chunk of the scan fallbacks (fwd AND bwd)")
    g.add_argument("--tune", action="store_true",
                   help="sweep this job's shapes up front and use the "
                        "fresh result instead of the committed table")


def tuning_flags_set(args: argparse.Namespace) -> bool:
    return (args.block_n is not None or args.block_k is not None
            or args.chunk is not None or args.tune)


def apply_tuning_flags(args: argparse.Namespace, *,
                       batch_n: int | None = None,
                       batch_k: int | None = None) -> None:
    """Install the flag overrides; loud ``SystemExit`` on bad values.

    ``batch_n``/``batch_k`` are the job's batch geometry (rows, widest
    id-list K) once known — a knob exceeding them would be silently
    clamped by the kernels, so it is rejected here instead."""
    try:
        set_overrides(block_n=args.block_n, block_k=args.block_k,
                      chunk=args.chunk)
    except ValueError as e:
        raise SystemExit(f"autotune flags: {e}") from None
    if batch_k is not None:
        for name, val in (("--chunk", args.chunk), ("--block-k", args.block_k)):
            if val is not None and val > batch_k:
                raise SystemExit(
                    f"{name} {val} exceeds the job's K={batch_k} id columns "
                    "— the kernel would silently clamp it; pass a value "
                    f"<= {batch_k} or drop the flag")
    if batch_n is not None and args.block_n is not None \
            and args.block_n > batch_n:
        raise SystemExit(
            f"--block-n {args.block_n} exceeds the job's batch of "
            f"{batch_n} rows — the kernel would silently clamp it; pass "
            f"a value <= {batch_n} or drop the flag")


def tune_job_shapes(shapes, *, mode: str = "auto", log=print) -> None:
    """``--tune``: sweep the job's (n, k, d, m) shapes and make the
    result THIS process's active table (committed files untouched).
    Flag overrides still beat it — pinning a knob while sweeping the
    rest is legitimate."""
    from repro.tune.sweep import sweep_shapes

    # shapes sharing a table envelope resolve identically — sweep each
    # envelope once, at its largest member (closest to the bucket edge)
    uniq: dict[str, tuple] = {}
    for n, k, d, m in sorted(set(shapes)):
        uniq[fused_envelope(n, k, 2 * m)] = (n, k, d, m)
    shapes = sorted(uniq.values())
    log(f"--tune: sweeping {len(shapes)} job shape(s) "
        f"{shapes} (in-memory table; committed files untouched)")
    set_active_table(sweep_shapes(shapes, mode=mode, log=log))
