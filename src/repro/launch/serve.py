"""Serving driver: train-or-load -> prune -> engine replay (the §4
deploy path as one command).

Quick smoke (train a small sparse model, prune it, serve ragged traffic):
  PYTHONPATH=src python -m repro.launch.serve --train-iters 10 \
      --sparse-features 20000 --sessions 256 --regions 4 \
      --lam 0.05 --beta 0.05 --requests 256 --artifact /tmp/lsplm_art.npz

Serve an existing training checkpoint (``repro.launch.train --ckpt``,
which saves ``{"theta": ...}``):
  PYTHONPATH=src python -m repro.launch.serve --ckpt /tmp/lsplm.npz \
      --requests 512

The driver prints the prune ledger (rows alive, MiB shipped), proves
pruned-vs-full score parity on a probe batch, then replays ragged
synthetic bundles through the :class:`~repro.serve.engine.ScoringEngine`
— one request per dispatch AND stacked same-envelope G>1 dispatches
(parity-asserted) — and reports the latency/throughput ledger,
asserting the steady state (everything after the warmup pass) triggered
ZERO recompiles.

``--int8`` additionally quantises the artifact (int8 rows + per-row
fp32 scale), round-trips it through save/load, and serves THAT
INT8-NATIVE — the engine compiles its own dtype-keyed executables over
the scale-fused int8 gather (fp32 rows never materialise) — printing
the size win and the bounded probability drift vs fp32.

``--load-qps`` switches on the traffic mode: open-loop Poisson arrivals
at the given rate(s) through the micro-batching queue (deadline-aware
flushing, admission control), reporting p50/p99 latency, achieved QPS
and candidates/sec per offered rate:
  PYTHONPATH=src python -m repro.launch.serve --train-iters 4 \
      --sparse-features 5000 --sessions 96 --regions 2 --requests 128 \
      --int8 --load-qps 500,2000 --max-batch 8 --max-delay-us 3000

``--coalesce`` merges several due per-envelope groups into single
dispatches (bitwise-identical scores, fewer device rounds — the flush
mix line shows how many rounds coalesced); ``--real-clock`` additionally
replays each rate through the wall-clock :class:`RealClockPump` front
door — Poisson-paced REAL sleeps, the pump's timer thread firing the
deadline flushes — and asserts the deterministic drain served every
accepted request.
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.io import checkpoint
from repro.launch.tuning import (
    add_tuning_flags,
    apply_tuning_flags,
    tune_job_shapes,
)
from repro.serve import (
    MicroBatchQueue,
    QueueConfig,
    RealClockPump,
    ScoringEngine,
    as_model,
    compress,
    load_artifact,
    poisson_arrivals,
    quantize,
    replay_open_loop,
    save_artifact,
    score_sparse,
    synthetic_requests,
)


def _trained_theta(args) -> jnp.ndarray:
    """--ckpt loads a saved Theta; otherwise train a small sparse model
    (same path as ``repro.launch.train --sparse``) so the artifact has
    REAL OWLQN+ sparsity, not a synthetic mask."""
    if args.ckpt:
        data = checkpoint.load_nested(args.ckpt)
        if "theta" not in data:
            raise SystemExit(f"--ckpt {args.ckpt!r} has no 'theta' entry")
        theta = jnp.asarray(data["theta"])
        obs.log(f"loaded theta {theta.shape} from {args.ckpt}")
        return theta

    from repro.core.objective import smooth_loss_and_grad
    from repro.data.sparse import generate_sparse
    from repro.optim import OWLQNPlus

    d, m = args.sparse_features, args.regions
    train = generate_sparse(
        num_features=d, num_user_features_range=(max(1, int(0.6 * d)), d),
        sessions=args.sessions, seed=args.seed)
    theta0 = jnp.asarray(
        0.01 * np.random.default_rng(args.seed).normal(size=(d, 2 * m)),
        jnp.float32)
    opt = OWLQNPlus(lambda t: smooth_loss_and_grad(t, train),
                    lam=args.lam, beta=args.beta)
    t0 = time.perf_counter()
    theta, trace = opt.run(theta0, max_iters=args.train_iters)
    obs.log(f"trained {args.train_iters} OWLQN+ iters on d={d:,} in "
            f"{time.perf_counter() - t0:.1f}s (f={float(trace[-1].f_new):.2f}, "
            f"nnz={int(trace[-1].nnz):,})")
    return theta


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default=None,
                    help="training checkpoint with a 'theta' entry; "
                         "omitted -> train a small sparse model first")
    ap.add_argument("--artifact", default=None,
                    help="write the pruned serving artifact here")
    ap.add_argument("--train-iters", type=int, default=10)
    ap.add_argument("--sparse-features", type=int, default=20_000)
    ap.add_argument("--sessions", type=int, default=256)
    ap.add_argument("--regions", type=int, default=4)
    ap.add_argument("--lam", type=float, default=0.05)
    ap.add_argument("--beta", type=float, default=0.05)
    ap.add_argument("--requests", type=int, default=256,
                    help="ragged synthetic bundles to replay")
    ap.add_argument("--int8", action="store_true",
                    help="quantise the artifact (int8 rows + fp32 row "
                         "scales), round-trip through save/load, serve that")
    ap.add_argument("--load-qps", default=None,
                    help="traffic mode: comma-separated offered QPS rates "
                         "for the open-loop Poisson replay through the "
                         "micro-batching queue")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="queue full-flush size (requests per dispatch)")
    ap.add_argument("--max-delay-us", type=float, default=3_000.0,
                    help="queue deadline: max micro-batching delay")
    ap.add_argument("--max-pending", type=int, default=256,
                    help="admission control: shed load past this backlog")
    ap.add_argument("--coalesce", action="store_true",
                    help="merge several due per-envelope groups into one "
                         "dispatch at the widest due envelope (bitwise-"
                         "identical scores, fewer device rounds)")
    ap.add_argument("--real-clock", action="store_true",
                    help="also replay each --load-qps rate through the "
                         "wall-clock RealClockPump front door (real "
                         "Poisson-paced sleeps, timer-thread flushes)")
    ap.add_argument("--seed", type=int, default=0)
    add_tuning_flags(ap)
    obs.add_flags(ap)
    args = ap.parse_args()
    apply_tuning_flags(args)  # value check up front; geometry check below
    if args.drift_ref and not args.monitor:
        raise SystemExit(
            "--drift-ref arms the health monitor's drift detectors; "
            "combine it with --monitor")
    if args.real_clock and not args.load_qps:
        raise SystemExit(
            "--real-clock paces the queue with wall-time Poisson arrivals; "
            "combine it with --load-qps")

    session = obs.configure_from_args(args, driver="repro.launch.serve")
    try:
        return _serve(args)
    finally:
        session.close()


def _real_clock_smoke(engine, requests, *, qps: float, config: QueueConfig,
                      seed: int) -> None:
    """Wall-clock front door: Poisson-paced REAL sleeps feed a
    :class:`RealClockPump`, whose timer thread fires the deadline
    flushes; ``stop()`` joins then drains, so afterwards every accepted
    request must have a completion (the determinism being smoked)."""
    queue = MicroBatchQueue(engine, config)
    arrivals = poisson_arrivals(len(requests), qps, seed)
    gaps = np.diff(np.concatenate([[0.0], arrivals]))
    before = engine.stats.compiles
    t0 = time.perf_counter()
    accepted = 0
    with RealClockPump(queue) as pump:
        for gap, req in zip(gaps, requests):
            time.sleep(gap)
            if pump.submit(req) is not None:
                accepted += 1
    wall = time.perf_counter() - t0
    comps = queue.completions
    assert len(comps) == accepted, \
        f"pump drained {len(comps)} of {accepted} accepted requests"
    assert engine.stats.compiles == before, "real-clock replay recompiled"
    lat = np.array([c.latency_us for c in comps]) if comps else np.zeros(1)
    fl = queue.stats.flushes
    obs.log(f"real-clock {qps:,.0f} qps: {accepted}/{len(requests)} accepted,"
            f" all drained in {wall:.2f}s wall; "
            f"p50 {np.percentile(lat, 50):,.0f} us, "
            f"p99 {np.percentile(lat, 99):,.0f} us "
            f"({fl['full']} full / {fl['deadline']} deadline / "
            f"{fl['drain']} drain / {fl['coalesced']} coalesced)")


def _serve(args) -> int:
    theta = _trained_theta(args)
    d = theta.shape[0]

    art = compress(theta)
    full_mb = theta.size * 4 / 2**20
    art_mb = (art.theta.size + art.remap.size + art.alive_ids.size) * 4 / 2**20
    obs.log(f"pruned: {art.num_alive:,}/{d:,} rows alive "
            f"({art.compression:.2%}); ship {art_mb:.2f} MiB vs "
            f"{full_mb:.2f} MiB full")
    if args.artifact:
        obs.log(f"artifact -> {save_artifact(args.artifact, art)}")

    # pruned-vs-full parity probe (bit-identical on the sparse path)
    rng = np.random.default_rng(args.seed + 7)
    ids = jnp.asarray(rng.integers(0, d, (512, 16)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(512, 16)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(score_sparse(as_model(theta), ids, vals)),
        np.asarray(score_sparse(art, ids, vals)))
    obs.log("parity: pruned scoring bit-identical to full Theta (512 probes)")

    model = art
    if args.int8:
        import tempfile

        q = quantize(art)
        with tempfile.TemporaryDirectory() as tmp:
            model = load_artifact(save_artifact(f"{tmp}/art_int8", q))
        dp = float(np.abs(
            np.asarray(score_sparse(model, ids, vals))
            - np.asarray(score_sparse(art, ids, vals))).max())
        assert dp <= 1e-2, f"int8 moved p by {dp:.2e} (> 1e-2)"
        obs.log(f"int8-native: rows payload "
                f"{q.codes.size + q.scales.size * 4:,} B "
                f"vs {art.theta.size * 4:,} B fp32 "
                f"({art.theta.size * 4 / (q.codes.size + q.scales.size * 4):.1f}x"
                f" smaller rows AND row-gather DMA bytes); round-tripped "
                f"save/load; serving the codes directly (scale fused into "
                f"the gather); max |dp| = {dp:.1e} vs fp32")

    engine = ScoringEngine(model)
    mon = obs.get_monitor()
    if args.drift_ref:
        ref = obs.load_drift_reference(args.drift_ref)
        mon.arm_drift(ref)
        obs.log(f"monitor armed from {args.drift_ref}: "
                f"{ref.num_bins} score bins, top-{ref.top_ids.shape[0]} id "
                f"traffic, reference calibration ratio {ref.ratio:.3f}")
    requests = synthetic_requests(args.requests, num_features=d,
                                  seed=args.seed + 1)
    # deploy-time warmup: compile the traffic's bucket set (all batch
    # sizes the G>1 path can round onto) up front, then the whole replay
    # is steady state
    envelopes = {engine.envelope(r) for r in requests}
    # the engine pads K/N up to its buckets before the kernels run, so
    # the geometry the knobs must fit is the PADDED envelope set
    kmax = max(max(ku, ka) for ku, ka, _n in envelopes)
    nmax = engine.max_batch * max(n for _ku, _ka, n in envelopes)
    apply_tuning_flags(args, batch_n=nmax, batch_k=kmax)
    if args.tune:
        m = theta.shape[1] // 2
        tune_job_shapes(
            {(g * n, ka, d, m) for _ku, ka, n in envelopes
             for g in (1, engine.max_batch)}
            | {(g, ku, d, m) for ku, _ka, _n in envelopes
               for g in (1, engine.max_batch)})
    if args.coalesce:
        # coalesced flushes dispatch at the elementwise max of merged
        # envelopes: warm the closure so they stay recompile-free too
        from repro.serve import envelope_closure

        envelopes = envelope_closure(envelopes)
    engine.warm(envelopes, batch_sizes=engine.g_buckets)
    warm_compiles = engine.stats.compiles
    single = engine.score_many(requests)
    batched = engine.score_batch(requests)
    for p_one, p_many in zip(single, batched):
        np.testing.assert_array_equal(p_one, p_many)
    s = engine.stats
    assert s.compiles == warm_compiles, \
        f"steady state recompiled: {s.compiles} != {warm_compiles}"
    obs.log(f"engine: {s.requests} requests / {s.candidates} candidates "
            f"over {len(s.bucket_hits)} buckets; {s.compiles} compiles "
            f"({s.compile_seconds:.2f}s, all in warmup), steady state "
            f"0 recompiles; single-vs-batched scores bit-identical; "
            f"{s.latency_us:.0f} us/request, {s.candidates_per_sec:,.0f} ads/s, "
            f"batched occupancy {s.occupancy:.2f}")

    if args.load_qps:
        cfg = QueueConfig(max_batch=args.max_batch,
                          max_delay_us=args.max_delay_us,
                          max_pending=args.max_pending,
                          coalesce=args.coalesce)
        for qps in (float(x) for x in args.load_qps.split(",") if x.strip()):
            before = engine.stats.compiles
            rep = replay_open_loop(engine, requests, qps=qps, config=cfg,
                                   seed=args.seed + 2)
            assert engine.stats.compiles == before, \
                "queue replay recompiled in steady state"
            obs.log(f"load {qps:,.0f} qps offered: "
                    f"p50 {rep['latency_p50_us']:,.0f} us, "
                    f"p99 {rep['latency_p99_us']:,.0f} us, "
                    f"achieved {rep['achieved_qps']:,.0f} qps, "
                    f"{rep['candidates_per_sec']:,.0f} ads/s, "
                    f"occupancy {rep['occupancy']:.2f}, "
                    f"{rep['dispatches']} dispatches "
                    f"({rep['flushes']['full']} full / "
                    f"{rep['flushes']['deadline']} deadline / "
                    f"{rep['flushes']['drain']} drain / "
                    f"{rep['flushes']['coalesced']} coalesced"
                    + (f" merging {rep['coalesced_groups']} groups"
                       if rep["flushes"]["coalesced"] else "")
                    + f"), rejected {rep['rejected']}")
            if args.real_clock:
                _real_clock_smoke(engine, requests, qps=qps, config=cfg,
                                  seed=args.seed + 3)

    if mon.enabled:
        mon.evaluate()  # settle the last partial eval_every window
        summ = mon.summary()
        active = ", ".join(summ["active"]) if summ["active"] else "none"
        drift = {k: v for k, v in summ["signals"].items()
                 if k.startswith(("drift.", "calib."))}
        obs.log(f"monitor: {summ['alerts']} alert state changes, "
                f"active: {active}"
                + (f"; drift signals: "
                   + ", ".join(f"{k}={v:.4f}" for k, v in sorted(drift.items()))
                   if drift else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
