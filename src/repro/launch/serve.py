"""Serving driver: train-or-load -> prune -> engine replay (the §4
deploy path as one command).

Quick smoke (train a small sparse model, prune it, serve ragged traffic):
  PYTHONPATH=src python -m repro.launch.serve --train-iters 10 \
      --sparse-features 20000 --sessions 256 --regions 4 \
      --lam 0.05 --beta 0.05 --requests 256 --artifact /tmp/lsplm_art.npz

Serve an existing training checkpoint (``repro.launch.train --ckpt``,
which saves ``{"theta": ...}``):
  PYTHONPATH=src python -m repro.launch.serve --ckpt /tmp/lsplm.npz \
      --requests 512

The driver prints the prune ledger (rows alive, MiB shipped), proves
pruned-vs-full score parity on a probe batch, then replays ragged
synthetic bundles through the :class:`~repro.serve.engine.ScoringEngine`
and reports the latency/throughput ledger — asserting the steady state
(everything after the warmup pass) triggered ZERO recompiles.
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.io import checkpoint
from repro.serve import (
    ScoringEngine,
    as_model,
    compress,
    save_artifact,
    score_sparse,
    synthetic_requests,
)


def _trained_theta(args) -> jnp.ndarray:
    """--ckpt loads a saved Theta; otherwise train a small sparse model
    (same path as ``repro.launch.train --sparse``) so the artifact has
    REAL OWLQN+ sparsity, not a synthetic mask."""
    if args.ckpt:
        data = checkpoint.load_nested(args.ckpt)
        if "theta" not in data:
            raise SystemExit(f"--ckpt {args.ckpt!r} has no 'theta' entry")
        theta = jnp.asarray(data["theta"])
        print(f"loaded theta {theta.shape} from {args.ckpt}")
        return theta

    from repro.core.objective import smooth_loss_and_grad
    from repro.data.sparse import generate_sparse
    from repro.optim import OWLQNPlus

    d, m = args.sparse_features, args.regions
    train = generate_sparse(
        num_features=d, num_user_features_range=(max(1, int(0.6 * d)), d),
        sessions=args.sessions, seed=args.seed)
    theta0 = jnp.asarray(
        0.01 * np.random.default_rng(args.seed).normal(size=(d, 2 * m)),
        jnp.float32)
    opt = OWLQNPlus(lambda t: smooth_loss_and_grad(t, train),
                    lam=args.lam, beta=args.beta)
    t0 = time.perf_counter()
    theta, trace = opt.run(theta0, max_iters=args.train_iters)
    print(f"trained {args.train_iters} OWLQN+ iters on d={d:,} in "
          f"{time.perf_counter() - t0:.1f}s (f={float(trace[-1].f_new):.2f}, "
          f"nnz={int(trace[-1].nnz):,})")
    return theta


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default=None,
                    help="training checkpoint with a 'theta' entry; "
                         "omitted -> train a small sparse model first")
    ap.add_argument("--artifact", default=None,
                    help="write the pruned serving artifact here")
    ap.add_argument("--train-iters", type=int, default=10)
    ap.add_argument("--sparse-features", type=int, default=20_000)
    ap.add_argument("--sessions", type=int, default=256)
    ap.add_argument("--regions", type=int, default=4)
    ap.add_argument("--lam", type=float, default=0.05)
    ap.add_argument("--beta", type=float, default=0.05)
    ap.add_argument("--requests", type=int, default=256,
                    help="ragged synthetic bundles to replay")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    theta = _trained_theta(args)
    d = theta.shape[0]

    art = compress(theta)
    full_mb = theta.size * 4 / 2**20
    art_mb = (art.theta.size + art.remap.size + art.alive_ids.size) * 4 / 2**20
    print(f"pruned: {art.num_alive:,}/{d:,} rows alive "
          f"({art.compression:.2%}); ship {art_mb:.2f} MiB vs "
          f"{full_mb:.2f} MiB full")
    if args.artifact:
        print(f"artifact -> {save_artifact(args.artifact, art)}")

    # pruned-vs-full parity probe (bit-identical on the sparse path)
    rng = np.random.default_rng(args.seed + 7)
    ids = jnp.asarray(rng.integers(0, d, (512, 16)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(512, 16)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(score_sparse(as_model(theta), ids, vals)),
        np.asarray(score_sparse(art, ids, vals)))
    print("parity: pruned scoring bit-identical to full Theta (512 probes)")

    engine = ScoringEngine(art)
    requests = synthetic_requests(args.requests, num_features=d,
                                  seed=args.seed + 1)
    # deploy-time warmup: compile the traffic's bucket set up front, then
    # the whole replay is steady state
    engine.warm({engine.envelope(r) for r in requests})
    warm_compiles = engine.stats.compiles
    engine.score_many(requests)
    s = engine.stats
    assert s.compiles == warm_compiles, \
        f"steady state recompiled: {s.compiles} != {warm_compiles}"
    print(f"engine: {s.requests} requests / {s.candidates} candidates over "
          f"{len(s.bucket_hits)} buckets; {s.compiles} compiles "
          f"({s.compile_seconds:.2f}s, all in warmup), steady state "
          f"0 recompiles; {s.latency_us:.0f} us/request, "
          f"{s.candidates_per_sec:,.0f} ads/s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
