"""End-to-end LS-PLM training driver (the paper's production job).

Trains LS-PLM with Algorithm 1 on the synthetic CTR workload using the
paper's distribution plan (DESIGN.md §3): batch over the data axis
(workers), Theta feature-rows over the model axis (servers), the
common-feature trick enabled.

Run (CPU simulation of the cluster with 8 host devices):
  PYTHONPATH=src REPRO_DEVICES=8 python -m repro.launch.train \
      --sessions 4000 --regions 12 --lam 1.0 --beta 1.0 --iters 60 \
      --mesh-data 4 --mesh-model 2 --ckpt /tmp/lsplm.npz

Sparse production mode (padded-COO ids over --sparse-features columns,
running on the fused sparse kernel — Pallas on TPU, chunked jnp on CPU):
  PYTHONPATH=src python -m repro.launch.train --sparse \
      --sparse-features 1000000 --sessions 1024 --regions 4 --iters 30

Distributed sparse mode (the paper's worker/server split on the sparse
path: samples over 'data', Theta rows over 'model' with id-range
routing via repro.shard):
  PYTHONPATH=src REPRO_DEVICES=8 python -m repro.launch.train --sparse \
      --sessions 512 --sparse-features 100000 --regions 4 \
      --mesh-data 2 --mesh-model 4 --iters 30

Streaming mode (production cadence: day-sliced stream, sliding-window
minibatch OWLQN+ warm-started across windows, host re-planning +
compilation overlapped with the device step; composes with the mesh
flags for the sharded path):
  PYTHONPATH=src python -m repro.launch.train --stream \
      --days 8 --window 2 --inner-iters 5 --sessions 256 \
      --sparse-features 100000 --regions 4 --ckpt /tmp/stream.npz
"""
import os
if "REPRO_DEVICES" in os.environ:  # must precede jax import
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={os.environ['REPRO_DEVICES']}"
    )

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import predict_proba
from repro.core.lsplm import params_from_theta
from repro.core.objective import smooth_loss_and_grad
from repro.data import CTRDataConfig, auc, generate, pad_to_multiple, to_dense_batch
from repro.dist import make_distributed_step, shard_batch, shard_state
from repro.io import checkpoint
from repro.launch.mesh import make_debug_mesh
from repro.launch.tuning import (
    add_tuning_flags,
    apply_tuning_flags,
    tune_job_shapes,
    tuning_flags_set,
)
from repro.optim import OWLQNPlus


def train_sparse(args) -> int:
    """Production-format training: padded-COO ids/vals over d columns,
    OWLQN+ on the fused sparse kernel's custom-VJP loss. Dense (B, d)
    matrices never exist; the backward touches only active Theta rows,
    scheduled by per-batch transpose plans (built once, host-side — no
    sort or scatter inside the optimizer step).

    With --mesh-data/--mesh-model the job runs the paper's worker/server
    split end to end (repro.shard): samples over 'data', Theta rows over
    'model' by id range, plan slices per shard, one z psum per step."""
    from repro.data import auc as auc_fn
    from repro.data.sparse import generate_sparse, sparse_predict

    distributed = args.mesh_data > 0 and args.mesh_model > 0
    if (args.mesh_data > 0) != (args.mesh_model > 0):
        raise SystemExit(
            "--mesh-data and --mesh-model must be set together (sparse "
            "mode shards samples x Theta rows as one (data, model) mesh)")

    d, m = args.sparse_features, args.regions
    user_range = (max(1, int(0.6 * d)), d)
    train = generate_sparse(num_features=d, num_user_features_range=user_range,
                            sessions=args.sessions, seed=args.seed + 1)
    test = generate_sparse(num_features=d, num_user_features_range=user_range,
                           sessions=max(args.sessions // 5, 32),
                           seed=args.seed + 2)
    theta0 = jnp.asarray(
        0.01 * np.random.default_rng(args.seed).normal(size=(d, 2 * m)),
        jnp.float32)
    ku = train.user_ids.shape[-1]
    ka = train.ad_ids.shape[-1]
    apply_tuning_flags(args, batch_n=train.ad_ids.shape[0],
                       batch_k=max(ku, ka))
    if args.tune:
        tune_job_shapes([(train.user_ids.shape[0], ku, d, m),
                         (train.ad_ids.shape[0], ka, d, m)])
    kern = ("pipelined block-DMA kernel" if jax.default_backend() == "tpu"
            else "scan-chunked jnp fallback")
    obs.log(f"sparse mode: d={d:,} columns, Theta {theta0.shape} "
            f"({theta0.size:,} params), backend={jax.default_backend()} ({kern})")
    for side, plan in (("user", train.user_plan), ("ad", train.ad_plan)):
        obs.log(f"  {side} transpose plan: {plan.num_kept:,} entries, "
                f"{plan.num_unique:,} unique ids, "
                f"{len(plan.class_width)} popularity classes")

    part = None
    if distributed:
        from repro.dist import shard_sparse_batch
        from repro.shard import (
            make_partition,
            make_sharded_sparse_loss,
            route_batch,
        )

        assert jax.device_count() >= args.mesh_data * args.mesh_model, (
            f"need {args.mesh_data * args.mesh_model} devices, "
            f"have {jax.device_count()} (set REPRO_DEVICES)")
        if args.sessions % args.mesh_data:
            raise SystemExit(f"--sessions {args.sessions} must divide by "
                             f"--mesh-data {args.mesh_data}")
        mesh = make_debug_mesh(data=args.mesh_data, model=args.mesh_model)
        part = make_partition(d, args.mesh_model)
        sbatch = shard_sparse_batch(
            mesh, route_batch(train, part, data_shards=args.mesh_data))
        opt = OWLQNPlus(make_sharded_sparse_loss(sbatch, mesh),
                        lam=args.lam, beta=args.beta)
        state = shard_state(opt.init(part.pad_rows(theta0)), mesh)
        step = make_distributed_step(opt, mesh)
        obs.log(f"mesh: data={args.mesh_data} x model={args.mesh_model} "
                f"(PS mapping: workers x servers); Theta rows id-range "
                f"sharded, {part.rows_per_shard:,} rows/shard, routed "
                f"K user={sbatch.user_ids.shape[-1]} "
                f"ad={sbatch.ad_ids.shape[-1]}")
    else:
        opt = OWLQNPlus(lambda t: smooth_loss_and_grad(t, train),
                        lam=args.lam, beta=args.beta)
        state = opt.init(theta0)
        step = jax.jit(opt.step)

    tracer = obs.get_tracer()
    for k in range(args.iters):
        t0 = time.perf_counter()
        with tracer.step_span("train/iter", k):
            state, stats = step(state)
        dt = time.perf_counter() - t0
        if k % 5 == 0 or k == args.iters - 1:
            theta_eval = state.theta if part is None else part.unpad_rows(
                jnp.asarray(jax.device_get(state.theta)))
            p = np.asarray(sparse_predict(theta_eval, test))
            a = auc_fn(np.asarray(test.y), p)
            st = jax.device_get(stats)
            rec = dict(step=k, f=float(st.f), f_new=float(st.f_new),
                       alpha=float(st.alpha), ls_iters=int(st.ls_iters),
                       grad_norm=float(st.grad_norm), nnz=int(st.nnz),
                       test_auc=float(a), wall_s=dt)
            obs.log(obs.render_train_iter(rec), kind="train_iter", **rec)
    theta = state.theta if part is None else part.unpad_rows(
        jnp.asarray(jax.device_get(state.theta)))
    if args.drift_ref:
        p = np.asarray(sparse_predict(theta, test))
        ids = np.concatenate([np.asarray(test.user_ids).ravel(),
                              np.asarray(test.ad_ids).ravel()])
        ref = obs.capture_reference(p, np.asarray(test.y), ids,
                                    num_features=d)
        obs.log(f"drift reference (held-out test, {p.shape[0]} scores, "
                f"ratio={ref.ratio:.3f}) -> "
                f"{obs.save_drift_reference(args.drift_ref, ref)}")
    if args.ckpt:
        checkpoint.save(args.ckpt, {"theta": theta})
        obs.log(f"checkpoint -> {args.ckpt}")
    return 0


def train_stream(args) -> int:
    """Day-by-day streaming training (repro.stream): per day, the last
    --window days are re-planned on the host — overlapped with the
    previous window's device iterations — and OWLQN+ runs --inner-iters
    warm-started steps. --mesh-data/--mesh-model runs every window on
    the sharded path (fixed equal id-range partition). --ckpt saves the
    resumable stream state (Theta + history + day cursor); --resume
    continues from it."""
    from repro.core.objective import nll_sparse
    from repro.data import auc as auc_fn
    from repro.data.sparse import sparse_predict
    from repro.stream import DayStream, StreamTrainer

    distributed = args.mesh_data > 0 and args.mesh_model > 0
    if (args.mesh_data > 0) != (args.mesh_model > 0):
        raise SystemExit("--mesh-data and --mesh-model must be set together")
    # np.savez appends .npz to suffix-less paths; normalize up front so
    # the --resume existence probe and the printed path match the file
    ckpt = args.ckpt and (args.ckpt if args.ckpt.endswith(".npz")
                          else args.ckpt + ".npz")
    d, m = args.sparse_features, args.regions
    stream = DayStream(args.days, sessions_per_day=args.sessions,
                       num_features=d, drift=args.drift, seed=args.seed)
    theta0 = jnp.asarray(
        0.01 * np.random.default_rng(args.seed).normal(size=(d, 2 * m)),
        jnp.float32)
    mesh = None
    if distributed:
        assert jax.device_count() >= args.mesh_data * args.mesh_model, (
            f"need {args.mesh_data * args.mesh_model} devices, "
            f"have {jax.device_count()} (set REPRO_DEVICES)")
        mesh = make_debug_mesh(data=args.mesh_data, model=args.mesh_model)
    if tuning_flags_set(args):
        day0 = stream.day(0)
        ku, ka = day0.user_ids.shape[-1], day0.ad_ids.shape[-1]
        apply_tuning_flags(args, batch_k=max(ku, ka))
        if args.tune:
            g, b = day0.user_ids.shape[0], day0.ad_ids.shape[0]
            w = args.window
            tune_job_shapes({(g, ku, d, m), (b, ka, d, m),
                             (g * w, ku, d, m), (b * w, ka, d, m)})
    trainer = StreamTrainer(
        stream, lam=args.lam, beta=args.beta, window=args.window,
        inner_iters=args.inner_iters, history=args.history, mesh=mesh,
        overlap=not args.sync_planner)
    obs.log(f"stream: {args.days} days x {args.sessions} sessions, d={d:,}, "
            f"window={args.window}, {args.inner_iters} inner iters/window, "
            f"history={args.history}, planner="
            f"{'synchronous' if args.sync_planner else 'overlapped'}"
            + (f", mesh data={args.mesh_data} x model={args.mesh_model}"
               if mesh is not None else ""))

    if args.resume and ckpt and os.path.exists(ckpt):
        state = trainer.load(ckpt, theta0)
        obs.log(f"resumed from {ckpt} at day {state.day}")
    else:
        state = trainer.init(theta0)

    last_eval: dict = {}  # scores/labels/ids of the newest held-out day

    def cb(t, ws, st):
        # the structured twin of this line is the trainer's own
        # stream_window record; the held-out eval is the driver's
        msg = (f"day {t:3d}  window={ws.days_in_window}d "
               f"f={ws.fs[-1]:12.2f} alpha={ws.alpha:.3g} "
               f"nnz={ws.nnz:8d} plan={ws.build_seconds * 1e3:6.0f}ms "
               f"step={ws.step_seconds * 1e3:6.0f}ms")
        if t + 1 < stream.num_days:  # held-out NEXT-day quality
            nxt = stream.day(t + 1)
            theta = trainer.theta(st)
            nll = float(nll_sparse(theta, nxt)) / nxt.y.shape[0]
            p = np.asarray(sparse_predict(theta, nxt))
            y = np.asarray(nxt.y)
            a = auc_fn(y, p)
            msg += f"  next-day nll={nll:.4f} auc={a:.4f}"
            obs.log(msg, kind="stream_eval", day=t, next_day_nll=nll,
                    next_day_auc=float(a))
            obs.get_monitor().observe_predictions(p, y)
            if args.drift_ref:
                last_eval.update(scores=p, labels=y, ids=np.concatenate(
                    [np.asarray(nxt.user_ids).ravel(),
                     np.asarray(nxt.ad_ids).ravel()]))
        else:
            obs.log(msg)
        if ckpt:  # every window is a resumable checkpoint
            trainer.save(ckpt, st)

    t0 = time.perf_counter()
    days_left = stream.num_days - state.day
    state, _trace = trainer.run(state, callback=cb)
    dt = time.perf_counter() - t0
    ps = trainer.planner_stats
    obs.log(f"trained {days_left} windows in {dt:.1f}s; planner: "
            f"{ps.build_seconds:.2f}s host build, {ps.wait_seconds:.2f}s "
            f"exposed, overlap ratio {ps.overlap_ratio:.2f}")
    if args.drift_ref:
        if not last_eval:
            raise SystemExit(
                "--drift-ref needs at least one held-out next-day eval; "
                "run with --days >= 2 (or resume earlier in the stream)")
        ref = obs.capture_reference(last_eval["scores"], last_eval["labels"],
                                    last_eval["ids"],
                                    num_features=args.sparse_features)
        obs.log(f"drift reference (last held-out day, "
                f"{last_eval['scores'].shape[0]} scores, "
                f"ratio={ref.ratio:.3f}) -> "
                f"{obs.save_drift_reference(args.drift_ref, ref)}")
    if ckpt:
        obs.log(f"stream checkpoint -> {ckpt} (resume with --resume)")
    return 0


def train_dense(args) -> int:
    """Dense-matrix training on the common-feature objective (the
    original small-d path; the default when neither --sparse nor
    --stream is given)."""
    cfg = CTRDataConfig(
        num_user_features=args.user_features, num_ad_features=args.ad_features,
        noise_features=args.noise_features, seed=args.seed,
    )
    train_cf, _ = generate(cfg, args.sessions, seed=1)
    test_cf, _ = generate(cfg, max(args.sessions // 5, 64), seed=2)
    d, m = cfg.num_features, args.regions
    theta0 = jnp.asarray(
        0.01 * np.random.default_rng(args.seed).normal(size=(d, 2 * m)),
        jnp.float32)

    distributed = args.mesh_data > 0 and args.mesh_model > 0
    if distributed:
        assert jax.device_count() >= args.mesh_data * args.mesh_model, (
            f"need {args.mesh_data * args.mesh_model} devices, "
            f"have {jax.device_count()} (set REPRO_DEVICES)")
        mesh = make_debug_mesh(data=args.mesh_data, model=args.mesh_model)
        batch = pad_to_multiple(train_cf, args.mesh_data)
        batch = shard_batch(mesh, jax.tree.map(jnp.asarray, batch),
                            common_feature=True)
        opt = OWLQNPlus(
            lambda t: smooth_loss_and_grad(t, batch, common_feature=True),
            lam=args.lam, beta=args.beta)
        state = shard_state(opt.init(theta0), mesh)
        step = make_distributed_step(opt, mesh)
        obs.log(f"mesh: data={args.mesh_data} x model={args.mesh_model} "
                f"(PS mapping: workers x servers)")
    else:
        batch = jax.tree.map(jnp.asarray, pad_to_multiple(train_cf, 1))
        opt = OWLQNPlus(
            lambda t: smooth_loss_and_grad(t, batch, common_feature=True),
            lam=args.lam, beta=args.beta)
        state = opt.init(theta0)
        step = jax.jit(opt.step)

    test_dense = to_dense_batch(test_cf)
    xs_test = jnp.asarray(test_dense.x)
    tracer = obs.get_tracer()
    for k in range(args.iters):
        t0 = time.perf_counter()
        with tracer.step_span("train/iter", k):
            state, stats = step(state)
        dt = time.perf_counter() - t0
        if k % 5 == 0 or k == args.iters - 1:
            theta_host = jax.device_get(state.theta)
            p = predict_proba(params_from_theta(jnp.asarray(theta_host)), xs_test)
            a = auc(test_dense.y, np.asarray(p))
            st = jax.device_get(stats)
            rec = dict(step=k, f=float(st.f), f_new=float(st.f_new),
                       alpha=float(st.alpha), ls_iters=int(st.ls_iters),
                       grad_norm=float(st.grad_norm), nnz=int(st.nnz),
                       test_auc=float(a), wall_s=dt)
            obs.log(obs.render_train_iter(rec, nnz_width=7),
                    kind="train_iter", **rec)
    if args.ckpt:
        checkpoint.save(args.ckpt, {"theta": state.theta})
        obs.log(f"checkpoint -> {args.ckpt}")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=4000)
    ap.add_argument("--user-features", type=int, default=64)
    ap.add_argument("--ad-features", type=int, default=48)
    ap.add_argument("--noise-features", type=int, default=16)
    ap.add_argument("--regions", type=int, default=12, help="m (Fig. 4)")
    ap.add_argument("--lam", type=float, default=1.0, help="L2,1 weight")
    ap.add_argument("--beta", type=float, default=1.0, help="L1 weight")
    ap.add_argument("--iters", type=int, default=60)
    ap.add_argument("--mesh-data", type=int, default=0, help="0 = single device")
    ap.add_argument("--mesh-model", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sparse", action="store_true",
                    help="train on padded-COO sparse features via the "
                         "fused sparse kernel (the paper's input format)")
    ap.add_argument("--sparse-features", type=int, default=1_000_000,
                    help="d for --sparse mode (feature columns)")
    ap.add_argument("--stream", action="store_true",
                    help="streaming day-by-day training on the sparse path "
                         "(repro.stream): sliding-window minibatch OWLQN+ "
                         "with an overlapped host re-planner")
    ap.add_argument("--days", type=int, default=8,
                    help="--stream: days in the synthetic stream")
    ap.add_argument("--window", type=int, default=2,
                    help="--stream: sliding window width (days)")
    ap.add_argument("--inner-iters", type=int, default=5,
                    help="--stream: OWLQN+ iterations per window")
    ap.add_argument("--history", choices=("reset", "carry"), default="reset",
                    help="--stream: L-BFGS history policy at window "
                         "boundaries (Theta always carries)")
    ap.add_argument("--drift", type=float, default=0.02,
                    help="--stream: per-day id-traffic drift fraction")
    ap.add_argument("--sync-planner", action="store_true",
                    help="--stream: disable the overlapped background "
                         "re-planner (synchronous fallback)")
    ap.add_argument("--resume", action="store_true",
                    help="--stream: resume from --ckpt if it exists")
    add_tuning_flags(ap)
    obs.add_flags(ap)
    args = ap.parse_args()

    if tuning_flags_set(args) and not (args.sparse or args.stream):
        raise SystemExit(
            "--block-n/--block-k/--chunk/--tune steer the sparse kernels; "
            "combine them with --sparse or --stream (the dense path has "
            "no tunable block sizes)")
    mode = "stream" if args.stream else "sparse" if args.sparse else "dense"
    if args.drift_ref and mode == "dense":
        raise SystemExit(
            "--drift-ref captures a sparse-id traffic reference; combine "
            "it with --sparse or --stream (the dense path has no feature "
            "ids to histogram)")
    session = obs.configure_from_args(args, driver="repro.launch.train",
                                      mode=mode)
    try:
        if args.stream:
            return train_stream(args)
        if args.sparse:
            return train_sparse(args)
        return train_dense(args)
    finally:
        session.close()


if __name__ == "__main__":
    raise SystemExit(main())
