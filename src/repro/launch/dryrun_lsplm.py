import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Dry-run of the paper's own production job: one Algorithm-1 iteration of
LS-PLM on the (16,16) single-pod and (2,16,16) multi-pod meshes.

Production scale stand-in: d = 2^19 features (12.6M parameters at m=12 —
the paper's 'tens of millions' regime), common-feature batch of 2^14
samples / 2^12 sessions per iteration. The paper's sparse-hash feature
store is simulated by dense columns (DESIGN.md §8).

  PYTHONPATH=src python -m repro.launch.dryrun_lsplm [--multi] [--out f.json]
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.objective import CommonFeatureBatch, smooth_loss_and_grad
from repro.dist import batch_specs, state_specs
from repro.launch.mesh import data_axes, make_production_mesh
from repro.optim import OWLQNPlus
from repro.utils.hlo import collective_bytes
from repro.utils.roofline import Roofline

D_FEATURES = 2**19
D_COMMON = 2**18
M_REGIONS = 12
BATCH = 2**14
SESSIONS = 2**12


def run(mesh_name: str, variant: str = "baseline"):
    """variants (§Perf): 'baseline' (fp32 features, LBFGS memory 10),
    'bf16_features' (feature matrices in bf16 — CTR indicators/counts
    tolerate it), 'bf16+m5_history' (also halve the LBFGS memory)."""
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = 512 if mesh_name == "multi" else 256
    dp = data_axes(mesh)
    sds = jax.ShapeDtypeStruct
    feat_dtype = jnp.bfloat16 if "bf16" in variant else jnp.float32
    memory = 5 if "m5" in variant else 10
    sessions = SESSIONS // 2 if variant == "cf8_sessions" else SESSIONS
    batch = CommonFeatureBatch(
        x_common=sds((sessions, D_COMMON), feat_dtype),
        x_noncommon=sds((BATCH, D_FEATURES - D_COMMON), feat_dtype),
        session_id=sds((BATCH,), jnp.int32),
        y=sds((BATCH,), jnp.float32),
        weight=sds((BATCH,), jnp.float32),
    )
    bspec = batch_specs(mesh, common_feature=True)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))

    def step(state, batch):
        opt = OWLQNPlus(
            lambda t: smooth_loss_and_grad(t, batch, common_feature=True),
            lam=1.0, beta=1.0, memory=memory)
        return opt.step(state)

    opt0 = OWLQNPlus(lambda t: (jnp.zeros(()), t), lam=1.0, beta=1.0,
                     memory=memory)
    theta_s = sds((D_FEATURES, 2 * M_REGIONS), jnp.float32)
    state_s = jax.eval_shape(opt0.init, theta_s)
    sspec = state_specs(mesh)

    t0 = time.time()
    jitted = jax.jit(step, in_shardings=(ns(sspec), ns(bspec)),
                     out_shardings=(ns(sspec), None))
    lowered = jitted.lower(state_s, batch)
    compiled = lowered.compile()
    dt = time.time() - t0
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax<=0.4 returns one dict per device
        ca = ca[0]
    ma = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    # Algorithm 1's line search is a while loop (body counted once); its
    # trip count is data dependent (typically 1-3 accepted quickly) —
    # report body-once numbers and note the multiplier.
    params = D_FEATURES * 2 * M_REGIONS
    # model flops: ls-plm fwd+bwd ~ 6 * params * batch eqv (common-feature
    # compressed: common rows count once per session)
    eff_rows = SESSIONS * D_COMMON + BATCH * (D_FEATURES - D_COMMON)
    model_flops = 6.0 * 2 * M_REGIONS * eff_rows / chips
    rl = Roofline(
        flops=float(ca.get("flops", 0.0)),
        hbm_bytes=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=float(coll["total_bytes"]),
        model_flops=model_flops,
    )
    rec = {
        "arch": "lsplm-production", "shape": "ctr_iteration", "mesh": mesh_name,
        "variant": variant,
        "chips": chips, "params": params,
        "compile_seconds": round(dt, 1),
        "memory": {
            "argument_bytes_per_chip": ma.argument_size_in_bytes,
            "temp_bytes_per_chip": ma.temp_size_in_bytes,
            "total_bytes_per_chip": ma.argument_size_in_bytes + ma.temp_size_in_bytes,
        },
        "collectives": coll,
        "roofline": rl.to_dict(),
    }
    r = rec["roofline"]
    print(f"[OK] lsplm-production {mesh_name} [{variant}]: "
          f"params={params / 1e6:.1f}M "
          f"mem/chip={rec['memory']['total_bytes_per_chip'] / 2**30:.2f}GiB "
          f"t_comp={r['t_compute_s']:.3e} t_mem={r['t_memory_s']:.3e} "
          f"t_coll={r['t_collective_s']:.3e} bound={r['bottleneck']}",
          flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    recs = [run(m) for m in meshes]
    if args.out:
        with open(args.out, "w") as f:
            json.dump(recs, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
