"""granite-moe-1b-a400m [moe] — 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab_size=49155, head_dim=64,
    num_experts=32, top_k=8, mlp_type="swiglu",
)
