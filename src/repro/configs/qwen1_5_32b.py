"""qwen1.5-32b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B family, 32B dims]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b", family="dense", source="hf:Qwen/Qwen1.5-0.5B",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
    d_ff=27392, vocab_size=152064, head_dim=128,
    mlp_type="swiglu", qkv_bias=True, rope_theta=1000000.0,
)
