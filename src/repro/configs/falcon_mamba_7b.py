"""falcon-mamba-7b [ssm] — attention-free Mamba1 [arXiv:2410.05355]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm", source="arXiv:2410.05355",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=65024,
    ssm_version=1, ssm_state=16, ssm_expand=2, ssm_conv=4,
)
