"""dbrx-132b [moe] — 16 experts top-4, fine-grained [hf:databricks/dbrx-base]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe", source="hf:databricks/dbrx-base",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=10752, vocab_size=100352, head_dim=128,
    num_experts=16, top_k=4, mlp_type="swiglu", rope_theta=500000.0,
)
