"""internvl2-2b [vlm] — InternViT frontend (STUB embeddings per the
modality carve-out) + InternLM2-1.8B language backbone [arXiv:2404.16821].
`input_specs` provides 256 precomputed patch embeddings per image."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm", source="arXiv:2404.16821",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553, head_dim=128,
    mlp_type="swiglu", num_prefix_embeds=256,
)
