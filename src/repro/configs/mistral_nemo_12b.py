"""mistral-nemo-12b [dense] — 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b", family="dense",
    source="hf:mistralai/Mistral-Nemo-Base-2407",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128,
    mlp_type="swiglu", rope_theta=1000000.0,
)
