"""Architecture config schema for the model zoo.

Every assigned architecture is expressed as an ``ArchConfig``; the model
builder (`repro.models.transformer`) consumes it. `reduced()` yields the
smoke-test variant (2 layers, d_model<=512, <=4 experts) mandated for CPU
tests.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    source: str  # citation (hf:... / arXiv:...)

    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: int | None = None  # default d_model // num_heads

    # layer flavour
    mlp_type: Literal["swiglu", "gelu"] = "swiglu"
    norm_type: Literal["rmsnorm", "nonparametric"] = "rmsnorm"  # olmo: nonparametric
    qkv_bias: bool = False  # qwen1.5
    rope_theta: float = 10000.0
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0  # 0 = dense MLP
    top_k: int = 0
    router_aux_coef: float = 0.01  # load-balance loss (divide-and-conquer health)

    # SSM (mamba)
    ssm_version: int = 0  # 0 = none, 1 = mamba1, 2 = mamba2
    ssm_state: int = 16
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_headdim: int = 64  # mamba2 head dim
    dt_rank: int | None = None  # mamba1; default ceil(d_model/16)

    # hybrid (zamba2): shared transformer block applied every k ssm layers
    shared_attn_every: int = 0  # 0 = disabled

    # modality frontend stub (vlm / audio): model consumes embeddings
    embeds_in: bool = False
    num_prefix_embeds: int = 0  # e.g. vision patches prepended (vlm)

    # long-context variant
    sliding_window: int = 8192  # used only by long_500k decode for attn archs

    # training
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # sharding strategy knobs (§Perf variants; defaults = baseline plan)
    seq_parallel: bool = False  # shard inter-block activations on S over
    #                             'model' (Megatron-SP style)
    attn_shard: str = "heads"  # "heads" | "head_dim" — which attention
    #                            axis the 'model' mesh axis shards
    kv_cache_dtype: str = "bf16"  # "bf16" | "int8" (quantised serving
    #                               cache with per-(token,head) scales)
    ce_chunk: int = 0  # >0: compute logits+CE in sequence chunks of this
    #                    size (remat'd) instead of materialising (B,S,V)

    # lowering knobs (dry-run cost probes flip these; defaults are the
    # production values)
    unroll_layers: bool = False  # unroll layer/attn-chunk scans so XLA's
    #                              cost_analysis sees every iteration
    attn_chunk: int = 512  # query-chunk size of chunked causal attention
    ssd_chunk: int = 64  # mamba2 SSD chunk length

    # -- derived -------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def d_inner(self) -> int:  # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank if self.dt_rank is not None else -(-self.d_model // 16)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can run long_500k natively (without the sliding-window variant)."""
        return self.family in ("ssm", "hybrid")

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/flavour, tiny dims."""
        d_model = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = max(1, min(self.num_kv_heads, heads))
        # keep GQA ratio flavour: if original had kv < heads, keep kv < heads
        if 0 < self.num_kv_heads < self.num_heads:
            kv = max(1, heads // 2)
        if self.num_heads == 0:  # attention-free ssm
            heads, kv = 0, 0
        return dataclasses.replace(
            self,
            num_layers=2,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=(d_model // heads) if heads else None,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16),
            ssm_headdim=min(self.ssm_headdim, 32),
            shared_attn_every=2 if self.shared_attn_every else 0,
            sliding_window=64,
            num_prefix_embeds=min(self.num_prefix_embeds, 8),
        )

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d  # lm head
        per_layer = 0
        if self.family == "ssm" or self.ssm_version:
            di, N = self.d_inner, self.ssm_state
            if self.ssm_version == 1:
                per_layer += d * 2 * di + di * self.ssm_conv
                per_layer += di * (self.resolved_dt_rank + 2 * N)
                per_layer += self.resolved_dt_rank * di + di * N + di + di * d
            else:  # mamba2
                nheads = di // self.ssm_headdim
                per_layer += d * (2 * di + 2 * N + nheads) + di * self.ssm_conv
                per_layer += nheads + di * d
        if self.family != "ssm" and not (self.family == "hybrid"):
            per_layer += d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d
        if self.num_experts:
            per_layer += d * self.num_experts
            per_layer += self.num_experts * 3 * d * self.d_ff
        elif self.d_ff and self.family != "ssm":
            mult = 3 if self.mlp_type == "swiglu" else 2
            per_layer += mult * d * self.d_ff
        n += L * per_layer
        if self.shared_attn_every:
            mult = 3 if self.mlp_type == "swiglu" else 2
            n += d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
                 + self.num_heads * hd * d + mult * d * self.d_ff
        return n

    def active_param_count(self) -> int:
        """N_active for MoE/hybrid rooflines (6*N_active*D): params that
        actually multiply each token. MoE: only top-k experts. Hybrid: the
        shared transformer block runs L/shared_attn_every times, so its
        params count that many times."""
        full = self.param_count()
        d = self.d_model
        if self.num_experts:
            unused = self.num_layers * (self.num_experts - self.top_k) \
                * 3 * d * self.d_ff
            full -= unused
        if self.shared_attn_every:
            hd = self.resolved_head_dim
            mult = 3 if self.mlp_type == "swiglu" else 2
            shared = (d * self.num_heads * hd
                      + 2 * d * self.num_kv_heads * hd
                      + self.num_heads * hd * d + mult * d * self.d_ff)
            reps = self.num_layers // self.shared_attn_every
            full += (reps - 1) * shared
        return full
