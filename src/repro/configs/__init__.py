"""Architecture registry: ``--arch <id>`` lookup + input-shape contracts.

``input_specs(cfg, shape_name, reduced=...)`` returns ShapeDtypeStruct
stand-ins for every model input of the given workload shape — weak-type
correct, shardable, no device allocation (the dry-run pattern).
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

_ARCHS = {
    "llama3.2-1b": "llama3_2_1b",
    "qwen1.5-32b": "qwen1_5_32b",
    "zamba2-2.7b": "zamba2_2_7b",
    "olmo-1b": "olmo_1b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "internvl2-2b": "internvl2_2b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "musicgen-medium": "musicgen_medium",
    "dbrx-132b": "dbrx_132b",
}

# The four assigned workload shapes.
INPUT_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def list_archs() -> list[str]:
    return list(_ARCHS)


def get_config(name: str) -> ArchConfig:
    if name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{_ARCHS[name]}")
    return mod.CONFIG


def uses_sliding_window(cfg: ArchConfig, shape_name: str) -> bool:
    """long_500k needs sub-quadratic attention: SSM/hybrid run natively,
    attention archs use the sliding-window decode variant (DESIGN.md §5)."""
    return shape_name == "long_500k" and cfg.family != "ssm"


def decode_cache_len(cfg: ArchConfig, shape_name: str) -> int:
    spec = INPUT_SHAPES[shape_name]
    if uses_sliding_window(cfg, shape_name):
        return min(cfg.sliding_window, spec["seq_len"])
    return spec["seq_len"]


def input_specs(cfg: ArchConfig, shape_name: str, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for the workload's model inputs."""
    spec = INPUT_SHAPES[shape_name]
    B, S = spec["global_batch"], spec["seq_len"]
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct

    if spec["kind"] == "train":
        if cfg.embeds_in:  # audio: frame embeddings from the codec stub
            return {"embeds": sds((B, S, cfg.d_model), dtype),
                    "labels": sds((B, S), i32)}
        if cfg.num_prefix_embeds:  # vlm: patch embeddings + text tokens
            P = cfg.num_prefix_embeds
            return {
                "prefix_embeds": sds((B, P, cfg.d_model), dtype),
                "tokens": sds((B, S - P), i32),
                "labels": sds((B, S - P), i32),
            }
        return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}

    if spec["kind"] == "prefill":
        if cfg.embeds_in:
            return {"embeds": sds((B, S, cfg.d_model), dtype)}
        if cfg.num_prefix_embeds:
            P = cfg.num_prefix_embeds
            return {"prefix_embeds": sds((B, P, cfg.d_model), dtype),
                    "tokens": sds((B, S - P), i32)}
        return {"tokens": sds((B, S), i32)}

    # decode: ONE new token against a cache of decode_cache_len positions
    if cfg.embeds_in:
        tok = {"embed": sds((B, cfg.d_model), dtype)}
    else:
        tok = {"token": sds((B,), i32)}
    tok["pos"] = sds((), i32)
    return tok
