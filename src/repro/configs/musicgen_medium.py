"""musicgen-medium [audio] — decoder-only LM over EnCodec tokens
[arXiv:2306.05284]. The EnCodec/conv frontend is a STUB per the modality
carve-out: `input_specs` provides precomputed frame embeddings (B,S,d);
the decoder predicts codebook tokens (vocab 2048)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio", source="arXiv:2306.05284",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    mlp_type="gelu", embeds_in=True,
)
