"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242]. Simplification noted in DESIGN.md: the shared
transformer block is applied every `shared_attn_every` Mamba2 layers
(Zamba2 additionally concatenates the original embedding into the shared
block input; we apply the block on the running hidden state)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid", source="arXiv:2411.15242",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm_version=2, ssm_state=64, ssm_expand=2, ssm_conv=4, ssm_headdim=64,
    shared_attn_every=6, mlp_type="swiglu",
)
