"""CTR evaluation metrics beyond AUC (industry standard set).

* log-loss (per-sample NLL) — the paper's training objective, reported
  per sample so datasets of different size compare;
* calibration ratio — sum(predicted CTR) / sum(clicks); online ad systems
  require this near 1.0 (bids are priced off predicted CTR);
* normalised entropy (He et al. 2014, the Facebook baseline the paper
  cites) — log-loss normalised by the entropy of the base rate.
"""
from __future__ import annotations

import numpy as np


def log_loss(y: np.ndarray, p: np.ndarray, eps: float = 1e-7) -> float:
    y = np.asarray(y, np.float64).ravel()
    p = np.clip(np.asarray(p, np.float64).ravel(), eps, 1 - eps)
    return float(-(y * np.log(p) + (1 - y) * np.log(1 - p)).mean())


def calibration_ratio(y: np.ndarray, p: np.ndarray) -> float:
    y = np.asarray(y, np.float64).ravel()
    p = np.asarray(p, np.float64).ravel()
    clicks = y.sum()
    return float(p.sum() / clicks) if clicks else float("inf")


def normalized_entropy(y: np.ndarray, p: np.ndarray) -> float:
    y = np.asarray(y, np.float64).ravel()
    base = y.mean()
    if base in (0.0, 1.0):
        return float("inf")
    h_base = -(base * np.log(base) + (1 - base) * np.log(1 - base))
    return log_loss(y, p) / h_base


def report(y: np.ndarray, p: np.ndarray) -> dict:
    from repro.data.synthetic_ctr import auc

    return {
        "auc": auc(np.asarray(y), np.asarray(p)),
        "log_loss": log_loss(y, p),
        "calibration": calibration_ratio(y, p),
        "normalized_entropy": normalized_entropy(y, p),
    }
