"""CTR evaluation metrics (industry standard set).

* AUC — rank-based (Fawcett 2006), ties by midrank; the paper's primary
  comparison metric (Fig. 5/7). This is the canonical implementation;
  ``repro.data.synthetic_ctr.auc`` re-exports it.
* log-loss (per-sample NLL) — the paper's training objective, reported
  per sample so datasets of different size compare;
* calibration ratio — mean predicted CTR / empirical CTR; online ad
  systems require this near 1.0 (bids are priced off predicted CTR).
  Used by the serving parity gates and ``benchmarks/bench_serve.py``;
* normalised entropy (He et al. 2014, the Facebook baseline the paper
  cites) — log-loss normalised by the entropy of the base rate.
"""
from __future__ import annotations

import numpy as np


def auc(y_true: np.ndarray, scores: np.ndarray) -> float:
    """Rank-based AUC (Fawcett 2006), ties handled by midrank."""
    y_true = np.asarray(y_true).ravel()
    scores = np.asarray(scores).ravel()
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    sorted_scores = scores[order]
    n = len(scores)
    i = 0
    r = 1.0
    while i < n:
        j = i
        while j + 1 < n and sorted_scores[j + 1] == sorted_scores[i]:
            j += 1
        ranks[order[i : j + 1]] = 0.5 * (r + r + (j - i))
        r += j - i + 1
        i = j + 1
    n_pos = y_true.sum()
    n_neg = n - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[y_true == 1].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))


def log_loss(y: np.ndarray, p: np.ndarray, eps: float = 1e-7) -> float:
    y = np.asarray(y, np.float64).ravel()
    p = np.clip(np.asarray(p, np.float64).ravel(), eps, 1 - eps)
    return float(-(y * np.log(p) + (1 - y) * np.log(1 - p)).mean())


def calibration_ratio(y: np.ndarray, p: np.ndarray) -> float:
    """mean(predicted CTR) / mean(empirical CTR) — 1.0 is perfectly
    calibrated; inf when the batch has no clicks."""
    y = np.asarray(y, np.float64).ravel()
    p = np.asarray(p, np.float64).ravel()
    clicks = y.sum()
    return float(p.sum() / clicks) if clicks else float("inf")


def bucketed_calibration(y: np.ndarray, p: np.ndarray,
                         edges: np.ndarray) -> np.ndarray:
    """Per-score-bucket :func:`calibration_ratio`: predictions are
    binned by ``edges`` (B+1 ascending bucket boundaries; values clamp
    into the end buckets) and each bucket's ratio is computed from its
    own (y, p) slice — ``inf`` where a bucket has no clicks, including
    empty buckets. Returns shape (B,). This is the per-bucket view the
    drift monitor compares against its train-time reference."""
    y = np.asarray(y, np.float64).ravel()
    p = np.asarray(p, np.float64).ravel()
    edges = np.asarray(edges, np.float64)
    nb = edges.size - 1
    idx = np.clip(np.searchsorted(edges, p, side="right") - 1, 0, nb - 1)
    sum_p = np.bincount(idx, weights=p, minlength=nb)
    sum_y = np.bincount(idx, weights=y, minlength=nb)
    return np.array([
        calibration_ratio(np.asarray([sy]), np.asarray([sp]))
        for sy, sp in zip(sum_y, sum_p)])


def normalized_entropy(y: np.ndarray, p: np.ndarray) -> float:
    y = np.asarray(y, np.float64).ravel()
    base = y.mean()
    if base in (0.0, 1.0):
        return float("inf")
    h_base = -(base * np.log(base) + (1 - base) * np.log(1 - base))
    return log_loss(y, p) / h_base


def report(y: np.ndarray, p: np.ndarray) -> dict:
    return {
        "auc": auc(np.asarray(y), np.asarray(p)),
        "log_loss": log_loss(y, p),
        "calibration": calibration_ratio(y, p),
        "normalized_entropy": normalized_entropy(y, p),
    }
