from repro.eval.metrics import (  # noqa: F401
    auc,
    bucketed_calibration,
    calibration_ratio,
    log_loss,
    normalized_entropy,
    report,
)
