from repro.eval.metrics import calibration_ratio, log_loss, normalized_entropy, report  # noqa: F401
