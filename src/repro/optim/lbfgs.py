"""Limited-memory BFGS two-loop recursion over pytrees.

History is stored as stacked leaves: each leaf of S/Y has shape
(M, *leaf.shape), ordered oldest -> newest in the last ``count`` slots
(slot M-1 is the newest). Invalid slots (unfilled, or pairs with
y.s <= 0, which would break positive-definiteness of the implied H)
are masked out — this realises the paper's §2.2.2 PD safeguard at the
history level as well.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


def tree_vdot(a: Pytree, b: Pytree) -> jax.Array:
    leaves = jax.tree.leaves(jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b))
    return jnp.sum(jnp.stack(leaves)) if len(leaves) > 1 else leaves[0]


def tree_axpy(alpha, x: Pytree, y: Pytree) -> Pytree:
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_scale(alpha, x: Pytree) -> Pytree:
    return jax.tree.map(lambda xi: alpha * xi, x)


class LBFGSHistory(NamedTuple):
    s: Pytree  # leaves (M, ...)
    y: Pytree  # leaves (M, ...)
    rho: jax.Array  # (M,) 1/(y.s), 0 where invalid
    valid: jax.Array  # (M,) bool
    gamma: jax.Array  # scalar: (s.y)/(y.y) of newest valid pair, else 1.0


def init_history(params_like: Pytree, memory: int) -> LBFGSHistory:
    zeros = jax.tree.map(
        lambda x: jnp.zeros((memory,) + x.shape, x.dtype), params_like
    )
    return LBFGSHistory(
        s=zeros,
        y=jax.tree.map(jnp.copy, zeros),
        rho=jnp.zeros((memory,)),
        valid=jnp.zeros((memory,), dtype=bool),
        gamma=jnp.asarray(1.0),
    )


def push(history: LBFGSHistory, s_new: Pytree, y_new: Pytree, eps: float = 1e-10) -> LBFGSHistory:
    """Append (s, y); newest lives at index M-1. Pair masked if y.s <= eps."""
    ys = tree_vdot(y_new, s_new)
    yy = tree_vdot(y_new, y_new)
    ok = ys > eps
    roll = lambda h, new: jnp.concatenate([h[1:], new[None]], axis=0)
    s = jax.tree.map(roll, history.s, s_new)
    y = jax.tree.map(roll, history.y, y_new)
    rho = jnp.concatenate([history.rho[1:], jnp.where(ok, 1.0 / jnp.where(ok, ys, 1.0), 0.0)[None]])
    valid = jnp.concatenate([history.valid[1:], ok[None]])
    gamma = jnp.where(ok, ys / jnp.where(yy > 0, yy, 1.0), history.gamma)
    return LBFGSHistory(s=s, y=y, rho=rho, valid=valid, gamma=gamma)


def two_loop(history: LBFGSHistory, d: Pytree) -> Pytree:
    """Return H @ d (H = implicit inverse Hessian). d plays the role that
    the negative gradient plays in smooth LBFGS (the paper uses the Eq. 9
    direction instead)."""
    M = history.rho.shape[0]

    def slot(tree, i):
        return jax.tree.map(lambda x: x[i], tree)

    def bwd(i, carry):
        # i runs 0..M-1 mapped to newest..oldest: idx = M-1-i
        q, alphas = carry
        idx = M - 1 - i
        s_i, y_i = slot(history.s, idx), slot(history.y, idx)
        a = history.rho[idx] * tree_vdot(s_i, q)
        a = jnp.where(history.valid[idx], a, 0.0)
        q = tree_axpy(-a, y_i, q)
        alphas = alphas.at[idx].set(a)
        return q, alphas

    q, alphas = jax.lax.fori_loop(0, M, bwd, (d, jnp.zeros((M,))))
    q = tree_scale(history.gamma, q)

    def fwd(idx, q):
        s_i, y_i = slot(history.s, idx), slot(history.y, idx)
        b = history.rho[idx] * tree_vdot(y_i, q)
        b = jnp.where(history.valid[idx], b, 0.0)
        coef = jnp.where(history.valid[idx], alphas[idx] - b, 0.0)
        return tree_axpy(coef, s_i, q)

    q = jax.lax.fori_loop(0, M, fwd, q)
    return q


def any_valid(history: LBFGSHistory) -> jax.Array:
    return jnp.any(history.valid)
