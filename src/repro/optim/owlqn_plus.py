"""Algorithm 1 (the paper's optimizer): OWLQN generalised to L1 + L2,1
non-convex objectives via directional-derivative descent directions.

Differences from standard LBFGS — exactly the paper's three modifications:
  1. Eq. 9 direction ``d`` replaces the negative gradient.
  2. Update direction ``p = pi(H d; d)`` constrained to d's orthant;
     pairs with y.s <= 0 are masked from the history (PD safeguard), and
     with an all-invalid history the two-loop degenerates to ``p = d``.
  3. Backtracking line search projects every trial point onto the orthant
     xi of Eq. 10 (Eq. 12).

Works on arbitrary pytrees. Group (L2,1) semantics per leaf: for ndim >= 2
leaves, axis -1 is the within-group axis (feature rows for the paper's
(d, 2m) Theta; fan-in rows for dense layers). 1-D leaves are treated as
(n, 1) — every element its own group, so L2,1 degenerates to L1 there.

The optimizer is pure-JAX and jit-able; under pjit with sharded Theta the
element/row-local algebra stays shard-local and only the scalar dot
products reduce — the paper's worker/server split (see DESIGN.md §3).
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import direction as dirlib
from repro.optim import lbfgs

Pytree = Any


# ---------------------------------------------------------------- leaf views
def _grouped(leaf: jax.Array) -> jax.Array:
    return leaf[:, None] if leaf.ndim == 1 else leaf


def _ungrouped(arr: jax.Array, like: jax.Array) -> jax.Array:
    return arr[:, 0] if like.ndim == 1 else arr


def _map_grouped(fn, *trees: Pytree) -> Pytree:
    def apply(*leaves):
        out = fn(*(_grouped(l) for l in leaves))
        return _ungrouped(out, leaves[0])

    return jax.tree.map(apply, *trees)


def direction_tree(theta: Pytree, grad: Pytree, lam: float, beta: float) -> Pytree:
    return _map_grouped(partial(dirlib.descent_direction, lam=lam, beta=beta), theta, grad)


def orthant_tree(theta: Pytree, d: Pytree) -> Pytree:
    return jax.tree.map(dirlib.choose_orthant, theta, d)


def project_tree(x: Pytree, omega: Pytree) -> Pytree:
    return jax.tree.map(dirlib.project_orthant, x, omega)


def reg_value(theta: Pytree, lam: float, beta: float) -> jax.Array:
    def leaf_reg(leaf):
        g = _grouped(leaf)
        l21 = jnp.sum(jnp.sqrt(jnp.sum(g * g, axis=-1)))
        l1 = jnp.sum(jnp.abs(g))
        return lam * l21 + beta * l1

    vals = [leaf_reg(l) for l in jax.tree.leaves(theta)]
    return jnp.sum(jnp.stack(vals))


def dirderiv_tree(theta: Pytree, grad: Pytree, d: Pytree, lam: float, beta: float) -> jax.Array:
    vals = [
        dirlib.directional_derivative(_grouped(t), _grouped(g), _grouped(dd), lam, beta)
        for t, g, dd in zip(jax.tree.leaves(theta), jax.tree.leaves(grad), jax.tree.leaves(d))
    ]
    return jnp.sum(jnp.stack(vals))


# ------------------------------------------------------------------- states
class OWLQNState(NamedTuple):
    theta: Pytree
    history: lbfgs.LBFGSHistory
    prev_theta: Pytree  # Theta^{k-1} (for s^{(k)})
    prev_d: Pytree  # d^{k-1}      (for y^{(k)} = d^{k-1} - d^{k})
    step: jax.Array  # iteration counter
    f: jax.Array  # full objective at theta (filled after first step)


class StepStats(NamedTuple):
    f: jax.Array  # objective BEFORE the step
    f_new: jax.Array
    alpha: jax.Array  # accepted step size (0 if line search failed)
    ls_iters: jax.Array
    grad_norm: jax.Array  # ||d|| — the optimality measure for Eq. 4
    nnz: jax.Array  # non-zero parameter count (sparsity tracking)


class OWLQNPlus:
    """Algorithm 1. ``loss_and_grad(theta) -> (loss, grad)`` must be the
    SMOOTH part (Eq. 5) only; regularisers are handled internally."""

    def __init__(
        self,
        loss_and_grad: Callable[[Pytree], tuple[jax.Array, Pytree]],
        lam: float,
        beta: float,
        memory: int = 10,
        c1: float = 1e-4,
        max_ls: int = 30,
        ls_shrink: float = 0.5,
    ):
        self.loss_and_grad = loss_and_grad
        self.lam = float(lam)
        self.beta = float(beta)
        self.memory = memory
        self.c1 = c1
        self.max_ls = max_ls
        self.ls_shrink = ls_shrink

    # -- init ---------------------------------------------------------------
    def init(self, theta0: Pytree) -> OWLQNState:
        return OWLQNState(
            theta=theta0,
            history=lbfgs.init_history(theta0, self.memory),
            prev_theta=jax.tree.map(jnp.copy, theta0),
            prev_d=jax.tree.map(jnp.zeros_like, theta0),
            step=jnp.asarray(0),
            f=jnp.asarray(jnp.inf),
        )

    # -- objective ----------------------------------------------------------
    def objective(self, theta: Pytree) -> jax.Array:
        loss, _ = self.loss_and_grad(theta)
        return loss + reg_value(theta, self.lam, self.beta)

    # -- one iteration of Algorithm 1 ----------------------------------------
    def step(self, state: OWLQNState) -> tuple[OWLQNState, StepStats]:
        lam, beta = self.lam, self.beta
        theta = state.theta
        loss, grad = self.loss_and_grad(theta)
        f0 = loss + reg_value(theta, lam, beta)

        # (1) Eq. 9 direction
        d = direction_tree(theta, grad, lam, beta)

        # (5)(6) push history pair from the PREVIOUS iteration
        s_prev = jax.tree.map(jnp.subtract, theta, state.prev_theta)
        y_prev = jax.tree.map(jnp.subtract, state.prev_d, d)  # -d^k - (-d^{k-1})
        history = jax.tree.map(
            lambda new, old: jnp.where(state.step > 0, new, old),
            lbfgs.push(state.history, s_prev, y_prev),
            state.history,
        )

        # (2) p = pi(H d; d); empty/masked history degenerates to p = d
        p = project_tree(lbfgs.two_loop(history, d), d)
        # safeguard: if the projection annihilated p (fully conflicting
        # curvature), fall back to d itself.
        p_norm2 = lbfgs.tree_vdot(p, p)
        p = jax.tree.map(lambda pi, di: jnp.where(p_norm2 > 0, pi, di), p, d)

        # (3) orthant xi (Eq. 10) + projected backtracking line search (Eq.12)
        xi = orthant_tree(theta, d)
        d_norm = jnp.sqrt(lbfgs.tree_vdot(d, d))
        alpha0 = jnp.where(
            state.step == 0,
            1.0 / jnp.maximum(jnp.sqrt(lbfgs.tree_vdot(p, p)), 1e-12),
            1.0,
        )
        neg_d = jax.tree.map(jnp.negative, d)  # pseudo-gradient analogue

        def trial(alpha):
            theta_t = project_tree(
                jax.tree.map(lambda t, pi: t + alpha * pi, theta, p), xi
            )
            loss_t, _ = self.loss_and_grad(theta_t)
            f_t = loss_t + reg_value(theta_t, lam, beta)
            # OWLQN acceptance: f(x') <= f(x) + c1 * <-d, x' - x>
            gain = lbfgs.tree_vdot(neg_d, jax.tree.map(jnp.subtract, theta_t, theta))
            ok = f_t <= f0 + self.c1 * gain
            return theta_t, f_t, ok

        def ls_cond(carry):
            alpha, _theta_t, _f_t, ok, it = carry
            return jnp.logical_and(jnp.logical_not(ok), it < self.max_ls)

        def ls_body(carry):
            alpha, _theta_t, _f_t, _ok, it = carry
            alpha = jnp.where(it == 0, alpha, alpha * self.ls_shrink)
            theta_t, f_t, ok = trial(alpha)
            return alpha, theta_t, f_t, ok, it + 1

        init = (alpha0, theta, f0, jnp.asarray(False), jnp.asarray(0))
        alpha, theta_t, f_t, ok, ls_iters = jax.lax.while_loop(ls_cond, ls_body, init)

        # line-search failure -> keep theta (alpha = 0)
        theta_new = jax.tree.map(
            lambda a, b: jnp.where(ok, a, b), theta_t, theta
        )
        f_new = jnp.where(ok, f_t, f0)
        alpha = jnp.where(ok, alpha, 0.0)

        nnz = jnp.sum(
            jnp.stack([jnp.sum(l != 0.0) for l in jax.tree.leaves(theta_new)])
        )
        new_state = OWLQNState(
            theta=theta_new,
            history=history,
            prev_theta=theta,
            prev_d=d,
            step=state.step + 1,
            f=f_new,
        )
        stats = StepStats(
            f=f0, f_new=f_new, alpha=alpha, ls_iters=ls_iters, grad_norm=d_norm, nnz=nnz
        )
        return new_state, stats

    # -- driver ---------------------------------------------------------------
    def run(
        self,
        theta0: Pytree,
        max_iters: int = 100,
        tol: float = 1e-6,
        callback: Callable[[int, StepStats], None] | None = None,
        jit: bool = True,
        ledger=None,
        tracer=None,
    ) -> tuple[Pytree, list[StepStats]]:
        """Python-loop driver with early stopping on ||d|| and f stagnation.

        Each iteration runs inside a ``train/iter`` span and — when a run
        ledger is active — emits one ``train_iter`` record (objective,
        accepted step, ``||d||`` optimality measure, non-zero count): the
        paper's convergence-vs-sparsity curves as a replayable artifact.
        The iteration math is untouched; observation happens on the host
        values ``run`` already pulls back, so trajectories are
        bit-for-bit identical with obs enabled or disabled.
        """
        led = ledger if ledger is not None else obs.get_ledger()
        tr = tracer if tracer is not None else obs.get_tracer()
        step_fn = jax.jit(self.step) if jit else self.step
        state = self.init(theta0)
        trace: list[StepStats] = []
        prev_f = None
        for k in range(max_iters):
            t0 = time.perf_counter()
            with tr.step_span("train/iter", k):
                state, stats = step_fn(state)
                trace.append(jax.device_get(stats))
            if led.enabled:
                st = trace[-1]
                led.emit(
                    "train_iter",
                    step=k,
                    f=float(st.f),
                    f_new=float(st.f_new),
                    alpha=float(st.alpha),
                    ls_iters=int(st.ls_iters),
                    grad_norm=float(st.grad_norm),
                    nnz=int(st.nnz),
                    wall_s=time.perf_counter() - t0,
                )
            if callback is not None:
                callback(k, trace[-1])
            f_new = float(trace[-1].f_new)
            if float(trace[-1].grad_norm) < tol:
                break
            if float(trace[-1].alpha) == 0.0:  # line search failed: converged
                break
            if prev_f is not None and abs(prev_f - f_new) <= tol * max(1.0, abs(prev_f)):
                break
            prev_f = f_new
        return state.theta, trace
