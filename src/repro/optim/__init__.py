from repro.optim.adamw import AdamW, AdamWState  # noqa: F401
from repro.optim.lbfgs import LBFGSHistory, init_history, push, two_loop  # noqa: F401
from repro.optim.owlqn_plus import OWLQNPlus, OWLQNState, StepStats  # noqa: F401
