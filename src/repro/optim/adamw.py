"""Minimal AdamW over pytrees (substrate for the transformer zoo; optax is
not available offline). Matches optax.adamw semantics (decoupled weight
decay, bias-corrected moments)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class AdamWState(NamedTuple):
    mu: Pytree
    nu: Pytree
    count: jax.Array


class AdamW(NamedTuple):
    lr: float | Callable[[jax.Array], jax.Array] = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0

    def init(self, params: Pytree) -> AdamWState:
        z = jax.tree.map(jnp.zeros_like, params)
        return AdamWState(mu=z, nu=jax.tree.map(jnp.copy, z), count=jnp.asarray(0))

    def update(self, grads: Pytree, state: AdamWState, params: Pytree):
        count = state.count + 1
        lr = self.lr(count) if callable(self.lr) else self.lr
        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g, state.nu, grads)
        mu_hat_scale = 1.0 / (1 - self.b1 ** count)
        nu_hat_scale = 1.0 / (1 - self.b2 ** count)
        updates = jax.tree.map(
            lambda m, v, p: -lr
            * (m * mu_hat_scale / (jnp.sqrt(v * nu_hat_scale) + self.eps)
               + self.weight_decay * p),
            mu, nu, params,
        )
        return updates, AdamWState(mu=mu, nu=nu, count=count)

    def apply(self, grads: Pytree, state: AdamWState, params: Pytree):
        updates, state = self.update(grads, state, params)
        return jax.tree.map(jnp.add, params, updates), state
