"""Autotune sweep harness: time kernel configs, parity-gate, persist.

For each shape envelope the harness times every config in a small grid
(best-of-``REPS`` wall clock on real device buffers) and accepts the
fastest config WHOSE OUTPUT MATCHES THE REF ORACLE — a config that loses
parity is rejected before it can ever be timed into the table, so a
miscompiled block size can make the sweep fail, never make training
wrong. A winner that does not beat the measured builtin default by
:data:`MIN_GAIN` is discarded in favour of the default — the table only
commits to wins that survive timing noise.

What gets swept depends on the backend (``repro.tune.table.backend_key``):

  * ``cpu`` (mode auto/jnp off-TPU): ``chunk_fwd``/``chunk_bwd`` — the
    K-chunk of the ``lax.scan`` fallbacks, forward and backward
    independently (their optima differ; see ``benchmarks/bench_tune.py``).
  * ``interpret`` (mode=interpret): ``fused_fwd`` / ``fused_fwd_int8``
    (block_n, block_k) and ``scatter`` (block_e). Interpret timings
    exercise the machinery and pick sane pipeline shapes for CI; they
    are not TPU performance.
  * ``tpu`` (mode auto/kernel on TPU): ``fused_fwd``, ``fused_fwd_int8``
    and ``scatter`` at the production shapes.

CLI (regeneration flow — see README "Autotuning"):

    PYTHONPATH=src python -m repro.tune.sweep --out src/repro/tune/tables/cpu.json
    PYTHONPATH=src python -m repro.tune.sweep --mode interpret --smoke \\
        --out src/repro/tune/tables/interpret.json
    # on a TPU host:
    PYTHONPATH=src python -m repro.tune.sweep --mode kernel \\
        --out src/repro/tune/tables/tpu.json

``--check TABLE.json`` re-times the committed config for every envelope
this sweep covers and fails (exit 1) if it is missing, loses parity, or
is slower than the fresh best by more than ``--check-tol`` — the CI
autotune job's freshness gate (timing-noise tolerant by design).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels.lsplm_sparse_fused.lsplm_sparse_fused import (
    lsplm_sparse_fused_forward,
    lsplm_sparse_fused_int8_forward,
)
from repro.kernels.lsplm_sparse_fused.ops import (
    _chunked_zmap,
    _dtheta_chunked,
    _dvals_chunked,
    pad_theta,
)
from repro.kernels.lsplm_sparse_fused.ref import sparse_matmul_ref
from repro.kernels.lsplm_sparse_scatter.ops import (
    build_transpose_plan,
    scatter_add_planned,
    scatter_add_ref,
)
from repro.tune import table as tabmod

# the production envelope bench_sparse_fused sweeps, the wide-K shapes
# bench_tune gates on, and the CI smoke shape — (N, K, d, m)
PROD_SHAPES = [(4096, 16, 16_384, 12), (8192, 16, 100_000, 8),
               (16384, 24, 500_000, 12), (32768, 48, 1_000_000, 4),
               (2048, 64, 100_000, 16), (8192, 64, 200_000, 8)]
SMOKE_SHAPES = [(512, 8, 4_096, 4)]

REPS = 5
# A non-default winner must beat the MEASURED default config by this
# factor to earn a table entry. Best-of-reps timing flatters marginal
# configs (the max of noisy estimates — winner's curse over the grid);
# a config that only "wins" by a few percent in the sweep routinely
# loses at bench time, so near-ties stay on the builtin default.
MIN_GAIN = 1.10
PARITY_RTOL = 2e-4
PARITY_ATOL = 2e-4

BLOCK_N_GRID = (64, 128, 256, 512)
BLOCK_K_GRID = (2, 4, 8, 16)
BLOCK_E_GRID = (256, 512, 1024, 2048, 4096)
CHUNK_GRID = (2, 4, 8, 16, 32, 48, 64)


def _make(n: int, k: int, d: int, m: int, seed: int = 0):
    """Deterministic sweep batch: padded Theta, pad-free uniform ids."""
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, d, (n, k)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    theta = jnp.asarray(rng.normal(size=(d, 2 * m)).astype(np.float32) * 0.1)
    dz = jnp.asarray(rng.normal(size=(n, 2 * m)).astype(np.float32))
    return ids, vals, pad_theta(theta), dz


def time_best(fn, *args, reps: int = REPS) -> float:
    """Best-of-``reps`` wall microseconds (after a compile + warm run)."""
    jax.block_until_ready(fn(*args))
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _parity(out, ref) -> bool:
    return bool(np.allclose(np.asarray(out), np.asarray(ref),
                            rtol=PARITY_RTOL, atol=PARITY_ATOL))


def _pick(rows: list[dict], default: dict | None = None) -> dict:
    """Fastest PARITY-PASSING config; raises if every config failed.

    With ``default`` (the kernel's builtin config), a non-default winner
    is only accepted when it beats the default's own measured time by
    :data:`MIN_GAIN`; otherwise the default row is returned."""
    ok = [r for r in rows if r["parity"]]
    if not ok:
        raise RuntimeError(f"no config passed parity: {rows}")
    best = min(ok, key=lambda r: r["us"])
    if default is not None and best["config"] != default:
        base = [r for r in ok if r["config"] == default]
        if base and base[0]["us"] < best["us"] * MIN_GAIN:
            return base[0]
    return best


def _sweep_rows(grid, make_fn, ref, *, reps: int) -> list[dict]:
    """Time each config in ``grid``; parity-gate before timing."""
    rows = []
    for cfg in grid:
        fn, args = make_fn(cfg)
        if not _parity(fn(*args), ref):
            rows.append({"config": cfg, "us": float("inf"), "parity": False})
            continue
        rows.append({"config": cfg, "us": time_best(fn, *args, reps=reps),
                     "parity": True})
    return rows


# ------------------------------------------------------------ per-kernel
def sweep_fused(n, k, d, m, *, mode: str, reps: int = REPS,
                extra: tuple = ()) -> list[dict]:
    """(block_n, block_k) grid for the Pallas fused forward."""
    ids, vals, tp, _ = _make(n, k, d, m)
    ref = sparse_matmul_ref(ids, vals, tp)
    grid = [(bn, bk) for bn in BLOCK_N_GRID if bn <= n
            for bk in BLOCK_K_GRID if bk <= k]
    grid = sorted(set(grid) | {e for e in extra if e[0] <= n and e[1] <= k})

    def make_fn(cfg):
        bn, bk = cfg

        def fn(i, v, t):
            _, z = lsplm_sparse_fused_forward(
                i, v, t, block_n=bn, block_k=bk,
                interpret=mode == "interpret")
            return z

        return fn, (ids, vals, tp)

    rows = _sweep_rows(grid, make_fn, ref, reps=reps)
    for r in rows:
        r["config"] = {"block_n": r["config"][0], "block_k": r["config"][1]}
    return rows


def sweep_fused_int8(n, k, d, m, *, mode: str, reps: int = REPS,
                     extra: tuple = ()) -> list[dict]:
    """(block_n, block_k) grid for the int8-native fused forward.

    The sweep model is the symmetric per-row quantisation of the fp32
    sweep Theta (``repro.serve.compress.quantize``'s rule, inlined on a
    plain padded Theta); the parity oracle is the ref matmul on the
    DEQUANTISED rows, so a block size only enters the table if the
    int8 pipeline reproduces the dequantise-then-score numbers."""
    ids, vals, tp, _ = _make(n, k, d, m)
    th = np.asarray(tp)
    amax = np.abs(th).max(axis=1)
    scales = (amax / 127.0).astype(np.float32)  # pad row stays scale 0
    safe = np.where(scales > 0, scales, 1.0)
    codes = np.rint(th / safe[:, None]).astype(np.int8)
    ref = sparse_matmul_ref(
        ids, vals, jnp.asarray(codes.astype(np.float32) * scales[:, None]))
    codes, scales = jnp.asarray(codes), jnp.asarray(scales)
    grid = [(bn, bk) for bn in BLOCK_N_GRID if bn <= n
            for bk in BLOCK_K_GRID if bk <= k]
    grid = sorted(set(grid) | {e for e in extra if e[0] <= n and e[1] <= k})

    def make_fn(cfg):
        bn, bk = cfg

        def fn(i, v, c, s):
            _, z = lsplm_sparse_fused_int8_forward(
                i, v, c, s, block_n=bn, block_k=bk,
                interpret=mode == "interpret")
            return z

        return fn, (ids, vals, codes, scales)

    rows = _sweep_rows(grid, make_fn, ref, reps=reps)
    for r in rows:
        r["config"] = {"block_n": r["config"][0], "block_k": r["config"][1]}
    return rows


def sweep_scatter(n, k, d, m, *, mode: str, reps: int = REPS,
                  extra: tuple = ()) -> tuple[list[dict], int]:
    """block_e grid for the Pallas run-length scatter; returns
    (rows, kept-entry count) so the caller can key the envelope."""
    ids, vals, tp, dz = _make(n, k, d, m)
    plan = build_transpose_plan(np.asarray(ids), num_rows=tp.shape[0])
    ref = scatter_add_ref(ids, vals, dz, tp.shape[0])
    grid = sorted(set(e for e in BLOCK_E_GRID) | set(extra))

    def make_fn(block_e):
        fn = jax.jit(lambda v, g: scatter_add_planned(
            plan, v, g, mode=mode, block_e=block_e))
        return fn, (vals, dz)

    rows = _sweep_rows(grid, make_fn, ref, reps=reps)
    for r in rows:
        r["config"] = {"block_e": r["config"]}
    return rows, plan.num_kept


def sweep_chunk_fwd(n, k, d, m, *, reps: int = REPS,
                    extra: tuple = ()) -> list[dict]:
    """chunk grid for the forward ``lax.scan`` fallback (jnp path)."""
    ids, vals, tp, _ = _make(n, k, d, m)
    ref = sparse_matmul_ref(ids, vals, tp)
    grid = sorted(c for c in set(CHUNK_GRID) | {k} | set(extra) if c <= k)

    def make_fn(chunk):
        fn = jax.jit(lambda i, v, t: _chunked_zmap(i, v, t, chunk))
        return fn, (ids, vals, tp)

    rows = _sweep_rows(grid, make_fn, ref, reps=reps)
    for r in rows:
        r["config"] = {"chunk": r["config"]}
    return rows


def sweep_chunk_bwd(n, k, d, m, *, reps: int = REPS,
                    extra: tuple = ()) -> list[dict]:
    """chunk grid for the backward scans (scatter-add + gather-dot)."""
    ids, vals, tp, dz = _make(n, k, d, m)
    dt_ref = scatter_add_ref(ids, vals, dz, tp.shape[0])
    dv_ref = jnp.einsum("nkm,nm->nk", jnp.take(tp, ids, axis=0), dz)
    ref = np.concatenate([np.asarray(dt_ref).ravel(),
                          np.asarray(dv_ref).ravel()])
    grid = sorted(c for c in set(CHUNK_GRID) | {k} | set(extra) if c <= k)

    def make_fn(chunk):
        def raw(i, v, t, g):
            return (_dtheta_chunked(i, v, t, g, chunk),
                    _dvals_chunked(i, v, t, g, chunk))

        jitted = jax.jit(raw)

        def fn(i, v, t, g):
            dt, dv = jitted(i, v, t, g)
            return jnp.concatenate([dt.ravel(), dv.ravel()])

        return fn, (ids, vals, tp, dz)

    rows = _sweep_rows(grid, make_fn, ref, reps=reps)
    for r in rows:
        r["config"] = {"chunk": r["config"]}
    return rows


# --------------------------------------------------------------- driver
def kernels_for_backend(backend: str) -> tuple[str, ...]:
    """Which table kernels matter on a backend: Pallas block sizes where
    the kernels actually compile/interpret, scan chunks elsewhere."""
    if backend in ("interpret", "tpu"):
        return ("fused_fwd", "fused_fwd_int8", "scatter")
    return ("chunk_fwd", "chunk_bwd")


def sweep_shapes(shapes, *, mode: str = "auto", reps: int = REPS,
                 table: tabmod.AutotuneTable | None = None,
                 log=obs.log) -> tabmod.AutotuneTable:
    """Sweep every applicable kernel at every shape into ``table``."""
    backend = tabmod.backend_key(mode)
    table = table if table is not None else tabmod.AutotuneTable()
    for n, k, d, m in shapes:
        m2 = 2 * m
        env = tabmod.fused_envelope(n, k, m2)
        for kernel in kernels_for_backend(backend):
            if kernel == "fused_fwd":
                rows = sweep_fused(n, k, d, m, mode=mode, reps=reps)
            elif kernel == "fused_fwd_int8":
                rows = sweep_fused_int8(n, k, d, m, mode=mode, reps=reps)
            elif kernel == "scatter":
                rows, kept = sweep_scatter(n, k, d, m, mode=mode, reps=reps)
                env_k = tabmod.scatter_envelope(kept, m2)
            elif kernel == "chunk_fwd":
                rows = sweep_chunk_fwd(n, k, d, m, reps=reps)
            else:
                rows = sweep_chunk_bwd(n, k, d, m, reps=reps)
            env_k = env_k if kernel == "scatter" else env
            best = _pick(rows, default=tabmod.BUILTIN_DEFAULTS[kernel])
            table.put(backend, kernel, env_k, best["config"])
            log(f"tune/{backend}/{kernel}/{env_k}: best {best['config']} "
                f"{best['us']:.0f}us over {len(rows)} configs "
                f"({sum(not r['parity'] for r in rows)} parity-rejected)")
    table.meta.setdefault(backend, {}).update({
        "reps": reps, "mode": mode,
        "shapes": [list(s) for s in shapes],
        "generator": "python -m repro.tune.sweep",
    })
    return table


def check_table(shapes, committed: tabmod.AutotuneTable, *,
                mode: str = "auto", reps: int = REPS, tol: float = 2.0,
                log=obs.log) -> list[str]:
    """Freshness gate: the committed config for every envelope covered by
    ``shapes`` must exist, hold parity, and stay within ``tol`` x of a
    fresh sweep's best time. Returns failure strings (empty == pass)."""
    backend = tabmod.backend_key(mode)
    failures = []
    for n, k, d, m in shapes:
        m2 = 2 * m
        for kernel in kernels_for_backend(backend):
            env = tabmod.fused_envelope(n, k, m2)
            if kernel == "scatter":
                env = tabmod.scatter_envelope(n * k, m2)
            cfg = committed.get(backend, kernel, env)
            if cfg is None:
                failures.append(f"{backend}/{kernel}/{env}: no committed entry")
                continue
            extra = (tuple(cfg[p] for p in ("block_n", "block_k"))
                     if kernel in ("fused_fwd", "fused_fwd_int8")
                     else tuple(cfg.values()))
            if kernel == "fused_fwd":
                rows = sweep_fused(n, k, d, m, mode=mode, reps=reps,
                                   extra=(extra,))
            elif kernel == "fused_fwd_int8":
                rows = sweep_fused_int8(n, k, d, m, mode=mode, reps=reps,
                                        extra=(extra,))
            elif kernel == "scatter":
                rows, _ = sweep_scatter(n, k, d, m, mode=mode, reps=reps,
                                        extra=extra)
            elif kernel == "chunk_fwd":
                rows = sweep_chunk_fwd(n, k, d, m, reps=reps, extra=extra)
            else:
                rows = sweep_chunk_bwd(n, k, d, m, reps=reps, extra=extra)
            best = _pick(rows)
            mine = [r for r in rows if r["config"] == cfg]
            if not mine or not mine[0]["parity"]:
                failures.append(f"{backend}/{kernel}/{env}: committed {cfg} "
                                "lost parity with the ref oracle")
                continue
            ratio = mine[0]["us"] / best["us"]
            status = "ok" if ratio <= tol else f"STALE (> {tol:.1f}x)"
            log(f"check/{backend}/{kernel}/{env}: committed {cfg} "
                f"{mine[0]['us']:.0f}us vs fresh best {best['config']} "
                f"{best['us']:.0f}us — {ratio:.2f}x {status}")
            if ratio > tol:
                failures.append(
                    f"{backend}/{kernel}/{env}: committed {cfg} is "
                    f"{ratio:.2f}x slower than fresh best {best['config']}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--mode", default="auto",
                    choices=("auto", "kernel", "interpret", "jnp"))
    ap.add_argument("--smoke", action="store_true",
                    help="sweep the CI smoke shape only")
    ap.add_argument("--reps", type=int, default=REPS)
    ap.add_argument("--out", default=None,
                    help="write/merge the swept table into this JSON file")
    ap.add_argument("--check", default=None,
                    help="freshness-gate a committed table instead of writing")
    ap.add_argument("--check-tol", type=float, default=2.0)
    args = ap.parse_args(argv)

    shapes = SMOKE_SHAPES if args.smoke else PROD_SHAPES + SMOKE_SHAPES
    if args.check:
        committed = tabmod.AutotuneTable.load(args.check)
        failures = check_table(shapes, committed, mode=args.mode,
                               reps=args.reps, tol=args.check_tol)
        for f in failures:
            obs.log(f"FAIL {f}",
                    printer=lambda msg: print(msg, file=sys.stderr))
        return 1 if failures else 0

    table = None
    if args.out:
        try:  # merge into the existing file so envelopes accumulate
            table = tabmod.AutotuneTable.load(args.out)
        except OSError:
            table = None
    table = sweep_shapes(shapes, mode=args.mode, reps=args.reps, table=table)
    backend = tabmod.backend_key(args.mode)
    if args.out:
        table.save(args.out, backend)
        obs.log(f"wrote {args.out} [{backend}]")
    else:
        print(table.to_json(backend))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
