"""Kernel autotune subsystem: swept block sizes behind every hot path.

``repro.tune.table`` holds the persisted ``(backend, kernel, envelope) ->
config`` table the sparse ops resolve their block sizes from;
``repro.tune.sweep`` regenerates it (timed + parity-gated). See the
README "Autotuning" section.
"""
from repro.tune.table import (  # noqa: F401
    AutotuneTable,
    BUILTIN_DEFAULTS,
    E_BUCKETS,
    K_BUCKETS,
    KERNEL_PARAMS,
    M2_BUCKETS,
    N_BUCKETS,
    TABLES_DIR,
    active_table,
    backend_key,
    clear_overrides,
    fused_envelope,
    get_overrides,
    resolve,
    round_up,
    scatter_envelope,
    set_active_table,
    set_overrides,
)
