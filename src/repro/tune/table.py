"""Persisted kernel autotune table — the repo's one source of block sizes.

Every hot path (fused sparse forward, plan-driven scatter backward, the
K-chunked jnp fallbacks) takes block-size knobs that used to be
hand-picked constants. This module replaces the constants with a lookup
keyed on ``(backend, kernel, shape envelope)``:

  * **backend** — ``"interpret"`` for interpret-mode Pallas runs, else
    ``jax.default_backend()`` (``"cpu"``, ``"tpu"``, ...). A config swept
    on one backend never leaks onto another.
  * **kernel** — one of :data:`KERNEL_PARAMS`: ``"fused_fwd"`` /
    ``"fused_fwd_int8"`` (block_n, block_k — the int8-native gather
    variant tunes independently: its row DMAs move 4x fewer bytes, so
    its pipeline optimum need not match fp32), ``"scatter"`` (block_e),
    ``"chunk_fwd"`` / ``"chunk_bwd"`` (chunk — forward and backward
    scans tune independently; their optimal chunks differ, see
    ``benchmarks/bench_tune.py``).
  * **envelope** — the shape bucket, rounded with the same
    :func:`round_up` rule the serving engine uses for its executable
    cache (smallest bucket edge >= x; past the top edge, next multiple of
    it). Envelopes are deliberately **d-free**: kernel cost does not
    depend on the Theta row count, and keying on (N, K, 2m) only keeps
    pruned-vs-full scoring on the same envelope — same config, bitwise
    identical results.

Resolution precedence (what a call site actually gets):

    explicit kwarg  >  set_overrides()  >  table entry  >  builtin default

Tables are JSON, one file per backend, under ``src/repro/tune/tables/``
(``cpu.json`` and ``interpret.json`` are committed; regenerate with
``python -m repro.tune.sweep`` — see the README "Autotuning" section).
The active table is loaded lazily ONCE per process and every
:func:`resolve` after that is a dict lookup: zero steady-state sweeps,
zero file I/O on the hot path.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Mapping, Sequence

import jax

# Bucket edges for envelope rounding. N covers batch-tile row counts from
# serving slates to full training batches; K/M2 mirror the serving
# engine's dense-at-the-small-end id-list edges; E covers sorted-entry
# counts (~N*K) for the scatter kernel.
N_BUCKETS = (256, 512, 1024, 2048, 4096, 8192, 16384, 32768, 65536)
K_BUCKETS = (4, 8, 16, 24, 32, 48, 64)
M2_BUCKETS = (4, 8, 16, 24, 32, 48, 64)
E_BUCKETS = (4096, 16384, 65536, 262144, 1048576, 4194304)

# kernel name -> the config keys a table entry for it must carry
KERNEL_PARAMS: dict[str, tuple[str, ...]] = {
    "fused_fwd": ("block_n", "block_k"),
    "fused_fwd_int8": ("block_n", "block_k"),
    "scatter": ("block_e",),
    "chunk_fwd": ("chunk",),
    "chunk_bwd": ("chunk",),
}

# the hand-picked constants the repo shipped with — the fallback when no
# table entry exists (unknown backend, unswept envelope) and the baseline
# every tuned config is benched against
BUILTIN_DEFAULTS: dict[str, dict[str, int]] = {
    "fused_fwd": {"block_n": 256, "block_k": 8},
    "fused_fwd_int8": {"block_n": 256, "block_k": 8},
    "scatter": {"block_e": 1024},
    "chunk_fwd": {"chunk": 8},
    "chunk_bwd": {"chunk": 8},
}

# every overridable knob, with the kernels it applies to
_PARAM_KERNELS = {
    "block_n": ("fused_fwd", "fused_fwd_int8"),
    "block_k": ("fused_fwd", "fused_fwd_int8"),
    "block_e": ("scatter",),
    "chunk": ("chunk_fwd", "chunk_bwd"),
}

TABLES_DIR = Path(__file__).resolve().parent / "tables"


def round_up(x: int, buckets: Sequence[int]) -> int:
    """Smallest bucket edge >= x; past the top edge, next multiple of it.

    The one envelope-rounding rule, shared with the serving engine's
    executable cache (``repro.serve.engine``)."""
    if x <= 0:
        raise ValueError(f"dimension must be positive, got {x}")
    for b in buckets:
        if x <= b:
            return b
    top = buckets[-1]
    return -(-x // top) * top


def fused_envelope(n: int, k: int, m2: int) -> str:
    """Envelope key for the forward-side kernels (fused_fwd, chunk_*)."""
    return (f"n{round_up(n, N_BUCKETS)}"
            f"_k{round_up(k, K_BUCKETS)}"
            f"_m{round_up(m2, M2_BUCKETS)}")


def scatter_envelope(entries: int, m2: int) -> str:
    """Envelope key for the scatter kernel: sorted-entry count + 2m.

    ``entries`` is the plan's kept entry count (~N*K minus pads)."""
    return f"e{round_up(max(entries, 1), E_BUCKETS)}_m{round_up(m2, M2_BUCKETS)}"


def backend_key(mode: str = "auto") -> str:
    """The table backend a call under ``mode`` resolves against."""
    if mode == "interpret":
        return "interpret"
    return jax.default_backend()


def _check_config(kernel: str, config: Mapping[str, int]) -> dict[str, int]:
    if kernel not in KERNEL_PARAMS:
        raise ValueError(
            f"unknown kernel {kernel!r}; expected one of {sorted(KERNEL_PARAMS)}")
    want = set(KERNEL_PARAMS[kernel])
    got = set(config)
    if got != want:
        raise ValueError(
            f"kernel {kernel!r} config must have keys {sorted(want)}, got {sorted(got)}")
    for key, val in config.items():
        if isinstance(val, bool) or not isinstance(val, int) or val < 1:
            raise ValueError(f"{kernel}.{key} must be a positive int, got {val!r}")
    return dict(config)


class AutotuneTable:
    """In-memory ``(backend, kernel, envelope) -> config`` mapping with
    JSON persistence (one file per backend)."""

    VERSION = 1

    def __init__(self):
        # backend -> kernel -> envelope -> {param: int}
        self._entries: dict[str, dict[str, dict[str, dict[str, int]]]] = {}
        self.meta: dict[str, dict] = {}  # backend -> provenance blob

    def put(self, backend: str, kernel: str, envelope: str,
            config: Mapping[str, int]) -> None:
        cfg = _check_config(kernel, config)
        self._entries.setdefault(backend, {}).setdefault(kernel, {})[envelope] = cfg

    def get(self, backend: str, kernel: str, envelope: str) -> dict[str, int] | None:
        """The stored config, or None (no silent defaulting here —
        :func:`resolve` owns the fallback chain)."""
        cfg = self._entries.get(backend, {}).get(kernel, {}).get(envelope)
        return dict(cfg) if cfg is not None else None

    def backends(self) -> tuple[str, ...]:
        return tuple(sorted(self._entries))

    def entries(self, backend: str) -> dict[str, dict[str, dict[str, int]]]:
        """``kernel -> envelope -> config`` for one backend (a copy)."""
        return {k: {e: dict(c) for e, c in envs.items()}
                for k, envs in self._entries.get(backend, {}).items()}

    # ----------------------------------------------------------- JSON I/O
    def to_json(self, backend: str) -> str:
        doc = {
            "version": self.VERSION,
            "backend": backend,
            "entries": self.entries(backend),
            "meta": self.meta.get(backend, {}),
        }
        return json.dumps(doc, indent=2, sort_keys=True) + "\n"

    def merge_json(self, text: str) -> str:
        """Merge one backend file into this table; returns the backend."""
        doc = json.loads(text)
        if doc.get("version") != self.VERSION:
            raise ValueError(f"unsupported table version {doc.get('version')!r}")
        backend = doc["backend"]
        for kernel, envs in doc.get("entries", {}).items():
            for envelope, cfg in envs.items():
                self.put(backend, kernel, envelope, cfg)
        if doc.get("meta"):
            self.meta[backend] = doc["meta"]
        return backend

    def save(self, path: str | Path, backend: str) -> None:
        Path(path).write_text(self.to_json(backend))

    @classmethod
    def load(cls, *paths: str | Path) -> "AutotuneTable":
        table = cls()
        for p in paths:
            table.merge_json(Path(p).read_text())
        return table

    @classmethod
    def load_dir(cls, directory: str | Path = TABLES_DIR) -> "AutotuneTable":
        """Load every ``*.json`` backend file under ``directory``."""
        return cls.load(*sorted(Path(directory).glob("*.json")))


# ------------------------------------------------- process-wide resolution
_active_table: AutotuneTable | None = None
_overrides: dict[str, int] = {}


def active_table() -> AutotuneTable:
    """The process-wide table, lazily loaded from the committed files
    ONCE (missing/empty dir -> empty table, builtin defaults apply)."""
    global _active_table
    if _active_table is None:
        try:
            _active_table = AutotuneTable.load_dir()
        except (OSError, ValueError):
            _active_table = AutotuneTable()
    return _active_table


def set_active_table(table: AutotuneTable | None) -> None:
    """Install a table (``--tune`` fresh sweeps, tests); None re-arms the
    lazy load of the committed files."""
    global _active_table
    _active_table = table


def set_overrides(**params: int | None) -> None:
    """Process-wide knob overrides (the launch ``--block-n/--block-k/
    --chunk`` flags): beat the table, lose to explicit call kwargs.
    ``chunk`` applies to both chunk_fwd and chunk_bwd. A value of None
    clears that override. Unknown knobs and non-positive/non-int values
    raise — never silently clamped."""
    for key, val in params.items():
        if key not in _PARAM_KERNELS:
            raise ValueError(
                f"unknown tunable {key!r}; expected one of {sorted(_PARAM_KERNELS)}")
        if val is None:
            _overrides.pop(key, None)
            continue
        if isinstance(val, bool) or not isinstance(val, int) or val < 1:
            raise ValueError(f"override {key}={val!r} must be a positive int")
        _overrides[key] = val


def clear_overrides() -> None:
    _overrides.clear()


def get_overrides() -> dict[str, int]:
    return dict(_overrides)


def resolve(kernel: str, envelope: str, *, mode: str = "auto") -> dict[str, int]:
    """The config a call site should run with — builtin defaults, beaten
    by the active table's ``(backend, kernel, envelope)`` entry, beaten
    by :func:`set_overrides`. Pure dict lookups: zero steady-state
    sweeps or I/O."""
    if kernel not in KERNEL_PARAMS:
        raise ValueError(
            f"unknown kernel {kernel!r}; expected one of {sorted(KERNEL_PARAMS)}")
    cfg = dict(BUILTIN_DEFAULTS[kernel])
    entry = active_table().get(backend_key(mode), kernel, envelope)
    if entry is not None:
        cfg.update(entry)
    for param in KERNEL_PARAMS[kernel]:
        if param in _overrides:
            cfg[param] = _overrides[param]
    return cfg
