"""Traffic shaping for the scoring engine: a micro-batching request
queue and an open-loop Poisson load generator.

The paper's models serve hundreds of millions of users; what makes that
affordable is never scoring one page view per device dispatch. The
:class:`MicroBatchQueue` sits in front of a
:class:`~repro.serve.engine.ScoringEngine` and turns an arrival stream
into the engine's batched ``G > 1`` dispatches:

  * arrivals group by their (Ku, Ka, N) envelope — only same-envelope
    requests can stack into one executable call;
  * a group FLUSHES when it reaches ``max_batch`` requests (full flush:
    best amortisation) or when its oldest request has waited
    ``max_delay_us`` (deadline flush: a tail-latency bound — batching
    may never hold a request longer than the deadline);
  * ADMISSION CONTROL: when ``max_pending`` requests are already queued
    the submit is rejected (load shedding) instead of growing an
    unbounded backlog — under overload the queue degrades to bounded
    latency + explicit drops, never to unbounded wait;
  * CROSS-ENVELOPE COALESCING (``coalesce=True``): when several small
    per-envelope groups are due at once (the low-QPS regime, where
    deadline flushes dominate and every group is tiny), they dispatch in
    ONE device round at the widest due envelope
    (``ScoringEngine.score_batch_at`` — elementwise max of the member
    envelopes, itself a bucket edge) instead of one round each. Scores
    are bitwise what per-envelope dispatch returns (widening only adds
    pad slots, which alias the zero pad row); the flush mix books these
    rounds under reason ``"coalesced"`` with the merged-group count, so
    occupancy gains from coalescing are visible, not silently folded
    into the deadline rows.

Two front-door modes: the virtual-clock methods below (replay,
benchmarks), and :class:`RealClockPump` — a small thread that sleeps to
:meth:`MicroBatchQueue.next_deadline` and calls ``flush_due(now)`` with
WALL time, so the same queue serves live traffic outside a replay loop
(deterministic shutdown: ``stop()`` joins the thread, then drains).

:func:`derive_g_buckets` closes the loop from measurement back to
deploy config: given a queue's measured flush-size mix it derives the
engine ``g_buckets`` set that covers the traffic (and warns when the
top bucket saturates — the signal to raise ``max_batch``).

Time is a caller-supplied virtual clock (monotonic seconds): the queue
never sleeps, it just orders events. A live server would feed
``time.perf_counter()``; tests and the load generator feed synthetic
arrival timestamps, which makes every flush decision deterministic and
replayable. Service times are REAL, though — each flush runs the actual
engine dispatch and the measured wall time advances the (single,
serial) server: flush start = max(trigger time, server free), and every
request in the batch completes when its dispatch finishes. A batch is
sealed at its trigger; arrivals while the server is busy join the next
one.

:func:`replay_open_loop` is the benchmark harness: OPEN-LOOP arrivals
(Poisson with rate ``qps``, drawn up front, independent of completions
— the standard way to measure tail latency without the coordinated-
omission trap of closed-loop clients) replayed through the queue,
reporting p50/p99/mean latency, candidates/sec, achieved QPS, batch
occupancy and drop counts. ``benchmarks/bench_serve.py`` turns the
report into ``BENCH_serve.json`` rows and the CI regression gate
watches them.
"""
from __future__ import annotations

import threading
import time
from typing import Mapping, NamedTuple, Sequence

import numpy as np

from repro import obs
from repro.serve.engine import (
    DEFAULT_G_BUCKETS,
    BundleRequest,
    ScoringEngine,
)


class QueueConfig(NamedTuple):
    """Micro-batching knobs (see module docstring)."""

    max_batch: int = 8  # full-flush size (kept <= engine.max_batch)
    max_delay_us: float = 2_000.0  # deadline: max queueing delay per request
    max_pending: int = 256  # admission: reject submits past this backlog
    coalesce: bool = False  # merge several due groups into one dispatch


class Completion(NamedTuple):
    """One served request: scores + the timeline that produced them."""

    ticket: int
    scores: np.ndarray  # (N_real,) p(y=1|x), request order
    arrival: float  # virtual seconds
    started: float  # flush execution start (>= arrival)
    completed: float  # started + measured dispatch wall time
    reason: str  # "full" | "deadline" | "drain" | "coalesced"

    @property
    def latency_us(self) -> float:
        return (self.completed - self.arrival) * 1e6


class QueueStats:
    """Queue counters (one labeled family per queue) — a registry view
    with the same ``accepted``/``rejected``/``flushes`` API as before."""

    _REASONS = ("full", "deadline", "drain", "coalesced")

    def __init__(self, registry=None):
        reg = registry if registry is not None else obs.get_registry()
        self._reg = reg
        self._labels = {"queue": obs.next_instance("queue")}
        labels = self._labels
        self._accepted = reg.counter("serve_queue_accepted", **labels)
        self._rejected = reg.counter("serve_queue_rejected", **labels)
        self._flushes = {r: reg.counter("serve_queue_flushes",
                                        reason=r, **labels)
                         for r in self._REASONS}
        self._delay_hist = reg.histogram("serve_queue_delay_seconds",
                                         **labels)
        self._pending = reg.gauge("serve_queue_pending", **labels)
        # merged-group count of coalesced rounds (>= 2 per such round):
        # flushes["coalesced"] rounds served this many per-envelope groups
        self._coalesced_groups = reg.counter("serve_queue_coalesced_groups",
                                             **labels)
        # exact flush-size mix {requests in round: rounds} — the input to
        # derive_g_buckets, and how occupancy per reason stays auditable
        self._sizes: dict[int, object] = {}

    def note_accept(self) -> None:
        self._accepted.inc(1.0)

    def note_pending(self, n: int) -> None:
        self._pending.set(float(n))

    def note_reject(self) -> None:
        self._rejected.inc(1.0)

    def note_flush(self, reason: str, queue_delay_s: float,
                   size: int | None = None, groups: int = 1) -> None:
        self._flushes[reason].inc(1.0)
        self._delay_hist.observe(queue_delay_s)
        if groups > 1:
            self._coalesced_groups.inc(float(groups))
        if size is not None:
            counter = self._sizes.get(size)
            if counter is None:
                counter = self._reg.counter("serve_queue_flush_size",
                                            size=str(size), **self._labels)
                self._sizes[size] = counter
            counter.inc(1.0)

    @property
    def accepted(self) -> int:
        return int(self._accepted.value)

    @property
    def rejected(self) -> int:
        return int(self._rejected.value)

    @property
    def flushes(self) -> dict[str, int]:
        return {r: int(c.value) for r, c in self._flushes.items()}

    @property
    def coalesced_groups(self) -> int:
        return int(self._coalesced_groups.value)

    @property
    def flush_sizes(self) -> dict[int, int]:
        """Measured flush-size mix {batch size: flush count}."""
        return {s: int(c.value) for s, c in sorted(self._sizes.items())}

    def as_dict(self) -> dict:
        return {"accepted": self.accepted, "rejected": self.rejected,
                "flushes": dict(self.flushes),
                "coalesced_groups": self.coalesced_groups,
                "flush_sizes": dict(self.flush_sizes)}


class MicroBatchQueue:
    """Deadline-aware micro-batching front of a :class:`ScoringEngine`.

    Single-threaded and virtual-clocked: callers push time forward via
    the ``now`` arguments (monotonic seconds, non-decreasing). Completed
    work accumulates in :attr:`completions` (also returned by the call
    that produced it).
    """

    def __init__(self, engine: ScoringEngine,
                 config: QueueConfig = QueueConfig()):
        if config.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {config.max_batch}")
        self.engine = engine
        self.config = config
        self.stats = QueueStats()
        self.completions: list[Completion] = []
        self._pending: dict[tuple[int, int, int],
                            list[tuple[int, BundleRequest, float]]] = {}
        self._next_ticket = 0
        self._busy_until = 0.0  # virtual time the serial server frees up

    @property
    def pending(self) -> int:
        return sum(len(v) for v in self._pending.values())

    def next_deadline(self) -> float | None:
        """Virtual time the oldest queued request must flush by."""
        oldest = [entries[0][2] for entries in self._pending.values() if entries]
        if not oldest:
            return None
        return min(oldest) + self.config.max_delay_us * 1e-6

    # ------------------------------------------------------------- events
    def submit(self, request: BundleRequest, now: float) -> int | None:
        """Enqueue one request at virtual time ``now``. Returns its
        ticket, or None when admission control sheds it. A group hitting
        ``max_batch`` flushes immediately (trigger time = ``now``)."""
        if self.pending >= self.config.max_pending:
            self.stats.note_reject()
            return None
        ticket = self._next_ticket
        self._next_ticket += 1
        env = self.engine.envelope(request)
        group = self._pending.setdefault(env, [])
        group.append((ticket, request, now))
        self.stats.note_accept()
        self.stats.note_pending(self.pending)
        if len(group) >= self.config.max_batch:
            self._flush(env, now, "full")
        return ticket

    def flush_due(self, now: float) -> list[Completion]:
        """Flush every group whose deadline has passed by ``now``
        (oldest-deadline first). With ``coalesce=True``, due groups merge
        into one dispatch at the widest due envelope while their combined
        size fits ``max_batch`` (bitwise-identical scores — see module
        docstring). Returns the completions produced."""
        delay_s = self.config.max_delay_us * 1e-6
        done: list[Completion] = []
        while True:
            due = sorted((entries[0][2], env)
                         for env, entries in self._pending.items() if entries)
            due = [(arr, env) for arr, env in due if arr + delay_s <= now]
            if not due:
                break
            if self.config.coalesce and len(due) >= 2:
                take: list[tuple[int, int, int]] = []
                total = 0
                for arr, env in due:
                    size = len(self._pending[env])
                    if take and total + size > self.config.max_batch:
                        break
                    take.append(env)
                    total += size
                if len(take) >= 2:
                    done += self._flush_coalesced(take, due[0][0] + delay_s)
                    continue
            oldest, env = due[0]
            done += self._flush(env, oldest + delay_s, "deadline")
        return done

    def drain(self, now: float) -> list[Completion]:
        """Flush everything still queued (shutdown / end of replay)."""
        done: list[Completion] = []
        for env in sorted(self._pending, key=lambda e: self._pending[e][0][2]):
            done += self._flush(env, now, "drain")
        return done

    # ------------------------------------------------------------ internals
    def _flush(self, env: tuple[int, int, int], trigger: float,
               reason: str) -> list[Completion]:
        entries = self._pending.pop(env)
        started = max(trigger, self._busy_until)
        # virtual queueing delay of the OLDEST request in the batch —
        # the figure the deadline bounds
        queue_delay_s = max(0.0, started - entries[0][2])
        self.stats.note_flush(reason, queue_delay_s, size=len(entries))
        self.stats.note_pending(self.pending)
        before = self.engine.stats.score_seconds
        with self.engine.dispatch_context(reason, queue_delay_s * 1e6):
            scores = self.engine.score_batch([r for _, r, _ in entries])
        wall = self.engine.stats.score_seconds - before
        completed = started + wall
        self._busy_until = completed
        out = [Completion(ticket=t, scores=p, arrival=arr, started=started,
                          completed=completed, reason=reason)
               for (t, _, arr), p in zip(entries, scores)]
        self.completions += out
        return out

    def _flush_coalesced(self, envs: Sequence[tuple[int, int, int]],
                         trigger: float) -> list[Completion]:
        """One device round for several due groups: requests merge in
        ticket (= arrival) order and dispatch at the elementwise-max
        envelope of the members, then completions slice back per ticket.
        Widening only adds pad slots (zero pad row), so the scores are
        bitwise what per-envelope dispatch would return."""
        widest = tuple(max(e[i] for e in envs) for i in range(3))
        entries = sorted((t for env in envs for t in self._pending.pop(env)),
                         key=lambda e: e[0])
        started = max(trigger, self._busy_until)
        queue_delay_s = max(0.0, started - min(arr for _, _, arr in entries))
        self.stats.note_flush("coalesced", queue_delay_s,
                              size=len(entries), groups=len(envs))
        self.stats.note_pending(self.pending)
        before = self.engine.stats.score_seconds
        with self.engine.dispatch_context("coalesced", queue_delay_s * 1e6):
            scores = self.engine.score_batch_at(
                [r for _, r, _ in entries], widest)
        wall = self.engine.stats.score_seconds - before
        completed = started + wall
        self._busy_until = completed
        out = [Completion(ticket=t, scores=p, arrival=arr, started=started,
                          completed=completed, reason="coalesced")
               for (t, _, arr), p in zip(entries, scores)]
        self.completions += out
        return out


def poisson_arrivals(num: int, qps: float, seed: int = 0) -> np.ndarray:
    """Cumulative arrival times (seconds) of a rate-``qps`` Poisson
    process: iid exponential gaps, mean 1/qps."""
    if qps <= 0:
        raise ValueError(f"qps must be positive, got {qps}")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / qps, size=num))


def replay_open_loop(engine: ScoringEngine,
                     requests: Sequence[BundleRequest], *, qps: float,
                     config: QueueConfig = QueueConfig(),
                     seed: int = 0) -> dict:
    """Open-loop load test: replay ``requests`` with Poisson arrivals at
    offered rate ``qps`` through a fresh :class:`MicroBatchQueue`,
    returning the latency/throughput report (see module docstring).

    Warm the engine's envelopes first (``engine.warm(...,
    batch_sizes=engine.g_buckets)``) when measuring steady state —
    compile time books separately but would serialise early flushes.
    """
    queue = MicroBatchQueue(engine, config)
    arrivals = poisson_arrivals(len(requests), qps, seed)
    before = engine.stats.as_dict()
    for t, req in zip(arrivals, requests):
        queue.flush_due(t)
        queue.submit(req, t)
    queue.flush_due(arrivals[-1])
    queue.drain(arrivals[-1])
    comps = queue.completions
    lat = np.array([c.latency_us for c in comps]) if comps else np.zeros(1)
    makespan = (max(c.completed for c in comps) - arrivals[0]) if comps else 0.0
    served_candidates = sum(c.scores.shape[0] for c in comps)
    after = engine.stats.as_dict()
    dispatches = after["dispatches"] - before["dispatches"]
    slots = after["slots"] - before["slots"]
    return {
        "offered_qps": qps,
        "requests": len(requests),
        "served": len(comps),
        "rejected": queue.stats.rejected,
        "achieved_qps": float(len(comps) / makespan) if makespan else 0.0,
        "candidates_per_sec":
            float(served_candidates / makespan) if makespan else 0.0,
        "latency_p50_us": float(np.percentile(lat, 50)),
        "latency_p99_us": float(np.percentile(lat, 99)),
        "latency_mean_us": float(lat.mean()),
        "dispatches": dispatches,
        "occupancy": len(comps) / slots if slots else 0.0,
        "flushes": dict(queue.stats.flushes),
        "coalesced_groups": queue.stats.coalesced_groups,
        "flush_sizes": dict(queue.stats.flush_sizes),
        "max_batch": config.max_batch,
        "max_delay_us": config.max_delay_us,
        "max_pending": config.max_pending,
        "coalesce": config.coalesce,
    }


class RealClockPump:
    """Wall-clock front door for a :class:`MicroBatchQueue` (satellite of
    the virtual-clock design): a background thread sleeps until
    :meth:`MicroBatchQueue.next_deadline` and calls ``flush_due(now)``
    with real time, so deadline flushes fire on schedule without any
    caller-driven replay loop. ``submit()`` stamps arrivals with the same
    clock (and still triggers full flushes inline, on the caller's
    thread — the pump only owns deadlines).

    All queue access is serialised under one lock, so the queue itself
    stays single-threaded. Shutdown is DETERMINISTIC: ``stop()`` wakes
    the thread, joins it, then drains the queue — after it returns every
    accepted request has a completion and no timer is live.

    ``clock`` is injectable (default ``time.perf_counter``) so tests can
    drive the pump on a synthetic clock.
    """

    def __init__(self, queue: MicroBatchQueue, *, clock=time.perf_counter):
        self.queue = queue
        self.clock = clock
        self._cond = threading.Condition()
        self._stop = False
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "RealClockPump":
        if self._thread is not None:
            raise RuntimeError("pump already started")
        self._stop = False
        self._thread = threading.Thread(target=self._run,
                                        name="repro-serve-pump", daemon=True)
        self._thread.start()
        return self

    def stop(self, *, drain: bool = True) -> list[Completion]:
        """Stop the timer thread (join), then drain. Idempotent."""
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        with self._cond:
            return self.queue.drain(self.clock()) if drain else []

    def __enter__(self) -> "RealClockPump":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -------------------------------------------------------------- serving
    def submit(self, request: BundleRequest) -> int | None:
        """Enqueue at wall time; returns the ticket (None if shed)."""
        with self._cond:
            ticket = self.queue.submit(request, self.clock())
            self._cond.notify_all()  # re-arm the timer for the new deadline
            return ticket

    def completions(self) -> list[Completion]:
        with self._cond:
            return list(self.queue.completions)

    def _run(self) -> None:
        with self._cond:
            while not self._stop:
                deadline = self.queue.next_deadline()
                if deadline is None:
                    self._cond.wait()  # nothing queued: sleep until submit
                    continue
                wait_s = deadline - self.clock()
                if wait_s > 0:
                    self._cond.wait(timeout=wait_s)
                    continue  # re-check: stop flag / newer deadline
                self.queue.flush_due(self.clock())


def derive_g_buckets(stats, *, max_buckets: int = 6,
                     saturation_frac: float = 0.5) -> tuple[int, ...]:
    """Queue-aware ``g_buckets`` autoscaling: derive the engine bucket
    set from a measured flush-size mix.

    ``stats`` is a :class:`QueueStats` (its :attr:`~QueueStats.flush_sizes`)
    or a plain ``{flush size: count}`` mapping. Each observed size rounds
    up to the next power of two (matching the engine's bucket rounding);
    the bucket set is {1} plus the most-frequent rounded sizes, capped at
    ``max_buckets`` (the top edge is always kept — every observed flush
    must fit). With no observations the builtin default is returned.

    When at least ``saturation_frac`` of flushes land on the TOP bucket,
    an ``obs.log`` warning fires: traffic is pinned at the batch ceiling,
    so raising the queue's ``max_batch`` (then re-deriving) would batch
    deeper instead of splitting rounds.
    """
    if isinstance(stats, QueueStats):
        stats = stats.flush_sizes
    if not isinstance(stats, Mapping):
        raise TypeError(f"expected QueueStats or a mapping, got {type(stats)}")
    weight: dict[int, int] = {}
    for size, count in stats.items():
        size, count = int(size), int(count)
        if size < 1 or count < 1:
            continue
        edge = 1 << (size - 1).bit_length()  # next power of two >= size
        weight[edge] = weight.get(edge, 0) + count
    if not weight:
        return DEFAULT_G_BUCKETS
    top = max(weight)
    edges = {1, top}
    for edge in sorted(weight, key=lambda e: weight[e], reverse=True):
        if len(edges) >= max_buckets:
            break
        edges.add(edge)
    total = sum(weight.values())
    if weight[top] / total >= saturation_frac and top > 1:
        obs.log(f"derive_g_buckets: {weight[top]}/{total} flushes saturate "
                f"the top G bucket ({top}); raise the queue's max_batch and "
                "re-derive to batch deeper", level="warn")
    return tuple(sorted(edges))
