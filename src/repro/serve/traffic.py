"""Traffic shaping for the scoring engine: a micro-batching request
queue and an open-loop Poisson load generator.

The paper's models serve hundreds of millions of users; what makes that
affordable is never scoring one page view per device dispatch. The
:class:`MicroBatchQueue` sits in front of a
:class:`~repro.serve.engine.ScoringEngine` and turns an arrival stream
into the engine's batched ``G > 1`` dispatches:

  * arrivals group by their (Ku, Ka, N) envelope — only same-envelope
    requests can stack into one executable call;
  * a group FLUSHES when it reaches ``max_batch`` requests (full flush:
    best amortisation) or when its oldest request has waited
    ``max_delay_us`` (deadline flush: a tail-latency bound — batching
    may never hold a request longer than the deadline);
  * ADMISSION CONTROL: when ``max_pending`` requests are already queued
    the submit is rejected (load shedding) instead of growing an
    unbounded backlog — under overload the queue degrades to bounded
    latency + explicit drops, never to unbounded wait.

Time is a caller-supplied virtual clock (monotonic seconds): the queue
never sleeps, it just orders events. A live server would feed
``time.perf_counter()``; tests and the load generator feed synthetic
arrival timestamps, which makes every flush decision deterministic and
replayable. Service times are REAL, though — each flush runs the actual
engine dispatch and the measured wall time advances the (single,
serial) server: flush start = max(trigger time, server free), and every
request in the batch completes when its dispatch finishes. A batch is
sealed at its trigger; arrivals while the server is busy join the next
one.

:func:`replay_open_loop` is the benchmark harness: OPEN-LOOP arrivals
(Poisson with rate ``qps``, drawn up front, independent of completions
— the standard way to measure tail latency without the coordinated-
omission trap of closed-loop clients) replayed through the queue,
reporting p50/p99/mean latency, candidates/sec, achieved QPS, batch
occupancy and drop counts. ``benchmarks/bench_serve.py`` turns the
report into ``BENCH_serve.json`` rows and the CI regression gate
watches them.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np

from repro import obs
from repro.serve.engine import BundleRequest, ScoringEngine


class QueueConfig(NamedTuple):
    """Micro-batching knobs (see module docstring)."""

    max_batch: int = 8  # full-flush size (kept <= engine.max_batch)
    max_delay_us: float = 2_000.0  # deadline: max queueing delay per request
    max_pending: int = 256  # admission: reject submits past this backlog


class Completion(NamedTuple):
    """One served request: scores + the timeline that produced them."""

    ticket: int
    scores: np.ndarray  # (N_real,) p(y=1|x), request order
    arrival: float  # virtual seconds
    started: float  # flush execution start (>= arrival)
    completed: float  # started + measured dispatch wall time
    reason: str  # "full" | "deadline" | "drain"

    @property
    def latency_us(self) -> float:
        return (self.completed - self.arrival) * 1e6


class QueueStats:
    """Queue counters (one labeled family per queue) — a registry view
    with the same ``accepted``/``rejected``/``flushes`` API as before."""

    _REASONS = ("full", "deadline", "drain")

    def __init__(self, registry=None):
        reg = registry if registry is not None else obs.get_registry()
        labels = {"queue": obs.next_instance("queue")}
        self._accepted = reg.counter("serve_queue_accepted", **labels)
        self._rejected = reg.counter("serve_queue_rejected", **labels)
        self._flushes = {r: reg.counter("serve_queue_flushes",
                                        reason=r, **labels)
                         for r in self._REASONS}
        self._delay_hist = reg.histogram("serve_queue_delay_seconds",
                                         **labels)
        self._pending = reg.gauge("serve_queue_pending", **labels)

    def note_accept(self) -> None:
        self._accepted.inc(1.0)

    def note_pending(self, n: int) -> None:
        self._pending.set(float(n))

    def note_reject(self) -> None:
        self._rejected.inc(1.0)

    def note_flush(self, reason: str, queue_delay_s: float) -> None:
        self._flushes[reason].inc(1.0)
        self._delay_hist.observe(queue_delay_s)

    @property
    def accepted(self) -> int:
        return int(self._accepted.value)

    @property
    def rejected(self) -> int:
        return int(self._rejected.value)

    @property
    def flushes(self) -> dict[str, int]:
        return {r: int(c.value) for r, c in self._flushes.items()}

    def as_dict(self) -> dict:
        return {"accepted": self.accepted, "rejected": self.rejected,
                "flushes": dict(self.flushes)}


class MicroBatchQueue:
    """Deadline-aware micro-batching front of a :class:`ScoringEngine`.

    Single-threaded and virtual-clocked: callers push time forward via
    the ``now`` arguments (monotonic seconds, non-decreasing). Completed
    work accumulates in :attr:`completions` (also returned by the call
    that produced it).
    """

    def __init__(self, engine: ScoringEngine,
                 config: QueueConfig = QueueConfig()):
        if config.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {config.max_batch}")
        self.engine = engine
        self.config = config
        self.stats = QueueStats()
        self.completions: list[Completion] = []
        self._pending: dict[tuple[int, int, int],
                            list[tuple[int, BundleRequest, float]]] = {}
        self._next_ticket = 0
        self._busy_until = 0.0  # virtual time the serial server frees up

    @property
    def pending(self) -> int:
        return sum(len(v) for v in self._pending.values())

    def next_deadline(self) -> float | None:
        """Virtual time the oldest queued request must flush by."""
        oldest = [entries[0][2] for entries in self._pending.values() if entries]
        if not oldest:
            return None
        return min(oldest) + self.config.max_delay_us * 1e-6

    # ------------------------------------------------------------- events
    def submit(self, request: BundleRequest, now: float) -> int | None:
        """Enqueue one request at virtual time ``now``. Returns its
        ticket, or None when admission control sheds it. A group hitting
        ``max_batch`` flushes immediately (trigger time = ``now``)."""
        if self.pending >= self.config.max_pending:
            self.stats.note_reject()
            return None
        ticket = self._next_ticket
        self._next_ticket += 1
        env = self.engine.envelope(request)
        group = self._pending.setdefault(env, [])
        group.append((ticket, request, now))
        self.stats.note_accept()
        self.stats.note_pending(self.pending)
        if len(group) >= self.config.max_batch:
            self._flush(env, now, "full")
        return ticket

    def flush_due(self, now: float) -> list[Completion]:
        """Flush every group whose deadline has passed by ``now``
        (oldest-deadline first). Returns the completions produced."""
        done: list[Completion] = []
        while True:
            due = [(entries[0][2], env)
                   for env, entries in self._pending.items() if entries]
            if not due:
                break
            oldest, env = min(due)
            deadline = oldest + self.config.max_delay_us * 1e-6
            if deadline > now:
                break
            done += self._flush(env, deadline, "deadline")
        return done

    def drain(self, now: float) -> list[Completion]:
        """Flush everything still queued (shutdown / end of replay)."""
        done: list[Completion] = []
        for env in sorted(self._pending, key=lambda e: self._pending[e][0][2]):
            done += self._flush(env, now, "drain")
        return done

    # ------------------------------------------------------------ internals
    def _flush(self, env: tuple[int, int, int], trigger: float,
               reason: str) -> list[Completion]:
        entries = self._pending.pop(env)
        started = max(trigger, self._busy_until)
        # virtual queueing delay of the OLDEST request in the batch —
        # the figure the deadline bounds
        queue_delay_s = max(0.0, started - entries[0][2])
        self.stats.note_flush(reason, queue_delay_s)
        self.stats.note_pending(self.pending)
        before = self.engine.stats.score_seconds
        with self.engine.dispatch_context(reason, queue_delay_s * 1e6):
            scores = self.engine.score_batch([r for _, r, _ in entries])
        wall = self.engine.stats.score_seconds - before
        completed = started + wall
        self._busy_until = completed
        out = [Completion(ticket=t, scores=p, arrival=arr, started=started,
                          completed=completed, reason=reason)
               for (t, _, arr), p in zip(entries, scores)]
        self.completions += out
        return out


def poisson_arrivals(num: int, qps: float, seed: int = 0) -> np.ndarray:
    """Cumulative arrival times (seconds) of a rate-``qps`` Poisson
    process: iid exponential gaps, mean 1/qps."""
    if qps <= 0:
        raise ValueError(f"qps must be positive, got {qps}")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / qps, size=num))


def replay_open_loop(engine: ScoringEngine,
                     requests: Sequence[BundleRequest], *, qps: float,
                     config: QueueConfig = QueueConfig(),
                     seed: int = 0) -> dict:
    """Open-loop load test: replay ``requests`` with Poisson arrivals at
    offered rate ``qps`` through a fresh :class:`MicroBatchQueue`,
    returning the latency/throughput report (see module docstring).

    Warm the engine's envelopes first (``engine.warm(...,
    batch_sizes=engine.g_buckets)``) when measuring steady state —
    compile time books separately but would serialise early flushes.
    """
    queue = MicroBatchQueue(engine, config)
    arrivals = poisson_arrivals(len(requests), qps, seed)
    before = engine.stats.as_dict()
    for t, req in zip(arrivals, requests):
        queue.flush_due(t)
        queue.submit(req, t)
    queue.flush_due(arrivals[-1])
    queue.drain(arrivals[-1])
    comps = queue.completions
    lat = np.array([c.latency_us for c in comps]) if comps else np.zeros(1)
    makespan = (max(c.completed for c in comps) - arrivals[0]) if comps else 0.0
    served_candidates = sum(c.scores.shape[0] for c in comps)
    after = engine.stats.as_dict()
    dispatches = after["dispatches"] - before["dispatches"]
    slots = after["slots"] - before["slots"]
    return {
        "offered_qps": qps,
        "requests": len(requests),
        "served": len(comps),
        "rejected": queue.stats.rejected,
        "achieved_qps": float(len(comps) / makespan) if makespan else 0.0,
        "candidates_per_sec":
            float(served_candidates / makespan) if makespan else 0.0,
        "latency_p50_us": float(np.percentile(lat, 50)),
        "latency_p99_us": float(np.percentile(lat, 99)),
        "latency_mean_us": float(lat.mean()),
        "dispatches": dispatches,
        "occupancy": len(comps) / slots if slots else 0.0,
        "flushes": dict(queue.stats.flushes),
        "max_batch": config.max_batch,
        "max_delay_us": config.max_delay_us,
        "max_pending": config.max_pending,
    }
