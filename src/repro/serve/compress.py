"""Pruned serving artifacts — the deployable form of a trained Theta.

The L1/L2,1 regularisers (Eq. 4) drive entire FEATURE ROWS of Theta to
exact zero (a feature row is the L2,1 group), and the paper's production
win is that the DEPLOYED model only ships the surviving rows (§4, Table
2: ~2% nonzero). :func:`compress` packs a trained (d, 2m) Theta into a
:class:`ServingArtifact`:

  * ``theta``      (R+1, 2m) — the R surviving rows, contiguous, plus the
                   trailing zero pad row the sparse kernels require
                   (compact pad id == R);
  * ``remap``      (d+1,) int32 — old feature id -> compact row. Dropped
                   ids AND the old pad id (== d) map to the pad row R, so
                   a request in the ORIGINAL id space is served by one
                   gather: ``compact_ids = remap[ids]``;
  * ``alive_ids``  (R,) int32 — the original ids of the packed rows (the
                   inverse of ``remap`` on the alive set; dense scoring
                   gathers x's columns with it).

Scoring a pruned artifact is BIT-IDENTICAL to scoring the full Theta on
the sparse paths: the gathered rows are the same numbers (alive rows are
copied verbatim; dropped ids land on the zero pad row exactly as their
all-zero row did before), and the contraction shapes/order per sample do
not change. The dense path contracts over R columns instead of d, which
reassociates the reduction — parity there is <= 1e-6, not bitwise (see
``serve.score.score_dense``).

On top of pruning, :func:`quantize` packs the surviving rows into a
:class:`QuantizedArtifact` — int8 codes plus one fp32 scale per row
(``row ≈ codes * scale``), behind the SAME remap — for another ~4x off
the deployed size. Quantisation is lossy but bounded: each Theta entry
moves by at most ``max|row| / 254`` (half an int8 step), and the induced
probability error is gated at ``max |Δp| <= 1e-2`` vs fp32 in
``tests/test_serve_compress.py`` and ``benchmarks/bench_serve.py``.
:func:`dequantize` rebuilds a :class:`ServingArtifact`, so every scorer
(flat, bundles, engine) serves an int8 deploy unchanged.

Artifacts save/load through ``repro.io.checkpoint`` (flat npz); the
field names make them self-describing, so :func:`load_artifact` needs no
``like`` tree (``checkpoint.load_nested``) and auto-detects which of the
two artifact forms the file holds.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.io import checkpoint


class ServingArtifact(NamedTuple):
    """A pruned, serving-ready LS-PLM model (see module docstring)."""

    theta: jax.Array  # (R+1, 2m) packed alive rows + zero pad row
    remap: jax.Array  # (d+1,) int32 old id -> compact row (dropped -> R)
    alive_ids: jax.Array  # (R,) int32 original ids of the packed rows
    num_features: int  # d of the full model (static)

    @property
    def num_alive(self) -> int:
        """R — surviving feature rows (the deployed model's size)."""
        return self.theta.shape[0] - 1

    @property
    def num_regions(self) -> int:
        return self.theta.shape[1] // 2

    @property
    def pad_id(self) -> int:
        """The compact pad id (== R); ``remap`` already targets it."""
        return self.theta.shape[0] - 1

    @property
    def compression(self) -> float:
        """Deployed/full row ratio (1.0 = nothing pruned)."""
        return self.num_alive / max(self.num_features, 1)


def compress(theta: jax.Array, *, threshold: float = 0.0) -> ServingArtifact:
    """Pack a trained UNPADDED Theta (d, 2m) into a pruned artifact.

    A row survives when ``max(|row|) > threshold``; the default 0.0 drops
    exactly the rows OWLQN+'s orthant projection zeroed (the L2,1 win) and
    nothing else, which is what keeps pruned scoring bit-identical.
    ``threshold > 0`` additionally drops near-zero rows — lossy, for
    size-quality tradeoffs; parity gates then no longer apply.
    """
    th = np.asarray(jax.device_get(theta))
    if th.ndim != 2 or th.shape[1] % 2:
        raise ValueError(f"expected an unpadded (d, 2m) Theta, got {th.shape}")
    d = th.shape[0]
    alive = np.abs(th).max(axis=1) > threshold
    alive_ids = np.flatnonzero(alive).astype(np.int32)
    r = alive_ids.size
    remap = np.full(d + 1, r, np.int32)  # dropped ids AND old pad id -> pad row
    remap[alive_ids] = np.arange(r, dtype=np.int32)
    packed = np.concatenate([th[alive_ids], np.zeros((1, th.shape[1]), th.dtype)])
    return ServingArtifact(
        theta=jnp.asarray(packed),
        remap=jnp.asarray(remap),
        alive_ids=jnp.asarray(alive_ids),
        num_features=d,
    )


class QuantizedArtifact(NamedTuple):
    """An int8-quantised pruned model: ~4x smaller than the fp32
    artifact on the wire (int8 codes + one fp32 scale per row), same
    remap/alive_ids, bounded-error scoring (see module docstring)."""

    codes: jax.Array  # (R+1, 2m) int8 — row i fp32 ≈ codes[i] * scales[i]
    scales: jax.Array  # (R+1,) fp32 per-row scale; pad row scale == 0
    remap: jax.Array  # (d+1,) int32 old id -> compact row (dropped -> R)
    alive_ids: jax.Array  # (R,) int32 original ids of the packed rows
    num_features: int  # d of the full model (static)

    @property
    def num_alive(self) -> int:
        return self.codes.shape[0] - 1

    @property
    def num_regions(self) -> int:
        return self.codes.shape[1] // 2

    @property
    def deployed_bytes(self) -> int:
        """Wire size of the model payload (codes + scales + remap +
        alive_ids), the number the ~4x claim is about."""
        return (self.codes.size * 1 + self.scales.size * 4
                + self.remap.size * 4 + self.alive_ids.size * 4)


def quantize(artifact: ServingArtifact) -> QuantizedArtifact:
    """Symmetric per-row int8 quantisation of a pruned artifact.

    ``scale = max|row| / 127`` and ``codes = round(row / scale)``, so
    every entry is off by at most scale/2 == max|row|/254. All-zero rows
    (there is exactly one — the pad row; alive rows have a nonzero by
    construction of :func:`compress`) get scale 0 and stay EXACTLY zero
    through the round trip, which keeps dropped-id/pad behaviour
    identical to fp32.
    """
    th = np.asarray(jax.device_get(artifact.theta))
    amax = np.abs(th).max(axis=1)
    scales = (amax / 127.0).astype(np.float32)
    safe = np.where(scales > 0, scales, 1.0)  # avoid 0/0 on the pad row
    codes = np.rint(th / safe[:, None]).astype(np.int8)
    return QuantizedArtifact(
        codes=jnp.asarray(codes),
        scales=jnp.asarray(scales),
        remap=artifact.remap,
        alive_ids=artifact.alive_ids,
        num_features=artifact.num_features,
    )


def dequantize(quant: QuantizedArtifact) -> ServingArtifact:
    """Rebuild a serving-ready fp32 artifact from int8 codes. This is
    how an int8 deploy is scored: one multiply at load time, every
    downstream path (flat/bundles/engine) unchanged."""
    theta = quant.codes.astype(jnp.float32) * quant.scales[:, None]
    return ServingArtifact(theta=theta, remap=quant.remap,
                           alive_ids=quant.alive_ids,
                           num_features=quant.num_features)


def save_artifact(path: str, artifact: ServingArtifact | QuantizedArtifact,
                  *, drift_ref=None) -> str:
    """Write either artifact form as a flat npz via
    ``repro.io.checkpoint`` (npz keeps the int8/fp32 dtypes, so a
    quantised save really is ~4x smaller). Returns the real path
    written (``.npz`` appended when missing).

    ``drift_ref`` (a :class:`repro.obs.drift.DriftReference`) embeds the
    training-time drift-reference snapshot under ``drift_ref/*`` keys in
    the same file, so one deploy artifact also arms the serving health
    monitor (``repro.obs.load_drift_reference`` reads it back from the
    artifact path). :func:`load_artifact` picks only the artifact's own
    fields, so an embedded reference never changes what gets served."""
    if drift_ref is None:
        return checkpoint.save(path, artifact)
    tree = {f: getattr(artifact, f) for f in artifact._fields}
    tree["drift_ref"] = drift_ref
    return checkpoint.save(path, tree)


def load_artifact(path: str) -> ServingArtifact | QuantizedArtifact:
    """Load an artifact saved by :func:`save_artifact`. Self-describing:
    the npz field names rebuild the structure (and pick which of the two
    artifact forms the file holds), no ``like`` tree needed."""
    data = checkpoint.load_nested(path)
    cls = QuantizedArtifact if "codes" in data else ServingArtifact
    missing = [f for f in cls._fields if f not in data]
    if missing:
        raise ValueError(
            f"{path!r} is not a serving artifact: missing fields {missing}")
    arrays = {f: jnp.asarray(data[f]) for f in cls._fields
              if f != "num_features"}
    return cls(num_features=int(np.asarray(data["num_features"]).item()),
               **arrays)
