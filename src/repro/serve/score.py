"""The ONE inference layer: every LS-PLM prediction goes through here.

Training-eval (``repro.data.sparse.sparse_predict``), the core model
predictors (``repro.core.lsplm.predict_proba_sparse``), the examples and
the batched :class:`~repro.serve.engine.ScoringEngine` all call these
functions — the Eq. 2 softmax-dot-sigmoid head lives in exactly one
place (``repro.kernels.lsplm_sparse_fused.ops.finalize_p``) and the
model argument is polymorphic:

  * a raw UNPADDED Theta ``(d, 2m)`` array,
  * ``repro.core.lsplm.LSPLMParams``,
  * a pruned :class:`~repro.serve.compress.ServingArtifact`,
  * an int8 :class:`~repro.serve.compress.QuantizedArtifact` — served
    INT8-NATIVE: the codes/scales are kept as-is and the sparse paths
    run the int8 gather ops (``lsplm_sparse_forward_int8`` /
    ``sparse_gather_matmul_int8``), which DMA int8 code rows and apply
    the per-row fp32 scale in the gather epilogue — fp32 rows are never
    materialised, the row gather moves ~4x fewer bytes, and the scores
    are the dequantise-then-score numbers exactly (same fp32 row values
    enter the same contraction; bounded-error vs the unquantised fp32
    model, see ``serve.compress``). The one exception is the DENSE path,
    which has no gather to fuse into: it dequantises on the fly (a
    (R, 2m) multiply per call — fine off the hot path, wasteful on it).

Request formats:

  * :func:`score_dense`    — dense ``x (..., d)`` rows;
  * :func:`score_sparse`   — flat padded-COO ``(ids, vals)`` rows, the
    production wire format, on the fused sparse kernel;
  * :func:`score_bundles`  — SESSION-SHARED sparse scoring (the serving
    side of Eq. 13, §3.2): each page view is one user id list + N ad
    candidates; the user half of Theta^T x is gathered and contracted
    ONCE per bundle and broadcast over its candidates. Versus the naive
    per-ad path (:func:`score_bundles_naive` — user ids concatenated
    into every candidate's id list) this removes the (N-1)/N redundant
    user gathers, which is where bundle throughput comes from
    (``benchmarks/bench_serve.py``).

Artifact requests stay in the ORIGINAL id space: ids are remapped to
compact rows by one gather through ``artifact.remap`` before hitting the
kernel, so pruned scoring is bit-identical on the sparse paths (same
gathered row values, same per-sample contraction shapes). The DENSE path
on an artifact contracts over the R alive columns instead of all d —
a shorter, reassociated reduction — so parity there is <= 1e-6, not
bitwise (documented acceptance carve-out).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.lsplm import LSPLMParams
from repro.kernels.lsplm_sparse_fused.ops import (
    finalize_p,
    logps_from_z,
    lsplm_sparse_forward,
    lsplm_sparse_forward_int8,
    pad_theta,
    sparse_gather_matmul,
    sparse_gather_matmul_int8,
)
from repro.serve.compress import QuantizedArtifact, ServingArtifact


class ScoreBundle(NamedTuple):
    """A batch of page-view bundles: G user rows, B = sum of candidates.

    Ids address the ORIGINAL feature space (pad id == d) regardless of
    whether the model is pruned — remapping is the scorer's job.
    """

    user_ids: jax.Array  # (G, Ku) int32
    user_vals: jax.Array  # (G, Ku)
    ad_ids: jax.Array  # (B, Ka) int32
    ad_vals: jax.Array  # (B, Ka)
    session_id: jax.Array  # (B,) int32 in [0, G)


class ServingModel(NamedTuple):
    """Normalised model: kernel-ready rows + optional id remap.

    Exactly one of ``theta`` (fp32 models) or ``codes``/``scales``
    (int8-native models) is set; :attr:`is_int8` is the dispatch bit the
    scoring paths branch on."""

    theta: jax.Array | None  # (D, 2m) with the trailing zero pad row
    remap: jax.Array | None  # (d+1,) int32, None for full models
    alive_ids: jax.Array | None  # (R,) int32, None for full models
    num_features: int  # original d
    codes: jax.Array | None = None  # (D, 2m) int8, int8-native models only
    scales: jax.Array | None = None  # (D,) fp32 row scales (pad row == 0)

    @property
    def is_int8(self) -> bool:
        return self.codes is not None

    def dense_theta(self) -> jax.Array:
        """The padded fp32 row matrix — int8 models dequantise ON THE
        FLY here (the dense path's documented carve-out; the sparse
        paths never call this)."""
        if self.codes is not None:
            return self.codes.astype(jnp.float32) * self.scales[:, None]
        return self.theta


def as_model(model) -> ServingModel:
    """Coerce any accepted model form (see module docstring); idempotent."""
    if isinstance(model, ServingModel):
        return model
    if isinstance(model, QuantizedArtifact):
        # int8-native: keep the codes/scales — the sparse scorers fuse
        # the scale into the gather instead of rebuilding fp32 rows
        return ServingModel(theta=None, remap=model.remap,
                            alive_ids=model.alive_ids,
                            num_features=model.num_features,
                            codes=model.codes, scales=model.scales)
    if isinstance(model, ServingArtifact):
        return ServingModel(theta=model.theta, remap=model.remap,
                            alive_ids=model.alive_ids,
                            num_features=model.num_features)
    if isinstance(model, LSPLMParams):
        model = model.theta
    theta = jnp.asarray(model)
    if theta.ndim != 2 or theta.shape[1] % 2:
        raise ValueError(f"expected an unpadded (d, 2m) Theta, got {theta.shape}")
    return ServingModel(theta=pad_theta(theta), remap=None, alive_ids=None,
                        num_features=theta.shape[0])


def _request_ids(model: ServingModel, ids: jax.Array) -> jax.Array:
    """Original-space ids -> kernel ids (compact for pruned models)."""
    if model.remap is None:
        return ids
    return jnp.take(model.remap, ids, axis=-1)


def _z_sparse(model: ServingModel, ids, vals, *, mode, dedup, plan):
    """Region logits for flat padded-COO rows, routed by model dtype:
    int8-native models run the scale-fused int8 gather (plans never
    apply — quantised models are always remapped artifacts, and plans
    are rejected on those before this is reached)."""
    if model.is_int8:
        return sparse_gather_matmul_int8(ids, vals, model.codes,
                                         model.scales, mode=mode,
                                         dedup=dedup)
    return sparse_gather_matmul(ids, vals, model.theta, mode=mode,
                                dedup=dedup, plan=plan)


def score_dense(model, x: jax.Array) -> jax.Array:
    """p(y=1|x) for dense rows x (..., d). Pruned models contract over
    the alive columns only (<= 1e-6 vs full — see module docstring);
    int8 models dequantise on the fly (no gather to fuse the scale
    into — the dense path's carve-out)."""
    model = as_model(model)
    if model.alive_ids is not None:
        x = jnp.take(x, model.alive_ids, axis=-1)
    return finalize_p(x @ model.dense_theta()[:-1])


def score_sparse(model, ids: jax.Array, vals: jax.Array, *,
                 mode: str = "auto", dedup: bool = True,
                 plan=None) -> jax.Array:
    """p(y=1|x) for flat padded-COO rows (N, K) on the fused kernel.

    ``plan`` (a full-model :class:`TransposePlan`) keeps a differentiated
    call's backward sort-free; plans address the full padded Theta, so
    they cannot be combined with a pruned model."""
    model = as_model(model)
    if plan is not None and model.remap is not None:
        raise ValueError("transpose plans address the full Theta layout; "
                         "rebuild the plan in compact space or score the "
                         "full model")
    if model.is_int8:
        return lsplm_sparse_forward_int8(_request_ids(model, ids), vals,
                                         model.codes, model.scales,
                                         mode=mode, dedup=dedup)
    return lsplm_sparse_forward(_request_ids(model, ids), vals, model.theta,
                                mode=mode, dedup=dedup, plan=plan)


def score_sparse_logps(model, ids: jax.Array, vals: jax.Array, *,
                       mode: str = "auto", dedup: bool = True,
                       plan=None) -> tuple[jax.Array, jax.Array]:
    """Stable (log_p1, log_p0) for flat padded-COO rows (the Eq. 5 eval
    head on the serving layer)."""
    model = as_model(model)
    if plan is not None and model.remap is not None:
        raise ValueError("transpose plans address the full Theta layout")
    z = _z_sparse(model, _request_ids(model, ids), vals, mode=mode,
                  dedup=dedup, plan=plan)
    return logps_from_z(z)


def bundle_logits(model, bundle: ScoreBundle, *, mode: str = "auto",
                  dedup: bool = True, user_plan=None,
                  ad_plan=None) -> jax.Array:
    """Session-shared region logits z (B, 2m): the user contraction runs
    once per bundle (G rows), then broadcasts over candidates (Eq. 13).

    ``user_plan``/``ad_plan`` (full-model transpose plans for the bundle's
    id tensors) keep a DIFFERENTIATED call's backward sort-free — the
    training-eval path passes a ``SparseCTRBatch``'s plans through here."""
    model = as_model(model)
    if (user_plan is not None or ad_plan is not None) \
            and model.remap is not None:
        raise ValueError("transpose plans address the full Theta layout; "
                         "they cannot be combined with a pruned artifact")
    z_user = _z_sparse(model, _request_ids(model, bundle.user_ids),
                       bundle.user_vals, mode=mode, dedup=dedup,
                       plan=user_plan)
    z_ad = _z_sparse(model, _request_ids(model, bundle.ad_ids),
                     bundle.ad_vals, mode=mode, dedup=dedup, plan=ad_plan)
    return z_user[bundle.session_id] + z_ad


def score_bundles(model, bundle: ScoreBundle, *, mode: str = "auto",
                  dedup: bool = True, user_plan=None,
                  ad_plan=None) -> jax.Array:
    """p(y=1|x) (B,) for session-grouped bundles — the serving hot path."""
    return finalize_p(bundle_logits(model, bundle, mode=mode, dedup=dedup,
                                    user_plan=user_plan, ad_plan=ad_plan))


def score_bundles_naive(model, bundle: ScoreBundle, *, mode: str = "auto",
                        dedup: bool = True) -> jax.Array:
    """The un-shared baseline: every candidate re-carries its bundle's
    user ids, so the user gathers/contractions run N times per page view
    instead of once. Identical scores; bench_serve measures the gap."""
    ids = jnp.concatenate(
        [bundle.user_ids[bundle.session_id], bundle.ad_ids], axis=-1)
    vals = jnp.concatenate(
        [bundle.user_vals[bundle.session_id], bundle.ad_vals], axis=-1)
    return score_sparse(model, ids, vals, mode=mode, dedup=dedup)


def predict(model, request, *, mode: str = "auto") -> jax.Array:
    """Unified entry: dispatch on the request's structure.

    * session-grouped sparse (has ``user_ids``/``ad_ids``/``session_id``,
      e.g. :class:`ScoreBundle` or a ``SparseCTRBatch``) -> shared path;
    * a ``(ids, vals)`` pair -> flat sparse;
    * a dense array ``(..., d)`` -> dense.
    """
    if hasattr(request, "user_ids") and hasattr(request, "session_id"):
        # a SparseCTRBatch carries transpose plans; thread them through so
        # differentiated full-model calls keep the sort-free backward
        # (score_bundles rejects plans on pruned models)
        model_n = as_model(model)
        user_plan = getattr(request, "user_plan", None)
        ad_plan = getattr(request, "ad_plan", None)
        if model_n.remap is not None:
            user_plan = ad_plan = None  # inference-only on artifacts
        return score_bundles(model_n, ScoreBundle(
            user_ids=request.user_ids, user_vals=request.user_vals,
            ad_ids=request.ad_ids, ad_vals=request.ad_vals,
            session_id=request.session_id), mode=mode,
            user_plan=user_plan, ad_plan=ad_plan)
    if isinstance(request, (tuple, list)) and len(request) == 2:
        ids, vals = request
        return score_sparse(model, ids, vals, mode=mode)
    return score_dense(model, jnp.asarray(request))
