"""Batched online scoring engine: bucketed shapes, cached executables.

Online traffic is ragged: every page view carries its own user id count
Ku, per-candidate id count Ka and candidate count N. JAX compiles per
shape, so scoring raw shapes would recompile on nearly every request —
the latency cliff production scorers cannot afford. The engine lands the
ROADMAP "bucketed shape padding" idea on the serving side:

  * each request is padded up to a bucketed ENVELOPE (K_user, K_ad, N)
    (pad slots carry the pad id with value 0, padded candidates are
    sliced off the result);
  * same-envelope requests STACK: :meth:`ScoringEngine.score_batch`
    groups a wavefront of requests by envelope and serves each group as
    ONE ``G > 1`` bundle call (G itself bucketed, pad bundles are all-pad
    and sliced off), so the per-dispatch overhead — python padding,
    executable launch, device sync — amortises over G page views. This
    is the traffic-shaped fast path the micro-batching queue
    (``repro.serve.traffic``) flushes into;
  * per (G, K_user, K_ad, N, dtype) envelope the scoring executable is
    AOT-compiled ONCE (``jit(...).lower(...).compile()``) and cached
    (dtype is "fp32" or "int8" — an int8-native engine's executables
    run the scale-fused int8 gather path and never collide with fp32
    ones on the same shapes);
    envelope keys are the ONLY source of compilation, so once the bucket
    set is warm a request replay of any mix/order/grouping triggers ZERO
    recompiles (asserted in ``tests/test_serve_engine.py``). An AOT
    executable also cannot silently retrace — a shape bug raises instead
    of recompiling.

Scoring runs the session-shared path (``serve.score.score_bundles``,
Eq. 13): each request's user contraction happens once and broadcasts
over its padded candidate block; a batched call carries G independent
user rows and G*N candidates. The model (full Theta, a pruned
:class:`~repro.serve.compress.ServingArtifact`, or an int8
:class:`~repro.serve.compress.QuantizedArtifact` — served INT8-NATIVE:
the executables run the scale-fused int8 gather, fp32 rows are never
materialised) is normalised and placed on device once at engine
construction; requests stay in the original id space either way.

:class:`EngineStats` keeps the latency/throughput ledger: request and
candidate counts, dispatch (AOT call) and padded-slot counts with the
implied batch occupancy, per-envelope hit counts, compile count and
seconds, scoring wall seconds, and the observed request rate (QPS) over
the scoring span (used by ``benchmarks/bench_serve.py`` and the
``repro.launch.serve`` smoke).
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.serve.score import ScoreBundle, as_model, score_bundles
from repro.tune import round_up

# default bucket edges; above the top edge, round up to a multiple of it.
# K edges are dense at the small end (production id lists are tens),
# N edges cover typical candidate-slate sizes, G edges the micro-batch
# sizes the queue flushes (powers of two so a handful of executables
# covers every flush size).
DEFAULT_K_BUCKETS = (8, 16, 24, 32, 48, 64)
DEFAULT_N_BUCKETS = (4, 8, 16, 32, 64)
DEFAULT_G_BUCKETS = (1, 2, 4, 8, 16)


class BundleRequest(NamedTuple):
    """One page view: a user id list + N candidate id lists (original id
    space, no padding — the engine pads)."""

    user_ids: np.ndarray  # (Ku,) int
    user_vals: np.ndarray  # (Ku,) float
    ad_ids: np.ndarray  # (N, Ka) int
    ad_vals: np.ndarray  # (N, Ka) float


class EngineStats:
    """Serving counters (one labeled family per engine) — a view over the
    process metrics registry: every field reads back out of a registry
    series, so the same numbers export through ``--metrics-out`` while
    the attribute/property API (and ``as_dict``) stays exactly as it was.
    """

    def __init__(self, registry=None):
        reg = registry if registry is not None else obs.get_registry()
        labels = {"engine": obs.next_instance("engine")}
        self._reg, self._labels = reg, labels
        self._requests = reg.counter("serve_requests", **labels)
        self._candidates = reg.counter("serve_candidates", **labels)
        self._dispatches = reg.counter("serve_dispatches", **labels)
        self._slots = reg.counter("serve_slots", **labels)
        self._compiles = reg.counter("serve_compiles", **labels)
        self._compile_s = reg.counter("serve_compile_seconds", **labels)
        self._score_s = reg.counter("serve_score_seconds", **labels)
        self._wall_hist = reg.histogram("serve_dispatch_wall_seconds",
                                        **labels)
        self._hits: dict[tuple, obs.Counter] = {}
        self._first_t: float | None = None
        self._last_t: float | None = None

    # ------------------------------------------------------------- mutators
    def note_compile(self, seconds: float) -> None:
        self._compiles.inc(1.0)
        self._compile_s.inc(seconds)

    def note_dispatch(self, key: tuple, requests: int,
                      candidates: int, wall_s: float) -> None:
        """Book one AOT executable call: its padded envelope, the real
        requests/candidates it carried, and its wall time."""
        self._score_s.inc(wall_s)
        self._wall_hist.observe(wall_s)
        self.note_span()
        self._dispatches.inc(1.0)
        self._slots.inc(float(key[0]))
        self._requests.inc(float(requests))
        self._candidates.inc(float(candidates))
        hit = self._hits.get(key)
        if hit is None:
            hit = self._reg.counter("serve_bucket_hits",
                                    envelope="x".join(map(str, key)),
                                    **self._labels)
            self._hits[key] = hit
        hit.inc(float(requests))

    def note_span(self) -> None:
        """Stamp the scoring span (first/last dispatch) for QPS."""
        now = time.perf_counter()
        if self._first_t is None:
            self._first_t = now
        self._last_t = now

    # ---------------------------------------------------------------- views
    @property
    def requests(self) -> int:
        return int(self._requests.value)

    @property
    def candidates(self) -> int:
        return int(self._candidates.value)

    @property
    def dispatches(self) -> int:
        return int(self._dispatches.value)

    @property
    def slots(self) -> int:
        return int(self._slots.value)

    @property
    def compiles(self) -> int:
        return int(self._compiles.value)

    @property
    def compile_seconds(self) -> float:
        return self._compile_s.value

    @property
    def score_seconds(self) -> float:
        return self._score_s.value

    @property
    def bucket_hits(self) -> dict[tuple, int]:
        return {k: int(c.value) for k, c in self._hits.items()}

    @property
    def latency_us(self) -> float:
        """Mean per-request scoring wall time (padding + device + sync);
        batched requests share their dispatch's wall time."""
        return self.score_seconds / self.requests * 1e6 if self.requests else 0.0

    @property
    def candidates_per_sec(self) -> float:
        return self.candidates / self.score_seconds if self.score_seconds else 0.0

    @property
    def occupancy(self) -> float:
        """Real requests per padded bundle slot (1.0 = no G padding)."""
        return self.requests / self.slots if self.slots else 0.0

    @property
    def qps(self) -> float:
        """Observed request rate over the scoring span (first to last
        dispatch); 0 until two dispatches have landed."""
        if self._first_t is None or self._last_t == self._first_t:
            return 0.0
        return self.requests / (self._last_t - self._first_t)

    def as_dict(self) -> dict:
        return {
            "requests": self.requests,
            "candidates": self.candidates,
            "dispatches": self.dispatches,
            "slots": self.slots,
            "occupancy": self.occupancy,
            "qps": self.qps,
            "compiles": self.compiles,
            "compile_seconds": self.compile_seconds,
            "score_seconds": self.score_seconds,
            "latency_us": self.latency_us,
            "candidates_per_sec": self.candidates_per_sec,
            "bucket_hits": {"x".join(map(str, k)): v
                            for k, v in self.bucket_hits.items()},
        }


# The engine's envelope rounding and the autotune table's shape buckets
# share ONE rule: a request padded to its engine bucket lands on the
# same table envelope every time, so block-size resolution is as
# recompile-free as the executable cache itself.
_round_up = round_up


class ScoringEngine:
    """Steady-state no-recompile bundle scorer (see module docstring)."""

    def __init__(self, model, *, mode: str = "auto", dedup: bool = True,
                 k_buckets: Sequence[int] = DEFAULT_K_BUCKETS,
                 n_buckets: Sequence[int] = DEFAULT_N_BUCKETS,
                 g_buckets: Sequence[int] = DEFAULT_G_BUCKETS):
        self._model = as_model(model)  # arrays are already device-resident
        self._mode = mode
        self._dedup = dedup
        self._k_buckets = tuple(sorted(k_buckets))
        self._n_buckets = tuple(sorted(n_buckets))
        self._g_buckets = tuple(sorted(g_buckets))
        self._pad_id = self._model.num_features  # original-space pad id
        # executables key on the model dtype too: an int8-native engine
        # and an fp32 engine never share (or clobber) a cache entry even
        # when their envelopes coincide, and the dtype rides the stats/
        # ledger envelope labels
        self._dtype = "int8" if self._model.is_int8 else "fp32"
        self._compiled: dict[tuple, jax.stages.Compiled] = {}
        self.stats = EngineStats()
        self._dispatch_ctx = ("direct", 0.0)  # (flush reason, queue delay us)

    @property
    def g_buckets(self) -> tuple[int, ...]:
        """The batch-size bucket edges dispatches round G up to."""
        return self._g_buckets

    @property
    def max_batch(self) -> int:
        """Largest bundle count one dispatch carries (top G bucket);
        bigger wavefronts split into chunks of this size."""
        return self._g_buckets[-1]

    # ------------------------------------------------------------ envelopes
    def envelope(self, request: BundleRequest) -> tuple[int, int, int]:
        """The (K_user, K_ad, N) bucket this request is served under."""
        ku = _round_up(request.user_ids.shape[-1], self._k_buckets)
        ka = _round_up(request.ad_ids.shape[-1], self._k_buckets)
        n = _round_up(request.ad_ids.shape[0], self._n_buckets)
        return ku, ka, n

    def _executable(self, key: tuple):
        comp = self._compiled.get(key)
        if comp is None:
            g, ku, ka, n = key[:4]
            model, mode, dedup = self._model, self._mode, self._dedup

            def fn(ui, uv, ai, av):
                bundle = ScoreBundle(
                    ui, uv, ai, av,
                    jnp.repeat(jnp.arange(g, dtype=jnp.int32), n))
                return score_bundles(model, bundle, mode=mode, dedup=dedup)

            t0 = time.perf_counter()
            with obs.get_tracer().span("serve/compile",
                                       envelope="x".join(map(str, key))):
                comp = jax.jit(fn).lower(
                    jax.ShapeDtypeStruct((g, ku), jnp.int32),
                    jax.ShapeDtypeStruct((g, ku), jnp.float32),
                    jax.ShapeDtypeStruct((g * n, ka), jnp.int32),
                    jax.ShapeDtypeStruct((g * n, ka), jnp.float32),
                ).compile()
            self.stats.note_compile(time.perf_counter() - t0)
            self._compiled[key] = comp
        return comp

    @contextmanager
    def dispatch_context(self, flush_reason: str, queue_delay_us: float):
        """Attribute the dispatches inside this scope to a micro-batch
        flush (``repro.serve.traffic`` wraps its drains in this so the
        ``serve_dispatch`` ledger records carry the flush reason and the
        oldest-request queue delay; un-wrapped calls book as "direct")."""
        prev = self._dispatch_ctx
        self._dispatch_ctx = (flush_reason, float(queue_delay_us))
        try:
            yield
        finally:
            self._dispatch_ctx = prev

    def warm(self, envelopes: Sequence[tuple[int, int, int]], *,
             batch_sizes: Sequence[int] = (1,)) -> None:
        """Precompile a bucket set (deploy-time, off the request path).

        ``batch_sizes`` are the G buckets to warm per (Ku, Ka, N)
        envelope — pass the engine's ``g_buckets`` when the traffic will
        arrive through :meth:`score_batch` / the micro-batching queue,
        whose flush sizes round onto exactly those buckets.
        """
        for ku, ka, n in envelopes:
            for g in batch_sizes:
                self._executable((_round_up(g, self._g_buckets), ku, ka, n,
                                  self._dtype))

    # -------------------------------------------------------------- scoring
    def _pad_batch(self, requests: Sequence[BundleRequest], key: tuple):
        """Stack same-envelope requests into the padded batch layout:
        request s owns user row s and candidate rows [s*n, (s+1)*n); pad
        candidate rows and pad bundle slots are all-pad-id (their scores
        come out 0.5 and are sliced off)."""
        g, ku, ka, n = key[:4]
        ui = np.full((g, ku), self._pad_id, np.int32)
        uv = np.zeros((g, ku), np.float32)
        ai = np.full((g * n, ka), self._pad_id, np.int32)
        av = np.zeros((g * n, ka), np.float32)
        for s, r in enumerate(requests):
            ui[s, :r.user_ids.shape[-1]] = r.user_ids
            uv[s, :r.user_vals.shape[-1]] = r.user_vals
            n_real, ka_real = r.ad_ids.shape
            ai[s * n:s * n + n_real, :ka_real] = r.ad_ids
            av[s * n:s * n + n_real, :ka_real] = r.ad_vals
        return ui, uv, ai, av

    def _score_chunk(self, requests: Sequence[BundleRequest],
                     env: tuple[int, int, int]) -> list[np.ndarray]:
        """One dispatch: requests fitting ``env``, len <= max_batch."""
        ku, ka, n = env
        key = (_round_up(len(requests), self._g_buckets), ku, ka, n,
               self._dtype)
        comp = self._executable(key)  # compile time books separately
        t0 = time.perf_counter()
        with obs.get_tracer().span("serve/dispatch", g=key[0],
                                   envelope="x".join(map(str, key))):
            ui, uv, ai, av = self._pad_batch(requests, key)
            p = np.asarray(jax.block_until_ready(comp(ui, uv, ai, av)))
            p = p.reshape(key[0], n)
        wall = time.perf_counter() - t0
        n_cands = sum(r.ad_ids.shape[0] for r in requests)
        self.stats.note_dispatch(key, len(requests), n_cands, wall)
        led = obs.get_ledger()
        if led.enabled:
            reason, qdelay = self._dispatch_ctx
            led.emit(
                "serve_dispatch", envelope=list(key), g=key[0],
                requests=len(requests), candidates=n_cands,
                occupancy=len(requests) / key[0], wall_s=wall,
                flush_reason=reason, queue_delay_us=qdelay)
        out = [p[s, :r.ad_ids.shape[0]] for s, r in enumerate(requests)]
        mon = obs.get_monitor()
        if mon.enabled:
            mon.observe_dispatch(out, requests)
        return out

    def score(self, request: BundleRequest) -> np.ndarray:
        """p(y=1|x) for each of the request's N candidates, in order
        (a G=1 dispatch)."""
        return self._score_chunk([request], self.envelope(request))[0]

    def score_batch(self, requests: Sequence[BundleRequest]) -> list[np.ndarray]:
        """Score a wavefront of requests, batching same-envelope ones
        into G>1 dispatches (groups bigger than ``max_batch`` split).

        Returns per-request score vectors in the INPUT order; the
        scores are exactly what :meth:`score` returns for each request
        alone (same envelope padding, same kernel — asserted in tests
        and ``benchmarks/bench_serve.py``), the win is dispatch count.
        """
        results: list[np.ndarray | None] = [None] * len(requests)
        groups: dict[tuple[int, int, int], list[int]] = {}
        for i, r in enumerate(requests):
            groups.setdefault(self.envelope(r), []).append(i)
        cap = self.max_batch
        for env, idxs in groups.items():
            for s in range(0, len(idxs), cap):
                chunk = idxs[s:s + cap]
                scores = self._score_chunk([requests[i] for i in chunk], env)
                for i, p in zip(chunk, scores):
                    results[i] = p
        return results  # type: ignore[return-value]

    def score_batch_at(self, requests: Sequence[BundleRequest],
                       env: tuple[int, int, int]) -> list[np.ndarray]:
        """Score a wavefront at ONE caller-chosen envelope every request
        must fit — the micro-batching queue's cross-envelope COALESCED
        flush path: several small same-deadline groups ride one device
        round at the widest due envelope instead of one round each.

        Scores are bitwise what per-envelope dispatch returns: widening
        a request's envelope only adds pad-id slots, which alias the
        zero pad row and contribute exact zeros to its per-sample
        contraction (pad candidate rows are sliced off). Wavefronts
        bigger than ``max_batch`` split in input order.
        """
        ku, ka, n = env
        for r in requests:
            if (r.user_ids.shape[-1] > ku or r.ad_ids.shape[-1] > ka
                    or r.ad_ids.shape[0] > n):
                raise ValueError(
                    f"request (Ku={r.user_ids.shape[-1]}, "
                    f"Ka={r.ad_ids.shape[-1]}, N={r.ad_ids.shape[0]}) "
                    f"does not fit envelope {env}")
        out: list[np.ndarray] = []
        for s in range(0, len(requests), self.max_batch):
            out += self._score_chunk(requests[s:s + self.max_batch], env)
        return out

    def score_many(self, requests: Sequence[BundleRequest]) -> list[np.ndarray]:
        """One-request-at-a-time replay (the un-batched baseline;
        ``score_batch`` is the traffic-shaped path)."""
        return [self.score(r) for r in requests]


def envelope_closure(
        envelopes: Sequence[tuple[int, int, int]]
) -> set[tuple[int, int, int]]:
    """Close an envelope set under elementwise max: the cross product of
    observed component values. A coalesced flush dispatches at the
    elementwise max of its member envelopes, which always lands in this
    closure — warm it (with ``batch_sizes=g_buckets``) and coalesced
    traffic keeps the zero-steady-state-recompile guarantee."""
    envs = list(envelopes)
    if not envs:
        return set()
    kus = {e[0] for e in envs}
    kas = {e[1] for e in envs}
    ns = {e[2] for e in envs}
    return {(ku, ka, n) for ku in kus for ka in kas for n in ns}


def synthetic_requests(num: int, *, num_features: int,
                       k_user: tuple[int, int] = (12, 24),
                       k_ad: tuple[int, int] = (6, 12),
                       n_ads: tuple[int, int] = (10, 30),
                       seed: int = 0) -> list[BundleRequest]:
    """Ragged random request traffic for tests/benches/smokes: every
    request draws its own Ku, Ka and N uniformly from the given ranges
    (inclusive), ids uniform over the ORIGINAL feature space."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(num):
        ku = int(rng.integers(k_user[0], k_user[1] + 1))
        ka = int(rng.integers(k_ad[0], k_ad[1] + 1))
        n = int(rng.integers(n_ads[0], n_ads[1] + 1))
        out.append(BundleRequest(
            user_ids=rng.integers(0, num_features, (ku,)).astype(np.int32),
            user_vals=(rng.normal(size=(ku,)) / np.sqrt(ku)).astype(np.float32),
            ad_ids=rng.integers(0, num_features, (n, ka)).astype(np.int32),
            ad_vals=(rng.normal(size=(n, ka)) / np.sqrt(ka)).astype(np.float32),
        ))
    return out
