"""Batched online scoring engine: bucketed shapes, cached executables.

Online traffic is ragged: every page view carries its own user id count
Ku, per-candidate id count Ka and candidate count N. JAX compiles per
shape, so scoring raw shapes would recompile on nearly every request —
the latency cliff production scorers cannot afford. The engine lands the
ROADMAP "bucketed shape padding" idea on the serving side:

  * each request is padded up to a bucketed ENVELOPE (K_user, K_ad, N)
    (pad slots carry the pad id with value 0, padded candidates are
    sliced off the result);
  * per envelope the scoring executable is AOT-compiled ONCE
    (``jit(...).lower(...).compile()``) and cached; envelope keys are the
    ONLY source of compilation, so once the bucket set is warm a request
    replay of any mix/order triggers ZERO recompiles (asserted in
    ``tests/test_serve_engine.py``). An AOT executable also cannot
    silently retrace — a shape bug raises instead of recompiling.

Scoring runs the session-shared path (``serve.score.score_bundles``,
Eq. 13): the user contraction happens once per request and broadcasts
over its padded candidate block. The model (full Theta or a pruned
:class:`~repro.serve.compress.ServingArtifact`) is normalised and placed
on device once at engine construction; requests stay in the original id
space either way.

:class:`EngineStats` keeps the latency/throughput ledger: request and
candidate counts, per-envelope hit counts, compile count and seconds,
and scoring wall seconds (used by ``benchmarks/bench_serve.py`` and the
``repro.launch.serve`` smoke).
"""
from __future__ import annotations

import time
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.score import ScoreBundle, as_model, score_bundles

# default bucket edges; above the top edge, round up to a multiple of it.
# K edges are dense at the small end (production id lists are tens),
# N edges cover typical candidate-slate sizes.
DEFAULT_K_BUCKETS = (8, 16, 24, 32, 48, 64)
DEFAULT_N_BUCKETS = (4, 8, 16, 32, 64)


class BundleRequest(NamedTuple):
    """One page view: a user id list + N candidate id lists (original id
    space, no padding — the engine pads)."""

    user_ids: np.ndarray  # (Ku,) int
    user_vals: np.ndarray  # (Ku,) float
    ad_ids: np.ndarray  # (N, Ka) int
    ad_vals: np.ndarray  # (N, Ka) float


class EngineStats:
    """Mutable serving ledger (one per engine)."""

    def __init__(self):
        self.requests = 0
        self.candidates = 0
        self.compiles = 0
        self.compile_seconds = 0.0
        self.score_seconds = 0.0
        self.bucket_hits: dict[tuple[int, int, int], int] = {}

    @property
    def latency_us(self) -> float:
        """Mean per-request scoring wall time (padding + device + sync)."""
        return self.score_seconds / self.requests * 1e6 if self.requests else 0.0

    @property
    def candidates_per_sec(self) -> float:
        return self.candidates / self.score_seconds if self.score_seconds else 0.0

    def as_dict(self) -> dict:
        return {
            "requests": self.requests,
            "candidates": self.candidates,
            "compiles": self.compiles,
            "compile_seconds": self.compile_seconds,
            "score_seconds": self.score_seconds,
            "latency_us": self.latency_us,
            "candidates_per_sec": self.candidates_per_sec,
            "bucket_hits": {"x".join(map(str, k)): v
                            for k, v in self.bucket_hits.items()},
        }


def _round_up(x: int, buckets: Sequence[int]) -> int:
    """Smallest bucket edge >= x; past the top edge, next multiple of it."""
    if x <= 0:
        raise ValueError(f"dimension must be positive, got {x}")
    for b in buckets:
        if x <= b:
            return b
    top = buckets[-1]
    return -(-x // top) * top


class ScoringEngine:
    """Steady-state no-recompile bundle scorer (see module docstring)."""

    def __init__(self, model, *, mode: str = "auto", dedup: bool = True,
                 k_buckets: Sequence[int] = DEFAULT_K_BUCKETS,
                 n_buckets: Sequence[int] = DEFAULT_N_BUCKETS):
        self._model = as_model(model)  # arrays are already device-resident
        self._mode = mode
        self._dedup = dedup
        self._k_buckets = tuple(sorted(k_buckets))
        self._n_buckets = tuple(sorted(n_buckets))
        self._pad_id = self._model.num_features  # original-space pad id
        self._compiled: dict[tuple[int, int, int], jax.stages.Compiled] = {}
        self.stats = EngineStats()

    # ------------------------------------------------------------ envelopes
    def envelope(self, request: BundleRequest) -> tuple[int, int, int]:
        """The (K_user, K_ad, N) bucket this request is served under."""
        ku = _round_up(request.user_ids.shape[-1], self._k_buckets)
        ka = _round_up(request.ad_ids.shape[-1], self._k_buckets)
        n = _round_up(request.ad_ids.shape[0], self._n_buckets)
        return ku, ka, n

    def _executable(self, key: tuple[int, int, int]):
        comp = self._compiled.get(key)
        if comp is None:
            ku, ka, n = key
            model, mode, dedup = self._model, self._mode, self._dedup

            def fn(ui, uv, ai, av):
                bundle = ScoreBundle(ui, uv, ai, av,
                                     jnp.zeros((n,), jnp.int32))
                return score_bundles(model, bundle, mode=mode, dedup=dedup)

            t0 = time.perf_counter()
            comp = jax.jit(fn).lower(
                jax.ShapeDtypeStruct((1, ku), jnp.int32),
                jax.ShapeDtypeStruct((1, ku), jnp.float32),
                jax.ShapeDtypeStruct((n, ka), jnp.int32),
                jax.ShapeDtypeStruct((n, ka), jnp.float32),
            ).compile()
            self.stats.compile_seconds += time.perf_counter() - t0
            self.stats.compiles += 1
            self._compiled[key] = comp
        return comp

    def warm(self, envelopes: Sequence[tuple[int, int, int]]) -> None:
        """Precompile a bucket set (deploy-time, off the request path)."""
        for key in envelopes:
            self._executable(key)

    # -------------------------------------------------------------- scoring
    def _pad(self, request: BundleRequest, key: tuple[int, int, int]):
        ku, ka, n = key
        n_real, ka_real = request.ad_ids.shape
        ui = np.full((1, ku), self._pad_id, np.int32)
        ui[0, :request.user_ids.shape[-1]] = request.user_ids
        uv = np.zeros((1, ku), np.float32)
        uv[0, :request.user_vals.shape[-1]] = request.user_vals
        ai = np.full((n, ka), self._pad_id, np.int32)
        ai[:n_real, :ka_real] = request.ad_ids
        av = np.zeros((n, ka), np.float32)
        av[:n_real, :ka_real] = request.ad_vals
        return ui, uv, ai, av

    def score(self, request: BundleRequest) -> np.ndarray:
        """p(y=1|x) for each of the request's N candidates, in order."""
        key = self.envelope(request)
        comp = self._executable(key)  # compile time books separately
        t0 = time.perf_counter()
        ui, uv, ai, av = self._pad(request, key)
        p = np.asarray(jax.block_until_ready(comp(ui, uv, ai, av)))
        self.stats.score_seconds += time.perf_counter() - t0
        self.stats.requests += 1
        n_real = request.ad_ids.shape[0]
        self.stats.candidates += n_real
        self.stats.bucket_hits[key] = self.stats.bucket_hits.get(key, 0) + 1
        return p[:n_real]

    def score_many(self, requests: Sequence[BundleRequest]) -> list[np.ndarray]:
        return [self.score(r) for r in requests]


def synthetic_requests(num: int, *, num_features: int,
                       k_user: tuple[int, int] = (12, 24),
                       k_ad: tuple[int, int] = (6, 12),
                       n_ads: tuple[int, int] = (10, 30),
                       seed: int = 0) -> list[BundleRequest]:
    """Ragged random request traffic for tests/benches/smokes: every
    request draws its own Ku, Ka and N uniformly from the given ranges
    (inclusive), ids uniform over the ORIGINAL feature space."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(num):
        ku = int(rng.integers(k_user[0], k_user[1] + 1))
        ka = int(rng.integers(k_ad[0], k_ad[1] + 1))
        n = int(rng.integers(n_ads[0], n_ads[1] + 1))
        out.append(BundleRequest(
            user_ids=rng.integers(0, num_features, (ku,)).astype(np.int32),
            user_vals=(rng.normal(size=(ku,)) / np.sqrt(ku)).astype(np.float32),
            ad_ids=rng.integers(0, num_features, (n, ka)).astype(np.int32),
            ad_vals=(rng.normal(size=(n, ka)) / np.sqrt(ka)).astype(np.float32),
        ))
    return out
