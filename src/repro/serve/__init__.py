"""Serving subsystem: pruned artifacts + the unified inference layer +
the bucketed scoring engine + traffic shaping (§3.2 / §4 of the paper,
production side).

``compress`` packs a trained Theta's surviving rows into a deployable
:class:`ServingArtifact` (and optionally int8-quantises it into a
:class:`QuantizedArtifact`, ~4x smaller again); ``score`` is the one
prediction layer every caller (training-eval, examples, the engine)
goes through; ``engine`` serves ragged request traffic with bucketed
shape padding, same-envelope G>1 batching and per-bucket cached
executables (steady state: zero recompiles; int8-native models compile
their own dtype-keyed executables); ``traffic`` adds the micro-batching
queue (deadline-aware flushing, admission control, cross-envelope flush
coalescing), the wall-clock :class:`RealClockPump` front door, the
queue-measured :func:`derive_g_buckets` autoscaler and the open-loop
Poisson load generator behind the p50/p99 benchmark.
"""
from repro.serve.compress import (  # noqa: F401
    QuantizedArtifact,
    ServingArtifact,
    compress,
    dequantize,
    load_artifact,
    quantize,
    save_artifact,
)
from repro.serve.engine import (  # noqa: F401
    BundleRequest,
    EngineStats,
    ScoringEngine,
    envelope_closure,
    synthetic_requests,
)
from repro.serve.traffic import (  # noqa: F401
    Completion,
    MicroBatchQueue,
    QueueConfig,
    QueueStats,
    RealClockPump,
    derive_g_buckets,
    poisson_arrivals,
    replay_open_loop,
)
from repro.serve.score import (  # noqa: F401
    ScoreBundle,
    ServingModel,
    as_model,
    bundle_logits,
    predict,
    score_bundles,
    score_bundles_naive,
    score_dense,
    score_sparse,
    score_sparse_logps,
)
