"""Serving subsystem: pruned artifacts + the unified inference layer +
the bucketed scoring engine (§3.2 / §4 of the paper, production side).

``compress`` packs a trained Theta's surviving rows into a deployable
:class:`ServingArtifact`; ``score`` is the one prediction layer every
caller (training-eval, examples, the engine) goes through; ``engine``
serves ragged request traffic with bucketed shape padding and per-bucket
cached executables (steady state: zero recompiles).
"""
from repro.serve.compress import (  # noqa: F401
    ServingArtifact,
    compress,
    load_artifact,
    save_artifact,
)
from repro.serve.engine import (  # noqa: F401
    BundleRequest,
    EngineStats,
    ScoringEngine,
    synthetic_requests,
)
from repro.serve.score import (  # noqa: F401
    ScoreBundle,
    ServingModel,
    as_model,
    bundle_logits,
    predict,
    score_bundles,
    score_bundles_naive,
    score_dense,
    score_sparse,
    score_sparse_logps,
)
