"""Sharded sparse subsystem: id-range routed Theta shards (§4, Fig. 5).

``partition``     id-range partitioner + host-side batch routing
``plan_slicing``  TransposePlan slicing at id-range / sample boundaries
``step``          shard_map sparse loss/grad over a (data, model) mesh
"""
from repro.shard.partition import (  # noqa: F401
    Partition,
    ShardedSparseBatch,
    balanced_partition,
    make_partition,
    route_batch,
    route_ids,
    shard_slot_width,
)
from repro.shard.plan_slicing import (  # noqa: F401
    restrict_plan,
    shard_plan_grid,
    slice_plan,
    stack_plans,
)
from repro.shard.step import (  # noqa: F401
    make_sharded_sparse_loss,
    sharded_sparse_loss_and_grad,
    sharded_sparse_nll,
)
