"""Id-range partitioning + host-side batch routing for the sharded
sparse subsystem — the paper's parameter-server split (§4, Fig. 5) made
concrete for padded-COO batches.

Each model shard owns one CONTIGUOUS id range ``[bounds[s], bounds[s+1])``
of the d feature columns. Contiguity is the load-bearing choice:

  * Theta rows are the L2,1 groups, so a feature row never straddles
    shards and OWLQN+'s orthant/direction algebra stays shard-local.
  * The backward :class:`~repro.kernels.lsplm_sparse_scatter.plan.
    TransposePlan` is sorted by id, so per-shard plans are contiguous
    SLICES of the full plan (``repro.shard.plan_slicing``) — no
    re-sorting at routing time.
  * Local ids are global ids minus the range start — routing is a
    subtract, not a hash map.

``make_partition`` cuts equal ranges; ``balanced_partition`` cuts at
quantiles of the batch's id histogram so Zipf-hot heads (real CTR id
traffic concentrates on low ids) don't overload shard 0 — unequal range
WIDTHS, near-equal entry COUNTS. Unequal ranges still present a uniform
(S * rows_per_shard, 2m) device layout: each shard's rows are padded to
the widest range (``Partition.pad_rows`` / ``unpad_rows``); pad rows
receive no ids, so their gradient is exactly zero and OWLQN+ keeps them
at exact zero — padding is free in math, only bytes.

``route_batch`` buckets each sample's (ids, vals) per shard into
per-shard padded-COO tensors with ONE uniform per-shard K (the max
in-shard count over all samples and shards, optionally rounded up) —
uniform because the sharded step stacks them on a leading 'model' axis
for ``shard_map``. Entry order within a sample is preserved (k-ascending),
which is what makes the sliced plans bit-identical to plans built
directly on the routed local ids.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.sparse import SparseCTRBatch
from repro.kernels.lsplm_sparse_scatter.plan import TransposePlan


class Partition:
    """Contiguous id-range partition of ``num_rows`` feature columns.

    ``bounds`` is (S+1,) non-decreasing with ``bounds[0] == 0`` and
    ``bounds[-1] == num_rows``; shard s owns ids in
    ``[bounds[s], bounds[s+1])``.
    """

    def __init__(self, bounds: Sequence[int]):
        b = np.asarray(bounds, np.int64)
        if b.ndim != 1 or b.size < 2:
            raise ValueError(f"bounds must be (S+1,) with S >= 1, got {b.shape}")
        if b[0] != 0:
            raise ValueError(f"bounds[0] must be 0, got {b[0]}")
        if np.any(np.diff(b) < 0):
            raise ValueError(f"bounds must be non-decreasing: {b}")
        self.bounds = b

    # ------------------------------------------------------------ properties
    @property
    def num_shards(self) -> int:
        return int(self.bounds.size - 1)

    @property
    def num_rows(self) -> int:
        return int(self.bounds[-1])

    @property
    def sizes(self) -> np.ndarray:
        return np.diff(self.bounds)

    @property
    def rows_per_shard(self) -> int:
        """Uniform per-shard row count of the padded device layout."""
        return int(max(1, self.sizes.max()))

    @property
    def is_uniform(self) -> bool:
        """True iff every range already has ``rows_per_shard`` rows (the
        padded layout is then the identity)."""
        return bool(np.all(self.sizes == self.rows_per_shard))

    def ranges(self) -> list[tuple[int, int]]:
        return [(int(self.bounds[s]), int(self.bounds[s + 1]))
                for s in range(self.num_shards)]

    def __repr__(self) -> str:
        return (f"Partition(num_rows={self.num_rows}, "
                f"num_shards={self.num_shards}, sizes={self.sizes.tolist()})")

    def __eq__(self, other) -> bool:
        return (isinstance(other, Partition)
                and np.array_equal(self.bounds, other.bounds))

    # ------------------------------------------------------------- id algebra
    def shard_of(self, ids) -> np.ndarray:
        """Owning shard per id (host numpy). Ids >= num_rows (e.g. the
        global pad id) map to ``num_shards`` — owned by nobody."""
        return np.searchsorted(self.bounds[1:], np.asarray(ids), side="right")

    # ---------------------------------------------------- padded Theta layout
    def pad_rows(self, theta: jax.Array) -> jax.Array:
        """(d, 2m) -> (S * rows_per_shard, 2m): shard s's rows at
        ``[s * rows_per_shard, s * rows_per_shard + sizes[s])``, zero
        padding after. Identity (no copy) for uniform partitions."""
        if theta.shape[0] != self.num_rows:
            raise ValueError(
                f"theta has {theta.shape[0]} rows, partition covers "
                f"{self.num_rows}")
        if self.is_uniform:
            return theta
        R = self.rows_per_shard
        parts = []
        for (lo, hi) in self.ranges():
            parts.append(theta[lo:hi])
            if hi - lo < R:
                parts.append(jnp.zeros((R - (hi - lo),) + theta.shape[1:],
                                       theta.dtype))
        return jnp.concatenate(parts, axis=0)

    def unpad_rows(self, theta_padded: jax.Array) -> jax.Array:
        """Inverse of :meth:`pad_rows` — drops the per-shard pad rows."""
        R = self.rows_per_shard
        if theta_padded.shape[0] != self.num_shards * R:
            raise ValueError(
                f"padded theta has {theta_padded.shape[0]} rows, expected "
                f"{self.num_shards * R}")
        if self.is_uniform:
            return theta_padded
        parts = [theta_padded[s * R: s * R + (hi - lo)]
                 for s, (lo, hi) in enumerate(self.ranges())]
        return jnp.concatenate(parts, axis=0)


def make_partition(num_rows: int, num_shards: int) -> Partition:
    """Equal contiguous ranges (first ``num_rows % num_shards`` shards get
    one extra row). With ``num_rows % num_shards == 0`` the padded device
    layout is the identity — this is the partition the trainer uses so
    GSPMD's equal axis split IS the id-range split."""
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if num_rows < num_shards:
        raise ValueError(
            f"cannot cut {num_rows} rows into {num_shards} non-empty ranges")
    base, rem = divmod(num_rows, num_shards)
    sizes = np.full(num_shards, base, np.int64)
    sizes[:rem] += 1
    return Partition(np.concatenate([[0], np.cumsum(sizes)]))


def balanced_partition(num_rows: int, num_shards: int, *id_arrays,
                       pad_id: int | None = None) -> Partition:
    """Frequency-balanced contiguous ranges from the batch's id histogram.

    Cuts at quantiles of the cumulative entry count so each shard serves
    ~1/S of the batch's gather/scatter traffic even when the id
    distribution is Zipf-hot (CTR reality: without this, equal ranges
    put nearly every entry on shard 0). A single id's mass cannot be
    split — pathological heads still bound the imbalance from below.
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    counts = np.zeros(num_rows, np.int64)
    for arr in id_arrays:
        flat = np.asarray(arr).reshape(-1)
        if pad_id is not None:
            flat = flat[flat != pad_id]
        if flat.size:
            counts += np.bincount(flat, minlength=num_rows)[:num_rows]
    cum = np.cumsum(counts)
    total = int(cum[-1]) if num_rows else 0
    if total == 0:  # no signal — fall back to equal ranges
        return make_partition(num_rows, num_shards)
    targets = (np.arange(1, num_shards) * total) / num_shards
    cuts = np.searchsorted(cum, targets, side="left") + 1
    bounds = np.concatenate([[0], cuts, [num_rows]])
    return Partition(np.maximum.accumulate(np.clip(bounds, 0, num_rows)))


def shard_slot_width(part: Partition, ids, *, pad_id: int,
                     num_samples: int | None = None,
                     k_multiple: int = 1) -> int:
    """The uniform per-shard K: max in-shard entry count over all
    (sample, shard) cells, rounded up to ``k_multiple``, at least 1.
    ``route_ids`` and ``plan_slicing.slice_plan`` both use this rule, so
    routed tensors and sliced plans agree without coordination."""
    ids = np.asarray(ids)
    N = ids.shape[0] if num_samples is None else num_samples
    flat = ids.reshape(-1)
    keep = flat != pad_id
    if not np.any(keep):
        return max(1, k_multiple)
    sh = part.shard_of(flat[keep])
    n = np.nonzero(keep)[0] // ids.shape[1]
    per_cell = np.bincount(sh * N + n, minlength=(part.num_shards + 1) * N)
    k = int(per_cell[: part.num_shards * N].max())
    return max(1, -(-k // k_multiple) * k_multiple)


def route_ids(part: Partition, ids, vals, *, pad_id: int,
              shard_k: int | None = None,
              k_multiple: int = 1) -> tuple[np.ndarray, np.ndarray, int]:
    """Bucket a padded-COO (N, K) tensor per model shard.

    Returns ``(ids_r, vals_r, Ks)`` with ``ids_r``/``vals_r`` of shape
    (S, N, Ks): shard s's slice holds, per sample, the entries whose
    global id falls in shard s's range — LOCAL ids (global minus range
    start), k-order preserved, tail padded with the local pad id
    ``part.rows_per_shard`` (the zero row ``pad_theta`` appends to each
    shard's padded row block) and value 0. Entries carrying the global
    ``pad_id`` are dropped (they are pads by the COO convention).
    """
    ids = np.asarray(ids)
    vals = np.asarray(vals)
    if ids.shape != vals.shape or ids.ndim != 2:
        raise ValueError(f"ids/vals must share (N, K): {ids.shape} vs "
                         f"{vals.shape}")
    N, K = ids.shape
    S = part.num_shards
    Ks = shard_slot_width(part, ids, pad_id=pad_id, k_multiple=k_multiple) \
        if shard_k is None else int(shard_k)

    flat = ids.reshape(-1)
    keep = np.nonzero(flat != pad_id)[0]
    sh = part.shard_of(flat[keep])
    if keep.size and sh.max() >= S:
        bad = flat[keep][sh >= S].max()
        raise ValueError(f"id {bad} outside partition range "
                         f"[0, {part.num_rows}) and != pad_id {pad_id}")
    n = keep // K

    ids_r = np.full((S, N, Ks), part.rows_per_shard, np.int32)
    vals_r = np.zeros((S, N, Ks), vals.dtype)
    if keep.size:
        # lexsort by (shard, sample); ties keep flat (= k) order, so the
        # within-sample entry order survives routing
        perm = np.argsort(sh * np.int64(N) + n, kind="stable")
        sh_s, n_s, e_s = sh[perm], n[perm], keep[perm]
        cell = sh_s * np.int64(N) + n_s
        starts = np.nonzero(np.diff(np.concatenate([[-1], cell])))[0]
        lens = np.diff(np.concatenate([starts, [cell.size]]))
        if lens.max() > Ks:
            raise ValueError(
                f"shard_k={Ks} too small: a (sample, shard) cell holds "
                f"{lens.max()} entries")
        offs = np.arange(cell.size) - np.repeat(starts, lens)
        ids_r[sh_s, n_s, offs] = (flat[e_s] - part.bounds[sh_s]).astype(np.int32)
        vals_r[sh_s, n_s, offs] = vals.reshape(-1)[e_s]
    return ids_r, vals_r, Ks


class ShardedSparseBatch(NamedTuple):
    """A :class:`~repro.data.sparse.SparseCTRBatch` routed for a
    (data x model) mesh.

    Id/val tensors carry a leading 'model' axis (S shards, LOCAL ids,
    local pad id = ``rows_per_shard``); ``session_id`` is rebased per
    data block (each data shard sees sessions [0, G / data_shards)).
    Plans, when present, are STACKED :class:`TransposePlan`s — every
    leaf has leading (data_shards, num_shards) axes and uniform padded
    shapes (``plan_slicing.stack_plans``) so ``shard_map`` can hand each
    device its own (data-block, id-range) plan cell.
    """

    user_ids: jax.Array   # (S, G, Ku') int32 local ids
    user_vals: jax.Array  # (S, G, Ku')
    ad_ids: jax.Array     # (S, B, Ka') int32 local ids
    ad_vals: jax.Array    # (S, B, Ka')
    session_id: jax.Array  # (B,) block-local session index
    y: jax.Array          # (B,)
    num_features: int = 0           # d (static, global columns)
    rows_per_shard: int = 0         # padded rows per model shard (static)
    data_shards: int = 1            # leading plan axis / batch blocks
    bounds: tuple[int, ...] = ()    # partition bounds (static, hashable)
    user_plan: TransposePlan | None = None  # stacked (Dd, S, ...) leaves
    ad_plan: TransposePlan | None = None

    @property
    def num_shards(self) -> int:
        return len(self.bounds) - 1

    @property
    def partition(self) -> Partition:
        return Partition(np.asarray(self.bounds, np.int64))


def route_batch(batch: SparseCTRBatch, part: Partition, *,
                data_shards: int = 1,
                k_multiple: int = 1) -> ShardedSparseBatch:
    """Route a session-structured sparse batch onto a (data x model) mesh.

    Ids/vals are bucketed per model shard (``route_ids``); the batch's
    transpose plans, when attached, are restricted per data block and
    sliced per id range (``plan_slicing``) — the id sort is NOT redone —
    then stacked into uniform (data_shards, num_shards, ...) leaves.

    Sessions must be contiguous and divisible: each data shard takes
    G / data_shards whole sessions (and their A ads each), mirroring the
    dense path's ``pad_to_multiple`` requirement.
    """
    from repro.shard.plan_slicing import shard_plan_grid, stack_plans

    d = batch.num_features
    if part.num_rows != d:
        raise ValueError(f"partition covers {part.num_rows} rows, batch has "
                         f"{d} feature columns")
    uid = np.asarray(batch.user_ids)
    aid = np.asarray(batch.ad_ids)
    sid = np.asarray(batch.session_id)
    G, B = uid.shape[0], aid.shape[0]
    Dd = int(data_shards)
    if Dd < 1 or G % Dd or B % Dd:
        raise ValueError(
            f"data_shards={Dd} must divide sessions ({G}) and samples ({B})")
    G_l, B_l = G // Dd, B // Dd
    blocks = sid.reshape(Dd, B_l) // G_l
    if not np.all(blocks == np.arange(Dd)[:, None]):
        raise ValueError(
            "sessions must be contiguous: data block b must hold exactly "
            f"sessions [b*{G_l}, (b+1)*{G_l})")

    user_r, user_v, Ku = route_ids(part, uid, np.asarray(batch.user_vals),
                                   pad_id=d, k_multiple=k_multiple)
    ad_r, ad_v, Ka = route_ids(part, aid, np.asarray(batch.ad_vals),
                               pad_id=d, k_multiple=k_multiple)

    user_plan = ad_plan = None
    if batch.user_plan is not None:
        user_plan = stack_plans(shard_plan_grid(
            batch.user_plan, part, num_cols=uid.shape[1],
            data_shards=Dd, shard_k=Ku))
    if batch.ad_plan is not None:
        ad_plan = stack_plans(shard_plan_grid(
            batch.ad_plan, part, num_cols=aid.shape[1],
            data_shards=Dd, shard_k=Ka))

    return ShardedSparseBatch(
        user_ids=jnp.asarray(user_r), user_vals=jnp.asarray(user_v),
        ad_ids=jnp.asarray(ad_r), ad_vals=jnp.asarray(ad_v),
        session_id=jnp.asarray((sid % G_l).astype(np.int32)),
        y=jnp.asarray(batch.y),
        num_features=d, rows_per_shard=part.rows_per_shard,
        data_shards=Dd, bounds=tuple(int(b) for b in part.bounds),
        user_plan=user_plan, ad_plan=ad_plan)
