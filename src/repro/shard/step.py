"""Distributed sparse loss/grad — the paper's worker/server split run as
one ``shard_map`` over a (data, model) mesh.

Per device, the program is exactly the single-device fused path on its
own block: Theta row block (its id range, padded), its data block's
routed (ids, vals) and plan cell. The only cross-device traffic is

  * one ``psum`` of the (B_local, 2m) region-logit PARTIALS over 'model'
    (each server shard contributes the rows it owns — Fig. 5's
    pull/push collapsed into a single reduction), and
  * one scalar ``psum`` of the per-block NLL over the data axis.

The backward needs nothing extra: the transpose of the 'model' psum
broadcasts dz to every server shard, whose plan-driven scatter then
produces exactly its own rows of dTheta — the row-sharded gradient the
sharded OWLQN+ step (``repro.dist``) consumes in place. The fused
forward kernels are the SAME ones the single-device path runs
(``lsplm_sparse_fused``), invoked per shard on local ids.

Composition: ``make_sharded_sparse_loss`` is a drop-in
``loss_and_grad`` for :class:`~repro.optim.owlqn_plus.OWLQNPlus`;
``dist.make_distributed_step`` then keeps the whole optimizer state
row-sharded across iterations, orthant algebra and all (Theta rows are
the L2,1 groups — they never straddle shards).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.dist import sparse_batch_specs
from repro.kernels.lsplm_sparse_fused.ops import (
    logps_from_z,
    pad_theta,
    sparse_gather_matmul,
)
from repro.launch.mesh import data_axes
from repro.shard.partition import ShardedSparseBatch
from repro.shard.plan_slicing import cell_plan


def _check_mesh(mesh, sbatch: ShardedSparseBatch) -> None:
    """The routed batch's (data, model) factorisation must equal the
    mesh's: a mismatch would make shard_map silently split the routed
    leading axes across the wrong number of devices (e.g. two id-range
    shards landing on one device, whose local pad id then aliases a real
    Theta row)."""
    model = mesh.shape["model"]
    data = 1
    for a in data_axes(mesh):
        data *= mesh.shape[a]
    if sbatch.num_shards != model or sbatch.data_shards != data:
        raise ValueError(
            f"batch routed for (data={sbatch.data_shards}, "
            f"model={sbatch.num_shards}) but mesh is (data={data}, "
            f"model={model}) — re-route with matching shard counts")


def sharded_sparse_nll(theta: jax.Array, sbatch: ShardedSparseBatch,
                       mesh, *, mode: str = "auto") -> jax.Array:
    """Eq. 5 NLL of the padded row-sharded Theta over the routed batch.

    ``theta`` is the (num_shards * rows_per_shard, 2m) PADDED layout
    (``Partition.pad_rows``), sharded — or shardable — as
    ``P('model', None)``: GSPMD's equal split of the leading axis IS the
    id-range split. Differentiable: ``jax.grad`` of this function yields
    the row-sharded dTheta with every scatter shard-local.
    """
    S, R = sbatch.num_shards, sbatch.rows_per_shard
    if theta.shape[0] != S * R:
        raise ValueError(
            f"theta has {theta.shape[0]} rows; routed batch expects the "
            f"padded layout {S} * {R} (Partition.pad_rows)")
    _check_mesh(mesh, sbatch)
    # ONE statement of the batch layout: the same specs shard_sparse_batch
    # placed the data with
    specs = sparse_batch_specs(mesh, sbatch)
    reduce_axes = data_axes(mesh)
    has_user_plan = sbatch.user_plan is not None
    has_ad_plan = sbatch.ad_plan is not None

    def local(theta_l, u_ids, u_vals, a_ids, a_vals, sid, y, *plans):
        it = iter(plans)
        u_plan = cell_plan(next(it)) if has_user_plan else None
        a_plan = cell_plan(next(it)) if has_ad_plan else None
        tp = pad_theta(theta_l)  # local zero pad row at index R
        z_u = sparse_gather_matmul(u_ids[0], u_vals[0], tp, mode=mode,
                                   plan=u_plan)
        z_a = sparse_gather_matmul(a_ids[0], a_vals[0], tp, mode=mode,
                                   plan=a_plan)
        # one reduction: every server shard's partial logits for the
        # local data block
        z = jax.lax.psum(z_u[sid] + z_a, "model")
        log_p1, log_p0 = logps_from_z(z)
        yf = y.astype(log_p1.dtype)
        nll = -jnp.sum(yf * log_p1 + (1.0 - yf) * log_p0)
        return jax.lax.psum(nll, reduce_axes)

    args = [sbatch.user_ids, sbatch.user_vals, sbatch.ad_ids, sbatch.ad_vals,
            sbatch.session_id, sbatch.y]
    in_specs = [P("model", None), specs.user_ids, specs.user_vals,
                specs.ad_ids, specs.ad_vals, specs.session_id, specs.y]
    if has_user_plan:
        args.append(sbatch.user_plan)
        in_specs.append(specs.user_plan)
    if has_ad_plan:
        args.append(sbatch.ad_plan)
        in_specs.append(specs.ad_plan)
    return shard_map(local, mesh=mesh, in_specs=tuple(in_specs),
                     out_specs=P())(theta, *args)


def sharded_sparse_loss_and_grad(theta: jax.Array,
                                 sbatch: ShardedSparseBatch, mesh, *,
                                 mode: str = "auto"):
    """(NLL, row-sharded dTheta) — the smooth part OWLQN+ consumes."""
    return jax.value_and_grad(sharded_sparse_nll)(theta, sbatch, mesh,
                                                  mode=mode)


def make_sharded_sparse_loss(sbatch: ShardedSparseBatch, mesh, *,
                             mode: str = "auto"):
    """Bind batch + mesh into the ``loss_and_grad(theta)`` callable
    :class:`~repro.optim.owlqn_plus.OWLQNPlus` expects; compose with
    ``dist.make_distributed_step`` to keep the optimizer state sharded
    across iterations."""
    def loss_and_grad(theta):
        return sharded_sparse_loss_and_grad(theta, sbatch, mesh, mode=mode)

    return loss_and_grad
