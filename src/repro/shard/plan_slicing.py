"""Slice a full-batch :class:`TransposePlan` for a (data x model) mesh.

The plan's layout is sorted by column id, so an id-range partition cuts
it into CONTIGUOUS slices — two ``searchsorted`` calls find shard s's
entries, and the expensive argsort is never repeated:

  * ``slice_plan``    — model axis: per-id-range shard-local plans with
    re-based ids (global minus range start) and re-bucketed popularity
    classes. Bit-identical to ``build_transpose_plan`` on the routed
    shard-local ids (tests/test_shard_plan.py proves it), because both
    feed the same ``assemble_plan_from_sorted`` and the slice inherits
    the full plan's stable id order.
  * ``restrict_plan`` — data axis: a sample-range sub-plan. Restriction
    by sample is a stable subset of the sorted entries (order preserved),
    again sort-free.
  * ``stack_plans``   — pack a (data_shards x num_shards) grid of cell
    plans into ONE plan whose every leaf has leading (Dd, S) axes and
    uniform padded shapes, so ``shard_map`` can pass it as a sharded
    operand and each device picks out its own cell. Padding is inert on
    BOTH scatter paths by construction: padded sorted entries carry the
    shard's zero-pad-row id (``num_rows - 1``) and gather their value
    from an unkept (zero-valued) slot of the routed grid, so the
    class-gather path masks them and the run-length kernel's pad run
    flushes exact zeros onto the compact row absent ids densify from;
    padded class slots are mask-0. The per-cell ``inv_sorted`` leaves
    keep their cell-local meaning, matching the kernel's flush order.

Why slice instead of rebuilding per shard: the argsort over N*K entries
is the only super-linear piece of plan construction. Slicing re-uses it
across all (data, model) cells — the grid costs one linear pass per
cell — and, more importantly, it is the paper's §4 observation made
executable: the parameter-server split of Theta is a SPLIT of the
transpose, not a new transpose.
"""
from __future__ import annotations

import numpy as np

from repro.kernels.lsplm_sparse_scatter.plan import (
    TransposePlan,
    assemble_plan_from_sorted,
)
from repro.shard.partition import Partition


def _host(plan: TransposePlan):
    """Pull the plan's sorted-layout leaves back to host numpy (they are
    small int32 arrays built on the host in the first place)."""
    return (np.asarray(plan.row_ids, np.int64),
            np.asarray(plan.sample_sorted, np.int64),
            np.asarray(plan.slot_sorted, np.int64))


def _group_offsets(keys: np.ndarray) -> np.ndarray:
    """Per-element offset within runs of equal consecutive keys."""
    if keys.size == 0:
        return keys.copy()
    starts = np.nonzero(np.diff(np.concatenate([[-1], keys])))[0]
    lens = np.diff(np.concatenate([starts, [keys.size]]))
    return np.arange(keys.size) - np.repeat(starts, lens)


def default_shard_k(plan: TransposePlan, part: Partition,
                    num_samples: int, *, k_multiple: int = 1) -> int:
    """Uniform per-shard K from the plan itself — same rule as
    ``partition.shard_slot_width`` on the raw ids (max in-shard entry
    count over all (sample, shard) cells, rounded up to ``k_multiple``,
    at least 1), so independently computed plan and tensor widths agree."""
    row_ids, sample_sorted, _ = _host(plan)
    owned = row_ids < part.num_rows  # a kept global pad id owns no shard
    k = 0
    if np.any(owned):
        sh = part.shard_of(row_ids[owned])
        per_cell = np.bincount(
            sh * np.int64(num_samples) + sample_sorted[owned])
        k = int(per_cell.max())
    return max(1, -(-k // k_multiple) * k_multiple)


def slice_plan(plan: TransposePlan, part: Partition, *, num_cols: int,
               shard_k: int | None = None,
               k_multiple: int = 1) -> list[TransposePlan]:
    """Per-model-shard plans as contiguous slices of a full-batch plan.

    Shard s's plan addresses the ROUTED local grid
    (N, shard_k) with local ids in [0, sizes[s]) and
    ``num_rows = rows_per_shard + 1`` (the per-shard padded row block
    plus its ``pad_theta`` zero row) — exactly what
    ``build_transpose_plan(routed_ids[s], rows_per_shard + 1,
    pad_id=rows_per_shard)`` would build, without re-sorting.

    Args:
      plan: full-batch plan (pad entries already dropped at build time).
      part: the id-range partition; must cover the ids the plan indexes.
      num_cols: K of the ORIGINAL (N, K) ids grid the plan was built on
        (plans only record N*K; the split needs N).
      shard_k: uniform routed K (defaults to the same max-cell +
        ``k_multiple`` rule ``route_ids`` uses, so independent calls
        agree — pass the same ``k_multiple`` given to routing).
    """
    row_ids, sample_sorted, slot_sorted = _host(plan)
    if plan.num_entries % num_cols:
        raise ValueError(f"num_cols={num_cols} does not divide "
                         f"num_entries={plan.num_entries}")
    N = plan.num_entries // num_cols
    Ks = default_shard_k(plan, part, N, k_multiple=k_multiple) \
        if shard_k is None else int(shard_k)
    num_rows_local = part.rows_per_shard + 1

    out = []
    for (lo, hi) in part.ranges():
        a = int(np.searchsorted(row_ids, lo, side="left"))
        b = int(np.searchsorted(row_ids, hi, side="left"))
        srt_l = row_ids[a:b] - lo
        n_l = sample_sorted[a:b]
        # routed slot = rank of the entry's original k among the sample's
        # in-shard entries; recovered by a stable grouping on (n, k) —
        # the id sort itself is inherited, not redone
        perm = np.argsort(n_l * np.int64(num_cols) + slot_sorted[a:b],
                          kind="stable")
        k_local = np.empty(b - a, np.int64)
        k_local[perm] = _group_offsets(n_l[perm])
        if k_local.size and k_local.max() >= Ks:
            raise ValueError(
                f"shard_k={Ks} too small for range [{lo}, {hi}): a sample "
                f"holds {int(k_local.max()) + 1} in-range entries")
        out.append(assemble_plan_from_sorted(
            srt_l, n_l * np.int64(Ks) + k_local,
            num_rows=num_rows_local, num_entries=N * Ks, num_cols=Ks))
    return out


def restrict_plan(plan: TransposePlan, n0: int, n1: int, *,
                  num_cols: int) -> TransposePlan:
    """Sample-range restriction: the plan of ``ids[n0:n1]`` (sort-free —
    a stable subset of sorted entries stays sorted)."""
    row_ids, sample_sorted, slot_sorted = _host(plan)
    if plan.num_entries % num_cols:
        raise ValueError(f"num_cols={num_cols} does not divide "
                         f"num_entries={plan.num_entries}")
    if not (0 <= n0 <= n1 <= plan.num_entries // num_cols):
        raise ValueError(f"bad sample range [{n0}, {n1}) for "
                         f"{plan.num_entries // num_cols} samples")
    keep = (sample_sorted >= n0) & (sample_sorted < n1)
    order = (sample_sorted[keep] - n0) * np.int64(num_cols) + slot_sorted[keep]
    return assemble_plan_from_sorted(
        row_ids[keep], order, num_rows=plan.num_rows,
        num_entries=(n1 - n0) * num_cols, num_cols=num_cols)


def shard_plan_grid(plan: TransposePlan, part: Partition, *, num_cols: int,
                    data_shards: int = 1,
                    shard_k: int | None = None,
                    k_multiple: int = 1) -> list[list[TransposePlan]]:
    """(data_shards x num_shards) grid of cell plans: restrict per data
    block, then slice per id range. ``shard_k`` must be the routed K when
    tensors were routed with an explicit/global one."""
    N = plan.num_entries // num_cols
    if N % data_shards:
        raise ValueError(f"data_shards={data_shards} does not divide "
                         f"N={N} samples")
    N_l = N // data_shards
    if shard_k is None:
        shard_k = default_shard_k(plan, part, N, k_multiple=k_multiple)
    return [
        slice_plan(restrict_plan(plan, b * N_l, (b + 1) * N_l,
                                 num_cols=num_cols),
                   part, num_cols=num_cols, shard_k=shard_k)
        for b in range(data_shards)
    ]


def _pad1(a: np.ndarray, size: int, fill: int) -> np.ndarray:
    if a.size == size:
        return a
    return np.concatenate([a, np.full(size - a.size, fill, a.dtype)])


def stack_plans(grid: list[list[TransposePlan]]) -> TransposePlan:
    """Stack a (Dd x S) grid of cell plans into one uniform plan.

    Every leaf gains leading (Dd, S) axes; ragged cell shapes are padded:

      * sorted entries to the max kept count — pad entries carry
        ``row_ids = num_rows - 1`` (each shard's zero pad row), sample 0,
        and an ``order`` aimed at an unkept slot of the routed grid
        (value 0 by the routing convention): they contribute exactly 0
        through every consumer — class gathers, the run-length kernel,
        ``dvals_planned`` — and a cell's ``rank`` zero-slot (position
        ``num_kept``) lands on one of them, which reads 0 as required;
      * popularity classes to the UNION of class widths with per-width
        max id counts — padded class rows are mask-0;
      * ``inv_compact`` is RECOMPUTED for the padded class-major layout
        (padding shifts compact row offsets); absent ids point at the
        appended zero row ``num_unique``.

    The stacked aux (num_rows/num_entries/num_kept/num_unique and the
    width union) is uniform across cells, which is what lets the whole
    plan ride through ``shard_map`` as one sharded pytree operand.
    """
    cells = [p for row in grid for p in row]
    if not cells:
        raise ValueError("empty plan grid")
    for p in cells:
        if p.num_kept > p.num_entries:
            raise ValueError("cell plan keeps more entries than its grid")
    Dd, S = len(grid), len(grid[0])
    if any(len(row) != S for row in grid):
        raise ValueError("ragged plan grid")
    num_rows = cells[0].num_rows
    num_entries = cells[0].num_entries
    if any(p.num_rows != num_rows or p.num_entries != num_entries
           for p in cells):
        raise ValueError("cell plans disagree on num_rows/num_entries — "
                         "route with a uniform shard_k")

    E_pad = max(p.num_kept for p in cells)
    widths = sorted({w for p in cells for w in p.class_width})
    u_max = {c: max((p.class_src[p.class_width.index(c)].shape[0] // c
                     if c in p.class_width else 0) for p in cells)
             for c in widths}
    U_stack = sum(u_max.values())
    base = {}
    off = 0
    for c in widths:
        base[c] = off
        off += u_max[c]

    row_ids, samp, slot, order, rank = [], [], [], [], []
    inv_compact, inv_sorted = [], []
    class_src = {c: [] for c in widths}
    class_samp = {c: [] for c in widths}
    class_mask = {c: [] for c in widths}
    for p in cells:
        r = np.asarray(p.row_ids, np.int32)
        o = np.asarray(p.order, np.int32)
        # padded sorted entries must be inert on EVERY scatter path, the
        # run-length kernel included: point their `order` at a flat slot
        # the cell does not keep — in a routed grid that is a pad slot
        # carrying value 0 (one exists whenever padding is needed, since
        # num_kept < E_pad <= num_entries), so the pad run accumulates
        # exact zeros and its flush lands them on the compact row absent
        # ids densify from
        if p.num_kept < E_pad:
            free = np.ones(num_entries, bool)
            free[o] = False
            pad_slot = int(np.flatnonzero(free)[0])
        else:
            pad_slot = 0  # no padding -> value never read
        row_ids.append(_pad1(r, E_pad, num_rows - 1))
        samp.append(_pad1(np.asarray(p.sample_sorted, np.int32), E_pad, 0))
        slot.append(_pad1(np.asarray(p.slot_sorted, np.int32), E_pad, 0))
        order.append(_pad1(o, E_pad, pad_slot))
        rank.append(np.asarray(p.rank, np.int32))
        inv_sorted.append(np.asarray(p.inv_sorted, np.int32))

        # padded class-major layout + matching inverse densification map
        uniq, counts = np.unique(r[: p.num_kept], return_counts=True)
        cls = np.ones_like(counts)
        if uniq.size:
            cls = np.where(counts <= 1, 1,
                           1 << np.ceil(np.log2(counts)).astype(np.int64))
        inv = np.full(num_rows, U_stack, np.int32)
        for c in widths:
            if c in p.class_width:
                j = p.class_width.index(c)
                src = np.asarray(p.class_src[j], np.int32)
                sp = np.asarray(p.class_samp[j], np.int32)
                mk = np.asarray(p.class_mask[j], np.int32)
            else:
                src = sp = mk = np.zeros(0, np.int32)
            size = u_max[c] * c
            class_src[c].append(_pad1(src, size, 0))
            class_samp[c].append(_pad1(sp, size, 0))
            class_mask[c].append(_pad1(mk, size, 0))
            sel = uniq[cls == c]
            inv[sel] = base[c] + np.arange(sel.size, dtype=np.int32)
        inv_compact.append(inv)

    import jax.numpy as jnp

    def stk(parts):
        return jnp.asarray(
            np.stack(parts).reshape((Dd, S) + parts[0].shape))

    return TransposePlan(
        class_src=[stk(class_src[c]) for c in widths],
        class_samp=[stk(class_samp[c]) for c in widths],
        class_mask=[stk(class_mask[c]) for c in widths],
        class_width=widths,
        row_ids=stk(row_ids), sample_sorted=stk(samp), slot_sorted=stk(slot),
        order=stk(order), rank=stk(rank),
        inv_compact=stk(inv_compact), inv_sorted=stk(inv_sorted),
        num_rows=num_rows, num_entries=num_entries, num_kept=E_pad,
        num_unique=U_stack)


def cell_plan(stacked: TransposePlan | None) -> TransposePlan | None:
    """Strip the leading (data, model) axes off a stacked plan — used
    INSIDE ``shard_map``, where each device's block has both leading
    dims of size 1."""
    if stacked is None:
        return None
    import jax

    return jax.tree.map(lambda a: a[0, 0], stacked)
