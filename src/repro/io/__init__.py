from repro.io.checkpoint import load, save  # noqa: F401
