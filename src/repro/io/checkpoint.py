"""Checkpointing substrate: flat-npz pytree save/restore.

Works for LS-PLM Theta, OWLQN state (incl. LBFGS history), transformer
param trees, and the streaming trainer's :class:`~repro.stream.trainer.
StreamState` (Theta + OWLQN+ history + day cursor — an interrupted
stream resumes exactly; python-scalar leaves such as the day cursor are
restored to python scalars, not 0-d arrays, see ``save_stream`` /
``load_stream``). Arrays are gathered to host (production note: on a
real pod each host writes its addressable shards; the npz format is the
CPU-sim stand-in for that)."""
from __future__ import annotations

import os

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(jax.device_get(tree))
    return out


def save(path: str, tree) -> str:
    """Write the flattened tree; returns the REAL path written.
    ``np.savez`` appends ``.npz`` to paths not already ending in it, so
    callers must print/reload the returned path, not their argument."""
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))
    return path if path.endswith(".npz") else path + ".npz"


def load(path: str, like):
    """Restore into the structure of `like` (same treedef). Leaves that
    are python scalars in `like` (static metadata like a stream's day
    cursor) come back as the same python type, so restored states are
    drop-in equal to what was saved — not 0-d arrays."""
    data = np.load(path)
    flat = dict(data.items())

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tree.items()}
        if hasattr(tree, "_fields"):
            return type(tree)(*(rebuild(getattr(tree, k), f"{prefix}{k}/")
                                for k in tree._fields))
        if isinstance(tree, (list, tuple)):
            return type(tree)(rebuild(v, f"{prefix}{i}/")
                              for i, v in enumerate(tree))
        key = prefix.rstrip("/")
        leaf = flat[key]
        if isinstance(tree, (bool, int, float)) and not isinstance(
                tree, np.ndarray):
            return type(tree)(leaf.item())
        want = getattr(tree, "shape", None)
        if want is not None and tuple(leaf.shape) != tuple(want):
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {tuple(leaf.shape)}, "
                f"expected {tuple(want)} — the checkpoint was saved under a "
                f"different configuration; refusing to restore silently")
        return leaf

    return rebuild(like)


def load_nested(path: str) -> dict:
    """Restore a checkpoint WITHOUT a ``like`` tree: the flat npz keys
    are split on ``/`` back into a nested dict of numpy leaves. List /
    tuple / NamedTuple structure is not recoverable this way (their
    positions come back as dict keys ``"0"``, ``"1"``, ...), so use
    :func:`load` when the exact treedef matters. This is the loader for
    SELF-DESCRIBING artifacts — e.g. ``repro.serve.load_artifact``
    rebuilds a pruned serving model from the field names alone."""
    data = np.load(path)
    out: dict = {}
    for key, leaf in data.items():
        node, parts = out, key.split("/")
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = leaf
    return out


def save_stream(path: str, stream_state) -> None:
    """Checkpoint a streaming trainer state (Theta + OWLQN+ history +
    day cursor). Plain :func:`save` — named for the call sites."""
    save(path, stream_state)


def load_stream(path: str, like):
    """Restore a streaming trainer state saved by :func:`save_stream`
    into the structure of ``like`` (e.g. ``StreamTrainer.init(theta0)``);
    the day cursor comes back as a python int so the resumed stream
    continues from exactly the next unconsumed day."""
    return load(path, like)
