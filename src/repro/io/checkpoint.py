"""Checkpointing substrate: flat-npz pytree save/restore.

Works for LS-PLM Theta, OWLQN state (incl. LBFGS history) and transformer
param trees. Arrays are gathered to host (production note: on a real pod
each host writes its addressable shards; the npz format is the CPU-sim
stand-in for that)."""
from __future__ import annotations

import os

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(jax.device_get(tree))
    return out


def save(path: str, tree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def load(path: str, like):
    """Restore into the structure of `like` (same treedef)."""
    data = np.load(path)
    flat = dict(data.items())

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tree.items()}
        if hasattr(tree, "_fields"):
            return type(tree)(*(rebuild(getattr(tree, k), f"{prefix}{k}/")
                                for k in tree._fields))
        if isinstance(tree, (list, tuple)):
            return type(tree)(rebuild(v, f"{prefix}{i}/")
                              for i, v in enumerate(tree))
        return flat[prefix.rstrip("/")]

    return rebuild(like)
