"""LS-PLM core: the paper's primary contribution in JAX."""
from repro.core.lsplm import (  # noqa: F401
    LSPLMConfig,
    LSPLMParams,
    foe_mixture_proba,
    init_params,
    params_from_theta,
    predict_logits_stable,
    predict_logits_stable_sparse,
    predict_proba,
    predict_proba_sparse,
)
from repro.core.objective import (  # noqa: F401
    CommonFeatureBatch,
    CTRBatch,
    is_sparse_batch,
    nll,
    nll_common_feature,
    nll_sparse,
    objective,
    smooth_loss_and_grad,
)
from repro.core.direction import (  # noqa: F401
    choose_orthant,
    descent_direction,
    directional_derivative,
    project_orthant,
)
