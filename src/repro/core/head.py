"""LS-PLM as a composable prediction head (beyond-paper integration).

``LSPLMHead`` attaches the paper's piecewise-linear mixture (Eq. 2) as a
classification / CTR head on top of ANY backbone embedding (e.g. the pooled
hidden state of one of the assigned transformer architectures). This is how
the paper's contribution is exposed as a first-class framework feature rather
than a standalone script.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lsplm import LSPLMParams, predict_logits_stable, predict_proba


def init_head(key: jax.Array, embed_dim: int, num_regions: int = 12, scale: float = 2e-2) -> LSPLMParams:
    ku, kw = jax.random.split(key)
    return LSPLMParams(
        u=scale * jax.random.normal(ku, (embed_dim, num_regions)),
        w=scale * jax.random.normal(kw, (embed_dim, num_regions)),
    )


def head_proba(params: LSPLMParams, h: jax.Array) -> jax.Array:
    """p(y=1 | h) for backbone features h (..., embed_dim)."""
    return predict_proba(params, h)


def head_nll(params: LSPLMParams, h: jax.Array, y: jax.Array) -> jax.Array:
    log_p1, log_p0 = predict_logits_stable(params, h)
    y = y.astype(log_p1.dtype)
    return -jnp.mean(y * log_p1 + (1.0 - y) * log_p0)
