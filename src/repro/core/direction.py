"""Eq. 8-10: descent direction for the non-convex non-smooth objective.

``descent_direction`` implements Proposition 2 (Eq. 9) — the bounded
direction minimising the directional derivative f'(Theta; d) of

    f = loss + lam*||Theta||_{2,1} + beta*||Theta||_1 .

With lam = 0 it reduces exactly to OWLQN's negative pseudo-gradient
(Andrew & Gao 2007), which tests assert.

Shapes: Theta and grad are (d, 2m); L2,1 rows are axis 0 groups.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 0.0  # exact zeros matter: sparsity is the point


def row_norm_keepdims(theta: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.sum(theta * theta, axis=-1, keepdims=True))


def descent_direction(
    theta: jax.Array, grad: jax.Array, lam: float, beta: float
) -> jax.Array:
    """The direction d of Eq. 9. grad = ∇loss(Theta) (smooth part only)."""
    g = -grad  # negative gradient of the smooth loss
    rn = row_norm_keepdims(theta)  # (d, 1)
    row_nonzero = rn > 0.0
    safe_rn = jnp.where(row_nonzero, rn, 1.0)

    # s = -∇loss - lam * Theta_ij / ||Theta_i.||   (only used when row != 0)
    s = g - lam * theta / safe_rn

    # case a: Theta_ij != 0
    d_a = s - beta * jnp.sign(theta)
    # case b: Theta_ij == 0 but row has support  -> soft-threshold s by beta
    d_b = jnp.maximum(jnp.abs(s) - beta, 0.0) * jnp.sign(s)
    # case c: whole row is zero -> v = softthresh(g, beta), group-shrink by lam
    v = jnp.maximum(jnp.abs(g) - beta, 0.0) * jnp.sign(g)
    vn = row_norm_keepdims(v)
    safe_vn = jnp.where(vn > 0.0, vn, 1.0)
    d_c = jnp.maximum(vn - lam, 0.0) / safe_vn * v

    elem_nonzero = theta != 0.0
    d = jnp.where(row_nonzero, jnp.where(elem_nonzero, d_a, d_b), d_c)
    return d


def project_orthant(theta: jax.Array, omega: jax.Array) -> jax.Array:
    """Eq. 8: pi_ij(Theta; Omega) — zero out entries whose sign disagrees."""
    return jnp.where(jnp.sign(theta) == jnp.sign(omega), theta, 0.0)


def choose_orthant(theta: jax.Array, d: jax.Array) -> jax.Array:
    """Eq. 10: xi = sign(Theta) where Theta != 0 else sign(d)."""
    return jnp.where(theta != 0.0, jnp.sign(theta), jnp.sign(d))


def directional_derivative(
    theta: jax.Array, grad: jax.Array, d: jax.Array, lam: float, beta: float
) -> jax.Array:
    """f'(Theta; d) in closed form (Lemma 1 / Appendix A, Eq. 15+18+19).

    Used by tests (checks d is a descent direction) and by the line search
    as the Armijo slope.
    """
    smooth = jnp.vdot(grad, d)
    rn = row_norm_keepdims(theta)[..., 0]  # (d,)
    row_nonzero = rn > 0.0
    safe_rn = jnp.where(row_nonzero, rn, 1.0)
    inner = jnp.sum(theta * d, axis=-1)  # Theta_i. . d_i.
    dnorm = jnp.sqrt(jnp.sum(d * d, axis=-1))
    l21_term = jnp.sum(jnp.where(row_nonzero, inner / safe_rn, dnorm))
    elem_nonzero = theta != 0.0
    l1_term = jnp.sum(
        jnp.where(elem_nonzero, jnp.sign(theta) * d, jnp.abs(d))
    )
    return smooth + lam * l21_term + beta * l1_term
