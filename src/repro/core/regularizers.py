"""Regularisers from Eq. 4: elementwise L1 and row-group L2,1.

The L2,1 group is a *feature row* of Theta (all 2m parameters owned by one
input feature): ||Theta||_{2,1} = sum_i sqrt(sum_j Theta_ij^2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def l1_norm(theta: jax.Array) -> jax.Array:
    return jnp.sum(jnp.abs(theta))


def row_norms(theta: jax.Array) -> jax.Array:
    """(d,) row L2 norms; rows are the feature-group axis 0."""
    return jnp.sqrt(jnp.sum(theta * theta, axis=tuple(range(1, theta.ndim))))


def l21_norm(theta: jax.Array) -> jax.Array:
    return jnp.sum(row_norms(theta))


def nonzero_count(theta: jax.Array, tol: float = 0.0) -> jax.Array:
    return jnp.sum(jnp.abs(theta) > tol)


def nonzero_feature_count(theta: jax.Array, tol: float = 0.0) -> jax.Array:
    """#features with any surviving parameter (Table 2's '#features')."""
    return jnp.sum(row_norms(theta) > tol)
