"""LS-PLM model (Gai et al. 2017, Eq. 1/2).

p(y=1|x) = g( sum_j  sigma(u_j^T x) * eta(w_j^T x) )

The common special case (Eq. 2) uses softmax dividing, sigmoid fitting and
g = identity; that is the production formulation and the default here.

Parameters are kept as a pytree ``LSPLMParams(u, w)`` with

    u : (d, m)  dividing ("router") weights
    w : (d, m)  fitting  ("expert") weights

i.e. Theta = concat([u, w], axis=1) in R^{d x 2m}: each *feature row* owns 2m
parameters, which is exactly the L2,1 group used by the paper's regulariser.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class LSPLMParams(NamedTuple):
    """Model parameters. Both leaves have shape (d, m)."""

    u: jax.Array
    w: jax.Array

    @property
    def theta(self) -> jax.Array:
        """The paper's Theta in R^{d x 2m} (feature-row major)."""
        return jnp.concatenate([self.u, self.w], axis=-1)


def params_from_theta(theta: jax.Array) -> LSPLMParams:
    m2 = theta.shape[-1]
    assert m2 % 2 == 0, "Theta last dim must be 2m"
    m = m2 // 2
    return LSPLMParams(u=theta[..., :m], w=theta[..., m:])


@dataclasses.dataclass(frozen=True)
class LSPLMConfig:
    num_features: int  # d
    num_regions: int = 12  # m, the paper's division number (Fig. 4: best 12)
    # generalised form hooks (Eq. 1). "softmax"/"sigmoid"/"identity".
    dividing: str = "softmax"
    fitting: str = "sigmoid"
    link: str = "identity"
    dtype: jnp.dtype = jnp.float32


def init_params(cfg: LSPLMConfig, key: jax.Array, scale: float = 1e-2) -> LSPLMParams:
    ku, kw = jax.random.split(key)
    shape = (cfg.num_features, cfg.num_regions)
    return LSPLMParams(
        u=(scale * jax.random.normal(ku, shape)).astype(cfg.dtype),
        w=(scale * jax.random.normal(kw, shape)).astype(cfg.dtype),
    )


def _dividing_fn(name: str) -> Callable[[jax.Array], jax.Array]:
    if name == "softmax":
        return partial(jax.nn.softmax, axis=-1)
    if name == "identity":
        return lambda z: z
    raise ValueError(f"unknown dividing fn {name!r}")


def _fitting_fn(name: str) -> Callable[[jax.Array], jax.Array]:
    if name == "sigmoid":
        return jax.nn.sigmoid
    if name == "identity":
        return lambda z: z
    raise ValueError(f"unknown fitting fn {name!r}")


def region_logits(params: LSPLMParams, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Return (x @ u, x @ w), each (..., m). The §3.2 hot spot."""
    return x @ params.u, x @ params.w


def predict_proba(
    params: LSPLMParams, x: jax.Array, cfg: LSPLMConfig | None = None
) -> jax.Array:
    """p(y=1|x) per Eq. 2 (or the generalised Eq. 1 via cfg). x: (..., d)."""
    zu, zw = region_logits(params, x)
    if cfg is None:
        gate = jax.nn.softmax(zu, axis=-1)
        fit = jax.nn.sigmoid(zw)
    else:
        gate = _dividing_fn(cfg.dividing)(zu)
        fit = _fitting_fn(cfg.fitting)(zw)
    p = jnp.sum(gate * fit, axis=-1)
    if cfg is not None and cfg.link != "identity":
        raise ValueError(f"unknown link {cfg.link!r}")
    return p


def predict_logits_stable(params: LSPLMParams, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Numerically-stable pieces for the NLL (Eq. 5).

    Returns (log_p1, log_p0) computed fully in log space:
        log p1 = logsumexp_i( log_softmax_i(zu) + log_sigmoid(zw_i) )
        log p0 = logsumexp_i( log_softmax_i(zu) + log_sigmoid(-zw_i) )
    This avoids log(0) for saturated sigmoids — essential with L1-driven
    large weights and for the optimizer's line search.
    """
    zu, zw = region_logits(params, x)
    log_gate = jax.nn.log_softmax(zu, axis=-1)
    log_p1 = jax.nn.logsumexp(log_gate + jax.nn.log_sigmoid(zw), axis=-1)
    log_p0 = jax.nn.logsumexp(log_gate + jax.nn.log_sigmoid(-zw), axis=-1)
    return log_p1, log_p0


def predict_proba_sparse(
    params: LSPLMParams, ids: jax.Array, vals: jax.Array, *,
    mode: str = "auto", plan=None
) -> jax.Array:
    """p(y=1|x) per Eq. 2 from padded-COO (ids, vals) — the production
    input format, served by the unified inference layer (``repro.serve``,
    fused sparse kernel underneath); ids use pad id == d. Pass ``plan``
    (``repro.data.sparse.build_transpose_plan``) when the call will be
    differentiated to keep the backward sort-free. Returns (N,)."""
    from repro.serve.score import score_sparse

    return score_sparse(params, ids, vals, mode=mode, plan=plan)


def predict_logits_stable_sparse(
    params: LSPLMParams, ids: jax.Array, vals: jax.Array, *,
    mode: str = "auto", plan=None
) -> tuple[jax.Array, jax.Array]:
    """Sparse analogue of ``predict_logits_stable``: (log_p1, log_p0)
    via the unified inference layer's region logits."""
    from repro.serve.score import score_sparse_logps

    return score_sparse_logps(params, ids, vals, mode=mode, plan=plan)


def foe_mixture_proba(params: LSPLMParams, x: jax.Array) -> jax.Array:
    """Eq. 3 (FOE / mixed-LR view): sum_i p(z=i|x) p(y=1|z=i,x).

    Identical to ``predict_proba`` by construction; kept as an explicit
    equivalence witness for tests.
    """
    zu, zw = region_logits(params, x)
    p_z = jax.nn.softmax(zu, axis=-1)  # p(z=i|x)
    p_y = jax.nn.sigmoid(zw)  # p(y=1|z=i,x)
    return jnp.einsum("...m,...m->...", p_z, p_y)
