"""Objective Eq. 4/5: f(Theta) = NLL + lambda*||Theta||_{2,1} + beta*||Theta||_1.

All functions operate on Theta as a single (d, 2m) array (the paper's
parameter layout; feature rows are L2,1 groups). The smooth part (NLL) is
differentiable everywhere; the regularisers are handled by the optimizer via
directional derivatives (Eq. 9), so ``smooth_loss_and_grad`` is what the
optimizer consumes.

Supports the common-feature trick (§3.2): when a batch carries
(x_common [G,d_c], session_id [B]) alongside x_noncommon [B,d_nc], the
common part of the dot products is computed once per session group and
gathered per sample (Eq. 13).

Sparse dispatch: batches shaped like ``repro.data.sparse.SparseCTRBatch``
(padded-COO ``user_ids``/``ad_ids`` id lists instead of dense x) are
detected structurally and routed to ``nll_sparse``, which runs on the
fused sparse kernel (``repro.kernels.lsplm_sparse_fused``) — Pallas
gather-matmul on TPU, chunked jnp elsewhere, scatter-add custom VJP.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import regularizers
from repro.core.lsplm import params_from_theta, predict_logits_stable
from repro.kernels.lsplm_sparse_fused.ops import (
    logps_from_z,
    pad_theta,
    sparse_gather_matmul,
)


class CTRBatch(NamedTuple):
    """A plain (uncompressed) batch."""

    x: jax.Array  # (B, d) dense or pre-embedded sparse features
    y: jax.Array  # (B,) in {0, 1}
    weight: jax.Array | None = None  # (B,) optional sample weights


class CommonFeatureBatch(NamedTuple):
    """Compressed batch per §3.2 (Eq. 13).

    Feature space is split: the first ``d_c`` feature columns are "common"
    (user features shared within one page-view session), the remaining
    ``d_nc`` are per-sample (ad features). x = [x_common ; x_noncommon].
    """

    x_common: jax.Array  # (G, d_c)   one row per session group
    x_noncommon: jax.Array  # (B, d_nc)
    session_id: jax.Array  # (B,) int in [0, G)
    y: jax.Array  # (B,)
    weight: jax.Array | None = None


def _nll_from_logps(log_p1, log_p0, y, weight):
    per = -(y * log_p1 + (1.0 - y) * log_p0)
    if weight is not None:
        per = per * weight
    return jnp.sum(per)


def nll(theta: jax.Array, batch: CTRBatch) -> jax.Array:
    """Eq. 5 — total (summed) negative log-likelihood."""
    params = params_from_theta(theta)
    log_p1, log_p0 = predict_logits_stable(params, batch.x)
    return _nll_from_logps(log_p1, log_p0, batch.y.astype(log_p1.dtype), batch.weight)


def nll_common_feature(theta: jax.Array, batch: CommonFeatureBatch) -> jax.Array:
    """Eq. 5 evaluated with the common-feature decomposition (Eq. 13).

    z = x @ Theta = x_c @ Theta_c  (once per group, gathered) + x_nc @ Theta_nc
    """
    d_c = batch.x_common.shape[-1]
    theta_c, theta_nc = theta[:d_c], theta[d_c:]
    z_c = batch.x_common @ theta_c  # (G, 2m) — computed ONCE per session
    z = z_c[batch.session_id] + batch.x_noncommon @ theta_nc  # (B, 2m)
    m = theta.shape[-1] // 2
    zu, zw = z[..., :m], z[..., m:]
    log_gate = jax.nn.log_softmax(zu, axis=-1)
    log_p1 = jax.nn.logsumexp(log_gate + jax.nn.log_sigmoid(zw), axis=-1)
    log_p0 = jax.nn.logsumexp(log_gate + jax.nn.log_sigmoid(-zw), axis=-1)
    return _nll_from_logps(log_p1, log_p0, batch.y.astype(log_p1.dtype), batch.weight)


def is_sparse_batch(batch) -> bool:
    """Structural check for a padded-COO sparse batch (SparseCTRBatch)."""
    return hasattr(batch, "ad_ids") and hasattr(batch, "user_ids")


def nll_sparse(theta: jax.Array, batch, *, mode: str = "auto") -> jax.Array:
    """Eq. 5 on padded-COO sparse features with the common-feature trick
    (Eq. 13): user region-logits once per session group, gathered per
    sample. Both gather-matmuls run on the fused sparse kernel, so the
    backward is the transposed scatter into active Theta rows only —
    sort-free when the batch carries precomputed transpose plans
    (``repro.data.sparse.build_batch_plans``), scan-chunked otherwise.
    """
    tp = pad_theta(theta)
    z_user = sparse_gather_matmul(batch.user_ids, batch.user_vals, tp,
                                  mode=mode,
                                  plan=getattr(batch, "user_plan", None))
    z_ad = sparse_gather_matmul(batch.ad_ids, batch.ad_vals, tp, mode=mode,
                                plan=getattr(batch, "ad_plan", None))
    z = z_user[batch.session_id] + z_ad
    log_p1, log_p0 = logps_from_z(z)
    return _nll_from_logps(log_p1, log_p0, batch.y.astype(log_p1.dtype), None)


def _nll_fn(batch, common_feature: bool):
    if is_sparse_batch(batch):
        return nll_sparse
    return nll_common_feature if common_feature else nll


def objective(
    theta: jax.Array, batch, lam: float, beta: float, *, common_feature: bool = False
) -> jax.Array:
    """f(Theta), Eq. 4. Used by tests and the line search. Dense,
    common-feature and sparse (padded-COO) batches all dispatch here."""
    loss = _nll_fn(batch, common_feature)(theta, batch)
    return loss + lam * regularizers.l21_norm(theta) + beta * regularizers.l1_norm(theta)


def smooth_loss_and_grad(theta: jax.Array, batch, *, common_feature: bool = False):
    """(loss(Theta), grad loss(Theta)) for the smooth NLL part only."""
    return jax.value_and_grad(_nll_fn(batch, common_feature))(theta, batch)
