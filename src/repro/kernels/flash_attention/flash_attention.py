"""Causal flash attention (forward) — pl.pallas_call + BlockSpec.

Online-softmax blocked attention: never materialises the (S, S) score
matrix, the requirement for prefill_32k (DESIGN.md §4). TPU mapping:

  * grid (B, H, S/BQ, S/BK); the KV axis is the minor (sequential) axis so
    the fp32 accumulator, running max m and running sum l persist in VMEM
    scratch across KV steps of one (b, h, q-block).
  * q/k/v tiles are (BQ, hd)/(BK, hd) VMEM blocks; matmuls hit the MXU
    with hd and BK multiples of 128 in production (tests sweep smaller
    shapes in interpret mode).
  * causal masking: KV blocks strictly above the diagonal contribute
    nothing; the diagonal block is masked elementwise. (A production
    variant would skip dead blocks via a skewed grid; on the straight
    grid they early-out on the mask.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_i, l_i, *,
            scale: float, block_q: int, block_k: int, n_k: int, causal: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    def _step():
        q = q_ref[0, 0].astype(jnp.float32)  # (BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (BK, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m_i[...], jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_i[...] - m_new)
        l_i[...] = l_i[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc[...] = acc[...] * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_i[...] = m_new

    if causal:
        # KV blocks strictly above the diagonal have no valid (q, k) pair
        pl.when(qi * block_q + block_q - 1 >= ki * block_k)(_step)
    else:
        _step()

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = jnp.where(l_i[...] == 0.0, 1.0, l_i[...])
        o_ref[0, 0] = (acc[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S, H, hd)  (GQA-repeated)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, hd = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    n_q, n_k = S // block_q, S // block_k
    scale = hd ** -0.5

    # layout (B, H, S, hd) so S tiles are contiguous per (b, h)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, n_k=n_k, causal=causal),
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        # jax >= 0.5 renamed TPUCompilerParams -> CompilerParams
        compiler_params=getattr(pltpu, "CompilerParams",
                                pltpu.TPUCompilerParams)(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
