"""Pure-jnp oracle: materialised-scores attention."""
import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q/k/v (B,S,H,hd) -> (B,S,H,hd), fp32 softmax."""
    B, S, H, hd = q.shape
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqs,bshd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
