"""Jit'd public wrapper with backend dispatch (TPU kernel / jnp chunked)."""
import jax

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref  # noqa: F401  (re-export)


def causal_attention(q, k, v, *, use_kernel: bool | None = None,
                     interpret: bool = False, block_q: int = 512,
                     block_k: int = 512):
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel or interpret:
        return flash_attention(q, k, v, causal=True, block_q=block_q,
                               block_k=block_k, interpret=interpret)
    from repro.models.layers import chunked_causal_attention
    return chunked_causal_attention(q, k, v)
