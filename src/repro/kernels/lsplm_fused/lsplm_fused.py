"""Fused LS-PLM forward kernel (Eq. 2) — pl.pallas_call + BlockSpec.

The paper's §3.2 hot spot is the pair of products u_i^T x and w_i^T x.
A naive implementation runs two matmuls (two HBM sweeps over x) and three
elementwise passes over the (B, m) intermediates. This kernel:

  * reads each x tile from HBM ONCE and contracts it against BOTH U and W
    (the dividing and fitting weights) in VMEM,
  * accumulates zu/zw in fp32 VMEM scratch across the d-tile grid axis,
  * applies softmax-dot-sigmoid fusion at the last d tile, writing only
    the (Bt,) probabilities back to HBM.

Grid: (B/BT, d/DT); d is the contraction axis (sequential, accumulating).
Tiles: x (BT, DT), u/w (DT, m), out p (BT, 1). m (regions) <= 128 assumed
(paper uses 12), so a (BT, m) accumulator tile is MXU/VPU friendly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, u_ref, w_ref, p_ref, zu_acc, zw_acc, *, n_dtiles: int):
    j = pl.program_id(1)  # d-tile index (sequential accumulation axis)

    @pl.when(j == 0)
    def _init():
        zu_acc[...] = jnp.zeros_like(zu_acc)
        zw_acc[...] = jnp.zeros_like(zw_acc)

    x = x_ref[...]
    zu_acc[...] += jnp.dot(x, u_ref[...], preferred_element_type=jnp.float32)
    zw_acc[...] += jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(j == n_dtiles - 1)
    def _finalize():
        zu = zu_acc[...]
        zw = zw_acc[...]
        gate = jax.nn.softmax(zu, axis=-1)
        fit = jax.nn.sigmoid(zw)
        p_ref[...] = jnp.sum(gate * fit, axis=-1, keepdims=True).astype(p_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_d", "interpret"))
def lsplm_fused_forward(
    x: jax.Array,  # (B, d)
    u: jax.Array,  # (d, m)
    w: jax.Array,  # (d, m)
    *,
    block_b: int = 256,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """p(y=1|x) per Eq. 2, fused. Returns (B,).

    Ragged shapes are zero-padded up to block multiples (pad rows/columns
    contribute nothing to either contraction) and the output sliced back,
    so real loaders' tail batches don't crash the kernel.
    """
    if block_b <= 0 or block_d <= 0:
        raise ValueError(f"block sizes must be positive, got ({block_b}, {block_d})")
    B, d = x.shape
    m = u.shape[1]
    if u.shape != w.shape or u.shape[0] != d:
        raise ValueError(f"u/w must be ({d}, m), got {u.shape}/{w.shape}")
    block_b = min(block_b, B)
    block_d = min(block_d, d)
    b_pad = pl.cdiv(B, block_b) * block_b
    d_pad = pl.cdiv(d, block_d) * block_d
    if b_pad != B or d_pad != d:
        x = jnp.pad(x, ((0, b_pad - B), (0, d_pad - d)))
        u = jnp.pad(u, ((0, d_pad - d), (0, 0)))
        w = jnp.pad(w, ((0, d_pad - d), (0, 0)))
    n_dtiles = d_pad // block_d
    grid = (b_pad // block_b, n_dtiles)

    out = pl.pallas_call(
        functools.partial(_kernel, n_dtiles=n_dtiles),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((block_d, m), lambda i, j: (j, 0)),
            pl.BlockSpec((block_d, m), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_pad, 1), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_b, m), jnp.float32),
            pltpu.VMEM((block_b, m), jnp.float32),
        ],
        interpret=interpret,
    )(x, u, w)
    return out[:B, 0]
