"""Pure-jnp oracle for the fused LS-PLM forward kernel."""
import jax
import jax.numpy as jnp


def lsplm_forward_ref(x: jax.Array, u: jax.Array, w: jax.Array) -> jax.Array:
    """Eq. 2: sum_i softmax_i(xU) sigmoid(xW_i). x (B,d) -> (B,)."""
    zu = jnp.dot(x, u, preferred_element_type=jnp.float32)
    zw = jnp.dot(x, w, preferred_element_type=jnp.float32)
    gate = jax.nn.softmax(zu, axis=-1)
    fit = jax.nn.sigmoid(zw)
    return jnp.sum(gate * fit, axis=-1).astype(x.dtype)
