"""Jit'd public wrapper: TPU Pallas kernel with jnp fallback."""
import jax

from repro.kernels.lsplm_fused.lsplm_fused import lsplm_fused_forward
from repro.kernels.lsplm_fused.ref import lsplm_forward_ref


def lsplm_forward(x, u, w, *, block_b: int = 256, block_d: int = 512,
                  use_kernel: bool | None = None, interpret: bool = False):
    """p(y=1|x) (B,). Uses the Pallas kernel on TPU (or interpret mode),
    jnp reference elsewhere."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel or interpret:
        return lsplm_fused_forward(x, u, w, block_b=block_b, block_d=block_d,
                                   interpret=interpret)
    return lsplm_forward_ref(x, u, w)
