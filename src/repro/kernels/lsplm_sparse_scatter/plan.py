"""Transpose plan — the precomputed CSR-style layout for the sparse backward.

The backward of z = x @ Theta on padded COO is the transposed scatter

    dTheta[r] = sum_{(n,k): ids[n,k]=r} vals[n,k] * dz[n]

Scattering E = N*K rows into a (D, 2m) table is the training hot spot:
XLA lowers ``.at[].add`` to a serial per-update loop (CPU) or a sorted
scatter (TPU), and it re-derives the id->entries mapping EVERY step even
though full-batch OWLQN+ feeds the same batch every iteration. The
transpose plan hoists all data-dependent index computation out of the
step: it is built ONCE per batch on the host (numpy) and the step then
runs only dense gathers, reshapes and reductions — no sort, no scatter.

Layout (all device leaves int32; static sizes in the pytree aux data):

  * ``order``/``row_ids``/``sample_sorted``/``slot_sorted`` — the E' kept
    entries (pad-id entries dropped) sorted by column id: a COO->CSC
    transposition recorded as a permutation.
  * ``classes`` — the segment-sum schedule. Unique ids are bucketed by
    popularity: class c holds ids whose entry count is in (c/2, c]
    (power-of-two widths), each padded to exactly c slots. A class is a
    dense (uc, c) gather table into the sorted entries, so its segment
    sums are one gather + reshape + ``sum(axis=1)`` — vectorisable
    everywhere, race-free by construction, and ≤2x padding waste even
    for Zipf-hot traffic (real CTR id distributions).
  * ``inv_compact`` — (D,) map from column id to its row in the compact
    per-unique-id result (U for untouched ids, which points at an
    appended zero row), turning the final densification into one plain
    gather instead of a scatter. ``inv_sorted`` is the same map for
    results in sorted-unique order — the layout the Pallas run-length
    kernel emits (classes reorder ids by popularity; the kernel walks
    them in id order).
  * ``rank`` — original entry -> sorted position (E'-pointing for
    dropped pad entries), so dvals comes back in (N, K) order with a
    gather as well.

The same plan drives the jnp segment-sum path (`ops.scatter_add_planned`),
the Pallas run-accumulate kernel (`lsplm_sparse_scatter.py`) and the
fused forward/backward custom VJPs in ``lsplm_sparse_fused.ops``.

Shapes in the plan are data-dependent (U, E' and the class split change
with the batch), so jitted consumers recompile when the batch changes.
That is the intended trade: the paper's OWLQN+ is full-batch — one batch,
hundreds of iterations — and streaming variants re-plan per day, not per
step.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
class TransposePlan:
    """Precomputed id->entries transposition of a padded-COO batch.

    Device arrays are all int32 (so custom-VJP cotangents are uniformly
    ``float0``); every size that determines an output shape is static
    python metadata carried in the pytree aux data.
    """

    def __init__(self, *, class_src, class_samp, class_mask, class_width,
                 row_ids, sample_sorted, slot_sorted, order, rank,
                 inv_compact, inv_sorted, num_rows: int, num_entries: int,
                 num_kept: int, num_unique: int):
        self.class_src = tuple(class_src)     # per class: (uc*c,) into entries
        self.class_samp = tuple(class_samp)   # per class: (uc*c,) sample index
        self.class_mask = tuple(class_mask)   # per class: (uc*c,) 0/1 pad mask
        self.class_width = tuple(int(c) for c in class_width)
        self.row_ids = row_ids                # (E',) sorted column ids
        self.sample_sorted = sample_sorted    # (E',) entry -> sample n
        self.slot_sorted = slot_sorted        # (E',) entry -> slot k
        self.order = order                    # (E',) sorted pos -> flat entry
        self.rank = rank                      # (N*K,) flat entry -> sorted pos
        self.inv_compact = inv_compact        # (D,) id -> compact row (U: zero)
        self.inv_sorted = inv_sorted          # (D,) id -> sorted-unique row
        self.num_rows = int(num_rows)         # D (padded Theta rows)
        self.num_entries = int(num_entries)   # N*K
        self.num_kept = int(num_kept)         # E' after pad-id drop
        self.num_unique = int(num_unique)     # U distinct non-pad ids

    def tree_flatten(self):
        children = (self.class_src, self.class_samp, self.class_mask,
                    self.row_ids, self.sample_sorted, self.slot_sorted,
                    self.order, self.rank, self.inv_compact, self.inv_sorted)
        aux = (self.class_width, self.num_rows, self.num_entries,
               self.num_kept, self.num_unique)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        (class_src, class_samp, class_mask, row_ids, sample_sorted,
         slot_sorted, order, rank, inv_compact, inv_sorted) = children
        class_width, num_rows, num_entries, num_kept, num_unique = aux
        return cls(class_src=class_src, class_samp=class_samp,
                   class_mask=class_mask, class_width=class_width,
                   row_ids=row_ids, sample_sorted=sample_sorted,
                   slot_sorted=slot_sorted, order=order, rank=rank,
                   inv_compact=inv_compact, inv_sorted=inv_sorted,
                   num_rows=num_rows, num_entries=num_entries,
                   num_kept=num_kept, num_unique=num_unique)

    def validate(self, ids_shape: tuple, theta_rows: int) -> None:
        n, k = ids_shape
        if n * k != self.num_entries:
            raise ValueError(
                f"plan was built for {self.num_entries} entries, batch has "
                f"{n}x{k}={n * k}")
        if theta_rows != self.num_rows:
            raise ValueError(
                f"plan was built for {self.num_rows} Theta rows, got "
                f"{theta_rows}")


def assemble_plan_from_sorted(
    srt: np.ndarray,
    order: np.ndarray,
    *,
    num_rows: int,
    num_entries: int,
    num_cols: int,
) -> TransposePlan:
    """Assemble a :class:`TransposePlan` from already-sorted entries.

    The data-dependent SORT is the only part of plan construction that is
    expensive; everything after it (popularity classes, inverse maps,
    rank) is determined by the sorted layout alone. Factoring it out lets
    ``repro.shard.plan_slicing`` slice a full-batch plan at id-range /
    sample-range boundaries and rebuild shard-local plans that are
    bit-identical to ``build_transpose_plan`` on the shard-local ids —
    WITHOUT re-sorting them.

    Args:
      srt: (E',) kept column ids sorted ascending (stable w.r.t. flat
        entry order within equal ids).
      order: (E',) sorted position -> flat entry index in the (N, K)
        grid the plan addresses (``num_entries == N * num_cols``).
      num_rows: D, rows of the padded Theta the plan addresses.
      num_entries: N * K of the addressed ids grid.
      num_cols: K of the addressed ids grid (recovers n = order // K).
    """
    srt = np.asarray(srt, np.int64)
    order = np.asarray(order, np.int64)
    E_kept = int(srt.size)
    K = int(num_cols)
    E = int(num_entries)

    uniq, counts = np.unique(srt, return_counts=True)
    U = int(uniq.size)
    ptr = np.concatenate([[0], np.cumsum(counts)]) if U else np.zeros(1, np.int64)

    # popularity classes: width c = 2^ceil(log2(count)), ids padded to c
    cls = np.ones_like(counts)
    if U:
        cls = np.where(
            counts <= 1, 1,
            1 << np.ceil(np.log2(counts)).astype(np.int64))
    class_src, class_samp, class_mask, class_width = [], [], [], []
    dest_parts = []
    for c in np.unique(cls):
        sel = np.nonzero(cls == c)[0]
        cnts = counts[sel]
        js = np.arange(int(c))
        pos = ptr[sel][:, None] + js[None, :]          # sorted positions
        valid = js[None, :] < cnts[:, None]
        pos = np.where(valid, pos, 0)
        src = order[pos]                               # original entries
        class_src.append(jnp.asarray(src.reshape(-1).astype(np.int32)))
        class_samp.append(jnp.asarray((src.reshape(-1) // K).astype(np.int32)))
        class_mask.append(jnp.asarray(valid.reshape(-1).astype(np.int32)))
        class_width.append(int(c))
        dest_parts.append(sel)

    # compact row order == class-major order of unique ids
    inv_compact = np.full(num_rows, U, np.int64)       # U -> appended zero row
    if dest_parts:
        dest = np.concatenate(dest_parts)          # compact row -> unique idx
        compact_pos = np.empty(U, np.int64)
        compact_pos[dest] = np.arange(U)           # unique idx -> compact row
        inv_compact[uniq] = compact_pos

    inv_sorted = np.full(num_rows, U, np.int64)        # U -> appended zero row
    inv_sorted[uniq] = np.arange(U)

    rank = np.full(E, E_kept, np.int64)                # dropped -> zero slot
    rank[order] = np.arange(E_kept)

    return TransposePlan(
        class_src=class_src, class_samp=class_samp, class_mask=class_mask,
        class_width=class_width,
        row_ids=jnp.asarray(srt.astype(np.int32)),
        sample_sorted=jnp.asarray((order // K).astype(np.int32)),
        slot_sorted=jnp.asarray((order % K).astype(np.int32)),
        order=jnp.asarray(order.astype(np.int32)),
        rank=jnp.asarray(rank.astype(np.int32)),
        inv_compact=jnp.asarray(inv_compact.astype(np.int32)),
        inv_sorted=jnp.asarray(inv_sorted.astype(np.int32)),
        num_rows=int(num_rows), num_entries=E, num_kept=E_kept,
        num_unique=U)


def build_transpose_plan(
    ids: Any,
    num_rows: int,
    *,
    pad_id: int | None = None,
) -> TransposePlan:
    """Build the per-batch transpose plan on the host (numpy, no jit).

    Args:
      ids: (N, K) int column ids of the padded-COO batch.
      num_rows: D, the number of rows of the PADDED Theta the batch will
        be contracted against (``d + 1`` with the zero pad row appended).
      pad_id: if given, entries with this id are dropped from the plan —
        their values are 0 by the padded-COO convention, so they
        contribute nothing and hot pad slots stop costing segment work.
        The pad row's cotangent is exactly 0 either way.

    Cost: one argsort + unique over N*K int32 — tens of ms at production
    batch sizes, paid once per batch (not per optimizer step).
    """
    ids = np.asarray(ids)
    if ids.ndim != 2:
        raise ValueError(f"ids must be (N, K), got {ids.shape}")
    N, K = ids.shape
    E = N * K
    flat = ids.reshape(-1).astype(np.int64)
    if flat.size and (flat.min() < 0 or flat.max() >= num_rows):
        raise ValueError(
            f"ids out of range [0, {num_rows}): [{flat.min()}, {flat.max()}]")

    keep_flat = np.arange(E, dtype=np.int64)
    if pad_id is not None:
        keep_flat = keep_flat[flat != pad_id]
    kept_ids = flat[keep_flat]
    order_kept = np.argsort(kept_ids, kind="stable")
    order = keep_flat[order_kept]            # sorted pos -> original entry
    srt = kept_ids[order_kept]               # sorted column ids

    return assemble_plan_from_sorted(
        srt, order, num_rows=num_rows, num_entries=E, num_cols=K)
