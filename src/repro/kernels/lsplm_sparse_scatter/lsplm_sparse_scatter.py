"""Pallas scatter kernel for the sparse LS-PLM backward (dTheta).

Consumes the transpose plan (`plan.py`): entries pre-sorted by column id,
so the scatter degenerates into a RUN-LENGTH SEGMENT SUM — walk the
sorted entries once, accumulate ``vals[e] * dz[sample[e]]`` into a VMEM
accumulator while the id stays the same, and flush the accumulator to
the next compact output row when it changes. No sort inside the step, no
read-modify-write on HBM (each compact row is written exactly once), and
no cross-program races: the grid is sequential on TPU and the
accumulator/cursor live in scratch, which persists across grid steps.

The kernel emits the COMPACT (U+1, 2m) result — one row per distinct id
in plan order plus a trailing zero row — and the caller densifies it
with the plan's ``inv_compact`` gather. That keeps the kernel free of
(D, 2m) traffic entirely: HBM cost is O(U) writes, not O(D).

Scalar-prefetched operands (``row_ids``, ``sample_sorted``) live in SMEM
so the flush target and the dz row index are known without touching
VMEM. dz rides in VMEM whole: (N, 2m) fp32 is ~3 MB at N=32k, m=12 —
well under budget; for larger batches slice the batch before planning.

The plan pads the sorted entries with at least one trailing sentinel
(id == num_rows, never a real id): the sentinel both triggers the final
flush of the last real run and absorbs the tail of the last grid block.

CI exercises this kernel in interpret mode; the compiled Mosaic path
follows the same sequential-grid contract (see the package README note
in ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(row_ids_ref, sample_ref, vals_ref, dz_ref, out_ref,
            acc, cursor, sem, *, block_e: int, num_kept: int, total: int):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        cursor[0] = row_ids_ref[0]   # id of the first run
        cursor[1] = 0                # next compact row to write

    def entry(e, carry):
        gid = pid * block_e + e
        rid = row_ids_ref[gid]

        @pl.when(rid != cursor[0])
        def _flush():
            pltpu.make_async_copy(acc.at[0], out_ref.at[cursor[1]], sem).start()
            pltpu.make_async_copy(acc.at[0], out_ref.at[cursor[1]], sem).wait()
            acc[...] = jnp.zeros_like(acc)
            cursor[0] = rid
            cursor[1] = cursor[1] + 1

        @pl.when(gid < num_kept)
        def _accumulate():
            n = sample_ref[gid]
            acc[0, :] = acc[0, :] + vals_ref[e].astype(jnp.float32) * dz_ref[n, :]

        # last entry overall: the sentinel tail flushed the final real run
        # above and accumulated nothing since, so acc is zero — write it to
        # the trailing zero row that inv_sorted points untouched ids at.
        @pl.when(gid == total - 1)
        def _zero_row():
            pltpu.make_async_copy(acc.at[0], out_ref.at[cursor[1]], sem).start()
            pltpu.make_async_copy(acc.at[0], out_ref.at[cursor[1]], sem).wait()

        return carry

    jax.lax.fori_loop(0, block_e, entry, 0)


@functools.partial(jax.jit, static_argnames=("num_unique", "num_kept",
                                             "block_e", "interpret"))
def lsplm_sparse_scatter_compact(
    row_ids: jax.Array,        # (E_pad,) int32 sorted ids + sentinel tail
    sample_sorted: jax.Array,  # (E_pad,) int32 entry -> sample
    vals_sorted: jax.Array,    # (E_pad,) f32 entry values (0 on sentinels)
    dz: jax.Array,             # (N, 2m) f32 upstream cotangent
    *,
    num_unique: int,
    num_kept: int,
    block_e: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """Segment-sum sorted entries into the compact (U+1, 2m) result.

    The inputs must come from ``ops.pad_plan_entries`` (sentinel-padded to
    a block multiple). Returns compact rows in plan order with a trailing
    zero row; densify with ``compact[plan.inv_compact]``.
    """
    E_pad = row_ids.shape[0]
    if E_pad % block_e:
        raise ValueError(f"E_pad={E_pad} not a multiple of block_e={block_e}")
    N, m2 = dz.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(E_pad // block_e,),
        in_specs=[
            pl.BlockSpec((block_e,), lambda i, *_: (i,)),
            pl.BlockSpec((N, m2), lambda i, *_: (0, 0)),  # dz whole, VMEM
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        scratch_shapes=[
            pltpu.VMEM((1, m2), jnp.float32),
            pltpu.SMEM((2,), jnp.int32),
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, block_e=block_e, num_kept=num_kept,
                          total=E_pad),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_unique + 1, m2), jnp.float32),
        interpret=interpret,
    )(row_ids, sample_sorted, vals_sorted, dz.astype(jnp.float32))
