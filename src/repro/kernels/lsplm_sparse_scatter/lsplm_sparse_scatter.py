"""Pallas scatter kernel for the sparse LS-PLM backward (dTheta).

Consumes the transpose plan (`plan.py`): entries pre-sorted by column id,
so the scatter degenerates into a RUN-LENGTH SEGMENT SUM — walk the
sorted entries once, accumulate ``vals[e] * dz[sample[e]]`` into a VMEM
accumulator while the id stays the same, and flush the accumulator to
the next compact output row when it changes. No sort inside the step, no
read-modify-write on HBM (each compact row is written exactly once), and
no cross-program races: the grid is sequential on TPU and the
accumulator/cursor live in scratch, which persists across grid steps.

Flushes are PIPELINED: the accumulator is double-buffered (two VMEM
slots, one DMA semaphore each). A flush starts the active slot's copy to
its compact row and immediately switches accumulation to the other slot
— so flush t's HBM write overlaps run t+1's accumulate stream instead of
stalling it (the old kernel start()+wait()ed every flush inline). A
slot's outstanding copy is drained only when that slot is about to be
reused (the NEXT flush), or at the sentinel tail; the in-flight flag and
destination row ride in the SMEM cursor so the matching copy descriptor
can be rebuilt for the deferred wait.

The kernel emits the COMPACT (U+1, 2m) result — one row per distinct id
in plan order plus a trailing zero row — and the caller densifies it
with the plan's ``inv_compact`` gather. That keeps the kernel free of
(D, 2m) traffic entirely: HBM cost is O(U) writes, not O(D).

Scalar-prefetched operands (``row_ids``, ``sample_sorted``) live in SMEM
so the flush target and the dz row index are known without touching
VMEM. dz rides in VMEM whole: (N, 2m) fp32 is ~3 MB at N=32k, m=12 —
well under budget; for larger batches slice the batch before planning.

The plan pads the sorted entries with at least one trailing sentinel
(id == num_rows, never a real id): the sentinel both triggers the final
flush of the last real run and absorbs the tail of the last grid block.

CI exercises this kernel in interpret mode; the compiled Mosaic path
follows the same sequential-grid contract (see the package README note
in ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(row_ids_ref, sample_ref, vals_ref, dz_ref, out_ref,
            acc, cursor, sems, *, block_e: int, num_kept: int, total: int):
    # SMEM cursor layout (persists across sequential grid steps):
    #   [0] id of the current run          [1] next compact row to write
    #   [2] active accumulator slot        [3+s] slot s copy in flight?
    #   [5+s] slot s in-flight destination row
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        cursor[0] = row_ids_ref[0]   # id of the first run
        for i in range(1, 7):
            cursor[i] = 0

    def drain(slot):
        # deferred wait: rebuild slot's outstanding copy descriptor from
        # the tracked destination row and settle its semaphore
        @pl.when(cursor[3 + slot] == 1)
        def _():
            pltpu.make_async_copy(
                acc.at[slot], out_ref.at[cursor[5 + slot]],
                sems.at[slot]).wait()
            cursor[3 + slot] = 0

    def entry(e, carry):
        gid = pid * block_e + e
        rid = row_ids_ref[gid]

        @pl.when(rid != cursor[0])
        def _flush():
            slot = cursor[2]
            other = 1 - slot
            drain(other)  # the slot we are about to accumulate into
            copy = pltpu.make_async_copy(
                acc.at[slot], out_ref.at[cursor[1]], sems.at[slot])
            copy.start()  # overlaps the next run's accumulation below
            cursor[3 + slot] = 1
            cursor[5 + slot] = cursor[1]
            acc[other, :] = jnp.zeros_like(acc[other, :])
            cursor[0] = rid
            cursor[1] = cursor[1] + 1
            cursor[2] = other

        @pl.when(gid < num_kept)
        def _accumulate():
            n = sample_ref[gid]
            s = cursor[2]
            acc[s, :] = acc[s, :] + vals_ref[e].astype(jnp.float32) * dz_ref[n, :]

        # last entry overall: the sentinel tail flushed the final real run
        # above and accumulated nothing since, so the active slot is zero —
        # write it to the trailing zero row that inv_sorted points untouched
        # ids at, after draining the other slot (nothing may stay in flight
        # past kernel end).
        @pl.when(gid == total - 1)
        def _zero_row():
            slot = cursor[2]
            drain(1 - slot)
            copy = pltpu.make_async_copy(
                acc.at[slot], out_ref.at[cursor[1]], sems.at[slot])
            copy.start()
            copy.wait()

        return carry

    jax.lax.fori_loop(0, block_e, entry, 0)


@functools.partial(jax.jit, static_argnames=("num_unique", "num_kept",
                                             "block_e", "interpret"))
def lsplm_sparse_scatter_compact(
    row_ids: jax.Array,        # (E_pad,) int32 sorted ids + sentinel tail
    sample_sorted: jax.Array,  # (E_pad,) int32 entry -> sample
    vals_sorted: jax.Array,    # (E_pad,) f32 entry values (0 on sentinels)
    dz: jax.Array,             # (N, 2m) f32 upstream cotangent
    *,
    num_unique: int,
    num_kept: int,
    block_e: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """Segment-sum sorted entries into the compact (U+1, 2m) result.

    The inputs must come from ``ops.pad_plan_entries`` (sentinel-padded to
    a block multiple). Returns compact rows in plan order with a trailing
    zero row; densify with ``compact[plan.inv_compact]``.
    """
    E_pad = row_ids.shape[0]
    if E_pad % block_e:
        raise ValueError(f"E_pad={E_pad} not a multiple of block_e={block_e}")
    N, m2 = dz.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(E_pad // block_e,),
        in_specs=[
            pl.BlockSpec((block_e,), lambda i, *_: (i,)),
            pl.BlockSpec((N, m2), lambda i, *_: (0, 0)),  # dz whole, VMEM
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        scratch_shapes=[
            pltpu.VMEM((2, m2), jnp.float32),   # double-buffered accumulator
            pltpu.SMEM((7,), jnp.int32),        # run/row/slot/in-flight cursor
            pltpu.SemaphoreType.DMA((2,)),      # one per accumulator slot
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, block_e=block_e, num_kept=num_kept,
                          total=E_pad),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_unique + 1, m2), jnp.float32),
        interpret=interpret,
    )(row_ids, sample_sorted, vals_sorted, dz.astype(jnp.float32))
