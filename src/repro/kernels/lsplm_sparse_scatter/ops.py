"""Plan-driven scatter-add ops — the sparse LS-PLM backward engine.

Public surface:

  * ``scatter_add_planned(plan, vals, dz, *, mode)`` -> dTheta (D, 2m):
    the transposed scatter as pure gathers + segment reductions driven by
    a precomputed :class:`~.plan.TransposePlan`. No sort, no XLA scatter,
    no data-dependent work inside the step.
  * ``dvals_planned(plan, theta, dz, shape)`` -> dvals (N, K): the gather
    half of the backward, read through the plan's sorted layout so the
    Theta row reads are id-ordered (cache/DMA friendly: duplicate ids
    are adjacent instead of strewn across the batch).
  * ``scatter_add_ref(ids, vals, dz, num_rows)``: the direct ``.at[].add``
    oracle the tests and benchmarks compare against.

``mode`` mirrors the fused-forward dispatch:
    "auto"      Pallas run-length kernel on TPU, class-gather jnp elsewhere
    "kernel"    force the compiled Pallas kernel
    "interpret" force the Pallas kernel in interpret mode (tests/CI)
    "jnp"       force the class-gather jnp path

jnp path mechanics: for each popularity class the plan provides a dense
(uc*c,) gather table into the batch entries; the class's per-id sums are

    (vals[src] * mask)[:, None] * dz[samp]  ->  reshape(uc, c, 2m).sum(1)

— one fused gather-multiply-reduce per class, every index known to be in
bounds (``promise_in_bounds``), so XLA emits no clamps, no sorts and no
serial scatter loop. The class results concatenate into a compact
(U+1, 2m) table (trailing zero row) and densify with one plain gather
through ``plan.inv_compact``. This is what makes the planned backward
>=2x faster than the chunked ``.at[].add`` scatter on CPU at production
sparsity (see ``benchmarks/bench_sparse_fused.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.lsplm_sparse_scatter.lsplm_sparse_scatter import (
    lsplm_sparse_scatter_compact,
)
from repro.kernels.lsplm_sparse_scatter.plan import (  # noqa: F401  (re-export)
    TransposePlan,
    build_transpose_plan,
)
from repro.tune import table as tune

_SCATTER_BLOCK_E = 1024  # builtin default entry block (autotune table wins)


def _take(a: jax.Array, idx: jax.Array, *, unique: bool = False) -> jax.Array:
    """Gather with plan-guaranteed in-bounds indices (no clamp codegen)."""
    return a.at[idx].get(mode="promise_in_bounds", unique_indices=unique)


def _use_kernel(mode: str) -> bool:
    if mode == "auto":
        return jax.default_backend() == "tpu"
    if mode in ("kernel", "interpret"):
        return True
    if mode == "jnp":
        return False
    raise ValueError(f"unknown mode {mode!r}")


def scatter_add_ref(ids: jax.Array, vals: jax.Array, dz: jax.Array,
                    num_rows: int) -> jax.Array:
    """Oracle: dTheta[r] = sum_{ids[n,k]=r} vals[n,k] * dz[n] (direct)."""
    m2 = dz.shape[-1]
    data = (vals.astype(jnp.float32)[..., None]
            * dz.astype(jnp.float32)[:, None, :]).reshape(-1, m2)
    return jnp.zeros((num_rows, m2), jnp.float32).at[ids.reshape(-1)].add(data)


def _compact_classes(plan: TransposePlan, vals: jax.Array,
                     dz: jax.Array) -> jax.Array:
    """Class-gather segment sums -> compact (U+1, 2m), class-major order."""
    m2 = dz.shape[-1]
    vflat = vals.reshape(-1).astype(jnp.float32)
    dz = dz.astype(jnp.float32)
    outs = []
    for src, samp, mask, width in zip(plan.class_src, plan.class_samp,
                                      plan.class_mask, plan.class_width):
        v = _take(vflat, src) * mask.astype(jnp.float32)
        rows = (v[:, None] * _take(dz, samp)).reshape(-1, width, m2)
        outs.append(rows.sum(axis=1))
    outs.append(jnp.zeros((1, m2), jnp.float32))
    return jnp.concatenate(outs, axis=0)


def scatter_add_planned(
    plan: TransposePlan,
    vals: jax.Array,   # (N, K)
    dz: jax.Array,     # (N, 2m)
    *,
    mode: str = "auto",
    block_e: int | None = None,
) -> jax.Array:
    """dTheta (D, 2m) from the precomputed transpose plan. Race-free by
    construction: every output row is produced by exactly one segment.
    ``block_e=None`` resolves from the autotune table (``repro.tune``)
    by the (entry-count, 2m) envelope; an explicit value wins."""
    if _use_kernel(mode):
        if block_e is None:
            env = tune.scatter_envelope(plan.num_kept, dz.shape[-1])
            block_e = tune.resolve("scatter", env, mode=mode)["block_e"]
        row_ids, sample_sorted, vals_sorted = pad_plan_entries(
            plan, vals, block_e=block_e)
        compact = lsplm_sparse_scatter_compact(
            row_ids, sample_sorted, vals_sorted, dz,
            num_unique=plan.num_unique, num_kept=plan.num_kept,
            block_e=block_e, interpret=mode == "interpret")
        return _take(compact, plan.inv_sorted, unique=False)
    compact = _compact_classes(plan, vals, dz)
    return _take(compact, plan.inv_compact, unique=False)


def dvals_planned(
    plan: TransposePlan,
    theta: jax.Array,  # (D, 2m)
    dz: jax.Array,     # (N, 2m)
    shape: tuple[int, int],
) -> jax.Array:
    """dvals[n,k] = theta[ids[n,k]] . dz[n] via the sorted layout.

    The Theta gather runs in id order (duplicates adjacent — the hot-id
    rows are read once per cache line instead of once per occurrence)
    and the result is permuted back to (N, K) with one gather; dropped
    pad entries land on the appended zero slot.
    """
    rows = _take(theta.astype(jnp.float32), plan.row_ids)
    dv_sorted = (rows * _take(dz.astype(jnp.float32),
                              plan.sample_sorted)).sum(axis=-1)
    dv_sorted = jnp.concatenate([dv_sorted, jnp.zeros((1,), jnp.float32)])
    return _take(dv_sorted, plan.rank).reshape(shape)


def pad_plan_entries(
    plan: TransposePlan,
    vals: jax.Array,
    *,
    block_e: int = _SCATTER_BLOCK_E,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Sentinel-pad the plan's sorted entries for the Pallas kernel.

    Appends >=1 sentinel entry (id == num_rows — larger than any real id,
    so it terminates the last run) and rounds up to a ``block_e``
    multiple. Returns (row_ids, sample_sorted, vals_sorted), each
    (E_pad,); sentinel slots carry sample 0 and value 0.
    """
    e = plan.num_kept
    e_pad = ((e + 1 + block_e - 1) // block_e) * block_e
    n_sent = e_pad - e
    sentinel_id = jnp.full((n_sent,), plan.num_rows, jnp.int32)
    sentinel_n = jnp.zeros((n_sent,), jnp.int32)
    vals_sorted = _take(vals.reshape(-1).astype(jnp.float32), plan.order)
    return (
        jnp.concatenate([plan.row_ids, sentinel_id]),
        jnp.concatenate([plan.sample_sorted, sentinel_n]),
        jnp.concatenate([vals_sorted, jnp.zeros((n_sent,), jnp.float32)]),
    )
