"""Pure-jnp oracle for the plan-driven scatter backward.

The direct transposed scatter-add that both the class-gather jnp path and
the Pallas run-length kernel must reproduce bit-for-bit up to summation
order:

    dTheta[r] = sum_{(n,k): ids[n,k]=r} vals[n,k] * dz[n]
    dvals[n,k] = theta[ids[n,k]] . dz[n]

Conventions match the fused forward package (``lsplm_sparse_fused``):
ids (N, K) with pad id == D-1, vals 0 on pad slots, theta (D, 2m) with
the zero pad row last.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scatter_bwd_ref(
    ids: jax.Array, vals: jax.Array, theta: jax.Array, dz: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """(dvals, dTheta) by direct gather/scatter — the comparison oracle."""
    m2 = theta.shape[-1]
    dz = dz.astype(jnp.float32)
    data = (vals.astype(jnp.float32)[..., None] * dz[:, None, :]).reshape(-1, m2)
    dtheta = jnp.zeros(theta.shape, jnp.float32).at[ids.reshape(-1)].add(data)
    rows = jnp.take(theta, ids, axis=0).astype(jnp.float32)
    dvals = jnp.einsum("nkm,nm->nk", rows, dz)
    return dvals.astype(vals.dtype), dtheta.astype(theta.dtype)
