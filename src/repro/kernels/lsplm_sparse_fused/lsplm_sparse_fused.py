"""Fused sparse LS-PLM forward kernel — padded-COO gather-matmul + Eq. 2.

The paper's production inputs are one-hot/multi-hot id lists over millions
of columns (§2, §3.2); a dense (B, d) batch never exists. The jnp path
(`ref.py`) gathers Theta rows with ``take`` — materialising an (N, K, 2m)
intermediate in HBM — and reduces it with an einsum (a second HBM sweep).
This kernel does the whole thing in one pass per batch tile:

  * ids/vals tiles (BT, K) live in VMEM; Theta (D, 2m) STAYS IN HBM —
    only the K active rows of each sample are DMA'd into a (K, 2m) VMEM
    scratch (exactly how production embedding lookups work),
  * each sample's z = vals_n . rows is one (K)x(K,2m) contraction,
    accumulated straight into a (BT, 2m) VMEM buffer — the (N, K, 2m)
    gather intermediate is never materialised anywhere,
  * the softmax-dot-sigmoid fusion (Eq. 2) runs in-register on the z
    tile; only (BT,) probabilities and the (BT, 2m) region logits are
    written back to HBM (z is the residual the custom VJP needs).

Grid: (N/BT,) over batch tiles. Theta must carry the zero pad row
(id == D-1) so pad slots contribute nothing; `ops.pad_theta` provides it.

Scaling note: Theta lives in HBM so d is bounded by device HBM, not VMEM
(a (1e6, 24) fp32 Theta is 96 MB — fine). Sharding Theta's rows across
chips (the paper's parameter-server axis) is the next step; see ROADMAP.

Coverage caveat: CI validates this kernel in INTERPRET mode only (the
runners have no TPU). The compiled Mosaic path — in particular driving
the per-row DMA index from the VMEM-resident ids tile — has not been
lowered on real hardware yet; first-TPU bring-up should start from
``mode="interpret"`` parity and may need ids moved to scalar prefetch.
See ROADMAP "Sparse kernel perf on real TPU".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, vals_ref, theta_ref, p_ref, z_ref, rows, sems, *, m: int):
    block_n, K = ids_ref.shape

    def row_body(n, carry):
        # start all K row-DMAs for this sample, then drain them: the
        # gathers overlap each other (and, across rows, the contraction).
        for k in range(K):
            pltpu.make_async_copy(
                theta_ref.at[ids_ref[n, k]], rows.at[k], sems.at[k]
            ).start()
        for k in range(K):
            pltpu.make_async_copy(
                theta_ref.at[ids_ref[n, k]], rows.at[k], sems.at[k]
            ).wait()
        z_ref[n, :] = jnp.dot(
            vals_ref[n, :].astype(jnp.float32),
            rows[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return carry

    jax.lax.fori_loop(0, block_n, row_body, 0)

    z = z_ref[...]
    gate = jax.nn.softmax(z[:, :m], axis=-1)
    fit = jax.nn.sigmoid(z[:, m:])
    p_ref[...] = jnp.sum(gate * fit, axis=-1, keepdims=True).astype(p_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lsplm_sparse_fused_forward(
    ids: jax.Array,  # (N, K) int32, pad id == theta.shape[0] - 1
    vals: jax.Array,  # (N, K)
    theta: jax.Array,  # (D, 2m) with zero pad row at D-1
    *,
    block_n: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused sparse forward. Returns (p (N,), z (N, 2m)).

    Ragged N is handled by padding the batch with pad-id rows up to a
    block multiple (those rows gather only the zero row) and slicing the
    outputs back — real loaders never need to round their batch sizes.
    """
    if ids.shape != vals.shape or ids.ndim != 2:
        raise ValueError(f"ids/vals must be (N, K), got {ids.shape}/{vals.shape}")
    if theta.ndim != 2 or theta.shape[1] % 2:
        raise ValueError(f"theta must be (D, 2m), got {theta.shape}")
    N, K = ids.shape
    D, m2 = theta.shape
    m = m2 // 2
    block_n = max(1, min(block_n, N))
    n_pad = pl.cdiv(N, block_n) * block_n
    if n_pad != N:
        ids = jnp.concatenate(
            [ids, jnp.full((n_pad - N, K), D - 1, ids.dtype)], axis=0)
        vals = jnp.concatenate(
            [vals, jnp.zeros((n_pad - N, K), vals.dtype)], axis=0)

    p, z = pl.pallas_call(
        functools.partial(_kernel, m=m),
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, K), lambda i: (i, 0)),
            pl.BlockSpec((block_n, K), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),  # Theta stays in HBM
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_n, m2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, 1), theta.dtype),
            jax.ShapeDtypeStruct((n_pad, m2), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((K, m2), theta.dtype),
            pltpu.SemaphoreType.DMA((K,)),
        ],
        interpret=interpret,
    )(ids, vals, theta)
    return p[:N, 0], z[:N]
