"""Fused sparse LS-PLM forward kernel — pipelined block-DMA gather + Eq. 2.

The paper's production inputs are one-hot/multi-hot id lists over millions
of columns (§2, §3.2); a dense (B, d) batch never exists. This kernel
computes p(y=1|x) straight from padded-COO (ids, vals) in one pass per
batch tile, with the row gathers organised as a true DMA pipeline:

  * ids are a SCALAR-PREFETCH operand (``PrefetchScalarGridSpec``): they
    land in SMEM before the kernel body runs, so every DMA's source row
    is known without touching VMEM — the requirement for issuing copies
    ahead of the compute that consumes them.
  * Theta (D, 2m) stays in HBM; the K id slots of each sample are
    processed in K-ROW BLOCKS of ``block_k`` rows. Two (block_k, 2m)
    VMEM buffers double-buffer the stream: while block t is being
    contracted against its vals chunk, the ``block_k`` row copies of
    block t+1 are already in flight — gathers for the next block overlap
    the matmul of the current one, across sample boundaries too (the
    flat pipeline index runs over the whole tile).
  * pad-id rows (id == D-1) are SKIPPED: no HBM DMA is issued; the
    buffer row is zeroed in place instead, so a pad slot contracts
    exactly like the zero pad row it aliases (even if its val is not 0,
    matching the jnp path and the oracle). Combined with the runtime
    dedup pre-pass in ``ops.dedup_tile_ids`` (duplicate ids within a
    sample collapse onto their first slot with summed values, freed
    slots become pad), hot features are fetched once per sample and
    ragged tails cost nothing.
  * the softmax-dot-sigmoid fusion (Eq. 2) runs in-register on the
    accumulated z tile; only (BT,) probabilities and the (BT, 2m) region
    logits are written back (z is the residual the custom VJP needs).

Grid: (N/block_n,) over batch tiles. Theta must carry the zero pad row
(id == D-1); ``ops.pad_theta`` provides it.

VMEM/SMEM sizing rule (what bounds the block sizes):

    VMEM  ~=  2 * block_k * 2m * 4        (double buffers)
            + block_n * K_pad * 4          (vals tile)
            + block_n * (2m + 1) * 4       (z + p tiles)
    SMEM  ~=  N_pad * K_pad * 4            (prefetched ids, whole batch)

so block_n * K and block_k * 2m are the knobs; ids SMEM residency bounds
the rows per ``pallas_call`` — CALLERS must slice batches whose
N_pad * K_pad * 4 bytes exceed SMEM into separate calls (no automatic
slabbing exists yet; see ROADMAP's TPU bring-up item). Theta itself
never enters VMEM (d is HBM-bounded: a (1e6, 24) fp32 Theta is 96 MB).

(block_n, block_k) are RESOLVED FROM THE AUTOTUNE TABLE (``repro.tune``,
kernel key ``"fused_fwd"``) when the public ops are called with the
knobs left at None — the sizing rule above bounds the sweep grid, the
sweep (``python -m repro.tune.sweep``) picks within it, parity-gated
against the ref oracle per config. Explicit kwargs always win.

Coverage: CI validates this kernel in INTERPRET mode (no TPU runners),
which exercises the full pipeline logic — scalar-prefetched indexing,
conditional skip DMAs, buffer rotation, cross-sample chunk flattening.
The compiled Mosaic path follows the standard prefetch+double-buffer
recipe (see the Pallas guide's "Double Buffering" pattern); first-TPU
bring-up runs ``tests/test_kernel_parity.py`` (``REPRO_KERNEL_PARITY=1``
— ``mode="kernel"`` vs ``mode="interpret"``) and then regenerates the
TPU table with ``python -m repro.tune.sweep --mode kernel --out
src/repro/tune/tables/tpu.json``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, vals_ref, theta_ref, p_ref, z_ref, bufs, sems, *,
            m: int, block_n: int, block_k: int, nkb: int, skip_id: int):
    """One batch tile: T = block_n * nkb pipelined K-row blocks."""
    pid = pl.program_id(0)
    T = block_n * nkb

    @pl.when(pid == 0)
    def _zero_buffers():  # never read uninitialised VMEM on skipped slots
        bufs[...] = jnp.zeros_like(bufs)

    def row_dma(t, slot, j):
        n = pid * block_n + t // nkb
        k = jax.lax.rem(t, nkb) * block_k + j
        return pltpu.make_async_copy(
            theta_ref.at[ids_ref[n, k]], bufs.at[slot, j], sems.at[slot, j])

    def start(t, slot):
        for j in range(block_k):
            n = pid * block_n + t // nkb
            k = jax.lax.rem(t, nkb) * block_k + j

            @pl.when(ids_ref[n, k] != skip_id)
            def _():
                row_dma(t, slot, j).start()

            # skipped slots must still contract like the zero pad row —
            # zero the buffer row (VMEM-only store; slot (t+1)%2 is idle
            # while step t computes, so this never races the matmul)
            @pl.when(ids_ref[n, k] == skip_id)
            def _():
                bufs[slot, j, :] = jnp.zeros_like(bufs[slot, j, :])

    def wait(t, slot):
        for j in range(block_k):
            n = pid * block_n + t // nkb
            k = jax.lax.rem(t, nkb) * block_k + j

            @pl.when(ids_ref[n, k] != skip_id)
            def _():
                row_dma(t, slot, j).wait()

    start(0, 0)

    def pipeline_step(t, carry):
        slot = jax.lax.rem(t, 2)

        @pl.when(t + 1 < T)
        def _prefetch_next():  # overlaps the contraction below
            start(t + 1, jax.lax.rem(t + 1, 2))

        wait(t, slot)
        n = t // nkb
        b = jax.lax.rem(t, nkb)
        vchunk = vals_ref[n, pl.ds(b * block_k, block_k)]
        partial = jnp.dot(
            vchunk.astype(jnp.float32),
            bufs[slot].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

        @pl.when(b == 0)
        def _():
            z_ref[n, :] = partial

        @pl.when(b != 0)
        def _():
            z_ref[n, :] = z_ref[n, :] + partial

        return carry

    jax.lax.fori_loop(0, T, pipeline_step, 0)

    z = z_ref[...]
    gate = jax.nn.softmax(z[:, :m], axis=-1)
    fit = jax.nn.sigmoid(z[:, m:])
    p_ref[...] = jnp.sum(gate * fit, axis=-1, keepdims=True).astype(p_ref.dtype)


def _kernel_int8(ids_ref, vals_ref, codes_ref, scales_ref, p_ref, z_ref,
                 bufs, sbufs, sems, ssems, *,
                 m: int, block_n: int, block_k: int, nkb: int, skip_id: int):
    """Int8-native batch tile: same pipeline as :func:`_kernel`, but the
    row DMAs move int8 CODE rows (4x fewer bytes than fp32) plus their
    (1,) fp32 scales; the scale is applied in VMEM right before the
    contraction — ``rows = codes.astype(f32) * scale`` — so fp32 rows
    never exist anywhere, HBM or VMEM, only the (block_k, 2m) working
    set of the current pipeline step."""
    pid = pl.program_id(0)
    T = block_n * nkb

    @pl.when(pid == 0)
    def _zero_buffers():  # never read uninitialised VMEM on skipped slots
        bufs[...] = jnp.zeros_like(bufs)
        sbufs[...] = jnp.zeros_like(sbufs)

    def row_dmas(t, slot, j):
        n = pid * block_n + t // nkb
        k = jax.lax.rem(t, nkb) * block_k + j
        rid = ids_ref[n, k]
        return (pltpu.make_async_copy(
                    codes_ref.at[rid], bufs.at[slot, j], sems.at[slot, j]),
                pltpu.make_async_copy(
                    scales_ref.at[rid], sbufs.at[slot, j], ssems.at[slot, j]))

    def start(t, slot):
        for j in range(block_k):
            n = pid * block_n + t // nkb
            k = jax.lax.rem(t, nkb) * block_k + j

            @pl.when(ids_ref[n, k] != skip_id)
            def _():
                for dma in row_dmas(t, slot, j):
                    dma.start()

            # a skipped slot must contract like the zero pad row: zero its
            # SCALE — codes are int8 (always finite), so stale codes times
            # an exact-0.0 scale contract to exact 0.0
            @pl.when(ids_ref[n, k] == skip_id)
            def _():
                sbufs[slot, j, :] = jnp.zeros_like(sbufs[slot, j, :])

    def wait(t, slot):
        for j in range(block_k):
            n = pid * block_n + t // nkb
            k = jax.lax.rem(t, nkb) * block_k + j

            @pl.when(ids_ref[n, k] != skip_id)
            def _():
                for dma in row_dmas(t, slot, j):
                    dma.wait()

    start(0, 0)

    def pipeline_step(t, carry):
        slot = jax.lax.rem(t, 2)

        @pl.when(t + 1 < T)
        def _prefetch_next():  # overlaps the contraction below
            start(t + 1, jax.lax.rem(t + 1, 2))

        wait(t, slot)
        n = t // nkb
        b = jax.lax.rem(t, nkb)
        vchunk = vals_ref[n, pl.ds(b * block_k, block_k)]
        # the scale epilogue: int8 codes -> fp32 rows, in VMEM, fused
        # into this step's contraction (pad slots have scale == 0.0)
        rows = bufs[slot].astype(jnp.float32) * sbufs[slot]
        partial = jnp.dot(vchunk.astype(jnp.float32), rows,
                          preferred_element_type=jnp.float32)

        @pl.when(b == 0)
        def _():
            z_ref[n, :] = partial

        @pl.when(b != 0)
        def _():
            z_ref[n, :] = z_ref[n, :] + partial

        return carry

    jax.lax.fori_loop(0, T, pipeline_step, 0)

    z = z_ref[...]
    gate = jax.nn.softmax(z[:, :m], axis=-1)
    fit = jax.nn.sigmoid(z[:, m:])
    p_ref[...] = jnp.sum(gate * fit, axis=-1, keepdims=True).astype(p_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_k", "interpret"))
def lsplm_sparse_fused_int8_forward(
    ids: jax.Array,  # (N, K) int32, pad id == codes.shape[0] - 1
    vals: jax.Array,  # (N, K)
    codes: jax.Array,  # (D, 2m) int8; row i fp32 == codes[i] * scales[i]
    scales: jax.Array,  # (D,) fp32 per-row scales; pad row scale == 0
    *,
    block_n: int = 256,
    block_k: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Int8-native pipelined fused sparse forward: serve a quantised
    model WITHOUT materialising fp32 rows. Returns (p (N,), z (N, 2m)).

    Identical gather/contraction structure to
    :func:`lsplm_sparse_fused_forward` on the dequantised rows — the
    row values entering each ``jnp.dot`` are the same fp32 numbers
    (``codes * scale``), computed in the VMEM epilogue instead of
    up-front in HBM, so the scores match the dequantise-then-score path
    while the per-row DMA traffic drops ~4x (int8 codes + one fp32
    scalar vs a fp32 row). Same VMEM/SMEM sizing rule as the fp32
    kernel with the double buffers at 1/4 size; (block_n, block_k)
    resolve from the autotune table under kernel key
    ``"fused_fwd_int8"``. CI validates in interpret mode (see module
    docstring).
    """
    if ids.shape != vals.shape or ids.ndim != 2:
        raise ValueError(f"ids/vals must be (N, K), got {ids.shape}/{vals.shape}")
    if codes.ndim != 2 or codes.shape[1] % 2:
        raise ValueError(f"codes must be (D, 2m), got {codes.shape}")
    if codes.dtype != jnp.int8:
        raise ValueError(f"codes must be int8, got {codes.dtype}")
    if scales.shape != (codes.shape[0],):
        raise ValueError(
            f"scales must be ({codes.shape[0]},), got {scales.shape}")
    N, K = ids.shape
    D, m2 = codes.shape
    m = m2 // 2
    block_n = max(1, min(block_n, N))
    block_k = max(1, min(block_k, K))
    n_pad = pl.cdiv(N, block_n) * block_n
    k_pad = pl.cdiv(K, block_k) * block_k
    if n_pad != N:
        ids = jnp.concatenate(
            [ids, jnp.full((n_pad - N, K), D - 1, ids.dtype)], axis=0)
        vals = jnp.concatenate(
            [vals, jnp.zeros((n_pad - N, K), vals.dtype)], axis=0)
    if k_pad != K:
        ids = jnp.concatenate(
            [ids, jnp.full((n_pad, k_pad - K), D - 1, ids.dtype)], axis=1)
        vals = jnp.concatenate(
            [vals, jnp.zeros((n_pad, k_pad - K), vals.dtype)], axis=1)
    nkb = k_pad // block_k

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, k_pad), lambda i, *_: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # codes stay in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),  # scales stay in HBM
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1), lambda i, *_: (i, 0)),
            pl.BlockSpec((block_n, m2), lambda i, *_: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, block_k, m2), jnp.int8),
            pltpu.VMEM((2, block_k, 1), jnp.float32),
            pltpu.SemaphoreType.DMA((2, block_k)),
            pltpu.SemaphoreType.DMA((2, block_k)),
        ],
    )
    p, z = pl.pallas_call(
        functools.partial(_kernel_int8, m=m, block_n=block_n,
                          block_k=block_k, nkb=nkb, skip_id=D - 1),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, m2), jnp.float32),
        ],
        interpret=interpret,
    )(ids, vals, codes, scales.astype(jnp.float32).reshape(D, 1))
    return p[:N, 0], z[:N]


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_k", "interpret"))
def lsplm_sparse_fused_forward(
    ids: jax.Array,  # (N, K) int32, pad id == theta.shape[0] - 1
    vals: jax.Array,  # (N, K)
    theta: jax.Array,  # (D, 2m) with zero pad row at D-1
    *,
    block_n: int = 256,
    block_k: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Pipelined fused sparse forward. Returns (p (N,), z (N, 2m)).

    Ragged N and K are handled by padding with pad-id slots up to block
    multiples (skipped by the pipeline, zero-valued in the contraction)
    and slicing the outputs back — loaders never round their shapes.
    """
    if ids.shape != vals.shape or ids.ndim != 2:
        raise ValueError(f"ids/vals must be (N, K), got {ids.shape}/{vals.shape}")
    if theta.ndim != 2 or theta.shape[1] % 2:
        raise ValueError(f"theta must be (D, 2m), got {theta.shape}")
    N, K = ids.shape
    D, m2 = theta.shape
    m = m2 // 2
    block_n = max(1, min(block_n, N))
    block_k = max(1, min(block_k, K))
    n_pad = pl.cdiv(N, block_n) * block_n
    k_pad = pl.cdiv(K, block_k) * block_k
    if n_pad != N:
        ids = jnp.concatenate(
            [ids, jnp.full((n_pad - N, K), D - 1, ids.dtype)], axis=0)
        vals = jnp.concatenate(
            [vals, jnp.zeros((n_pad - N, K), vals.dtype)], axis=0)
    if k_pad != K:
        ids = jnp.concatenate(
            [ids, jnp.full((n_pad, k_pad - K), D - 1, ids.dtype)], axis=1)
        vals = jnp.concatenate(
            [vals, jnp.zeros((n_pad, k_pad - K), vals.dtype)], axis=1)
    nkb = k_pad // block_k

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, k_pad), lambda i, *_: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # Theta stays in HBM
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1), lambda i, *_: (i, 0)),
            pl.BlockSpec((block_n, m2), lambda i, *_: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, block_k, m2), theta.dtype),
            pltpu.SemaphoreType.DMA((2, block_k)),
        ],
    )
    p, z = pl.pallas_call(
        functools.partial(_kernel, m=m, block_n=block_n, block_k=block_k,
                          nkb=nkb, skip_id=D - 1),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, 1), theta.dtype),
            jax.ShapeDtypeStruct((n_pad, m2), jnp.float32),
        ],
        interpret=interpret,
    )(ids, vals, theta)
    return p[:N, 0], z[:N]
