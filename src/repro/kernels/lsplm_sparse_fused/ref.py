"""Pure-jnp oracle for the fused sparse LS-PLM kernel.

This is the padded-COO math that ``repro/data/sparse.py`` shipped as its
production path before the Pallas kernel existed: a ``take`` gather that
materialises the (N, K, 2m) row intermediate in HBM, then an einsum
reduction. It stays here as the bit-exact oracle for the kernel tests and
as the baseline ``benchmarks/bench_sparse_fused.py`` measures against.

Conventions (shared by kernel, ops and oracle):

    ids   (N, K) int32    active column ids; pad slots carry id == D-1
    vals  (N, K) float    feature values; 0.0 on pad slots
    theta (D, 2m) float   PADDED parameters — the last row must be all
                          zeros so pad ids contribute nothing

with D = d + 1 (``ops.pad_theta`` appends the zero row).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sparse_matmul_ref(ids: jax.Array, vals: jax.Array, theta: jax.Array) -> jax.Array:
    """z[n] = sum_k vals[n,k] * theta[ids[n,k], :].  (N, K) -> (N, 2m)."""
    rows = jnp.take(theta, ids, axis=0)  # (N, K, 2m) — the HBM intermediate
    return jnp.einsum("nk,nkm->nm", vals.astype(rows.dtype), rows)


def lsplm_sparse_forward_ref(ids: jax.Array, vals: jax.Array, theta: jax.Array) -> jax.Array:
    """p(y=1|x) per Eq. 2 on padded-COO inputs. Returns (N,)."""
    z = sparse_matmul_ref(ids, vals, theta)
    m = theta.shape[-1] // 2
    gate = jax.nn.softmax(z[..., :m], axis=-1)
    fit = jax.nn.sigmoid(z[..., m:])
    return jnp.sum(gate * fit, axis=-1)


def lsplm_sparse_logps_ref(
    ids: jax.Array, vals: jax.Array, theta: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Numerically-stable (log_p1, log_p0) for the NLL (Eq. 5), sparse."""
    z = sparse_matmul_ref(ids, vals, theta)
    m = theta.shape[-1] // 2
    log_gate = jax.nn.log_softmax(z[..., :m], axis=-1)
    log_p1 = jax.nn.logsumexp(log_gate + jax.nn.log_sigmoid(z[..., m:]), axis=-1)
    log_p0 = jax.nn.logsumexp(log_gate + jax.nn.log_sigmoid(-z[..., m:]), axis=-1)
    return log_p1, log_p0
