"""Public fused sparse LS-PLM ops: dispatch + ``jax.custom_vjp``.

Two differentiable entry points, both backed by the Pallas kernel on TPU
(or in interpret mode) and by a K-chunked accumulation elsewhere — the
chunked path keeps the live intermediate at (N, chunk, 2m) instead of the
(N, K, 2m) HBM blob the ``take``+einsum oracle materialises, which is
what makes it win at production sparsity (K << d; see
``benchmarks/bench_sparse_fused.py``):

  * ``sparse_gather_matmul(ids, vals, theta) -> z (N, 2m)`` — the region
    logits. The stable-NLL training path (log-space Eq. 5) builds on this,
    so OWLQN+ line searches differentiate through the custom VJP.
  * ``lsplm_sparse_forward(ids, vals, theta) -> p (N,)`` — fully fused
    probabilities (softmax-dot-sigmoid in-register on the kernel path).

Both VJPs share one backward: the transposed scatter-add

    dTheta[r] = sum_{(n,k): ids[n,k]=r} vals[n,k] * dz[n]     (segment-sum)
    dvals[n,k] = theta[ids[n,k]] . dz[n]                      (gather-dot)

emitted as K-chunked ``jax.ops.segment_sum`` into Theta rows — the exact
transpose of the forward gather, and TPU-native (sorted scatter / one-hot
matmul under XLA). ids are integer primals and get float0 cotangents.

``mode`` selects the forward implementation:
    "auto"      Pallas kernel on TPU, chunked jnp elsewhere (default)
    "kernel"    force the compiled Pallas kernel
    "interpret" force the Pallas kernel in interpret mode (tests/CI)
    "jnp"       force the chunked jnp path
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.lsplm_sparse_fused.lsplm_sparse_fused import (
    lsplm_sparse_fused_forward,
)

_CHUNK = 8  # K-chunk for the jnp fallback and the scatter backward


def pad_theta(theta: jax.Array) -> jax.Array:
    """Append the zero pad row (pad id == d == theta.shape[0])."""
    return jnp.concatenate(
        [theta, jnp.zeros((1, theta.shape[1]), theta.dtype)], axis=0)


def _finalize_p(z: jax.Array) -> jax.Array:
    m = z.shape[-1] // 2
    gate = jax.nn.softmax(z[..., :m], axis=-1)
    fit = jax.nn.sigmoid(z[..., m:])
    return jnp.sum(gate * fit, axis=-1)


def logps_from_z(z: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Stable (log_p1, log_p0) from region logits z (..., 2m) — the one
    log-space Eq. 5 head shared by every fused-path consumer."""
    m = z.shape[-1] // 2
    log_gate = jax.nn.log_softmax(z[..., :m], axis=-1)
    log_p1 = jax.nn.logsumexp(log_gate + jax.nn.log_sigmoid(z[..., m:]), axis=-1)
    log_p0 = jax.nn.logsumexp(log_gate + jax.nn.log_sigmoid(-z[..., m:]), axis=-1)
    return log_p1, log_p0


def _chunked_zmap(ids, vals, theta, chunk: int = _CHUNK) -> jax.Array:
    """Fused-style jnp forward: accumulate z in K-chunks so the live
    gather intermediate is (N, chunk, 2m), never (N, K, 2m)."""
    N, K = ids.shape
    z = jnp.zeros((N, theta.shape[1]), jnp.float32)
    for k0 in range(0, K, chunk):
        rows = jnp.take(theta, ids[:, k0:k0 + chunk], axis=0)
        z = z + jnp.einsum(
            "nk,nkm->nm", vals[:, k0:k0 + chunk].astype(rows.dtype), rows)
    return z


def _use_kernel(mode: str) -> bool:
    if mode == "auto":
        return jax.default_backend() == "tpu"
    if mode in ("kernel", "interpret"):
        return True
    if mode == "jnp":
        return False
    raise ValueError(f"unknown mode {mode!r}")


def _zmap(mode: str, block_n: int, ids, vals, theta) -> jax.Array:
    if _use_kernel(mode):
        _, z = lsplm_sparse_fused_forward(
            ids, vals, theta, block_n=block_n, interpret=mode == "interpret")
        return z
    return _chunked_zmap(ids, vals, theta)


def _scatter_bwd(ids, vals, theta, dz):
    """Shared VJP tail: dz (N, 2m) -> (dvals, dtheta), K-chunked."""
    m2 = theta.shape[1]
    dz = dz.astype(jnp.float32)
    dtheta = jnp.zeros(theta.shape, jnp.float32)
    dvals_parts = []
    for k0 in range(0, ids.shape[1], _CHUNK):
        i = ids[:, k0:k0 + _CHUNK]
        v = vals[:, k0:k0 + _CHUNK].astype(jnp.float32)
        data = (v[..., None] * dz[:, None, :]).reshape(-1, m2)
        # scatter straight into the one accumulator (duplicate ids sum) —
        # a per-chunk segment_sum would build a full (D, 2m) temp each time
        dtheta = dtheta.at[i.reshape(-1)].add(data)
        rows = jnp.take(theta, i, axis=0).astype(jnp.float32)
        dvals_parts.append(jnp.einsum("nkm,nm->nk", rows, dz))
    dvals = jnp.concatenate(dvals_parts, axis=1).astype(vals.dtype)
    return dvals, dtheta.astype(theta.dtype)


def _float0_like(ids):
    return np.zeros(ids.shape, dtype=jax.dtypes.float0)


# ------------------------------------------------------- z-level custom VJP
@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _gather_matmul(mode: str, block_n: int, ids, vals, theta):
    return _zmap(mode, block_n, ids, vals, theta)


def _gather_matmul_fwd(mode, block_n, ids, vals, theta):
    return _zmap(mode, block_n, ids, vals, theta), (ids, vals, theta)


def _gather_matmul_bwd(mode, block_n, res, dz):
    ids, vals, theta = res
    dvals, dtheta = _scatter_bwd(ids, vals, theta, dz)
    return _float0_like(ids), dvals, dtheta


_gather_matmul.defvjp(_gather_matmul_fwd, _gather_matmul_bwd)


# ------------------------------------------------------- p-level custom VJP
@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _forward_p(mode: str, block_n: int, ids, vals, theta):
    if _use_kernel(mode):
        p, _ = lsplm_sparse_fused_forward(
            ids, vals, theta, block_n=block_n, interpret=mode == "interpret")
        return p
    return _finalize_p(_chunked_zmap(ids, vals, theta))


def _forward_p_fwd(mode, block_n, ids, vals, theta):
    if _use_kernel(mode):
        p, z = lsplm_sparse_fused_forward(
            ids, vals, theta, block_n=block_n, interpret=mode == "interpret")
    else:
        z = _chunked_zmap(ids, vals, theta)
        p = _finalize_p(z)
    return p, (ids, vals, theta, z, p)


def _forward_p_bwd(mode, block_n, res, dp):
    ids, vals, theta, z, p = res
    m = z.shape[-1] // 2
    gate = jax.nn.softmax(z[:, :m], axis=-1)
    fit = jax.nn.sigmoid(z[:, m:])
    dp = dp.astype(jnp.float32)[:, None]
    dzu = dp * gate * (fit - p.astype(jnp.float32)[:, None])
    dzw = dp * gate * fit * (1.0 - fit)
    dvals, dtheta = _scatter_bwd(ids, vals, theta,
                                 jnp.concatenate([dzu, dzw], axis=-1))
    return _float0_like(ids), dvals, dtheta


_forward_p.defvjp(_forward_p_fwd, _forward_p_bwd)


# ------------------------------------------------------------- public API
def sparse_gather_matmul(ids, vals, theta, *, mode: str = "auto",
                         block_n: int = 256) -> jax.Array:
    """z = x @ Theta from padded COO, fused, custom-VJP'd. (N, K) -> (N, 2m)."""
    return _gather_matmul(mode, block_n, ids, vals, theta)


def lsplm_sparse_forward(ids, vals, theta, *, mode: str = "auto",
                         block_n: int = 256) -> jax.Array:
    """p(y=1|x) per Eq. 2 from padded COO, fully fused. Returns (N,)."""
    return _forward_p(mode, block_n, ids, vals, theta)


def lsplm_sparse_logps(ids, vals, theta, *, mode: str = "auto",
                       block_n: int = 256) -> tuple[jax.Array, jax.Array]:
    """Stable (log_p1, log_p0) for Eq. 5 on padded COO — the training path."""
    z = sparse_gather_matmul(ids, vals, theta, mode=mode, block_n=block_n)
    return logps_from_z(z)
