"""Public fused sparse LS-PLM ops: dispatch + ``jax.custom_vjp``.

Two differentiable entry points, both backed by the pipelined Pallas
kernel on TPU (or in interpret mode) and by a K-chunked ``lax.scan``
accumulation elsewhere — the chunked path keeps the live intermediate at
(N, chunk, 2m) instead of the (N, K, 2m) HBM blob the ``take``+einsum
oracle materialises, which is what makes it win at production sparsity
(K << d; see ``benchmarks/bench_sparse_fused.py``):

  * ``sparse_gather_matmul(ids, vals, theta) -> z (N, 2m)`` — the region
    logits. The stable-NLL training path (log-space Eq. 5) builds on this,
    so OWLQN+ line searches differentiate through the custom VJP.
  * ``lsplm_sparse_forward(ids, vals, theta) -> p (N,)`` — fully fused
    probabilities (softmax-dot-sigmoid in-register on the kernel path).

Plus the INFERENCE-ONLY int8-native pair — ``sparse_gather_matmul_int8``
and ``lsplm_sparse_forward_int8`` — which score a quantised model
(int8 ``codes`` + per-row fp32 ``scales``) without ever materialising
fp32 rows: the kernel DMAs int8 code rows and applies the scale in the
VMEM epilogue (~4x fewer row-DMA bytes), the jnp fallback fuses the same
multiply into its gather chunks. No VJP: training stays fp32,
quantisation is a deploy-time transform (``repro.serve.compress``).

Both VJPs share one backward: the transposed scatter

    dTheta[r] = sum_{(n,k): ids[n,k]=r} vals[n,k] * dz[n]     (segment-sum)
    dvals[n,k] = theta[ids[n,k]] . dz[n]                      (gather-dot)

With a precomputed :class:`TransposePlan` (``plan=`` — built once per
batch by ``repro.data.sparse.build_transpose_plan``) the dTheta half runs
on ``repro.kernels.lsplm_sparse_scatter``: race-free segment sums with NO
sort and NO scatter inside the step — the Pallas run-length kernel on
TPU, plan-scheduled class gathers elsewhere. Without a plan it falls back
to a ``lax.scan`` of K-chunked ``.at[].add`` scatters (constant trace
size in K). The dvals half reuses the forward-gathered Theta rows when
they were small enough to keep as residuals (``ROWS_REUSE_LIMIT``), else
re-gathers through the plan's id-sorted layout (duplicates adjacent). ids
are integer primals and get float0 cotangents; so does every plan leaf.

``mode`` selects the implementation on both sides of the VJP:
    "auto"      Pallas kernels on TPU, chunked/plan jnp elsewhere (default)
    "kernel"    force the compiled Pallas kernels
    "interpret" force the Pallas kernels in interpret mode (tests/CI)
    "jnp"       force the jnp paths

Tunables: ``block_n``/``block_k`` (kernel tiles) and ``chunk`` (scan
fallbacks) default to None = RESOLVED FROM THE AUTOTUNE TABLE
(``repro.tune``) by the ``(backend, kernel, shape-envelope)`` key —
explicit kwargs always win, then ``repro.tune.set_overrides``, then the
committed table, then the builtin defaults. The forward and backward
scans resolve their chunks independently (``chunk_fwd``/``chunk_bwd``
table kernels); an explicit ``chunk=`` kwarg pins both. Resolution is
trace-time dict lookups — zero steady-state sweeps.
``ROWS_REUSE_LIMIT`` caps ids.size * 2m kept as (N, K, 2m) residual rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.lsplm_sparse_fused.lsplm_sparse_fused import (
    lsplm_sparse_fused_forward,
    lsplm_sparse_fused_int8_forward,
)
from repro.kernels.lsplm_sparse_scatter.ops import (
    TransposePlan,
    dvals_planned,
    scatter_add_planned,
)
from repro.tune import table as tune

DEFAULT_CHUNK = 8     # K-chunk for the scan fallbacks (builtin default)
ROWS_REUSE_LIMIT = 1 << 22  # save fwd rows as residuals up to this many floats


def pad_theta(theta: jax.Array) -> jax.Array:
    """Append the zero pad row (pad id == d == theta.shape[0]).

    The trailing row is RESERVED: every consumer in this package treats
    id D-1 as the pad slot (skipped by the kernel pipeline, dropped by
    transpose plans); its values must be 0.
    """
    return jnp.concatenate(
        [theta, jnp.zeros((1, theta.shape[1]), theta.dtype)], axis=0)


def finalize_p(z: jax.Array) -> jax.Array:
    """Eq. 2 head: region logits z (..., 2m) -> p(y=1|x) (...,). The ONE
    softmax-dot-sigmoid used by every inference consumer (``repro.serve``,
    the dense predictors, the jnp fallbacks here)."""
    m = z.shape[-1] // 2
    gate = jax.nn.softmax(z[..., :m], axis=-1)
    fit = jax.nn.sigmoid(z[..., m:])
    return jnp.sum(gate * fit, axis=-1)


def logps_from_z(z: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Stable (log_p1, log_p0) from region logits z (..., 2m) — the one
    log-space Eq. 5 head shared by every fused-path consumer."""
    m = z.shape[-1] // 2
    log_gate = jax.nn.log_softmax(z[..., :m], axis=-1)
    log_p1 = jax.nn.logsumexp(log_gate + jax.nn.log_sigmoid(z[..., m:]), axis=-1)
    log_p0 = jax.nn.logsumexp(log_gate + jax.nn.log_sigmoid(-z[..., m:]), axis=-1)
    return log_p1, log_p0


def dedup_tile_ids(ids: jax.Array, vals: jax.Array,
                   pad_id: int) -> tuple[jax.Array, jax.Array]:
    """Collapse duplicate ids within each sample onto one slot.

    Repeated ids (hot features, multi-valued slots) are merged: the
    shared slot carries the SUM of their values, freed slots become
    (pad_id, 0). z is unchanged (sum_k v_k * theta[i_k] groups by id);
    the kernel pipeline then fetches each hot row once per sample and
    skips the freed slots entirely.

    This is a RUNTIME pre-pass on the kernel path (an (N, K) per-row
    argsort + two small scatters per call), worth it when id traffic is
    hot/duplicated; pass ``dedup=False`` to the public ops for batches
    known to be duplicate-free (e.g. pre-coalesced serving traffic).
    """
    N, K = ids.shape
    order = jnp.argsort(ids, axis=1)
    ids_s = jnp.take_along_axis(ids, order, axis=1)
    vals_s = jnp.take_along_axis(vals, order, axis=1)
    first = jnp.concatenate(
        [jnp.ones((N, 1), bool), ids_s[:, 1:] != ids_s[:, :-1]], axis=1)
    seg = jnp.cumsum(first.astype(jnp.int32), axis=1) - 1
    row = jnp.broadcast_to(jnp.arange(N)[:, None], (N, K))
    vals_d = jnp.zeros_like(vals).at[row, seg].add(vals_s)
    ids_d = jnp.full_like(ids, pad_id).at[row, seg].min(ids_s)
    return ids_d, vals_d


def _pad_k(ids, vals, pad_id, multiple):
    """Right-pad the K axis with (pad_id, 0) slots to a block multiple."""
    N, K = ids.shape
    k_pad = -(-K // multiple) * multiple
    if k_pad == K:
        return ids, vals
    return (
        jnp.concatenate(
            [ids, jnp.full((N, k_pad - K), pad_id, ids.dtype)], axis=1),
        jnp.concatenate(
            [vals, jnp.zeros((N, k_pad - K), vals.dtype)], axis=1),
    )


def _chunk_blocks(ids, vals, pad_id, chunk):
    """Shared K-blocking for the scan paths: clamp chunk, pad K, reshape
    to (kb, N, chunk) scan order."""
    K = ids.shape[1]
    chunk = DEFAULT_CHUNK if chunk is None else chunk
    chunk = max(1, min(chunk, K))
    ids_p, vals_p = _pad_k(ids, vals, pad_id, chunk)
    kb = ids_p.shape[1] // chunk
    N = ids.shape[0]
    return (ids_p.reshape(N, kb, chunk).transpose(1, 0, 2),
            vals_p.reshape(N, kb, chunk).transpose(1, 0, 2), chunk, kb)


def _chunked_zmap(ids, vals, theta, chunk: int | None = None) -> jax.Array:
    """Fused-style jnp forward: ``lax.scan`` over K-chunks so the live
    gather intermediate is (N, chunk, 2m) and the TRACE is constant in K
    (a python loop would grow the program linearly with K)."""
    N = ids.shape[0]
    ids_r, vals_r, _, _ = _chunk_blocks(ids, vals, theta.shape[0] - 1, chunk)

    def body(z, xs):
        i, v = xs
        rows = jnp.take(theta, i, axis=0)
        return z + jnp.einsum("nk,nkm->nm", v.astype(rows.dtype), rows), None

    z0 = jnp.zeros((N, theta.shape[1]), jnp.float32)
    z, _ = jax.lax.scan(body, z0, (ids_r, vals_r))
    return z


def _chunk_pair(chunk) -> tuple[int | None, int | None]:
    """Normalise the VJP's nondiff chunk arg to (chunk_fwd, chunk_bwd).

    The public ops thread a resolved (fwd, bwd) tuple; direct private
    callers (benchmarks) may still pass a single int or None."""
    return chunk if isinstance(chunk, tuple) else (chunk, chunk)


def _resolve_fused(ids, theta, mode, block_n, block_k, chunk):
    """Fill None knobs from the autotune table (explicit kwargs win).

    Trace-time python on static shapes — a jitted caller pays this once
    per shape, never per step."""
    env = tune.fused_envelope(ids.shape[0], ids.shape[1], theta.shape[-1])
    if block_n is None or block_k is None:
        cfg = tune.resolve("fused_fwd", env, mode=mode)
        block_n = cfg["block_n"] if block_n is None else block_n
        block_k = cfg["block_k"] if block_k is None else block_k
    if chunk is None:
        chunk = (tune.resolve("chunk_fwd", env, mode=mode)["chunk"],
                 tune.resolve("chunk_bwd", env, mode=mode)["chunk"])
    else:
        chunk = (chunk, chunk)
    return block_n, block_k, chunk


def _use_kernel(mode: str) -> bool:
    if mode == "auto":
        return jax.default_backend() == "tpu"
    if mode in ("kernel", "interpret"):
        return True
    if mode == "jnp":
        return False
    raise ValueError(f"unknown mode {mode!r}")


def _save_rows(ids, theta) -> bool:
    return ids.size * theta.shape[-1] <= ROWS_REUSE_LIMIT


def _kernel_forward(mode, block_n, block_k, dedup, ids, vals, theta):
    if dedup:
        ids, vals = dedup_tile_ids(ids, vals, theta.shape[0] - 1)
    return lsplm_sparse_fused_forward(
        ids, vals, theta, block_n=block_n, block_k=block_k,
        interpret=mode == "interpret")


def _zmap(mode, block_n, block_k, chunk, dedup, ids, vals, theta):
    """Primal forward z — NEVER materialises the (N, K, 2m) rows."""
    if _use_kernel(mode):
        _, z = _kernel_forward(mode, block_n, block_k, dedup, ids, vals, theta)
        return z
    return _chunked_zmap(ids, vals, theta, _chunk_pair(chunk)[0])


def _zmap_with_rows(mode, block_n, block_k, chunk, dedup, ids, vals, theta):
    """VJP-forward z plus (optionally) the gathered rows kept as the
    residual. Only DIFFERENTIATED calls come through here: when the
    batch is small enough (``ROWS_REUSE_LIMIT``) the (N, K, 2m) rows are
    gathered once, reused for z now and for dvals in the backward —
    inference calls take ``_zmap`` and never build the blob."""
    if _use_kernel(mode):
        _, z = _kernel_forward(mode, block_n, block_k, dedup, ids, vals, theta)
        return z, None
    if _save_rows(ids, theta):
        rows = jnp.take(theta, ids, axis=0)
        z = jnp.einsum("nk,nkm->nm", vals.astype(rows.dtype), rows)
        return z.astype(jnp.float32), rows
    return _chunked_zmap(ids, vals, theta, _chunk_pair(chunk)[0]), None


def _dtheta_chunked(ids, vals, theta, dz, chunk):
    """``lax.scan`` of K-chunked scatter-adds (constant trace size in K)."""
    m2 = theta.shape[1]
    ids_r, vals_r, _, _ = _chunk_blocks(ids, vals, theta.shape[0] - 1, chunk)

    def body(dtheta, xs):
        i, v = xs
        data = (v.astype(jnp.float32)[..., None] * dz[:, None, :]).reshape(-1, m2)
        # scatter straight into the one accumulator (duplicate ids sum) —
        # a per-chunk segment_sum would build a full (D, 2m) temp each time
        return dtheta.at[i.reshape(-1)].add(data), None

    dtheta, _ = jax.lax.scan(
        body, jnp.zeros(theta.shape, jnp.float32), (ids_r, vals_r))
    return dtheta


def _dvals_chunked(ids, vals, theta, dz, chunk):
    """``lax.scan`` of K-chunked gather-dots (the no-plan/no-rows case)."""
    N, K = ids.shape
    ids_r, vals_r, chunk, kb = _chunk_blocks(ids, vals, theta.shape[0] - 1, chunk)

    def body(_, xs):
        i, _v = xs
        rows = jnp.take(theta, i, axis=0).astype(jnp.float32)
        return 0, jnp.einsum("nkm,nm->nk", rows, dz)

    _, dv = jax.lax.scan(body, 0, (ids_r, vals_r))
    return dv.transpose(1, 0, 2).reshape(N, kb * chunk)[:, :K]


def _scatter_bwd(mode, chunk, ids, vals, theta, dz, plan, rows):
    """Shared VJP tail: dz (N, 2m) -> (dvals, dtheta)."""
    dz = dz.astype(jnp.float32)
    chunk = _chunk_pair(chunk)[1]
    if plan is not None:
        plan.validate(ids.shape, theta.shape[0])
        dtheta = scatter_add_planned(plan, vals, dz, mode=mode)
    else:
        dtheta = _dtheta_chunked(ids, vals, theta, dz, chunk)
    if rows is not None:  # reuse the forward's gathered rows (no re-gather)
        dvals = jnp.einsum("nkm,nm->nk", rows.astype(jnp.float32), dz)
    elif plan is not None:
        dvals = dvals_planned(plan, theta, dz, ids.shape)
    else:
        dvals = _dvals_chunked(ids, vals, theta, dz, chunk)
    return dvals.astype(vals.dtype), dtheta.astype(theta.dtype)


def _float0_like(x):
    return jax.tree.map(
        lambda a: np.zeros(a.shape, dtype=jax.dtypes.float0), x)


# ------------------------------------------------------- z-level custom VJP
@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _gather_matmul(mode, block_n, block_k, chunk, dedup, ids, vals, theta,
                   plan):
    return _zmap(mode, block_n, block_k, chunk, dedup, ids, vals, theta)


def _gather_matmul_fwd(mode, block_n, block_k, chunk, dedup, ids, vals, theta,
                       plan):
    z, rows = _zmap_with_rows(mode, block_n, block_k, chunk, dedup, ids, vals,
                              theta)
    return z, (ids, vals, theta, plan, rows)


def _gather_matmul_bwd(mode, block_n, block_k, chunk, dedup, res, dz):
    ids, vals, theta, plan, rows = res
    dvals, dtheta = _scatter_bwd(mode, chunk, ids, vals, theta, dz, plan, rows)
    return _float0_like(ids), dvals, dtheta, _float0_like(plan)


_gather_matmul.defvjp(_gather_matmul_fwd, _gather_matmul_bwd)


# ------------------------------------------------------- p-level custom VJP
@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _forward_p(mode, block_n, block_k, chunk, dedup, ids, vals, theta, plan):
    if _use_kernel(mode):
        p, _ = _kernel_forward(mode, block_n, block_k, dedup, ids, vals, theta)
        return p
    return finalize_p(_zmap(mode, block_n, block_k, chunk, dedup, ids, vals,
                            theta))


def _forward_p_fwd(mode, block_n, block_k, chunk, dedup, ids, vals, theta,
                   plan):
    if _use_kernel(mode):
        p, z = _kernel_forward(mode, block_n, block_k, dedup, ids, vals, theta)
        rows = None
    else:
        z, rows = _zmap_with_rows(mode, block_n, block_k, chunk, dedup, ids,
                                  vals, theta)
        p = finalize_p(z)
    return p, (ids, vals, theta, z, p, plan, rows)


def _forward_p_bwd(mode, block_n, block_k, chunk, dedup, res, dp):
    ids, vals, theta, z, p, plan, rows = res
    m = z.shape[-1] // 2
    gate = jax.nn.softmax(z[:, :m], axis=-1)
    fit = jax.nn.sigmoid(z[:, m:])
    dp = dp.astype(jnp.float32)[:, None]
    dzu = dp * gate * (fit - p.astype(jnp.float32)[:, None])
    dzw = dp * gate * fit * (1.0 - fit)
    dvals, dtheta = _scatter_bwd(
        mode, chunk, ids, vals, theta,
        jnp.concatenate([dzu, dzw], axis=-1), plan, rows)
    return _float0_like(ids), dvals, dtheta, _float0_like(plan)


_forward_p.defvjp(_forward_p_fwd, _forward_p_bwd)


# ------------------------------------------------------------- public API
def sparse_gather_matmul(ids, vals, theta, *, mode: str = "auto",
                         block_n: int | None = None,
                         block_k: int | None = None,
                         chunk: int | None = None, dedup: bool = True,
                         plan: TransposePlan | None = None) -> jax.Array:
    """z = x @ Theta from padded COO, fused, custom-VJP'd. (N, K) -> (N, 2m).

    Pass ``plan`` (one ``build_transpose_plan`` per batch) to run the
    backward on the precomputed transpose layout — no sort/scatter in
    the step. Without it the backward scans K-chunked scatter-adds.
    ``dedup=False`` skips the kernel path's per-call duplicate-id
    collapse for batches known to be duplicate-free. block_n/block_k/
    chunk left at None resolve from the autotune table (``repro.tune``).
    """
    if plan is not None:
        plan.validate(ids.shape, theta.shape[0])
    block_n, block_k, chunk = _resolve_fused(ids, theta, mode, block_n,
                                             block_k, chunk)
    return _gather_matmul(mode, block_n, block_k, chunk, dedup, ids, vals,
                          theta, plan)


def lsplm_sparse_forward(ids, vals, theta, *, mode: str = "auto",
                         block_n: int | None = None,
                         block_k: int | None = None,
                         chunk: int | None = None, dedup: bool = True,
                         plan: TransposePlan | None = None) -> jax.Array:
    """p(y=1|x) per Eq. 2 from padded COO, fully fused. Returns (N,)."""
    if plan is not None:
        plan.validate(ids.shape, theta.shape[0])
    block_n, block_k, chunk = _resolve_fused(ids, theta, mode, block_n,
                                             block_k, chunk)
    return _forward_p(mode, block_n, block_k, chunk, dedup, ids, vals, theta,
                      plan)


def _resolve_fused_int8(ids, codes, mode, block_n, block_k, chunk):
    """Knob resolution for the int8-native path: same envelope rule as
    :func:`_resolve_fused`, but block sizes key on ``"fused_fwd_int8"``
    (the int8 pipeline's DMA:compute balance differs, so it tunes
    independently); the jnp fallback chunk shares ``chunk_fwd``."""
    env = tune.fused_envelope(ids.shape[0], ids.shape[1], codes.shape[-1])
    if block_n is None or block_k is None:
        cfg = tune.resolve("fused_fwd_int8", env, mode=mode)
        block_n = cfg["block_n"] if block_n is None else block_n
        block_k = cfg["block_k"] if block_k is None else block_k
    if chunk is None:
        chunk = tune.resolve("chunk_fwd", env, mode=mode)["chunk"]
    return block_n, block_k, chunk


def _chunked_zmap_int8(ids, vals, codes, scales,
                       chunk: int | None = None) -> jax.Array:
    """Int8-native jnp forward: the ``lax.scan`` K-chunk structure of
    :func:`_chunked_zmap` with the scale epilogue fused into each chunk
    — gathered int8 code rows become fp32 via one multiply by their
    per-row scale, so the fp32 row values (and therefore the einsum and
    the accumulation order) are IDENTICAL to running :func:`_chunked_zmap`
    on the dequantised ``codes * scales`` Theta; only the gather moves
    int8 bytes. Pad rows stay exact zero (pad scale == 0)."""
    N = ids.shape[0]
    ids_r, vals_r, _, _ = _chunk_blocks(ids, vals, codes.shape[0] - 1, chunk)

    def body(z, xs):
        i, v = xs
        rows = (jnp.take(codes, i, axis=0).astype(jnp.float32)
                * jnp.take(scales, i, axis=0)[..., None])
        return z + jnp.einsum("nk,nkm->nm", v.astype(rows.dtype), rows), None

    z0 = jnp.zeros((N, codes.shape[1]), jnp.float32)
    z, _ = jax.lax.scan(body, z0, (ids_r, vals_r))
    return z


def _check_int8_model(codes, scales):
    if codes.ndim != 2 or codes.shape[1] % 2:
        raise ValueError(f"codes must be (D, 2m), got {codes.shape}")
    if codes.dtype != jnp.int8:
        raise ValueError(f"codes must be int8, got {codes.dtype}")
    if scales.shape != (codes.shape[0],):
        raise ValueError(
            f"scales must be ({codes.shape[0]},), got {scales.shape}")


def sparse_gather_matmul_int8(ids, vals, codes, scales, *, mode: str = "auto",
                              block_n: int | None = None,
                              block_k: int | None = None,
                              chunk: int | None = None,
                              dedup: bool = True) -> jax.Array:
    """z = x @ (codes * scales) from padded COO WITHOUT materialising the
    fp32 rows — the int8-native serving path. (N, K) -> (N, 2m).

    ``codes`` is the (D, 2m) int8 matrix with the zero pad row at D-1;
    ``scales`` the (D,) per-row fp32 scales (pad row scale 0). On the
    kernel path the row DMAs move int8 + one fp32 scalar per row (~4x
    fewer bytes than fp32 rows at production K << d) and the scale is
    applied in the VMEM epilogue; the jnp fallback fuses the same
    multiply into its gather chunks. INFERENCE-ONLY: no custom VJP —
    training differentiates the fp32 ops, quantisation is a deploy-time
    transform. Knobs resolve from the autotune table under
    ``"fused_fwd_int8"``.
    """
    _check_int8_model(codes, scales)
    block_n, block_k, chunk = _resolve_fused_int8(ids, codes, mode, block_n,
                                                  block_k, chunk)
    if _use_kernel(mode):
        if dedup:
            ids, vals = dedup_tile_ids(ids, vals, codes.shape[0] - 1)
        _, z = lsplm_sparse_fused_int8_forward(
            ids, vals, codes, scales, block_n=block_n, block_k=block_k,
            interpret=mode == "interpret")
        return z
    return _chunked_zmap_int8(ids, vals, codes, scales, chunk)


def lsplm_sparse_forward_int8(ids, vals, codes, scales, *, mode: str = "auto",
                              block_n: int | None = None,
                              block_k: int | None = None,
                              chunk: int | None = None,
                              dedup: bool = True) -> jax.Array:
    """p(y=1|x) per Eq. 2 from padded COO on int8 codes, fully fused
    (softmax-dot-sigmoid in-register on the kernel path). Returns (N,).
    Inference-only; see :func:`sparse_gather_matmul_int8`."""
    _check_int8_model(codes, scales)
    block_n, block_k, chunk = _resolve_fused_int8(ids, codes, mode, block_n,
                                                  block_k, chunk)
    if _use_kernel(mode):
        if dedup:
            ids, vals = dedup_tile_ids(ids, vals, codes.shape[0] - 1)
        p, _ = lsplm_sparse_fused_int8_forward(
            ids, vals, codes, scales, block_n=block_n, block_k=block_k,
            interpret=mode == "interpret")
        return p
    return finalize_p(_chunked_zmap_int8(ids, vals, codes, scales, chunk))


def lsplm_sparse_logps(ids, vals, theta, *, mode: str = "auto",
                       block_n: int | None = None,
                       block_k: int | None = None,
                       chunk: int | None = None, dedup: bool = True,
                       plan: TransposePlan | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Stable (log_p1, log_p0) for Eq. 5 on padded COO — the training path."""
    z = sparse_gather_matmul(ids, vals, theta, mode=mode, block_n=block_n,
                             block_k=block_k, chunk=chunk, dedup=dedup,
                             plan=plan)
    return logps_from_z(z)
