"""Eq. 9 descent-direction kernel — pl.pallas_call + BlockSpec.

One fused VMEM pass computes, per feature-row tile:
  * the row L2 norms (the L2,1 group reduction),
  * the three-case Eq. 9 select (nonzero / elem-zero / row-zero),
so Theta and grad stream from HBM exactly once and the direction streams
out once — vs 5+ elementwise passes in the naive jnp composition. Rows
(feature groups) are the tiled axis; the 2m columns stay whole inside a
tile, keeping the group reduction VMEM-local (this mirrors the paper's
server-shard locality: a feature row never crosses a tile).

Grid: (d / BLOCK_ROWS,). Tiles: theta/grad/out (BLOCK_ROWS, 2m).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(theta_ref, grad_ref, out_ref, *, lam: float, beta: float):
    theta = theta_ref[...].astype(jnp.float32)
    g = -grad_ref[...].astype(jnp.float32)

    rn = jnp.sqrt(jnp.sum(theta * theta, axis=-1, keepdims=True))
    row_nonzero = rn > 0.0
    safe_rn = jnp.where(row_nonzero, rn, 1.0)

    s = g - lam * theta / safe_rn
    d_a = s - beta * jnp.sign(theta)
    d_b = jnp.maximum(jnp.abs(s) - beta, 0.0) * jnp.sign(s)
    v = jnp.maximum(jnp.abs(g) - beta, 0.0) * jnp.sign(g)
    vn = jnp.sqrt(jnp.sum(v * v, axis=-1, keepdims=True))
    safe_vn = jnp.where(vn > 0.0, vn, 1.0)
    d_c = jnp.maximum(vn - lam, 0.0) / safe_vn * v

    elem_nonzero = theta != 0.0
    d = jnp.where(row_nonzero, jnp.where(elem_nonzero, d_a, d_b), d_c)
    out_ref[...] = d.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lam", "beta", "block_rows", "interpret"))
def owlqn_direction(
    theta: jax.Array,  # (d, 2m)
    grad: jax.Array,  # (d, 2m)
    lam: float,
    beta: float,
    *,
    block_rows: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    d, m2 = theta.shape
    block_rows = min(block_rows, d)
    assert d % block_rows == 0, (d, block_rows)
    return pl.pallas_call(
        functools.partial(_kernel, lam=float(lam), beta=float(beta)),
        grid=(d // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, m2), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, m2), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, m2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d, m2), theta.dtype),
        interpret=interpret,
    )(theta, grad)
