"""Jit'd public wrapper with backend dispatch."""
import jax

from repro.kernels.owlqn_direction.owlqn_direction import owlqn_direction
from repro.kernels.owlqn_direction.ref import owlqn_direction_ref


def direction(theta, grad, lam, beta, *, use_kernel: bool | None = None,
              interpret: bool = False, block_rows: int = 1024):
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel or interpret:
        return owlqn_direction(theta, grad, float(lam), float(beta),
                               block_rows=block_rows, interpret=interpret)
    return owlqn_direction_ref(theta, grad, lam, beta)
