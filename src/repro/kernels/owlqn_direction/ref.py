"""Oracle: the library implementation of Eq. 9 (itself tested against
numeric directional derivatives in tests/test_direction.py)."""
from repro.core.direction import descent_direction as owlqn_direction_ref  # noqa: F401
