"""Pure-jnp oracle: sequential selective scan (lax.scan over time)."""
import jax
import jax.numpy as jnp


def mamba1_scan_ref(dt, x, B_in, C_in, A, D, h0=None):
    """Same contract as the kernel: returns (y (B,S,di), h_final (B,di,N))."""
    Bb, S, di = x.shape
    N = B_in.shape[-1]
    dt = dt.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    Bf = B_in.astype(jnp.float32)
    Cf = C_in.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((Bb, di, N), jnp.float32)

    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp
        da = jnp.exp(dt_t[..., None] * Af[None])
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    hT, ys = jax.lax.scan(
        step, h0,
        (dt.transpose(1, 0, 2), xf.transpose(1, 0, 2),
         Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2)),
    )
    y = ys.transpose(1, 0, 2) + D.astype(jnp.float32)[None, None] * xf
    return y.astype(x.dtype), hT
