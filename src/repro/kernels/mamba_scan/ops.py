"""Jit'd public wrapper with backend dispatch."""
import jax

from repro.kernels.mamba_scan.mamba_scan import mamba1_scan
from repro.kernels.mamba_scan.ref import mamba1_scan_ref


def selective_scan(dt, x, B_in, C_in, A, D, h0=None, *,
                   use_kernel: bool | None = None, interpret: bool = False,
                   block_d: int = 256):
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel or interpret:
        return mamba1_scan(dt, x, B_in, C_in, A, D, h0,
                           block_d=block_d, interpret=interpret)
    return mamba1_scan_ref(dt, x, B_in, C_in, A, D, h0)
