"""Mamba1 selective-scan kernel — pl.pallas_call + BlockSpec.

TPU adaptation of the CUDA selective-scan (DESIGN.md §4): the recurrent
state h (BLOCK_D, N) lives in VMEM scratch for the whole sequence; inputs
stream HBM->VMEM once per (batch, channel-tile) and outputs stream back
once. This is the streaming model used for the roofline's analytic SSM
correction — the kernel realises it.

  h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) B_t
  y_t = h_t . C_t + D * x_t

Grid: (B, d_inner / BLOCK_D); each program scans S timesteps with a
fori_loop over rows of its VMEM-resident tiles.
Tiles: dt/x/y (S, BLOCK_D), Bc/Cc (S, N) (shared across channel tiles),
A (BLOCK_D, N), D (1, BLOCK_D).

VMEM budget (production S=4096, BLOCK_D=256, N=16, fp32):
  dt+x+y: 3 * 4096*256*4 = 12.6 MB -> choose BLOCK_D/S so this fits; for
  longer S the caller splits the sequence and chains the carried state
  (init_h input), exactly like decode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, d_ref, h0_ref,
            y_ref, hT_ref, h_scr, *, seq_len: int):
    A = a_ref[0]  # (BLOCK_D, N)
    Dp = d_ref[0]  # (1, BLOCK_D)
    h_scr[...] = h0_ref[0]  # (BLOCK_D, N)

    def step(t, _):
        dt = dt_ref[0, t][:, None]  # (BLOCK_D, 1)
        x = x_ref[0, t][:, None]
        Bv = b_ref[0, t][None, :]  # (1, N)
        Cv = c_ref[0, t][None, :]
        da = jnp.exp(dt * A)  # (BLOCK_D, N)
        h = da * h_scr[...] + (dt * x) * Bv
        h_scr[...] = h
        y = jnp.sum(h * Cv, axis=-1) + Dp[0] * x[:, 0]
        y_ref[0, t] = y.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, seq_len, step, 0)
    hT_ref[0] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def mamba1_scan(
    dt: jax.Array,  # (B, S, di) fp32 (post-softplus)
    x: jax.Array,  # (B, S, di)  (post-conv, post-silu)
    B_in: jax.Array,  # (B, S, N)
    C_in: jax.Array,  # (B, S, N)
    A: jax.Array,  # (di, N)  (negative)
    D: jax.Array,  # (di,)
    h0: jax.Array | None = None,  # (B, di, N) carried state
    *,
    block_d: int = 256,
    interpret: bool = False,
):
    """Returns (y (B,S,di), h_final (B,di,N))."""
    Bb, S, di = x.shape
    N = B_in.shape[-1]
    block_d = min(block_d, di)
    assert di % block_d == 0
    nd = di // block_d
    if h0 is None:
        h0 = jnp.zeros((Bb, di, N), jnp.float32)

    f32 = lambda t: t.astype(jnp.float32)
    y, hT = pl.pallas_call(
        functools.partial(_kernel, seq_len=S),
        grid=(Bb, nd),
        in_specs=[
            pl.BlockSpec((1, S, block_d), lambda b, i: (b, 0, i)),  # dt
            pl.BlockSpec((1, S, block_d), lambda b, i: (b, 0, i)),  # x
            pl.BlockSpec((1, S, N), lambda b, i: (b, 0, 0)),  # B
            pl.BlockSpec((1, S, N), lambda b, i: (b, 0, 0)),  # C
            pl.BlockSpec((1, block_d, N), lambda b, i: (0, i, 0)),  # A
            pl.BlockSpec((1, 1, block_d), lambda b, i: (0, 0, i)),  # D
            pl.BlockSpec((1, block_d, N), lambda b, i: (b, i, 0)),  # h0
        ],
        out_specs=[
            pl.BlockSpec((1, S, block_d), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, block_d, N), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, S, di), x.dtype),
            jax.ShapeDtypeStruct((Bb, di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(f32(dt), f32(x), f32(B_in), f32(C_in), f32(A)[None], f32(D)[None, None],
      f32(h0))
    return y, hT
