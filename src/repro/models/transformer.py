"""Composable decoder-only model zoo covering all assigned families.

Families and block layout:
  dense / vlm / audio : [norm->attn->res, norm->(swiglu|gelu)->res] x L
  moe                 : [norm->attn->res, norm->moe_ffn->res] x L
  ssm (mamba1)        : [norm->mamba1->res] x L
  hybrid (zamba2)     : groups of k mamba2 layers followed by ONE SHARED
                        transformer block (same params every group)

Entry points:
  init_model / param_specs          parameters + production shardings
  forward                            full-sequence logits (train path)
  prefill                            logits for last token + filled caches
  decode_step                        1 token with KV / SSM / window caches
  make_train_step / make_serve_step  jit-able step builders
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

Params = dict


def _dt(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _pdt(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# ===================================================================== init
def _init_block(key, cfg: ArchConfig, dtype) -> Params:
    """One layer's params (unstacked)."""
    ks = jax.random.split(key, 4)
    if cfg.family == "ssm":
        p = {"mamba": S.init_mamba1(ks[0], cfg, dtype)}
        if cfg.norm_type == "rmsnorm":
            p["norm"] = jnp.ones((cfg.d_model,), dtype)
        return p
    if cfg.family == "hybrid":
        p = {"mamba": S.init_mamba2(ks[0], cfg, dtype)}
        if cfg.norm_type == "rmsnorm":
            p["norm"] = jnp.ones((cfg.d_model,), dtype)
        return p
    p = {"attn": L.init_attention(ks[0], cfg, dtype)}
    if cfg.num_experts:
        p["ffn"] = M.init_moe(ks[1], cfg, dtype)
    else:
        p["ffn"] = L.init_mlp(ks[1], cfg, dtype)
    if cfg.norm_type == "rmsnorm":
        p["norm1"] = jnp.ones((cfg.d_model,), dtype)
        p["norm2"] = jnp.ones((cfg.d_model,), dtype)
    return p


def _init_shared_block(key, cfg: ArchConfig, dtype) -> Params:
    ks = jax.random.split(key, 2)
    p = {"attn": L.init_attention(ks[0], cfg, dtype),
         "ffn": L.init_mlp(ks[1], cfg, dtype)}
    if cfg.norm_type == "rmsnorm":
        p["norm1"] = jnp.ones((cfg.d_model,), dtype)
        p["norm2"] = jnp.ones((cfg.d_model,), dtype)
    return p


def init_model(cfg: ArchConfig, key: jax.Array) -> Params:
    dtype = _pdt(cfg)
    k_emb, k_layers, k_head, k_shared = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    stacked = jax.vmap(lambda k: _init_block(k, cfg, dtype))(layer_keys)
    params: Params = {"layers": stacked}
    params["embed"] = (
        cfg.d_model ** -0.5
        * jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model), dtype)
    )
    if cfg.norm_type == "rmsnorm":
        params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            cfg.d_model ** -0.5
            * jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size), dtype)
        )
    if cfg.shared_attn_every:
        params["shared"] = _init_shared_block(k_shared, cfg, dtype)
    return params


# ============================================================ param specs
def _block_specs(cfg: ArchConfig, stacked: bool) -> Params:
    """PartitionSpecs mirroring _init_block; prepend None for the L axis."""
    pre = (None,) if stacked else ()

    def s(*axes):
        return P(*(pre + axes))

    if cfg.family in ("ssm", "hybrid"):
        if cfg.ssm_version == 1 or cfg.family == "ssm":
            mamba = {
                "in_proj": s("data", "model"),
                "conv_w": s(None, "model"),
                "conv_b": s("model"),
                "x_proj": s("model", None),
                "dt_proj": s(None, "model"),
                "dt_bias": s("model"),
                "A_log": s("model", None),
                "D": s("model"),
                "out_proj": s("model", "data"),
            }
        else:
            mamba = {
                "in_proj": s("data", "model"),
                "conv_w": s(None, "model"),
                "conv_b": s("model"),
                "dt_bias": s(None),
                "A_log": s(None),
                "D": s(None),
                "norm_scale": s("model"),
                "out_proj": s("model", "data"),
            }
        p = {"mamba": mamba}
        if cfg.norm_type == "rmsnorm":
            p["norm"] = s(None)
        return p

    attn = {
        "wq": s("data", "model"),
        "wk": s("data", "model"),
        "wv": s("data", "model"),
        "wo": s("model", "data"),
    }
    if cfg.qkv_bias:
        attn.update({"bq": s("model"), "bk": s("model"), "bv": s("model")})
    p = {"attn": attn}
    if cfg.num_experts:
        p["ffn"] = {
            "router": s(None, None),
            "w1": s("model", None, "data"),
            "w3": s("model", None, "data"),
            "w2": s("model", "data", None),
        }
    elif cfg.mlp_type == "swiglu":
        p["ffn"] = {"w1": s("data", "model"), "w3": s("data", "model"),
                    "w2": s("model", "data")}
    else:
        p["ffn"] = {"w1": s("data", "model"), "w2": s("model", "data")}
    if cfg.norm_type == "rmsnorm":
        p["norm1"] = s(None)
        p["norm2"] = s(None)
    return p


def _shared_block_specs(cfg: ArchConfig) -> Params:
    """The hybrid shared block is a TRANSFORMER block (attn + mlp)."""
    attn = {"wq": P("data", "model"), "wk": P("data", "model"),
            "wv": P("data", "model"), "wo": P("model", "data")}
    if cfg.qkv_bias:
        attn.update({"bq": P("model"), "bk": P("model"), "bv": P("model")})
    if cfg.mlp_type == "swiglu":
        ffn = {"w1": P("data", "model"), "w3": P("data", "model"),
               "w2": P("model", "data")}
    else:
        ffn = {"w1": P("data", "model"), "w2": P("model", "data")}
    p = {"attn": attn, "ffn": ffn}
    if cfg.norm_type == "rmsnorm":
        p["norm1"] = P(None)
        p["norm2"] = P(None)
    return p


def param_specs(cfg: ArchConfig, model_size: int = 16) -> Params:
    """Production shardings. Explicit pjit arg shardings must divide
    evenly, so odd vocab sizes (granite 49155, internvl2 92553) keep the
    vocab axis unsharded and rely on the d axis only."""
    specs: Params = {"layers": _block_specs(cfg, stacked=True)}
    vocab_ok = cfg.vocab_size % model_size == 0
    specs["embed"] = P("model", "data") if vocab_ok else P(None, "data")
    if cfg.norm_type == "rmsnorm":
        specs["final_norm"] = P(None)
    if not cfg.tie_embeddings:
        specs["lm_head"] = P("data", "model") if vocab_ok else P("data", None)
    if cfg.shared_attn_every:
        specs["shared"] = _shared_block_specs(cfg)
    return specs


# ============================================================ block forward
def _dp(mesh) -> tuple[str, ...]:
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _constrain(x, mesh, spec):
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec)
    )


def _attn_full(h, p, cfg: ArchConfig, positions, mesh, dp):
    """Full-sequence causal attention sub-block (pre-norm, residual)."""
    x = L.apply_norm(h, p.get("norm1"), cfg)
    # NOTE §Perf qwen_train/opt3: an explicit pre-QKV all-gather constraint
    # here was tried and REFUTED (t_coll 27->35 s, t_mem 24->41 s): GSPMD's
    # own placement of the S->replicated reshard beats the hand-placed one.
    q, k, v = L.qkv_proj(x, p["attn"], cfg)
    cos, sin = L.rope_cos_sin(positions, cfg.resolved_head_dim, cfg.rope_theta)
    cos_b, sin_b = cos[None, :, None, :], sin[None, :, None, :]
    q = L.apply_rope(q, cos_b, sin_b)
    k = L.apply_rope(k, cos_b, sin_b)
    rep = cfg.num_heads // cfg.num_kv_heads
    kr = L.repeat_kv(k, rep)
    vr = L.repeat_kv(v, rep)
    if cfg.attn_shard == "head_dim":
        hspec = P(dp, None, None, "model")
    else:
        hspec = P(dp, None, "model", None)
    q = _constrain(q, mesh, hspec)
    kr = _constrain(kr, mesh, hspec)
    vr = _constrain(vr, mesh, hspec)
    o = L.chunked_causal_attention(q, kr, vr, chunk=cfg.attn_chunk,
                                   unroll=cfg.unroll_layers)
    B, Sq = h.shape[:2]
    o = o.reshape(B, Sq, -1)
    return h + o @ p["attn"]["wo"].astype(o.dtype), (k, v)


def _ffn_full(h, p, cfg: ArchConfig, mesh, dp, batch_sharded=True):
    x = L.apply_norm(h, p.get("norm2"), cfg)
    if cfg.num_experts:
        moe_mesh = mesh if (mesh is not None and batch_sharded) else None
        out, aux = M.moe_ffn(x, p["ffn"], cfg, mesh=moe_mesh)
    else:
        out, aux = L.mlp_apply(x, p["ffn"], cfg), jnp.zeros((), jnp.float32)
    return h + out, aux


def _hspec(cfg, dp):
    """Inter-block activation sharding: baseline replicates S; the
    seq_parallel variant shards S over 'model' (Megatron-SP), dividing the
    saved scan carries by the model-axis size."""
    return P(dp, "model", None) if cfg.seq_parallel else P(dp, None, None)


def _transformer_block(h, p, cfg, positions, mesh, dp, batch_sharded=True):
    h, kv = _attn_full(h, p, cfg, positions, mesh, dp)
    h, aux = _ffn_full(h, p, cfg, mesh, dp, batch_sharded)
    h = _constrain(h, mesh, _hspec(cfg, dp))
    return h, kv, aux


def _ssm_block(h, p, cfg, mesh, dp):
    x = L.apply_norm(h, p.get("norm"), cfg)
    if cfg.family == "ssm":
        y = S.mamba1_forward(x, p["mamba"], cfg)
    else:
        y = S.mamba2_forward(x, p["mamba"], cfg, chunk=cfg.ssd_chunk)
    h = h + y
    return _constrain(h, mesh, _hspec(cfg, dp))


# ============================================================ full forward
def embed_tokens(params, cfg: ArchConfig, tokens):
    e = params["embed"]
    h = jnp.take(e, tokens, axis=0).astype(_dt(cfg))
    return h


def lm_logits(params, cfg: ArchConfig, h):
    if cfg.tie_embeddings:
        w = params["embed"].astype(h.dtype).T
    else:
        w = params["lm_head"].astype(h.dtype)
    return h @ w


def _final_norm(params, cfg, h):
    return L.apply_norm(h, params.get("final_norm"), cfg)


def forward(
    params: Params,
    cfg: ArchConfig,
    tokens: jax.Array | None = None,  # (B, S_text)
    embeds: jax.Array | None = None,  # (B, S, d) modality-stub input
    prefix_embeds: jax.Array | None = None,  # (B, P, d) e.g. vision patches
    mesh=None,
    batch_sharded: bool = True,
    remat: bool = True,
    return_hidden: bool = False,
):
    """Full-sequence forward. Returns (logits (B,S,V), aux_loss) — or
    (final-norm hidden states (B,S,d), aux_loss) with return_hidden."""
    dp = _dp(mesh)
    if embeds is not None:
        h = embeds.astype(_dt(cfg))
    else:
        h = embed_tokens(params, cfg, tokens)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    B, Stot, _ = h.shape
    h = _constrain(h, mesh, P(dp, None, None))
    positions = jnp.arange(Stot)

    if cfg.family in ("ssm",):
        def body(hc, lp):
            return _ssm_block(hc, lp, cfg, mesh, dp), None

        body = jax.checkpoint(body) if remat else body
        h, _ = jax.lax.scan(body, h, params["layers"], unroll=cfg.unroll_layers)
        aux = jnp.zeros((), jnp.float32)
    elif cfg.family == "hybrid":
        k = cfg.shared_attn_every
        nl, J = cfg.num_layers, cfg.num_layers // max(cfg.shared_attn_every, 1)
        assert k and nl % k == 0, (nl, k)
        grouped = jax.tree.map(
            lambda x: x.reshape((J, k) + x.shape[1:]), params["layers"]
        )

        def group(hc, gp):
            def inner(hc2, lp):
                return _ssm_block(hc2, lp, cfg, mesh, dp), None

            hc, _ = jax.lax.scan(inner, hc, gp, unroll=cfg.unroll_layers)
            hc, _kv, _aux = _transformer_block(
                hc, params["shared"], cfg, positions, mesh, dp, batch_sharded
            )
            return hc, None

        group = jax.checkpoint(group) if remat else group
        h, _ = jax.lax.scan(group, h, grouped, unroll=cfg.unroll_layers)
        aux = jnp.zeros((), jnp.float32)
    else:
        def body(hc, lp):
            hc, _kv, aux_l = _transformer_block(
                hc, lp, cfg, positions, mesh, dp, batch_sharded
            )
            return hc, aux_l

        body = jax.checkpoint(body) if remat else body
        h, auxs = jax.lax.scan(body, h, params["layers"], unroll=cfg.unroll_layers)
        aux = jnp.sum(auxs)

    h = _final_norm(params, cfg, h)
    if return_hidden:
        return h, aux
    logits = lm_logits(params, cfg, h)
    logits = _constrain(logits, mesh, P(dp, None, "model"))
    return logits, aux


# =============================================================== loss/train
def cross_entropy(logits, labels, weights=None):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if weights is None:
        return -jnp.mean(ll)
    w = weights.astype(jnp.float32)
    return -jnp.sum(ll * w) / jnp.maximum(jnp.sum(w), 1.0)


def chunked_cross_entropy(params, cfg: ArchConfig, h, labels, weights,
                          mesh, chunk: int):
    """CE scanned over sequence chunks: the (B,S,V) logits tensor is never
    materialised (peak is (B,chunk,V)); the chunk body is remat'd so the
    backward recomputes per-chunk logits instead of saving them."""
    B, S, d = h.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    hc = h.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)
    wc = (weights.reshape(B, nc, chunk).transpose(1, 0, 2)
          if weights is not None else None)
    dp = _dp(mesh)

    def body(carry, inp):
        if wc is None:
            hcb, lcb = inp
            w = None
        else:
            hcb, lcb, w = inp
        logits = lm_logits(params, cfg, hcb)
        logits = _constrain(logits, mesh, P(dp, None, "model"))
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, lcb[..., None], axis=-1)[..., 0]
        if w is None:
            s, n = -jnp.sum(ll), jnp.asarray(ll.size, jnp.float32)
        else:
            wf = w.astype(jnp.float32)
            s, n = -jnp.sum(ll * wf), jnp.sum(wf)
        return (carry[0] + s, carry[1] + n), None

    body = jax.checkpoint(body)
    xs = (hc, lc) if wc is None else (hc, lc, wc)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), xs)
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, cfg: ArchConfig, batch: dict, mesh=None):
    labels = batch["labels"]
    if cfg.ce_chunk:
        h, aux = forward(
            params, cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            prefix_embeds=batch.get("prefix_embeds"),
            mesh=mesh,
            return_hidden=True,
        )
        pad = h.shape[1] - labels.shape[1]
        if pad:  # prefix positions (vlm) carry no LM loss
            h = h[:, pad:]
        ce = chunked_cross_entropy(params, cfg, h, labels,
                                   batch.get("loss_weights"), mesh,
                                   cfg.ce_chunk)
        return ce + cfg.router_aux_coef * aux, (ce, aux)
    logits, aux = forward(
        params, cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        prefix_embeds=batch.get("prefix_embeds"),
        mesh=mesh,
    )
    pad = logits.shape[1] - labels.shape[1]
    if pad:  # prefix positions (vlm) carry no LM loss
        logits = logits[:, pad:]
    ce = cross_entropy(logits, labels, batch.get("loss_weights"))
    return ce + cfg.router_aux_coef * aux, (ce, aux)


def make_train_step(cfg: ArchConfig, mesh=None, lr: float = 3e-4):
    from repro.optim import AdamW

    opt = AdamW(lr=lr, weight_decay=0.01)

    def train_step(params, opt_state, batch):
        (loss, (ce, aux)), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, mesh), has_aux=True
        )(params)
        params, opt_state = opt.apply(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "ce": ce, "aux": aux}

    return opt, train_step


# ================================================================== caches
def attn_cache_shape(cfg: ArchConfig, B: int, S_max: int):
    return (B, S_max, cfg.num_kv_heads, cfg.resolved_head_dim)


def init_caches(cfg: ArchConfig, B: int, S_max: int, dtype=jnp.bfloat16) -> Any:
    """Decode caches. S_max = window size for sliding-window decode."""
    nl = cfg.num_layers
    if cfg.family == "ssm":
        di, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
        return {
            "conv": jnp.zeros((nl, B, K - 1, di), dtype),
            "ssm": jnp.zeros((nl, B, di, N), jnp.float32),
        }
    if cfg.family == "hybrid":
        di, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
        nh, pd = di // cfg.ssm_headdim, cfg.ssm_headdim
        J = cfg.num_layers // cfg.shared_attn_every
        return {
            "conv": jnp.zeros((nl, B, K - 1, di + 2 * N), dtype),
            "ssm": jnp.zeros((nl, B, nh, pd, N), jnp.float32),
            "k": jnp.zeros((J,) + attn_cache_shape(cfg, B, S_max), dtype),
            "v": jnp.zeros((J,) + attn_cache_shape(cfg, B, S_max), dtype),
        }
    if cfg.kv_cache_dtype == "int8":
        shp = attn_cache_shape(cfg, B, S_max)
        return {
            "k": jnp.zeros((nl,) + shp, jnp.int8),
            "v": jnp.zeros((nl,) + shp, jnp.int8),
            "k_scale": jnp.zeros((nl,) + shp[:-1] + (1,), jnp.bfloat16),
            "v_scale": jnp.zeros((nl,) + shp[:-1] + (1,), jnp.bfloat16),
        }
    return {
        "k": jnp.zeros((nl,) + attn_cache_shape(cfg, B, S_max), dtype),
        "v": jnp.zeros((nl,) + attn_cache_shape(cfg, B, S_max), dtype),
    }


def cache_specs(cfg: ArchConfig, batch_sharded: bool = True,
                dp: tuple[str, ...] = ("data",),
                model_size: int = 16) -> Any:
    """Explicit arg/out shardings must divide evenly (pjit requirement) —
    shard KV heads over `model` when divisible, else shard head_dim
    (always a multiple of 16 across the assigned archs)."""
    bspec = dp if batch_sharded else None
    if cfg.family == "ssm":
        return {"conv": P(None, bspec, None, "model"),
                "ssm": P(None, bspec, "model", None)}
    if cfg.num_kv_heads and cfg.num_kv_heads % model_size == 0:
        kv = P(None, bspec, None, "model", None)
    else:
        kv = P(None, bspec, None, None, "model")  # shard head_dim instead
    if cfg.family == "hybrid":
        return {
            "conv": P(None, bspec, None, "model"),
            "ssm": P(None, bspec, None, None, None),
            "k": kv,
            "v": kv,
        }
    if cfg.kv_cache_dtype == "int8":
        # scales have a singleton last dim -> never shard it
        sc = P(*(list(kv)[:-1] + [None])) if kv[-1] == "model" else kv
        return {"k": kv, "v": kv, "k_scale": sc, "v_scale": sc}
    return {"k": kv, "v": kv}


# ================================================================== prefill
def prefill(params, cfg: ArchConfig, tokens=None, embeds=None,
            prefix_embeds=None, mesh=None, batch_sharded: bool = True):
    """Run the full prompt, return (last-token logits, caches filled with
    the first S positions)."""
    dp = _dp(mesh)
    if embeds is not None:
        h = embeds.astype(_dt(cfg))
    else:
        h = embed_tokens(params, cfg, tokens)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    B, Stot, _ = h.shape
    h = _constrain(h, mesh, P(dp, None, None))
    positions = jnp.arange(Stot)

    if cfg.family == "ssm":
        def body(hc, lp):
            x = L.apply_norm(hc, lp.get("norm"), cfg)
            y, st = S.mamba1_forward(x, lp["mamba"], cfg, return_state=True)
            hc = _constrain(hc + y, mesh, P(dp, None, None))
            return hc, (st["conv"], st["ssm"])

        h, (convs, ssms) = jax.lax.scan(body, h, params["layers"], unroll=cfg.unroll_layers)
        caches = {"conv": convs, "ssm": ssms}
    elif cfg.family == "hybrid":
        k = cfg.shared_attn_every
        J = cfg.num_layers // k
        grouped = jax.tree.map(
            lambda x: x.reshape((J, k) + x.shape[1:]), params["layers"]
        )

        def group(hc, gp):
            def inner(hc2, lp):
                x = L.apply_norm(hc2, lp.get("norm"), cfg)
                y, st = S.mamba2_forward(x, lp["mamba"], cfg,
                                         chunk=cfg.ssd_chunk, return_state=True)
                hc2 = _constrain(hc2 + y, mesh, P(dp, None, None))
                return hc2, (st["conv"], st["ssm"])

            hc, states = jax.lax.scan(inner, hc, gp, unroll=cfg.unroll_layers)
            hc, (kk, vv), _aux = _transformer_block(
                hc, params["shared"], cfg, positions, mesh, dp, batch_sharded
            )
            return hc, (states, kk, vv)

        h, (states, ks, vs) = jax.lax.scan(group, h, grouped, unroll=cfg.unroll_layers)
        convs, ssms = states
        caches = {
            "conv": convs.reshape((cfg.num_layers,) + convs.shape[2:]),
            "ssm": ssms.reshape((cfg.num_layers,) + ssms.shape[2:]),
            "k": ks, "v": vs,
        }
    else:
        def body(hc, lp):
            hc, (kk, vv), _aux = _transformer_block(
                hc, lp, cfg, positions, mesh, dp, batch_sharded
            )
            return hc, (kk, vv)

        h, (ks, vs) = jax.lax.scan(body, h, params["layers"], unroll=cfg.unroll_layers)
        caches = {"k": ks, "v": vs}

    h = _final_norm(params, cfg, h)
    logits = lm_logits(params, cfg, h[:, -1:])
    logits = _constrain(logits, mesh, P(dp, None, "model"))
    return logits[:, 0], caches


# ================================================================== decode
def _attn_decode(h, p, cfg: ArchConfig, k_cache, v_cache, pos, window, mesh, dp,
                 batch_sharded=True):
    """h (B,1,d); cache (B,S_c,KVH,hd); pos scalar current position."""
    x = L.apply_norm(h, p.get("norm1"), cfg)
    q, k, v = L.qkv_proj(x, p["attn"], cfg)
    cos, sin = L.rope_cos_sin(jnp.asarray(pos)[None], cfg.resolved_head_dim,
                              cfg.rope_theta)
    cos_b, sin_b = cos[None, :, None, :], sin[None, :, None, :]
    q = L.apply_rope(q, cos_b, sin_b)
    k = L.apply_rope(k, cos_b, sin_b)
    if cfg.kv_cache_dtype == "int8":
        S_c = k_cache[0].shape[1]
    else:
        S_c = k_cache.shape[1]
    slot = (pos % S_c) if window else pos

    def upd(cache, new):
        return jax.lax.dynamic_update_slice_in_dim(
            cache, new.astype(cache.dtype), slot, 1)

    if cfg.kv_cache_dtype == "int8":
        k_cache, k_scale = k_cache  # (cache, scale) pairs
        v_cache, v_scale = v_cache

        def quant(x):  # per-(token,head) symmetric int8
            s = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
            s = jnp.maximum(s, 1e-8)
            q = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
            return q, s.astype(jnp.bfloat16)

        kq, ks = quant(k.astype(jnp.float32))
        vq, vs = quant(v.astype(jnp.float32))
        k_cache = upd(k_cache, kq)
        v_cache = upd(v_cache, vq)
        k_scale = upd(k_scale, ks)
        v_scale = upd(v_scale, vs)
        k_deq = k_cache.astype(_dt(cfg)) * k_scale.astype(_dt(cfg))
        v_deq = v_cache.astype(_dt(cfg)) * v_scale.astype(_dt(cfg))
        k_cache, v_cache = (k_cache, k_scale), (v_cache, v_scale)
    else:
        k_cache = upd(k_cache, k)
        v_cache = upd(v_cache, v)
        k_deq = k_cache.astype(_dt(cfg))
        v_deq = v_cache.astype(_dt(cfg))
    valid = jnp.minimum(pos + 1, S_c)
    rep = cfg.num_heads // cfg.num_kv_heads
    kr = L.repeat_kv(k_deq, rep)
    vr = L.repeat_kv(v_deq, rep)
    bs = dp if batch_sharded else None
    if cfg.attn_shard == "head_dim":
        hspec = P(bs, None, None, "model")
    else:
        hspec = P(bs, None, "model", None)
    q = _constrain(q, mesh, hspec)
    kr = _constrain(kr, mesh, hspec)
    vr = _constrain(vr, mesh, hspec)
    o = L.decode_attention(q, kr, vr, valid)
    B = h.shape[0]
    o = o.reshape(B, 1, -1)
    return h + o @ p["attn"]["wo"].astype(o.dtype), k_cache, v_cache


def decode_step(params, cfg: ArchConfig, caches, token=None, embed=None,
                pos=None, window: bool = False, mesh=None,
                batch_sharded: bool = True,
                moe_serving_mode: str = "weight_gather"):
    """One serving step: next-token logits given caches at position `pos`.

    token (B,) int32 or embed (B,d); pos scalar int32.
    """
    dp = _dp(mesh)
    if embed is not None:
        h = embed[:, None, :].astype(_dt(cfg))
    else:
        h = embed_tokens(params, cfg, token[:, None])
    h = _constrain(h, mesh, P(dp, None, None))

    if cfg.family == "ssm":
        def body(hc, inp):
            lp, conv, ssm = inp
            x = L.apply_norm(hc[:, 0], lp.get("norm"), cfg)
            y, st = S.mamba1_decode(x, {"conv": conv, "ssm": ssm}, lp["mamba"], cfg)
            return hc + y[:, None], (st["conv"], st["ssm"])

        h, (convs, ssms) = jax.lax.scan(
            body, h, (params["layers"], caches["conv"], caches["ssm"]),
            unroll=cfg.unroll_layers,
        )
        new_caches = {"conv": convs, "ssm": ssms}
    elif cfg.family == "hybrid":
        k = cfg.shared_attn_every
        J = cfg.num_layers // k
        grouped = jax.tree.map(
            lambda x: x.reshape((J, k) + x.shape[1:]), params["layers"]
        )
        gconv = caches["conv"].reshape((J, k) + caches["conv"].shape[1:])
        gssm = caches["ssm"].reshape((J, k) + caches["ssm"].shape[1:])

        def group(hc, inp):
            gp, conv_g, ssm_g, kc, vc = inp

            def inner(hc2, inp2):
                lp, conv, ssm = inp2
                x = L.apply_norm(hc2[:, 0], lp.get("norm"), cfg)
                y, st = S.mamba2_decode(x, {"conv": conv, "ssm": ssm}, lp["mamba"], cfg)
                return hc2 + y[:, None], (st["conv"], st["ssm"])

            hc, (conv_n, ssm_n) = jax.lax.scan(inner, hc, (gp, conv_g, ssm_g),
                                               unroll=cfg.unroll_layers)
            hc, kc, vc = _attn_decode(hc, params["shared"], cfg, kc, vc, pos,
                                      window, mesh, dp, batch_sharded)
            x = L.apply_norm(hc, params["shared"].get("norm2"), cfg)
            hc = hc + L.mlp_apply(x, params["shared"]["ffn"], cfg)
            return hc, (conv_n, ssm_n, kc, vc)

        h, (convs, ssms, ks, vs) = jax.lax.scan(
            group, h, (grouped, gconv, gssm, caches["k"], caches["v"]),
            unroll=cfg.unroll_layers,
        )
        new_caches = {
            "conv": convs.reshape(caches["conv"].shape),
            "ssm": ssms.reshape(caches["ssm"].shape),
            "k": ks, "v": vs,
        }
    else:
        def body(hc, inp):
            if cfg.kv_cache_dtype == "int8":
                lp, kc, vc, ksc, vsc = inp
                kc, vc = (kc, ksc), (vc, vsc)
            else:
                lp, kc, vc = inp
            hc, kc, vc = _attn_decode(hc, lp, cfg, kc, vc, pos, window, mesh,
                                      dp, batch_sharded)
            if cfg.kv_cache_dtype == "int8":
                (kc, ksc), (vc, vsc) = kc, vc
            x = L.apply_norm(hc, lp.get("norm2"), cfg)
            if cfg.num_experts:
                moe_mesh = mesh if (mesh is not None and batch_sharded) else None
                out, _aux = M.moe_ffn(x, lp["ffn"], cfg, mesh=moe_mesh,
                                      serving_mode=moe_serving_mode)
            else:
                out = L.mlp_apply(x, lp["ffn"], cfg)
            hc = hc + out
            hc = _constrain(hc, mesh, P(dp, None, None))
            if cfg.kv_cache_dtype == "int8":
                return hc, (kc, vc, ksc, vsc)
            return hc, (kc, vc)

        if cfg.kv_cache_dtype == "int8":
            h, (ks, vs, kss, vss) = jax.lax.scan(
                body, h,
                (params["layers"], caches["k"], caches["v"],
                 caches["k_scale"], caches["v_scale"]),
                unroll=cfg.unroll_layers,
            )
            new_caches = {"k": ks, "v": vs, "k_scale": kss, "v_scale": vss}
        else:
            h, (ks, vs) = jax.lax.scan(
                body, h, (params["layers"], caches["k"], caches["v"]),
                unroll=cfg.unroll_layers,
            )
            new_caches = {"k": ks, "v": vs}

    h = _final_norm(params, cfg, h)
    logits = lm_logits(params, cfg, h[:, 0])
    logits = _constrain(logits, mesh, P(dp, "model"))
    return logits, new_caches


def make_serve_step(cfg: ArchConfig, mesh=None, window: bool = False,
                    batch_sharded: bool = True,
                    moe_serving_mode: str = "weight_gather"):
    def serve_step(params, caches, token_or_embed, pos):
        kw = {"embed": token_or_embed} if cfg.embeds_in else {"token": token_or_embed}
        return decode_step(params, cfg, caches, pos=pos, window=window,
                           mesh=mesh, batch_sharded=batch_sharded,
                           moe_serving_mode=moe_serving_mode, **kw)

    return serve_step
