"""Autoregressive generation driver over ``decode_step``.

Production serving loop for the model zoo: prefill the prompt, then
sample tokens with temperature / top-k under a jit'd step. Works for
every family (KV caches, SSM states, hybrid, sliding window).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import decode_step, init_caches, prefill


def sample_logits(logits: jax.Array, key: jax.Array, temperature: float = 1.0,
                  top_k: int = 0) -> jax.Array:
    """logits (B, V) -> tokens (B,)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def generate(
    params: Any,
    cfg: ArchConfig,
    prompt: jax.Array,  # (B, S_prompt) int32
    max_new_tokens: int,
    key: jax.Array,
    temperature: float = 1.0,
    top_k: int = 0,
    window: bool = False,
    mesh=None,
) -> jax.Array:
    """Returns (B, max_new_tokens) sampled continuations."""
    B, S_p = prompt.shape
    cache_len = (min(cfg.sliding_window, S_p + max_new_tokens)
                 if window else S_p + max_new_tokens)

    logits, caches0 = prefill(params, cfg, tokens=prompt, mesh=mesh)
    caches = init_caches(cfg, B, cache_len)
    # copy prefill caches into the (larger) decode buffers
    caches = jax.tree.map(
        lambda big, small: jax.lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), 0, axis=2)
        if big.ndim >= 3 and big.shape[2] >= small.shape[2] else
        small.astype(big.dtype),
        caches, caches0,
    )

    step_fn = jax.jit(
        lambda c, tok, pos: decode_step(params, cfg, c, token=tok, pos=pos,
                                        window=window, mesh=mesh))

    def body(carry, i):
        caches, tok, key = carry
        key, sub = jax.random.split(key)
        logits, caches = step_fn(caches, tok, S_p + i)
        nxt = sample_logits(logits, sub, temperature, top_k)
        return (caches, nxt, key), nxt

    tok0 = sample_logits(logits, key, temperature, top_k)
    outs = [tok0]
    carry = (caches, tok0, key)
    for i in range(max_new_tokens - 1):
        carry, nxt = body(carry, jnp.asarray(i, jnp.int32))
        outs.append(nxt)
    return jnp.stack(outs, axis=1)
