"""Mixture-of-Experts FFN — the paper's divide-and-conquer gating scaled up.

LS-PLM's softmax-dividing / per-region-fitting structure (Eq. 2) is exactly
a token-level MoE router + experts; this module is where the paper's idea
lives inside the transformer zoo (DESIGN.md §5).

Implementation: sort-based token dispatch with capacity truncation
(drop-on-overflow), replicated-activation expert parallelism:

  * activations (B,S,d) are sharded over `data` and replicated over `model`;
  * experts are sharded over `model` (E_loc = E / model_size per device);
  * each device routes its local tokens to ITS experts only (no all-to-all
    needed with replicated activations), computes them, and the partial
    outputs are `psum`ed over `model`.

The same local routine runs unsharded (mesh=None) for CPU smoke tests, so
the shard_map path is testably identical to the reference path.

Router load-balance auxiliary loss follows Switch Transformer:
  aux = E * sum_e( frac_tokens_e * mean_prob_e ).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig


def init_moe(key, cfg: ArchConfig, dtype):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": d ** -0.5 * jax.random.normal(ks[0], (d, E), dtype),
        "w1": d ** -0.5 * jax.random.normal(ks[1], (E, d, f), dtype),
        "w3": d ** -0.5 * jax.random.normal(ks[2], (E, d, f), dtype),
        "w2": f ** -0.5 * jax.random.normal(ks[3], (E, f, d), dtype),
    }


def _route(x_flat: jax.Array, router_w: jax.Array, k: int):
    """x (T,d) -> (gate (T,k) fp32, idx (T,k) int, probs (T,E) fp32)."""
    logits = (x_flat @ router_w.astype(x_flat.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)  # renormalise top-k
    return gate, idx, probs


def _aux_loss(probs: jax.Array, idx: jax.Array, num_experts: int) -> jax.Array:
    """Switch-style load-balance loss over the local token set."""
    T = probs.shape[0]
    assign = jax.nn.one_hot(idx[:, 0], num_experts, dtype=jnp.float32)
    frac_tokens = jnp.mean(assign, axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(frac_tokens * mean_prob)


def _dispatch_compute(
    x_flat: jax.Array,  # (T, d)
    gate: jax.Array,  # (T, k) fp32
    idx: jax.Array,  # (T, k)
    w1: jax.Array,  # (E_loc, d, f)
    w3: jax.Array,
    w2: jax.Array,
    *,
    expert_lo: int,
    capacity: int,
) -> jax.Array:
    """Sort-based dispatch of local tokens to the local expert slice.

    Returns the partial output (T, d): tokens not routed to a local expert
    (or dropped by capacity) contribute zero.
    """
    T, d = x_flat.shape
    E_loc = w1.shape[0]
    k = idx.shape[1]

    flat_e = idx.reshape(-1) - expert_lo  # (T*k,) local expert id or OOR
    mine = (flat_e >= 0) & (flat_e < E_loc)
    sort_key = jnp.where(mine, flat_e, E_loc)  # foreign tokens sort last
    order = jnp.argsort(sort_key, stable=True)  # (T*k,)
    sorted_e = sort_key[order]
    # position within expert group = rank - first rank of that expert
    ranks = jnp.arange(T * k)
    counts = jnp.bincount(sorted_e, length=E_loc + 1)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)])[:-1]
    pos = ranks - starts[sorted_e]
    keep = (sorted_e < E_loc) & (pos < capacity)
    slot = jnp.where(keep, sorted_e * capacity + pos, E_loc * capacity)  # drop slot

    token_of = order // k  # original token per assignment
    # scatter tokens into (E_loc*capacity + 1, d) buffer (last row = dropped)
    buf = jnp.zeros((E_loc * capacity + 1, d), x_flat.dtype)
    buf = buf.at[slot].set(x_flat[token_of])
    eb = buf[: E_loc * capacity].reshape(E_loc, capacity, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, w1.astype(eb.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", eb, w3.astype(eb.dtype))
    eo = jnp.einsum("ecf,efd->ecd", h, w2.astype(eb.dtype))
    eo = jnp.concatenate([eo.reshape(E_loc * capacity, d),
                          jnp.zeros((1, d), eo.dtype)], axis=0)

    out_per_assign = eo[slot] * gate.reshape(-1, 1)[order].astype(eo.dtype)
    out = jnp.zeros_like(x_flat).at[token_of].add(
        jnp.where(keep[:, None], out_per_assign, 0.0)
    )
    return out


def capacity_for(tokens: int, num_experts: int, top_k: int, factor: float = 1.25) -> int:
    cap = int(tokens * top_k / num_experts * factor)
    return max(8, -(-cap // 8) * 8)  # round up to 8


def moe_ffn(
    x: jax.Array,  # (B, S, d)
    params: dict,
    cfg: ArchConfig,
    mesh: jax.sharding.Mesh | None = None,
    capacity_factor: float = 1.25,
    serving_mode: str = "weight_gather",
) -> tuple[jax.Array, jax.Array]:
    """Returns (out (B,S,d), aux_loss scalar).

    Two expert-parallel communication plans (EXPERIMENTS.md §Perf):
      * "weight_gather" (training default): expert weights are FSDP-
        sharded over `data` on the d_ff axis and all-gathered at the
        shard_map boundary. Amortised over B*S train tokens this is
        cheap and keeps per-chip parameter memory minimal.
      * "token_gather" (serving): weights stay fully local (E over
        `model`, d_ff over `data`); the (tiny) token activations are
        all-gathered over `data` instead, every device computes its
        d_ff-slice of its experts, and partial outputs psum over both
        axes. For decode (few tokens, huge weights) this moves orders of
        magnitude fewer bytes.
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k

    if mesh is None or "model" not in mesh.axis_names:
        x_flat = x.reshape(-1, d)
        gate, idx, probs = _route(x_flat, params["router"], k)
        cap = capacity_for(x_flat.shape[0], E, k, capacity_factor)
        out = _dispatch_compute(
            x_flat, gate, idx, params["w1"], params["w3"], params["w2"],
            expert_lo=0, capacity=cap,
        )
        return out.reshape(B, S, d), _aux_loss(probs, idx, E)

    from jax.experimental.shard_map import shard_map

    model_size = mesh.shape["model"]
    assert E % model_size == 0, (E, model_size)
    E_loc = E // model_size
    import math

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = math.prod(mesh.shape[a] for a in dp) if dp else 1
    B_loc = B // dp_size

    if serving_mode == "token_gather" and dp:
        cap = capacity_for(B * S, E, k, capacity_factor)

        def local_tg(xl, router_w, w1, w3, w2):
            # xl (B_loc,S,d); w* (E_loc, d, f_loc) stay LOCAL (no gather)
            xg = jax.lax.all_gather(xl, dp, axis=0, tiled=True)  # (B,S,d)
            T = xg.shape[0] * xg.shape[1]
            x_flat = xg.reshape(T, d)
            gate, idx, probs = _route(x_flat, router_w, k)
            midx = jax.lax.axis_index("model")
            out = _dispatch_compute(
                x_flat, gate, idx, w1, w3, w2,
                expert_lo=midx * E_loc, capacity=cap,
            )
            # partial over experts (model) AND d_ff slices (data):
            # psum_scatter back to this device's batch shard.
            out = jax.lax.psum(out.reshape((dp_size,) + xl.shape), "model")
            out = jax.lax.psum_scatter(out, dp, scatter_dimension=0,
                                       tiled=False)
            aux = _aux_loss(probs, idx, E)
            return out.reshape(xl.shape), aux

        out, aux = shard_map(
            local_tg,
            mesh=mesh,
            in_specs=(P(dp, None, None), P(), P("model", None, dp),
                      P("model", None, dp), P("model", dp, None)),
            out_specs=(P(dp, None, None), P()),
            check_rep=False,
        )(x, params["router"], params["w1"], params["w3"], params["w2"])
        return out, aux

    cap = capacity_for(B_loc * S, E, k, capacity_factor)

    def local(xl, router_w, w1, w3, w2):
        # xl (B_loc, S, d) — replicated over model; w* hold local experts
        # (the data-axis d_ff shards were all-gathered at the boundary)
        T = xl.shape[0] * xl.shape[1]
        x_flat = xl.reshape(T, d)
        gate, idx, probs = _route(x_flat, router_w, k)
        midx = jax.lax.axis_index("model")
        out = _dispatch_compute(
            x_flat, gate, idx, w1, w3, w2,
            expert_lo=midx * E_loc, capacity=cap,
        )
        out = jax.lax.psum(out, "model")
        aux = _aux_loss(probs, idx, E)  # identical on every model shard
        aux = jax.lax.pmean(aux, dp) if dp else aux
        return out.reshape(xl.shape), aux

    out, aux = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(dp, None, None), P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(dp, None, None), P()),
        check_rep=False,
    )(x, params["router"], params["w1"], params["w3"], params["w2"])
    return out, aux


def moe_ffn_dense_reference(x: jax.Array, params: dict, cfg: ArchConfig):
    """O(T·E) dense oracle (no capacity drops) for tests: every token is
    processed by its top-k experts exactly."""
    B, S, d = x.shape
    x_flat = x.reshape(-1, d)
    gate, idx, probs = _route(x_flat, params["router"], cfg.top_k)
    all_out = jnp.stack([
        (jax.nn.silu(x_flat @ params["w1"][e].astype(x_flat.dtype))
         * (x_flat @ params["w3"][e].astype(x_flat.dtype)))
        @ params["w2"][e].astype(x_flat.dtype)
        for e in range(cfg.num_experts)
    ], axis=1)  # (T, E, d)
    sel = jnp.take_along_axis(all_out, idx[..., None], axis=1)  # (T,k,d)
    out = jnp.sum(sel * gate[..., None].astype(sel.dtype), axis=1)
    return out.reshape(B, S, d), _aux_loss(probs, idx, cfg.num_experts)
