"""Transformer building blocks (pure functions over param dicts).

Conventions:
  * activations default bf16, params fp32 (cast at use).
  * attention is GQA with `rep = H // KVH`; q shape (B, S, KVH, rep, hd).
  * prefill uses query-chunked attention (no S x S materialisation) so
    32k-token prefill fits; decode attends 1 token against the cache.
  * sliding-window decode uses a ring-buffer cache of window size (the
    long_500k path for attention architectures).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


# --------------------------------------------------------------------- norms
def rmsnorm(x: jax.Array, scale: jax.Array | None, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    if scale is not None:
        x = x * scale.astype(jnp.float32)
    return x.astype(dt)


def nonparametric_layernorm(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo's LN: no learnable scale/bias (arXiv:2402.00838)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def apply_norm(x: jax.Array, scale: jax.Array | None, cfg: ArchConfig) -> jax.Array:
    if cfg.norm_type == "nonparametric":
        return nonparametric_layernorm(x)
    return rmsnorm(x, scale)


# ---------------------------------------------------------------------- rope
def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float):
    """positions (...,) -> cos/sin (..., head_dim/2), fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, *, hd); cos/sin broadcastable (..., S, 1, hd/2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(dt)


# ----------------------------------------------------------------- attention
NEG_INF = -1e30


def repeat_kv(kv: jax.Array, rep: int) -> jax.Array:
    """(B,S,KVH,hd) -> (B,S,KVH*rep,hd). GQA repeat at use-site so caches
    stay KVH-sized while ALL attention tensors share one uniform
    heads-over-model sharding (avoids SPMD resharding conflicts)."""
    if rep == 1:
        return kv
    B, S, KVH, hd = kv.shape
    return jnp.broadcast_to(kv[:, :, :, None], (B, S, KVH, rep, hd)).reshape(
        B, S, KVH * rep, hd)


def chunked_causal_attention(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S, H, hd)  (already GQA-repeated)
    v: jax.Array,
    *,
    chunk: int = 512,
    scale: float | None = None,
    unroll: bool = False,
) -> jax.Array:
    """Causal self-attention scanned over query chunks.

    Peak score memory is (B, H, chunk, S) instead of (B, H, S, S) —
    required at 32k. Returns (B, S, H, hd) in q.dtype.
    """
    B, S, H, hd = q.shape
    scale = scale if scale is not None else hd ** -0.5
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nchunk = S // chunk
    qs = q.reshape(B, nchunk, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    kpos = jnp.arange(S)

    def body(carry, inp):
        ci, qc = inp  # qc (B, chunk, H, hd)
        qpos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqhd,bshd->bhqs", qc, k,
                       preferred_element_type=jnp.float32) * scale
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqs,bshd->bqhd", p, v.astype(p.dtype))
        return carry, o.astype(q.dtype)

    _, out = jax.lax.scan(body, None, (jnp.arange(nchunk), qs), unroll=unroll)
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


def decode_attention(
    q: jax.Array,  # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, S_cache, H, hd)  (already GQA-repeated)
    v_cache: jax.Array,
    valid_len: jax.Array,  # scalar or (B,) number of valid cache slots
    *,
    scale: float | None = None,
) -> jax.Array:
    hd = q.shape[-1]
    scale = scale if scale is not None else hd ** -0.5
    s = jnp.einsum("bqhd,bshd->bhqs", q, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(k_cache.shape[1])
    mask = pos[None] < jnp.reshape(valid_len, (-1, 1))  # (B,S)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqs,bshd->bqhd", p, v_cache.astype(p.dtype))
    return o.astype(q.dtype)


# --------------------------------------------------------------------- mlps
def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w1.astype(x.dtype)) * (x @ w3.astype(x.dtype))
    return h @ w2.astype(x.dtype)


def gelu_mlp(x: jax.Array, w1: jax.Array, w2: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ w1.astype(x.dtype)) @ w2.astype(x.dtype)


# ------------------------------------------------------------- attn params
def init_attention(key, cfg: ArchConfig, dtype):
    d, H, KVH, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    sc = d ** -0.5
    p = {
        "wq": sc * jax.random.normal(ks[0], (d, H * hd), dtype),
        "wk": sc * jax.random.normal(ks[1], (d, KVH * hd), dtype),
        "wv": sc * jax.random.normal(ks[2], (d, KVH * hd), dtype),
        "wo": (H * hd) ** -0.5 * jax.random.normal(ks[3], (H * hd, d), dtype),
    }
    if cfg.qkv_bias:  # qwen1.5
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KVH * hd,), dtype)
        p["bv"] = jnp.zeros((KVH * hd,), dtype)
    return p


def qkv_proj(x: jax.Array, p: dict, cfg: ArchConfig):
    """x (B,S,d) -> q (B,S,H,hd), k/v (B,S,KVH,hd)."""
    B, S, _ = x.shape
    H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return (
        q.reshape(B, S, H, hd),
        k.reshape(B, S, KVH, hd),
        v.reshape(B, S, KVH, hd),
    )


def init_mlp(key, cfg: ArchConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "w1": d ** -0.5 * jax.random.normal(ks[0], (d, f), dtype),
            "w3": d ** -0.5 * jax.random.normal(ks[1], (d, f), dtype),
            "w2": f ** -0.5 * jax.random.normal(ks[2], (f, d), dtype),
        }
    return {
        "w1": d ** -0.5 * jax.random.normal(ks[0], (d, f), dtype),
        "w2": f ** -0.5 * jax.random.normal(ks[1], (f, d), dtype),
    }


def mlp_apply(x: jax.Array, p: dict, cfg: ArchConfig) -> jax.Array:
    if cfg.mlp_type == "swiglu":
        return swiglu(x, p["w1"], p["w3"], p["w2"])
    return gelu_mlp(x, p["w1"], p["w2"])
