from repro.models.transformer import (  # noqa: F401
    cross_entropy,
    decode_step,
    forward,
    init_caches,
    init_model,
    loss_fn,
    make_serve_step,
    make_train_step,
    param_specs,
    prefill,
)
