"""State-space blocks: Mamba1 (falcon-mamba) and Mamba2/SSD (zamba2).

TPU adaptation notes (DESIGN.md §4): Mamba2's scalar-per-head A admits the
chunked SSD matmul formulation — MXU-friendly (intra-chunk blocks are plain
masked matmuls, inter-chunk is a short scan over S/chunk states). Mamba1's
per-(channel,state) decay does NOT admit that factorisation, so its train
path is a `lax.scan` over time (the Pallas kernel tiles it over VMEM).

Shapes:
  mamba1: d_inner = expand*d, state N, conv K, dt_rank R.
  mamba2: heads nh = d_inner / headdim, scalar A per head, ngroups=1.
Decode carries: conv_state (B, K-1, conv_width), ssm_state
  (B, d_inner, N) for mamba1 / (B, nh, headdim, N) for mamba2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


# ------------------------------------------------------------------ helpers
def causal_conv1d(x: jax.Array, w: jax.Array, bias: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv. x (B,S,C), w (K,C) -> (B,S,C)."""
    K = w.shape[0]
    w = w.astype(x.dtype)
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    if bias is not None:
        out = out + bias.astype(x.dtype)[None, None, :]
    return out


def conv_step(conv_state: jax.Array, x_t: jax.Array, w: jax.Array, bias=None):
    """Single decode step. conv_state (B,K-1,C), x_t (B,C)."""
    window = jnp.concatenate([conv_state.astype(x_t.dtype), x_t[:, None, :]], axis=1)
    out = jnp.einsum("bkc,kc->bc", window, w.astype(window.dtype))
    if bias is not None:
        out = out + bias.astype(out.dtype)[None, :]
    return window[:, 1:], out


# =============================================================== Mamba 1 ====
def init_mamba1(key, cfg: ArchConfig, dtype):
    d, di, N, K, R = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv,
                      cfg.resolved_dt_rank)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": d ** -0.5 * jax.random.normal(ks[0], (d, 2 * di), dtype),
        "conv_w": 0.5 * jax.random.normal(ks[1], (K, di), dtype) / K,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": di ** -0.5 * jax.random.normal(ks[2], (di, R + 2 * N), dtype),
        "dt_proj": R ** -0.5 * jax.random.normal(ks[3], (R, di), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))).astype(dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (di, 1))).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": di ** -0.5 * jax.random.normal(ks[5], (di, d), dtype),
    }


def _mamba1_inner(params, cfg, x_conv, z, return_state: bool = False):
    """Shared SSM math after conv. x_conv/z (B,S,di) -> y (B,S,di)."""
    N, R = cfg.ssm_state, cfg.resolved_dt_rank
    xdb = x_conv @ params["x_proj"].astype(x_conv.dtype)  # (B,S,R+2N)
    dt_in, B_ssm, C_ssm = jnp.split(xdb, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        (dt_in @ params["dt_proj"].astype(dt_in.dtype)).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32)
    )  # (B,S,di) fp32
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (di,N)
    B_f = B_ssm.astype(jnp.float32)
    C_f = C_ssm.astype(jnp.float32)
    xf = x_conv.astype(jnp.float32)

    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp  # (B,di),(B,di),(B,N),(B,N)
        da = jnp.exp(dt_t[..., None] * A[None])  # (B,di,N)
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    Bsz, S, di = x_conv.shape
    h0 = jnp.zeros((Bsz, di, N), jnp.float32)
    h_final, ys = jax.lax.scan(
        step, h0,
        (dt.transpose(1, 0, 2), xf.transpose(1, 0, 2),
         B_f.transpose(1, 0, 2), C_f.transpose(1, 0, 2)),
    )
    y = ys.transpose(1, 0, 2) + params["D"].astype(jnp.float32)[None, None] * xf
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_conv.dtype)
    return (y, h_final) if return_state else y


def mamba1_forward(x: jax.Array, params: dict, cfg: ArchConfig,
                   return_state: bool = False):
    """Full-sequence selective scan. x (B,S,d) -> (B,S,d) [+ decode state]."""
    di, K = cfg.d_inner, cfg.ssm_conv
    xz = x @ params["in_proj"].astype(x.dtype)
    x_in, z = jnp.split(xz, [di], axis=-1)
    x_conv = jax.nn.silu(causal_conv1d(x_in, params["conv_w"], params["conv_b"]))
    if not return_state:
        y = _mamba1_inner(params, cfg, x_conv, z)
        return y @ params["out_proj"].astype(y.dtype)
    y, h_final = _mamba1_inner(params, cfg, x_conv, z, return_state=True)
    pad = jnp.zeros((x.shape[0], max(K - 1 - x.shape[1], 0), di), x_in.dtype)
    conv_state = jnp.concatenate([pad, x_in[:, -(K - 1):]], axis=1)
    return y @ params["out_proj"].astype(y.dtype), \
        {"conv": conv_state, "ssm": h_final}


def mamba1_decode(x_t: jax.Array, state: dict, params: dict, cfg: ArchConfig):
    """Single-token step. x_t (B,d); state {conv (B,K-1,di), ssm (B,di,N)}."""
    di, N, R = cfg.d_inner, cfg.ssm_state, cfg.resolved_dt_rank
    xz = x_t @ params["in_proj"].astype(x_t.dtype)
    x_in, z = jnp.split(xz, [di], axis=-1)
    conv_state, x_c = conv_step(state["conv"], x_in, params["conv_w"], params["conv_b"])
    x_c = jax.nn.silu(x_c)
    xdb = x_c @ params["x_proj"].astype(x_c.dtype)
    dt_in, B_ssm, C_ssm = jnp.split(xdb, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        (dt_in @ params["dt_proj"].astype(dt_in.dtype)).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32)
    )
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    da = jnp.exp(dt[..., None] * A[None])
    h = da * state["ssm"] + (dt * x_c.astype(jnp.float32))[..., None] * B_ssm.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, C_ssm.astype(jnp.float32))
    y = y + params["D"].astype(jnp.float32)[None] * x_c.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_t.dtype)
    return y @ params["out_proj"].astype(y.dtype), {"conv": conv_state, "ssm": h}


# =============================================================== Mamba 2 ====
def init_mamba2(key, cfg: ArchConfig, dtype):
    d, di, N, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    nh = di // cfg.ssm_headdim
    conv_width = di + 2 * N  # conv over (x, B, C)
    ks = jax.random.split(key, 5)
    return {
        "in_proj": d ** -0.5 * jax.random.normal(ks[0], (d, 2 * di + 2 * N + nh), dtype),
        "conv_w": 0.5 * jax.random.normal(ks[1], (K, conv_width), dtype) / K,
        "conv_b": jnp.zeros((conv_width,), dtype),
        "dt_bias": jnp.zeros((nh,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(dtype),
        "D": jnp.ones((nh,), dtype),
        "norm_scale": jnp.ones((di,), dtype),  # gated RMSNorm pre out-proj
        "out_proj": di ** -0.5 * jax.random.normal(ks[4], (di, d), dtype),
    }


def _ssd_chunked(xh, dt, A, B, C, chunk: int):
    """Chunked SSD (Mamba2). xh (b,s,nh,p), dt (b,s,nh) fp32, A (nh,),
    B/C (b,s,N). Returns (y (b,s,nh,p), final_state (b,nh,p,N))."""
    b, s, nh, p = xh.shape
    N = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    xc = xh.reshape(b, nc, chunk, nh, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, nh)
    Bc = B.reshape(b, nc, chunk, N).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, N).astype(jnp.float32)

    a = dtc * A[None, None, None, :]  # (b,nc,l,h) negative
    a_cum = jnp.cumsum(a, axis=2)
    # intra-chunk: L_ij = exp(a_cum_i - a_cum_j) for j <= i
    diff = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]  # (b,nc,i,j,h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # mask BEFORE exp: exp of the (positive, unbounded) upper-triangular
    # entries would overflow and poison gradients through the where.
    L = jnp.exp(jnp.where(mask, diff, -jnp.inf))
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (b,nc,i,j)
    dtx = dtc[..., None] * xc  # (b,nc,l,h,p)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, L, dtx)

    # chunk states: S_c = sum_j exp(a_cum_last - a_cum_j) dtx_j ⊗ B_j
    decay_to_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # (b,nc,l,h)
    states = jnp.einsum("bclh,bclhp,bcln->bchpn", decay_to_end, dtx, Bc)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # (b,nc,h)

    def scan_fn(h, inp):
        st, dec = inp  # (b,h,p,n), (b,h)
        h_new = dec[..., None, None] * h + st
        return h_new, h  # emit PREVIOUS state for the chunk

    h0 = jnp.zeros((b, nh, p, N), jnp.float32)
    h_final, prev_states = jax.lax.scan(
        scan_fn, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,nc,h,p,n)
    state_decay = jnp.exp(a_cum)  # (b,nc,l,h)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, prev_states, state_decay)
    y = (y_diag + y_off).reshape(b, s, nh, p)
    return y, h_final


def mamba2_forward(x: jax.Array, params: dict, cfg: ArchConfig, chunk: int = 64,
                   return_state: bool = False):
    """Full-sequence SSD. x (B,S,d) -> (B,S,d) [+ decode state]."""
    di, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    nh = di // cfg.ssm_headdim
    p = cfg.ssm_headdim
    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z, xbc_raw, dt_in = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    xbc = jax.nn.silu(causal_conv1d(xbc_raw, params["conv_w"], params["conv_b"]))
    xs, B_ssm, C_ssm = jnp.split(xbc, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs.reshape(*xs.shape[:-1], nh, p)
    y, h_final = _ssd_chunked(xh, dt, A, B_ssm, C_ssm, chunk)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*x.shape[:-1], di)
    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-6)
    y = (y * params["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    out = y @ params["out_proj"].astype(y.dtype)
    if not return_state:
        return out
    pad = jnp.zeros((x.shape[0], max(K - 1 - x.shape[1], 0), xbc_raw.shape[-1]),
                    xbc_raw.dtype)
    conv_state = jnp.concatenate([pad, xbc_raw[:, -(K - 1):]], axis=1)
    return out, {"conv": conv_state, "ssm": h_final}


def mamba2_decode(x_t: jax.Array, state: dict, params: dict, cfg: ArchConfig):
    """Single-token step. state {conv (B,K-1,di+2N), ssm (B,nh,p,N)}."""
    di, N = cfg.d_inner, cfg.ssm_state
    nh, p = di // cfg.ssm_headdim, cfg.ssm_headdim
    zxbcdt = x_t @ params["in_proj"].astype(x_t.dtype)
    z, xbc, dt_in = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    conv_state, xbc = conv_step(state["conv"], xbc, params["conv_w"], params["conv_b"])
    xbc = jax.nn.silu(xbc)
    xs, B_ssm, C_ssm = jnp.split(xbc, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))  # (B,nh)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs.reshape(-1, nh, p).astype(jnp.float32)
    da = jnp.exp(dt * A[None])  # (B,nh)
    h = da[..., None, None] * state["ssm"] + \
        (dt[..., None] * xh)[..., None] * B_ssm.astype(jnp.float32)[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h, C_ssm.astype(jnp.float32))
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(-1, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-6)
    y = (y * params["norm_scale"].astype(jnp.float32)).astype(x_t.dtype)
    return y @ params["out_proj"].astype(y.dtype), {"conv": conv_state, "ssm": h}


def mamba_ref_sequential(x, params, cfg):
    """Step-by-step decode-path oracle for tests: running mamba1_decode over
    the sequence must equal mamba1_forward (and mamba2 likewise)."""
    B, S, d = x.shape
    if cfg.ssm_version == 1:
        di, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
        state = {"conv": jnp.zeros((B, K - 1, di), x.dtype),
                 "ssm": jnp.zeros((B, di, N), jnp.float32)}
        step = lambda s, xt: mamba1_decode(xt, s, params, cfg)[::-1]
    else:
        di, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
        nh, p = di // cfg.ssm_headdim, cfg.ssm_headdim
        state = {"conv": jnp.zeros((B, K - 1, di + 2 * N), x.dtype),
                 "ssm": jnp.zeros((B, nh, p, N), jnp.float32)}
        step = lambda s, xt: mamba2_decode(xt, s, params, cfg)[::-1]
    ys = []
    for t in range(S):
        state, y = step(state, x[:, t])
        ys.append(y)
    return jnp.stack(ys, axis=1)
