"""Streaming training subsystem — the paper's production cadence.

``source``   day-sliced sparse CTR stream with id-traffic drift
``planner``  double-buffered host re-planner (plans + routing + compile
             overlapped with the device step)
``trainer``  warm-started minibatch OWLQN+ across sliding windows
"""
from repro.stream.planner import (  # noqa: F401
    PlannerStats,
    PreparedWindow,
    WindowPlanner,
    plan_window,
)
from repro.stream.source import DayStream, concat_batches  # noqa: F401
from repro.stream.trainer import (  # noqa: F401
    StreamState,
    StreamTrainer,
    WindowStats,
)
