"""Day-sliced sparse CTR stream — the production cadence of §4.

The paper trains LS-PLM full-batch, but Alibaba's system retrains as new
days of impressions arrive. :class:`DayStream` models that arrival
process: day t is a session-structured padded-COO
:class:`~repro.data.sparse.SparseCTRBatch` (NO transpose plans attached
— planning is the streaming trainer's job, done once per window on the
host by ``repro.stream.planner``), drawn from the SAME planted
piecewise-linear truth as ``generate_sparse`` (hashed per-id weights, so
an id means the same thing on every day) but with per-day
id-DISTRIBUTION drift: the Zipf-hot head of the id traffic rotates by
``drift`` of the id space per day. Real CTR id traffic does exactly this
— new ads/users enter, old ones cool off — and it is what makes
day-by-day retraining beat a train-once model on the next day's
impressions (the streaming NLL gate in tests/test_stream_trainer.py).

``window(t, W)`` concatenates the last W days ending at t (a sliding
window, fewer on the early days) into one batch; sessions stay
contiguous and ascending, so the window routes onto a (data x model)
mesh unchanged (``repro.shard.route_batch``'s contiguity requirement).

Days are deterministic in (seed, day) and cached (bounded: the
``cache_days`` most recent; evicted days regenerate bit-identically), so
iterating windows re-reads each day W times but generates it once and
memory stays flat on long streams.
"""
from __future__ import annotations

import threading
from typing import Iterator, Sequence

import jax.numpy as jnp
import numpy as np

from repro.data.sparse import SparseCTRBatch, planted_ctr_labels


def concat_batches(batches: Sequence[SparseCTRBatch]) -> SparseCTRBatch:
    """Concatenate session-structured sparse batches (sessions stacked in
    order, session ids re-based so they stay contiguous and ascending).
    All batches must share d and the per-row K widths (true for every
    batch of one :class:`DayStream`). Plans are NOT carried over — a
    concatenation addresses new sample indices, so the caller re-plans
    (that is the point of the streaming planner)."""
    if not batches:
        raise ValueError("concat_batches needs at least one batch")
    d = batches[0].num_features
    ku = batches[0].user_ids.shape[1]
    ka = batches[0].ad_ids.shape[1]
    for b in batches:
        if b.num_features != d or b.user_ids.shape[1] != ku \
                or b.ad_ids.shape[1] != ka:
            raise ValueError(
                "batches disagree on d or K widths: "
                f"{(b.num_features, b.user_ids.shape[1], b.ad_ids.shape[1])} "
                f"vs {(d, ku, ka)}")
    if len(batches) == 1:
        b = batches[0]
        return b._replace(user_plan=None, ad_plan=None)
    sids, off = [], 0
    for b in batches:
        sids.append(np.asarray(b.session_id) + off)
        off += int(np.asarray(b.user_ids).shape[0])
    cat = lambda xs: jnp.concatenate([jnp.asarray(x) for x in xs], axis=0)
    return SparseCTRBatch(
        user_ids=cat([b.user_ids for b in batches]),
        user_vals=cat([b.user_vals for b in batches]),
        ad_ids=cat([b.ad_ids for b in batches]),
        ad_vals=cat([b.ad_vals for b in batches]),
        session_id=jnp.asarray(np.concatenate(sids).astype(np.int32)),
        y=cat([b.y for b in batches]),
        num_features=d)


class DayStream:
    """Deterministic per-day sparse CTR batches with id-traffic drift.

    Day t draws user ids from ``[user_lo, d)`` and ad ids from
    ``[0, user_lo)``. A ``head_frac`` share of the traffic is a HOT HEAD
    — exponentially decaying over ids with characteristic width
    ``head_width * span``, centered at an offset that rotates by
    ``drift * span`` ids per day (wrapping) — and the rest is uniform
    background. The exponential head has a real width scale (a pure
    power law does not), so the defaults (width 8% of the span, daily
    shift 2%) make consecutive days share ~80% of their hot traffic
    while a week apart shares almost none. Labels come
    from the shared planted truth (``planted_ctr_labels``), which
    depends only on the ids/vals — so the truth never drifts, only the
    traffic does, and a model trained on recent days generalises to the
    next day better than a stale one.
    """

    def __init__(self, num_days: int, sessions_per_day: int = 128, *,
                 num_features: int = 100_000,
                 ads_per_session: int = 4,
                 active_user: int = 16, active_ad: int = 8,
                 user_frac: float = 0.6,
                 drift: float = 0.02, head_frac: float = 0.75,
                 head_width: float = 0.08, binary_vals: bool = True,
                 cache_days: int = 16, seed: int = 0):
        if num_days < 1:
            raise ValueError(f"num_days must be >= 1, got {num_days}")
        if sessions_per_day < 1:
            raise ValueError(
                f"sessions_per_day must be >= 1, got {sessions_per_day}")
        self.num_days = int(num_days)
        self.sessions_per_day = int(sessions_per_day)
        self.num_features = int(num_features)
        self.ads_per_session = int(ads_per_session)
        self.active_user = int(active_user)
        self.active_ad = int(active_ad)
        self.user_lo = max(1, int(user_frac * num_features))
        self.drift = float(drift)
        self.head_frac = float(head_frac)
        self.head_width = float(head_width)
        self.binary_vals = bool(binary_vals)
        self.cache_days = max(1, int(cache_days))
        self.seed = int(seed)
        self._cache: dict[int, SparseCTRBatch] = {}
        # the planner thread and the trainer's eval can ask for the same
        # day concurrently; generation is deterministic, the lock just
        # stops the work being done twice
        self._lock = threading.Lock()

    # ------------------------------------------------------------- generation
    def _drifted_ids(self, rng, lo: int, hi: int, shape, day: int):
        """``head_frac`` of draws from an exponentially-decaying hot head
        at ``lo + offset(day)`` (wrapping), the rest uniform background:
        the head gives hot repeated ids, the rotation gives drift, the
        width scale gives adjacent-day overlap."""
        span = hi - lo
        scale = max(1.0, self.head_width * span)
        offset = int(round(self.drift * day * span))
        r = (-scale * np.log1p(-rng.random(shape))).astype(np.int64)
        head = (offset + r) % span
        tail = rng.integers(0, span, shape)
        ids = np.where(rng.random(shape) < self.head_frac, head, tail)
        return lo + ids

    def day(self, t: int) -> SparseCTRBatch:
        """Day t's impressions (no plans attached)."""
        if not 0 <= t < self.num_days:
            raise IndexError(f"day {t} outside [0, {self.num_days})")
        with self._lock:
            return self._day_locked(t)

    def _day_locked(self, t: int) -> SparseCTRBatch:
        if t in self._cache:
            return self._cache[t]
        while len(self._cache) >= self.cache_days:  # LRU-ish: drop oldest
            self._cache.pop(next(iter(self._cache)))
        rng = np.random.default_rng(self.seed * 1_000_003 + t)
        d, G, A = self.num_features, self.sessions_per_day, self.ads_per_session
        B = G * A
        user_ids = self._drifted_ids(rng, self.user_lo, d,
                                     (G, self.active_user), t)
        ad_ids = self._drifted_ids(rng, 0, self.user_lo,
                                   (B, self.active_ad), t)
        if self.binary_vals:
            # production wire format: multi-hot indicators (value 1,
            # scaled so |x| is K-independent). An id's contribution to
            # the planted logit is then a constant — estimable from its
            # click counts alone — which keeps next-day NLL calibrated.
            user_vals = np.full((G, self.active_user),
                                1.0 / np.sqrt(self.active_user), np.float32)
            ad_vals = np.full((B, self.active_ad),
                              1.0 / np.sqrt(self.active_ad), np.float32)
        else:
            user_vals = rng.normal(size=(G, self.active_user)).astype(
                np.float32) / np.sqrt(self.active_user)
            ad_vals = rng.normal(size=(B, self.active_ad)).astype(
                np.float32) / np.sqrt(self.active_ad)
        session_id = np.repeat(np.arange(G, dtype=np.int32), A)
        y = planted_ctr_labels(user_ids, user_vals, ad_ids, ad_vals,
                               session_id, rng)
        batch = SparseCTRBatch(
            user_ids=jnp.asarray(user_ids, jnp.int32),
            user_vals=jnp.asarray(user_vals),
            ad_ids=jnp.asarray(ad_ids, jnp.int32),
            ad_vals=jnp.asarray(ad_vals),
            session_id=jnp.asarray(session_id),
            y=jnp.asarray(y),
            num_features=d)
        self._cache[t] = batch
        return batch

    def window(self, t: int, window: int = 1) -> SparseCTRBatch:
        """The sliding training window ending at day t: days
        ``[max(0, t - window + 1), t]`` concatenated (early days see
        fewer than ``window`` days). No plans attached."""
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        lo = max(0, t - window + 1)
        return concat_batches([self.day(s) for s in range(lo, t + 1)])

    # ------------------------------------------------------------- protocol
    def __len__(self) -> int:
        return self.num_days

    def __iter__(self) -> Iterator[SparseCTRBatch]:
        return (self.day(t) for t in range(self.num_days))
