"""Streaming day-by-day OWLQN+ — minibatch windows with warm starts.

The paper's optimizer is full-batch: one batch, hundreds of iterations.
Production retrains as days arrive. :class:`StreamTrainer` runs that
cadence over a :class:`~repro.stream.source.DayStream`: for each day t
it takes the sliding window of the last W days, re-plans it on the host
(overlapped with the previous window's device steps via
:class:`~repro.stream.planner.WindowPlanner`), and runs a bounded number
of OWLQN+ inner iterations warm-started from the previous window's
Theta.

Reset-vs-carry policy (``history=``): Theta ALWAYS carries across
windows (the warm start is the point of streaming). The L-BFGS history
is different — its (s, y) pairs approximate the curvature of the
PREVIOUS window's objective, and the objective changes when the window
slides:

  * ``"reset"`` (default): drop the history (and prev_theta/prev_d) at
    every window boundary. The first inner iteration of each window is
    then a pure Eq. 9 direction step. Safe, and exactly reproduces the
    full-batch trajectory when the window never changes — the streaming
    parity gate in tests/test_stream_trainer.py.
  * ``"carry"``: keep the history across the boundary. The pair pushed
    at the boundary mixes directions of two objectives; OWLQN+'s PD
    safeguard (pairs with y.s <= 0 are masked) drops genuinely
    inconsistent pairs, so with small drift the curvature carry-over
    saves inner iterations. With large drift prefer ``"reset"``.

Exact-zero sparsity crosses window boundaries untouched by
construction: the warm start copies Theta bit-for-bit and OWLQN+'s
orthant algebra is sign-exact, so a feature that L1/L2,1 pushed to exact
zero stays exact zero until some window's data argues it back in
(asserted in tests/test_stream_trainer.py).

With a mesh the whole thing runs the paper's worker/server split per
window: the planner routes + slices + stacks per-shard plans
(``repro.shard``), the step is ``dist.make_distributed_step`` on the
row-sharded state, and the id-range partition is FIXED across windows
(equal ranges) so Theta never re-layouts at a boundary.

Because plan shapes are data-dependent, every window is a fresh XLA
executable; the trainer therefore AOT-compiles the window's step
(``jit(...).lower(...).compile()``) INSIDE the planner's background
thread (``jit_ahead=True``), hiding compilation behind device work along
with plan construction — this is most of the overlap win measured by
``benchmarks/bench_stream.py``.
"""
from __future__ import annotations

import time
from typing import Callable, NamedTuple

import jax

from repro import obs
from repro.core.objective import smooth_loss_and_grad
from repro.optim.owlqn_plus import OWLQNPlus, OWLQNState
from repro.stream.planner import PlannerStats, PreparedWindow, WindowPlanner
from repro.stream.source import DayStream


class StreamState(NamedTuple):
    """Checkpointable streaming-trainer state: the optimizer state (Theta
    + L-BFGS history + step counter) and the day cursor (the NEXT day to
    consume). Round-trips exactly through ``repro.io.checkpoint``."""

    opt: OWLQNState
    day: int = 0


class WindowStats(NamedTuple):
    day: int                  # window end day
    days_in_window: int
    fs: tuple                 # objective after each inner iteration
    alpha: float              # last accepted step size
    nnz: int                  # non-zeros after the window
    step_seconds: float       # device time for the inner iterations
    build_seconds: float      # host time to plan (+route/compile) the window


def _no_loss(_theta):
    raise RuntimeError("template optimizer has no loss bound; "
                       "windows bind their own")


class StreamTrainer:
    """Minibatch OWLQN+ over a day stream with an overlapped re-planner.

    Args:
      stream: the :class:`DayStream` (or anything with ``num_days``,
        ``num_features``, ``sessions_per_day``, ``window(t, W)``).
      lam, beta: the Eq. 4 L2,1 / L1 weights.
      window: sliding-window width W in days.
      inner_iters: OWLQN+ iterations per window (the per-window budget).
      history: ``"reset"`` or ``"carry"`` — see the module docstring.
      mesh: optional (data x model) mesh; the stream then trains the
        sharded path per window with a FIXED equal id-range partition.
      overlap: background re-planner on/off (off = synchronous fallback).
      jit_ahead: AOT-compile each window's step in the planner thread.
    """

    def __init__(self, stream: DayStream, *, lam: float, beta: float,
                 window: int = 1, inner_iters: int = 5,
                 history: str = "reset", memory: int = 10,
                 mesh=None, partition=None, overlap: bool = True,
                 jit_ahead: bool = True, mode: str = "auto"):
        if history not in ("reset", "carry"):
            raise ValueError(f"history must be 'reset' or 'carry', "
                             f"got {history!r}")
        if window < 1 or inner_iters < 1:
            raise ValueError("window and inner_iters must be >= 1")
        self.stream = stream
        self.lam, self.beta = float(lam), float(beta)
        self.window = int(window)
        self.inner_iters = int(inner_iters)
        self.history = history
        self.memory = int(memory)
        self.mesh = mesh
        self.overlap = bool(overlap)
        self.jit_ahead = bool(jit_ahead)
        self.mode = mode
        self.planner_stats = PlannerStats(0, 0.0, 0.0, 0.0, 0.0)

        self.partition = partition
        self.data_shards = 1
        if mesh is not None:
            from repro.launch.mesh import data_axes
            from repro.shard.partition import make_partition

            if self.partition is None:
                self.partition = make_partition(stream.num_features,
                                                mesh.shape["model"])
            if self.partition.num_rows != stream.num_features:
                raise ValueError(
                    f"partition covers {self.partition.num_rows} rows, "
                    f"stream has {stream.num_features} features")
            for a in data_axes(mesh):
                self.data_shards *= mesh.shape[a]
            if stream.sessions_per_day % self.data_shards:
                raise ValueError(
                    f"sessions_per_day={stream.sessions_per_day} must divide "
                    f"by the mesh's data extent {self.data_shards}")
        elif partition is not None:
            raise ValueError("partition given without a mesh")
        # template optimizer: init/state algebra only (no loss bound)
        self._template = OWLQNPlus(_no_loss, lam=self.lam, beta=self.beta,
                                   memory=self.memory)
        self._opt_struct = None  # ShapeDtypeStructs for AOT lowering

    # ------------------------------------------------------------ state mgmt
    def init(self, theta0) -> StreamState:
        """Fresh stream state at day 0. With a mesh, ``theta0`` is the
        global (d, 2m) Theta — it is padded to the partition's row layout
        and the whole state device_put row-sharded."""
        if self.mesh is not None:
            from repro.dist import shard_state

            opt = shard_state(
                self._template.init(self.partition.pad_rows(theta0)),
                self.mesh)
        else:
            opt = self._template.init(theta0)
        return StreamState(opt=opt, day=0)

    def theta(self, state: StreamState):
        """The global (d, 2m) Theta of a stream state (host-side; pad rows
        dropped on the sharded path)."""
        import jax.numpy as jnp

        th = jnp.asarray(jax.device_get(state.opt.theta))
        return th if self.mesh is None else self.partition.unpad_rows(th)

    def save(self, path: str, state: StreamState) -> None:
        """Checkpoint the stream (Theta + OWLQN+ history + day cursor)."""
        from repro.io import checkpoint

        checkpoint.save_stream(path, state)

    def load(self, path: str, theta_like) -> StreamState:
        """Resume a checkpointed stream exactly. ``theta_like`` provides
        the global Theta shape/dtype (values ignored)."""
        from repro.io import checkpoint

        st = checkpoint.load_stream(path, self.init(theta_like))
        if self.mesh is not None:
            from repro.dist import shard_state

            st = st._replace(opt=shard_state(st.opt, self.mesh))
        return st

    # ------------------------------------------------------------ per window
    def _make_loss(self, batch) -> Callable:
        if self.mesh is None:
            return lambda t: smooth_loss_and_grad(t, batch)
        from repro.shard.step import make_sharded_sparse_loss

        return make_sharded_sparse_loss(batch, self.mesh, mode=self.mode)

    def _prepare(self, day: int) -> PreparedWindow:
        """Build one window end-to-end on the host: slide + re-plan
        (+ route/stack + device_put on a mesh) + bind the loss +
        (optionally) AOT-compile the step. Runs on the planner thread."""
        from repro.stream.planner import plan_window

        tracer = obs.get_tracer()
        t0 = time.perf_counter()
        with tracer.span("stream/plan", day=day):
            raw = self.stream.window(day, self.window)
            batch = plan_window(raw, partition=self.partition,
                                data_shards=self.data_shards, mesh=self.mesh)
        plan_s = time.perf_counter() - t0
        opt = OWLQNPlus(self._make_loss(batch), lam=self.lam, beta=self.beta,
                        memory=self.memory)
        if self.mesh is not None:
            from repro.dist import make_distributed_step

            step = make_distributed_step(opt, self.mesh)
        else:
            step = jax.jit(opt.step)
        compile_s = 0.0
        if self.jit_ahead and self._opt_struct is not None:
            t1 = time.perf_counter()
            with tracer.span("stream/compile", day=day):
                step = step.lower(self._opt_struct).compile()
            compile_s = time.perf_counter() - t1
        return PreparedWindow(day=day, batch=batch, step=step,
                              plan_seconds=plan_s, compile_seconds=compile_s)

    def _window_start(self, win: PreparedWindow,
                      opt_state: OWLQNState) -> OWLQNState:
        """Apply the reset-vs-carry policy at a window boundary. Theta
        always carries (bit-exact warm start); ``"reset"`` re-inits the
        history/prev_* around it."""
        if self.history == "carry":
            return opt_state
        fresh = self._template.init(opt_state.theta)
        if self.mesh is not None:
            from repro.dist import shard_state

            fresh = shard_state(fresh, self.mesh)
        return fresh

    # ---------------------------------------------------------------- driver
    def run(self, state: StreamState, days: int | None = None, *,
            callback: Callable[[int, WindowStats, StreamState],
                               None] | None = None,
            ) -> tuple[StreamState, list[WindowStats]]:
        """Consume ``days`` windows starting at ``state.day`` (default: to
        the end of the stream). ``callback(day, stats, state)`` fires
        after each window with the ADVANCED state (for eval /
        checkpointing mid-stream). Returns the advanced state and
        per-window stats; ``self.planner_stats`` holds the run's overlap
        accounting."""
        start = int(state.day)
        if days is None:
            days = self.stream.num_days - start
        if days <= 0:
            return state, []
        if start + days > self.stream.num_days:
            raise ValueError(f"stream has {self.stream.num_days} days; "
                             f"cannot run [{start}, {start + days})")
        self._opt_struct = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state.opt)
        trace: list[WindowStats] = []
        planner = WindowPlanner(self._prepare, overlap=self.overlap)
        led = obs.get_ledger()
        tracer = obs.get_tracer()
        global_iter = 0  # train_iter record index across windows
        try:
            # the FIRST window has no device work to hide behind — let
            # get() build it synchronously so the overlap stats only
            # count windows that genuinely could overlap
            for i in range(days):
                t = start + i
                win = planner.get(t)
                if i + 1 < days:  # next window builds WHILE we step
                    planner.prefetch(t + 1)
                opt_state = self._window_start(win, state.opt)
                t0 = time.perf_counter()
                fs = []
                iter_stats = []
                last = None
                with tracer.span("stream/step", day=t):
                    for j in range(self.inner_iters):
                        with tracer.step_span("train/iter", global_iter + j,
                                              day=t):
                            opt_state, last = win.step(opt_state)
                            fs.append(float(last.f_new))
                        iter_stats.append(last)
                    jax.block_until_ready(opt_state.theta)
                dt = time.perf_counter() - t0
                state = StreamState(opt=opt_state, day=t + 1)
                ws = WindowStats(
                    day=t, days_in_window=min(self.window, t + 1),
                    fs=tuple(fs), alpha=float(last.alpha),
                    nnz=int(last.nnz), step_seconds=dt,
                    build_seconds=win.build_seconds)
                trace.append(ws)
                if led.enabled:
                    for j, st in enumerate(jax.device_get(iter_stats)):
                        led.emit(
                            "train_iter", step=global_iter + j, day=t,
                            window_iter=j, f=float(st.f),
                            f_new=float(st.f_new), alpha=float(st.alpha),
                            ls_iters=int(st.ls_iters),
                            grad_norm=float(st.grad_norm), nnz=int(st.nnz))
                    led.emit(
                        "stream_window", day=t,
                        days_in_window=ws.days_in_window,
                        plan_s=win.plan_seconds, compile_s=win.compile_seconds,
                        build_s=win.build_seconds, wait_s=win.wait_seconds,
                        prefetched=win.prefetched, step_s=dt,
                        carry=self.history, alpha=ws.alpha, nnz=ws.nnz,
                        fs=list(ws.fs))
                global_iter += self.inner_iters
                if callback is not None:
                    callback(t, ws, state)
        finally:
            self.planner_stats = planner.stats
            if led.enabled:
                ps = self.planner_stats
                led.emit(
                    "stream_summary", windows=ps.windows,
                    build_seconds=ps.build_seconds,
                    wait_seconds=ps.wait_seconds,
                    prefetched_build_seconds=ps.prefetched_build_seconds,
                    prefetched_wait_seconds=ps.prefetched_wait_seconds,
                    overlap_ratio=ps.overlap_ratio)
            planner.close()
        return state, trace
