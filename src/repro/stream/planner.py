"""Overlapped host re-planner — double-buffer the next window's plans
(and compile) behind the current window's device steps.

Per window the streaming trainer pays three host-side costs before the
device can step:

  1. transpose-plan construction (one argsort + linear passes per id
     tensor — ``data/sparse.build_batch_plans``);
  2. with a mesh: routing + plan slicing + stacking for the
     (data x model) grid (``repro.shard.partition`` /
     ``repro.shard.plan_slicing``) and the device_put;
  3. (re)compilation of the window's step — plan shapes are
     data-dependent, so a new window is a new executable (see
     ``kernels/lsplm_sparse_scatter/plan.py``: re-plan per day is the
     intended trade).

All three are independent of the CURRENT window's device work, so
:class:`WindowPlanner` runs them on one background thread
(``ThreadPoolExecutor``): while the device grinds window t's inner
OWLQN+ iterations, the host builds window t+1. ``overlap=False`` is the
synchronous fallback (same results, serial timing) — the bench
(``benchmarks/bench_stream.py``) measures the speedup between the two.

The planner is generic over what a "prepared window" is: the trainer
hands it a ``build(day) -> PreparedWindow`` callable; :func:`plan_window`
is the batch-preparation piece (plans, and routing when a partition /
mesh is configured).

Overlap accounting: every build is timed inside the worker; every
``get`` times how long the trainer actually BLOCKED. The overlap ratio
is the fraction of prefetched build time hidden behind device work —
``1 - wait / build`` over prefetched windows (the first window of a run
has nothing to hide behind and is excluded).

The accounting lives in the process metrics registry (``repro.obs``):
each planner owns a labeled family of ``stream_planner_*`` counters and
:attr:`WindowPlanner.stats` is a view that reads them back into the same
:class:`PlannerStats` tuple as before — same ``+=`` arithmetic in the
same order, so ``overlap_ratio`` is preserved bit-for-bit. Builds run
inside ``stream/plan_window`` spans on the worker thread and blocked
time inside ``stream/wait`` on the trainer thread, so an exported trace
shows exactly how window t+1's host build interleaves with window t's
device steps.
"""
from __future__ import annotations

import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, NamedTuple

from repro import obs


class PreparedWindow(NamedTuple):
    """Everything the trainer needs to step a window."""

    day: int
    batch: Any          # planned SparseCTRBatch | routed ShardedSparseBatch
    step: Any           # callable(state) -> (state, stats), ready to run
    build_seconds: float = 0.0
    plan_seconds: float = 0.0     # batch-plan share of the build
    compile_seconds: float = 0.0  # AOT-compile share of the build
    wait_seconds: float = 0.0     # how long get() blocked (stamped by planner)
    prefetched: bool = False      # built in the background vs inline


class PlannerStats(NamedTuple):
    windows: int                 # windows served
    build_seconds: float         # total host build time (all windows)
    wait_seconds: float          # total time the trainer blocked
    prefetched_build_seconds: float  # build time of prefetched windows
    prefetched_wait_seconds: float   # blocked time on prefetched windows

    @property
    def overlap_ratio(self) -> float:
        """Fraction of prefetched build time hidden behind device work."""
        if self.prefetched_build_seconds <= 0.0:
            return 0.0
        return max(0.0, 1.0 - self.prefetched_wait_seconds
                   / self.prefetched_build_seconds)


def plan_window(batch, *, partition=None, data_shards: int = 1, mesh=None):
    """Prepare one window's batch for the device: attach fresh transpose
    plans; with a ``partition`` additionally route + slice + stack for a
    (data x model) mesh (``repro.shard``), and with a ``mesh`` also
    device_put the routed batch per ``dist.sparse_batch_specs``. This is
    the host work the background thread hides."""
    from repro.data.sparse import build_batch_plans

    if partition is None:
        if mesh is not None:
            raise ValueError("mesh given without a partition — the sharded "
                             "stream routes by id range")
        return build_batch_plans(batch)
    sb = build_batch_plans(batch, shards=partition, data_shards=data_shards)
    if mesh is not None:
        from repro.dist import shard_sparse_batch

        sb = shard_sparse_batch(mesh, sb)
    return sb


class WindowPlanner:
    """Double-buffered background builder of :class:`PreparedWindow`s.

    Protocol (the trainer's loop)::

        planner.prefetch(t0)
        for t in days:
            win = planner.get(t)       # blocks only on un-hidden build time
            planner.prefetch(t + 1)    # next window builds DURING stepping
            ... run win.step inner_iters times ...
        planner.close()

    ``overlap=False`` degrades ``get`` to a synchronous build (prefetch
    becomes a no-op) — identical results, serial schedule.
    """

    def __init__(self, build: Callable[[int], PreparedWindow], *,
                 overlap: bool = True, registry=None):
        self._build = build
        self._pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="replanner") if overlap else None
        self._pending: dict[int, Future] = {}
        reg = registry if registry is not None else obs.get_registry()
        labels = {"planner": obs.next_instance("planner")}
        self._windows = reg.counter("stream_planner_windows", **labels)
        self._build_s = reg.counter("stream_planner_build_seconds", **labels)
        self._wait_s = reg.counter("stream_planner_wait_seconds", **labels)
        self._pre_build_s = reg.counter(
            "stream_planner_prefetched_build_seconds", **labels)
        self._pre_wait_s = reg.counter(
            "stream_planner_prefetched_wait_seconds", **labels)
        self._build_hist = reg.histogram(
            "stream_planner_build_wall_seconds", **labels)

    @property
    def overlap(self) -> bool:
        return self._pool is not None

    def _timed(self, day: int) -> PreparedWindow:
        t0 = time.perf_counter()
        with obs.get_tracer().span("stream/plan_window", day=day):
            out = self._build(day)
        dt = time.perf_counter() - t0
        self._build_hist.observe(dt)
        return out._replace(build_seconds=dt)

    def prefetch(self, day: int) -> None:
        """Start building ``day`` in the background (no-op when
        synchronous or already pending)."""
        if self._pool is None or day in self._pending:
            return
        self._pending[day] = self._pool.submit(self._timed, day)

    def get(self, day: int) -> PreparedWindow:
        """The prepared window for ``day`` — joins the background build if
        one is pending, else builds synchronously right here."""
        fut = self._pending.pop(day, None)
        t0 = time.perf_counter()
        prefetched = fut is not None
        if fut is None:
            out = self._timed(day)
            wait = out.build_seconds  # fully exposed
        else:
            with obs.get_tracer().span("stream/wait", day=day):
                out = fut.result()
            wait = time.perf_counter() - t0
            self._pre_build_s.inc(out.build_seconds)
            self._pre_wait_s.inc(min(wait, out.build_seconds))
        self._windows.inc(1.0)
        self._build_s.inc(out.build_seconds)
        self._wait_s.inc(wait)
        return out._replace(wait_seconds=wait, prefetched=prefetched)

    @property
    def stats(self) -> PlannerStats:
        """The familiar tuple, read back out of the registry counters."""
        return PlannerStats(
            windows=int(self._windows.value),
            build_seconds=self._build_s.value,
            wait_seconds=self._wait_s.value,
            prefetched_build_seconds=self._pre_build_s.value,
            prefetched_wait_seconds=self._pre_wait_s.value)

    def close(self) -> None:
        for fut in self._pending.values():
            fut.cancel()
        self._pending.clear()
        if self._pool is not None:
            self._pool.shutdown(wait=True)

    def __enter__(self) -> "WindowPlanner":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
