"""Parse collective traffic out of compiled (post-SPMD) HLO text.

cost_analysis() has no collective-byte entry, so we sum the RESULT shapes
of every collective op in the per-device program. This is a volume proxy:
e.g. an all-gather's result bytes are the full gathered size per device,
an all-reduce's are the reduced tensor per device. Ring-algorithm
wire-bytes differ by small constant factors; we report the proxy and use
it consistently for before/after comparisons.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Returns {op_kind: {"count": n, "bytes": b}, ..., "total_bytes": int}.

    Matches lines of the form
      %name = TYPE all-gather(...)   /  = (TYPE, TYPE) all-reduce(...)
    and sums the result TYPE bytes (per-device program => per-chip bytes).
    `-start` variants are counted; `-done` variants are skipped to avoid
    double counting.
    """
    stats: dict = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        for op in COLLECTIVE_OPS:
            # require "<op>(" or "<op>-start(" as the instruction
            m = re.search(rf"=\s+(.+?)\s+{op}(?:-start)?\(", line)
            if m and f"{op}-done" not in line:
                b = _shape_bytes(m.group(1))
                stats[op]["count"] += 1
                stats[op]["bytes"] += b
                break
    out = {k: dict(v) for k, v in stats.items()}
    out["total_bytes"] = sum(v["bytes"] for v in stats.values())
    return out
