"""Three-term roofline model from dry-run artifacts (TPU v5e constants).

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = collective_bytes_per_chip / link_bw

The SPMD program produced by pjit is per-device, so cost_analysis() numbers
are already per-chip.
"""
from __future__ import annotations

import dataclasses

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclasses.dataclass
class Roofline:
    flops: float  # per chip
    hbm_bytes: float  # per chip
    coll_bytes: float  # per chip
    model_flops: float  # useful 6ND (or 2ND) per chip

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Lower-bound step time if terms overlap perfectly."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy/padding waste gauge."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Upper bound on model-FLOPs utilisation at the roofline."""
        if self.t_bound == 0:
            return 0.0
        return (self.model_flops / PEAK_FLOPS_BF16) / self.t_bound

    def to_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "collective_bytes_per_chip": self.coll_bytes,
            "model_flops_per_chip": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def model_flops_per_chip(cfg, shape_kind: str, tokens: int, chips: int) -> float:
    """6*N_active*D for training, 2*N_active*D for inference, split per chip."""
    n = cfg.active_param_count()
    mult = 6 if shape_kind == "train" else 2
    return mult * n * tokens / chips
