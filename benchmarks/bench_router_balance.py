"""Ablation — router load balance (the paper's divide-and-conquer gating
at MoE scale). LS-PLM's softmax divider learns region assignment freely;
Switch-style MoE needs the auxiliary balance loss to avoid expert
collapse. We train the reduced granite-moe arch with and without the aux
loss and report expert-utilisation entropy (1.0 = perfectly balanced).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.data.tokens import TokenStream
from repro.models import init_model, make_train_step
from repro.models.moe import _route


def _expert_entropy(params, cfg, tokens):
    from repro.models.transformer import embed_tokens
    h = embed_tokens(params, cfg, tokens)
    # route through layer-0's router (representative)
    router = jax.tree.map(lambda x: x[0], params["layers"])["ffn"]["router"]
    _gate, idx, _probs = _route(h.reshape(-1, cfg.d_model), router, cfg.top_k)
    counts = np.bincount(np.asarray(idx).ravel(), minlength=cfg.num_experts)
    p = counts / counts.sum()
    ent = -(p[p > 0] * np.log(p[p > 0])).sum() / np.log(cfg.num_experts)
    return float(ent), counts.max() / max(counts.mean(), 1)


def run(steps: int = 60):
    rows = []
    for aux_coef in (0.0, 0.05):
        cfg = dataclasses.replace(get_config("granite-moe-1b-a400m").reduced(),
                                  router_aux_coef=aux_coef)
        params = init_model(cfg, jax.random.PRNGKey(0))
        # adversarial start: bias every router toward expert 0 (collapse
        # seed) — the aux loss must recover balance, plain CE need not
        params["layers"]["ffn"]["router"] = (
            params["layers"]["ffn"]["router"].at[..., 0].add(2.0))
        opt, train_step = make_train_step(cfg, lr=3e-3)
        opt_state = opt.init(params)
        step = jax.jit(train_step)
        stream = TokenStream(cfg.vocab_size, seed=0)
        probe0 = jnp.asarray(stream.batch(16, 33)["tokens"])
        ent0, peak0 = _expert_entropy(params, cfg, probe0)
        ce = None
        for i in range(steps):
            b = stream.batch(8, 33)
            batch = {"tokens": jnp.asarray(b["tokens"]),
                     "labels": jnp.asarray(b["labels"])}
            params, opt_state, m = step(params, opt_state, batch)
            ce = float(m["ce"])
        probe = jnp.asarray(stream.batch(16, 33)["tokens"])
        ent, peak = _expert_entropy(params, cfg, probe)
        rows.append((
            f"ablation_router_aux{aux_coef:g}", "0",
            f"ce={ce:.4f};entropy_init={ent0:.3f};entropy_final={ent:.3f};"
            f"peak_load_init={peak0:.2f};peak_load_final={peak:.2f}",
        ))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
