"""§Roofline table from the dry-run artifacts (benchmarks/dryrun_*.json).

One row per (arch x shape x mesh): the three roofline terms, the dominant
bottleneck, useful-FLOPs ratio and per-chip memory. Reads the JSON written
by `repro.launch.dryrun`; does NOT compile anything itself.
"""
from __future__ import annotations

import json
import os

HERE = os.path.dirname(__file__)


def load(mesh: str = "single"):
    path = os.path.join(HERE, f"dryrun_{mesh}.json")
    if not os.path.exists(path):
        return []
    with open(path) as f:
        recs = json.load(f)
    # dedupe: keep the LAST successful record per combo (reruns supersede)
    by_key = {}
    for r in recs:
        if "roofline" in r:
            by_key[(r["arch"], r["shape"], r["mesh"])] = r
    return list(by_key.values())


def rows(mesh: str = "single"):
    out = []
    for r in load(mesh):
        rl = r["roofline"]
        mem_gib = r["memory"]["total_bytes_per_chip"] / 2**30
        out.append((
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            f"{rl['t_bound_s'] * 1e6:.1f}" if "t_bound_s" in rl else
            f"{max(rl['t_compute_s'], rl['t_memory_s'], rl['t_collective_s']) * 1e6:.1f}",
            f"t_comp={rl['t_compute_s']:.3e};t_mem={rl['t_memory_s']:.3e};"
            f"t_coll={rl['t_collective_s']:.3e};bound={rl['bottleneck']};"
            f"useful={rl['useful_flops_ratio']:.2f};mem_gib={mem_gib:.2f}",
        ))
    return out


def run():
    all_rows = rows("single") + rows("multi")
    for name, us, derived in all_rows:
        print(f"{name},{us},{derived}")
    if not all_rows:
        print("roofline_missing,0,run repro.launch.dryrun first")
    return all_rows


if __name__ == "__main__":
    run()
