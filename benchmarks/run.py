"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * bench_division        — Fig. 4 (division number m sweep)
  * bench_regularization  — Table 2 (L1 / L2,1 sparsity + AUC)
  * bench_common_feature  — Table 3 (common-feature trick cost)
  * bench_lr_vs_lsplm     — Fig. 5 (LS-PLM vs LR over 7 datasets)
  * bench_sparse_fused    — fused sparse kernel vs gather+einsum vs dense
  * roofline_report       — §Roofline rows from the dry-run artifacts

Usage:
  PYTHONPATH=src python -m benchmarks.run [--only SUBSTR] [--smoke]

``--only`` filters modules by name substring; ``--smoke`` asks modules
that support it for tiny shapes (the CI smoke step runs
``--only sparse_fused --smoke`` on CPU).
"""
from __future__ import annotations

import argparse
import inspect
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run only modules whose name contains this substring")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes where supported (CI)")
    args = ap.parse_args()

    from benchmarks import (
        bench_common_feature,
        bench_division,
        bench_lr_vs_lsplm,
        bench_regularization,
        bench_router_balance,
        bench_sparse_fused,
        roofline_report,
    )

    mods = [bench_division, bench_regularization, bench_common_feature,
            bench_lr_vs_lsplm, bench_router_balance, bench_sparse_fused,
            roofline_report]
    if args.only:
        mods = [m for m in mods if args.only in m.__name__]
        if not mods:
            raise SystemExit(f"--only {args.only!r} matched no benchmark module")

    ok = True
    for mod in mods:
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
            kwargs["smoke"] = True
        try:
            mod.run(**kwargs)
        except Exception:  # noqa: BLE001
            ok = False
            print(f"{mod.__name__},0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if not ok:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
