"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * bench_division        — Fig. 4 (division number m sweep)
  * bench_regularization  — Table 2 (L1 / L2,1 sparsity + AUC)
  * bench_common_feature  — Table 3 (common-feature trick cost)
  * bench_lr_vs_lsplm     — Fig. 5 (LS-PLM vs LR over 7 datasets)
  * roofline_report       — §Roofline rows from the dry-run artifacts
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_common_feature,
        bench_division,
        bench_lr_vs_lsplm,
        bench_regularization,
        bench_router_balance,
        roofline_report,
    )

    ok = True
    for mod in (bench_division, bench_regularization, bench_common_feature,
                bench_lr_vs_lsplm, bench_router_balance, roofline_report):
        try:
            mod.run()
        except Exception:  # noqa: BLE001
            ok = False
            print(f"{mod.__name__},0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if not ok:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
