"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * bench_division        — Fig. 4 (division number m sweep)
  * bench_regularization  — Table 2 (L1 / L2,1 sparsity + AUC)
  * bench_common_feature  — Table 3 (common-feature trick cost)
  * bench_lr_vs_lsplm     — Fig. 5 (LS-PLM vs LR over 7 datasets)
  * bench_sparse_fused    — fused sparse kernel fwd/bwd vs oracles
  * bench_tune            — autotuned configs vs the hand-picked defaults
  * bench_stream          — streaming trainer: overlapped re-planner
  * bench_serve           — serving: pruned artifacts, shared bundles, engine
  * bench_obs             — observability overhead: instrumented train step
  * roofline_report       — §Roofline rows from the dry-run artifacts

Usage:
  PYTHONPATH=src python -m benchmarks.run [--only NAME[,NAME...]] \
      [--smoke] [--json]

``--only`` selects suites by name — an exact module name (with or
without the ``bench_`` prefix) or a substring; comma-separate to run
several — so CI jobs can run a single suite without paying for the
rest. ``--smoke`` asks modules that support it for tiny shapes;
``--json`` additionally writes the machine-readable perf trajectories
CI archives as artifacts: ``BENCH_sparse_fused.json`` (kernel
fwd/bwd timings + speedups), ``BENCH_stream.json`` (streaming
steps/sec, overlap ratio, overlapped-vs-sync speedup, per-day decay
table), ``BENCH_serve.json`` (pruned-vs-full, shared-vs-naive,
engine latency) and ``BENCH_obs.json`` (instrumentation overhead
ratio). The CI smoke steps run ``--only sparse_fused``, ``--only
stream``, ``--only serve`` and ``--only obs`` with ``--smoke --json``
on CPU.

Every ``--json`` artifact also carries a ``meta`` block — git rev,
backend, device/cpu counts and the module's wall seconds — so an
archived trajectory is self-describing. ``check_regression.py`` treats
``meta.*`` as info-only: provenance drift never fails the gate.
"""
from __future__ import annotations

import os

if "REPRO_DEVICES" in os.environ:  # must precede any jax import: the
    # sharded sparse rows need forced host devices (same knob as
    # repro.launch.train and the CI shard job)
    os.environ["XLA_FLAGS"] = " ".join(filter(None, [
        os.environ.get("XLA_FLAGS"),
        f"--xla_force_host_platform_device_count={os.environ['REPRO_DEVICES']}",
    ]))

import argparse
import inspect
import json
import subprocess
import sys
import time
import traceback

SPARSE_FUSED_JSON = "BENCH_sparse_fused.json"
TUNE_JSON = "BENCH_tune.json"
STREAM_JSON = "BENCH_stream.json"
SERVE_JSON = "BENCH_serve.json"
OBS_JSON = "BENCH_obs.json"


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
    except Exception:  # noqa: BLE001 — provenance only, never fail a bench
        return "unknown"


def _meta(wall_seconds: float) -> dict:
    """Provenance stamped into every BENCH_*.json. Info-only for the
    regression gate (``check_regression.py`` matches ``meta.*``)."""
    import jax

    return {
        "git_rev": _git_rev(),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "cpu_count": os.cpu_count(),
        "wall_seconds": wall_seconds,
    }


def _select(mods, only: str):
    """--only: comma-separated names; each matches a module exactly
    (``bench_stream`` / ``stream``) or as a substring. An unmatched name
    is a hard error LISTING the valid modules — a typo must not silently
    run nothing (CI would archive an empty artifact and call it green).
    """
    picked = []
    for name in (s.strip() for s in only.split(",") if s.strip()):
        short = {m.__name__.split(".")[-1]: m for m in mods}
        hits = [short[name]] if name in short else (
            [short[f"bench_{name}"]] if f"bench_{name}" in short
            else [m for m in mods if name in m.__name__])
        if not hits:
            raise SystemExit(
                f"--only {name!r} matched no benchmark module; valid names: "
                + ", ".join(sorted(short)))
        picked += [m for m in hits if m not in picked]
    return picked


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run only these suites: exact module names (with or "
                         "without the bench_ prefix) or substrings, "
                         "comma-separated")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes where supported (CI)")
    ap.add_argument("--json", action="store_true",
                    help=f"write {SPARSE_FUSED_JSON} / {TUNE_JSON} / "
                         f"{STREAM_JSON} / {SERVE_JSON} / {OBS_JSON} with "
                         "the machine-readable timings (CI artifacts)")
    args = ap.parse_args()

    from benchmarks import (
        bench_common_feature,
        bench_division,
        bench_lr_vs_lsplm,
        bench_obs,
        bench_regularization,
        bench_router_balance,
        bench_serve,
        bench_sparse_fused,
        bench_stream,
        bench_tune,
        roofline_report,
    )

    mods = [bench_division, bench_regularization, bench_common_feature,
            bench_lr_vs_lsplm, bench_router_balance, bench_sparse_fused,
            bench_tune, bench_stream, bench_serve, bench_obs,
            roofline_report]
    json_paths = {bench_sparse_fused: SPARSE_FUSED_JSON,
                  bench_tune: TUNE_JSON,
                  bench_stream: STREAM_JSON,
                  bench_serve: SERVE_JSON,
                  bench_obs: OBS_JSON}
    if args.only:
        mods = _select(mods, args.only)

    ok = True
    for mod in mods:
        kwargs = {}
        params = inspect.signature(mod.run).parameters
        if args.smoke and "smoke" in params:
            kwargs["smoke"] = True
        collect: dict = {}
        if args.json and mod in json_paths:
            kwargs["collect"] = collect
        t0 = time.perf_counter()
        try:
            mod.run(**kwargs)
        except Exception:  # noqa: BLE001
            ok = False
            print(f"{mod.__name__},0,ERROR", file=sys.stderr)
            traceback.print_exc()
            if "collect" in kwargs:
                collect["error"] = traceback.format_exc()
        if "collect" in kwargs:
            collect["meta"] = _meta(time.perf_counter() - t0)
            # written even when a gate raised (possibly partial, plus the
            # "error" traceback): CI archives the trajectory either way
            # and the regression gate reports WHAT was missing instead of
            # diffing against a file that does not exist
            with open(json_paths[mod], "w") as f:
                json.dump(collect, f, indent=2, sort_keys=True)
            print(f"wrote {json_paths[mod]}", file=sys.stderr)
    if not ok:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
