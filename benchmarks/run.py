"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * bench_division        — Fig. 4 (division number m sweep)
  * bench_regularization  — Table 2 (L1 / L2,1 sparsity + AUC)
  * bench_common_feature  — Table 3 (common-feature trick cost)
  * bench_lr_vs_lsplm     — Fig. 5 (LS-PLM vs LR over 7 datasets)
  * bench_sparse_fused    — fused sparse kernel fwd/bwd vs oracles
  * roofline_report       — §Roofline rows from the dry-run artifacts

Usage:
  PYTHONPATH=src python -m benchmarks.run [--only SUBSTR] [--smoke] [--json]

``--only`` filters modules by name substring; ``--smoke`` asks modules
that support it for tiny shapes; ``--json`` additionally writes
``BENCH_sparse_fused.json`` — the machine-readable perf trajectory
(shapes, fwd/bwd microseconds, speedups vs the take+einsum oracle and
the chunked scatter) that CI archives as an artifact. The CI smoke step
runs ``--only sparse_fused --smoke --json`` on CPU.
"""
from __future__ import annotations

import os

if "REPRO_DEVICES" in os.environ:  # must precede any jax import: the
    # sharded sparse rows need forced host devices (same knob as
    # repro.launch.train and the CI shard job)
    os.environ["XLA_FLAGS"] = " ".join(filter(None, [
        os.environ.get("XLA_FLAGS"),
        f"--xla_force_host_platform_device_count={os.environ['REPRO_DEVICES']}",
    ]))

import argparse
import inspect
import json
import sys
import traceback

SPARSE_FUSED_JSON = "BENCH_sparse_fused.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run only modules whose name contains this substring")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes where supported (CI)")
    ap.add_argument("--json", action="store_true",
                    help=f"write {SPARSE_FUSED_JSON} with the sparse-kernel "
                         "timings (CI artifact)")
    args = ap.parse_args()

    from benchmarks import (
        bench_common_feature,
        bench_division,
        bench_lr_vs_lsplm,
        bench_regularization,
        bench_router_balance,
        bench_sparse_fused,
        roofline_report,
    )

    mods = [bench_division, bench_regularization, bench_common_feature,
            bench_lr_vs_lsplm, bench_router_balance, bench_sparse_fused,
            roofline_report]
    if args.only:
        mods = [m for m in mods if args.only in m.__name__]
        if not mods:
            raise SystemExit(f"--only {args.only!r} matched no benchmark module")

    ok = True
    for mod in mods:
        kwargs = {}
        params = inspect.signature(mod.run).parameters
        if args.smoke and "smoke" in params:
            kwargs["smoke"] = True
        collect: dict = {}
        if args.json and mod is bench_sparse_fused:
            kwargs["collect"] = collect
        try:
            mod.run(**kwargs)
        except Exception:  # noqa: BLE001
            ok = False
            print(f"{mod.__name__},0,ERROR", file=sys.stderr)
            traceback.print_exc()
        if collect:
            with open(SPARSE_FUSED_JSON, "w") as f:
                json.dump(collect, f, indent=2, sort_keys=True)
            print(f"wrote {SPARSE_FUSED_JSON}", file=sys.stderr)
    if not ok:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
