"""Fused sparse LS-PLM forward — fused vs gather+einsum vs densified.

The paper's production regime is K active ids out of d columns with
K << d (§2, §3.2). Three executions of the same p(y=1|x):

  * fused      repro.kernels.lsplm_sparse_fused.ops.lsplm_sparse_forward
               (Pallas kernel on TPU; K-chunked accumulation elsewhere —
               either way the (N, K, 2m) gather intermediate never lands
               in memory)
  * ref        the gather+einsum oracle (materialises (N, K, 2m))
  * densified  scatter into a dense (N, d) batch + the dense matmul —
               only run where N*d stays addressable; at production width
               it would need tens of GiB, which is the whole point

CSV rows: sparse_fused/<path>/N{N}_K{K}_d{d}_m{m},us,<speedup vs ref>.

Smoke mode (CI): tiny shapes, plus an interpret-mode Pallas-kernel
parity check so the kernel itself is exercised on CPU-only runners.
"""
from __future__ import annotations

import os

import jax
import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.lsplm_sparse_fused.lsplm_sparse_fused import (
    lsplm_sparse_fused_forward,
)
from repro.kernels.lsplm_sparse_fused.ops import lsplm_sparse_forward, pad_theta
from repro.kernels.lsplm_sparse_fused.ref import lsplm_sparse_forward_ref

# production-like sparsity sweep: K << d throughout
SHAPES = [  # (N, K, d, m)
    (4096, 16, 16_384, 12),  # small enough to also densify
    (16384, 24, 500_000, 12),
    (32768, 48, 1_000_000, 4),
]
SMOKE_SHAPES = [(512, 8, 4_096, 4)]
DENSIFY_LIMIT = 2**27  # max N*d elements we are willing to materialise


def _make(N, K, d, m, seed=0):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, d, (N, K)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(N, K)).astype(np.float32) / np.sqrt(K))
    theta = jnp.asarray(rng.normal(size=(d, 2 * m)).astype(np.float32) * 0.1)
    return ids, vals, pad_theta(theta)


def _densified(ids, vals, theta):
    N = ids.shape[0]
    d1 = theta.shape[0]
    x = jnp.zeros((N, d1), jnp.float32).at[
        jnp.arange(N)[:, None], ids].add(vals)
    z = x @ theta
    m = theta.shape[1] // 2
    gate = jax.nn.softmax(z[:, :m], axis=-1)
    return jnp.sum(gate * jax.nn.sigmoid(z[:, m:]), axis=-1)


def run(smoke: bool | None = None):
    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"
    shapes = SMOKE_SHAPES if smoke else SHAPES
    rows = []
    for (N, K, d, m) in shapes:
        tag = f"N{N}_K{K}_d{d}_m{m}"
        ids, vals, tp = _make(N, K, d, m)

        fused = jax.jit(lambda i, v, t: lsplm_sparse_forward(i, v, t))
        ref = jax.jit(lsplm_sparse_forward_ref)
        p_f = np.asarray(fused(ids, vals, tp))
        p_r = np.asarray(ref(ids, vals, tp))
        np.testing.assert_allclose(p_f, p_r, rtol=2e-4, atol=2e-6)

        t_ref = time_fn(ref, ids, vals, tp)
        t_fused = time_fn(fused, ids, vals, tp)
        rows.append((f"sparse_fused/fused/{tag}", t_fused,
                     f"{t_ref / t_fused:.2f}x_vs_ref"))
        rows.append((f"sparse_fused/gather_einsum/{tag}", t_ref, "1.00x_vs_ref"))
        if N * d <= DENSIFY_LIMIT:
            dens = jax.jit(_densified)
            np.testing.assert_allclose(
                np.asarray(dens(ids, vals, tp)), p_r, rtol=2e-4, atol=2e-6)
            t_dens = time_fn(dens, ids, vals, tp)
            rows.append((f"sparse_fused/densified/{tag}", t_dens,
                         f"{t_ref / t_dens:.2f}x_vs_ref"))

    if smoke:
        # exercise the actual Pallas kernel (interpret mode) for parity
        (N, K, d, m) = SMOKE_SHAPES[0]
        ids, vals, tp = _make(N, K, d, m)
        p_k, _ = lsplm_sparse_fused_forward(ids, vals, tp, block_n=128,
                                            interpret=True)
        np.testing.assert_allclose(
            np.asarray(p_k),
            np.asarray(lsplm_sparse_forward_ref(ids, vals, tp)),
            rtol=1e-5, atol=1e-6)
        rows.append((f"sparse_fused/kernel_interpret/N{N}_K{K}_d{d}_m{m}",
                     0.0, "parity_ok"))
    emit(rows)
