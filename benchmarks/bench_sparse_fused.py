"""Fused sparse LS-PLM — forward AND backward benchmarks.

The paper's production regime is K active ids out of d columns with
K << d (§2, §3.2). Forward, three executions of the same p(y=1|x):

  * fused      repro.kernels.lsplm_sparse_fused.ops.lsplm_sparse_forward
               (pipelined Pallas kernel on TPU; K-chunked scan elsewhere —
               either way the (N, K, 2m) gather intermediate never lands
               in memory)
  * ref        the gather+einsum oracle (materialises (N, K, 2m))
  * densified  scatter into a dense (N, d) batch + the dense matmul —
               only run where N*d stays addressable; at production width
               it would need tens of GiB, which is the whole point

Backward, the training hot spot — dTheta (+ dvals) from dz:

  * bwd_chunked  the python-unrolled K-chunked ``.at[].add`` scatter
                 (what PR 1 shipped — the baseline)
  * bwd_scan     the ``lax.scan`` no-plan fallback (constant trace size)
  * bwd_planned  the precomputed-transpose-plan path: class-gather
                 segment sums + one inverse gather, no sort, no scatter

measured at production shapes with BOTH uniform and Zipf-hot id traffic
(real CTR id streams are Zipf; ``data/sparse.generate_sparse`` models
that). The planned backward must beat the chunked scatter by >= 2x at
production sparsity on the jnp path — enforced on the geomean across the
uniform production shapes when REPRO_BENCH_ENFORCE is set (the perf
trajectory gate, also recorded in BENCH_sparse_fused.json via
``benchmarks/run.py --json``).

Sharded rows: with >= 8 devices (``REPRO_DEVICES=8`` forces host
devices; ``benchmarks/run.py`` honors it), the sweep adds
``sharded_fwd`` / ``sharded_fwd_bwd`` rows — the ``repro.shard``
(data x model) mesh step on a session batch vs the same loss/grad
single-device — with a parity assert. On forced HOST devices these
numbers measure orchestration overhead, not speedup (8 "devices" share
the CPU); the rows exist to track the trajectory and gate correctness.

CSV rows: sparse_fused/<path>/<tag>,us,<speedup vs baseline>.

Smoke mode (CI): tiny shapes; the interpret-mode Pallas kernels are
exercised for parity and the fused forward must hold parity with the
oracle within PARITY_SLACK (timing-noise margin on shared runners).
"""
from __future__ import annotations

import os

import jax
import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.lsplm_sparse_fused.lsplm_sparse_fused import (
    lsplm_sparse_fused_forward,
)
from repro.kernels.lsplm_sparse_fused.ops import (
    _dtheta_chunked,
    _dvals_chunked,
    lsplm_sparse_forward,
    pad_theta,
)
from repro.kernels.lsplm_sparse_fused.ref import lsplm_sparse_forward_ref
from repro.kernels.lsplm_sparse_scatter.ops import (
    build_transpose_plan,
    dvals_planned,
    scatter_add_planned,
)

# production-like sparsity sweep: K << d throughout
SHAPES = [  # (N, K, d, m)
    (4096, 16, 16_384, 12),  # small enough to also densify
    (16384, 24, 500_000, 12),
    (32768, 48, 1_000_000, 4),
]
SMOKE_SHAPES = [(512, 8, 4_096, 4)]
DENSIFY_LIMIT = 2**27  # max N*d elements we are willing to materialise
# fused forward must stay within this factor of the oracle in CI smoke
# (generous: shared runners jitter; the full sweep shows the real margin)
PARITY_SLACK = float(os.environ.get("REPRO_BENCH_PARITY_SLACK", "1.5"))
# plan-based backward vs the chunked scatter (jnp path): enforced on the
# GEOMEAN over the uniform-id production shapes — per-shape wall-clock on
# shared boxes jitters +-30%, the aggregate is stable (typ. ~3x: the
# d=1M K=48 shape alone is ~5x)
BWD_TARGET_SPEEDUP = 2.0


def _make(N, K, d, m, seed=0, zipf=False):
    rng = np.random.default_rng(seed)
    if zipf:  # hot head like real CTR id traffic (cf. generate_sparse)
        ids_np = (d * (rng.random((N, K)) ** 10.0)).astype(np.int64)
    else:
        ids_np = np.asarray(rng.integers(0, d, (N, K)))
    ids = jnp.asarray(ids_np, jnp.int32)
    vals = jnp.asarray(rng.normal(size=(N, K)).astype(np.float32) / np.sqrt(K))
    theta = jnp.asarray(rng.normal(size=(d, 2 * m)).astype(np.float32) * 0.1)
    return ids_np, ids, vals, pad_theta(theta)


def _densified(ids, vals, theta):
    N = ids.shape[0]
    d1 = theta.shape[0]
    x = jnp.zeros((N, d1), jnp.float32).at[
        jnp.arange(N)[:, None], ids].add(vals)
    z = x @ theta
    m = theta.shape[1] // 2
    gate = jax.nn.softmax(z[:, :m], axis=-1)
    return jnp.sum(gate * jax.nn.sigmoid(z[:, m:]), axis=-1)


def _bench_backward(ids_np, ids, vals, tp, tag, rows, results):
    """Backward shoot-out (dTheta + dvals from dz):

      bwd_chunked  the python-unrolled K-chunked ``.at[].add`` scatter —
                   byte-for-byte what PR 1 shipped (the enforcement
                   baseline)
      bwd_scan     the new ``lax.scan`` no-plan fallback
      bwd_planned  the precomputed-transpose-plan path

    ids are passed as runtime arguments everywhere: baking them in as
    jit constants pushes XLA's CPU scatter onto a ~4x slower
    constant-specialised path, which would flatter the plan unfairly
    (training closures DO hit that path — the plan's real-world win is
    larger than the number reported here).
    """
    N, K = ids.shape
    m2 = tp.shape[1]
    d = tp.shape[0] - 1
    rng = np.random.default_rng(1)
    dz = jnp.asarray(rng.normal(size=(N, m2)).astype(np.float32))
    plan = build_transpose_plan(ids_np, d + 1, pad_id=d)

    def bwd_chunked(ids, vals, dz):  # PR-1 faithful (python chunk loop)
        dtheta = jnp.zeros(tp.shape, jnp.float32)
        dvals_parts = []
        for k0 in range(0, K, 8):
            i = ids[:, k0:k0 + 8]
            v = vals[:, k0:k0 + 8].astype(jnp.float32)
            data = (v[..., None] * dz[:, None, :]).reshape(-1, m2)
            dtheta = dtheta.at[i.reshape(-1)].add(data)
            rows_ = jnp.take(tp, i, axis=0).astype(jnp.float32)
            dvals_parts.append(jnp.einsum("nkm,nm->nk", rows_, dz))
        return jnp.concatenate(dvals_parts, axis=1), dtheta

    def bwd_scan(ids, vals, dz):
        dt = _dtheta_chunked(ids, vals, tp, dz, None)
        dv = _dvals_chunked(ids, vals, tp, dz, None)
        return dv, dt

    def bwd_planned(plan, vals, dz):
        dt = scatter_add_planned(plan, vals, dz, mode="jnp")
        dv = dvals_planned(plan, tp, dz, (N, K))
        return dv, dt

    f_c = jax.jit(bwd_chunked)
    f_s = jax.jit(bwd_scan)
    f_p = jax.jit(bwd_planned)
    dv_c, dt_c = f_c(ids, vals, dz)
    dv_p, dt_p = f_p(plan, vals, dz)
    scale = max(1.0, float(jnp.abs(dt_c).max()))
    np.testing.assert_allclose(np.asarray(dt_p) / scale,
                               np.asarray(dt_c) / scale, atol=2e-5)
    np.testing.assert_allclose(np.asarray(dv_p), np.asarray(dv_c),
                               rtol=2e-4, atol=2e-5)

    t_c = time_fn(f_c, ids, vals, dz)
    t_s = time_fn(f_s, ids, vals, dz)
    t_p = time_fn(f_p, plan, vals, dz)
    speedup = t_c / t_p
    rows.append((f"sparse_fused/bwd_chunked/{tag}", t_c, "1.00x_vs_chunked"))
    rows.append((f"sparse_fused/bwd_scan/{tag}", t_s,
                 f"{t_c / t_s:.2f}x_vs_chunked"))
    rows.append((f"sparse_fused/bwd_planned/{tag}", t_p,
                 f"{speedup:.2f}x_vs_chunked"))
    results[tag]["bwd_chunked_us"] = t_c
    results[tag]["bwd_scan_us"] = t_s
    results[tag]["bwd_planned_us"] = t_p
    results[tag]["bwd_speedup"] = speedup
    return speedup


SHARD_MESHES = [(2, 4), (4, 2)]
# (sessions, d, m) for the sharded rows; ads/session, K come from defaults
SHARD_SHAPES = [(256, 100_000, 4)]
SHARD_SMOKE_SHAPES = [(64, 4_096, 4)]


def _bench_sharded(rows, results, smoke):
    """Sharded step vs single-device on a session batch (needs devices)."""
    need = max(a * b for a, b in SHARD_MESHES)
    if jax.device_count() < need:
        rows.append((f"sparse_fused/sharded/skipped_devices_"
                     f"{jax.device_count()}_of_{need}", 0.0, "set_REPRO_DEVICES"))
        return
    from repro.data.sparse import (
        generate_sparse,
        sparse_loss_and_grad,
        sparse_nll,
    )
    from repro.dist import shard_sparse_batch
    from repro.launch.mesh import make_debug_mesh
    from repro.shard import (
        make_partition,
        route_batch,
        sharded_sparse_loss_and_grad,
        sharded_sparse_nll,
    )

    for (G, d, m) in (SHARD_SMOKE_SHAPES if smoke else SHARD_SHAPES):
        batch = generate_sparse(
            num_features=d, num_user_features_range=(int(0.6 * d), d),
            sessions=G, seed=7)
        theta = jnp.asarray(np.random.default_rng(0).normal(
            size=(d, 2 * m)).astype(np.float32) * 0.05)
        lg_single = jax.jit(lambda t: sparse_loss_and_grad(t, batch))
        nll_single = jax.jit(lambda t: sparse_nll(t, batch))
        l_ref, g_ref = lg_single(theta)
        t_fwd_1 = time_fn(nll_single, theta)
        t_bwd_1 = time_fn(lg_single, theta)
        for (dd, dm) in SHARD_MESHES:
            tag = f"G{G}_d{d}_m{m}_mesh{dd}x{dm}"
            mesh = make_debug_mesh(data=dd, model=dm)
            part = make_partition(d, dm)
            sb = shard_sparse_batch(mesh, route_batch(batch, part,
                                                      data_shards=dd))
            theta_p = jax.device_put(part.pad_rows(theta))
            fwd = jax.jit(lambda t: sharded_sparse_nll(t, sb, mesh))
            bwd = jax.jit(lambda t: sharded_sparse_loss_and_grad(t, sb, mesh))
            l_sh, g_sh = bwd(theta_p)
            np.testing.assert_allclose(float(l_sh), float(l_ref), rtol=2e-5)
            scale = max(1.0, float(jnp.abs(g_ref).max()))
            np.testing.assert_allclose(
                np.asarray(part.unpad_rows(jax.device_get(g_sh))) / scale,
                np.asarray(g_ref) / scale, atol=3e-5)
            t_fwd = time_fn(fwd, theta_p)
            t_bwd = time_fn(bwd, theta_p)
            rows.append((f"sparse_fused/sharded_fwd/{tag}", t_fwd,
                         f"{t_fwd_1 / t_fwd:.2f}x_vs_single"))
            rows.append((f"sparse_fused/sharded_fwd_bwd/{tag}", t_bwd,
                         f"{t_bwd_1 / t_bwd:.2f}x_vs_single"))
            results[tag] = {
                "G": G, "d": d, "m": m, "mesh_data": dd, "mesh_model": dm,
                "sharded_fwd_us": t_fwd, "sharded_fwd_bwd_us": t_bwd,
                "single_fwd_us": t_fwd_1, "single_fwd_bwd_us": t_bwd_1,
                "parity": "ok",
            }


def run(smoke: bool | None = None, collect: dict | None = None):
    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"
    enforce = os.environ.get("REPRO_BENCH_ENFORCE", "") == "1"
    shapes = SMOKE_SHAPES if smoke else SHAPES
    rows = []
    results: dict = {}
    if collect is not None:  # bind BEFORE the sweep: a failing run still
        collect["backend"] = jax.default_backend()   # leaves partial data
        collect["smoke"] = smoke                     # for the CI artifact
        collect["parity_slack"] = PARITY_SLACK
        collect["bwd_target_speedup"] = BWD_TARGET_SPEEDUP
        collect["shapes"] = results
    for (N, K, d, m) in shapes:
        for zipf in ((False,) if smoke else (False, True)):
            tag = f"N{N}_K{K}_d{d}_m{m}" + ("_zipf" if zipf else "")
            ids_np, ids, vals, tp = _make(N, K, d, m, zipf=zipf)
            results[tag] = {"N": N, "K": K, "d": d, "m": m,
                            "ids": "zipf" if zipf else "uniform"}

            fused = jax.jit(lambda i, v, t: lsplm_sparse_forward(i, v, t))
            ref = jax.jit(lsplm_sparse_forward_ref)
            p_f = np.asarray(fused(ids, vals, tp))
            p_r = np.asarray(ref(ids, vals, tp))
            np.testing.assert_allclose(p_f, p_r, rtol=2e-4, atol=2e-6)

            t_ref = time_fn(ref, ids, vals, tp)
            t_fused = time_fn(fused, ids, vals, tp)
            rows.append((f"sparse_fused/fused/{tag}", t_fused,
                         f"{t_ref / t_fused:.2f}x_vs_ref"))
            rows.append((f"sparse_fused/gather_einsum/{tag}", t_ref,
                         "1.00x_vs_ref"))
            results[tag]["fwd_fused_us"] = t_fused
            results[tag]["fwd_ref_us"] = t_ref
            results[tag]["fwd_speedup_vs_ref"] = t_ref / t_fused
            if smoke and t_fused > PARITY_SLACK * t_ref:
                # shared runners jitter: re-measure once before failing
                t_ref = min(t_ref, time_fn(ref, ids, vals, tp))
                t_fused = min(t_fused, time_fn(fused, ids, vals, tp))
                results[tag]["fwd_fused_us"] = t_fused
                results[tag]["fwd_ref_us"] = t_ref
                results[tag]["fwd_speedup_vs_ref"] = t_ref / t_fused
            if smoke and t_fused > PARITY_SLACK * t_ref:
                raise AssertionError(
                    f"fused forward lost parity with the oracle at {tag}: "
                    f"{t_fused:.0f}us vs {t_ref:.0f}us "
                    f"(slack {PARITY_SLACK}x, best of 2 runs)")

            if not zipf and N * d <= DENSIFY_LIMIT:
                dens = jax.jit(_densified)
                np.testing.assert_allclose(
                    np.asarray(dens(ids, vals, tp)), p_r, rtol=2e-4, atol=2e-6)
                t_dens = time_fn(dens, ids, vals, tp)
                rows.append((f"sparse_fused/densified/{tag}", t_dens,
                             f"{t_ref / t_dens:.2f}x_vs_ref"))
                results[tag]["fwd_densified_us"] = t_dens

            _bench_backward(ids_np, ids, vals, tp, tag, rows, results)

    if enforce and not smoke:
        ups = [r["bwd_speedup"] for r in results.values()
               if r["ids"] == "uniform"]
        geomean = float(np.exp(np.mean(np.log(ups))))
        print(f"sparse_fused/bwd_planned/geomean,0.0,"
              f"{geomean:.2f}x_vs_chunked")
        if geomean < BWD_TARGET_SPEEDUP:
            raise AssertionError(
                f"plan-based backward geomean only {geomean:.2f}x vs the "
                f"chunked scatter (target {BWD_TARGET_SPEEDUP}x); "
                f"per-shape: {[round(u, 2) for u in ups]}")

    _bench_sharded(rows, results, smoke)

    if smoke:
        # exercise the actual Pallas kernels (interpret mode) for parity
        (N, K, d, m) = SMOKE_SHAPES[0]
        _, ids, vals, tp = _make(N, K, d, m)
        p_k, _ = lsplm_sparse_fused_forward(ids, vals, tp, block_n=128,
                                            block_k=4, interpret=True)
        np.testing.assert_allclose(
            np.asarray(p_k),
            np.asarray(lsplm_sparse_forward_ref(ids, vals, tp)),
            rtol=1e-5, atol=1e-6)
        rows.append((f"sparse_fused/kernel_interpret/N{N}_K{K}_d{d}_m{m}",
                     0.0, "parity_ok"))
    emit(rows)
    return results
