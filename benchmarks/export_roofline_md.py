"""Render EXPERIMENTS.md §Roofline tables from the dry-run artifacts.

  PYTHONPATH=src:. python -m benchmarks.export_roofline_md > benchmarks/ROOFLINE.md
"""
from __future__ import annotations

from benchmarks.roofline_report import load

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def lever(r) -> str:
    """One sentence: what would move the dominant term down (validated
    for the hillclimbed pairs in EXPERIMENTS.md §Perf)."""
    b = r["roofline"]["bottleneck"]
    shape, arch = r["shape"], r["arch"]
    moe = arch.startswith(("dbrx", "granite"))
    if shape == "train_4k" and b == "memory":
        return ("seq-parallel inter-block activations (validated on qwen: "
                "-83% mem) + chunked CE over the vocab logits")
    if shape == "prefill_32k" and b == "memory":
        return ("flash-attention kernel keeps score blocks in VMEM "
                "(kernels/flash_attention); larger attn chunks cut "
                "softmax re-reads")
    if b == "collective" and shape in ("decode_32k", "long_500k"):
        base = ("align cache layout with attention sharding "
                "(attn_shard=head_dim: validated -54% on dbrx)")
        if moe:
            base += " + token-gather MoE serving"
        return base
    if b == "memory" and shape in ("decode_32k", "long_500k"):
        return ("int8 KV cache (validated 3.3x on qwen) and batch growth "
                "to amortise weight reads")
    if b == "collective":
        return ("reduce-scatter/all-gather overlap with compute via "
                "latency-hiding scheduler; fewer resharding boundaries")
    if b == "compute":
        return "MXU-aligned kernel tiling; already near compute roofline"
    return "see §Perf"


def fmt(mesh: str, title: str) -> str:
    recs = load(mesh)
    recs.sort(key=lambda r: (r["arch"], ORDER.index(r["shape"])))
    lines = [f"### {title}", ""]
    lines.append("| arch | shape | mem/chip GiB | t_comp s | t_mem s | "
                 "t_coll s | bound | useful | MFU bound | lever on dominant term |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        rl = r["roofline"]
        mem = r["memory"]["total_bytes_per_chip"] / 2**30
        sw = " (SW)" if r.get("sliding_window") else ""
        lines.append(
            f"| {r['arch']} | {r['shape']}{sw} | {mem:.2f} | "
            f"{rl['t_compute_s']:.2e} | {rl['t_memory_s']:.2e} | "
            f"{rl['t_collective_s']:.2e} | {rl['bottleneck']} | "
            f"{rl['useful_flops_ratio']:.2f} | {rl['mfu_bound']:.3f} | "
            f"{lever(r)} |")
    lines.append("")
    return "\n".join(lines)


def main():
    print("# Roofline tables (generated from benchmarks/dryrun_*.json)\n")
    print("(SW) = sliding-window decode variant for attention archs at "
          "long_500k. Multi-pod rows prove the 512-chip lowering; their "
          "cost columns are body-once HLO numbers (no probes), see "
          "EXPERIMENTS.md accounting notes.\n")
    print(fmt("single", "Single pod — (data=16, model=16), 256 chips"))
    print(fmt("multi", "Multi-pod — (pod=2, data=16, model=16), 512 chips"))


if __name__ == "__main__":
    main()
