"""Table 3 — training cost with/without the common-feature trick.

Measures, on identical data:
  * memory: bytes to store the batch compressed vs decompressed,
  * time: wall-clock per loss+gradient evaluation (jitted, full batch),
  * flops: analytic dot-product FLOPs of one evaluation.
Paper: 65.2% memory saving, 91.7% time saving (their user-feature block is
much wider than ours, so our savings are smaller but the same mechanism).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import CTRBatch
from repro.core.objective import smooth_loss_and_grad
from repro.data import (CTRDataConfig, flops_per_eval, generate,
                        memory_bytes, to_dense_batch)

M = 12

# Production-like feature balance (§3.2): user profile + behaviour history
# features are the WIDE block ("shopping item IDs, preferred brands,
# favorite shops"), shared across the ~8 ads of a page view.
CF_CFG = CTRDataConfig(
    num_user_features=512, num_ad_features=32, noise_features=0,
    true_regions=4, ads_per_session=8, density=0.1, seed=0,
)
SESSIONS = 2000


def run():
    train_cf, _ = generate(CF_CFG, SESSIONS, seed=1)
    dense = to_dense_batch(train_cf)
    d = CF_CFG.num_features
    theta = jnp.asarray(
        0.01 * np.random.default_rng(0).normal(size=(d, 2 * M)), jnp.float32)

    cf_batch = jax.tree.map(jnp.asarray, train_cf)
    dense_batch = CTRBatch(x=jnp.asarray(dense.x), y=jnp.asarray(dense.y))

    f_cf = jax.jit(lambda t: smooth_loss_and_grad(t, cf_batch, common_feature=True))
    f_dense = jax.jit(lambda t: smooth_loss_and_grad(t, dense_batch))

    us_cf = time_fn(f_cf, theta)
    us_dense = time_fn(f_dense, theta)
    mem_cf = memory_bytes(train_cf, compressed=True)
    mem_dense = memory_bytes(train_cf, compressed=False)
    fl_cf = flops_per_eval(train_cf, M, compressed=True)
    fl_dense = flops_per_eval(train_cf, M, compressed=False)

    # correctness guard: both paths compute the same loss
    l1 = float(f_cf(theta)[0])
    l2 = float(f_dense(theta)[0])
    assert abs(l1 - l2) / abs(l2) < 1e-4, (l1, l2)

    rows = [
        ("table3_with_cf", f"{us_cf:.0f}",
         f"mem_bytes={mem_cf};flops={fl_cf}"),
        ("table3_without_cf", f"{us_dense:.0f}",
         f"mem_bytes={mem_dense};flops={fl_dense}"),
        ("table3_savings", "0",
         f"mem_saving={1 - mem_cf / mem_dense:.1%};"
         f"time_saving={max(0.0, 1 - us_cf / us_dense):.1%};"
         f"flop_saving={1 - fl_cf / fl_dense:.1%}"),
    ]
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
