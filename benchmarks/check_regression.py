"""CI bench-regression gate: diff a smoke run's BENCH_*.json against
its committed baseline with per-metric tolerances.

CI has always ARCHIVED the ``BENCH_*.json`` trajectories; this is the
step that finally reads them. After every bench-smoke step the workflow
runs::

    python -m benchmarks.check_regression BENCH_serve.json \
        --baseline benchmarks/baselines/BENCH_serve.json \
        --summary "$GITHUB_STEP_SUMMARY"

Both files flatten to dotted metric paths; every baseline metric is
matched against the RULES table below (first regex wins) and the
comparison table lands in the GitHub step summary on every push —
pass or fail. The build fails when:

  * a gated metric degrades past its tolerance,
  * a baseline metric disappears from the run (a silently-skipped
    benchmark section must not look green),
  * the run recorded an ``error`` (``benchmarks/run.py --json`` writes
    the traceback into the JSON when a gate raises).

Tolerance philosophy — smoke shapes on shared CI runners:

  * DETERMINISTIC metrics (compile counts, dispatch grouping, parity
    strings, config echoes, sparsity/size ratios) gate EXACTLY — any
    drift is a real behaviour change;
  * QUALITY metrics (AUC) gate tightly — they are seeded and should
    not move;
  * SPEED metrics (us, seconds, ads/sec, QPS, speedup ratios) gate
    LOOSELY (runner hardware varies): latency may grow up to 5x, and
    throughput/speedups may drop to 20%/half before failing. The gate
    catches order-of-magnitude regressions — an accidentally-serialised
    hot path, a recompile storm — not scheduler noise;
  * TRAFFIC-DEPENDENT counters (queue flush mix, occupancy, rejects —
    functions of real measured service times) are reported as info
    only.

Regenerating baselines when a change LEGITIMATELY moves a number is
documented in README "CI & benchmarks": rerun the smoke bench with
``--json`` and copy the fresh file into ``benchmarks/baselines/``, in
the same PR as the change that moved it.
"""
from __future__ import annotations

import argparse
import json
import re
import sys

# (regex on the dotted metric path, kind, tolerance) — FIRST match wins.
# kinds: exact | higher_better | lower_better | forbidden | info
RULES: list[tuple[str, str, float]] = [
    (r"(^|\.)error$", "forbidden", 0.0),
    # provenance stamp (git rev, backend, device/cpu counts, module
    # wall): self-description, never a gate — must precede the speed
    # rules or meta.wall_seconds would gate on runner drift
    (r"(^|\.)meta\.", "info", 0.0),
    # open-loop load rows: latency/throughput gate loosely, the flush
    # mix / occupancy / shed counts follow real service walls -> info
    (r"\.load\..*latency_p\d+_us$", "lower_better", 4.0),
    (r"\.load\..*latency_mean_us$", "lower_better", 4.0),
    (r"\.load\..*(candidates_per_sec|achieved_qps)$", "higher_better", 0.8),
    (r"\.load\.", "info", 0.0),
    # wall-clock-shaped engine counters that depend on traffic timing
    (r"(^|\.)(qps|occupancy)$", "info", 0.0),
    (r"(^|\.)(bucket_hits|flushes)\.", "info", 0.0),
    (r"(^|\.)(requests|served|rejected|accepted|slots|candidates)$",
     "info", 0.0),
    # deterministic structure: any drift is a real behaviour change
    (r"(^|\.)(compiles|dispatches|alive_rows|deployed_bytes)$", "exact", 0.0),
    (r"(^|\.)(parity|backend|smoke)$", "exact", 0.0),
    (r"(^|\.)(d|m|nnz_frac|sessions|ads_per_session|k_user|k_ad"
     r"|max_batch|max_delay_us|max_pending|target_speedup"
     r"|offered_qps)$", "exact", 0.0),
    (r"(rows_ratio|deployed_size_ratio|compression)$", "lower_better", 0.01),
    (r"(^|\.)max_dp$", "lower_better", 0.5),
    # quality: seeded, should not move
    (r"(^|\.)auc_\w+$", "higher_better", 0.02),
    (r"(^|\.)calibration_\w+$", "info", 0.0),
    # obs instrumentation overhead: the real <=2% gate runs in the bench
    # itself under REPRO_BENCH_ENFORCE; here a loose backstop that only
    # catches a hot path growing pathologically slow on smoke shapes
    (r"(^|\.)max_overhead_ratio$", "exact", 0.0),
    (r"(^|\.)overhead_ratio$", "lower_better", 0.5),
    # speed: loose (shared-runner noise), catches order-of-magnitude only
    (r"(speedup_geomean|speedup)$", "higher_better", 0.5),
    (r"(_us|_seconds)$", "lower_better", 4.0),
    (r"_us_per_iter$", "lower_better", 4.0),
    (r"(per_sec|steps_per_sec)$", "higher_better", 0.8),
]
DEFAULT_RULE = ("info", 0.0)


def flatten(tree, prefix: str = "") -> dict:
    """JSON -> {dotted.path: scalar leaf} (lists index numerically)."""
    out: dict = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(tree, list):
        for i, v in enumerate(tree):
            out.update(flatten(v, f"{prefix}{i}."))
    else:
        out[prefix.rstrip(".")] = tree
    return out


def rule_for(path: str) -> tuple[str, float]:
    for pattern, kind, tol in RULES:
        if re.search(pattern, path):
            return kind, tol
    return DEFAULT_RULE


def _fmt(v) -> str:
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return str(v)
    if isinstance(v, int):
        return str(v)
    return f"{v:.4g}"


def compare(baseline: dict, run: dict) -> tuple[list[dict], bool]:
    """Row dicts for every baseline metric (+ run-side error keys and a
    count of new metrics); second return is overall pass."""
    base_flat, run_flat = flatten(baseline), flatten(run)
    rows, ok = [], True
    for path in sorted(set(base_flat) | set(run_flat)):
        kind, tol = rule_for(path)
        base_v, run_v = base_flat.get(path), run_flat.get(path)
        row = {"metric": path, "kind": kind, "tol": tol,
               "baseline": base_v, "run": run_v, "status": "ok"}
        if kind == "forbidden":
            if path in run_flat:
                row["status"] = "FAIL: bench recorded an error"
                ok = False
            else:
                continue  # error absent everywhere -> nothing to report
        elif path not in run_flat:
            row["status"] = "FAIL: metric missing from run"
            ok = False
        elif path not in base_flat:
            row["status"] = "new (no baseline)"
        elif kind == "exact":
            if base_v != run_v:
                row["status"] = "FAIL: changed (exact)"
                ok = False
        elif kind in ("higher_better", "lower_better"):
            if not isinstance(run_v, (int, float)) \
                    or not isinstance(base_v, (int, float)):
                if base_v != run_v:
                    row["status"] = "FAIL: changed (non-numeric)"
                    ok = False
            elif kind == "higher_better" and run_v < base_v * (1 - tol):
                row["status"] = f"FAIL: below baseline - {tol:.0%}"
                ok = False
            elif kind == "lower_better" and run_v > base_v * (1 + tol):
                row["status"] = f"FAIL: above baseline + {tol:.0%}"
                ok = False
        rows.append(row)
    return rows, ok


def render_markdown(name: str, rows: list[dict], ok: bool) -> str:
    """The baseline-vs-run table for $GITHUB_STEP_SUMMARY: gated metrics
    and failures in the open, info rows collapsed."""
    gated = [r for r in rows if r["kind"] != "info"
             or r["status"].startswith("FAIL")]
    info_n = len(rows) - len(gated)
    verdict = "PASS" if ok else "FAIL"
    out = [f"### Bench regression gate — `{name}`: **{verdict}**", ""]
    out += ["| metric | baseline | run | rule | status |",
            "|---|---|---|---|---|"]
    for r in gated:
        rule = r["kind"] if r["kind"] in ("exact", "forbidden") \
            else f"{r['kind']} ±{r['tol']:.0%}"
        status = r["status"]
        if status.startswith("FAIL"):
            status = f"**{status}**"
        out.append(f"| `{r['metric']}` | {_fmt(r['baseline'])} "
                   f"| {_fmt(r['run'])} | {rule} | {status} |")
    out.append("")
    out.append(f"_{info_n} info-only metrics not shown "
               f"(traffic-dependent counters, config echoes)._")
    out.append("")
    return "\n".join(out)


def main() -> int:
    ap = argparse.ArgumentParser(
        description="diff a BENCH_*.json smoke run against its committed "
                    "baseline with per-metric tolerances")
    ap.add_argument("run", help="the smoke run's BENCH_*.json")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline (benchmarks/baselines/...)")
    ap.add_argument("--summary", default=None,
                    help="append the markdown comparison table here "
                         "(pass $GITHUB_STEP_SUMMARY in CI)")
    args = ap.parse_args()

    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"no baseline at {args.baseline!r} — generate one with the "
              "matching smoke bench (--smoke --json) and commit it there "
              "(see README 'CI & benchmarks')", file=sys.stderr)
        return 1
    try:
        with open(args.run) as f:
            run = json.load(f)
    except FileNotFoundError:
        print(f"no bench output at {args.run!r} — did the bench-smoke step "
              "run with --json?", file=sys.stderr)
        return 1

    rows, ok = compare(baseline, run)
    md = render_markdown(args.run, rows, ok)
    print(md)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(md + "\n")
    if not ok:
        fails = [r for r in rows if r["status"].startswith("FAIL")]
        print(f"regression gate FAILED on {len(fails)} metric(s); if a "
              "change legitimately moved a number, regenerate the baseline "
              "(README 'CI & benchmarks')", file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
