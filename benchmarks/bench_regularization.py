"""Table 2 — regularisation effects on sparsity and AUC.

Paper claims (qualitative, reproduced on synthetic data):
  * L2,1 alone removes features (zero rows) and many params;
  * L1 alone leaves fewer nonzero params than L2,1 alone;
  * L1 + L2,1 together give the sparsest model AND the best AUC.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import DATA_CFG, emit, eval_auc, fit_lsplm, load_split
from repro.core import regularizers

# the paper's Table-2 combos, plus a strong-L2,1 row: our generator has
# only 8/56 irrelevant columns (vs millions in production), so the
# feature-selection onset sits at larger lambda than the paper's lam=1.
GRID = ((0.0, 0.0), (0.0, 1.0), (1.0, 0.0), (1.0, 1.0), (1.0, 10.0))


def run():
    import numpy as np

    train_cf, test_cf = load_split(day=0)
    rows = []
    for beta, lam in GRID:
        theta, _ = fit_lsplm(train_cf, m=12, lam=lam, beta=beta)
        nnz = int(regularizers.nonzero_count(theta))
        nfeat = int(regularizers.nonzero_feature_count(theta))
        test_auc = eval_auc(theta, test_cf)
        # of the killed rows, how many are the planted noise columns?
        row_nnz = np.abs(np.asarray(theta)).sum(axis=1)
        killed = np.nonzero(row_nnz == 0)[0]
        noise_killed = int((killed >= DATA_CFG.num_features
                            - DATA_CFG.noise_features).sum())
        rows.append((
            f"table2_reg_beta{beta:g}_lam{lam:g}",
            "0",
            f"features={nfeat}/{DATA_CFG.num_features};nnz={nnz};"
            f"test_auc={test_auc:.4f};"
            f"noise_rows_killed={noise_killed}/{DATA_CFG.noise_features}",
        ))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
