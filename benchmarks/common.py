"""Shared helpers for the paper-table benchmarks.

All benchmarks run on a synthetic workload that mirrors the paper's data
statistics (sparse features, session/common-feature structure, piecewise-
linear ground truth) — see DESIGN.md §8 for the simulation rationale.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CTRBatch, predict_proba
from repro.core.lsplm import params_from_theta
from repro.core.objective import smooth_loss_and_grad
from repro.data import CTRDataConfig, auc, generate, to_dense_batch
from repro.optim import OWLQNPlus

DATA_CFG = CTRDataConfig(
    num_user_features=24, num_ad_features=24, noise_features=8,
    true_regions=4, ads_per_session=4, seed=0,
)
TRAIN_SESSIONS = 4000
TEST_SESSIONS = 800


def load_split(day: int = 0):
    """One 'day' (Table 1): disjoint train/test from the shared truth."""
    train_cf, _ = generate(DATA_CFG, TRAIN_SESSIONS, seed=100 * day + 1)
    test_cf, _ = generate(DATA_CFG, TEST_SESSIONS, seed=100 * day + 2)
    return train_cf, test_cf


def fit_lsplm(train_cf, m: int, lam: float, beta: float, iters: int = 70,
              seed: int = 0):
    train = to_dense_batch(train_cf)
    tb = CTRBatch(x=jnp.asarray(train.x), y=jnp.asarray(train.y))
    d = DATA_CFG.num_features
    theta0 = jnp.asarray(
        0.01 * np.random.default_rng(seed).normal(size=(d, 2 * m)), jnp.float32)
    opt = OWLQNPlus(lambda t: smooth_loss_and_grad(t, tb), lam=lam, beta=beta)
    theta, trace = opt.run(theta0, max_iters=iters)
    return theta, trace


def eval_auc(theta, cf_batch) -> float:
    dense = to_dense_batch(cf_batch)
    p = predict_proba(params_from_theta(theta), jnp.asarray(dense.x))
    return auc(dense.y, np.asarray(p))


def time_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-clock microseconds per call (blocks on device results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def emit(rows: list[tuple]):
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
