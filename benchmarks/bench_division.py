"""Fig. 4 — model performance vs division number m.

Paper claim: test AUC improves markedly from m=6 to m=12, then gently for
m=24, 36 (capacity saturates); training cost grows with m.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, eval_auc, fit_lsplm, load_split

MS = (1, 6, 12, 24)


def run():
    train_cf, test_cf = load_split(day=0)
    rows = []
    for m in MS:
        t0 = time.perf_counter()
        theta, trace = fit_lsplm(train_cf, m=m, lam=1.0, beta=1.0)
        wall = time.perf_counter() - t0
        train_auc = eval_auc(theta, train_cf)
        test_auc = eval_auc(theta, test_cf)
        rows.append((
            f"fig4_division_m{m}",
            f"{wall * 1e6:.0f}",
            f"train_auc={train_auc:.4f};test_auc={test_auc:.4f};iters={len(trace)}",
        ))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
