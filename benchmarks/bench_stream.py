"""Streaming trainer benchmark — overlapped host re-planner vs
synchronous re-planning.

Per window, the streaming trainer pays host-side plan construction
(+ routing on a mesh) AND recompilation (plan shapes are
data-dependent) before the device can step. ``repro.stream.planner``
hides both behind the previous window's device iterations; this bench
runs the SAME drifted stream twice — ``overlap=False`` (everything
serial, the baseline) and ``overlap=True`` — and reports end-to-end
steps/sec across windows plus the planner's measured overlap ratio.

The trajectory is identical in both modes (the planner changes WHEN
host work happens, never WHAT), so the bench asserts final-Theta parity
before timing counts.

Enforcement: with REPRO_BENCH_ENFORCE=1 (and not --smoke) the
overlapped mode must BEAT synchronous on the geomean, and must reach
STREAM_TARGET_SPEEDUP (1.3x) when the host has the parallel slack the
overlap design assumes (>= MIN_CPUS_FOR_TARGET cpus — on a 2-core box
the background build and the foreground step fight for the same two
cores, which caps the achievable speedup around 1.25x even at overlap
ratio 1.0; on a real accelerator host the step does not consume host
cores at all). The enforced target is recorded alongside the measured
numbers in BENCH_stream.json via ``benchmarks/run.py --json``.

CSV rows: stream/<mode>/<tag>,us_per_step,steps_per_sec and a
stream/overlap_speedup/<tag> summary row.

Decay table (paper Fig. 7 analogue): a model trained once on day 0 is
evaluated on every later day of a drifted stream (held-out per-sample
NLL + AUC, ``repro.eval.metrics``) next to the streaming trainer's
model refreshed through day t-1 — the frozen model DECAYS as the id
traffic drifts away from it while the streamed one holds, which is the
paper's argument for daily retraining. Rows
``stream/decay_{frozen,stream}/day<t>`` plus a ``decay`` section in
BENCH_stream.json.
"""
from __future__ import annotations

import os
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit

# production sparsity: K active ids out of d columns, K << d. Windows
# slide over a drifted day stream; inner_iters is set so device work
# roughly balances host build (the regime streaming runs in — compile +
# plan per window amortised over a bounded iteration budget).
CONFIGS = [  # (days, sessions/day, d, m, active_user, active_ad, W, inner)
    (6, 256, 200_000, 4, 24, 12, 3, 3),
    (6, 384, 300_000, 4, 24, 12, 2, 3),
]
SMOKE_CONFIGS = [(3, 32, 5_000, 2, 8, 5, 2, 2)]
STREAM_TARGET_SPEEDUP = 1.3
# below this many cpus the full target is unreachable by construction
# (hidden host work steals the step's own cores); the enforced floor is
# then TWO_CORE_FLOOR — the packing win that 2 cores do sustain
MIN_CPUS_FOR_TARGET = 4
TWO_CORE_FLOOR = 1.1
# wall-clock on shared/small boxes jitters (the overlapped mode's
# background compile contends with the device step for cores): measure
# each mode REPS times and keep the best steps/sec, like time_fn's
# median does for the kernel benches
REPS = 2


def _run_mode(stream, theta0, *, window, inner, overlap):
    from repro.stream import StreamTrainer

    tr = StreamTrainer(stream, lam=1.0, beta=1.0, window=window,
                       inner_iters=inner, overlap=overlap)
    t0 = time.perf_counter()
    state, trace = tr.run(tr.init(theta0))
    wall = time.perf_counter() - t0
    steps = stream.num_days * inner
    return {
        "wall_s": wall,
        "steps_per_sec": steps / wall,
        "build_s": tr.planner_stats.build_seconds,
        "exposed_s": tr.planner_stats.wait_seconds,
        "overlap_ratio": tr.planner_stats.overlap_ratio,
        "theta": np.asarray(tr.theta(state)),
        "fs": [f for w in trace for f in w.fs],
    }


def _decay_table(smoke: bool, collect: dict | None, rows: list) -> None:
    """Per-day held-out NLL/AUC of a frozen day-0 model vs the streaming
    trainer's rolling model (Fig. 7 analogue). Small LEARNABLE shapes —
    at production d the synthetic stream is too sparse to beat the null
    NLL, which would hide the decay signal."""
    from repro.core.objective import nll_sparse, smooth_loss_and_grad
    from repro.data.sparse import build_batch_plans, sparse_predict
    from repro.eval import auc
    from repro.optim import OWLQNPlus
    from repro.stream import DayStream, StreamTrainer

    days, G, d, m, au, ad, W, inner, iters = (
        (4, 48, 300, 2, 8, 5, 2, 3, 8) if smoke else
        (7, 192, 400, 4, 16, 8, 2, 4, 30))
    lam = beta = 0.25
    stream = DayStream(days, sessions_per_day=G, num_features=d,
                       active_user=au, active_ad=ad, drift=0.06, seed=11)
    theta0 = jnp.asarray(
        0.01 * np.random.default_rng(17).normal(size=(d, 2 * m)), jnp.float32)

    # frozen: one train on day 0, never refreshed (what Fig. 7 measures)
    day0 = build_batch_plans(stream.day(0))
    opt = OWLQNPlus(lambda t: smooth_loss_and_grad(t, day0),
                    lam=lam, beta=beta)
    theta_frozen, _ = opt.run(theta0, max_iters=iters)

    # streaming: refreshed through day t-1 when scoring day t
    per_day = {}
    tr = StreamTrainer(stream, lam=lam, beta=beta, window=W,
                       inner_iters=inner, overlap=False)
    tr.run(tr.init(theta0),
           callback=lambda t, ws, st: per_day.__setitem__(t, tr.theta(st)))

    def day_eval(theta, t):
        b = stream.day(t)
        nll = float(nll_sparse(theta, b)) / int(b.y.shape[0])
        return nll, auc(np.asarray(b.y), np.asarray(sparse_predict(theta, b)))

    frozen, streaming = [], []
    for t in range(1, days):
        nf, af = day_eval(theta_frozen, t)
        ns, a_s = day_eval(per_day[t - 1], t)
        rows.append((f"stream/decay_frozen/day{t}", 0.0,
                     f"nll={nf:.4f};auc={af:.4f}"))
        rows.append((f"stream/decay_stream/day{t}", 0.0,
                     f"nll={ns:.4f};auc={a_s:.4f}"))
        frozen.append({"day": t, "nll": nf, "auc": af})
        streaming.append({"day": t, "nll": ns, "auc": a_s})
    if collect is not None:
        collect["decay"] = {
            "days": days, "sessions_per_day": G, "d": d, "m": m,
            "window": W, "inner_iters": inner, "train_once_iters": iters,
            "drift": 0.06, "frozen": frozen, "streaming": streaming,
        }


def run(smoke: bool | None = None, collect: dict | None = None):
    from repro.stream import DayStream

    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"
    enforce = os.environ.get("REPRO_BENCH_ENFORCE", "") == "1"
    configs = SMOKE_CONFIGS if smoke else CONFIGS
    rows = []
    results: dict = {}
    if collect is not None:  # bind BEFORE the sweep: a failing run still
        import jax                        # leaves partial data for CI
        collect["backend"] = jax.default_backend()
        collect["smoke"] = smoke
        collect["target_speedup"] = STREAM_TARGET_SPEEDUP
        collect["configs"] = results

    speedups = []
    for (days, G, d, m, au, ad, W, inner) in configs:
        tag = f"days{days}_G{G}_d{d}_m{m}_w{W}_i{inner}"
        stream = DayStream(days, sessions_per_day=G, num_features=d,
                           active_user=au, active_ad=ad, seed=9)
        for t in range(days):  # warm the day cache so the first timed
            stream.day(t)      # mode doesn't pay one-time generation
        theta0 = jnp.asarray(
            0.01 * np.random.default_rng(0).normal(size=(d, 2 * m)),
            jnp.float32)
        reps = 1 if smoke else REPS
        best = {}
        for mode in (False, True):
            runs = [_run_mode(stream, theta0, window=W, inner=inner,
                              overlap=mode) for _ in range(reps)]
            best[mode] = max(runs, key=lambda r: r["steps_per_sec"])
        sync, over = best[False], best[True]
        # the planner must not change the trajectory
        assert sync["fs"] == over["fs"], (sync["fs"], over["fs"])
        np.testing.assert_array_equal(sync["theta"], over["theta"])
        speedup = over["steps_per_sec"] / sync["steps_per_sec"]
        speedups.append(speedup)
        steps = days * inner
        rows.append((f"stream/sync/{tag}", sync["wall_s"] * 1e6 / steps,
                     f"{sync['steps_per_sec']:.2f}steps_per_sec"))
        rows.append((f"stream/overlap/{tag}", over["wall_s"] * 1e6 / steps,
                     f"{over['steps_per_sec']:.2f}steps_per_sec"))
        rows.append((f"stream/overlap_speedup/{tag}", 0.0,
                     f"{speedup:.2f}x_vs_sync_ratio{over['overlap_ratio']:.2f}"))
        results[tag] = {
            "days": days, "sessions_per_day": G, "d": d, "m": m,
            "active_user": au, "active_ad": ad, "window": W,
            "inner_iters": inner,
            "sync_wall_s": sync["wall_s"],
            "sync_steps_per_sec": sync["steps_per_sec"],
            "overlap_wall_s": over["wall_s"],
            "overlap_steps_per_sec": over["steps_per_sec"],
            "overlap_build_s": over["build_s"],
            "overlap_exposed_s": over["exposed_s"],
            "overlap_ratio": over["overlap_ratio"],
            "speedup": speedup,
            "parity": "ok",
        }

    geomean = float(np.exp(np.mean(np.log(speedups))))
    cpus = os.cpu_count() or 1
    enforced = STREAM_TARGET_SPEEDUP if cpus >= MIN_CPUS_FOR_TARGET \
        else TWO_CORE_FLOOR
    rows.append(("stream/overlap_speedup/geomean", 0.0,
                 f"{geomean:.2f}x_vs_sync"))
    if collect is not None:
        collect["geomean_speedup"] = geomean
        collect["cpus"] = cpus
        collect["enforced_target"] = enforced
    # decay table + row emission run BEFORE the enforcement raise: a
    # failed speedup gate must not discard the measured rows or the
    # CI-archived decay section
    _decay_table(smoke, collect, rows)
    emit(rows)
    if enforce and not smoke and geomean < enforced:
        raise AssertionError(
            f"overlapped planner geomean only {geomean:.2f}x vs synchronous "
            f"re-planning (enforced target {enforced}x on {cpus} cpus, "
            f"design target {STREAM_TARGET_SPEEDUP}x); per-config: "
            f"{[round(s, 2) for s in speedups]}")
    return results
